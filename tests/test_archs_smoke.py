"""Per-architecture smoke tests: REDUCED config of each assigned arch family
runs one forward/train step on CPU, asserting output shapes + no NaNs.
(The FULL configs are exercised via the dry-run; see launch/dryrun.py.)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compat
from repro.configs.registry import ARCHS
from repro.launch.mesh import make_local_mesh
from repro.optim.adamw import AdamWConfig, adamw_init

LM_ARCHS = [a for a in ARCHS if a in (
    "olmoe-1b-7b", "granite-moe-3b-a800m", "qwen2.5-32b", "gemma3-1b",
    "deepseek-67b")]
GNN_ARCHS = ["schnet", "graphcast", "gat-cora", "meshgraphnet"]


def _finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.fixture(scope="module")
def mesh():
    return make_local_mesh(data=1, model=1)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch, mesh):
    import importlib

    from repro.launch.train import reduced_lm
    from repro.models import transformer as T

    cfg = reduced_lm(importlib.import_module(ARCHS[arch]).CONFIG)
    params = T.init_params(jax.random.PRNGKey(0), cfg, ep=1)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab)
    with compat.set_mesh(mesh):
        logits, aux, _ = T.forward(params, tokens, cfg, mesh, False)
        assert logits.shape == (2, 32, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(
            logits[..., : cfg.vocab].astype(jnp.float32))))
        # one train step moves the loss machinery end to end
        step = jax.jit(T.make_train_step(cfg, mesh, AdamWConfig(), False))
        p2, s2, m = step(params, adamw_init(params), {
            "tokens": tokens, "labels": labels})
        assert np.isfinite(m["loss"]) and _finite(p2)
        # decode one token
        kc, vc = T.init_decode_cache(cfg, 2, 64)
        serve = jax.jit(T.make_serve_step(cfg, mesh, False))
        nxt, kc2, vc2 = serve(params, kc, vc, jnp.int32(0), tokens[:, 0])
        assert nxt.shape == (2,) and int(nxt.max()) < cfg.vocab
        assert _finite((kc2, vc2))


def _reduced_gnn_cfg(arch, cfg):
    if arch == "schnet":
        return dataclasses.replace(cfg, n_interactions=2, d_hidden=16,
                                   n_rbf=8)
    if arch == "graphcast":
        return dataclasses.replace(cfg, n_layers=2, d_hidden=16, n_vars=6)
    if arch == "gat-cora":
        return dataclasses.replace(cfg, d_in=12, n_classes=3)
    return dataclasses.replace(cfg, n_layers=2, d_hidden=16, d_node_in=8)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke(arch, mesh):
    import importlib

    from repro.configs.registry import _gnn_module
    from repro.data.graphs import make_full_graph
    from repro.optim.adamw import adamw_update

    cfg = _reduced_gnn_cfg(arch, importlib.import_module(ARCHS[arch]).CONFIG)
    mod = _gnn_module(arch)
    d_feat = {"schnet": 1, "graphcast": 6, "gat-cora": 12,
              "meshgraphnet": 8}[arch]
    g = make_full_graph(arch, n=40, e=96, e_cap=96, d_feat=d_feat,
                        n_classes=3)
    g = jax.tree.map(jnp.asarray, g)
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    loss = mod.loss_fn(params, g, cfg)
    assert np.isfinite(float(loss))
    grads = jax.grad(mod.loss_fn)(params, g, cfg)
    p2, s2, m = adamw_update(AdamWConfig(), grads, adamw_init(params), params)
    assert _finite(p2) and np.isfinite(float(m["grad_norm"]))


def test_deepfm_smoke(mesh):
    from repro.data.recsys import CTRPipeline
    from repro.models.recsys import deepfm as D
    from repro.optim.adamw import adamw_update

    cfg = D.DeepFMConfig(n_sparse=6, embed_dim=4, mlp_dims=(16, 16),
                         rows_per_field=50)
    params = D.init_params(jax.random.PRNGKey(0), cfg)
    pipe = CTRPipeline(n_sparse=6, rows_per_field=50, batch=32)
    b = next(pipe)
    logits = D.forward(params, jnp.asarray(b["ids"]), cfg)
    assert logits.shape == (32,) and _finite(logits)
    grads = jax.grad(D.bce_loss)(params, jnp.asarray(b["ids"]),
                                 jnp.asarray(b["labels"]), cfg)
    p2, _, m = adamw_update(AdamWConfig(), grads, adamw_init(params), params)
    assert _finite(p2)
    scores = D.retrieval_scores(
        params, jnp.asarray(b["ids"][:1]),
        jnp.asarray(b["ids"][:, :3] % 50), cfg)
    assert scores.shape == (32,) and _finite(scores)


def test_gnn_minibatch_pipeline_smoke(mesh):
    import importlib

    from repro.configs.registry import _gnn_module
    from repro.data.graphs import MinibatchPipeline

    cfg = _reduced_gnn_cfg(
        "gat-cora", importlib.import_module(ARCHS["gat-cora"]).CONFIG)
    pipe = MinibatchPipeline("gat-cora", n_nodes=500, n_edges=4000,
                             d_feat=12, n_classes=3, batch_nodes=8,
                             fanout=(3, 2))
    g = jax.tree.map(jnp.asarray, next(pipe))
    mod = _gnn_module("gat-cora")
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    assert np.isfinite(float(mod.loss_fn(params, g, cfg)))


def test_molecule_batch_smoke(mesh):
    import importlib

    from repro.configs.registry import _gnn_module
    from repro.data.graphs import make_molecule_batch

    cfg = _reduced_gnn_cfg(
        "schnet", importlib.import_module(ARCHS["schnet"]).CONFIG)
    g = jax.tree.map(jnp.asarray,
                     make_molecule_batch("schnet", 10, 24, 4, 1))
    mod = _gnn_module("schnet")
    params = mod.init_params(jax.random.PRNGKey(0), cfg)
    energies = mod.apply(params, g, cfg)
    assert energies.shape == (4,) and _finite(energies)
