"""Fallback stand-ins for `hypothesis` so test modules collect without it.

The property tests are kept when hypothesis is installed (it's in
requirements-dev.txt); without it they become individually-skipped tests
instead of failing the whole module at import time. Usage:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

import pytest

HAVE_HYPOTHESIS = False


class _Stub:
    """Absorbs any strategy-building expression (st.lists(...), composites)."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self


st = _Stub()


def given(*args, **kwargs):
    def decorate(fn):
        @pytest.mark.skip(reason="hypothesis not installed (see requirements-dev.txt)")
        def skipped():
            pass

        skipped.__name__ = fn.__name__
        skipped.__doc__ = fn.__doc__
        return skipped

    return decorate


def settings(*args, **kwargs):
    def decorate(fn):
        return fn

    return decorate
