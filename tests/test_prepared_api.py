"""Prepared-query API: logical algebra, FILTER/OPTIONAL/LIMIT through the
compiled pipeline, PreparedQuery handles, typed server results, plan-cache
eviction and the overflow->regrow->recompile fallback."""
import numpy as np
import pytest

from repro.sparql import algebra, lubm
from repro.sparql.baseline import reference_rows
from repro.sparql.engine import PreparedQuery, QueryEngine, ResultSet
from repro.sparql.parser import ParseError, parse
from repro.sparql.store import store_from_string_triples

UB = "http://swat.cse.lehigh.edu/onto/univ-bench.owl#"
RDF_TYPE = "<http://www.w3.org/1999/02/22-rdf-syntax-ns#type>"
PREFIX = f"PREFIX ub: <{UB}>\n"


def rows_as_sets(rows):
    return sorted(tuple(sorted(r.items())) for r in rows)


def student_store(n_students=15, n_with_advisor=12):
    """Students, most with advisors, all with a numeric age and a name."""
    triples = []
    for i in range(n_students):
        s = f"<s{i}>"
        triples.append((s, RDF_TYPE, f"<{UB}Student>"))
        if i < n_with_advisor:
            triples.append((s, f"<{UB}advisor>", f"<p{i % 4}>"))
        triples.append((s, f"<{UB}age>", str(18 + i)))
        triples.append((s, f"<{UB}name>", f'"student{i}"'))
    return store_from_string_triples(triples)


# ------------------------------------------------------------------ parser


def test_parser_line_comments_and_numbers():
    q = parse(
        "# leading comment\n"
        "SELECT ?x ?a WHERE {\n"
        "  ?x <age> ?a .  # trailing comment\n"
        "  FILTER (?a >= 21)\n"
        "} LIMIT 5 OFFSET 2"
    )
    assert len(q.patterns) == 1
    assert q.filters[0].op == ">="
    assert isinstance(q.filters[0].rhs, algebra.NumLit)
    assert q.filters[0].rhs.value == 21.0
    assert q.limit == 5 and q.offset == 2


def test_parser_numeric_literal_in_triple_object():
    q = parse("SELECT ?x WHERE { ?x <age> 42 . }")
    assert q.patterns[0].o == "42"
    q = parse("SELECT ?x WHERE { ?x <temp> -3.5 . }")
    assert q.patterns[0].o == "-3.5"


def test_parser_optional_and_filter_kinds():
    q = parse(PREFIX + """SELECT ?x ?y WHERE {
        ?x a ub:Student .
        OPTIONAL { ?x ub:advisor ?y }
        FILTER (?x != ?y)
        FILTER (?n = "bob" && ?n != ?x)
        ?x ub:name ?n .
    }""")
    assert len(q.patterns) == 2  # required BGP gathers around the OPTIONAL
    assert len(q.optionals) == 1 and len(q.optionals[0]) == 1
    assert [c.op for c in q.filters] == ["!=", "=", "!="]
    assert isinstance(q.filters[1].rhs, algebra.TermLit)
    tree = q.algebra()
    assert isinstance(tree, algebra.Project)
    assert isinstance(tree.child, algebra.Filter)
    assert isinstance(tree.child.child, algebra.LeftJoin)


def test_parser_errors():
    for bad in [
        "SELECT ?x WHERE { ?x <p> ?y . } LIMIT -1",
        "SELECT ?x WHERE { ?x <p> ?y . } LIMIT 2 LIMIT 3",
        "SELECT ?x WHERE { ?x <p> ?y . FILTER (?z = 1) }",  # unbound ?z
        "SELECT ?x WHERE { ?x <p> ?y . FILTER (3 < ?y) }",  # lhs not a var
        'SELECT ?x WHERE { ?x <p> ?y . FILTER (?y < "s") }',  # ordered str
        "SELECT ?x WHERE { OPTIONAL { ?x <p> ?y } }",  # no required BGP
        "SELECT ?x WHERE { ?x <p> ?y . OPTIONAL { } }",
        "SELECT ?x WHERE { ?x <p> ?y . OPTIONAL { OPTIONAL { ?x <q> ?z } } }",
        "SELECT ?x WHERE { ?x <p> ?y . } garbage",
    ]:
        with pytest.raises(ParseError):
            parse(bad)


# ------------------------------------------------- acceptance (ISSUE 2)


ACCEPTANCE = (
    PREFIX
    + "SELECT ?x ?y WHERE { ?x a ub:Student . "
    "OPTIONAL { ?x ub:advisor ?y } FILTER (?x != ?y) } LIMIT 10"
)


def test_acceptance_query_compiled_and_cached():
    """The ISSUE acceptance query: parses, compiles to one cached device
    program, returns correct rows vs the NumPy reference; a warm repeat is
    0 compiles / 1 dispatch."""
    store = student_store()
    eng = QueryEngine(store)
    pq = eng.prepare(ACCEPTANCE)
    cold = pq.run()
    assert cold.stats.cache_misses == 1 and cold.stats.n_compiles == 1
    warm = pq.run()
    assert warm.stats.n_compiles == 0
    assert warm.stats.n_dispatches == 1
    assert warm.stats.cache_hits == 1

    q = parse(ACCEPTANCE)
    full = reference_rows(store, q)  # pre-slice oracle
    # FILTER(?x != ?y) errors out unbound ?y rows: only advised students
    assert len(full) == 12
    for result in (cold, warm):
        assert len(result) == min(10, len(full))
        ref_set = set(rows_as_sets(full))
        for row in result:
            assert tuple(sorted(row.items())) in ref_set


def test_acceptance_query_eager_matches_reference():
    store = student_store()
    eng = QueryEngine(store, compiled=False)
    rows = eng.query(ACCEPTANCE)
    full = reference_rows(store, parse(ACCEPTANCE))
    assert len(rows) == min(10, len(full))
    assert set(rows_as_sets(rows)) <= set(rows_as_sets(full))


# ------------------------------------------------- FILTER differential


@pytest.mark.parametrize("compiled", [True, False])
@pytest.mark.parametrize("cond", [
    "?a >= 25", "?a < 21", "?a = 20", "?a != 20", "?a > 18.5",
    '?n = "student3"', '?n != "student3"', "?x != ?n",
])
def test_filter_matches_reference(compiled, cond):
    store = student_store()
    eng = QueryEngine(store, compiled=compiled)
    text = (PREFIX + "SELECT ?x ?a ?n WHERE { ?x ub:age ?a . "
            f"?x ub:name ?n . FILTER ({cond}) }}")
    got = eng.query(text)
    want = reference_rows(store, parse(text))
    assert rows_as_sets(got) == rows_as_sets(want), cond


def test_filter_numeric_compares_by_value_not_identity():
    triples = [("<a>", "<v>", "5"), ("<b>", "<v>", "5.0"),
               ("<c>", "<v>", '"5"'), ("<d>", "<v>", "6")]
    store = store_from_string_triples(triples)
    for compiled in (True, False):
        eng = QueryEngine(store, compiled=compiled)
        rows = eng.query("SELECT ?x WHERE { ?x <v> ?v . FILTER (?v = 5) }")
        # "5" and "5.0" compare equal by value; the string '"5"' errors out
        assert sorted(r["?x"] for r in rows) == ["<a>", "<b>"]


def test_filter_constants_share_one_compiled_program():
    """Same filter structure, different constant -> plan-cache hit (the
    constant rides in as a runtime input, not a compiled shape)."""
    store = student_store()
    eng = QueryEngine(store)
    text = PREFIX + "SELECT ?x WHERE {{ ?x ub:age ?a . FILTER (?a > {c}) }}"
    r1 = eng.prepare(text.format(c=20)).run()
    assert r1.stats.cache_misses == 1 and r1.stats.n_compiles == 1
    r2 = eng.prepare(text.format(c=28)).run()
    assert r2.stats.cache_hits == 1 and r2.stats.n_compiles == 0
    want = reference_rows(
        store, parse(text.format(c=28)))
    assert rows_as_sets(r2.rows) == rows_as_sets(want)


# ------------------------------------------------ OPTIONAL differential


@pytest.mark.parametrize("compiled", [True, False])
def test_optional_pads_unmatched_with_unbound(compiled):
    store = student_store(n_students=8, n_with_advisor=5)
    eng = QueryEngine(store, compiled=compiled)
    text = PREFIX + """SELECT ?x ?y WHERE {
        ?x a ub:Student . OPTIONAL { ?x ub:advisor ?y } }"""
    got = eng.query(text)
    want = reference_rows(store, parse(text))
    assert rows_as_sets(got) == rows_as_sets(want)
    assert len(got) == 8
    assert sum(1 for r in got if "?y" not in r) == 3  # unbound omitted


@pytest.mark.parametrize("compiled", [True, False])
def test_multi_pattern_optional_group(compiled):
    store = student_store()
    eng = QueryEngine(store, compiled=compiled)
    text = PREFIX + """SELECT ?x ?y ?a WHERE {
        ?x a ub:Student .
        OPTIONAL { ?x ub:advisor ?y . ?x ub:age ?a }
    }"""
    got = eng.query(text)
    want = reference_rows(store, parse(text))
    assert rows_as_sets(got) == rows_as_sets(want)


def test_optional_must_share_a_variable():
    store = student_store()
    eng = QueryEngine(store)
    with pytest.raises(ValueError):
        eng.prepare(PREFIX + """SELECT ?x WHERE {
            ?x a ub:Student . OPTIONAL { ?z ub:name ?n } }""")


@pytest.mark.parametrize("compiled", [True, False])
def test_chained_optionals_on_required_vars(compiled):
    """Multiple OPTIONAL groups are fine when each joins through
    always-bound (required) variables."""
    store = student_store(n_students=8, n_with_advisor=5)
    eng = QueryEngine(store, compiled=compiled)
    text = PREFIX + """SELECT ?x ?y ?n WHERE {
        ?x a ub:Student .
        OPTIONAL { ?x ub:advisor ?y }
        OPTIONAL { ?x ub:name ?n }
    }"""
    got = eng.query(text)
    want = reference_rows(store, parse(text))
    assert rows_as_sets(got) == rows_as_sets(want)


def test_chained_optional_through_unbound_var_rejected():
    """An OPTIONAL group joining on a variable a previous OPTIONAL may
    have left UNBOUND is rejected: SPARQL's unbound-compatible left-join
    semantics are not implemented, so answering would be silently wrong."""
    triples = [("<s1>", "<p>", "<o1>"), ("<s2>", "<p>", "<o2>"),
               ("<o1>", "<q>", "<z1>"), ("<z1>", "<r>", "<w1>"),
               ("<z9>", "<r>", "<w9>")]
    eng = QueryEngine(store_from_string_triples(triples))
    with pytest.raises(ValueError, match="earlier OPTIONAL"):
        eng.prepare("""SELECT * WHERE { ?x <p> ?y .
            OPTIONAL { ?y <q> ?z } OPTIONAL { ?z <r> ?w } }""")


# ------------------------------------------------------- LIMIT / OFFSET


@pytest.mark.parametrize("compiled", [True, False])
def test_limit_offset_counts(compiled):
    store = student_store()
    eng = QueryEngine(store, compiled=compiled)
    base = PREFIX + "SELECT ?x WHERE { ?x a ub:Student . }"
    assert len(eng.query(base)) == 15
    assert len(eng.query(base + " LIMIT 4")) == 4
    assert len(eng.query(base + " LIMIT 4 OFFSET 13")) == 2  # tail clamp
    assert len(eng.query(base + " OFFSET 6")) == 9
    assert len(eng.query(base + " LIMIT 0")) == 0
    # sliced rows are a subset of the full result
    full = set(rows_as_sets(eng.query(base)))
    assert set(rows_as_sets(eng.query(base + " LIMIT 7"))) <= full


def test_limits_share_one_compiled_program():
    store = student_store()
    eng = QueryEngine(store)
    base = PREFIX + "SELECT ?x WHERE { ?x a ub:Student . } LIMIT "
    r1 = eng.prepare(base + "3").run()
    r2 = eng.prepare(base + "9").run()
    assert r1.stats.cache_misses == 1
    assert r2.stats.cache_hits == 1 and r2.stats.n_compiles == 0
    assert (len(r1), len(r2)) == (3, 9)


# --------------------------------------------- PreparedQuery / ResultSet


def test_prepare_run_returns_typed_result():
    store = student_store()
    eng = QueryEngine(store)
    pq = eng.prepare(PREFIX + "SELECT ?x ?a WHERE { ?x ub:age ?a . }")
    assert isinstance(pq, PreparedQuery)
    rs = pq.run()
    assert isinstance(rs, ResultSet)
    assert rs.vars == ("?x", "?a")
    assert len(rs) == 15 and rs[0].keys() == {"?x", "?a"}
    assert rs == rs.rows  # list back-compat
    assert pq.n_runs == 1 and pq.last_stats is rs.stats
    pq.run()
    assert pq.n_runs == 2
    assert pq.stats.n_dispatches >= rs.stats.n_dispatches + 1


def test_explain_reports_plan_and_cache_state():
    store = student_store()
    eng = QueryEngine(store)
    pq = eng.prepare(ACCEPTANCE)
    cold = pq.explain()
    assert "LeftJoin" in cold and "Filter(?x != ?y)" in cold
    assert "Slice(offset=0, limit=10)" in cold
    assert "not compiled yet" in cold
    assert "scan[0]" in cold and "bucket=" in cold
    pq.run()
    warm = pq.explain()
    assert "cache: compiled, join buckets=" in warm
    assert "1 run(s)" in warm


def test_engine_query_is_thin_wrapper():
    store = student_store()
    eng = QueryEngine(store)
    text = PREFIX + "SELECT ?x WHERE { ?x a ub:Student . } LIMIT 3"
    assert eng.query(text) == eng.prepare(text).run().rows


# --------------------------------- plan cache: FIFO eviction + overflow


def test_plan_cache_fifo_eviction_at_max_entries():
    store = student_store()
    eng = QueryEngine(store, plan_cache_entries=2)
    q1 = PREFIX + "SELECT ?x WHERE { ?x a ub:Student . }"
    q2 = PREFIX + "SELECT ?x ?a WHERE { ?x ub:age ?a . ?x ub:name ?n . }"
    q3 = PREFIX + """SELECT ?x ?a WHERE {
        ?x a ub:Student . ?x ub:age ?a . ?x ub:name ?n . }"""
    for q in (q1, q2, q3):  # third insert evicts the first (FIFO)
        assert eng.prepare(q).run().stats.cache_misses == 1
    assert len(eng.plan_cache) == 2
    r2 = eng.prepare(q2).run()
    assert r2.stats.cache_hits == 1  # survivor still cached
    r1 = eng.prepare(q1).run()
    assert r1.stats.cache_misses == 1  # evicted: recompiles
    assert len(eng.plan_cache) == 2


def test_overflow_regrow_recompile_with_optional_shape():
    """Warm-cache bucket overflow on a FILTER+OPTIONAL shape: the engine
    grows the flagged bucket from the exact totals and recompiles."""
    triples = [("<z>", "<p0>", "<w>")]
    triples += [("<h>", "<p0>", f"<v{i}>") for i in range(40)]
    triples += [("<z>", "<p1>", "<c1>"), ("<h>", "<p1>", "<c2>")]
    triples += [("<z>", "<opt>", "<o1>")]
    store = store_from_string_triples(triples)
    eng = QueryEngine(store)

    def q(const):
        return (f"SELECT ?x ?y ?o WHERE {{ ?x <p0> ?y . ?x <p1> <{const}> . "
                "OPTIONAL { ?x <opt> ?o } FILTER (?x != ?y) }")

    r1 = eng.prepare(q("c1")).run()  # cold: tiny calibrated buckets
    assert len(r1) == 1 and r1.stats.n_compiles == 1
    r2 = eng.prepare(q("c2")).run()  # warm hit, 40x the join size
    assert r2.stats.cache_hits == 1
    assert r2.stats.n_retries >= 1 and r2.stats.n_compiles >= 1
    want = reference_rows(store, parse(q("c2")))
    assert rows_as_sets(r2.rows) == rows_as_sets(want)
    assert len(r2) == 40
    r3 = eng.prepare(q("c2")).run()  # grown bucket now cached
    assert r3.stats.n_retries == 0 and r3.stats.n_compiles == 0
    assert r3.stats.n_dispatches == 1


# ------------------------------------------------------- typed serving


def test_server_returns_query_result_envelope():
    from repro.serve.sparql_server import QueryResult, SPARQLServer

    store = student_store()
    srv = SPARQLServer(QueryEngine(store), max_batch=2)
    try:
        res = srv.query(PREFIX + "SELECT ?x WHERE { ?x a ub:Student . }")
        assert isinstance(res, QueryResult)
        assert res.vars == ("?x",)
        assert len(res) == 15 and not res.from_cache
        res2 = srv.query(PREFIX + "SELECT ?x WHERE { ?x a ub:Student . }")
        assert res2.from_cache  # PreparedQuery handle reused
        stats = srv.stats()
        assert stats["prepared_cache"]["hits"] == 1
        assert stats["prepared_cache"]["misses"] == 1
    finally:
        srv.close()


def test_server_raises_typed_errors_on_caller_thread():
    from repro.serve.sparql_server import (
        ParseQueryError,
        QueryError,
        SPARQLServer,
    )

    store = student_store()
    srv = SPARQLServer(QueryEngine(store), max_batch=2)
    try:
        with pytest.raises(ParseQueryError) as ei:
            srv.query("SELECT garbage")
        assert ei.value.kind == "parse"
        assert isinstance(ei.value, ParseError)  # back-compat
        with pytest.raises(QueryError) as ei:
            srv.query(PREFIX + """SELECT ?x WHERE {
                ?x a ub:Student . OPTIONAL { ?z ub:foo ?n } }""")
        assert ei.value.kind == "plan"
        # worker thread survived; later requests still serve
        assert len(srv.query(
            PREFIX + "SELECT ?x WHERE { ?x a ub:Student . }")) == 15
    finally:
        srv.close()


# ------------------------------------------------------- LUBM coverage


def test_filter_optional_on_lubm_matches_eager():
    store = lubm.generate(scale=1, seed=0)
    compiled = QueryEngine(store)
    eager = QueryEngine(store, compiled=False)
    text = lubm.PREFIX + """SELECT ?p ?n ?d WHERE {
        ?p a ub:FullProfessor .
        ?p ub:name ?n .
        OPTIONAL { ?p ub:worksFor ?d }
        FILTER (?n != "prof_0_0_0")
    }"""
    for _ in range(2):  # cold then warm
        assert rows_as_sets(compiled.query(text)) == rows_as_sets(
            eager.query(text))


def test_unbound_sentinel_never_collides_with_terms():
    # dictionary ids are dense and non-negative; UNBOUND is -1
    from repro.core.relation import UNBOUND

    store = student_store()
    assert UNBOUND == -1
    assert all(
        store.dictionary.lookup(t) >= 0
        for t in ("<s0>", f"<{UB}Student>")
    )
    vals = store.dictionary.numeric_values()
    assert np.isnan(vals).any() and np.isfinite(vals).any()
