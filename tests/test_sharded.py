"""Sharded store + distributed query execution.

In-process tests run on the default 1-device mesh (XLA locks the device
count at first jax import); real device counts {2, 4, 8} run the same
differential sweep through tests/distributed/sharded_query_prog.py in
subprocesses, exactly like tests/test_distributed.py.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypothesis_compat import given, settings, st  # noqa: F401

from repro.core.planner import TriplePattern
from repro.sparql import lubm
from repro.sparql.baseline import reference_rows
from repro.sparql.engine import QueryEngine, ShardedQueryEngine
from repro.sparql.parser import parse
from repro.sparql.sharded_store import (
    ShardedTripleStore,
    shard_store,
    sharded_store_from_string_triples,
    subject_shard,
)
from repro.sparql.store import StoreStatistics, store_from_string_triples

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def rows_as_sets(rows):
    return sorted(tuple(sorted(r.items())) for r in rows)


def _mini_store(seed: int):
    rng = np.random.default_rng(seed)
    ents = [f"<e{i}>" for i in range(6)]
    triples = set()
    for _ in range(40):
        triples.add((
            ents[rng.integers(6)],
            f"<p{rng.integers(3)}>",
            ents[rng.integers(6)],
        ))
    for i in range(6):
        triples.add((ents[i], "<age>", str(15 + 3 * i)))
    return sorted(triples)


def _query_text(shape, p1, p2, cmp_op, cut):
    base = f"?x <p{p1}> ?y"
    if shape == "bgp":
        return f"SELECT ?x ?y ?z WHERE {{ {base} . ?y <p{p2}> ?z . }}"
    if shape == "filter":
        return (f"SELECT ?x ?y ?a WHERE {{ {base} . ?x <age> ?a . "
                f"FILTER (?a {cmp_op} {cut} || ?x = <e1>) }}")
    if shape == "optional":
        return (f"SELECT ?x ?y ?z WHERE {{ {base} . "
                f"OPTIONAL {{ ?x <p{p2}> ?z }} }}")
    assert shape == "union"
    return (f"SELECT ?x ?v WHERE {{ {{ ?x <p{p1}> ?v }} UNION "
            f"{{ ?x <p{p2}> ?v }} }}")


# --------------------------------------------------- store partitioning


def test_partition_disjoint_and_covering():
    store = lubm.generate(scale=1, seed=0)
    ss = shard_store(store, 4)
    sizes = ss.shard_sizes()
    assert sum(sizes) == len(store.triples)
    assert all(n > 0 for n in sizes)  # LUBM subjects spread over 4 shards
    # every triple lives on exactly the shard its subject hashes to
    owner = subject_shard(store.triples[:, 0], 4)
    for k, shard in enumerate(ss.shards):
        assert (subject_shard(shard.triples[:, 0], 4) == k).all()
        assert len(shard) == int((owner == k).sum())


def test_same_subject_same_shard():
    ss = sharded_store_from_string_triples(
        [("<a>", "<p>", "<x>"), ("<a>", "<q>", "<y>"),
         ("<b>", "<p>", "<x>")], n_shards=8
    )
    a = ss.dictionary.lookup("<a>")
    k = int(subject_shard(np.array([a]), 8)[0])
    assert len([t for t in ss.shards[k].triples if t[0] == a]) == 2


def test_statistics_merge_exact_counts():
    store = lubm.generate(scale=1, seed=1)
    ss = shard_store(store, 4)
    merged = ss.statistics
    exact = StoreStatistics.from_triples(store.triples)
    assert merged.n_triples == exact.n_triples
    # subject-hash sharding: distinct subjects are disjoint -> sums exact
    assert merged.n_subjects == exact.n_subjects
    assert merged.n_predicates == exact.n_predicates
    for pid, ps in exact.predicates.items():
        assert merged.predicates[pid].count == ps.count
        assert merged.predicates[pid].n_subjects == ps.n_subjects
        # objects overlap between shards: merge reports a lower bound
        assert merged.predicates[pid].n_objects <= ps.n_objects


def test_estimate_cardinality_sums_shards():
    store = lubm.generate(scale=1, seed=0)
    ss = shard_store(store, 4)
    tp = TriplePattern("?s", lubm.RDF_TYPE,
                       f"<{lubm.UB}GraduateStudent>")
    assert ss.estimate_cardinality(tp) == store.estimate_cardinality(tp)
    assert sum(ss.per_shard_counts(tp)) == store.estimate_cardinality(tp)


def test_scan_blocks_are_per_shard_partitions():
    store = lubm.generate(scale=1, seed=0)
    ss = shard_store(store, 4)
    tp = TriplePattern("?s", f"<{lubm.UB}memberOf>", "?d")
    rel = ss.match_pattern_device(tp)
    cap = rel.capacity // 4
    counts = ss.per_shard_counts(tp)
    valid = np.asarray(rel.valid)
    for k in range(4):
        assert int(valid[k * cap:(k + 1) * cap].sum()) == counts[k]
    # upload-once: second fetch is a cache hit rebinding schema only
    again = ss.match_pattern_device(tp)
    assert ss.scan_cache_stats()["hits"] == 1
    assert again.schema == rel.schema


# --------------------------------------------------- engine construction


def test_engine_rejects_plain_store():
    store = lubm.generate(scale=1, seed=0)
    with pytest.raises(TypeError):
        ShardedQueryEngine(store)


def test_engine_rejects_shard_count_mismatch():
    store = lubm.generate(scale=1, seed=0)
    with pytest.raises(ValueError):
        ShardedQueryEngine(shard_store(store, 3))  # 1-device mesh


def test_engine_rejects_eager_mode():
    store = lubm.generate(scale=1, seed=0)
    with pytest.raises(ValueError):
        ShardedQueryEngine(shard_store(store, 1), compiled=False)


# ------------------------------------------- differential (1-device mesh)


@pytest.fixture(scope="module")
def engines():
    store = lubm.generate(scale=1, seed=0)
    return store, QueryEngine(store), ShardedQueryEngine(
        shard_store(store, 1)
    )


@pytest.mark.parametrize("name", sorted(lubm.QUERIES))
def test_lubm_queries_match_single_device(engines, name):
    store, single, sharded = engines
    text = lubm.QUERIES[name]
    want = rows_as_sets(reference_rows(store, parse(text)))
    assert rows_as_sets(single.query(text)) == want
    assert rows_as_sets(sharded.query(text)) == want


def test_warm_query_one_dispatch_zero_compiles(engines):
    _, _, sharded = engines
    pq = sharded.prepare(lubm.QUERIES["Q2"])
    pq.run()
    warm = pq.run()
    assert warm.stats.n_dispatches == 1
    assert warm.stats.n_compiles == 0
    assert warm.stats.cache_hits == 1


def test_explain_shows_shard_buckets(engines):
    _, _, sharded = engines
    pq = sharded.prepare(lubm.QUERIES["Q2"])
    pq.run()
    out = pq.explain()
    assert "sharded: 1 shard(s)" in out
    assert "per-shard rows=" in out
    assert "shuffle buckets=" in out


def test_explain_analyze_reports_backends_and_shuffles(engines):
    """Sharded EXPLAIN ANALYZE: per-join estimated vs actual rows plus
    the distributed decisions — worst-shard rows per join slot and the
    per-site shuffle strategy (emitted/elided/broadcast)."""
    _, _, sharded = engines
    pq = sharded.prepare(lubm.QUERIES["Q2"])
    pq.run()
    out = pq.explain(analyze=True)
    assert "EXPLAIN ANALYZE (last run):" in out
    assert "est_rows=" in out and "actual_rows=" in out
    assert "worst_shard_rows=" in out
    assert "mr_join" in out or "matrix_join" in out
    assert "data movement:" in out
    # actuals line up with the decoded result and the estimator slots
    st = pq.last_stats
    assert len(st.join_totals) >= 1
    assert all(t >= 0 for t in st.join_totals)


def test_run_batch_stacks_same_shape_queries(engines):
    """Warm same-shape queries ride ONE stacked mesh dispatch (lanes x
    shards) — the sharded engine no longer falls back to sequential."""
    store, _, sharded = engines
    text = lubm.QUERIES["Q2"]
    sharded.query(text)  # warm the shape
    prepared = [sharded.prepare(text) for _ in range(3)]
    out = sharded.run_batch(prepared)
    want = rows_as_sets(reference_rows(store, parse(text)))
    assert all(rows_as_sets(r.rows) == want for r in out)
    group = sharded.last_batch[0]
    assert not group.fallback
    assert group.n_dispatches == 1  # one launch for the whole chunk
    assert group.widths == (4,)  # 3 lanes bucketed to the pow-2 width


def test_run_batch_mixed_shapes_isolated_per_group(engines):
    store, _, sharded = engines
    prepared = [sharded.prepare(lubm.QUERIES["Q1"]),
                sharded.prepare(lubm.QUERIES["Q4"])]
    out = sharded.run_batch(prepared)
    assert [rows_as_sets(r.rows) for r in out] == [
        rows_as_sets(reference_rows(store, parse(p.text)))
        for p in prepared
    ]
    assert len(sharded.last_batch) == 2  # one group per plan shape


def test_save_cache_roundtrips_shuffle_caps(tmp_path, engines):
    store, _, _ = engines
    eng = ShardedQueryEngine(shard_store(store, 1))
    pq = eng.prepare(lubm.QUERIES["Q7"])
    pq.run()
    path = tmp_path / "warm.json"
    assert eng.save_cache(str(path)) >= 1
    data = json.loads(path.read_text())
    assert all("shuffle_caps" in e for e in data["entries"])
    # restart: compiles straight at the persisted caps — no calibration
    eng2 = ShardedQueryEngine(shard_store(store, 1),
                              warmup_path=str(path))
    rs = eng2.prepare(lubm.QUERIES["Q7"]).run()
    assert rs.stats.n_count_passes == 0
    assert rs.stats.n_retries == 0
    assert rows_as_sets(rs.rows) == rows_as_sets(
        reference_rows(store, parse(lubm.QUERIES["Q7"])))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=7),
    shape=st.sampled_from(["bgp", "filter", "optional", "union"]),
    p1=st.integers(min_value=0, max_value=2),
    p2=st.integers(min_value=0, max_value=2),
    cmp_op=st.sampled_from(["<", ">=", "=", "!="]),
    cut=st.integers(min_value=14, max_value=32),
)
def test_sharded_matches_single_and_oracle(seed, shape, p1, p2, cmp_op, cut):
    """Property (acceptance): sharded run() == single-device run() ==
    baseline.reference_rows across BGP/FILTER/OPTIONAL/UNION. Device
    counts 2/4/8 sweep the same space via the subprocess prog."""
    triples = _mini_store(seed)
    store = store_from_string_triples(triples)
    text = _query_text(shape, p1, p2, cmp_op, cut)
    want = rows_as_sets(reference_rows(store, parse(text)))
    assert rows_as_sets(QueryEngine(store).query(text)) == want, text
    sharded = ShardedQueryEngine(
        sharded_store_from_string_triples(triples, n_shards=1)
    )
    assert rows_as_sets(sharded.query(text)) == want, text


@pytest.mark.parametrize("seed", [0, 3, 5])
@pytest.mark.parametrize("shape", ["bgp", "filter", "optional", "union"])
def test_sharded_differential_sweep_without_hypothesis(seed, shape):
    """Deterministic slice of the property space (runs even where
    hypothesis is unavailable)."""
    triples = _mini_store(seed)
    store = store_from_string_triples(triples)
    text = _query_text(shape, p1=seed % 3, p2=(seed + 1) % 3,
                       cmp_op="<" if seed % 2 else ">=", cut=18 + seed)
    want = rows_as_sets(reference_rows(store, parse(text)))
    sharded = ShardedQueryEngine(
        sharded_store_from_string_triples(triples, n_shards=1)
    )
    assert rows_as_sets(sharded.query(text)) == want, text


# ----------------------------------------------- real device counts (2/4/8)


def run_prog(relpath, *args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, relpath), *args],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, (
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    )
    return proc.stdout


@pytest.mark.parametrize("n_dev", [2, 4, 8])
def test_sharded_queries_n_devices(n_dev):
    out = run_prog("tests/distributed/sharded_query_prog.py", str(n_dev))
    assert f"ALL SHARDED QUERY CASES PASSED n_dev={n_dev}" in out
