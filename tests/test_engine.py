"""SPARQL engine end-to-end: vs a brute-force python oracle, on LUBM data,
through the parser, planner, MapReduce-join chain and the server."""
import itertools

import numpy as np
import pytest

from repro.core.planner import TriplePattern
from repro.sparql import lubm
from repro.sparql.baseline import hash_join, nested_loop_join, \
    partitioned_hash_join
from repro.sparql.engine import QueryEngine
from repro.sparql.parser import ParseError, parse
from repro.sparql.store import store_from_string_triples


def brute_force(triples, patterns: list[TriplePattern]):
    """Reference: enumerate all bindings by backtracking over patterns."""
    results = [dict()]
    for tp in patterns:
        new = []
        for binding in results:
            for s, p, o in triples:
                b = dict(binding)
                ok = True
                for term, val in ((tp.s, s), (tp.p, p), (tp.o, o)):
                    if term.startswith("?"):
                        if b.get(term, val) != val:
                            ok = False
                            break
                        b[term] = val
                    elif term != val:
                        ok = False
                        break
                if ok:
                    new.append(b)
        results = new
    return results


TRIPLES = [
    ("<anny>", "<hasJob>", "<professor>"),
    ("<jim>", "<hasJob>", "<doctor>"),
    ("<susan>", "<hasJob>", "<nurse>"),
    ("<doctor>", "<workAt>", '"Hospital"'),
    ("<nurse>", "<workAt>", '"Hospital"'),
    ("<professor>", "<workAt>", '"University"'),
]


def test_paper_intro_query():
    """The exact query from the paper's introduction (Table 1)."""
    store = store_from_string_triples(TRIPLES)
    eng = QueryEngine(store)
    rows = eng.query(
        'SELECT ?person WHERE { ?person <hasJob> ?job . '
        '?job <workAt> "Hospital" . }'
    )
    assert sorted(r["?person"] for r in rows) == ["<jim>", "<susan>"]


@pytest.mark.parametrize("exact", [True, False])
def test_engine_matches_brute_force_random(exact):
    rng = np.random.default_rng(7)
    ents = [f"<e{i}>" for i in range(12)]
    preds = [f"<p{i}>" for i in range(3)]
    triples = list({
        (ents[rng.integers(12)], preds[rng.integers(3)],
         ents[rng.integers(12)])
        for _ in range(120)
    })
    store = store_from_string_triples(triples)
    eng = QueryEngine(store, exact_count_pass=exact)
    queries = [
        [TriplePattern("?x", "<p0>", "?y"), TriplePattern("?y", "<p1>", "?z")],
        [TriplePattern("?x", "<p0>", "?y"), TriplePattern("?x", "<p1>", "?z")],
        [TriplePattern("?x", "?p", "?y"), TriplePattern("?y", "<p2>", "?z")],
        [TriplePattern("?x", "<p0>", "?y"), TriplePattern("?y", "<p1>", "?z"),
         TriplePattern("?z", "<p2>", "?w")],
    ]
    for pats in queries:
        from repro.sparql.parser import Query

        got, _ = eng.execute(Query([], False, pats))
        vars_ = got.schema
        got_set = got.to_set()
        d = store.dictionary
        want = {
            tuple(d.lookup(b[v]) for v in vars_)
            for b in brute_force(triples, pats)
        }
        assert got_set == want, f"mismatch for {pats}"


def test_engine_on_lubm_queries():
    store = lubm.generate(scale=1, seed=0)
    eng = QueryEngine(store)
    for name, text in lubm.QUERIES.items():
        rows = eng.query(text)
        # every result binds every projected var to a real term
        for r in rows:
            assert all(isinstance(v, str) and v for v in r.values())
    # Q2 must produce chains contained in Q2's department constraint
    rows = eng.query(lubm.QUERIES["Q2"])
    assert rows, "Q2 should match on scale-1 LUBM"


def test_baselines_agree_with_engine():
    store = lubm.generate(scale=1, seed=1)
    eng = QueryEngine(store)
    q = parse(lubm.QUERIES["Q2"])
    rel, _ = eng.execute(q)
    ours = rel.to_set()
    # same partial matches through the three baseline joins
    from repro.core.planner import plan_bgp

    steps = plan_bgp(q.patterns, store.estimate_cardinality)
    parts = [store.match_pattern(q.patterns[s.pattern_index]).to_numpy()
             for s in steps]
    schemas = [store.match_pattern(q.patterns[s.pattern_index]).schema
               for s in steps]
    for join in (hash_join, nested_loop_join, partitioned_hash_join):
        sch, rows = schemas[0], parts[0]
        for sch2, rows2 in zip(schemas[1:], parts[1:]):
            sch, rows = join(sch, rows, sch2, rows2)
        got = {tuple(int(x) for x in r) for r in rows}
        # align column order with ours before comparing
        idx = [sch.index(v) for v in rel.schema]
        got = {tuple(r[i] for i in idx) for r in got}
        assert got == ours, join.__name__


def test_parser_errors():
    for bad in [
        "SELECT WHERE { ?x <p> ?y . }",
        "SELECT ?x { ?x <p> ?y . }",
        "SELECT ?z WHERE { ?x <p> ?y . }",
        "PREFIX foo <bar> SELECT ?x WHERE { ?x <p> ?y . }",
        "SELECT ?x WHERE { }",
    ]:
        with pytest.raises(ParseError):
            parse(bad)


def test_distinct_and_projection():
    store = store_from_string_triples(TRIPLES)
    eng = QueryEngine(store)
    rows = eng.query(
        'SELECT DISTINCT ?place WHERE { ?job <workAt> ?place . }'
    )
    assert sorted(r["?place"] for r in rows) == ['"Hospital"', '"University"']


def test_sparql_server_batches():
    from repro.serve.sparql_server import SPARQLServer

    store = store_from_string_triples(TRIPLES)
    srv = SPARQLServer(QueryEngine(store), max_batch=4)
    import threading

    results = {}

    def ask(i):
        results[i] = srv.query(
            'SELECT ?person WHERE { ?person <hasJob> ?job . '
            '?job <workAt> "Hospital" . }'
        )

    ts = [threading.Thread(target=ask, args=(i,)) for i in range(6)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert all(len(v) == 2 for v in results.values())
    assert srv.stats()["requests"] == 6
    srv.close()
