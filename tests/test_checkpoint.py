"""Fault tolerance: checkpoint manager semantics + crash/restart training
equivalence + elastic re-shard restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.tokens import TokenPipeline
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.trainer import (SimulatedFailure, Trainer, TrainSettings,
                                 run_with_restarts)


def _tree_allclose(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), rtol=0, atol=0)


def test_manager_roundtrip_keepk_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_k=2, async_write=True)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": [jnp.int32(3)]}
    for step in (1, 2, 3, 4):
        mgr.save(step, tree, extra_meta={"pipeline": {"step": step}})
    mgr.wait()
    assert mgr.all_steps() == [3, 4]  # keep_k pruned
    got = mgr.restore(4, tree)
    _tree_allclose(got, tree)
    assert mgr.meta(4)["pipeline"]["step"] == 4


def test_manager_atomic_tmp_never_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_k=5)
    mgr.save(7, {"x": jnp.ones(3)})
    names = os.listdir(tmp_path)
    assert "step_00000007" in names
    assert not any(n.endswith(".tmp") for n in names)


CFG = T.TransformerConfig(
    name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
    d_ff=64, vocab=128, kv_chunk=8, remat=False)


def _make_trainer(tmp_path, fail_at=-1, total=12):
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh()
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    step = jax.jit(T.make_train_step(CFG, mesh, AdamWConfig(lr=1e-3), False))
    pipe = TokenPipeline(vocab=CFG.vocab, batch=4, seq=16)
    to_dev = lambda b: {k: jnp.asarray(v) for k, v in b.items()}
    return Trainer(step, params, pipe, str(tmp_path),
                   TrainSettings(total_steps=total, ckpt_every=4,
                                 log_every=0, fail_at_step=fail_at,
                                 async_ckpt=False),
                   to_device=to_dev)


def test_crash_restart_matches_uninterrupted(tmp_path):
    straight = _make_trainer(tmp_path / "a")
    straight.run()
    calls = {"n": 0}

    def factory():  # one-off preemption: only the first attempt dies
        calls["n"] += 1
        return _make_trainer(tmp_path / "b",
                             fail_at=6 if calls["n"] == 1 else -1)

    resumed = run_with_restarts(factory)
    assert resumed.step == straight.step
    _tree_allclose(straight.params, resumed.params)
    _tree_allclose(straight.opt_state["m"], resumed.opt_state["m"])


def test_restart_resumes_pipeline_position(tmp_path):
    tr = _make_trainer(tmp_path, fail_at=6, total=8)
    with pytest.raises(SimulatedFailure):
        tr.run()
    tr2 = _make_trainer(tmp_path, total=8)
    assert tr2.resume_if_possible()
    assert tr2.step == 4  # last checkpoint
    assert tr2.pipeline.step == 4  # data stream cursor restored


def test_elastic_reshard_restore(tmp_path):
    """Save on one mesh, restore onto a different sharding layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_local_mesh

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    mgr.save(1, tree)
    mesh = make_local_mesh(data=1, model=1)
    shard = {"w": NamedSharding(mesh, P(None, "model"))}
    got = mgr.restore(1, tree, shardings=shard)
    assert got["w"].sharding == shard["w"]
    _tree_allclose(got, tree)


def test_nonfinite_step_skipped(tmp_path):
    tr = _make_trainer(tmp_path, total=1)
    bad_step = lambda p, s, b: (p, s, {"loss": jnp.float32(np.nan)})
    tr.train_step = bad_step
    before = jax.tree.leaves(tr.params)[0]
    tr.run()
    after = jax.tree.leaves(tr.params)[0]
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))
    assert tr.history[-1].get("skipped") == 1.0
