"""Partitioning-aware lowering: the propagation lattice and per-site
shuffle strategies of core/dist_executor.analyze_plan.

Pure host-side static analysis (schemas + capacities only) — no mesh, no
device, so these run in tier-1. The device-level differential checks of
the same machinery live in tests/distributed/sharded_query_prog.py.
"""
import pytest

from repro.core import dist_executor as dx
from repro.core.plan_ir import (
    Distinct,
    MatrixJoin,
    MRJoin,
    PhysicalPlan,
    Project,
    Scan,
    UnionAll,
)


def scan(index, schema, cap=64, part_col=-1):
    return Scan(index=index, schema=tuple(schema), capacity=cap,
                part_col=part_col)


def join(left, right, key, cap=128, cls=MRJoin):
    schema = tuple(left.schema) + tuple(
        v for v in right.schema if v not in left.schema
    )
    return cls(left=left, right=right, key_vars=tuple(key), schema=schema,
               capacity=cap)


def plan_of(root, n_scans=2, n_joins=1):
    return PhysicalPlan(root=root, n_scans=n_scans,
                        join_caps=(128,) * n_joins)


# ------------------------------------------------------- the lattice itself


def test_partitioning_singletons_and_str():
    assert dx.UNKNOWN.kind == "unknown"
    assert dx.REPLICATED.kind == "replicated"
    p = dx.hash_part(("?x",))
    assert p.kind == "hash" and p.cols == ("?x",)
    assert str(p) == "hash(?x)"
    with pytest.raises(AssertionError):
        dx.hash_part(())


def test_scan_partitioned_on_subject_column():
    st = dx.analyze_plan(
        plan_of(join(scan(0, ("?x", "?a"), part_col=0),
                     scan(1, ("?x", "?b"), part_col=0), ("?x",))),
        n_shards=4,
    )
    assert len(st) == 1


# ----------------------------------------------- join alignment / elision


def test_subject_star_elides_every_shuffle():
    """Both sides subject-hash partitioned on the join key: the map-side
    join — zero collectives emitted (the tentpole's headline case)."""
    root = join(scan(0, ("?x", "?a"), part_col=0),
                scan(1, ("?x", "?b"), part_col=0), ("?x",))
    (s,) = dx.analyze_plan(plan_of(root), n_shards=4)
    assert (s.left, s.right) == ("local", "local")
    assert s.emitted == 0 and s.elided == 2 and not s.broadcast
    assert dx.strategy_counts([s]) == {
        "emitted": 0, "elided": 2, "broadcast": 0
    }


def test_chain_join_shuffles_misaligned_side_only():
    """?x<p>?y . ?y<q>?z joined on ?y: the right scan is subject-hash
    partitioned on ?y (aligned), the left is partitioned on ?x — only the
    left side's rows move."""
    root = join(scan(0, ("?x", "?y"), part_col=0),
                scan(1, ("?y", "?z"), part_col=0), ("?y",))
    (s,) = dx.analyze_plan(plan_of(root), n_shards=4)
    assert (s.left, s.right) == ("shuffle", "local")
    assert s.emitted == 1 and s.elided == 1


def test_single_shard_everything_local():
    root = join(scan(0, ("?x", "?y")), scan(1, ("?y", "?z")), ("?y",))
    (s,) = dx.analyze_plan(plan_of(root), n_shards=1)
    assert (s.left, s.right) == ("local", "local")


def test_alignment_is_column_order_sensitive():
    """hash((?a,?b)) routes by FNV over the tuple IN ORDER — a join keyed
    (?b,?a) must re-shuffle even though the column sets match."""
    up = join(scan(0, ("?a", "?b"), part_col=0),
              scan(1, ("?a", "?b", "?c"), part_col=0), ("?a", "?b"))
    aligned_next = join(up, scan(2, ("?a", "?b", "?d")), ("?a", "?b"),
                        cap=256)
    st = dx.analyze_plan(plan_of(aligned_next, 3, 2), n_shards=4,
                         broadcast_rows=0)
    assert st[1].left == "local"  # output part hash(?a,?b) == key
    swapped_next = join(up, scan(2, ("?a", "?b", "?d")), ("?b", "?a"),
                        cap=256)
    st = dx.analyze_plan(plan_of(swapped_next, 3, 2), n_shards=4,
                         broadcast_rows=0)
    assert st[1].left == "shuffle"


def test_join_output_partitioned_on_key():
    """A join's output is hash(key): the next join on the same key runs
    map-side even when no scan was aligned to begin with."""
    first = join(scan(0, ("?x", "?y"), part_col=0),
                 scan(1, ("?z", "?y"), part_col=0), ("?y",))
    second = join(first, scan(2, ("?y", "?w"), part_col=0), ("?y",),
                  cap=256)
    st = dx.analyze_plan(plan_of(second, 3, 2), n_shards=4,
                         broadcast_rows=0)
    assert st[0].emitted == 2  # both scans misaligned on ?y
    assert st[1].left == "local"  # first join's output is hash(?y)
    assert st[1].right == "local"  # subject-var scan of ?y aligned too


def test_matrix_join_site_analyzed_same_as_mr():
    root = join(scan(0, ("?x", "?a"), part_col=0),
                scan(1, ("?x", "?b"), part_col=0), ("?x",),
                cls=MatrixJoin)
    (s,) = dx.analyze_plan(plan_of(root), n_shards=4)
    assert s.op == "matrix_join"
    assert s.emitted == 0 and s.elided == 2


# --------------------------------------------------------------- broadcast


def test_small_misaligned_right_broadcasts():
    root = join(scan(0, ("?x", "?y"), part_col=0),
                scan(1, ("?z", "?y"), part_col=0, cap=16), ("?y",))
    (s,) = dx.analyze_plan(plan_of(root), n_shards=4, broadcast_rows=2048)
    assert (s.left, s.right) == ("local", "broadcast")
    assert s.broadcast and s.emitted == 0
    # too big to replicate at this threshold: shuffle both sides instead
    (s,) = dx.analyze_plan(plan_of(root), n_shards=4, broadcast_rows=32)
    assert (s.left, s.right) == ("shuffle", "shuffle")


def test_broadcast_keeps_left_partitioning():
    """Under a broadcast the left rows never move, so the OUTPUT keeps the
    left partitioning (hash(?x)), not hash(key) — a later subject-star
    join on ?x stays map-side."""
    first = join(scan(0, ("?x", "?y"), part_col=0),
                 scan(1, ("?z", "?y"), part_col=0, cap=16), ("?y",))
    second = join(first, scan(2, ("?x", "?w"), part_col=0), ("?x",),
                  cap=256)
    st = dx.analyze_plan(plan_of(second, 3, 2), n_shards=4)
    assert st[0].broadcast
    assert (st[1].left, st[1].right) == ("local", "local")


# ------------------------------------------- project / distinct / union


def test_project_keeps_part_when_columns_survive():
    base = join(scan(0, ("?x", "?a"), part_col=0),
                scan(1, ("?x", "?b"), part_col=0), ("?x",))
    keep = Distinct(child=Project(child=base, schema=("?x", "?a")))
    st = dx.analyze_plan(plan_of(keep), n_shards=4)
    assert st[-1].op == "distinct" and st[-1].left == "local"


def test_project_dropping_part_column_resets_to_unknown():
    base = join(scan(0, ("?x", "?a"), part_col=0),
                scan(1, ("?x", "?b"), part_col=0), ("?x",))
    drop = Distinct(child=Project(child=base, schema=("?a", "?b")))
    st = dx.analyze_plan(plan_of(drop), n_shards=4)
    assert st[-1].left == "shuffle"  # ?x projected away -> unknown


def test_distinct_local_iff_hash_cols_subset_of_schema():
    aligned = Distinct(child=scan(0, ("?x", "?a"), part_col=0))
    (s,) = dx.analyze_plan(plan_of(aligned, 1, 0), n_shards=4)
    assert s.left == "local"  # equal rows agree on ?x -> co-located
    arbitrary = Distinct(child=scan(0, ("?x", "?a")))
    (s,) = dx.analyze_plan(plan_of(arbitrary, 1, 0), n_shards=4)
    assert s.left == "shuffle"
    (s,) = dx.analyze_plan(plan_of(arbitrary, 1, 0), n_shards=1)
    assert s.left == "local"  # 1 shard: everything is trivially aligned


def test_union_common_partitioning():
    a = scan(0, ("?x", "?v"), part_col=0)
    b = scan(1, ("?x", "?v"), part_col=0)
    u = UnionAll(children=(a, b), schema=("?x", "?v"))
    (s,) = dx.analyze_plan(plan_of(Distinct(child=u), 2, 0), n_shards=4)
    assert s.left == "local"  # both branches hash(?x) -> union keeps it
    mixed = UnionAll(children=(a, scan(1, ("?x", "?v"))),
                     schema=("?x", "?v"))
    (s,) = dx.analyze_plan(plan_of(Distinct(child=mixed), 2, 0),
                           n_shards=4)
    assert s.left == "shuffle"  # branches disagree -> unknown


# --------------------------------------------- site enumeration / caps


def test_site_enumeration_and_per_stage_caps():
    first = join(scan(0, ("?x", "?y"), part_col=0),
                 scan(1, ("?y", "?z"), part_col=0), ("?y",))
    root = Distinct(child=first)
    plan = plan_of(root)
    sites = dx.shuffle_site_nodes(plan)
    assert [type(n).__name__ for n in sites] == ["MRJoin", "Distinct"]
    assert dx.n_shuffle_slots(plan, n_stages=2) == 4  # 2 sites x 2 stages
    caps = dx.initial_shuffle_caps(plan, (2, 4))
    assert len(caps) == 4
    # stage caps scale with 1/axis_size: the 2-way stage's bucket is at
    # least the 4-way stage's for the same site
    assert caps[0] >= caps[1] and caps[2] >= caps[3]
