"""Unit tests for the observability package: metrics instruments and the
Prometheus exposition round-trip, span tracing (context-managed and
retroactive), the tracer's ring/slow-log bounding, the Chrome trace-event
export and its checked-in schema."""
import json
import os
import threading
import time

import pytest

from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Trace,
    Tracer,
    log_buckets,
    parse_prometheus,
    phase_totals,
    quantile_from_samples,
    validate_chrome_events,
)

SCHEMA_PATH = os.path.join(
    os.path.dirname(__file__), "..", "docs", "trace_schema.json"
)


# ----------------------------------------------------------- metrics


def test_counter_inc_and_labels():
    c = Counter("t_total", "help", labelnames=("outcome",))
    c.labels(outcome="ok").inc()
    c.labels(outcome="ok").inc(2)
    c.labels(outcome="err").inc()
    assert c.labels(outcome="ok").value == 3
    assert c.labels(outcome="err").value == 1
    with pytest.raises(ValueError):
        c.labels(wrong="x")
    with pytest.raises(ValueError):
        c.labels(outcome="ok").inc(-1)


def test_counter_set_total_is_monotone():
    c = Counter("t_total", "")
    c.set_total(5)
    c.set_total(3)  # never moves backwards
    assert c.value == 5
    c.set_total(9)
    assert c.value == 9


def test_gauge_set_inc_dec():
    g = Gauge("t_gauge", "")
    g.set(4)
    g.inc()
    g.dec(2)
    assert g.value == 3


def test_metric_name_validation():
    with pytest.raises(ValueError):
        Counter("bad name", "")
    with pytest.raises(ValueError):
        Counter("ok_total", "", labelnames=("bad-label",))


def test_histogram_observe_render_and_quantile():
    h = Histogram("t_seconds", "", buckets=(0.001, 0.01, 0.1, 1.0))
    for v in (0.0005, 0.005, 0.005, 0.05, 2.0):
        h.observe(v)
    child = h._default_child()
    assert child.count == 5
    assert child.counts[-1] == 1  # the +Inf bucket
    assert h.quantile(0.5) == 0.01  # bucket-resolution median
    lines = h.render()
    # cumulative buckets + sum + count
    assert any(
        line.startswith('t_seconds_bucket{le="+Inf"} 5') for line in lines
    )
    assert any(line.startswith("t_seconds_count 5") for line in lines)


def test_histogram_rejects_bad_buckets():
    with pytest.raises(ValueError):
        Histogram("t_seconds", "", buckets=(0.1, 0.1))


def test_log_buckets_geometric():
    b = log_buckets(start=0.001, factor=2.0, count=4)
    assert b == (0.001, 0.002, 0.004, 0.008)


def test_registry_get_or_create_and_type_conflict():
    m = MetricsRegistry()
    c1 = m.counter("x_total", "h")
    c2 = m.counter("x_total")
    assert c1 is c2
    with pytest.raises(ValueError):
        m.gauge("x_total")
    assert m.get("x_total") is c1
    assert m.get("missing") is None


def test_registry_collector_bridges_plain_attributes():
    m = MetricsRegistry()
    state = {"hits": 0}
    c = m.counter("hits_total", "bridged")
    m.register_collector(lambda: c.set_total(state["hits"]))
    state["hits"] = 7
    text = m.render_prometheus()
    assert "hits_total 7" in text


def test_render_prometheus_parses_round_trip():
    m = MetricsRegistry()
    m.counter("req_total", "requests", labelnames=("outcome",)).labels(
        outcome="ok"
    ).inc(3)
    m.gauge("depth", "queue depth").set(2)
    h = m.histogram("lat_seconds", "latency", buckets=(0.01, 0.1))
    h.observe(0.005)
    h.observe(0.5)
    parsed = parse_prometheus(m.render_prometheus())
    assert parsed["req_total"] == [({"outcome": "ok"}, 3.0)]
    assert parsed["depth"] == [({}, 2.0)]
    infs = [
        v for labels, v in parsed["lat_seconds_bucket"]
        if labels["le"] == "+Inf"
    ]
    assert infs == [2.0]


def test_parse_prometheus_rejects_bad_grammar():
    with pytest.raises(ValueError):
        parse_prometheus("not a metric line at all!\n")
    with pytest.raises(ValueError):
        parse_prometheus('x_total{bad label="v"} 1\n')


def test_parse_prometheus_rejects_non_monotone_histogram():
    bad = (
        'h_bucket{le="0.1"} 5\n'
        'h_bucket{le="+Inf"} 3\n'
        "h_count 3\n"
    )
    with pytest.raises(ValueError):
        parse_prometheus(bad)


def test_parse_prometheus_rejects_inf_count_disagreement():
    bad = (
        'h_bucket{le="0.1"} 1\n'
        'h_bucket{le="+Inf"} 2\n'
        "h_count 3\n"
    )
    with pytest.raises(ValueError):
        parse_prometheus(bad)


def test_quantile_from_samples():
    assert quantile_from_samples([], 0.5) == 0.0
    vs = list(range(1, 101))
    assert quantile_from_samples(vs, 0.5) in (50, 51)
    assert quantile_from_samples(vs, 0.99) in (99, 100)
    assert quantile_from_samples(vs, 1.0) == 100


def test_counter_thread_safety():
    c = Counter("t_total", "")

    def spin():
        for _ in range(1000):
            c.inc()

    ts = [threading.Thread(target=spin) for _ in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == 8000


# ------------------------------------------------------------- traces


def test_span_context_manager_closes_and_records_errors():
    tr = Trace("query")
    with tr.span("parse"):
        pass
    with pytest.raises(RuntimeError):
        with tr.span("optimize"):
            raise RuntimeError("boom")
    parse_span = tr.find("parse")[0]
    opt_span = tr.find("optimize")[0]
    assert not parse_span.open
    assert not opt_span.open
    assert opt_span.attrs["error"] == "RuntimeError"
    # only the root remains open until finish()
    assert tr.open_spans() == [tr.root]
    tr.finish()
    assert tr.open_spans() == []


def test_add_span_is_born_closed():
    tr = Trace("query")
    t0 = time.perf_counter()
    t1 = t0 + 0.25
    s = tr.add_span("dispatch", t0, t1, dispatch_id=3, lane=1)
    assert not s.open
    assert abs(s.duration_s - 0.25) < 1e-6
    assert s.attrs == {"dispatch_id": 3, "lane": 1}
    assert s.parent_id == tr.root.span_id


def test_span_nesting_parent_ids():
    tr = Trace("query")
    outer = tr.start("outer")
    inner = tr.start("inner", parent=outer)
    tr.end(inner)
    tr.end(outer)
    assert inner.parent_id == outer.span_id
    assert outer.parent_id == tr.root.span_id
    tree = tr.tree_str()
    assert "outer" in tree and "inner" in tree


def test_tracer_ring_is_bounded():
    tc = Tracer(ring_size=4)
    for i in range(10):
        tr = tc.new_trace("query", i=i)
        tc.finish(tr)
    recent = tc.recent()
    assert len(recent) == 4
    assert [t.root.attrs["i"] for t in recent] == [6, 7, 8, 9]
    assert tc.n_traces == 10


def test_tracer_slow_log_threshold():
    tc = Tracer(slow_ms=5.0, slow_log_size=2)
    fast = tc.new_trace("query")
    tc.finish(fast)
    slow = tc.new_trace("query")
    slow.root.t0 = -1.0  # 1s duration without sleeping
    tc.finish(slow)
    assert tc.slow_queries() == [slow]
    assert tc.n_slow == 1


def test_open_span_count_sees_leaks():
    tc = Tracer()
    tr = tc.new_trace("query")
    tr.start("leaked")
    tc.finish(tr)
    assert tc.open_span_count() == 1


def test_finish_attrs_land_on_root():
    tc = Tracer()
    tr = tc.new_trace("query")
    tc.finish(tr, outcome="ok")
    assert tr.root.attrs["outcome"] == "ok"


def test_phase_totals_sums_closed_spans():
    tr1 = Trace("query")
    t = time.perf_counter()
    tr1.add_span("dispatch", t, t + 0.1)
    tr2 = Trace("query")
    tr2.add_span("dispatch", t, t + 0.2)
    tr2.add_span("decode", t, t + 0.05)
    tr2.start("leaked")  # open: contributes nothing
    totals = phase_totals([tr1, tr2])
    assert abs(totals["dispatch"] - 0.3) < 1e-6
    assert abs(totals["decode"] - 0.05) < 1e-6
    assert "leaked" not in totals


def test_chrome_export_matches_checked_in_schema():
    with open(SCHEMA_PATH) as f:
        schema = json.load(f)
    tc = Tracer()
    tr = tc.new_trace("query", query="SELECT ...")
    with tr.span("parse"):
        pass
    t = time.perf_counter()
    tr.add_span("dispatch", t, t + 0.01, dispatch_id=1, lane=0)
    tc.finish(tr, outcome="ok")
    events = tc.export_chrome()
    assert len(events) == 3
    assert validate_chrome_events(events, schema) == []
    # and the export is genuinely JSON-serialisable
    json.dumps(events)


def test_schema_validator_flags_violations():
    with open(SCHEMA_PATH) as f:
        schema = json.load(f)
    good = {
        "name": "x", "cat": "query", "ph": "X", "ts": 1.0, "dur": 1.0,
        "pid": 1, "tid": 1, "args": {"trace_id": 1, "span_id": 2},
    }
    assert validate_chrome_events([good], schema) == []
    bad_ph = dict(good, ph="B")
    assert validate_chrome_events([bad_ph], schema)
    bad_dur = dict(good, dur=-1.0)
    assert validate_chrome_events([bad_dur], schema)
    missing = {k: v for k, v in good.items() if k != "args"}
    assert validate_chrome_events([missing], schema)
