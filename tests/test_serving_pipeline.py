"""The two-stage serving pipeline: dispatch/decode overlap (DecodePool,
Deferred slots, PendingDecode), per-request exception copies with preserved
tracebacks, per-query wall-clock deadlines, and cross-shape padded
stacking — differential against sequential run() and the NumPy oracle,
under real concurrent submission."""
import threading
import time

from repro.serve.batcher import (
    BatchTimeout,
    Deferred,
    MicroBatcher,
    _exc_copy,
)
from repro.serve.decode import DecodePool
from repro.sparql.baseline import reference_rows
from repro.sparql.engine import PendingDecode, QueryEngine
from repro.sparql.parser import parse
from repro.sparql.store import store_from_string_triples


def rows_as_sets(rows):
    return sorted(tuple(sorted(r.items())) for r in rows)


def pipeline_store():
    """Entities wired for every algebra shape the differential test hits:
    BGP chains, numeric FILTER, sparse OPTIONAL matches, UNION branches."""
    triples = []
    for i in range(10):
        triples.append((f"<s{i}>", "<p0>", f"<m{i % 3}>"))
        triples.append((f"<s{i}>", "<age>", str(18 + 2 * i)))
        if i % 2:
            triples.append((f"<s{i}>", "<p1>", f"<o{i}>"))
    for j in range(3):
        triples.append((f"<m{j}>", "<q>", f"<z{j}>"))
        triples.append((f"<m{j}>", "<q>", f"<z{j + 3}>"))
    return store_from_string_triples(triples)


QUERIES = [
    "SELECT ?x ?z WHERE { ?x <p0> ?y . ?y <q> ?z . }",
    ("SELECT ?x ?a WHERE { ?x <p0> ?y . ?x <age> ?a . "
     "FILTER (?a > 24) }"),
    ("SELECT ?x ?y ?o WHERE { ?x <p0> ?y . "
     "OPTIONAL { ?x <p1> ?o } }"),
    ("SELECT ?x ?v WHERE { { ?x <p0> ?v } UNION "
     "{ ?x <p1> ?v } }"),
]


def _server(store, **kw):
    from repro.serve.sparql_server import SPARQLServer

    kw.setdefault("max_batch", 8)
    return SPARQLServer(QueryEngine(store), **kw)


# --------------------------------------------------- decode pool unit


def test_decode_pool_isolates_crashes_and_counts():
    from repro.serve.batcher import Request

    pool = DecodePool(n_workers=2, max_queue=8)
    try:
        ok = Request("a")
        bad = Request("b")
        pool.submit(ok, lambda: "fine")
        pool.submit(bad, lambda: (_ for _ in ()).throw(RuntimeError("die")))
        assert ok.event.wait(5) and bad.event.wait(5)
        assert ok.result == "fine"
        assert isinstance(bad.result, RuntimeError)
        # the pool survived the crash and keeps decoding
        again = Request("c")
        pool.submit(again, lambda: 42)
        assert again.event.wait(5) and again.result == 42
        s = pool.stats()
        assert s["decoded"] == 2 and s["errors"] == 1
    finally:
        pool.close()


def test_decode_pool_skips_abandoned_requests():
    from repro.serve.batcher import Request

    pool = DecodePool(n_workers=1, max_queue=8)
    try:
        r = Request("x")
        r.abandoned = True
        ran = []
        pool.submit(r, lambda: ran.append(1))
        assert r.event.wait(5)
        assert not ran and pool.stats()["skipped"] == 1
    finally:
        pool.close()


# ------------------------------------------- batch-failure exception copy


def test_batch_failure_gives_each_request_an_independent_copy():
    """Regression (satellite): every request in a failed batch must get its
    OWN exception object — concurrent re-raises on submitter threads race
    on __traceback__ if one instance fans out — and the copy must carry
    the original raise site's traceback."""

    def boom(payloads):
        raise ValueError("batch exploded")

    b = MicroBatcher(boom, max_batch=4, max_wait_s=0.05)
    try:
        errs = []
        lock = threading.Lock()

        def hit():
            try:
                b.submit("q", timeout=10)
            except ValueError as e:
                with lock:
                    errs.append(e)

        ts = [threading.Thread(target=hit) for _ in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert len(errs) == 4
        assert len({id(e) for e in errs}) == 4  # independent instances
        for e in errs:
            assert str(e) == "batch exploded"
            tb = e.__traceback__
            frames = []
            while tb is not None:
                frames.append(tb.tb_frame.f_code.co_name)
                tb = tb.tb_next
            assert "boom" in frames  # original raise site preserved
    finally:
        b.close()


def test_exc_copy_falls_back_for_awkward_constructors():
    class Picky(Exception):
        def __init__(self, a, b):  # copy.copy's cls(*args) path TypeErrors
            super().__init__(f"{a}/{b}")
            self.a = a

    try:
        raise Picky(1, 2)
    except Picky as e:
        orig = e
    c = _exc_copy(orig)
    assert c is not orig
    assert c.a == 1 and c.args == orig.args
    assert c.__traceback__ is orig.__traceback__


# ------------------------------------------------------- deadline path


def test_query_timeout_raises_typed_error_and_counts():
    from repro.serve.sparql_server import QueryTimeoutError

    store = pipeline_store()
    srv = _server(store)
    try:
        try:
            srv.query(QUERIES[0], timeout_ms=0.0001)
        except QueryTimeoutError as e:
            assert e.kind == "timeout"
            assert isinstance(e, TimeoutError)
        else:  # pragma: no cover - absurdly fast machine
            pass
        # an expired request must not wedge later ones
        assert len(srv.query(QUERIES[0])) > 0
        assert srv.stats()["timeouts"] <= 1
    finally:
        srv.close()


def test_batcher_timeout_marks_request_abandoned():
    gate = threading.Event()

    def slow(payloads):
        gate.wait(5)
        return [Deferred(lambda: "late") for _ in payloads]

    b = MicroBatcher(slow, max_batch=2, max_wait_s=0.001)
    try:
        try:
            b.submit("q", timeout=0.05)
            raise AssertionError("expected BatchTimeout")
        except BatchTimeout:
            pass
    finally:
        gate.set()
        b.close()


# -------------------------------------------- pipelined differential


def test_pipelined_results_match_sequential_and_oracle_concurrent():
    """Acceptance: pipelined server results == sequential run() == NumPy
    oracle across BGP/FILTER/OPTIONAL/UNION under concurrent submission,
    with mid-batch parse errors isolated to their own callers."""
    from repro.serve.sparql_server import ParseQueryError, QueryResult

    store = pipeline_store()
    eng_ref = QueryEngine(store)
    want = {}
    for t in QUERIES:
        oracle = rows_as_sets(reference_rows(store, parse(t)))
        seq = rows_as_sets(eng_ref.prepare(t).run().rows)
        assert seq == oracle, t
        want[t] = oracle
    srv = _server(store, max_wait_s=0.02, decode_workers=2)
    try:
        n = 32
        plan = [QUERIES[i % len(QUERIES)] for i in range(n)]
        bad_at = {5, 17}
        results: list = [None] * n
        errors: list = [None] * n

        def hit(i):
            try:
                text = "BROKEN {" if i in bad_at else plan[i]
                results[i] = srv.query(text)
            except Exception as e:
                errors[i] = e

        ts = [threading.Thread(target=hit, args=(i,)) for i in range(n)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        for i in range(n):
            if i in bad_at:
                assert isinstance(errors[i], ParseQueryError), errors[i]
            else:
                assert isinstance(results[i], QueryResult), errors[i]
                assert rows_as_sets(results[i].rows) == want[plan[i]]
        st = srv.stats()
        assert st["pipeline"]["deferred"] > 0
        assert st["pipeline"]["decode"]["decoded"] > 0
        assert st["pipeline"]["decode"]["errors"] == 0
    finally:
        srv.close()


def test_decode_worker_crash_is_isolated_and_server_survives():
    """A crash INSIDE a decode worker (decode stage, not dispatch) becomes
    that one request's typed QueryError; batchmates and later requests are
    unaffected."""
    from repro.serve.sparql_server import QueryError, QueryResult

    store = pipeline_store()
    srv = _server(store, decode_workers=1)
    try:
        srv.query(QUERIES[0])  # warm
        real = srv.engine._decode_numpy
        crashed = []

        def sabotage(schema, rows):
            if not crashed:
                crashed.append(1)
                raise RuntimeError("decode worker crash")
            return real(schema, rows)

        srv.engine._decode_numpy = sabotage
        try:
            try:
                srv.query(QUERIES[0])
                raise AssertionError("expected QueryError")
            except QueryError as e:
                assert e.kind == "decode"
        finally:
            srv.engine._decode_numpy = real
        out = srv.query(QUERIES[0])
        assert isinstance(out, QueryResult) and len(out) > 0
        assert srv.stats()["pipeline"]["decode"]["errors"] >= 1
    finally:
        srv.close()


def test_synchronous_mode_still_works():
    """decode_workers=0 restores the pre-pipeline synchronous batcher (the
    bench baseline): same results, no pool."""
    from repro.serve.sparql_server import QueryResult

    store = pipeline_store()
    srv = _server(store, decode_workers=0)
    try:
        out = srv.query(QUERIES[0])
        assert isinstance(out, QueryResult)
        assert rows_as_sets(out.rows) == rows_as_sets(
            reference_rows(store, parse(QUERIES[0]))
        )
        assert srv.stats()["pipeline"]["decode"] is None
    finally:
        srv.close()


# -------------------------------------------- cross-shape padded stacking


def padding_store():
    """Two predicates with very different cardinalities, so structurally
    identical queries land in different pow-2 scan buckets (= near-miss
    PlanShapes that only padding can merge)."""
    triples = []
    for i in range(12):
        triples.append((f"<s{i}>", "<small>", f"<m{i % 3}>"))
    for i in range(150):
        triples.append((f"<a{i}>", "<big>", f"<m{i % 3}>"))
    for j in range(3):
        triples.append((f"<m{j}>", "<q>", f"<z{j}>"))
    return store_from_string_triples(triples)


PAD_QUERIES = [
    "SELECT ?x ?z WHERE { ?x <small> ?y . ?y <q> ?z . }",
    "SELECT ?x ?z WHERE { ?x <big> ?y . ?y <q> ?z . }",
]


def _warm(eng, texts, copies=4):
    ps = [eng.prepare(t) for t in texts for _ in range(copies)]
    for p in ps:
        p.run()
    return ps


def test_padding_reduces_dispatches_without_changing_rows():
    store = padding_store()
    base = QueryEngine(store, pad_stacking=False)
    ps0 = _warm(base, PAD_QUERIES)
    d0 = base.stacked_dispatches
    res0 = base.run_batch(ps0)
    unpadded_dispatches = base.stacked_dispatches - d0

    eng = QueryEngine(store)  # pad_stacking defaults ON
    ps1 = _warm(eng, PAD_QUERIES)
    d1 = eng.stacked_dispatches
    res1 = eng.run_batch(ps1)
    padded_dispatches = eng.stacked_dispatches - d1

    # acceptance: strictly fewer stacked dispatches, identical rows
    assert padded_dispatches < unpadded_dispatches
    assert eng.padded_groups == 1
    g = eng.last_batch[0]
    assert g.padded and g.n_shapes == 2
    for a, b in zip(res0, res1):
        assert rows_as_sets(a.rows) == rows_as_sets(b.rows)


def test_padding_cost_guard_falls_back_per_shape():
    store = padding_store()
    eng = QueryEngine(store, pad_waste_limit=0.0)  # any waste rejected
    ps = _warm(eng, PAD_QUERIES)
    d0 = eng.stacked_dispatches
    eng.run_batch(ps)
    assert eng.stacked_dispatches - d0 == 2  # one per shape, no merge
    assert eng.padded_groups == 0
    assert eng.pad_rejects == 1
    assert all(not g.padded for g in eng.last_batch)


def test_padding_requires_all_member_shapes_warm():
    store = padding_store()
    eng = QueryEngine(store)
    cold = [eng.prepare(t) for t in PAD_QUERIES for _ in range(3)]
    eng.run_batch(cold)  # nothing warm yet: groups must stay separate
    assert eng.padded_groups == 0
    # now both shapes are warm: the next mixed batch merges
    eng.run_batch(cold)
    assert eng.padded_groups == 1


def test_padded_group_survives_store_updates():
    """Padded signatures are shape-level: after an update within capacity
    buckets, the padded entry keeps serving (no recompiles)."""
    store = padding_store()
    eng = QueryEngine(store)
    ps = _warm(eng, PAD_QUERIES)
    eng.run_batch(ps)
    assert eng.padded_groups == 1
    eng.update('INSERT DATA { <s0> <small> <m1> . }')
    res = eng.run_batch(ps)
    assert eng.padded_groups == 2
    want = [rows_as_sets(reference_rows(store, parse(p.text))) for p in ps]
    for r, w in zip(res, want):
        assert rows_as_sets(r.rows) == w


def test_pipelined_padding_through_server():
    """End-to-end: a mixed-shape warm workload through the pipelined
    server pads into fewer stacked dispatches and reports the ledger."""
    store = padding_store()
    srv = _server(store, max_wait_s=0.05)
    try:
        for t in PAD_QUERIES:  # warm both shapes (cold path, solo)
            srv.query(t)
            srv.query(t)
        results: dict = {}

        def hit(i):
            t = PAD_QUERIES[i % 2]
            results[i] = (t, srv.query(t))

        ts = [threading.Thread(target=hit, args=(i,)) for i in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        for t, out in results.values():
            assert rows_as_sets(out.rows) == rows_as_sets(
                reference_rows(store, parse(t))
            )
        pad = srv.stats()["batched"]["padding"]
        assert pad["pad_rejects"] == 0
        assert pad["waste_ratio"] >= 0.0
    finally:
        srv.close()
