"""Algorithm 1 (MapReduce join) vs a python oracle, incl. hypothesis sweeps."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip without the dev extra
    from _hypothesis_compat import given, settings, st

from repro.core import mr_join as mj
from repro.core.relation import Relation


def oracle_join(l_schema, l_rows, r_schema, r_rows):
    """Nested-loop natural join with python sets (ground truth)."""
    shared = [v for v in l_schema if v in r_schema]
    r_extra = [v for v in r_schema if v not in l_schema]
    out = []
    for lr in l_rows:
        for rr in r_rows:
            if all(lr[l_schema.index(v)] == rr[r_schema.index(v)] for v in shared):
                out.append(tuple(lr) + tuple(rr[r_schema.index(v)] for v in r_extra))
    return out


def make_rel(schema, rows, capacity=None):
    return Relation.from_numpy(schema, np.array(rows, np.int32).reshape(-1, len(schema)),
                               capacity=capacity)


def run_join(l_schema, l_rows, r_schema, r_rows, capacity=None, **kw):
    left = make_rel(l_schema, l_rows)
    right = make_rel(r_schema, r_rows)
    expected = oracle_join(l_schema, l_rows, r_schema, r_rows)
    cap = capacity or max(1, 2 * len(expected) + 4)
    out, total, overflowed = mj.mr_join(left, right, cap, **kw)
    assert int(total) == len(expected)
    assert not bool(overflowed)
    got = sorted(map(tuple, out.to_numpy().tolist()))
    assert got == sorted(expected)
    return out


def test_paper_table1_example():
    """The exact example of Table 1: persons/jobs joined on ?job."""
    # dictionary: Professor=0 Doctor=1 Nurse=2 Anny=3 Jim=4 Susan=5 Hospital=6
    tp1 = [(0, 3), (1, 4), (2, 5)]  # (?job, ?person)
    tp2 = [(1, 6), (2, 6)]  # (?job, "Hospital"-bound object col)
    out = run_join(("?job", "?person"), tp1, ("?job", "?o"), tp2)
    assert out.to_set() == {(1, 4, 6), (2, 5, 6)}  # Doctor/Jim, Nurse/Susan


def test_duplicate_keys_cartesian_within_group():
    l = [(7, i) for i in range(4)] + [(8, 9)]
    r = [(7, 100 + j) for j in range(3)]
    run_join(("?k", "?a"), l, ("?k", "?b"), r)


def test_no_matches():
    out = run_join(("?k", "?a"), [(1, 2)], ("?k", "?b"), [(3, 4)])
    assert out.to_set() == set()


def test_multi_variable_key():
    l = [(1, 2, 10), (1, 3, 11), (2, 2, 12)]
    r = [(1, 2, 20), (2, 2, 21), (2, 2, 22)]
    run_join(("?x", "?y", "?a"), l, ("?x", "?y", "?b"), r)


def test_overflow_flag():
    left = make_rel(("?k", "?a"), [(1, i) for i in range(8)])
    right = make_rel(("?k", "?b"), [(1, i) for i in range(8)])
    out, total, overflowed = mj.mr_join(left, right, capacity=16)
    assert int(total) == 64 and bool(overflowed)
    # truncated but the reported rows are real join rows
    rows = out.to_numpy()
    assert len(rows) == 16 and set(rows[:, 0].tolist()) == {1}


def test_padding_rows_never_join():
    left = make_rel(("?k", "?a"), [(0, 1)], capacity=8)  # 7 invalid zero rows
    right = make_rel(("?k", "?b"), [(0, 2)], capacity=8)
    out, total, _ = mj.mr_join(left, right, 8)
    assert int(total) == 1
    assert out.to_set() == {(0, 1, 2)}


def test_jit_count_and_expand_agree():
    left = make_rel(("?k", "?a"), [(i % 3, i) for i in range(32)])
    right = make_rel(("?k", "?b"), [(i % 5, i) for i in range(32)])
    count = jax.jit(mj.mr_join_count)(left, right)
    out, total, _ = jax.jit(mj.mr_join, static_argnums=2)(left, right, 512)
    assert int(count) == int(total)


def test_cross_join():
    left = make_rel(("?a",), [(1,), (2,)])
    right = make_rel(("?b",), [(5,), (6,), (7,)])
    out, total, ov = mj.cross_join(left, right, 8)
    assert int(total) == 6 and not bool(ov)
    assert out.to_set() == set(
        (a, b) for a in (1, 2) for b in (5, 6, 7)
    )


def test_distinct_and_compact():
    rel = make_rel(("?a", "?b"), [(1, 2), (1, 2), (3, 4), (0, 0)], capacity=8)
    d = mj.distinct(rel)
    assert d.to_set() == {(1, 2), (3, 4), (0, 0)}
    assert int(d.count()) == 3
    c = mj.compact(d)
    assert bool(np.all(np.asarray(c.valid)[: int(d.count())]))


def test_semijoin_mask():
    left = make_rel(("?k", "?a"), [(1, 10), (2, 11), (3, 12)])
    right = make_rel(("?k", "?b"), [(1, 0), (3, 0)])
    mask = mj.semijoin_mask(left, right)
    np.testing.assert_array_equal(np.asarray(mask), [True, False, True])


@st.composite
def relation_pair(draw):
    n_keys = draw(st.integers(1, 5))
    l_rows = draw(st.lists(st.tuples(st.integers(0, n_keys), st.integers(0, 6)),
                           min_size=1, max_size=24))
    r_rows = draw(st.lists(st.tuples(st.integers(0, n_keys), st.integers(0, 6)),
                           min_size=1, max_size=24))
    return l_rows, r_rows


@settings(max_examples=60, deadline=None)
@given(relation_pair())
def test_hypothesis_matches_oracle(pair):
    l_rows, r_rows = pair
    run_join(("?k", "?a"), l_rows, ("?k", "?b"), r_rows)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3), st.integers(0, 5)),
                min_size=1, max_size=16),
       st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3), st.integers(0, 5)),
                min_size=1, max_size=16))
def test_hypothesis_multivar(l_rows, r_rows):
    run_join(("?x", "?y", "?a"), l_rows, ("?x", "?y", "?b"), r_rows)
