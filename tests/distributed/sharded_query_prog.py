"""Subprocess body: sharded SPARQL execution on N forced host devices.

Differential acceptance for the sharded subsystem at a real device count
(the parent pytest process keeps 1 device — XLA locks the count at first
jax import):

  * every LUBM bench query (plus FILTER / OPTIONAL / UNION / LIMIT
    operator shapes) answers IDENTICALLY through the sharded engine, the
    single-device engine and the NumPy oracle;
  * a deterministic slice of the property-test query space (the same
    generator tests/test_sharded.py sweeps under hypothesis at 1 device)
    agrees with the oracle too;
  * warm queries are exactly ONE shard_map dispatch with ZERO compiles;
  * the per-shard max join bucket never exceeds the single-device bucket,
    and is strictly smaller on the join-heavy queries when n_dev > 1.

Usage: sharded_query_prog.py [n_devices]   (default 8)
"""
import os
import sys

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 8
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEV} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402

from repro.sparql import lubm  # noqa: E402
from repro.sparql.baseline import reference_rows  # noqa: E402
from repro.sparql.engine import QueryEngine, ShardedQueryEngine  # noqa: E402
from repro.sparql.parser import parse  # noqa: E402
from repro.sparql.sharded_store import shard_store  # noqa: E402
from repro.sparql.store import store_from_string_triples  # noqa: E402

EXTRA = {
    "F1": lubm.PREFIX + """SELECT ?p ?n WHERE {
        ?p a ub:FullProfessor . ?p ub:name ?n .
        FILTER (?n != "prof_0_0_0") }""",
    "O1": lubm.PREFIX + """SELECT ?s ?a WHERE {
        ?s a ub:GraduateStudent . OPTIONAL { ?s ub:advisor ?a } }""",
    "U1": lubm.PREFIX + """SELECT ?s ?v WHERE {
        ?s a ub:GraduateStudent .
        { ?s ub:advisor ?v } UNION { ?s ub:memberOf ?v } }""",
    "D1q": lubm.PREFIX + "SELECT DISTINCT ?d WHERE { ?s ub:memberOf ?d . }",
    "L1": lubm.PREFIX
    + "SELECT ?s ?d WHERE { ?s ub:memberOf ?d . } LIMIT 17",
}


def rows_key(rows):
    return sorted(tuple(sorted(r.items())) for r in rows)


def sweep_store(seed):
    """The mini random store the in-process property test uses."""
    import numpy as np

    rng = np.random.default_rng(seed)
    ents = [f"<e{i}>" for i in range(6)]
    triples = set()
    for _ in range(40):
        triples.add((
            ents[rng.integers(6)],
            f"<p{rng.integers(3)}>",
            ents[rng.integers(6)],
        ))
    for i in range(6):
        triples.add((ents[i], "<age>", str(15 + 3 * i)))
    return sorted(triples)


def sweep_query(shape, p1, p2, cmp_op, cut):
    base = f"?x <p{p1}> ?y"
    if shape == "bgp":
        return f"SELECT ?x ?y ?z WHERE {{ {base} . ?y <p{p2}> ?z . }}"
    if shape == "filter":
        return (f"SELECT ?x ?y ?a WHERE {{ {base} . ?x <age> ?a . "
                f"FILTER (?a {cmp_op} {cut} || ?x = <e1>) }}")
    if shape == "optional":
        return (f"SELECT ?x ?y ?z WHERE {{ {base} . "
                f"OPTIONAL {{ ?x <p{p2}> ?z }} }}")
    return (f"SELECT ?x ?v WHERE {{ {{ ?x <p{p1}> ?v }} UNION "
            f"{{ ?x <p{p2}> ?v }} }}")


def main():
    assert jax.device_count() == N_DEV, (jax.device_count(), N_DEV)
    store = lubm.generate(scale=1, seed=0, join_shapes=True)
    single = QueryEngine(store)
    sharded = ShardedQueryEngine(shard_store(store, N_DEV))
    queries = {**lubm.QUERIES, **lubm.J_QUERIES, **EXTRA}
    bucket_wins = 0
    for name, text in queries.items():
        pq_single = single.prepare(text)
        pq_sharded = sharded.prepare(text)
        rows_single = pq_single.run()
        rows_sharded = pq_sharded.run()
        if name == "L1":  # any right-sized subset is a correct slice
            want = rows_key(reference_rows(store, parse(text)))
            assert len(rows_single) == len(rows_sharded) == 17
            assert set(rows_key(rows_sharded.rows)) <= set(want), name
        else:
            want = rows_key(reference_rows(store, parse(text)))
            assert rows_key(rows_single.rows) == want, name
            assert rows_key(rows_sharded.rows) == want, (
                name, len(rows_sharded), len(want))
        # warm: one shard_map dispatch, zero compiles, for both engines
        warm_sh = pq_sharded.run()
        assert warm_sh.stats.n_dispatches == 1, (name, warm_sh.stats)
        assert warm_sh.stats.n_compiles == 0, (name, warm_sh.stats)
        warm_si = pq_single.run()
        # per-shard bucket accounting vs the single-device bucket
        sh_b = warm_sh.stats.peak_join_bucket
        si_b = warm_si.stats.peak_join_bucket
        assert sh_b <= si_b, (name, sh_b, si_b)
        if sh_b < si_b:
            bucket_wins += 1
        print(f"ok {name}: rows={len(rows_sharded)} "
              f"per_shard_bucket={sh_b} single_bucket={si_b}")
    if N_DEV > 1:
        assert bucket_wins > 0, "sharding never shrank a join bucket"
    if N_DEV == 8:
        # hierarchical 2x4 (pod x data) mesh: the two-stage shuffle routes
        # inter-pod first, then intra-pod — results must stay identical
        mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
        hier = ShardedQueryEngine(shard_store(store, 8), mesh=mesh2)
        for name in ("Q2", "Q9", "U1"):
            text = queries[name]
            want = rows_key(reference_rows(store, parse(text)))
            assert rows_key(hier.query(text)) == want, ("2x4", name)
        print("ok hierarchical 2x4 mesh")
    # deterministic slice of the property-test space
    for seed in (0, 3, 5):
        triples = sweep_store(seed)
        st = store_from_string_triples(triples)
        s_eng = ShardedQueryEngine(shard_store(st, N_DEV))
        for shape in ("bgp", "filter", "optional", "union"):
            text = sweep_query(shape, seed % 3, (seed + 1) % 3,
                               "<" if seed % 2 else ">=", 18 + seed)
            want = rows_key(reference_rows(st, parse(text)))
            got = rows_key(s_eng.query(text))
            assert got == want, (seed, shape, text)
        print(f"ok sweep seed={seed}")
    check_shuffle_elision(store, sharded)
    check_broadcast_join()
    check_stacked_batch()
    print(f"ALL SHARDED QUERY CASES PASSED n_dev={N_DEV}")


def check_shuffle_elision(store, sharded):
    """Partitioning-aware lowering at a real device count: the subject-
    star emits ZERO shuffle collectives (both scans born subject-hash
    aligned on the join key), the chain emits exactly one per join (the
    probe side arrives partitioned on the previous key)."""
    star = lubm.PREFIX + """SELECT ?s ?a WHERE {
        ?s a ub:GraduateStudent . ?s ub:advisor ?a . }"""
    pq = sharded.prepare(star)
    want = rows_key(reference_rows(store, parse(star)))
    assert rows_key(pq.run().rows) == want
    warm = pq.run()
    assert warm.stats.n_shuffles_emitted == 0, warm.stats
    assert warm.stats.n_shuffles_elided == 2, warm.stats
    chain = lubm.PREFIX + """SELECT ?s ?n WHERE {
        ?s ub:advisor ?p . ?p ub:name ?n . }"""
    pq = sharded.prepare(chain)
    want = rows_key(reference_rows(store, parse(chain)))
    assert rows_key(pq.run().rows) == want
    warm = pq.run()
    if N_DEV > 1:
        assert warm.stats.n_shuffles_emitted == 1, warm.stats
        assert warm.stats.n_shuffles_elided == 1, warm.stats
    else:  # 1 shard: everything is trivially aligned
        assert warm.stats.n_shuffles_emitted == 0, warm.stats
    print("ok shuffle elision (star=0 emitted, chain=1 emitted)")


def check_broadcast_join():
    """Both join inputs misaligned on an object-object key + a small
    build side: the lowering replicates the small side with ONE
    all_gather instead of shuffling both — and the answer still matches
    the oracle."""
    st = store_from_string_triples(sweep_store(0))
    eng = ShardedQueryEngine(shard_store(st, N_DEV))
    text = "SELECT ?x ?y ?z WHERE { ?x <p0> ?y . ?z <p1> ?y . }"
    want = rows_key(reference_rows(st, parse(text)))
    pq = eng.prepare(text)
    assert rows_key(pq.run().rows) == want
    warm = pq.run()
    if N_DEV > 1:
        assert warm.stats.n_broadcast_joins == 1, warm.stats
        assert warm.stats.n_shuffles_emitted == 0, warm.stats
    print("ok broadcast join")


def check_stacked_batch():
    """Warm same-shape queries (different runtime constants) ride ONE
    stacked (lanes x shards) dispatch on the real mesh."""
    st = store_from_string_triples(sweep_store(3))
    eng = ShardedQueryEngine(shard_store(st, N_DEV))
    texts = [sweep_query("filter", 0, 1, ">=", cut) for cut in (16, 19, 25)]
    eng.query(texts[0])  # warm the shape
    prepared = [eng.prepare(t) for t in texts]
    out = eng.run_batch(prepared)
    for t, rs in zip(texts, out):
        assert rows_key(rs.rows) == rows_key(
            reference_rows(st, parse(t))), t
    group = eng.last_batch[0]
    assert not group.fallback, "stacked sharded dispatch fell back"
    assert group.widths == (4,), group
    assert group.n_dispatches == 1, group
    print("ok stacked batch")


if __name__ == "__main__":
    main()
