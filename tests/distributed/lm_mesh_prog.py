"""8-device LM validation: the full train_step + serve_step lower, compile
AND execute on a (1,2,4) pod mesh with real (reduced) weights — catching
sharding bugs that the abstract dry-run can't (numerics, donation).
Also checks multi-device loss == single-device loss (sharding-invariance).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402

from repro.core import compat  # noqa: E402

import dataclasses
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, adamw_init


def main():
    assert jax.device_count() == 8
    cfg = T.TransformerConfig(
        name="mesh-test", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_head=8, d_ff=64, vocab=250,  # 250 -> padded_vocab 256 exercised
        n_experts=6, top_k=2, d_expert_ff=32, capacity_factor=8.0,
        kv_chunk=8, remat=True,
    )
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    params = T.init_params(jax.random.PRNGKey(0), cfg, ep=2)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": labels}

    opt = adamw_init(params)
    step = jax.jit(T.make_train_step(cfg, mesh, AdamWConfig(), True))
    with compat.set_mesh(mesh):
        p2, s2, m = step(params, opt, batch)
        loss_mesh = float(m["loss"])
    assert np.isfinite(loss_mesh)
    for leaf in jax.tree.leaves(p2):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
    print(f"train_step on 2x2x2 mesh: loss={loss_mesh:.4f}")

    # sharding invariance: same loss on a single-device mesh
    mesh1 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    params1 = T.init_params(jax.random.PRNGKey(0), cfg, ep=2)
    step1 = jax.jit(T.make_loss_fn(cfg, mesh1, True))
    with compat.set_mesh(mesh1):
        loss1, _ = step1(params1, tokens, labels)
    stepm = jax.jit(T.make_loss_fn(cfg, mesh, True))
    with compat.set_mesh(mesh):
        lossm, _ = stepm(params, tokens, labels)
    np.testing.assert_allclose(float(lossm), float(loss1), rtol=2e-3)
    print(f"loss sharding-invariance: {float(lossm):.5f} == {float(loss1):.5f}")

    # serve_step on the mesh (donated caches)
    serve = jax.jit(T.make_serve_step(cfg, mesh, True), donate_argnums=(1, 2))
    kc, vc = T.init_decode_cache(cfg, 8, 64)
    with compat.set_mesh(mesh):
        nxt, kc, vc = serve(params, kc, vc, jnp.int32(0), tokens[:, 0])
        nxt2, kc, vc = serve(params, kc, vc, jnp.int32(1), nxt)
    assert nxt2.shape == (8,) and int(nxt2.max()) < cfg.vocab
    print("serve_step on mesh: two decode steps OK")
    print("LM MESH TRAIN/SERVE PASSED")


if __name__ == "__main__":
    main()
