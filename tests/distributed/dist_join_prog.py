"""Subprocess body: distributed MR join on 8 fake CPU devices vs oracle.

Run via tests/test_distributed.py (sets XLA_FLAGS before jax import).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import distributed as dj  # noqa: E402
from repro.core.relation import Relation  # noqa: E402


def oracle_join(l_schema, l_rows, r_schema, r_rows):
    shared = [v for v in l_schema if v in r_schema]
    r_extra = [v for v in r_schema if v not in l_schema]
    out = []
    for lr in l_rows:
        for rr in r_rows:
            if all(lr[l_schema.index(v)] == rr[r_schema.index(v)] for v in shared):
                out.append(tuple(lr) + tuple(rr[r_schema.index(v)] for v in r_extra))
    return out


def run_case(mesh, axis_names, l_rows, r_rows, seed):
    l_schema, r_schema = ("?k", "?a"), ("?k", "?b")
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    left = Relation.from_numpy(l_schema, l_rows,
                               capacity=_pad(len(l_rows), n_shards))
    right = Relation.from_numpy(r_schema, r_rows,
                                capacity=_pad(len(r_rows), n_shards))
    fn = dj.make_distributed_join(mesh, axis_names, bucket_capacity=64,
                                  join_capacity=256, left_schema=l_schema,
                                  right_schema=r_schema)
    out, totals, ov = fn(left, right)
    assert not bool(np.any(np.asarray(ov))), "bucket/join overflow"
    expected = sorted(oracle_join(l_schema, l_rows.tolist(), r_schema,
                                  r_rows.tolist()))
    got = sorted(map(tuple, out.to_numpy().tolist()))
    assert got == expected, (len(got), len(expected))
    assert int(np.asarray(totals).sum()) == len(expected)
    print(f"ok seed={seed} axes={axis_names} results={len(expected)}")


def _pad(n, m):
    return ((max(n, 1) + m - 1) // m) * m


def main():
    assert jax.device_count() == 8, jax.device_count()
    rng = np.random.RandomState(0)
    # flat shuffle on one axis
    mesh1 = jax.make_mesh((8,), ("data",))
    # hierarchical: pod x data
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    for seed in range(3):
        rng = np.random.RandomState(seed)
        l_rows = rng.randint(0, 12, size=(rng.randint(8, 60), 2)).astype(np.int32)
        r_rows = rng.randint(0, 12, size=(rng.randint(8, 60), 2)).astype(np.int32)
        run_case(mesh1, ("data",), l_rows, r_rows, seed)
        run_case(mesh2, ("pod", "data"), l_rows, r_rows, seed)
    print("ALL DISTRIBUTED JOIN CASES PASSED")


if __name__ == "__main__":
    main()
