"""8-device validation of the MapSQ-dispatch MoE and the sharded embedding
lookup: outputs AND gradients must match the single-path dense references.

Run via tests/test_distributed.py in a subprocess (device count locks at
first jax init, so the main pytest process keeps 1 device).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402

from repro.core import compat  # noqa: E402

import jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P

from repro.models import moe as M

TOL = dict(rtol=2e-3, atol=2e-3)


def dense_moe_reference(p: M.MoEParams, x, st: M.MoESettings, e_pad: int):
    """Every expert applied to every token, combined by top-k gates —
    O(E) compute but exact (no capacity drops at high cf)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p.router.astype(jnp.float32)
    logits = jnp.where(jnp.arange(e_pad) < st.n_experts, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, st.top_k)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(xf.shape[0])[:, None], eidx].set(gate_vals)
    g = jnp.einsum("td,edf->etf", xf, p.we_gate)
    u = jnp.einsum("td,edf->etf", xf, p.we_up)
    h = jax.nn.silu(g.astype(jnp.float32)) * u.astype(jnp.float32)
    eo = jnp.einsum("etf,efd->etd", h.astype(x.dtype), p.we_down)
    y = jnp.einsum("te,etd->td", gates.astype(jnp.float32),
                   eo.astype(jnp.float32))
    return y.astype(x.dtype).reshape(b, s, d)


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    st = M.MoESettings(n_experts=6, top_k=2, d_expert_ff=32,
                       capacity_factor=8.0)  # high cf => no drops
    ep = 4
    e_pad = st.e_pad(ep)  # 8
    d_model = 16
    key = jax.random.PRNGKey(0)
    p = M.init_moe_params(key, d_model, st, ep, jnp.float32)
    b, s = 4, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d_model), jnp.float32)

    token_spec = P(("data",), "model", None)
    pspec = M.MoEParams(router=P(None, None), we_gate=P("model", None, None),
                        we_up=P("model", None, None),
                        we_down=P("model", None, None))
    ep_fn = jax.jit(compat.shard_map(
        partial(M.moe_ffn_ep_local, st=st, expert_axis="model"),
        mesh=mesh, in_specs=(pspec, token_spec), out_specs=token_spec,
        check_vma=False,
    ))
    with compat.set_mesh(mesh):
        y_ep = ep_fn(p, x)
    y_ref = dense_moe_reference(p, x, st, e_pad)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref), **TOL)
    print("forward: EP(shard_map, 8dev) == dense reference")

    y_oh = M.moe_ffn_onehot(p, x, st, e_pad)
    np.testing.assert_allclose(np.asarray(y_oh), np.asarray(y_ref), **TOL)
    print("forward: one-hot dispatch == dense reference")

    # gradient exactness through the all_to_all round trip
    tgt = jax.random.normal(jax.random.PRNGKey(2), (b, s, d_model))

    def loss_ep(p, x):
        return jnp.mean((ep_fn(p, x) - tgt) ** 2)

    def loss_ref(p, x):
        return jnp.mean((dense_moe_reference(p, x, st, e_pad) - tgt) ** 2)

    g_ep = jax.grad(loss_ep, argnums=(0, 1))(p, x)
    g_ref = jax.grad(loss_ref, argnums=(0, 1))(p, x)
    for a, b_ in zip(jax.tree.leaves(g_ep), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), **TOL)
    print("grads: EP == dense reference (params AND activations)")

    # ---- sharded embedding lookup (deepfm path) --------------------------
    from repro.models.recsys import deepfm as D

    table = jax.random.normal(jax.random.PRNGKey(3), (64, 5))
    ids = jax.random.randint(jax.random.PRNGKey(4), (128,), 0, 64)
    lookup = jax.jit(D.make_sharded_lookup(mesh, ("data",), cap=64))
    with compat.set_mesh(mesh):
        rows = lookup(table, ids)
    np.testing.assert_allclose(np.asarray(rows), np.asarray(table[ids]),
                               **TOL)
    print("lookup: sharded all_to_all == take")

    def loss_l(t):
        return jnp.sum(lookup(t, ids) ** 2)

    g1 = jax.grad(loss_l)(table)
    g2 = jax.grad(lambda t: jnp.sum(t[ids] ** 2))(table)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), **TOL)
    print("lookup grads: scatter-add transpose exact")

    print("ALL MOE/LOOKUP DISTRIBUTED CASES PASSED")


if __name__ == "__main__":
    main()
