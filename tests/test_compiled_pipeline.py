"""The compiled query pipeline: plan IR, one-dispatch executor, plan/compile
cache — hit/miss accounting, bucket-overflow retry, compiled-vs-eager
differential results, device-side DISTINCT, and the `;` parser extension."""
import numpy as np
import pytest

from repro.core import plan_ir
from repro.sparql import lubm
from repro.sparql.engine import QueryEngine
from repro.sparql.parser import ParseError, parse
from repro.sparql.store import store_from_string_triples


@pytest.fixture(scope="module")
def lubm_store():
    return lubm.generate(scale=1, seed=0)


def rows_as_sets(rows):
    return sorted(tuple(sorted(r.items())) for r in rows)


# ---------------------------------------------------------------- bucketing


def test_bucket_capacity_quantizes_pow2_with_floor():
    assert plan_ir.bucket_capacity(0) == plan_ir.MIN_BUCKET
    assert plan_ir.bucket_capacity(1) == plan_ir.MIN_BUCKET
    assert plan_ir.bucket_capacity(8) == 8
    assert plan_ir.bucket_capacity(9) == 16
    assert plan_ir.bucket_capacity(1000) == 1024
    # near-miss sizes share a bucket -> share a compiled shape
    assert plan_ir.bucket_capacity(513) == plan_ir.bucket_capacity(1024)


def test_canonical_renaming_is_order_stable():
    m = plan_ir.canonical_renaming((("?b", "?a"), ("?a", "?z")))
    assert m == {"?b": "?c0", "?a": "?c1", "?z": "?c2"}


# ------------------------------------------------------- cache hit behaviour


def test_warm_cache_zero_compiles_single_dispatch(lubm_store):
    """Acceptance: a repeated LUBM query = 0 jit compiles, 1 device dispatch
    for the whole join chain, no per-join count passes, no retries."""
    eng = QueryEngine(lubm_store)
    for name, text in lubm.QUERIES.items():
        q = parse(text)
        _, cold = eng.execute(q)
        assert cold.cache_misses == 1 and cold.n_compiles == 1, name
        rel, warm = eng.execute(q)
        assert warm.cache_hits == 1, name
        assert warm.n_compiles == 0, name
        assert warm.n_dispatches == 1, name
        assert warm.n_count_passes == 0, name
        assert warm.n_retries == 0, name
        assert len(rel.to_numpy()) > 0, name


def test_cache_shared_across_variable_renames(lubm_store):
    """Same structure, different variable spelling -> same compiled plan."""
    eng = QueryEngine(lubm_store)
    q1 = lubm.PREFIX + """SELECT ?s ?p WHERE {
        ?s ub:advisor ?p . ?p ub:worksFor <http://example.org/Dept0_0> . }"""
    q2 = lubm.PREFIX + """SELECT ?student ?adv WHERE {
        ?student ub:advisor ?adv .
        ?adv ub:worksFor <http://example.org/Dept0_0> . }"""
    _, s1 = eng.execute(parse(q1))
    rel, s2 = eng.execute(parse(q2))
    assert s1.cache_misses == 1
    assert s2.cache_hits == 1 and s2.n_compiles == 0
    assert rel.schema == ("?student", "?adv")


def test_cache_miss_on_different_shape(lubm_store):
    eng = QueryEngine(lubm_store)
    _, s1 = eng.execute(parse(lubm.QUERIES["Q2"]))
    _, s2 = eng.execute(parse(lubm.QUERIES["Q4"]))
    assert s1.cache_misses == 1 and s2.cache_misses == 1
    assert len(eng.plan_cache) == 2


# ------------------------------------------------------- overflow -> retry


def test_bucket_overflow_grows_and_retries():
    """A same-shape query with a much larger join result overflows the
    cached bucket; the engine grows it from the exact totals and recompiles
    (the host-level Mars fallback), still returning exact results."""
    triples = [("<z>", "<p0>", "<w>")]
    triples += [(f"<h>", "<p0>", f"<v{i}>") for i in range(50)]
    triples += [("<z>", "<p1>", "<c1>"), ("<h>", "<p1>", "<c2>")]
    store = store_from_string_triples(triples)
    eng = QueryEngine(store)

    def q(const):
        return f"SELECT ?x ?y WHERE {{ ?x <p0> ?y . ?x <p1> <{const}> . }}"

    rows1 = eng.query(q("c1"))  # cold: calibrates tiny join bucket
    assert rows_as_sets(rows1) == rows_as_sets([{"?x": "<z>", "?y": "<w>"}])
    rel, stats = eng.execute(parse(q("c2")))  # warm hit, 50 results
    assert stats.cache_hits == 1
    assert stats.n_retries >= 1 and stats.n_compiles >= 1
    got = {tuple(int(x) for x in r) for r in rel.to_numpy()}
    eager = QueryEngine(store, compiled=False)
    want, _ = eager.execute(parse(q("c2")))
    assert got == want.to_set()
    assert len(got) == 50
    # the grown bucket is now cached: next time, no retry
    _, again = eng.execute(parse(q("c2")))
    assert again.n_retries == 0 and again.n_compiles == 0
    assert again.n_dispatches == 1


# ------------------------------------------- compiled vs eager differential


def test_compiled_matches_eager_on_lubm(lubm_store):
    compiled = QueryEngine(lubm_store)
    eager = QueryEngine(lubm_store, compiled=False)
    for name, text in lubm.QUERIES.items():
        for _ in range(2):  # cold then warm
            assert rows_as_sets(compiled.query(text)) == rows_as_sets(
                eager.query(text)
            ), name


def test_compiled_matches_eager_with_distinct(lubm_store):
    text = lubm.PREFIX + """SELECT DISTINCT ?d WHERE {
        ?s ub:memberOf ?d . ?s ub:advisor ?p . }"""
    compiled = QueryEngine(lubm_store)
    eager = QueryEngine(lubm_store, compiled=False)
    got_c = compiled.query(text)
    got_e = eager.query(text)
    assert rows_as_sets(got_c) == rows_as_sets(got_e)
    # dedup really happened (device-side, before decode)
    depts = [r["?d"] for r in got_c]
    assert len(depts) == len(set(depts)) == 15


def test_distinct_deduplicates_before_decode():
    triples = [
        ("<doctor>", "<workAt>", '"Hospital"'),
        ("<nurse>", "<workAt>", '"Hospital"'),
        ("<professor>", "<workAt>", '"University"'),
    ]
    for compiled in (True, False):
        eng = QueryEngine(store_from_string_triples(triples), compiled=compiled)
        q = parse('SELECT DISTINCT ?place WHERE { ?job <workAt> ?place . }')
        rel, _ = eng.execute(q)
        rows = rel.to_numpy()
        assert len(rows) == 2  # already unique on device
        assert sorted(r["?place"] for r in eng.query(
            'SELECT DISTINCT ?place WHERE { ?job <workAt> ?place . }'
        )) == ['"Hospital"', '"University"']


# --------------------------------------------------------- scans & serving


def test_device_scans_upload_once():
    store = lubm.generate(scale=1, seed=3)
    eng = QueryEngine(store)
    eng.query(lubm.QUERIES["Q4"])
    misses_after_cold = store.scan_cache_stats()["misses"]
    eng.query(lubm.QUERIES["Q4"])
    s = store.scan_cache_stats()
    assert s["misses"] == misses_after_cold  # no re-staging on the warm run
    assert s["hits"] >= 3  # one per pattern


def test_server_reports_cache_hit_rate():
    from repro.serve.sparql_server import SPARQLServer

    store = lubm.generate(scale=1, seed=2)
    srv = SPARQLServer(QueryEngine(store), max_batch=4)
    try:
        text = lubm.QUERIES["Q1"]
        for _ in range(4):
            srv.query(text)
        stats = srv.stats()
        assert stats["requests"] == 4
        assert stats["plan_cache"]["misses"] == 1
        assert stats["plan_cache"]["hits"] == 3
        assert stats["plan_cache"]["hit_rate"] == pytest.approx(0.75)
        assert stats["scan_cache"]["hits"] > 0
    finally:
        srv.close()


def test_server_survives_bad_query():
    from repro.serve.sparql_server import SPARQLServer

    store = store_from_string_triples([("<a>", "<p>", "<b>")])
    srv = SPARQLServer(QueryEngine(store), max_batch=2)
    try:
        with pytest.raises(ParseError):
            srv.query("SELECT garbage")
        # the worker thread survived; later requests still serve
        assert srv.query("SELECT ?x WHERE { ?x <p> <b> . }") == [
            {"?x": "<a>"}
        ]
    finally:
        srv.close()


# ------------------------------------------------------------------ parser


def test_parser_semicolon_predicate_object_list():
    q = parse(lubm.PREFIX + """SELECT ?x ?d WHERE {
        ?x a ub:GraduateStudent ; ub:memberOf ?d .
    }""")
    assert len(q.patterns) == 2
    assert q.patterns[0].s == q.patterns[1].s == "?x"
    assert q.patterns[0].p.endswith("rdf-syntax-ns#type>")
    assert q.patterns[1].o == "?d"


def test_parser_semicolon_executes_like_expanded_form(lubm_store):
    eng = QueryEngine(lubm_store)
    compact = lubm.PREFIX + """SELECT ?s ?d WHERE {
        ?s a ub:GraduateStudent ; ub:memberOf ?d ; ub:advisor ?p . }"""
    expanded = lubm.PREFIX + """SELECT ?s ?d WHERE {
        ?s a ub:GraduateStudent .
        ?s ub:memberOf ?d .
        ?s ub:advisor ?p . }"""
    assert rows_as_sets(eng.query(compact)) == rows_as_sets(
        eng.query(expanded)
    )


def test_parser_semicolon_trailing_and_errors():
    q = parse('SELECT ?x WHERE { ?x <p> <o> ; . }')  # dangling ; tolerated
    assert len(q.patterns) == 1
    with pytest.raises(ParseError):
        parse('SELECT ?x WHERE { ?x <p> ; <o> . }')  # ; needs a full p-o pair
