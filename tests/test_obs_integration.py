"""End-to-end observability: traces propagating through the engine, the
micro-batcher, stacked dispatches and the decode pool (failure paths
included), the device-time accounting identity, terminal-outcome
single-counting, EXPLAIN ANALYZE actuals, and the Prometheus exposition
of the serving counters."""
import threading
import time

from repro.obs import Tracer, parse_prometheus
from repro.serve.batcher import MicroBatcher, Request
from repro.serve.decode import DecodePool
from repro.sparql.engine import PendingDecode, QueryEngine
from repro.sparql.store import store_from_string_triples

from tests.test_serving_pipeline import QUERIES, pipeline_store


def _server(store, tracer=None, **kw):
    from repro.serve.sparql_server import SPARQLServer

    kw.setdefault("max_batch", 8)
    return SPARQLServer(QueryEngine(store, tracer=tracer), **kw)


def _all_span_names(traces):
    names = set()
    for t in traces:
        names.update(s.name for s in t.spans)
    return names


# ------------------------------------------------ device-time identity


def test_device_time_equals_sum_over_exec_stats():
    """Satellite: engine.device_time_s (the global device-busy ledger)
    must equal the sum of per-run ExecStats.device_time_s over every run
    — cold calibration, warm compiled, and stacked batched runs included
    (per-lane shares partition each stacked dispatch's time)."""
    store = pipeline_store()
    eng = QueryEngine(store)
    per_run = []
    for text in QUERIES:
        pq = eng.prepare(text)
        pq.run()  # cold: calibration + compile
        per_run.append(pq.last_stats.device_time_s)
        pq.run()  # warm: single compiled dispatch
        per_run.append(pq.last_stats.device_time_s)
    # stacked batch: four copies of one shape coalesce into one dispatch
    ps = [eng.prepare(QUERIES[0]) for _ in range(4)]
    eng.run_batch(ps)
    per_run.extend(p.last_stats.device_time_s for p in ps)
    total = sum(per_run)
    assert total > 0.0
    assert abs(eng.device_time_s - total) <= 1e-6 * max(1.0, total), (
        f"engine ledger {eng.device_time_s} != sum over runs {total}"
    )


def test_device_time_identity_eager_mode():
    store = pipeline_store()
    eng = QueryEngine(store, compiled=False)
    per_run = []
    for text in QUERIES:
        pq = eng.prepare(text)
        pq.run()
        per_run.append(pq.last_stats.device_time_s)
    total = sum(per_run)
    assert total > 0.0
    assert abs(eng.device_time_s - total) <= 1e-6 * max(1.0, total)


# ------------------------------------------------- engine-level tracing


def test_trace_covers_pipeline_phases_and_closes():
    store = pipeline_store()
    tracer = Tracer()
    eng = QueryEngine(store, tracer=tracer)
    tr = tracer.new_trace("query")
    pq = eng.prepare(QUERIES[0], trace=tr)
    pq.run(trace=tr)
    tracer.finish(tr, outcome="ok")
    names = {s.name for s in tr.spans}
    for expected in ("query", "parse", "optimize", "compile", "dispatch",
                     "transfer", "decode"):
        assert expected in names, f"missing span {expected}: {names}"
    assert tr.open_spans() == []
    # the calibration dispatch is marked as such
    disp = tr.find("dispatch")
    assert any(s.attrs.get("calibration") for s in disp)


def test_stacked_dispatch_fans_out_with_shared_dispatch_id():
    """One stacked device launch must appear in every lane's trace as a
    dispatch span sharing the dispatch_id, with distinct lane indices."""
    store = pipeline_store()
    tracer = Tracer()
    eng = QueryEngine(store, tracer=tracer)
    eng.prepare(QUERIES[0]).run()  # warm the shape
    ps = [eng.prepare(QUERIES[0]) for _ in range(4)]
    traces = [tracer.new_trace("query") for _ in ps]
    outcomes = eng.run_batch_pipelined(ps, traces=traces)
    for oc in outcomes:
        if isinstance(oc, PendingDecode):
            oc.resolve()
        else:
            assert not isinstance(oc, Exception), oc
    for tr in traces:
        tracer.finish(tr)
    spans = [s for tr in traces for s in tr.find("dispatch")
             if s.attrs.get("stacked")]
    assert len(spans) == 4
    assert len({s.attrs["dispatch_id"] for s in spans}) == 1
    assert sorted(s.attrs["lane"] for s in spans) == [0, 1, 2, 3]
    assert all(s.attrs["width"] == 4 for s in spans)
    assert tracer.open_span_count() == 0


# ------------------------------------------------- server-level tracing


def test_server_traces_requests_and_ring_holds_them():
    store = pipeline_store()
    tracer = Tracer(slow_ms=0.0)
    srv = _server(store, tracer=tracer)
    try:
        for text in QUERIES:
            srv.query(text)
        traces = srv.recent_traces()
        assert len(traces) == len(QUERIES)
        names = _all_span_names(traces)
        for expected in ("query", "parse", "optimize", "dispatch",
                         "transfer", "decode"):
            assert expected in names
        assert all(t.root.attrs["outcome"] == "ok" for t in traces)
        assert tracer.open_span_count() == 0
        assert len(srv.slow_queries()) == len(QUERIES)  # slow_ms=0
    finally:
        srv.close()


def test_concurrent_serving_leaves_zero_open_spans():
    """Acceptance: a 32-thread serving run (mixed shapes, parse failures
    included) retires every trace with zero open spans."""
    store = pipeline_store()
    tracer = Tracer(ring_size=128)
    srv = _server(store, tracer=tracer, max_wait_s=0.02, decode_workers=2)
    try:
        n = 32
        errs = [None] * n

        def hit(i):
            try:
                text = "BROKEN {" if i % 11 == 5 else QUERIES[i % 4]
                srv.query(text)
            except Exception as e:
                errs[i] = e

        ts = [threading.Thread(target=hit, args=(i,)) for i in range(n)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        traces = srv.recent_traces()
        assert len(traces) == n
        assert tracer.open_span_count() == 0
        outcomes = [t.root.attrs["outcome"] for t in traces]
        assert outcomes.count("error") == sum(
            1 for i in range(n) if i % 11 == 5
        )
    finally:
        srv.close()


# --------------------------------------------------- failure-path spans


def test_decode_worker_crash_closes_spans():
    store = pipeline_store()
    tracer = Tracer()
    srv = _server(store, tracer=tracer, decode_workers=1)
    try:
        srv.query(QUERIES[0])  # warm
        real = srv.engine._decode_numpy
        crashed = []

        def sabotage(schema, rows):
            if not crashed:
                crashed.append(1)
                raise RuntimeError("decode worker crash")
            return real(schema, rows)

        srv.engine._decode_numpy = sabotage
        try:
            try:
                srv.query(QUERIES[0])
            except Exception:
                pass
        finally:
            srv.engine._decode_numpy = real
        traces = srv.recent_traces()
        assert "decode_error" in _all_span_names(traces)
        assert tracer.open_span_count() == 0
        crashed_trace = traces[-1]
        assert crashed_trace.root.attrs["outcome"] == "error"
    finally:
        srv.close()


def test_abandoned_request_skip_closes_spans():
    """The decode pool's abandoned-skip path records its marker span on
    the request's trace instead of leaving the trace path dangling."""
    tracer = Tracer()
    pool = DecodePool(n_workers=1, max_queue=8)
    try:
        tr = tracer.new_trace("query")
        r = Request("x", trace=tr)
        r.abandoned = True
        ran = []
        pool.submit(r, lambda: ran.append(1))
        assert r.event.wait(5)
        tracer.finish(tr, outcome="timeout")
        assert not ran
        skips = tr.find("decode_skipped")
        assert len(skips) == 1 and skips[0].attrs["abandoned"]
        assert tr.open_spans() == []
    finally:
        pool.close()


def test_batch_error_fanout_closes_spans():
    """A batch_fn explosion fans _exc_copy instances to every submitter;
    each request's trace gets a closed batch_error span."""
    tracer = Tracer()

    def boom(payloads):
        raise ValueError("batch exploded")

    b = MicroBatcher(boom, max_batch=4, max_wait_s=0.05)
    try:
        traces = [tracer.new_trace("query") for _ in range(3)]
        errs = []
        lock = threading.Lock()

        def hit(tr):
            try:
                b.submit("q", timeout=10, trace=tr)
            except ValueError as e:
                with lock:
                    errs.append(e)
            finally:
                tracer.finish(tr, outcome="error")

        ts = [threading.Thread(target=hit, args=(tr,)) for tr in traces]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert len(errs) == 3
        for tr in traces:
            spans = tr.find("batch_error")
            assert len(spans) == 1
            assert spans[0].attrs["error"] == "ValueError"
            assert tr.open_spans() == []
    finally:
        b.close()


# --------------------------------------- terminal-outcome single count


def test_timeout_counted_exactly_once_even_if_decode_completes():
    """Satellite regression: a request that times out and whose decode
    work later finishes must count once, as a timeout — never also under
    ok. Every request lands under exactly one outcome."""
    from repro.serve.sparql_server import QueryTimeoutError

    store = pipeline_store()
    srv = _server(store, decode_workers=1)
    try:
        srv.query(QUERIES[0])  # warm (ok #1)
        real = srv.engine._decode_numpy
        slow = []

        def sluggish(schema, rows):
            if not slow:
                slow.append(1)
                time.sleep(0.4)  # decode outlives the submitter deadline
            return real(schema, rows)

        srv.engine._decode_numpy = sluggish
        try:
            try:
                srv.query(QUERIES[0], timeout_ms=50)
                raise AssertionError("expected QueryTimeoutError")
            except QueryTimeoutError:
                pass
            time.sleep(0.6)  # let the sluggish decode actually complete
        finally:
            srv.engine._decode_numpy = real
        srv.query(QUERIES[0])  # ok #2, after the timeout resolved late
        counts = {
            o: srv.engine.metrics.get("mapsq_requests_total")
            .labels(outcome=o).value
            for o in ("ok", "timeout", "error")
        }
        assert counts == {"ok": 2.0, "timeout": 1.0, "error": 0.0}
        st = srv.stats()
        assert st["timeouts"] == 1
    finally:
        srv.close()


# -------------------------------------------------- EXPLAIN ANALYZE


def test_explain_analyze_shows_estimates_and_actuals():
    store = pipeline_store()
    eng = QueryEngine(store)
    pq = eng.prepare(QUERIES[0])
    pq.run()
    text = pq.explain(analyze=True)
    assert "EXPLAIN ANALYZE (last run):" in text
    assert "est_rows=" in text and "actual_rows=" in text
    assert "q_error=" in text and "fill=" in text
    assert "mr_join" in text or "matrix_join" in text
    assert "rows_emitted=" in text
    # actuals match the decoded result
    rows = len(pq.run().rows)
    assert f"rows_emitted={rows}" in pq.explain(analyze=True)


def test_explain_analyze_runs_query_when_never_run():
    store = pipeline_store()
    eng = QueryEngine(store)
    pq = eng.prepare(QUERIES[0])
    assert pq.last_stats is None
    text = pq.explain(analyze=True)
    assert pq.last_stats is not None
    assert "actual_rows=" in text


def test_explain_without_analyze_unchanged():
    store = pipeline_store()
    eng = QueryEngine(store)
    out = eng.explain(QUERIES[0])
    assert "EXPLAIN ANALYZE" not in out


def test_exec_stats_carry_join_actuals():
    store = pipeline_store()
    eng = QueryEngine(store)
    pq = eng.prepare(QUERIES[0])
    rs = pq.run()
    st = pq.last_stats
    assert len(st.join_totals) == 1
    assert st.join_totals[0] > 0
    assert st.rows_emitted == len(rs.rows)
    assert len(st.join_caps) == len(st.join_totals)
    assert all(w <= c for w, c in zip(st.join_worst, st.join_caps))


# -------------------------------------------------- metrics exposition


def test_prometheus_exposes_serving_counters():
    store = pipeline_store()
    tracer = Tracer()
    srv = _server(store, tracer=tracer)
    try:
        for text in QUERIES:
            srv.query(text)
        parsed = parse_prometheus(srv.render_prometheus())
        for name in (
            "mapsq_requests_total",
            "mapsq_request_latency_seconds_bucket",
            "mapsq_prepared_cache_hits_total",
            "mapsq_plan_cache_hits_total",
            "mapsq_scan_cache_hits_total",
            "mapsq_stacked_dispatches_total",
            "mapsq_padding_padded_cells_total",
            "mapsq_deferred_total",
            "mapsq_decode_decoded_total",
            "mapsq_device_time_seconds_total",
            "mapsq_store_version",
            "mapsq_traces_total",
        ):
            assert name in parsed, f"exposition missing {name}"
        ok = [v for labels, v in parsed["mapsq_requests_total"]
              if labels["outcome"] == "ok"]
        assert ok == [float(len(QUERIES))]
    finally:
        srv.close()


def test_stats_shape_is_backward_compatible():
    store = pipeline_store()
    srv = _server(store)
    try:
        srv.query(QUERIES[0])
        st = srv.stats()
        assert set(st) == {
            "batches", "requests", "timeouts", "plan_cache", "scan_cache",
            "store", "updates", "prepared_cache", "batched", "pipeline",
        }
        assert set(st["updates"]) == {
            "requests", "rows_inserted", "rows_deleted"
        }
        assert set(st["prepared_cache"]) == {
            "entries", "hits", "misses", "hit_rate"
        }
        assert set(st["batched"]["padding"]) == {
            "padded_groups", "pad_rejects", "padded_cells", "real_cells",
            "waste_ratio",
        }
        assert set(st["pipeline"]) == {
            "deferred", "dispatch_s", "device_time_s", "decode"
        }
        srv.update("INSERT DATA { <s0> <p0> <m9> . }")
        assert srv.stats()["updates"]["requests"] == 1
    finally:
        srv.close()


def test_tracing_off_engine_has_no_tracer_overhead_paths():
    """With no Tracer attached the server must not create traces and the
    ring accessors stay empty."""
    store = pipeline_store()
    srv = _server(store)
    try:
        srv.query(QUERIES[0])
        assert srv.recent_traces() == []
        assert srv.slow_queries() == []
    finally:
        srv.close()
