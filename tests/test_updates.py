"""Mutable store / SPARQL UPDATE path: parser grammar for INSERT DATA /
DELETE DATA, delta-block write semantics (tail + tombstones, set
semantics, revival), compaction, versioned scan-cache eviction,
incremental statistics vs full recompute, snapshot-pinned prepared
handles, and the differential guarantee — after any sequence of updates,
query results equal both the NumPy oracle and a store rebuilt from
scratch, across operator shapes, join backends, eager/compiled and
sharded execution. Warm plan shapes re-run at 0 compiles / 1 dispatch
across writes and compaction as long as scans stay inside their
capacity buckets."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    from _hypothesis_compat import given, settings, st  # noqa: F401
    HAVE_HYPOTHESIS = False

from repro.core.planner import TriplePattern
from repro.sparql import algebra
from repro.sparql.baseline import reference_rows
from repro.sparql.engine import QueryEngine, ShardedQueryEngine
from repro.sparql.parser import ParseError, parse, parse_update
from repro.sparql.sharded_store import sharded_store_from_string_triples
from repro.sparql.store import StoreStatistics, store_from_string_triples

RDF_TYPE = "<http://www.w3.org/1999/02/22-rdf-syntax-ns#type>"


def rows_as_sets(rows):
    return sorted(tuple(sorted(r.items())) for r in rows)


def decoded_triples(store):
    d = store.dictionary
    return {
        (d.decode(int(s)), d.decode(int(p)), d.decode(int(o)))
        for s, p, o in np.asarray(store.triples)
    }


def _mini_triples(seed: int):
    rng = np.random.default_rng(seed)
    ents = [f"<e{i}>" for i in range(6)]
    triples = set()
    for _ in range(40):
        triples.add((
            ents[rng.integers(6)],
            f"<p{rng.integers(3)}>",
            ents[rng.integers(6)],
        ))
    for i in range(6):
        triples.add((ents[i], "<age>", str(15 + 3 * i)))
    return sorted(triples)


def _query_text(shape, p1=0, p2=1, cmp_op=">=", cut=21):
    base = f"?x <p{p1}> ?y"
    if shape == "bgp":
        return f"SELECT ?x ?y ?z WHERE {{ {base} . ?y <p{p2}> ?z . }}"
    if shape == "filter":
        return (f"SELECT ?x ?y ?a WHERE {{ {base} . ?x <age> ?a . "
                f"FILTER (?a {cmp_op} {cut} || ?x = <e1>) }}")
    if shape == "optional":
        return (f"SELECT ?x ?y ?z WHERE {{ {base} . "
                f"OPTIONAL {{ ?x <p{p2}> ?z }} }}")
    assert shape == "union"
    return (f"SELECT ?x ?v WHERE {{ {{ ?x <p{p1}> ?v }} UNION "
            f"{{ ?x <p{p2}> ?v }} }}")


# A fixed update script over the _mini_triples universe: inserts reuse
# existing entities (new edges), deletes hit rows every seed generates.
def _apply_script(store):
    ins1 = [("<e0>", "<p0>", "<e5>"), ("<e5>", "<p1>", "<e0>"),
            ("<e4>", "<p2>", "<e4>")]
    dels = [t for t in _mini_triples(3)[:6]]
    ins2 = [("<e2>", "<p0>", "<e2>"), ("<e1>", "<p2>", "<e5>")]
    store.insert_triples(ins1)
    store.delete_triples(dels)
    store.insert_triples(ins2)


# ------------------------------------------------------- parser grammar


def test_parse_update_insert_data():
    req = parse_update('INSERT DATA { <a> <p> <b> . <b> <p> "x" }')
    assert len(req.ops) == 1
    assert isinstance(req.ops[0], algebra.InsertData)
    assert req.n_triples() == 2
    assert req.ops[0].triples[0] == TriplePattern("<a>", "<p>", "<b>")


def test_parse_update_ops_in_order_with_trailing_semicolon():
    req = parse_update(
        "INSERT DATA { <a> <p> <b> } ; DELETE DATA { <c> <p> <d> } ;"
    )
    assert [type(op) for op in req.ops] == [
        algebra.InsertData, algebra.DeleteData
    ]


def test_parse_update_prefix_and_rdf_type_keyword():
    req = parse_update(
        "PREFIX ex: <http://ex.org/>\n"
        "INSERT DATA { ex:a a ex:T ; ex:p ex:b . ex:c ex:p ex:a }"
    )
    (op,) = req.ops
    assert op.triples[0] == TriplePattern(
        "<http://ex.org/a>", RDF_TYPE, "<http://ex.org/T>"
    )
    # the `;` predicate-object list shares its subject
    assert op.triples[1] == TriplePattern(
        "<http://ex.org/a>", "<http://ex.org/p>", "<http://ex.org/b>"
    )
    assert len(op.triples) == 3


@pytest.mark.parametrize("bad", [
    "INSERT DATA { ?x <p> <b> }",        # variables are not ground
    "INSERT { <a> <p> <b> }",            # DATA keyword required
    "DELETE DATA { <a> <p> }",           # triple needs three terms
    "SELECT ?x WHERE { ?x <p> ?y }",     # queries are not updates
])
def test_parse_update_rejects(bad):
    with pytest.raises(ParseError):
        parse_update(bad)


def test_format_update_names_ops():
    req = parse_update(
        "INSERT DATA { <a> <p> <b> } ; DELETE DATA { <a> <p> <b> }"
    )
    out = algebra.format_update(req.ops)
    assert "InsertData" in out and "DeleteData" in out


# -------------------------------------------------- store write semantics


def test_insert_delete_set_semantics():
    store = store_from_string_triples([("<a>", "<p>", "<b>")])
    assert store.insert_triples([("<a>", "<p>", "<b>")]) == 0  # dup
    assert store.insert_triples([("<a>", "<p>", "<c>")]) == 1
    assert store.delete_triples([("<z>", "<p>", "<q>")]) == 0  # absent
    assert store.delete_triples([("<a>", "<p>", "<c>")]) == 1  # tail row
    assert store.delete_triples([("<a>", "<p>", "<b>")]) == 1  # base row
    ws = store.write_stats()
    assert ws["tombstones"] == 1 and ws["tail_rows"] == 0
    assert ws["total_rows"] == 0
    assert decoded_triples(store) == set()


def test_reinsert_revives_tombstoned_base_row():
    store = store_from_string_triples([("<a>", "<p>", "<b>")])
    store.delete_triples([("<a>", "<p>", "<b>")])
    assert store.insert_triples([("<a>", "<p>", "<b>")]) == 1
    ws = store.write_stats()
    # revival un-tombstones the base row instead of appending a tail dup
    assert ws["tombstones"] == 0 and ws["tail_rows"] == 0
    assert decoded_triples(store) == {("<a>", "<p>", "<b>")}


def test_compact_folds_tail_and_clears_tombstones():
    store = store_from_string_triples(_mini_triples(0))
    _apply_script(store)
    before = decoded_triples(store)
    v = store.version
    store.compact()
    ws = store.write_stats()
    assert ws["tail_rows"] == 0 and ws["tombstones"] == 0
    assert ws["compactions"] == 1 and ws["version"] == v + 1
    assert ws["base_rows"] == ws["total_rows"] == len(before)
    assert decoded_triples(store) == before


def test_version_monotonic_per_committed_write():
    store = store_from_string_triples([("<a>", "<p>", "<b>")])
    v0 = store.version
    store.insert_triples([("<a>", "<p>", "<c>")])
    v1 = store.version
    assert v1 == v0 + 1
    store.insert_triples([("<a>", "<p>", "<c>")])  # no-op: dup
    assert store.version == v1
    store.delete_triples([("<a>", "<p>", "<c>")])
    assert store.version == v1 + 1


def test_scan_capacity_floor_survives_writes_and_compaction():
    store = store_from_string_triples(_mini_triples(0))
    tp = TriplePattern("?x", "<p0>", "?y")
    store.match_pattern_device(tp)  # establish the bucket floor
    cap0 = store.scan_capacity(tp)
    # deletes shrink the match count but not the floored capacity
    dels = [t for t in _mini_triples(0) if t[1] == "<p0>"][:3]
    store.delete_triples(dels)
    assert store.scan_capacity(tp) == cap0
    store.compact()
    assert store.scan_capacity(tp) == cap0


def test_stale_scan_cache_entries_evicted_not_leaked():
    store = store_from_string_triples(_mini_triples(0))
    tp = TriplePattern("?x", "<p0>", "?y")
    store.match_pattern_device(tp)
    entries0 = store.scan_cache_stats()["entries"]
    assert store.insert_triples([("<e0>", "<p0>", "<zz>")]) == 1
    store.match_pattern_device(tp)  # stale hit -> evict + restage
    st1 = store.scan_cache_stats()
    assert st1["evictions"] >= 1
    assert st1["entries"] == entries0  # replaced in place, no growth
    rel = store.match_pattern_device(tp)
    assert store.scan_cache_stats()["hits"] >= 1  # current-version hit
    assert rel is not None


def test_tombstoned_rows_masked_not_removed_from_staged_block():
    # plan-shape stability: a tombstoned base row keeps its slot with
    # valid=False, so the block shape (and compiled program) is unchanged
    store = store_from_string_triples(
        [("<a>", "<p>", "<b>"), ("<c>", "<p>", "<d>")]
    )
    tp = TriplePattern("?x", "<p>", "?y")
    r0 = store.match_pattern_device(tp)
    n_valid0 = int(np.asarray(r0.valid).sum())
    store.delete_triples([("<a>", "<p>", "<b>")])
    r1 = store.match_pattern_device(tp)
    assert r1.capacity == r0.capacity
    assert int(np.asarray(r1.valid).sum()) == n_valid0 - 1


# ----------------------------------------------- incremental statistics


def _assert_stats_match(inc, full, exact_degrees):
    assert inc.n_triples == full.n_triples
    assert inc.n_subjects == full.n_subjects
    assert inc.n_objects == full.n_objects
    assert inc.n_predicates == full.n_predicates
    assert set(inc.predicates) == set(full.predicates)
    for pid, ps in full.predicates.items():
        ips = inc.predicates[pid]
        assert ips.count == ps.count
        assert ips.n_subjects == ps.n_subjects
        assert ips.n_objects == ps.n_objects
        if exact_degrees:
            assert ips.max_s_degree == ps.max_s_degree
            assert ips.max_o_degree == ps.max_o_degree
        else:  # after deletes the max degree is an upper bound
            assert ips.max_s_degree >= ps.max_s_degree
            assert ips.max_o_degree >= ps.max_o_degree


def test_incremental_statistics_exact_on_inserts():
    store = store_from_string_triples(_mini_triples(1))
    _ = store.statistics  # materialize, then maintain incrementally
    store.insert_triples([
        ("<e0>", "<p0>", "<e5>"), ("<n1>", "<p9>", "<n2>"),
        ("<e0>", "<p0>", "<e4>"),
    ])
    _assert_stats_match(
        store.statistics, StoreStatistics.from_triples(store.triples),
        exact_degrees=True,
    )


def test_incremental_statistics_bounds_after_deletes():
    store = store_from_string_triples(_mini_triples(1))
    _ = store.statistics
    _apply_script(store)
    _assert_stats_match(
        store.statistics, StoreStatistics.from_triples(store.triples),
        exact_degrees=False,
    )
    store.compact()  # compaction schedules a full recompute
    _assert_stats_match(
        store.statistics, StoreStatistics.from_triples(store.triples),
        exact_degrees=True,
    )


# ----------------------------------------------- differential guarantee


def _check_against_oracle_and_rebuild(engine, store, texts):
    rebuilt = store_from_string_triples(sorted(decoded_triples(store)))
    fresh = QueryEngine(rebuilt, compiled=False)
    for text in texts:
        want = rows_as_sets(reference_rows(store, parse(text)))
        assert rows_as_sets(engine.query(text)) == want, text
        assert rows_as_sets(fresh.query(text)) == want, text


@pytest.mark.parametrize("shape", ["bgp", "filter", "optional", "union"])
def test_updates_differential_compiled(shape):
    store = store_from_string_triples(_mini_triples(0))
    eng = QueryEngine(store)
    text = _query_text(shape)
    before = rows_as_sets(eng.query(text))  # warm the shape pre-update
    _apply_script(store)
    _check_against_oracle_and_rebuild(eng, store, [text])
    store.compact()
    _check_against_oracle_and_rebuild(eng, store, [text])
    assert before == rows_as_sets(
        QueryEngine(store_from_string_triples(_mini_triples(0)),
                    compiled=False).query(text))


@pytest.mark.parametrize("backend", ["mr", "matrix"])
def test_updates_differential_join_backends(backend):
    store = store_from_string_triples(_mini_triples(2))
    eng = QueryEngine(store, join_backend=backend)
    text = _query_text("bgp", p1=1, p2=0)
    eng.query(text)
    _apply_script(store)
    _check_against_oracle_and_rebuild(eng, store, [text])


def test_updates_differential_eager():
    store = store_from_string_triples(_mini_triples(4))
    eng = QueryEngine(store, compiled=False)
    texts = [_query_text(s) for s in ("bgp", "filter", "union")]
    _apply_script(store)
    _check_against_oracle_and_rebuild(eng, store, texts)


def test_updates_differential_sharded():
    store = sharded_store_from_string_triples(_mini_triples(5), n_shards=1)
    eng = ShardedQueryEngine(store)
    text = _query_text("bgp")
    eng.query(text)  # warm pre-update
    _apply_script(store)
    _check_against_oracle_and_rebuild(eng, store, [text])
    ws = store.write_stats()
    assert ws["n_shards"] == 1 and ws["tail_rows"] > 0
    store.compact()
    _check_against_oracle_and_rebuild(eng, store, [text])
    assert store.write_stats()["compactions"] == 1


# --------------------------------- warm shapes survive writes (acceptance)


def test_warm_shape_zero_compiles_across_writes_and_compaction():
    store = store_from_string_triples(_mini_triples(0))
    eng = QueryEngine(store)
    pq = eng.prepare(_query_text("bgp"))
    pq.run()
    warm = pq.run()
    assert warm.stats.n_compiles == 0 and warm.stats.n_dispatches == 1
    # write within every pattern's bucket headroom, reusing existing terms
    # (a new term could grow the pow-2 numeric table = a legal recompile)
    tp1 = TriplePattern("?x", "<p0>", "?y")
    headroom = store.scan_capacity(tp1) - int(
        np.asarray(store.match_pattern_device(tp1).valid).sum())
    candidates = [(f"<e{i}>", "<p0>", f"<e{(i + 3) % 6}>")
                  for i in range(6)]
    new_rows = [t for t in candidates
                if t not in decoded_triples(store)][:max(1, headroom // 2)]
    assert store.insert_triples(new_rows) >= 1
    dels = [t for t in _mini_triples(0) if t[1] == "<p0>"][:2]
    assert store.delete_triples(dels) == 2
    rs = pq.run()
    assert rs.stats.n_compiles == 0 and rs.stats.n_dispatches == 1
    assert rs.stats.store_version == store.version
    store.compact()
    rs2 = pq.run()
    assert rs2.stats.n_compiles == 0 and rs2.stats.n_dispatches == 1
    want = rows_as_sets(reference_rows(store, parse(pq.text)))
    assert rows_as_sets(rs2.rows) == want
    rebuilt = store_from_string_triples(sorted(decoded_triples(store)))
    assert rows_as_sets(QueryEngine(rebuilt).query(pq.text)) == want


def test_numeric_table_growth_recompiles_then_stays_warm():
    store = store_from_string_triples(_mini_triples(0))
    eng = QueryEngine(store)
    pq = eng.prepare(_query_text("filter"))
    pq.run()
    # grow the dictionary past its pow-2 boundary: numeric-values table
    # changes shape, the warm entry must recompile once, then stay warm
    n0 = len(store.dictionary)
    target = 1
    while target <= n0:
        target *= 2
    fresh = [(f"<new{i}>", "<age>", str(100 + i))
             for i in range(target - n0 + 1)]
    store.insert_triples(fresh)
    assert len(store.dictionary) > target
    r1 = pq.run()
    assert r1.stats.n_compiles >= 1
    want = rows_as_sets(reference_rows(store, parse(pq.text)))
    assert rows_as_sets(r1.rows) == want
    r2 = pq.run()
    assert r2.stats.n_compiles == 0 and r2.stats.n_dispatches == 1
    assert rows_as_sets(r2.rows) == want


# --------------------------------------------- engine + prepared handles


def test_engine_update_and_stats():
    store = store_from_string_triples(_mini_triples(0))
    eng = QueryEngine(store)
    res = eng.update(
        'INSERT DATA { <e0> <p0> <zz> . <e0> <p0> <zz> } ; '
        'DELETE DATA { <e0> <p0> <zz> }'
    )
    assert (res.inserted, res.deleted, res.n_ops) == (1, 1, 2)
    assert res.version == store.version
    st = eng.stats()
    assert st["store"]["version"] == store.version
    assert {"plan_cache", "scan_cache", "store"} <= set(st)
    with pytest.raises(ParseError):
        eng.update("INSERT DATA { ?x <p> <b> }")


def test_prepared_refresh_repins_version():
    store = store_from_string_triples(_mini_triples(0))
    eng = QueryEngine(store)
    pq = eng.prepare(_query_text("bgp"))
    assert pq.refresh() is False  # nothing changed yet
    eng.update("INSERT DATA { <e0> <p1> <e1> }")
    assert pq.planned_version != store.version
    assert pq.refresh() is True
    assert pq.planned_version == store.version
    want = rows_as_sets(reference_rows(store, parse(pq.text)))
    assert rows_as_sets(pq.run().rows) == want


def test_explain_reports_store_version():
    store = store_from_string_triples(_mini_triples(0))
    eng = QueryEngine(store)
    pq = eng.prepare(_query_text("bgp"))
    pq.run()
    assert f"version={store.version}" in pq.explain()
    eng.update("INSERT DATA { <e0> <p1> <e1> }")
    assert "stale" in pq.explain()
    pq.refresh()
    assert "stale" not in pq.explain()


# --------------------------------------------------------------- server


def test_server_update_endpoint_and_stats():
    from repro.serve.sparql_server import ParseQueryError, SPARQLServer
    store = store_from_string_triples(_mini_triples(0))
    srv = SPARQLServer(engine=QueryEngine(store))
    try:
        text = _query_text("bgp")
        srv.query(text)
        res = srv.update(
            "INSERT DATA { <e0> <p0> <e5> } ; "
            "DELETE DATA { <e0> <p0> <e5> }"
        )
        assert res.inserted == 1 and res.deleted == 1
        want = rows_as_sets(reference_rows(store, parse(text)))
        assert rows_as_sets(srv.query(text).rows) == want
        st = srv.stats()
        assert st["updates"] == {
            "requests": 1, "rows_inserted": 1, "rows_deleted": 1,
        }
        assert st["store"]["version"] == store.version
        with pytest.raises(ParseQueryError):
            srv.update("INSERT DATA { ?x <p> <b> }")
    finally:
        srv.close()


# --------------------------------------------- property-based round-trip


_UNIVERSE = [(f"<e{i % 4}>", f"<p{i % 2}>", f"<e{(i * 3) % 5}>")
             for i in range(10)]


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=5),
    ops=st.lists(
        st.tuples(st.sampled_from(["insert", "delete", "compact"]),
                  st.integers(min_value=0, max_value=9)),
        min_size=1, max_size=12,
    ),
)
def test_interleaved_updates_round_trip(seed, ops):
    """Property: any interleaving of insert/delete/compact leaves the
    store's effective triples equal to a plain python set model, with
    version/compaction counters and write_stats invariants intact."""
    base = _mini_triples(seed)
    store = store_from_string_triples(base)
    model = set(base)
    for kind, i in ops:
        t = _UNIVERSE[i]
        if kind == "insert":
            applied = store.insert_triples([t])
            assert applied == (0 if t in model else 1)
            model.add(t)
        elif kind == "delete":
            applied = store.delete_triples([t])
            assert applied == (1 if t in model else 0)
            model.discard(t)
        else:
            store.compact()
            assert store.write_stats()["tail_rows"] == 0
            assert store.write_stats()["tombstones"] == 0
        ws = store.write_stats()
        assert ws["total_rows"] == len(model)
        assert ws["total_rows"] == ws["base_rows"] + ws["tail_rows"] \
            - ws["tombstones"]
    assert decoded_triples(store) == model
    # and the store still answers queries correctly post-interleaving
    text = "SELECT ?x ?y WHERE { ?x <p0> ?y . }"
    want = rows_as_sets(reference_rows(store, parse(text)))
    got = rows_as_sets(QueryEngine(store, compiled=False).query(text))
    assert got == want
