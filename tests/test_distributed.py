"""Distributed tests run in subprocesses so the main session keeps 1 device
(XLA locks the device count at first jax import)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_prog(relpath, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, relpath)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_distributed_mr_join_8dev():
    out = run_prog("tests/distributed/dist_join_prog.py")
    assert "ALL DISTRIBUTED JOIN CASES PASSED" in out


def test_moe_ep_and_lookup_8dev():
    out = run_prog("tests/distributed/moe_ep_prog.py")
    assert "ALL MOE/LOOKUP DISTRIBUTED CASES PASSED" in out


def test_lm_train_step_2x4_mesh():
    out = run_prog("tests/distributed/lm_mesh_prog.py")
    assert "LM MESH TRAIN/SERVE PASSED" in out
