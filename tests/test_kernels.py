"""Per-kernel allclose vs pure-jnp oracles: shape sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip without the dev extra
    from _hypothesis_compat import given, settings, st

from repro.kernels.bitonic_sort import ops as sort_ops
from repro.kernels.bitonic_sort import ref as sort_ref
from repro.kernels.pair_expand import ops as pe_ops
from repro.kernels.pair_expand import ref as pe_ref
from repro.kernels.segment_reduce import ops as seg_ops
from repro.kernels.segment_reduce import ref as seg_ref


# ---------------------------------------------------------------- bitonic --
@pytest.mark.parametrize("n", [2, 7, 16, 100, 255, 256, 1000, 4096])
def test_bitonic_sort_shapes(n):
    rng = np.random.RandomState(n)
    keys = rng.randint(0, max(2, n // 2), size=n).astype(np.int32)  # dup keys
    vals = np.arange(n, dtype=np.int32)
    sk, sv = sort_ops.sort_pairs(jnp.asarray(keys), jnp.asarray(vals))
    rk, rv = sort_ref.sort_pairs(jnp.asarray(keys), jnp.asarray(vals))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(rk))
    # bitonic is unstable: compare (key,val) multisets, not order
    got = sorted(zip(np.asarray(sk).tolist(), np.asarray(sv).tolist()))
    want = sorted(zip(keys.tolist(), vals.tolist()))
    assert got == want


def test_bitonic_argsort_is_permutation():
    keys = jnp.asarray(np.random.RandomState(0).randint(-50, 50, 513), jnp.int32)
    order = sort_ops.argsort_i32(keys)
    assert sorted(np.asarray(order).tolist()) == list(range(513))
    np.testing.assert_array_equal(
        np.asarray(keys[order]), np.sort(np.asarray(keys))
    )


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-(2**20), 2**20), min_size=1, max_size=300))
def test_bitonic_hypothesis(xs):
    keys = jnp.asarray(np.array(xs, np.int32))
    sk, _ = sort_ops.sort_pairs(keys, jnp.zeros_like(keys))
    np.testing.assert_array_equal(np.asarray(sk), np.sort(np.array(xs, np.int32)))


# ------------------------------------------------------------ pair expand --
@pytest.mark.parametrize("n_left,capacity", [(1, 1024), (5, 1024), (700, 2048),
                                             (1024, 4096)])
def test_pair_expand_shapes(n_left, capacity):
    rng = np.random.RandomState(n_left)
    counts = rng.randint(0, 5, size=n_left).astype(np.int32)
    prefix = np.cumsum(counts).astype(np.int32)
    ki, ko, kv = pe_ops.pair_expand(jnp.asarray(prefix), jnp.asarray(counts),
                                    capacity)
    ri, ro, rv = pe_ref.pair_expand(jnp.asarray(prefix), jnp.asarray(counts),
                                    capacity)
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))
    valid = np.asarray(rv)
    np.testing.assert_array_equal(np.asarray(ki)[valid], np.asarray(ri)[valid])
    np.testing.assert_array_equal(np.asarray(ko)[valid], np.asarray(ro)[valid])


def test_pair_expand_enumerates_all_pairs():
    counts = jnp.asarray([2, 0, 3, 1], jnp.int32)
    prefix = jnp.cumsum(counts)
    i, off, valid = pe_ops.pair_expand(prefix, counts, 1024)
    pairs = {(int(a), int(b)) for a, b, v in
             zip(np.asarray(i), np.asarray(off), np.asarray(valid)) if v}
    assert pairs == {(0, 0), (0, 1), (2, 0), (2, 1), (2, 2), (3, 0)}


# ---------------------------------------------------------- segment reduce --
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,s", [(10, 8, 4), (512, 128, 16), (1000, 64, 33)])
def test_segment_sum_shapes(n, d, s, dtype):
    rng = np.random.RandomState(n + d)
    ids = np.sort(rng.randint(0, s, size=n)).astype(np.int32)
    data = rng.randn(n, d).astype(np.float32)
    got = seg_ops.sorted_segment_sum(jnp.asarray(data, dtype), jnp.asarray(ids), s)
    # Oracle in fp32: the kernel accumulates in fp32 on the MXU, the bf16 ref
    # does not, so both are compared against fp32 ground truth (taxonomy §E).
    want = seg_ref.sorted_segment_sum(jnp.asarray(data), jnp.asarray(ids), s)
    rtol, atol = (1e-6, 1e-5) if dtype == jnp.float32 else (5e-2, 0.3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=rtol, atol=atol)


def test_segment_sum_empty_segments_are_zero():
    data = jnp.ones((4, 3), jnp.float32)
    ids = jnp.asarray([0, 0, 3, 3], jnp.int32)
    out = seg_ops.sorted_segment_sum(data, ids, 5)
    np.testing.assert_allclose(np.asarray(out)[1], 0.0)
    np.testing.assert_allclose(np.asarray(out)[4], 0.0)
    np.testing.assert_allclose(np.asarray(out)[0], 2.0)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 200), st.integers(1, 17), st.integers(1, 40))
def test_segment_sum_hypothesis(n, d, s):
    rng = np.random.RandomState(n * d + s)
    ids = np.sort(rng.randint(0, s, size=n)).astype(np.int32)
    data = rng.randn(n, d).astype(np.float32)
    got = seg_ops.sorted_segment_sum(jnp.asarray(data), jnp.asarray(ids), s)
    want = seg_ref.sorted_segment_sum(jnp.asarray(data), jnp.asarray(ids), s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-4)


# ------------------------------------------------- kernel-backed full join --
def test_mr_join_with_kernel_expansion_matches_jnp():
    from repro.core import mr_join as mj
    from repro.core.relation import Relation

    rng = np.random.RandomState(7)
    l_rows = rng.randint(0, 9, size=(40, 2)).astype(np.int32)
    r_rows = rng.randint(0, 9, size=(37, 2)).astype(np.int32)
    left = Relation.from_numpy(("?k", "?a"), l_rows)
    right = Relation.from_numpy(("?k", "?b"), r_rows)
    out_j, tot_j, _ = mj.mr_join(left, right, 2048, use_kernel=False)
    out_k, tot_k, _ = mj.mr_join(left, right, 2048, use_kernel=True)
    assert int(tot_j) == int(tot_k)
    assert out_j.to_set() == out_k.to_set()


# ----------------------------------------------------------- spmm join ----
from repro.kernels.spmm_join import ops as spmm_ops  # noqa: E402
from repro.kernels.spmm_join import ref as spmm_ref  # noqa: E402


def _layout_oracle(lk: np.ndarray, rk: np.ndarray):
    eq = lk[:, None] == rk[None, :]
    counts = eq.sum(1).astype(np.int32)
    first = (rk[None, :] < lk[:, None]).sum(1).astype(np.int32)
    b = (eq * (np.cumsum(eq, axis=0) - eq)).sum(1).astype(np.int32)
    cl = eq.sum(0).astype(np.int32)
    return counts, first, b, cl


@pytest.mark.parametrize("n_l,n_r", [(1, 1), (2, 3), (40, 7), (130, 70),
                                     (700, 80), (1024, 256), (1100, 300)])
def test_match_layout_shapes(n_l, n_r):
    rng = np.random.RandomState(n_l + n_r)
    lk = rng.randint(0, 11, size=n_l).astype(np.int32)
    rk = rng.randint(0, 11, size=n_r).astype(np.int32)
    want = _layout_oracle(lk, rk)
    for use_kernel in (False, True):
        got = spmm_ops.match_layout(jnp.asarray(lk), jnp.asarray(rk),
                                    use_kernel=use_kernel, interpret=True)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), w)


def test_match_layout_blocked_ref_matches_one_shot():
    # force the blocked fori_loop path (n_l * n_r above the one-shot cap)
    rng = np.random.RandomState(3)
    n_l = spmm_ref.ONE_SHOT_ELEMS // 64 + 200  # not a BLOCK_ROWS multiple
    lk = rng.randint(0, 13, size=n_l).astype(np.int32)
    rk = rng.randint(0, 13, size=64).astype(np.int32)
    got = spmm_ref.match_layout(jnp.asarray(lk), jnp.asarray(rk))
    want = _layout_oracle(lk, rk)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), w)


@pytest.mark.parametrize("n", [1, 2, 17, 255, 256, 1000, 1024, 1300])
def test_sort_ranks_is_stable_sorted_position(n):
    rng = np.random.RandomState(n)
    keys = rng.randint(0, max(2, n // 3), size=n).astype(np.int32)
    order = np.argsort(keys, kind="stable")
    want = np.empty(n, np.int64)
    want[order] = np.arange(n)
    for use_kernel in (False, True):
        pos = spmm_ops.sort_ranks(jnp.asarray(keys), use_kernel=use_kernel,
                                  interpret=True)
        np.testing.assert_array_equal(np.asarray(pos), want)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 9), min_size=1, max_size=120),
       st.lists(st.integers(0, 9), min_size=1, max_size=120))
def test_match_layout_hypothesis(ls, rs):
    lk = np.array(ls, np.int32)
    rk = np.array(rs, np.int32)
    got = spmm_ops.match_layout(jnp.asarray(lk), jnp.asarray(rk),
                                use_kernel=True, interpret=True)
    for g, w in zip(got, _layout_oracle(lk, rk)):
        np.testing.assert_array_equal(np.asarray(g), w)


def test_match_layout_vmaps():
    rng = np.random.RandomState(5)
    lks = rng.randint(0, 6, size=(4, 33)).astype(np.int32)
    rks = rng.randint(0, 6, size=(4, 21)).astype(np.int32)
    fn = jax.vmap(lambda a, b: spmm_ops.match_layout(a, b, use_kernel=False))
    counts, first, b, cl = fn(jnp.asarray(lks), jnp.asarray(rks))
    for i in range(4):
        want = _layout_oracle(lks[i], rks[i])
        for g, w in zip((counts[i], first[i], b[i], cl[i]), want):
            np.testing.assert_array_equal(np.asarray(g), w)


def _join_rows(rel):
    return np.asarray(rel.cols)[np.asarray(rel.valid)]


@pytest.mark.parametrize("capacity", [1, 3, 16, 64, 4096])
def test_matrix_join_matches_mr_join_exactly(capacity):
    """Bit-identical output (order included) at every capacity, including
    overflowing ones — the regrow loop depends on exact truncation."""
    from repro.core import matrix_join as mxj
    from repro.core import mr_join as mj
    from repro.core.relation import Relation

    rng = np.random.RandomState(11)
    left = Relation.from_numpy(
        ("?k", "?a"), rng.randint(0, 5, size=(50, 2)).astype(np.int32))
    right = Relation.from_numpy(
        ("?k", "?b"), rng.randint(0, 5, size=(41, 2)).astype(np.int32))
    out_m, tot_m, ovf_m = mj.mr_join(left, right, capacity)
    out_x, tot_x, ovf_x = mxj.matrix_join(left, right, capacity)
    assert int(tot_m) == int(tot_x)
    assert bool(ovf_m) == bool(ovf_x)
    np.testing.assert_array_equal(_join_rows(out_m), _join_rows(out_x))


def test_matrix_left_join_matches_mr_left_join():
    from repro.core import matrix_join as mxj
    from repro.core import mr_join as mj
    from repro.core.relation import Relation

    rng = np.random.RandomState(13)
    left = Relation.from_numpy(
        ("?k", "?a"), rng.randint(0, 9, size=(40, 2)).astype(np.int32))
    right = Relation.from_numpy(
        ("?k", "?b"), rng.randint(0, 9, size=(30, 2)).astype(np.int32))
    out_m, tot_m, _ = mj.left_join(left, right, 512)
    out_x, tot_x, _ = mxj.matrix_left_join(left, right, 512)
    assert int(tot_m) == int(tot_x)
    assert out_m.to_set() == out_x.to_set()


def test_matrix_join_kernel_path_matches_ref_path():
    from repro.core import matrix_join as mxj
    from repro.core.relation import Relation

    rng = np.random.RandomState(17)
    left = Relation.from_numpy(
        ("?k", "?a"), rng.randint(0, 7, size=(60, 2)).astype(np.int32))
    right = Relation.from_numpy(
        ("?k", "?b"), rng.randint(0, 7, size=(44, 2)).astype(np.int32))
    out_r, tot_r, _ = mxj.matrix_join(left, right, 1024, use_kernel=False)
    out_k, tot_k, _ = mxj.matrix_join(left, right, 1024, use_kernel=True)
    assert int(tot_r) == int(tot_k)
    np.testing.assert_array_equal(_join_rows(out_r), _join_rows(out_k))
