"""Cost-based optimizer: statistics catalog, statistics-driven join
ordering (beats the greedy order on the J1/J2 shapes), filter pushdown,
UNION through the whole stack, FILTER `&&`/`||`, plan-cache warmup
persistence, and property-based differential tests that every rewritten
plan returns the same rows as the NumPy oracle and the unoptimized plan."""
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    from _hypothesis_compat import given, settings, st  # noqa: F401
    HAVE_HYPOTHESIS = False

from repro.core import plan_ir
from repro.core.planner import TriplePattern, plan_bgp
from repro.sparql import lubm, optimizer
from repro.sparql.baseline import reference_rows
from repro.sparql.engine import QueryEngine
from repro.sparql.parser import ParseError, parse
from repro.sparql.store import StoreStatistics, store_from_string_triples

UB = "http://swat.cse.lehigh.edu/onto/univ-bench.owl#"
RDF_TYPE = "<http://www.w3.org/1999/02/22-rdf-syntax-ns#type>"
PREFIX = f"PREFIX ub: <{UB}>\n"


def rows_as_sets(rows):
    return sorted(tuple(sorted(r.items())) for r in rows)


def student_store(n_students=15, n_with_advisor=12):
    triples = []
    for i in range(n_students):
        s = f"<s{i}>"
        triples.append((s, RDF_TYPE, f"<{UB}Student>"))
        if i < n_with_advisor:
            triples.append((s, f"<{UB}advisor>", f"<p{i % 4}>"))
        triples.append((s, f"<{UB}age>", str(18 + i)))
        triples.append((s, f"<{UB}name>", f'"student{i}"'))
    return store_from_string_triples(triples)


@pytest.fixture(scope="module")
def j_store():
    return lubm.generate(scale=1, seed=0, join_shapes=True)


# ----------------------------------------------------- statistics catalog


def test_store_statistics_catalog():
    store = store_from_string_triples([
        ("<a>", "<p>", "<x>"),
        ("<a>", "<p>", "<y>"),
        ("<b>", "<p>", "<x>"),
        ("<b>", "<q>", "<x>"),
    ])
    stats = store.statistics
    assert isinstance(stats, StoreStatistics)
    assert stats.n_triples == 4
    assert stats.n_subjects == 2 and stats.n_predicates == 2
    p = store.dictionary.lookup("<p>")
    assert stats.predicates[p].count == 3
    assert stats.predicates[p].n_subjects == 2
    assert stats.predicates[p].n_objects == 2


def test_pattern_cardinality_and_distinct_estimates():
    store = store_from_string_triples(
        [(f"<s{i}>", "<p>", f"<o{i % 3}>") for i in range(12)]
        + [(f"<s{i}>", "<q>", "<z>") for i in range(4)]
    )
    stats = store.statistics
    lk = store.dictionary.lookup
    tp = TriplePattern("?x", "<p>", "?y")
    assert stats.pattern_cardinality(tp, lk) == 12
    assert stats.distinct_values(tp, "?x", lk) == 12
    assert stats.distinct_values(tp, "?y", lk) == 3
    # bound object: count/n_objects under uniformity
    tp2 = TriplePattern("?x", "<p>", "<o0>")
    assert stats.pattern_cardinality(tp2, lk) == pytest.approx(4.0)
    # unknown constants can never match
    assert stats.pattern_cardinality(
        TriplePattern("?x", "<nope>", "?y"), lk
    ) == 0.0


# ------------------------------------- J1/J2: the statistics-order win


@pytest.mark.parametrize("name", ["J1", "J2"])
def test_stats_join_order_beats_greedy_on_j_shapes(j_store, name):
    """Acceptance: on the bad-join-order shapes the statistics-driven
    order produces strictly smaller maximum intermediate join buckets than
    the greedy order, with identical results, and the warm query stays at
    one dispatch with zero compiles."""
    text = lubm.J_QUERIES[name]
    greedy = QueryEngine(j_store, optimize=False)
    stats = QueryEngine(j_store)
    rg = greedy.prepare(text).run()
    ps = stats.prepare(text)
    rs = ps.run()
    assert rows_as_sets(rg.rows) == rows_as_sets(rs.rows)
    assert rs.stats.peak_join_bucket < rg.stats.peak_join_bucket, (
        rs.stats.peak_join_bucket,
        rg.stats.peak_join_bucket,
    )
    # the win is structural, not marginal: an order of magnitude
    assert rs.stats.peak_join_bucket * 8 <= rg.stats.peak_join_bucket
    warm = ps.run()
    assert warm.stats.n_dispatches == 1 and warm.stats.n_compiles == 0
    # explain carries the calibrated buckets and the pass trace
    report = ps.explain()
    assert "join_order[required]" in report
    assert "cache: compiled, join buckets=" in report


def test_exhaustive_start_orders_from_selective_tail(j_store):
    """order_patterns starts J1 from the 12-row tail, not the 10-row type
    scan the greedy heuristic picks (whose only join explodes)."""
    q = parse(lubm.J_QUERIES["J1"])
    order, flags, ests, _backends, _, _moved = optimizer.order_patterns(
        q.patterns,
        j_store.estimate_cardinality,
        j_store.statistics,
        j_store.dictionary.lookup,
    )
    assert not any(flags)  # fully connected: no cross joins
    assert max(ests) <= 16  # every estimated intermediate stays tiny
    # greedy starts at the type scan (index 0, cardinality 10) instead
    steps = plan_bgp(q.patterns, j_store.estimate_cardinality)
    assert steps[0].pattern_index == 0
    assert order[0] != 0


# --------------------------------------------------------- filter pushdown


def test_filter_pushdown_shrinks_join_buckets():
    """A filter on a scan's own variables is applied before the join, so
    the calibrated join bucket shrinks vs the unoptimized plan."""
    store = student_store()
    text = (PREFIX + "SELECT ?x ?a ?n WHERE { ?x ub:age ?a . "
            "?x ub:name ?n . FILTER (?a >= 32) }")
    legacy = QueryEngine(store, optimize=False)
    opt = QueryEngine(store)
    rl = legacy.prepare(text).run()
    ro = opt.prepare(text).run()
    assert rows_as_sets(rl.rows) == rows_as_sets(ro.rows)
    assert len(ro.rows) == 1
    assert ro.stats.peak_join_bucket < rl.stats.peak_join_bucket
    report = opt.prepare(text).explain()
    assert "filter_pushdown" in report and "scan[" in report


def test_pushdown_query_warm_single_dispatch():
    store = student_store()
    eng = QueryEngine(store)
    text = (PREFIX + "SELECT ?x ?n WHERE { ?x ub:age ?a . "
            "?x ub:name ?n . FILTER (?a >= 25 || ?a < 20) }")
    pq = eng.prepare(text)
    pq.run()
    warm = pq.run()
    assert warm.stats.n_dispatches == 1
    assert warm.stats.n_compiles == 0
    assert warm.stats.cache_hits == 1


def test_filter_on_optional_var_stays_after_left_join():
    """Conjuncts reading OPTIONAL-bound variables must not sink into the
    optional side (that would turn filtered rows into unmatched-but-kept
    rows); they attach after the left join and still match the oracle."""
    store = student_store(n_students=8, n_with_advisor=5)
    text = PREFIX + """SELECT ?x ?y WHERE {
        ?x a ub:Student . OPTIONAL { ?x ub:advisor ?y }
        FILTER (?y != <p1>) }"""
    for compiled in (True, False):
        eng = QueryEngine(store, compiled=compiled)
        got = eng.query(text)
        want = reference_rows(store, parse(text))
        assert rows_as_sets(got) == rows_as_sets(want)


def test_projection_prune_in_trace():
    store = student_store()
    # ?n is bound by exactly one pattern and never projected or filtered
    text = PREFIX + "SELECT ?x WHERE { ?x a ub:Student . ?x ub:name ?n . }"
    report = QueryEngine(store).prepare(text).explain()
    assert "projection_prune" in report and "?n" in report


# ------------------------------------------------------------------ UNION


UNION_QUERIES = [
    # shared required part, single-pattern branches
    PREFIX + """SELECT ?x ?v WHERE { ?x a ub:Student .
        { ?x ub:advisor ?v } UNION { ?x ub:name ?v } }""",
    # no required part at all
    PREFIX + """SELECT ?x ?v WHERE {
        { ?x ub:advisor ?v } UNION { ?x ub:age ?v } }""",
    # multi-pattern branch + DISTINCT dedup across branches
    PREFIX + """SELECT DISTINCT ?x WHERE {
        { ?x ub:advisor ?p . ?x ub:age ?a } UNION { ?x ub:name ?n } }""",
    # branch-only variables differ per branch (UNBOUND padding)
    PREFIX + """SELECT ?x ?p ?n WHERE { ?x a ub:Student .
        { ?x ub:advisor ?p } UNION { ?x ub:name ?n } }""",
    # three branches
    PREFIX + """SELECT ?x ?v WHERE { { ?x ub:advisor ?v }
        UNION { ?x ub:name ?v } UNION { ?x ub:age ?v } }""",
]


@pytest.mark.parametrize("compiled", [True, False])
@pytest.mark.parametrize("qi", range(len(UNION_QUERIES)))
def test_union_matches_oracle(compiled, qi):
    store = student_store()
    eng = QueryEngine(store, compiled=compiled)
    text = UNION_QUERIES[qi]
    got = eng.query(text)
    want = reference_rows(store, parse(text))
    assert rows_as_sets(got) == rows_as_sets(want), text


def test_union_keeps_duplicates_multiset_semantics():
    triples = [("<a>", "<p>", "<v>"), ("<a>", "<q>", "<v>")]
    store = store_from_string_triples(triples)
    for compiled in (True, False):
        eng = QueryEngine(store, compiled=compiled)
        rows = eng.query(
            "SELECT ?x ?v WHERE { { ?x <p> ?v } UNION { ?x <q> ?v } }"
        )
        assert len(rows) == 2  # same solution from both branches survives


def test_union_warm_single_dispatch_zero_compiles():
    """Acceptance: warm-query dispatch count stays at 1 with 0 compiles
    for UNION queries."""
    store = student_store()
    eng = QueryEngine(store)
    pq = eng.prepare(UNION_QUERIES[0])
    cold = pq.run()
    assert cold.stats.cache_misses == 1 and cold.stats.n_compiles == 1
    warm = pq.run()
    assert warm.stats.n_dispatches == 1
    assert warm.stats.n_compiles == 0
    assert warm.stats.n_count_passes == 0


def test_filter_distributed_into_union_branches():
    store = student_store()
    text = PREFIX + """SELECT ?x ?v WHERE { ?x a ub:Student .
        { ?x ub:advisor ?v } UNION { ?x ub:name ?v }
        FILTER (?v != <p1>) }"""
    eng = QueryEngine(store)
    pq = eng.prepare(text)
    got = pq.run()
    want = reference_rows(store, parse(text))
    assert rows_as_sets(got.rows) == rows_as_sets(want)
    assert "distributed into 2 UNION branch(es)" in pq.explain()


def test_union_parse_errors():
    for bad in [
        "SELECT ?x WHERE { { ?x <p> ?y } }",  # braced group, no UNION
        "SELECT ?x WHERE { { ?x <p> ?y } UNION { } }",  # empty branch
        # two separate UNION blocks
        """SELECT ?x WHERE { { ?x <p> ?y } UNION { ?x <q> ?y } .
           { ?x <r> ?y } UNION { ?x <s> ?y } }""",
        # OPTIONAL + UNION combination
        """SELECT ?x WHERE { ?x <p> ?y .
           { ?x <q> ?z } UNION { ?x <r> ?z } OPTIONAL { ?x <s> ?w } }""",
        # nested UNION inside a branch
        "SELECT ?x WHERE { { { ?x <p> ?y } UNION { ?x <q> ?y } } UNION "
        "{ ?x <r> ?y } }",
    ]:
        with pytest.raises(ParseError):
            parse(bad)


# ------------------------------------------------------- FILTER && / ||


@pytest.mark.parametrize("compiled", [True, False])
@pytest.mark.parametrize("cond", [
    "?a >= 25 || ?a < 20",
    "?a > 20 && ?a < 25",
    '(?a >= 25 && ?n != "student8") || ?a = 18',
    '?n = "student3" || ?n = "student5"',
    "(?a < 20 || ?a > 30) && ?x != <s14>",
])
def test_boolean_connectives_match_oracle(compiled, cond):
    store = student_store()
    eng = QueryEngine(store, compiled=compiled)
    text = (PREFIX + "SELECT ?x ?a ?n WHERE { ?x ub:age ?a . "
            f"?x ub:name ?n . FILTER ({cond}) }}")
    got = eng.query(text)
    want = reference_rows(store, parse(text))
    assert rows_as_sets(got) == rows_as_sets(want), cond


def test_or_with_unbound_operand_keeps_true_side():
    """SPARQL: error || true is true. A row whose OPTIONAL var is unbound
    still passes when the other disjunct holds."""
    store = student_store(n_students=6, n_with_advisor=3)
    text = PREFIX + """SELECT ?x ?y ?a WHERE {
        ?x a ub:Student . ?x ub:age ?a .
        OPTIONAL { ?x ub:advisor ?y }
        FILTER (?y = <p0> || ?a >= 21) }"""
    for compiled in (True, False):
        eng = QueryEngine(store, compiled=compiled)
        got = eng.query(text)
        want = reference_rows(store, parse(text))
        assert rows_as_sets(got) == rows_as_sets(want)


def test_filters_with_or_share_compiled_program():
    store = student_store()
    eng = QueryEngine(store)
    text = (PREFIX + "SELECT ?x WHERE {{ ?x ub:age ?a . "
            "FILTER (?a < {lo} || ?a > {hi}) }}")
    r1 = eng.prepare(text.format(lo=20, hi=30)).run()
    r2 = eng.prepare(text.format(lo=19, hi=25)).run()
    assert r1.stats.cache_misses == 1
    assert r2.stats.cache_hits == 1 and r2.stats.n_compiles == 0
    want = reference_rows(store, parse(text.format(lo=19, hi=25)))
    assert rows_as_sets(r2.rows) == rows_as_sets(want)


# ----------------------------------------------- planner cross-join order


def test_plan_bgp_cross_joins_smallest_first():
    cards = {"<big>": 100.0, "<mid>": 20.0, "<tiny>": 5.0}
    patterns = [
        TriplePattern("?x", "<big>", "?y"),
        TriplePattern("?z", "<mid>", "?w"),
        TriplePattern("?u", "<tiny>", "?v"),
    ]
    steps = plan_bgp(patterns, lambda tp: cards[tp.p])
    assert [st.pattern_index for st in steps] == [2, 1, 0]
    assert [st.is_cross for st in steps] == [False, True, True]


# ------------------------------------------------- warmup persistence


def test_save_cache_warmup_skips_calibration(tmp_path):
    store = student_store()
    eng = QueryEngine(store)
    q1 = PREFIX + "SELECT ?x WHERE { ?x a ub:Student . ?x ub:age ?a . }"
    q2 = UNION_QUERIES[0]
    rows1 = eng.query(q1)
    rows2 = eng.query(q2)
    path = tmp_path / "warmup.json"
    assert eng.save_cache(str(path)) == 2
    # a fresh engine (fresh process stand-in) with the warmup file
    eng2 = QueryEngine(store, warmup_path=str(path))
    r1 = eng2.prepare(q1).run()
    # no calibration: zero count passes, exactly one compile + dispatch
    assert r1.stats.n_count_passes == 0
    assert r1.stats.n_compiles == 1
    assert r1.stats.n_dispatches == 1
    assert rows_as_sets(r1.rows) == rows_as_sets(rows1)
    r2 = eng2.prepare(q2).run()
    assert r2.stats.n_count_passes == 0
    assert rows_as_sets(r2.rows) == rows_as_sets(rows2)
    # from the second run on it is a plain cache hit
    r1b = eng2.prepare(q1).run()
    assert r1b.stats.cache_hits == 1 and r1b.stats.n_compiles == 0


def test_warmup_missing_file_is_fresh_start(tmp_path):
    store = student_store()
    eng = QueryEngine(store, warmup_path=str(tmp_path / "absent.json"))
    assert len(eng.query(PREFIX + "SELECT ?x WHERE { ?x a ub:Student . }")) \
        == 15


def test_shape_json_roundtrip():
    shape = plan_ir.make_shape(
        (("?c0", "?c1"), ("?c1", "?c2"), ("?c0", "?c3"), ("?c0", "?c4")),
        (16, 8, 8, 32),
        (False,),
        ("?c0", "?c2"),
        True,
        opt_groups=(),
        union_groups=(plan_ir.GroupSpec(1, ()), plan_ir.GroupSpec(1, ())),
        has_required=True,
        filters=(
            (("scan", 0), ("cmp", "?c1", ">", "num", 0)),
            (("top",), ("or", (("cmp", "?c0", "!=", "id", 0),
                               ("cmp", "?c0", "=", "var", "?c2")))),
        ),
        n_consts=(1, 1),
        has_slice=True,
        prune=True,
    )
    back = plan_ir.shape_from_jsonable(
        json.loads(json.dumps(plan_ir.shape_to_jsonable(shape)))
    )
    assert back == shape and hash(back) == hash(shape)


def test_server_save_cache_passthrough(tmp_path):
    from repro.serve.sparql_server import SPARQLServer

    store = student_store()
    srv = SPARQLServer(QueryEngine(store), max_batch=2)
    try:
        srv.query(PREFIX + "SELECT ?x WHERE { ?x a ub:Student . }")
        path = tmp_path / "server-warmup.json"
        assert srv.save_cache(str(path)) == 1
        assert path.exists()
    finally:
        srv.close()


# ---------------------------------------- property-based differential


def _mini_store(seed: int):
    rng = np.random.default_rng(seed)
    ents = [f"<e{i}>" for i in range(6)]
    triples = set()
    for _ in range(40):
        triples.add((
            ents[rng.integers(6)],
            f"<p{rng.integers(3)}>",
            ents[rng.integers(6)],
        ))
    for i in range(6):  # numeric attributes for FILTER coverage
        triples.add((ents[i], "<age>", str(15 + 3 * i)))
    return store_from_string_triples(sorted(triples))


def _query_text(shape: str, p1: int, p2: int, cmp_op: str, cut: int) -> str:
    """A query template per operator shape, always engine-valid."""
    base = f"?x <p{p1}> ?y"
    if shape == "bgp":
        return f"SELECT ?x ?y ?z WHERE {{ {base} . ?y <p{p2}> ?z . }}"
    if shape == "filter":
        return (f"SELECT ?x ?y ?a WHERE {{ {base} . ?x <age> ?a . "
                f"FILTER (?a {cmp_op} {cut} || ?x = <e1>) }}")
    if shape == "optional":
        return (f"SELECT ?x ?y ?z WHERE {{ {base} . "
                f"OPTIONAL {{ ?x <p{p2}> ?z }} }}")
    assert shape == "union"
    return (f"SELECT ?x ?v WHERE {{ {{ ?x <p{p1}> ?v }} UNION "
            f"{{ ?x <p{p2}> ?v }} }}")


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=7),
    shape=st.sampled_from(["bgp", "filter", "optional", "union"]),
    p1=st.integers(min_value=0, max_value=2),
    p2=st.integers(min_value=0, max_value=2),
    cmp_op=st.sampled_from(["<", ">=", "=", "!="]),
    cut=st.integers(min_value=14, max_value=32),
)
def test_optimized_plan_matches_oracle_and_unoptimized(
    seed, shape, p1, p2, cmp_op, cut
):
    """Property (acceptance): every rewritten plan returns the same rows
    as baseline.reference_rows and as the unoptimized plan, across
    BGP/OPTIONAL/FILTER/UNION shapes."""
    store = _mini_store(seed)
    text = _query_text(shape, p1, p2, cmp_op, cut)
    q = parse(text)
    want = rows_as_sets(reference_rows(store, q))
    optimized = QueryEngine(store, compiled=False)
    unoptimized = QueryEngine(store, compiled=False, optimize=False)
    assert rows_as_sets(optimized.query(text)) == want, text
    assert rows_as_sets(unoptimized.query(text)) == want, text


@pytest.mark.parametrize("seed", [0, 3, 5])
@pytest.mark.parametrize("shape", ["bgp", "filter", "optional", "union"])
def test_differential_sweep_without_hypothesis(seed, shape):
    """Deterministic slice of the property-test space, so the differential
    guarantee is exercised even where hypothesis is unavailable."""
    store = _mini_store(seed)
    text = _query_text(shape, p1=seed % 3, p2=(seed + 1) % 3,
                       cmp_op="<" if seed % 2 else ">=", cut=18 + seed)
    q = parse(text)
    want = rows_as_sets(reference_rows(store, q))
    optimized = QueryEngine(store, compiled=False)
    unoptimized = QueryEngine(store, compiled=False, optimize=False)
    assert rows_as_sets(optimized.query(text)) == want, text
    assert rows_as_sets(unoptimized.query(text)) == want, text


@pytest.mark.parametrize("shape", ["bgp", "filter", "optional", "union"])
def test_compiled_matches_oracle_per_shape(shape):
    """The compiled (one-dispatch) pipeline agrees with the oracle on each
    operator shape the property test sweeps."""
    store = _mini_store(3)
    text = _query_text(shape, 0, 1, ">=", 21)
    q = parse(text)
    want = rows_as_sets(reference_rows(store, q))
    got = rows_as_sets(QueryEngine(store).query(text))
    assert got == want, text


# ------------------------------------------- dual physical join algebra


def _skew_store():
    """One hot object on <hot>: 40 subjects point at it; plus singletons."""
    triples = []
    for i in range(40):
        triples.append((f"<s{i}>", "<hot>", "<obj>"))
    for i in range(10):
        triples.append((f"<u{i}>", "<hot>", f"<v{i}>"))
        triples.append((f"<obj>", "<next>", f"<w{i}>"))
    return store_from_string_triples(triples)


def test_predicate_skew_statistics():
    stats = _skew_store().statistics
    by_name = {}
    lookup = _skew_store().dictionary  # only for readability below
    for pid, ps in stats.predicates.items():
        by_name[pid] = ps
    hot = max(stats.predicates.values(), key=lambda ps: ps.max_o_degree)
    assert hot.count == 50 and hot.max_o_degree == 40
    assert hot.o_skew == pytest.approx(40 / (50 / 11))
    assert hot.max_s_degree == 1 and hot.s_skew == pytest.approx(1.0)


def test_skew_statistics_json_roundtrip():
    stats = _skew_store().statistics
    back = StoreStatistics.from_jsonable(
        json.loads(json.dumps(stats.to_jsonable()))
    )
    assert back == stats
    # pre-skew catalogs (3-entry rows) default the degrees to uniform
    old = stats.to_jsonable()
    old["predicates"] = {
        pid: row[:3] for pid, row in old["predicates"].items()
    }
    degraded = StoreStatistics.from_jsonable(old)
    assert all(
        ps.max_s_degree == 1 and ps.max_o_degree == 1
        for ps in degraded.predicates.values()
    )


def test_optimizer_routes_skewed_join_to_matrix_backend():
    """S1's hot-key join must be routed to the matrix backend from the
    store statistics alone — no override — and the trace must say so."""
    store = lubm.generate(scale=1, seed=0, skew_shapes=True)
    eng = QueryEngine(store)
    text = lubm.S_QUERIES["S1"]
    prog = eng._build_program(eng.prepare(text).query)
    assert prog.plan.join_backends == ("matrix",)
    assert "matrix_join" in eng.explain(text)
    assert "join_backend[required]: matrix join" in eng.explain(text)


def test_uniform_joins_stay_on_mr_backend():
    """Plain LUBM joins have no hot key: every slot keeps the MR backend
    and explain() renders mr_join."""
    store = lubm.generate(scale=1, seed=0)
    eng = QueryEngine(store)
    for name in ("Q2", "Q9"):
        prog = eng._build_program(eng.prepare(lubm.QUERIES[name]).query)
        assert set(prog.plan.join_backends) <= {"mr"}, name
        assert "matrix_join" not in eng.explain(lubm.QUERIES[name])


def test_join_backend_override_validation():
    store = student_store()
    with pytest.raises(ValueError, match="join_backend"):
        QueryEngine(store, join_backend="gpu")
    # valid values pass through to every join slot
    eng = QueryEngine(store, join_backend="matrix")
    q = PREFIX + "SELECT ?x ?a WHERE { ?x a ub:Student . ?x ub:age ?a . }"
    pq = eng.prepare(q)
    shape = eng._shape_for(
        pq._program,
        tuple(store.match_pattern(tp).schema for tp in pq._program.patterns),
        tuple(store.match_pattern(tp).capacity
              for tp in pq._program.patterns),
    )
    assert set(shape.join_backends) == {"matrix"}


def test_sharded_engine_accepts_matrix_backend():
    """The shard-local join is the single-device algebra verbatim, so the
    SpMM backend is valid inside shard_map too (it used to be pinned to
    "mr"); matrix results must match the mr backend on a sharded store."""
    from repro.sparql.engine import ShardedQueryEngine
    from repro.sparql.sharded_store import shard_store

    store = shard_store(student_store(), n_shards=1)
    q = PREFIX + "SELECT ?x ?a WHERE { ?x a ub:Student . ?x ub:age ?a . }"
    got_mr = rows_as_sets(ShardedQueryEngine(store, join_backend="mr").query(q))
    got_mx = rows_as_sets(
        ShardedQueryEngine(store, join_backend="matrix").query(q))
    assert got_mx == got_mr
    assert len(got_mx) > 0


@pytest.mark.parametrize("seed", [0, 2, 5])
@pytest.mark.parametrize("shape", ["bgp", "filter", "optional", "union"])
def test_backends_agree_with_oracle_per_shape(seed, shape):
    """Differential (acceptance): MR backend == matrix backend == NumPy
    oracle on every operator shape, compiled single-dispatch pipeline."""
    store = _mini_store(seed)
    text = _query_text(shape, p1=seed % 3, p2=(seed + 1) % 3,
                       cmp_op=">=" if seed % 2 else "<", cut=19 + seed)
    want = rows_as_sets(reference_rows(store, parse(text)))
    got_mr = rows_as_sets(QueryEngine(store, join_backend="mr").query(text))
    got_mx = rows_as_sets(
        QueryEngine(store, join_backend="matrix").query(text))
    assert got_mr == want, text
    assert got_mx == want, text


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=7),
    shape=st.sampled_from(["bgp", "filter", "optional", "union"]),
    p1=st.integers(min_value=0, max_value=2),
    p2=st.integers(min_value=0, max_value=2),
)
def test_backends_agree_property(seed, shape, p1, p2):
    store = _mini_store(seed)
    text = _query_text(shape, p1, p2, ">=", 20)
    want = rows_as_sets(reference_rows(store, parse(text)))
    assert rows_as_sets(
        QueryEngine(store, join_backend="mr").query(text)) == want, text
    assert rows_as_sets(
        QueryEngine(store, join_backend="matrix").query(text)) == want, text


def test_matrix_backend_warm_single_dispatch():
    store = lubm.generate(scale=1, seed=0, skew_shapes=True)
    eng = QueryEngine(store)  # auto: picks matrix for S1 from stats
    pq = eng.prepare(lubm.S_QUERIES["S1"])
    pq.run()
    warm = pq.run()
    assert warm.stats.n_compiles == 0
    assert warm.stats.n_dispatches == 1
    assert len(warm.rows) == 20000


# -------------------------------------- filter-selectivity cost model


def _filter_order_store():
    """p1 is the biggest leaf (200 distinct subjects) but an `=` filter
    collapses it to ~1 row; blind ordering leads with the tiny p2-p3 tail
    instead (better sum of intermediates) and drags the full 200-row p1
    relation through the chain."""
    triples = []
    for i in range(200):
        triples.append((f"<x{i}>", "<p1>", f"<y{i % 4}>"))
    for i in range(4):
        triples.append((f"<y{i}>", "<p2>", f"<z{i}>"))
    for i in range(4):
        triples.append((f"<z{i}>", "<p3>", f"<w{i}>"))
    return store_from_string_triples(triples)


def test_filter_selectivity_changes_join_order():
    import dataclasses

    store = _filter_order_store()
    text = ("SELECT ?x ?y ?z ?w WHERE { ?x <p1> ?y . ?y <p2> ?z . "
            "?z <p3> ?w . FILTER (?x = <x3>) }")
    q = parse(text)
    aware = optimizer.optimize(q, store)
    blind = optimizer.optimize(dataclasses.replace(q, filters=()), store)
    # the equality filter collapses p1's leaf estimate, so the aware
    # order leads with it; blind ordering starts elsewhere
    assert aware.required[0].p == "<p1>"
    assert blind.required[0].p != "<p1>"
    assert max(aware.join_ests) * 4 <= max(blind.join_ests)


def test_filter_selectivity_shrinks_join_buckets():
    """End-to-end regression: with the selectivity-aware model the
    compiled pipeline's peak join bucket shrinks vs the legacy order
    (which both ignores filters and orders greedily)."""
    store = _filter_order_store()
    text = ("SELECT ?x ?y ?z ?w WHERE { ?x <p1> ?y . ?y <p2> ?z . "
            "?z <p3> ?w . FILTER (?x = <x3>) }")
    r_opt = QueryEngine(store).prepare(text).run()
    r_leg = QueryEngine(store, optimize=False).prepare(text).run()
    assert rows_as_sets(r_opt.rows) == rows_as_sets(r_leg.rows)
    assert r_opt.stats.peak_join_bucket < r_leg.stats.peak_join_bucket


# ------------------------------------------ warmup with skew statistics


def test_save_cache_v3_roundtrips_statistics_and_backends(tmp_path):
    store = lubm.generate(scale=1, seed=0, skew_shapes=True)
    eng = QueryEngine(store)
    text = lubm.S_QUERIES["S1"]
    eng.prepare(text).run()
    path = tmp_path / "warmup.json"
    assert eng.save_cache(str(path)) == 1
    blob = json.loads(path.read_text())
    assert blob["version"] == 3
    assert "statistics" in blob
    assert any(
        "matrix" in e["shape"].get("join_backends", [])
        for e in blob["entries"]
    )
    # a fresh engine on a fresh store object: statistics come from the
    # file (no recompute) and the matrix plan replays without calibration
    store2 = lubm.generate(scale=1, seed=0, skew_shapes=True)
    assert store2._statistics is None
    eng2 = QueryEngine(store2, warmup_path=str(path))
    assert store2._statistics is not None
    r = eng2.prepare(text).run()
    assert r.stats.n_count_passes == 0
    assert r.stats.n_compiles == 1 and r.stats.n_dispatches == 1
    assert len(r.rows) == 20000


def test_save_cache_v2_files_still_load(tmp_path):
    """Warmup files from before the statistics block (version 2) load;
    shapes without join_backends default every slot to the MR backend."""
    store = student_store()
    eng = QueryEngine(store)
    q = PREFIX + "SELECT ?x ?a WHERE { ?x a ub:Student . ?x ub:age ?a . }"
    eng.query(q)
    path = tmp_path / "v2.json"
    eng.save_cache(str(path))
    blob = json.loads(path.read_text())
    blob["version"] = 2
    blob.pop("statistics", None)
    for e in blob["entries"]:
        e["shape"].pop("join_backends", None)
    path.write_text(json.dumps(blob))
    eng2 = QueryEngine(store, warmup_path=str(path))
    r = eng2.prepare(q).run()
    assert r.stats.n_count_passes == 0
    assert rows_as_sets(r.rows) == rows_as_sets(eng.query(q))
