"""Data pipelines: determinism (restart-safety), sampler realism, and
hypothesis properties of the batch formats."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # property tests skip without the dev extra
    from _hypothesis_compat import given, settings, st

from repro.data.graphs import MinibatchPipeline, make_molecule_batch
from repro.data.recsys import CTRPipeline
from repro.data.tokens import Prefetcher, TokenPipeline
from repro.models.gnn.sampler import CSRGraph, block_capacity, sample_block


def test_token_pipeline_deterministic_restart():
    p1 = TokenPipeline(vocab=100, batch=4, seq=8, seed=3)
    stream1 = [next(p1) for _ in range(6)]
    # restart from checkpointed state at step 3
    p2 = TokenPipeline(vocab=100, batch=4, seq=8, seed=3)
    p2.load_state_dict({"seed": 3, "step": 3})
    for i in range(3):
        np.testing.assert_array_equal(stream1[3 + i]["tokens"],
                                      next(p2)["tokens"])


def test_token_labels_are_shifted_tokens():
    p = TokenPipeline(vocab=50, batch=2, seq=16)
    b = next(p)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    assert b["tokens"].max() < 50 and b["tokens"].min() >= 0


def test_prefetcher_preserves_order():
    p = TokenPipeline(vocab=100, batch=2, seq=4, seed=1)
    want = [p.batch_at(i)["tokens"] for i in range(5)]
    pf = Prefetcher(TokenPipeline(vocab=100, batch=2, seq=4, seed=1))
    got = [next(pf)["tokens"] for _ in range(5)]
    pf.close()
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


def test_ctr_pipeline_ids_in_range():
    p = CTRPipeline(n_sparse=5, rows_per_field=64, batch=32, seed=2)
    b = next(p)
    assert b["ids"].shape == (32, 5)
    assert b["ids"].min() >= 0 and b["ids"].max() < 64
    assert set(np.unique(b["labels"])) <= {0.0, 1.0}
    # deterministic restart
    p2 = CTRPipeline(n_sparse=5, rows_per_field=64, batch=32, seed=2)
    np.testing.assert_array_equal(b["ids"], next(p2)["ids"])


def test_minibatch_pipeline_static_shapes_and_masks():
    p = MinibatchPipeline("gat-cora", n_nodes=300, n_edges=2400, d_feat=6,
                          n_classes=4, batch_nodes=8, fanout=(4, 3))
    n_cap, e_cap = block_capacity(8, [4, 3])
    for _ in range(3):
        g = next(p)
        assert g.node_feat.shape == (n_cap, 6)
        assert g.src.shape == (e_cap,) and g.dst.shape == (e_cap,)
        assert bool(np.all(np.diff(g.dst) >= 0)), "edges must be dst-sorted"
        assert g.extras["train_mask"].sum() == 8


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(1, 60), st.integers(1, 6),
       st.integers(1, 5))
def test_sampler_edges_point_to_sampled_nodes(n, e, batch, fanout):
    rng = np.random.default_rng(0)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    csr = CSRGraph.from_edges(src, dst, n)
    seeds = rng.integers(0, n, batch)
    nodes, s, d, m = sample_block(csr, seeds, [fanout], rng)
    assert len(nodes) == batch + batch * fanout
    assert s.max() < len(nodes) and d.max() < len(nodes)
    # every sampled edge's endpoint pair is (child, parent) with parent a seed
    assert np.all(d < batch)
    # sampled neighbors really are graph neighbors (or self-loop fallback)
    for si, di in zip(s[m], d[m]):
        u, v = int(nodes[si]), int(nodes[di])
        in_nbrs = csr.indices[csr.indptr[v]:csr.indptr[v + 1]]
        assert u in in_nbrs or u == v


def test_molecule_batch_graph_ids_sorted():
    g = make_molecule_batch("schnet", 10, 24, 8, 1)
    assert bool(np.all(np.diff(g.graph_ids) >= 0))
    assert g.extras["energy"].shape == (8,)
