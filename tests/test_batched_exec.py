"""Batched same-shape query execution: plan-group coalescing, pow-2 width
bucketing with masked padding lanes, ceil(N/width) stacked dispatches,
overflow regrow inside a stacked dispatch, server routing with per-query
error isolation, batch-width serving stats, the Pallas pair-expand kernel
in the compiled + stacked paths, and (shape, caps, width) warmup
round-trips."""
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    from _hypothesis_compat import given, settings, st  # noqa: F401
    HAVE_HYPOTHESIS = False

from repro.core import plan_ir
from repro.sparql.baseline import reference_rows
from repro.sparql.engine import QueryEngine
from repro.sparql.parser import parse
from repro.sparql.store import store_from_string_triples


def rows_as_sets(rows):
    return sorted(tuple(sorted(r.items())) for r in rows)


def chain_store(n_src=12, fan=3):
    """?x <p> ?y . ?y <q> ?z chains plus numeric attributes for FILTER."""
    triples = []
    for i in range(n_src):
        triples.append((f"<s{i}>", "<p>", f"<m{i % fan}>"))
        triples.append((f"<s{i}>", "<age>", str(20 + i)))
    for j in range(fan):
        triples.append((f"<m{j}>", "<q>", f"<z{j}>"))
        triples.append((f"<m{j}>", "<q>", f"<z{j + fan}>"))
    return store_from_string_triples(triples)


def same_shape_queries(n):
    """n queries of ONE plan shape: only the FILTER constant differs (a
    runtime input), so they all group on one compiled plan signature."""
    return [
        "SELECT ?x ?z WHERE { ?x <p> ?y . ?y <q> ?z . "
        f"FILTER (?x != <s{k}>) }}"
        for k in range(n)
    ]


def run_sequential(prepared):
    return [pq.run() for pq in prepared]


# ------------------------------------------------------- width bucketing


def test_bucket_width_pow2_and_clamp():
    assert plan_ir.bucket_width(1, 64) == 1
    assert plan_ir.bucket_width(3, 64) == 4
    assert plan_ir.bucket_width(16, 64) == 16
    assert plan_ir.bucket_width(17, 64) == 32
    assert plan_ir.bucket_width(200, 64) == 64
    assert plan_ir.bucket_width(5, 4) == 4
    # max_width is a lane CAP: a non-pow-2 value clamps DOWN, never up
    assert plan_ir.bucket_width(48, 48) == 32
    assert plan_ir.floor_pow2(48) == 32


def test_non_pow2_width_cap_never_exceeded():
    """max_batch_width bounds device memory per dispatch — a non-pow-2
    cap chunks at its pow-2 floor instead of rounding lanes up past it."""
    store = chain_store()
    eng = QueryEngine(store, max_batch_width=6)
    prepared = [eng.prepare(t) for t in same_shape_queries(6)]
    seq = run_sequential(prepared)
    res = eng.run_batch(prepared)
    assert eng.last_batch[0].widths == (4, 2)  # chunks of 4 + 2, never 8
    for r, s in zip(res, seq):
        assert r.rows == s.rows


# ------------------------------------------------- stacked dispatch core


def test_warm_same_shape_batch_is_one_dispatch():
    """Acceptance: N warm same-shape queries execute in ceil(N/width)
    device dispatches, with results identical to sequential execution."""
    store = chain_store()
    eng = QueryEngine(store)
    prepared = [eng.prepare(t) for t in same_shape_queries(8)]
    seq = run_sequential(prepared)  # warms the plan cache
    res = eng.run_batch(prepared)
    assert len(eng.last_batch) == 1
    group = eng.last_batch[0]
    assert group.n_queries == 8
    assert group.widths == (8,)
    assert group.n_dispatches == 1  # ceil(8/8)
    assert group.n_compiles == 1  # the width-8 stacked executable
    for r, s in zip(res, seq):
        assert r.rows == s.rows
        assert r.vars == s.vars
    # per-query stats report the shared stacked dispatch
    assert all(r.stats.n_dispatches == 1 for r in res)
    assert all(r.stats.batch_width == 8 for r in res)
    assert all(r.stats.cache_hits == 1 for r in res)
    # second batch: stacked executable is warm too — zero compiles
    res2 = eng.run_batch(prepared)
    assert eng.last_batch[0].n_dispatches == 1
    assert eng.last_batch[0].n_compiles == 0
    for r, s in zip(res2, seq):
        assert r.rows == s.rows


def test_ceil_n_over_width_chunking():
    store = chain_store()
    eng = QueryEngine(store, max_batch_width=4)
    prepared = [eng.prepare(t) for t in same_shape_queries(10)]
    seq = run_sequential(prepared)
    eng.run_batch(prepared)  # compiles width-4 and width-2 variants
    res = eng.run_batch(prepared)
    group = eng.last_batch[0]
    # 10 queries at width cap 4: chunks of 4 + 4 + 2 -> 3 dispatches
    assert group.widths == (4, 4, 2)
    assert group.n_dispatches == 3
    assert group.n_compiles == 0
    for r, s in zip(res, seq):
        assert r.rows == s.rows


def test_padding_lanes_contribute_nothing():
    """A 5-query batch pads to width 8: the 3 masked lanes (copies of lane
    0's inputs) must not leak rows into any result."""
    store = chain_store()
    eng = QueryEngine(store)
    prepared = [eng.prepare(t) for t in same_shape_queries(5)]
    seq = run_sequential(prepared)
    res = eng.run_batch(prepared)
    assert eng.last_batch[0].widths == (8,)
    for r, s in zip(res, seq):
        assert r.rows == s.rows
    # pow-2 bucketing: a later 6-query batch reuses the width-8 executable
    res6 = eng.run_batch([eng.prepare(t) for t in same_shape_queries(6)])
    assert eng.last_batch[0].widths == (8,)
    assert eng.last_batch[0].n_compiles == 0
    for r, s in zip(res6, seq[:6]):
        assert r.rows == s.rows


def test_cold_group_calibrates_first_then_stacks_rest():
    store = chain_store()
    eng = QueryEngine(store)
    prepared = [eng.prepare(t) for t in same_shape_queries(7)]
    res = eng.run_batch(prepared)
    group = eng.last_batch[0]
    assert group.cold
    # first query: eager calibration (count + expand dispatches) + base
    # compile; remaining 6 stack into one width-8 dispatch + its compile
    assert group.widths == (8,)
    assert group.n_compiles == 2
    seq = run_sequential(prepared)
    for r, s in zip(res, seq):
        assert r.rows == s.rows


def test_mixed_batch_falls_back_per_group():
    store = chain_store()
    eng = QueryEngine(store)
    a = [eng.prepare(t) for t in same_shape_queries(4)]
    b = [
        eng.prepare("SELECT ?x ?a WHERE { ?x <p> ?y . ?x <age> ?a . }")
        for _ in range(3)
    ]
    run_sequential(a + b)
    # interleaved arrival order; grouping reassembles the plan groups
    mixed = [a[0], b[0], a[1], b[1], a[2], b[2], a[3]]
    res = eng.run_batch(mixed)
    assert len(eng.last_batch) == 2
    assert {g.n_queries for g in eng.last_batch} == {4, 3}
    assert all(g.n_dispatches == 1 for g in eng.last_batch)
    seq = run_sequential(mixed)
    for r, s in zip(res, seq):
        assert r.rows == s.rows


def test_single_query_group_uses_solo_path():
    store = chain_store()
    eng = QueryEngine(store)
    pq = eng.prepare(same_shape_queries(1)[0])
    pq.run()
    res = eng.run_batch([pq])
    assert res[0].stats.batch_width == 0  # no stacked dispatch
    assert eng.last_batch[0].widths == ()
    assert eng.stacked_dispatches == 0


def test_overflow_in_one_lane_regrows_and_retries():
    """A warm-calibrated bucket that a batchmate overflows: the chunk
    regrows from the worst lane's exact totals and retries."""
    triples = []
    for i in range(8):
        triples.append((f"<s{i}>", "<p1>", "<m1>"))
    triples.append(("<m1>", "<qq>", "<z0>"))  # join total 8
    for i in range(8):
        triples.append((f"<t{i}>", "<p2>", "<m2>"))
    for j in range(7):
        triples.append(("<m2>", "<qq>", f"<w{j}>"))  # join total 56
    store = store_from_string_triples(triples)
    eng = QueryEngine(store)
    q_small = "SELECT ?x ?z WHERE { ?x <p1> ?y . ?y <qq> ?z . }"
    q_big = "SELECT ?x ?z WHERE { ?x <p2> ?y . ?y <qq> ?z . }"
    ps, pb = eng.prepare(q_small), eng.prepare(q_big)
    ps.run()  # calibrates the shared shape at the small join bucket
    res = eng.run_batch([ps, pb])
    assert res[0].stats.n_retries == 1
    assert len(res[0].rows) == 8
    assert len(res[1].rows) == 56
    assert rows_as_sets(res[1].rows) == rows_as_sets(pb.run().rows)
    # regrown caps are cached: the next batch is retry-free
    res2 = eng.run_batch([ps, pb])
    assert res2[0].stats.n_retries == 0
    assert eng.last_batch[0].n_dispatches == 1


def test_eager_engine_run_batch_falls_back_sequential():
    store = chain_store()
    eng = QueryEngine(store, compiled=False)
    prepared = [eng.prepare(t) for t in same_shape_queries(4)]
    res = eng.run_batch(prepared)
    assert eng.last_batch[0].fallback
    seq = run_sequential(prepared)
    for r, s in zip(res, seq):
        assert r.rows == s.rows


def test_run_batch_outcomes_isolates_execution_errors():
    """A batchmate whose bucket regrow exceeds max_capacity fails alone:
    the chunk's stacked dispatch raises, the sequential fallback isolates
    the culprit, and its same-shape neighbours still return rows."""
    triples = []
    for i in range(8):
        triples.append((f"<s{i}>", "<p1>", "<m1>"))
    triples.append(("<m1>", "<qq>", "<z0>"))  # join total 8
    for i in range(8):
        triples.append((f"<t{i}>", "<p2>", "<m2>"))
    for j in range(7):
        triples.append(("<m2>", "<qq>", f"<w{j}>"))  # join total 56 > 16
    store = store_from_string_triples(triples)
    eng = QueryEngine(store, max_capacity=16)
    ok = eng.prepare("SELECT ?x ?z WHERE { ?x <p1> ?y . ?y <qq> ?z . }")
    boom = eng.prepare("SELECT ?x ?z WHERE { ?x <p2> ?y . ?y <qq> ?z . }")
    ok.run()  # calibrates the shared shape at the small bucket
    outcomes = eng.run_batch_outcomes([ok, boom, ok])
    assert isinstance(outcomes[1], MemoryError)
    assert eng.last_batch[0].fallback
    want = ok.run().rows
    assert outcomes[0].rows == want
    assert outcomes[2].rows == want
    with pytest.raises(MemoryError):
        eng.run_batch([ok, boom])


# ----------------------------------------------- engine counters / stats


def test_engine_batch_counters_accumulate():
    store = chain_store()
    eng = QueryEngine(store)
    prepared = [eng.prepare(t) for t in same_shape_queries(8)]
    run_sequential(prepared)
    eng.run_batch(prepared)
    eng.run_batch(prepared[:3])
    assert eng.stacked_dispatches == 2
    assert eng.stacked_queries == 11
    assert eng.batch_width_hist == {8: 1, 4: 1}


# ------------------------------------------------------------ server path


def _server(store, **kw):
    from repro.serve.sparql_server import SPARQLServer

    return SPARQLServer(QueryEngine(store), max_batch=8, **kw)


def _dispatch(srv, texts):
    """Call the server's dispatch stage directly and resolve its Deferred
    slots inline (what the batcher/decode pool does between the stages),
    so tests keep seeing the typed QueryResult/QueryError envelopes."""
    from repro.serve.batcher import Deferred

    outs = []
    for o in srv._run_batch(texts):
        if isinstance(o, Deferred):
            try:
                o = o.fn()
            except Exception as e:  # decode errors travel as exceptions
                o = e
        outs.append(o)
    return outs


def test_server_batch_coalesces_and_isolates_errors():
    from repro.serve.sparql_server import ParseQueryError, QueryResult

    store = chain_store()
    srv = _server(store)
    try:
        texts = same_shape_queries(4)
        _dispatch(srv, texts)  # cold pass warms plan + stacked caches
        outs = _dispatch(srv, [texts[0], "SELECT NONSENSE", *texts[1:]])
        assert isinstance(outs[1], ParseQueryError)
        good = [o for i, o in enumerate(outs) if i != 1]
        assert all(isinstance(o, QueryResult) for o in good)
        engine = srv.engine
        assert engine.last_batch[0].n_dispatches == 1
        want = [engine.prepare(t).run().rows for t in texts]
        assert [o.rows for o in good] == want
    finally:
        srv.close()


def test_server_stats_report_batch_width_histogram():
    store = chain_store()
    srv = _server(store)
    try:
        texts = same_shape_queries(8)
        _dispatch(srv, texts)
        _dispatch(srv, texts)
        s = srv.stats()["batched"]
        assert s["stacked_dispatches"] >= 2
        assert s["stacked_queries"] >= 15  # 7 stacked cold + 8 warm
        assert s["queries_per_dispatch"] > 1
        assert 8 in s["batch_width_hist"]
        assert isinstance(s["arrival_batch_hist"], dict)
    finally:
        srv.close()


def test_server_concurrent_same_query_batches(tmp_path):
    """End-to-end through the MicroBatcher worker with real threads."""
    import threading

    store = chain_store()
    srv = _server(store, max_wait_s=0.05)
    try:
        text = same_shape_queries(2)[0]
        want = srv.query(text).rows  # warm
        results = [None] * 6
        errors = []

        def worker(i):
            try:
                results[i] = srv.query(text).rows
            except Exception as e:  # pragma: no cover - fail loudly below
                errors.append(e)

        threads = [
            threading.Thread(target=worker, args=(i,)) for i in range(6)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert all(r == want for r in results)
        hist = srv.stats()["batched"]["arrival_batch_hist"]
        assert hist  # the batcher recorded its arrival sizes
    finally:
        srv.close()


def test_server_batch_execution_flag_off():
    store = chain_store()
    srv = _server(store, batch_execution=False)
    try:
        texts = same_shape_queries(4)
        _dispatch(srv, texts)
        _dispatch(srv, texts)
        assert srv.engine.stacked_dispatches == 0
    finally:
        srv.close()


# ---------------------------------------------- pair-expand kernel wiring


def test_use_kernel_parity_compiled_and_batched(monkeypatch):
    """QueryEngine(use_kernel=True) routes the compiled AND stacked paths
    through the Pallas pair-expand kernel and matches the jnp results."""
    from repro.kernels.pair_expand import ops as pe_ops

    calls = {"n": 0}
    real = pe_ops.pair_expand

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(pe_ops, "pair_expand", counting)
    # route expand_pairs through the patched symbol (it imports lazily)
    store = chain_store()
    ref = QueryEngine(store)
    kern = QueryEngine(store, use_kernel=True)
    texts = same_shape_queries(4)
    want = [ref.prepare(t).run().rows for t in texts]
    prepared = [kern.prepare(t) for t in texts]
    got_seq = run_sequential(prepared)
    assert calls["n"] > 0  # kernel hit during compiled lowering
    assert [r.rows for r in got_seq] == want
    calls["n"] = 0
    got_batch = kern.run_batch(prepared)
    assert calls["n"] > 0  # kernel hit during stacked (vmapped) lowering
    assert [r.rows for r in got_batch] == want
    assert kern.last_batch[0].n_dispatches == 1


def test_expand_pairs_kernel_matches_jnp_reference():
    import jax.numpy as jnp

    from repro.core import mr_join as mj
    from repro.core.relation import Relation

    left = Relation.from_numpy(
        ("?a", "?k"), np.array([[1, 7], [2, 8], [3, 7], [4, 9]]), capacity=8
    )
    right = Relation.from_numpy(
        ("?k", "?b"), np.array([[7, 11], [7, 12], [9, 13]]), capacity=4
    )
    plan, _ = mj.mr_join_plan(left, right)
    li_r, rj_r, v_r = mj.expand_pairs_jnp(plan, 16)
    li_k, rj_k, v_k = mj.expand_pairs(plan, 16, use_kernel=True)
    assert jnp.array_equal(v_r, v_k)
    assert jnp.array_equal(jnp.where(v_r, li_r, -1), jnp.where(v_k, li_k, -1))
    assert jnp.array_equal(jnp.where(v_r, rj_r, -1), jnp.where(v_k, rj_k, -1))


# ------------------------------------- warmup persistence across widths


def test_save_cache_roundtrips_widths(tmp_path):
    store = chain_store()
    eng = QueryEngine(store)
    prepared = [eng.prepare(t) for t in same_shape_queries(6)]
    run_sequential(prepared)
    eng.run_batch(prepared)  # compiles the width-8 stacked variant
    path = tmp_path / "warm.json"
    assert eng.save_cache(str(path)) == 1
    data = json.loads(path.read_text())
    assert data["entries"][0]["widths"] == [8]
    # restart: caps warm (no calibration), the persisted width precompiles
    # with the entry, and widths survive a re-save even though this
    # process never ran a batch
    eng2 = QueryEngine(store, warmup_path=str(path))
    prepared2 = [eng2.prepare(t) for t in same_shape_queries(6)]
    rs = prepared2[0].run()
    assert rs.stats.n_count_passes == 0
    assert rs.stats.n_compiles == 2  # base executable + warm width 8
    eng2.run_batch(prepared2)
    assert eng2.last_batch[0].n_compiles == 0  # first batch is fully warm
    assert eng2.last_batch[0].widths == (8,)
    assert eng2.save_cache(str(path)) == 1
    assert json.loads(path.read_text())["entries"][0]["widths"] == [8]


def test_warmup_accepts_pre_batching_files(tmp_path):
    """Files saved before stacked execution existed (no widths key) still
    warm the cache — the signature extension is backward compatible."""
    store = chain_store()
    eng = QueryEngine(store)
    eng.prepare(same_shape_queries(1)[0]).run()
    path = tmp_path / "warm.json"
    eng.save_cache(str(path))
    data = json.loads(path.read_text())
    for e in data["entries"]:
        del e["widths"]
    path.write_text(json.dumps({"version": 1, "entries": data["entries"]}))
    eng2 = QueryEngine(store, warmup_path=str(path))
    rs = eng2.prepare(same_shape_queries(2)[1]).run()
    assert rs.stats.n_count_passes == 0  # caps still warm
    assert json.loads(path.read_text())["entries"][0].get("widths", []) == []


def test_widths_reset_after_overflow_regrow(tmp_path):
    """An overflow regrow replaces the cache entry; the re-saved signature
    carries the widths recompiled at the NEW caps."""
    triples = []
    for i in range(8):
        triples.append((f"<s{i}>", "<p1>", "<m1>"))
    triples.append(("<m1>", "<qq>", "<z0>"))
    for i in range(8):
        triples.append((f"<t{i}>", "<p2>", "<m2>"))
    for j in range(7):
        triples.append(("<m2>", "<qq>", f"<w{j}>"))
    store = store_from_string_triples(triples)
    eng = QueryEngine(store)
    ps = eng.prepare("SELECT ?x ?z WHERE { ?x <p1> ?y . ?y <qq> ?z . }")
    pb = eng.prepare("SELECT ?x ?z WHERE { ?x <p2> ?y . ?y <qq> ?z . }")
    ps.run()
    eng.run_batch([ps, pb])  # overflow -> regrow -> width-2 at new caps
    path = tmp_path / "warm.json"
    eng.save_cache(str(path))
    entry = json.loads(path.read_text())["entries"][0]
    assert entry["widths"] == [2]
    assert max(entry["join_caps"]) >= 56


# --------------------------------------------- property-based differential


def _batch_store(seed: int):
    rng = np.random.default_rng(seed)
    ents = [f"<e{i}>" for i in range(6)]
    triples = set()
    for _ in range(40):
        triples.add((
            ents[rng.integers(6)],
            f"<p{rng.integers(3)}>",
            ents[rng.integers(6)],
        ))
    for i in range(6):
        triples.add((ents[i], "<age>", str(15 + 3 * i)))
    return store_from_string_triples(sorted(triples))


def _query_text(shape: str, p1: int, p2: int, cut: int) -> str:
    base = f"?x <p{p1}> ?y"
    if shape == "bgp":
        return f"SELECT ?x ?y ?z WHERE {{ {base} . ?y <p{p2}> ?z . }}"
    if shape == "filter":
        return (f"SELECT ?x ?y ?a WHERE {{ {base} . ?x <age> ?a . "
                f"FILTER (?a < {cut} || ?x = <e1>) }}")
    if shape == "optional":
        return (f"SELECT ?x ?y ?z WHERE {{ {base} . "
                f"OPTIONAL {{ ?x <p{p2}> ?z }} }}")
    assert shape == "union"
    return (f"SELECT ?x ?v WHERE {{ {{ ?x <p{p1}> ?v }} UNION "
            f"{{ ?x <p{p2}> ?v }} }}")


def _assert_batch_matches_sequential_and_oracle(store, texts):
    eng = QueryEngine(store)
    prepared = [eng.prepare(t) for t in texts]
    want_each = [
        rows_as_sets(reference_rows(store, parse(t))) for t in texts
    ]
    res = eng.run_batch(prepared)
    seq = run_sequential(prepared)
    for r, s, w, t in zip(res, seq, want_each, texts):
        assert r.rows == s.rows, t
        assert rows_as_sets(r.rows) == w, t
    # batches straddle plan groups: every group still ran
    assert sum(g.n_queries for g in eng.last_batch) == len(texts)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=7),
    picks=st.lists(
        st.tuples(
            st.sampled_from(["bgp", "filter", "optional", "union"]),
            st.integers(min_value=0, max_value=2),
            st.integers(min_value=0, max_value=2),
            st.integers(min_value=14, max_value=32),
        ),
        min_size=2,
        max_size=8,
    ),
)
def test_run_batch_matches_sequential_and_oracle(seed, picks):
    """Property (acceptance): run_batch over a random mix of BGP / FILTER /
    OPTIONAL / UNION queries — including batches straddling several plan
    groups — returns exactly what per-query run() and the NumPy oracle
    return."""
    store = _batch_store(seed)
    texts = [_query_text(s, p1, p2, cut) for s, p1, p2, cut in picks]
    _assert_batch_matches_sequential_and_oracle(store, texts)


@pytest.mark.parametrize("seed", [0, 2, 5])
def test_run_batch_differential_sweep_without_hypothesis(seed):
    """Deterministic slice of the property space (runs without
    hypothesis): one query of each operator shape in a single batch."""
    store = _batch_store(seed)
    texts = [
        _query_text(s, seed % 3, (seed + 1) % 3, 18 + seed)
        for s in ("bgp", "filter", "optional", "union")
    ] * 2  # duplicates: same-shape pairs actually stack
    _assert_batch_matches_sequential_and_oracle(store, texts)


def test_server_mixed_batch_with_parse_error_matches_oracle():
    """The server path: a straddling batch with a parse error keeps every
    other slot correct (per-request isolation end to end)."""
    from repro.serve.sparql_server import ParseQueryError

    store = _batch_store(1)
    texts = [
        _query_text("bgp", 0, 1, 20),
        _query_text("union", 0, 1, 20),
        "SELECT WHERE BROKEN {",
        _query_text("bgp", 0, 1, 20),
        _query_text("filter", 1, 2, 24),
    ]
    srv = _server(store)
    try:
        _dispatch(srv, texts)  # warm
        outs = _dispatch(srv, texts)
        assert isinstance(outs[2], ParseQueryError)
        for i, text in enumerate(texts):
            if i == 2:
                continue
            want = rows_as_sets(reference_rows(store, parse(text)))
            assert rows_as_sets(outs[i].rows) == want, text
    finally:
        srv.close()
