"""§Perf GNN machinery correctness on a 1-device mesh: the shuffle
gather/scatter and the streamed edge blocks must match the plain paths
exactly (multi-device equivalence is covered by tests/distributed/)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compat
from repro.data.graphs import make_full_graph
from repro.models.gnn import graphcast as gc
from repro.models.gnn import meshgraphnet as mgn


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _graph(arch, d_feat, seed=3):
    g = make_full_graph(arch, n=64, e=512, e_cap=512, d_feat=d_feat,
                        n_classes=1, seed=seed)
    return jax.tree.map(jnp.asarray, g)


def test_graphcast_streamed_matches_plain(mesh):
    base = gc.GraphCastConfig(n_layers=2, d_hidden=16, n_vars=6)
    g = _graph("graphcast", 6)
    p = gc.init_params(jax.random.PRNGKey(0), base)
    opt = dataclasses.replace(
        base, node_spec=("data", "model"), shuffle_gather=True,
        edge_stream_chunks=4, remat=True)
    with compat.set_mesh(mesh):
        np.testing.assert_allclose(
            np.asarray(gc.apply(p, g, base)),
            np.asarray(gc.apply(p, g, opt)), rtol=2e-4, atol=2e-4)
        g1 = jax.grad(lambda p: gc.loss_fn(p, g, base))(p)
        g2 = jax.grad(lambda p: gc.loss_fn(p, g, opt))(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_meshgraphnet_shuffle_matches_plain(mesh):
    base = mgn.MGNConfig(n_layers=3, d_hidden=16, d_node_in=8)
    g = _graph("meshgraphnet", 8)
    p = mgn.init_params(jax.random.PRNGKey(1), base)
    opt = dataclasses.replace(base, node_spec=("data", "model"),
                              shuffle_gather=True, remat=True)
    with compat.set_mesh(mesh):
        np.testing.assert_allclose(
            np.asarray(mgn.apply(p, g, base)),
            np.asarray(mgn.apply(p, g, opt)), rtol=2e-4, atol=2e-4)
