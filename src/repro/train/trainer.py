"""Training loop with the fault-tolerance contract a 1000-node job needs:

  * step-addressed checkpoints of (params, opt_state, data-pipeline state),
    async writer, keep-k, atomic commit (checkpoint/manager.py);
  * crash-and-restart: `run()` resumes from the latest checkpoint — the
    deterministic pipelines regenerate the exact remaining stream;
  * failure injection for tests (`fail_at_step` raises mid-run after the
    optimizer update, before the checkpoint, like a real preemption);
  * straggler posture: grad-accum microbatching bounds the per-step work
    unit; NaN-step skipping (metric-gated) bounds bad-host blast radius.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.optim.adamw import adamw_init


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainSettings:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    keep_k: int = 3
    async_ckpt: bool = True
    fail_at_step: int = -1  # test hook: raise after this step once
    skip_nonfinite_steps: bool = True


class Trainer:
    """Drives (train_step, pipeline, checkpoint) to a step budget."""

    def __init__(
        self,
        train_step: Callable,  # (params, opt_state, batch) -> (p, s, metrics)
        params: Any,
        pipeline: Any,  # __next__ + state_dict/load_state_dict
        ckpt_dir: str,
        settings: TrainSettings = TrainSettings(),
        opt_state: Any = None,
        to_device: Callable | None = None,
    ):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state if opt_state is not None else adamw_init(
            params)
        self.pipeline = pipeline
        self.s = settings
        self.mgr = CheckpointManager(ckpt_dir, keep_k=settings.keep_k,
                                     async_write=settings.async_ckpt)
        self.to_device = to_device or (lambda b: b)
        self.step = 0
        self.history: list[dict] = []
        self._failed_once = False

    # -- checkpoint glue ---------------------------------------------------
    def _save(self) -> None:
        tree = {"params": self.params, "opt": self.opt_state}
        self.mgr.save(self.step, tree,
                      extra_meta={"pipeline": self.pipeline.state_dict()})

    def _restore(self, step: int) -> None:
        like = {"params": self.params, "opt": self.opt_state}
        tree = self.mgr.restore(step, like)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.pipeline.load_state_dict(self.mgr.meta(step)["pipeline"])
        self.step = step

    def resume_if_possible(self) -> bool:
        latest = self.mgr.latest_step()
        if latest is None:
            return False
        self._restore(latest)
        return True

    # -- main loop -----------------------------------------------------------
    def run(self) -> list[dict]:
        while self.step < self.s.total_steps:
            batch = self.to_device(next(self.pipeline))
            t0 = time.time()
            new_p, new_s, metrics = self.train_step(
                self.params, self.opt_state, batch
            )
            metrics = {k: float(v) for k, v in metrics.items()}
            if self.s.skip_nonfinite_steps and not all(
                math.isfinite(v) for v in metrics.values()
            ):
                # bad step (bad host / overflow): drop the update, keep going
                metrics["skipped"] = 1.0
            else:
                self.params, self.opt_state = new_p, new_s
            self.step += 1
            metrics["step"] = self.step
            metrics["dt"] = time.time() - t0
            self.history.append(metrics)
            if self.s.log_every and self.step % self.s.log_every == 0:
                print(
                    f"step {self.step}: "
                    + " ".join(f"{k}={v:.4g}" for k, v in metrics.items()
                               if k not in ("step",)),
                    flush=True,
                )
            if (
                self.s.fail_at_step == self.step and not self._failed_once
            ):
                self._failed_once = True
                raise SimulatedFailure(f"injected failure at {self.step}")
            if self.s.ckpt_every and self.step % self.s.ckpt_every == 0:
                self._save()
        self.mgr.wait()
        return self.history


def run_with_restarts(make_trainer: Callable[[], Trainer],
                      max_restarts: int = 3) -> Trainer:
    """Supervisor loop: restart-from-checkpoint on failure (the single-
    process analogue of a cluster controller rescheduling a died job)."""
    restarts = 0
    while True:
        tr = make_trainer()
        tr.resume_if_possible()
        try:
            tr.run()
            return tr
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
