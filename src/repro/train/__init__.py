from repro.train.trainer import Trainer, TrainSettings  # noqa: F401
