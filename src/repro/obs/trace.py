"""Lightweight span tracing for the query path.

One `Trace` per request, a tree of `Span`s under its root covering
parse -> optimize -> compile -> dispatch -> transfer -> decode. Clocks
are monotonic (`time.perf_counter`); a wall-clock epoch captured at
trace creation anchors the Chrome trace-event export. Everything is
thread-safe: spans are appended under the trace's lock, because a
request's spans are produced on three different threads (submitter,
batcher, decode worker).

Two span styles, chosen for leak-freedom:

  * context-managed (`trace.span("parse")`) — closes on the `with`
    exit, exceptions included;
  * retroactive (`trace.add_span(name, t0, t1)`) — created already
    closed from measured timestamps. The engine uses these for
    dispatch/compile/transfer/decode, so a span recorded from a worker
    thread can never be left open by a crash: either the interval
    completed and is recorded closed, or nothing is recorded.

Only the root span (closed by `Tracer.finish`, which callers invoke in
a `finally`) and context-managed spans can be open at all; the
leaked-span tests assert `open_spans()` is empty over the whole ring.

A stacked dispatch fans ONE device launch out to N lane traces: each
lane records its own "dispatch" span over the same interval, correlated
by a shared `dispatch_id` attribute.

`Tracer` owns the bounded ring of finished traces (the server's
`recent_traces()`) and the slow-query log: traces whose total duration
crosses `slow_ms` are kept separately with their full span tree and the
plan signature the engine attached.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Iterable

_ids = itertools.count(1)


class Span:
    """One timed interval inside a trace. `t0`/`t1` are perf_counter
    seconds relative to the trace's origin; `t1 < 0` means still open."""

    __slots__ = ("span_id", "parent_id", "name", "t0", "t1", "attrs",
                 "thread")

    def __init__(self, span_id: int, parent_id: int | None, name: str,
                 t0: float, attrs: dict[str, Any]):
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.t0 = t0
        self.t1 = -1.0
        self.attrs = attrs
        self.thread = threading.get_ident()

    @property
    def open(self) -> bool:
        return self.t1 < 0.0

    @property
    def duration_s(self) -> float:
        return max(0.0, self.t1 - self.t0) if not self.open else 0.0

    def __repr__(self) -> str:
        state = "open" if self.open else f"{self.duration_s * 1e3:.2f}ms"
        return f"Span({self.name}, {state})"


class _SpanCtx:
    """Context manager that closes its span on exit, exceptions included
    (the error type is recorded as an attribute, not swallowed)."""

    __slots__ = ("trace", "span")

    def __init__(self, trace: "Trace", span: Span):
        self.trace = trace
        self.span = span

    def __enter__(self) -> Span:
        return self.span

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.span.attrs["error"] = exc_type.__name__
        self.trace.end(self.span)


class Trace:
    """One request's span tree. Append-only and thread-safe; spans keep
    arriving (from racing decode workers) even after `finish()` — they
    are recorded closed, so the leak invariant is unaffected."""

    def __init__(self, name: str, attrs: dict[str, Any] | None = None):
        self.trace_id = next(_ids)
        self._lock = threading.Lock()
        # perf_counter origin + wall epoch: exports need absolute time
        self.origin = time.perf_counter()
        self.epoch_s = time.time()
        self.spans: list[Span] = []
        self.root = Span(next(_ids), None, name, 0.0, dict(attrs or {}))
        self.spans.append(self.root)

    def _now(self) -> float:
        return time.perf_counter() - self.origin

    def start(self, name: str, parent: Span | None = None,
              **attrs: Any) -> Span:
        s = Span(
            next(_ids),
            (parent or self.root).span_id,
            name,
            self._now(),
            attrs,
        )
        with self._lock:
            self.spans.append(s)
        return s

    def end(self, span: Span, **attrs: Any) -> None:
        if attrs:
            span.attrs.update(attrs)
        span.t1 = self._now()

    def span(self, name: str, parent: Span | None = None,
             **attrs: Any) -> _SpanCtx:
        return _SpanCtx(self, self.start(name, parent, **attrs))

    def add_span(self, name: str, t0: float, t1: float,
                 parent: Span | None = None, **attrs: Any) -> Span:
        """Record an already-measured interval (perf_counter absolute
        seconds, as returned by time.perf_counter()). Born closed."""
        s = Span(
            next(_ids),
            (parent or self.root).span_id,
            name,
            t0 - self.origin,
            attrs,
        )
        s.t1 = t1 - self.origin
        with self._lock:
            self.spans.append(s)
        return s

    def finish(self, **attrs: Any) -> None:
        if self.root.open:
            self.end(self.root, **attrs)
        elif attrs:
            self.root.attrs.update(attrs)

    # -- queries -----------------------------------------------------------
    @property
    def duration_s(self) -> float:
        return self.root.duration_s

    def open_spans(self) -> list[Span]:
        with self._lock:
            return [s for s in self.spans if s.open]

    def find(self, name: str) -> list[Span]:
        with self._lock:
            return [s for s in self.spans if s.name == name]

    # -- exports -----------------------------------------------------------
    def to_chrome_events(self, pid: int = 1) -> list[dict]:
        """Chrome trace-event JSON (the `chrome://tracing` / Perfetto
        format): complete ("X") events, microsecond timestamps anchored
        to the trace's wall epoch."""
        base_us = self.epoch_s * 1e6
        out = []
        with self._lock:
            spans = list(self.spans)
        for s in spans:
            t1 = s.t1 if not s.open else self._now()
            out.append({
                "name": s.name,
                "cat": "query",
                "ph": "X",
                "ts": base_us + s.t0 * 1e6,
                "dur": max(0.0, t1 - s.t0) * 1e6,
                "pid": pid,
                "tid": s.thread,
                "args": dict(
                    s.attrs,
                    trace_id=self.trace_id,
                    span_id=s.span_id,
                    parent_id=s.parent_id,
                ),
            })
        return out

    def tree_str(self) -> str:
        """Indented span tree with durations — the slow-query log line."""
        with self._lock:
            spans = list(self.spans)
        kids: dict[int | None, list[Span]] = {}
        for s in spans:
            kids.setdefault(s.parent_id, []).append(s)
        lines: list[str] = []

        def walk(s: Span, depth: int) -> None:
            dur = "open" if s.open else f"{s.duration_s * 1e3:.2f}ms"
            extra = "".join(
                f" {k}={v}" for k, v in sorted(s.attrs.items())
            )
            lines.append(f"{'  ' * depth}{s.name} {dur}{extra}")
            for c in sorted(kids.get(s.span_id, ()), key=lambda x: x.t0):
                walk(c, depth + 1)

        walk(self.root, 0)
        return "\n".join(lines)


class Tracer:
    """Trace factory + bounded ring of finished traces + slow-query log.

    `slow_ms=None` disables the slow log; otherwise any finished trace
    whose duration crosses the threshold is kept (ring-bounded) with its
    full span tree and whatever `plan_sig` attribute the engine set."""

    def __init__(self, ring_size: int = 256, slow_ms: float | None = None,
                 slow_log_size: int = 64):
        self.ring_size = max(1, ring_size)
        self.slow_ms = slow_ms
        self.slow_log_size = max(1, slow_log_size)
        self._lock = threading.Lock()
        self._ring: list[Trace] = []
        self._slow: list[Trace] = []
        self.n_traces = 0
        self.n_slow = 0

    def new_trace(self, name: str = "query",
                  **attrs: Any) -> Trace:
        return Trace(name, attrs)

    def finish(self, trace: Trace, **attrs: Any) -> None:
        """Close the trace's root and retire it into the ring (and the
        slow log when it crossed the threshold). Must be called exactly
        once per trace, in the request path's `finally`."""
        trace.finish(**attrs)
        with self._lock:
            self.n_traces += 1
            self._ring.append(trace)
            if len(self._ring) > self.ring_size:
                del self._ring[: len(self._ring) - self.ring_size]
            if (
                self.slow_ms is not None
                and trace.duration_s * 1e3 >= self.slow_ms
            ):
                self.n_slow += 1
                self._slow.append(trace)
                if len(self._slow) > self.slow_log_size:
                    del self._slow[: len(self._slow) - self.slow_log_size]

    def recent(self) -> list[Trace]:
        with self._lock:
            return list(self._ring)

    def slow_queries(self) -> list[Trace]:
        with self._lock:
            return list(self._slow)

    def open_span_count(self) -> int:
        """Leaked (still-open) spans across every retired trace — the
        zero-leak acceptance check."""
        return sum(len(t.open_spans()) for t in self.recent())

    def export_chrome(self) -> list[dict]:
        events: list[dict] = []
        for t in self.recent():
            events.extend(t.to_chrome_events())
        return events


def phase_totals(traces: Iterable[Trace]) -> dict[str, float]:
    """Total seconds spent per span name across traces — the per-phase
    latency breakdown (dispatch vs transfer vs decode) the serving bench
    reports at the saturating burst. Open spans contribute nothing."""
    out: dict[str, float] = {}
    for t in traces:
        with t._lock:
            spans = list(t.spans)
        for s in spans:
            if not s.open:
                out[s.name] = out.get(s.name, 0.0) + s.duration_s
    return out


# -- trace JSON schema validation ---------------------------------------------
# A deliberately small JSON-Schema subset (type / required / properties /
# items / enum / minimum), enough to validate the Chrome trace-event export
# against the checked-in docs/trace_schema.json without external deps.

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "number": (int, float),
    "integer": int,
    "boolean": bool,
}


def validate_chrome_events(value: Any, schema: dict,
                           path: str = "$") -> list[str]:
    """Validate `value` against the schema subset; returns a list of
    error strings (empty = valid)."""
    errs: list[str] = []
    typ = schema.get("type")
    if typ is not None:
        expected = _TYPES[typ]
        if typ == "number" and isinstance(value, bool):
            errs.append(f"{path}: expected number, got bool")
        elif not isinstance(value, expected) or (
            typ == "integer" and isinstance(value, bool)
        ):
            errs.append(f"{path}: expected {typ}, "
                        f"got {type(value).__name__}")
            return errs
    if "enum" in schema and value not in schema["enum"]:
        errs.append(f"{path}: {value!r} not in {schema['enum']}")
    if "minimum" in schema and isinstance(value, (int, float)):
        if value < schema["minimum"]:
            errs.append(f"{path}: {value} < minimum {schema['minimum']}")
    if isinstance(value, dict):
        for req in schema.get("required", ()):
            if req not in value:
                errs.append(f"{path}: missing required key {req!r}")
        for k, sub in schema.get("properties", {}).items():
            if k in value:
                errs.extend(
                    validate_chrome_events(value[k], sub, f"{path}.{k}")
                )
    if isinstance(value, list) and "items" in schema:
        for i, item in enumerate(value):
            errs.extend(
                validate_chrome_events(item, schema["items"], f"{path}[{i}]")
            )
    return errs
