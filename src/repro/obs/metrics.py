"""Unified metrics registry: counters, gauges, log-bucketed histograms,
and a Prometheus text exposition.

One `MetricsRegistry` per engine (`engine.metrics`); the server registers
its request-path metrics on the same instance so `render_prometheus()`
is a single scrape covering every layer. Two integration styles:

  * direct instruments — request outcomes, latencies, timeouts, update
    counters: incremented/observed at the event site (the registry is
    the source of truth; `stats()` reads the instrument back);
  * collector callbacks — hot-path counters the engine keeps as plain
    attributes (padded_cells, stacked_dispatches, device_time_s, the
    plan/scan cache dicts): a callback registered with
    `register_collector` mirrors them into instruments at scrape time,
    so the dispatch path pays nothing for exposition.

Histograms are log-bucketed: boundaries grow geometrically (factor 2 by
default) from `start`, which matches latency's dynamic range with a
handful of buckets and renders as a valid cumulative Prometheus
histogram (`_bucket{le=...}` non-decreasing, `+Inf` == `_count`).

`parse_prometheus` is the exposition's own validator (used by tests and
the obs-smoke CI gate): it checks line grammar, label syntax, histogram
bucket monotonicity and the `+Inf`/_count agreement.
"""
from __future__ import annotations

import math
import re
import threading
from typing import Any, Callable, Iterable

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _label_str(labelnames: tuple[str, ...], labelvalues: tuple) -> str:
    if not labelnames:
        return ""
    pairs = ",".join(
        f'{k}="{str(v)}"' for k, v in zip(labelnames, labelvalues)
    )
    return "{" + pairs + "}"


class _Metric:
    """Common child-per-labelset machinery."""

    kind = "untyped"

    def __init__(self, name: str, help: str,
                 labelnames: tuple[str, ...] = ()):
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"bad label name {ln!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._children: dict[tuple, Any] = {}
        if not self.labelnames:
            # a label-free instrument exposes its zero from birth (labelled
            # children appear on first labels() touch, as in prometheus)
            self._children[()] = self._make_child()

    def labels(self, **kv: Any):
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(kv)}"
            )
        key = tuple(str(kv[ln]) for ln in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._make_child()
                self._children[key] = child
            return child

    def _default_child(self):
        """The label-free instrument (lazily created)."""
        if self.labelnames:
            raise ValueError(f"{self.name} requires labels()")
        with self._lock:
            child = self._children.get(())
            if child is None:
                child = self._make_child()
                self._children[()] = child
            return child

    def _items(self) -> list[tuple[tuple, Any]]:
        with self._lock:
            return sorted(self._children.items())


class _CounterChild:
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += v

    def set_total(self, v: float) -> None:
        """Bridge entry point for collector callbacks mirroring an
        external cumulative value; monotone (never moves backwards)."""
        with self._lock:
            self._value = max(self._value, float(v))

    @property
    def value(self) -> float:
        return self._value


class Counter(_Metric):
    kind = "counter"

    def _make_child(self) -> _CounterChild:
        return _CounterChild()

    def inc(self, v: float = 1.0) -> None:
        self._default_child().inc(v)

    def set_total(self, v: float) -> None:
        self._default_child().set_total(v)

    @property
    def value(self) -> float:
        return self._default_child().value

    def render(self) -> list[str]:
        return [
            f"{self.name}{_label_str(self.labelnames, k)} {_fmt(c.value)}"
            for k, c in self._items()
        ]


class _GaugeChild:
    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._value += v

    def dec(self, v: float = 1.0) -> None:
        self.inc(-v)

    @property
    def value(self) -> float:
        return self._value


class Gauge(_Metric):
    kind = "gauge"

    def _make_child(self) -> _GaugeChild:
        return _GaugeChild()

    def set(self, v: float) -> None:
        self._default_child().set(v)

    def inc(self, v: float = 1.0) -> None:
        self._default_child().inc(v)

    def dec(self, v: float = 1.0) -> None:
        self._default_child().dec(v)

    @property
    def value(self) -> float:
        return self._default_child().value

    def render(self) -> list[str]:
        return [
            f"{self.name}{_label_str(self.labelnames, k)} {_fmt(c.value)}"
            for k, c in self._items()
        ]


def log_buckets(start: float = 0.0005, factor: float = 2.0,
                count: int = 16) -> tuple[float, ...]:
    """Geometric bucket boundaries: start, start*factor, ... — latency's
    dynamic range in `count` buckets (default 0.5ms .. ~16s)."""
    return tuple(start * factor ** i for i in range(count))


class _HistogramChild:
    __slots__ = ("_lock", "buckets", "counts", "sum", "count")

    def __init__(self, buckets: tuple[float, ...]):
        self._lock = threading.Lock()
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)  # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        # first bucket whose upper bound contains v (binary search is
        # overkill at <=16 buckets)
        i = 0
        for i, b in enumerate(self.buckets):
            if v <= b:
                break
        else:
            i = len(self.buckets)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper bound of the bucket the
        q-quantile observation landed in)."""
        with self._lock:
            total = self.count
            counts = list(self.counts)
        if total == 0:
            return 0.0
        target = math.ceil(q * total)
        seen = 0
        for i, c in enumerate(counts):
            seen += c
            if seen >= target:
                return (
                    self.buckets[i] if i < len(self.buckets)
                    else float("inf")
                )
        return float("inf")


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str,
                 labelnames: tuple[str, ...] = (),
                 buckets: tuple[float, ...] | None = None):
        b = tuple(sorted(buckets)) if buckets else log_buckets()
        if not b or any(
            b[i] >= b[i + 1] for i in range(len(b) - 1)
        ):
            raise ValueError("buckets must be strictly increasing")
        self.buckets = b  # before super(): _make_child reads it
        super().__init__(name, help, labelnames)

    def _make_child(self) -> _HistogramChild:
        return _HistogramChild(self.buckets)

    def observe(self, v: float) -> None:
        self._default_child().observe(v)

    def quantile(self, q: float) -> float:
        return self._default_child().quantile(q)

    def render(self) -> list[str]:
        lines = []
        for key, c in self._items():
            cum = 0
            with c._lock:
                counts = list(c.counts)
                total = c.count
                s = c.sum
            for b, n in zip(self.buckets, counts):
                cum += n
                le = _label_str(
                    self.labelnames + ("le",), key + (_fmt(b),)
                )
                lines.append(f"{self.name}_bucket{le} {cum}")
            le = _label_str(self.labelnames + ("le",), key + ("+Inf",))
            lines.append(f"{self.name}_bucket{le} {total}")
            base = _label_str(self.labelnames, key)
            lines.append(f"{self.name}_sum{base} {repr(float(s))}")
            lines.append(f"{self.name}_count{base} {total}")
        return lines


class MetricsRegistry:
    """Name -> instrument, plus scrape-time collector callbacks."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}
        self._collectors: list[Callable[[], None]] = []

    def _get_or_create(self, cls, name: str, help: str,
                       labelnames: tuple[str, ...], **kw) -> Any:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise ValueError(
                        f"{name} already registered as {m.kind}"
                    )
                return m
            m = cls(name, help, labelnames, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: tuple[str, ...] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple[str, ...] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: tuple[str, ...] = (),
                  buckets: tuple[float, ...] | None = None) -> Histogram:
        return self._get_or_create(
            Histogram, name, help, labelnames, buckets=buckets
        )

    def register_collector(self, fn: Callable[[], None]) -> None:
        """`fn` runs at every scrape, before rendering — the bridge for
        counters kept as plain attributes on hot paths."""
        with self._lock:
            self._collectors.append(fn)

    def get(self, name: str) -> _Metric | None:
        with self._lock:
            return self._metrics.get(name)

    def collect(self) -> None:
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            fn()

    def render_prometheus(self) -> str:
        """The text exposition format, one scrape: runs collectors, then
        renders every instrument with HELP/TYPE headers."""
        self.collect()
        with self._lock:
            metrics = sorted(self._metrics.items())
        lines: list[str] = []
        for name, m in metrics:
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            lines.extend(m.render())
        return "\n".join(lines) + "\n"


# -- exposition validation ----------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+"
    r"(?P<value>[-+]?(?:\d+\.?\d*(?:[eE][-+]?\d+)?|Inf|NaN))$"
)
_LABEL_PAIR_RE = re.compile(
    r'^[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"$'
)


def parse_prometheus(text: str) -> dict[str, list[tuple[dict, float]]]:
    """Parse (and validate) a text exposition; raises ValueError on any
    grammar violation, histogram bucket non-monotonicity, or +Inf/_count
    disagreement. Returns {metric_name: [(labels, value), ...]}."""
    out: dict[str, list[tuple[dict, float]]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: unparseable sample {line!r}")
        labels: dict[str, str] = {}
        if m.group("labels"):
            for pair in m.group("labels").split(","):
                if not _LABEL_PAIR_RE.match(pair):
                    raise ValueError(
                        f"line {lineno}: bad label pair {pair!r}"
                    )
                k, v = pair.split("=", 1)
                labels[k] = v[1:-1]
        raw = m.group("value")
        value = float(raw.replace("Inf", "inf"))
        out.setdefault(m.group("name"), []).append((labels, value))
    _check_histograms(out)
    return out


def _check_histograms(
    samples: dict[str, list[tuple[dict, float]]]
) -> None:
    for name in [n for n in samples if n.endswith("_bucket")]:
        base = name[: -len("_bucket")]
        series: dict[tuple, list[tuple[float, float]]] = {}
        for labels, value in samples[name]:
            le = labels.get("le")
            if le is None:
                raise ValueError(f"{name}: bucket sample without le")
            key = tuple(sorted(
                (k, v) for k, v in labels.items() if k != "le"
            ))
            series.setdefault(key, []).append(
                (float(le.replace("+Inf", "inf")), value)
            )
        for key, buckets in series.items():
            buckets.sort()
            counts = [c for _, c in buckets]
            if any(
                a > b for a, b in zip(counts, counts[1:])
            ):
                raise ValueError(
                    f"{base}: bucket counts not monotone at {dict(key)}"
                )
            if buckets[-1][0] != float("inf"):
                raise ValueError(f"{base}: missing +Inf bucket")
            for labels, value in samples.get(f"{base}_count", ()):
                if tuple(sorted(labels.items())) == key and (
                    value != buckets[-1][1]
                ):
                    raise ValueError(
                        f"{base}: +Inf bucket != _count at {dict(key)}"
                    )


def quantile_from_samples(values: Iterable[float], q: float) -> float:
    """Plain percentile helper (numpy-free) for the bench's overhead
    guard."""
    vs = sorted(values)
    if not vs:
        return 0.0
    idx = min(len(vs) - 1, max(0, int(round(q * (len(vs) - 1)))))
    return vs[idx]
