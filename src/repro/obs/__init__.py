"""Observability: span tracing, the unified metrics registry, and the
helpers behind EXPLAIN ANALYZE.

Zero dependencies beyond the standard library — the engine and the
serving tier import this unconditionally, so it must cost nothing when
tracing is off (every hook is guarded by `trace is not None`).
"""
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    log_buckets,
    parse_prometheus,
    quantile_from_samples,
)
from repro.obs.trace import (  # noqa: F401
    Span,
    Trace,
    Tracer,
    phase_totals,
    validate_chrome_events,
)
