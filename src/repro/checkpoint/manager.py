"""Fault-tolerant checkpointing: step-addressed, atomic, keep-k, async,
elastic-reshard restore.

Layout:  <dir>/step_{N:08d}/arrays.npz + meta.json, written to a tmp dir
and atomically renamed (a crashed writer never corrupts the latest good
step). `restore(..., shardings=...)` device_puts every leaf with the NEW
sharding, so a job restarted on a different mesh shape (elastic scaling)
resumes from the same step — the npz holds the full logical arrays.

On a real multi-host pod each host writes only its addressable shards;
here the single-process form keeps the same interface (save/restore/
latest_step/all_steps) so the trainer and tests exercise the real
protocol: write-tmp → fsync → rename → prune.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import ml_dtypes
import numpy as np


def _flatten(tree) -> tuple[list[np.ndarray], object]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _encode(a: np.ndarray) -> np.ndarray:
    """npz can't roundtrip ml_dtypes (bf16 etc.) — store as uint16 bits."""
    if a.dtype == ml_dtypes.bfloat16:
        return a.view(np.uint16)
    return a


def _decode(a: np.ndarray, like_dtype) -> np.ndarray:
    if np.dtype(like_dtype) == ml_dtypes.bfloat16:
        return a.view(ml_dtypes.bfloat16)
    return a.astype(like_dtype) if a.dtype != like_dtype else a


class CheckpointManager:
    def __init__(self, directory: str, keep_k: int = 3,
                 async_write: bool = False):
        self.dir = directory
        self.keep_k = keep_k
        self.async_write = async_write
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- paths ---------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ------------------------------------------------------------
    def save(self, step: int, tree, extra_meta: dict | None = None) -> None:
        """Blocking or async depending on construction. The tree is
        snapshotted to host BEFORE returning, so the caller may donate or
        mutate device buffers immediately."""
        self.wait()  # one writer in flight at a time
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]
        meta = {
            "step": step,
            "treedef": str(treedef),
            "time": time.time(),
            **(extra_meta or {}),
        }
        if self.async_write:
            t = threading.Thread(target=self._write, args=(step, host, meta),
                                 daemon=True)
            t.start()
            self._pending = t
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host_leaves: list[np.ndarray], meta: dict):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": _encode(a) for i, a in enumerate(host_leaves)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._prune()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_k]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def restore(self, step: int, like_tree, shardings=None):
        """Restore into the structure of `like_tree`. `shardings`: optional
        matching pytree of Shardings — enables elastic re-shard (restore
        onto a different mesh than the one that saved)."""
        d = self._step_dir(step)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            host = [z[f"a{i}"] for i in range(len(z.files))]
        leaves, treedef = _flatten(like_tree)
        assert len(leaves) == len(host), (
            f"checkpoint has {len(host)} leaves, model wants {len(leaves)}"
        )
        host = [
            _decode(h, l.dtype) if hasattr(l, "dtype") else h
            for h, l in zip(host, leaves)
        ]
        if shardings is None:
            new = [jax.numpy.asarray(h) for h in host]
        else:
            shard_leaves = treedef.flatten_up_to(shardings)
            new = [jax.device_put(h, s) for h, s in zip(host, shard_leaves)]
        return treedef.unflatten(new)

    def meta(self, step: int) -> dict:
        with open(os.path.join(self._step_dir(step), "meta.json")) as f:
            return json.load(f)
