"""schnet [arXiv:1706.08566]: n_interactions=3 d_hidden=64 rbf=300 cutoff=10."""
from repro.models.gnn.schnet import SchNetConfig

CONFIG = SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0)
FAMILY = "gnn"
