"""granite-moe-3b-a800m [hf:ibm-granite]: 32L d_model=1536 24H (GQA kv=8)
d_ff(expert)=512 vocab=49155, MoE 40 experts top-8.

NOTE: the assignment lists both "MoE 40e top-8" and "32 experts top-8"; we
take the primary field (40 experts). 40 % 16 != 0, so experts are padded to
48 on a 16-way model axis (8 dead experts, -inf router logits; see
models/moe.py docstring).
"""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="granite-moe-3b-a800m",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab=49155,
    n_experts=40,
    top_k=8,
    d_expert_ff=512,
    rope_theta=1e4,
    fsdp=False,
)
FAMILY = "lm"
