"""qwen2.5-32b [hf:Qwen]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA with QKV bias. FSDP posture (32B params)."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen2.5-32b",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=27648,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    fsdp=True,
    # §Perf: fused chunked CE — logits (B,S,V) never materialize
    ce_chunk=1024,
)
FAMILY = "lm"
