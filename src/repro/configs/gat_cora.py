"""gat-cora [arXiv:1710.10903]: 2 layers, d_hidden=8, 8 heads, attn aggregator."""
from repro.models.gnn.gat import GATConfig

CONFIG = GATConfig(n_layers=2, d_hidden=8, n_heads=8, n_classes=7, d_in=1433)
FAMILY = "gnn"
