"""graphcast [arXiv:2212.12794]: 16 processor layers, d_hidden=512,
mesh_refinement=6, sum aggregator, n_vars=227 (encoder-processor-decoder)."""
from repro.models.gnn.graphcast import GraphCastConfig

CONFIG = GraphCastConfig(n_layers=16, d_hidden=512, n_vars=227,
                         mesh_refinement=6)
FAMILY = "gnn"
