"""The paper's own workload: distributed MapReduce join over LUBM-style
dictionary-encoded relations (the 11th 'architecture')."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class MapSQConfig:
    left_schema: tuple[str, ...] = ("?x", "?y")
    right_schema: tuple[str, ...] = ("?y", "?z")
    bucket_capacity: int = 4096
    join_capacity: int = 65536


CONFIG = MapSQConfig()
FAMILY = "sparql"
