from repro.configs.registry import ARCHS, SHAPES_FOR, build_cell  # noqa: F401
