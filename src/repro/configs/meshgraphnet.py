"""meshgraphnet [arXiv:2010.03409]: 15 layers, d_hidden=128, sum aggregator,
2-layer MLPs."""
from repro.models.gnn.meshgraphnet import MGNConfig

CONFIG = MGNConfig(n_layers=15, d_hidden=128, mlp_layers=2, d_node_in=8,
                   d_edge_in=4, d_out=3)
FAMILY = "gnn"
