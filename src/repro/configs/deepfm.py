"""deepfm [arXiv:1703.04247]: n_sparse=39 embed_dim=10 mlp=400-400-400
interaction=fm. ~33.5M embedding rows (Criteo-scale), row-sharded."""
from repro.models.recsys.deepfm import DeepFMConfig

CONFIG = DeepFMConfig(n_sparse=39, embed_dim=10, mlp_dims=(400, 400, 400),
                      rows_per_field=860_000)
FAMILY = "recsys"
