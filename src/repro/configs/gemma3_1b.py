"""gemma3-1b [hf:google/gemma-3-1b-pt]: 26L d_model=1152 4H (GQA kv=1)
d_ff=6912 vocab=262144 — 5:1 local:global attention (window 512), 128k ctx,
QK-norm, tied embeddings, embed scaling."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma3-1b",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab=262144,
    sliding_window=512,
    global_every=6,  # layers 6, 12, ... are global -> 5:1 local:global
    qk_norm=True,
    rope_theta=1e6,
    rope_theta_local=1e4,
    embed_scale=True,
    tied_embeddings=True,
    fsdp=False,
)
FAMILY = "lm"
