"""olmoe-1b-7b [arXiv:2409.02060; hf]: 16L d_model=2048 16H (GQA kv=16)
d_ff(expert)=1024 vocab=50304, MoE 64 experts top-8."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1024,
    vocab=50304,
    n_experts=64,
    top_k=8,
    d_expert_ff=1024,
    rope_theta=1e4,
    fsdp=False,
)
FAMILY = "lm"
