"""deepseek-67b [arXiv:2401.02954; hf]: 95L d_model=8192 64H (GQA kv=8)
d_ff=22016 vocab=102400 — llama-arch dense. FSDP posture (67B params)."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="deepseek-67b",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=22016,
    vocab=102400,
    rope_theta=1e4,
    fsdp=True,
    # §Perf: fused chunked CE — logits (B,S,V) never materialize
    ce_chunk=1024,
)
FAMILY = "lm"
