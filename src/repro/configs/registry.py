"""Arch × shape registry: every assigned (architecture, input-shape) cell as
an abstract, shardable compute step for the dry-run, and a concrete builder
for smoke tests / examples.

`build_cell(arch, shape, mesh, multi_pod)` returns a Cell holding:
  fn            — the (un-jitted) step function,
  inputs        — pytrees of ShapeDtypeStruct WITH NamedShardings attached,
  donate        — argument indices safe to donate (params/opt or caches),
  model_flops   — 'useful' FLOPs (6·N_active·D etc.) for §Roofline ratios.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import AdamWConfig, adamw_init

ARCHS: dict[str, str] = {
    # arch id -> config module
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "schnet": "repro.configs.schnet",
    "graphcast": "repro.configs.graphcast",
    "gat-cora": "repro.configs.gat_cora",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "deepfm": "repro.configs.deepfm",
    "mapsq": "repro.configs.mapsq_lubm",
}

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}
GNN_SHAPES = {
    "full_graph_sm": dict(kind="full", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7),
    "minibatch_lg": dict(kind="minibatch", n_nodes=232_965,
                         n_edges=114_615_892, d_feat=602, n_classes=41,
                         batch_nodes=1024, fanout=(15, 10)),
    "ogb_products": dict(kind="full", n_nodes=2_449_029, n_edges=61_859_140,
                         d_feat=100, n_classes=47),
    "molecule": dict(kind="batched", n_nodes=30, n_edges=64, batch=128,
                     d_feat=16, n_classes=1),
}
RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65_536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_448),
    # n_candidates padded from 1,000,000 to the next multiple of 512 chips
}
SPARQL_SHAPES = {
    "join_1m": dict(kind="join", rows=1 << 20),
    "join_16m": dict(kind="join", rows=1 << 24),
}


def SHAPES_FOR(arch: str) -> dict[str, dict]:
    fam = importlib.import_module(ARCHS[arch]).FAMILY
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES,
            "sparql": SPARQL_SHAPES}[fam]


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Callable
    inputs: tuple
    donate: tuple[int, ...] = ()
    model_flops: float = 0.0
    note: str = ""


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _tree_sds(shapes_tree, specs_tree, mesh):
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes_tree,
        specs_tree,
    )


def _norm_spec(spec: P, ndim: int) -> list:
    dims = list(spec)
    return dims + [None] * (ndim - len(dims))


def zero1_spec(spec: P, shape: tuple[int, ...], data_size: int) -> P:
    """Add a ZeRO-1 "data" sharding on the first free, divisible dim."""
    dims = _norm_spec(spec, len(shape))
    if "data" in dims or ("data",) in dims:
        return P(*dims)
    for i, (d, s) in enumerate(zip(dims, shape)):
        if d is None and s % data_size == 0 and s >= data_size:
            dims[i] = "data"
            break
    return P(*dims)


def _opt_specs(param_specs_tree, param_shapes_tree, data_size: int):
    mv = jax.tree.map(
        lambda sp, sh: zero1_spec(sp, sh.shape, data_size),
        param_specs_tree, param_shapes_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    return {"m": mv, "v": mv, "step": P()}


def _dp(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def _all_axes(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def _round_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _mesh_sizes(mesh) -> tuple[int, int, int]:
    """(n_devices, data_size(incl pod), model_size)."""
    model = mesh.shape.get("model", 1)
    data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    return data * model, data, model


DEFAULT_OPT = AdamWConfig()


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _build_lm(arch: str, cfg, shape_name: str, sh: dict, mesh, multi_pod):
    from repro.models import transformer as T

    n_dev, data, model = _mesh_sizes(mesh)
    dp = _dp(multi_pod)
    pshapes = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg, ep=model)
    )
    pspecs = T.param_specs(cfg, multi_pod, model)
    params = _tree_sds(pshapes, pspecs, mesh)
    b, s = sh["batch"], sh["seq"]
    kind = sh["kind"]
    mflops = T.model_flops(cfg, kind, b, s, ep=model)

    if kind == "train":
        oshapes = jax.eval_shape(adamw_init, pshapes)
        ospecs = _opt_specs(pspecs, pshapes, mesh.shape.get("data", 1))
        opt = _tree_sds(oshapes, ospecs, mesh)
        batch = {
            "tokens": _sds((b, s), jnp.int32, mesh, P(dp, None)),
            "labels": _sds((b, s), jnp.int32, mesh, P(dp, None)),
        }
        fn = T.make_train_step(cfg, mesh, DEFAULT_OPT, multi_pod)
        return Cell(arch, shape_name, kind, fn, (params, opt, batch),
                    donate=(0, 1), model_flops=mflops)

    if kind == "prefill":
        tokens = _sds((b, s), jnp.int32, mesh, P(dp, None))
        fn = T.make_prefill_step(cfg, mesh, multi_pod)
        return Cell(arch, shape_name, kind, fn, (params, tokens),
                    model_flops=mflops)

    # decode: one new token against a seq-long KV cache
    cshape = (cfg.n_layers, b, s, cfg.n_kv_heads, cfg.d_head)
    if b == 1:
        cspec = P(None, None, _all_axes(multi_pod), None, None)
    else:
        cspec = P(None, dp, "model", None, None)
    kc = _sds(cshape, cfg.dtype, mesh, cspec)
    vc = _sds(cshape, cfg.dtype, mesh, cspec)
    pos = _sds((), jnp.int32, mesh, P())
    tokens = _sds((b,), jnp.int32, mesh, P(dp) if b > 1 else P())
    fn = T.make_serve_step(cfg, mesh, multi_pod)
    return Cell(arch, shape_name, kind, fn, (params, kc, vc, pos, tokens),
                donate=(1, 2), model_flops=mflops)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gnn_dims(arch: str, sh: dict, n_dev: int) -> dict:
    """Device-visible graph dims for a (gnn arch, shape) cell."""
    kind = sh["kind"]
    if kind == "minibatch":
        from repro.models.gnn.sampler import block_capacity

        n, e = block_capacity(sh["batch_nodes"], list(sh["fanout"]))
    elif kind == "batched":
        n, e = sh["n_nodes"] * sh["batch"], sh["n_edges"] * sh["batch"]
    else:
        n, e = sh["n_nodes"], sh["n_edges"]
    e = _round_to(e, 512)  # edge dim shards over up to 512 chips
    n_graphs = sh.get("batch", 1)
    # §Perf iterations 1-3 (graphcast × ogb_products): replicated node
    # tensors cost 216 GiB/chip at 2.45M nodes — infeasible. Large graphs
    # shard the node dim over EVERY mesh axis (padded to 512) and run node/
    # edge activations in bf16; XLA inserts the gather/scatter collectives.
    # See EXPERIMENTS.md §Perf for the iteration log.
    shard_nodes = n >= 1_000_000
    if shard_nodes:
        n = _round_to(n, 512)
    d = dict(n=n, e=e, n_graphs=n_graphs, d_feat=sh["d_feat"],
             n_classes=sh["n_classes"], shard_nodes=shard_nodes)
    # graphcast mesh sizes derive from the shape (DESIGN.md §6)
    d["n_mesh"] = _round_to(max(8, n // 4), 512 if shard_nodes else 1)
    d["e_mesh"] = _round_to(max(64, d["n_mesh"] * 7), 512)
    return d


def _gnn_extras_specs(arch: str, dims: dict, mesh, espec, nspec):
    f4 = jnp.float32
    n, e = dims["n"], dims["e"]
    mspec = nspec  # mesh-node arrays follow the node sharding policy
    if arch == "gat-cora":
        return {
            "labels": _sds((n,), jnp.int32, mesh, nspec),
            "train_mask": _sds((n,), jnp.bool_, mesh, nspec),
        }
    if arch == "schnet":
        ng = dims["n_graphs"]
        return {
            "positions": _sds((n, 3), f4, mesh, nspec),
            "species": _sds((n,), jnp.int32, mesh, nspec),
            "energy": _sds((ng,), f4, mesh, P()),
            "graph_mask": _sds((ng,), jnp.bool_, mesh, P()),
        }
    if arch == "meshgraphnet":
        return {
            "edge_feat": _sds((e, 4), f4, mesh, espec),
            "targets": _sds((n, 3), f4, mesh, nspec),
        }
    if arch == "graphcast":
        nm, em = dims["n_mesh"], dims["e_mesh"]
        return {
            "mesh_feat_init": _sds((nm, 1), f4, mesh, mspec),
            "g2m_feat": _sds((e, 4), f4, mesh, espec),
            "mesh_edge_feat": _sds((em, 4), f4, mesh, espec),
            "mesh_src": _sds((em,), jnp.int32, mesh, espec),
            "mesh_dst": _sds((em,), jnp.int32, mesh, espec),
            "mesh_mask": _sds((em,), jnp.bool_, mesh, espec),
            "m2g_feat": _sds((e, 4), f4, mesh, espec),
            "m2g_src": _sds((e,), jnp.int32, mesh, espec),
            "m2g_dst": _sds((e,), jnp.int32, mesh, espec),
            "m2g_mask": _sds((e,), jnp.bool_, mesh, espec),
            "targets": _sds((n, 227), f4, mesh, nspec),
        }
    raise KeyError(arch)


def _gnn_module(arch: str):
    from repro.models.gnn import gat, graphcast, meshgraphnet, schnet

    return {"gat-cora": gat, "schnet": schnet, "meshgraphnet": meshgraphnet,
            "graphcast": graphcast}[arch]


def _gnn_node_feat_dim(arch: str, cfg, dims: dict) -> int:
    if arch == "graphcast":
        return cfg.n_vars
    if arch == "schnet":
        return 1  # schnet reads species/positions from extras
    return dims["d_feat"]


def _gnn_cfg_for_shape(arch: str, cfg, dims: dict, multi_pod: bool = False):
    """Bind per-shape input dims into the arch config."""
    if arch == "gat-cora":
        cfg = dataclasses.replace(cfg, d_in=dims["d_feat"],
                                  n_classes=dims["n_classes"])
    if arch == "meshgraphnet":
        cfg = dataclasses.replace(cfg, d_node_in=dims["d_feat"])
    if dims.get("shard_nodes") and hasattr(cfg, "node_spec"):
        # §Perf iterations 1-5: node dim sharded over every axis, blocks
        # remat'd, activations bf16, gathers/scatters via the MapSQ shuffle,
        # one-shot edge sets streamed (graphcast only)
        extra = {}
        if hasattr(cfg, "edge_stream_chunks"):
            extra["edge_stream_chunks"] = 16
        cfg = dataclasses.replace(cfg, node_spec=_all_axes(multi_pod),
                                  remat=True, compute_dtype=jnp.bfloat16,
                                  shuffle_gather=True, **extra)
    return cfg


def _build_gnn(arch: str, cfg, shape_name: str, sh: dict, mesh, multi_pod):
    from repro.models.gnn.common import GraphBatch

    n_dev, data, model = _mesh_sizes(mesh)
    dims = _gnn_dims(arch, sh, n_dev)
    cfg = _gnn_cfg_for_shape(arch, cfg, dims, multi_pod)
    mod = _gnn_module(arch)
    espec = P(_all_axes(multi_pod))  # edges shard over every axis
    # small graphs: node tables replicated (psum aggregation);
    # large graphs: node dim sharded over every axis (§Perf iterations 1-3)
    nspec = P(_all_axes(multi_pod)) if dims["shard_nodes"] else P()
    n, e = dims["n"], dims["e"]
    g = GraphBatch(
        node_feat=_sds((n, _gnn_node_feat_dim(arch, cfg, dims)), jnp.float32,
                       mesh, nspec),
        src=_sds((e,), jnp.int32, mesh, espec),
        dst=_sds((e,), jnp.int32, mesh, espec),
        node_mask=_sds((n,), jnp.bool_, mesh, nspec),
        edge_mask=_sds((e,), jnp.bool_, mesh, espec),
        graph_ids=_sds((n,), jnp.int32, mesh, nspec),
        extras=_gnn_extras_specs(arch, dims, mesh, espec, nspec),
    )
    pshapes = jax.eval_shape(
        lambda: mod.init_params(jax.random.PRNGKey(0), cfg)
    )
    pspecs = jax.tree.map(lambda _: P(), pshapes)
    params = _tree_sds(pshapes, pspecs, mesh)
    oshapes = jax.eval_shape(adamw_init, pshapes)
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    opt = _tree_sds(oshapes, ospecs, mesh)

    opt_cfg = DEFAULT_OPT

    def train_step(params, opt_state, graph):
        from repro.optim.adamw import adamw_update

        grads = jax.grad(mod.loss_fn)(params, graph, cfg)
        new_p, new_s, m = adamw_update(opt_cfg, grads, opt_state, params)
        return new_p, new_s, m

    mflops = _gnn_model_flops(arch, cfg, dims)
    return Cell(arch, shape_name, "train", train_step, (params, opt, g),
                donate=(0, 1), model_flops=mflops)


def _gnn_model_flops(arch: str, cfg, dims: dict) -> float:
    n, e = dims["n"], dims["e"]
    if arch == "gat-cora":
        d_in, h, d = cfg.d_in, cfg.n_heads, cfg.d_hidden
        fwd = 2 * n * d_in * h * d + 6 * e * h * d
        fwd += 2 * n * (h * d) * cfg.n_classes + 6 * e * cfg.n_classes
    elif arch == "schnet":
        d, r = cfg.d_hidden, cfg.n_rbf
        per = 2 * e * (r * d + d * d) + 2 * e * d + 6 * n * d * d
        fwd = cfg.n_interactions * per + 2 * n * d * d
    elif arch == "meshgraphnet":
        d = cfg.d_hidden
        per = 2 * e * (3 * d + d) * d + 2 * n * (2 * d + d) * d
        fwd = cfg.n_layers * per + 2 * n * cfg.d_node_in * d + 2 * e * 4 * d
    else:  # graphcast
        d = cfg.d_hidden
        nm, em = dims["n_mesh"], dims["e_mesh"]
        blk = lambda ee, nn: 2 * ee * (3 * d + d) * d + 2 * nn * (2 * d + d) * d
        fwd = (2 * n * cfg.n_vars * d + blk(e, nm)
               + cfg.n_layers * blk(em, nm) + blk(e, n)
               + 2 * n * d * cfg.n_vars)
    return 3.0 * fwd  # train = fwd + bwd(2x)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _build_recsys(arch: str, cfg, shape_name: str, sh: dict, mesh, multi_pod):
    from repro.models.recsys import deepfm as D

    n_dev, data, model = _mesh_sizes(mesh)
    dp = _dp(multi_pod)
    pshapes = jax.eval_shape(
        lambda: D.init_params(jax.random.PRNGKey(0), cfg)
    )
    pspecs = D.param_specs(cfg)
    params = _tree_sds(pshapes, pspecs, mesh)
    b = sh["batch"]
    kind = sh["kind"]
    ids_spec = P(dp, None)

    def make_lookup(n_flat):
        cap = max(64, _round_to(int(n_flat // n_dev // model *
                                    cfg.shuffle_capacity_factor) + 8, 8))
        return D.make_sharded_lookup(mesh, dp, cap)

    mlp_flops = 2 * sum(
        a * b2 for a, b2 in zip(
            (cfg.n_sparse * cfg.embed_dim,) + cfg.mlp_dims,
            cfg.mlp_dims + (1,))
    )
    fm_flops = 4 * cfg.n_sparse * cfg.embed_dim
    fwd = b * (mlp_flops + fm_flops)

    if kind == "train":
        lookup = make_lookup(b * cfg.n_sparse)
        oshapes = jax.eval_shape(adamw_init, pshapes)
        ospecs = _opt_specs(pspecs, pshapes, mesh.shape.get("data", 1))
        opt = _tree_sds(oshapes, ospecs, mesh)
        batch = {
            "ids": _sds((b, cfg.n_sparse), jnp.int32, mesh, ids_spec),
            "labels": _sds((b,), jnp.float32, mesh, P(dp)),
        }

        def train_step(params, opt_state, batch):
            from repro.optim.adamw import adamw_update

            grads = jax.grad(D.bce_loss)(params, batch["ids"],
                                         batch["labels"], cfg, lookup)
            new_p, new_s, m = adamw_update(DEFAULT_OPT, grads, opt_state,
                                           params)
            return new_p, new_s, m

        return Cell(arch, shape_name, kind, train_step,
                    (params, opt, batch), donate=(0, 1),
                    model_flops=3.0 * fwd)

    if kind == "serve":
        lookup = make_lookup(b * cfg.n_sparse)

        def serve(params, ids):
            return jax.nn.sigmoid(D.forward(params, ids, cfg, lookup))

        ids = _sds((b, cfg.n_sparse), jnp.int32, mesh, ids_spec)
        return Cell(arch, shape_name, kind, serve, (params, ids),
                    model_flops=fwd)

    # retrieval: 1 query x n_candidates batched dot
    nc = sh["n_candidates"]
    lookup = make_lookup(nc * cfg.n_item_fields)

    def retrieve(params, user_ids, cand_ids):
        return D.retrieval_scores(params, user_ids, cand_ids, cfg, lookup)

    user = _sds((1, cfg.n_sparse), jnp.int32, mesh, P())
    cand = _sds((nc, cfg.n_item_fields), jnp.int32, mesh,
                P(_all_axes(multi_pod), None))
    r_flops = nc * (cfg.n_item_fields + 1) * cfg.embed_dim * 2
    return Cell(arch, shape_name, kind, retrieve, (params, user, cand),
                model_flops=r_flops)


# ---------------------------------------------------------------------------
# SPARQL (the paper's own workload) cells
# ---------------------------------------------------------------------------

def _build_sparql(arch: str, cfg, shape_name: str, sh: dict, mesh, multi_pod):
    from repro.core.distributed import make_distributed_join_fn
    from repro.core.relation import Relation

    n_dev, data, model = _mesh_sizes(mesh)
    axes = _all_axes(multi_pod)
    rows = sh["rows"]
    rows_local = rows // n_dev
    # §Perf iteration (mapsq): per-destination bucket capacity sized to the
    # expected rows/destination x2 skew headroom (was rows_local*2 — a 16x
    # overallocation that made every stage's working set axis_size x cap).
    max_axis = max(mesh.shape.values())
    bucket_cap = max(64, _round_to(int(rows_local / max_axis * 2) + 8, 8))
    join_cap = _round_to(rows_local * 4, 8)
    fn = make_distributed_join_fn(mesh, axes, bucket_cap, join_cap,
                                  cfg.left_schema, cfg.right_schema)
    spec_rows = P(axes, None)
    spec_valid = P(axes)
    mk = lambda schema: Relation(
        schema,
        _sds((rows, len(schema)), jnp.int32, mesh, spec_rows),
        _sds((rows,), jnp.bool_, mesh, spec_valid),
    )
    left = mk(cfg.left_schema)
    right = mk(cfg.right_schema)
    # 'useful work': the sort (n log n compares) + output materialization
    mflops = 2 * rows * math.log2(max(rows, 2)) + 3 * rows
    return Cell(arch, shape_name, "join", fn, (left, right),
                model_flops=mflops)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape: str, mesh, multi_pod: bool,
               n_layers: int | None = None) -> Cell:
    """`n_layers` overrides the LM layer count — used by the dry-run's
    differential cost extraction (XLA cost_analysis counts a scanned layer
    body ONCE; compiling L=2 and L=4 and extrapolating recovers the true
    affine cost terms flops(L) = a + b·L)."""
    mod = importlib.import_module(ARCHS[arch])
    cfg, fam = mod.CONFIG, mod.FAMILY
    if n_layers is not None and fam == "lm":
        # probe configs unroll the scan so cost_analysis sees every layer
        cfg = dataclasses.replace(cfg, n_layers=n_layers, scan_unroll=True)
    sh = SHAPES_FOR(arch)[shape]
    builder = {"lm": _build_lm, "gnn": _build_gnn, "recsys": _build_recsys,
               "sparql": _build_sparql}[fam]
    return builder(arch, cfg, shape, sh, mesh, multi_pod)


def family_of(arch: str) -> str:
    return importlib.import_module(ARCHS[arch]).FAMILY


def lm_layer_count(arch: str) -> int:
    return importlib.import_module(ARCHS[arch]).CONFIG.n_layers
