"""AdamW with global-norm clipping and a linear-warmup cosine schedule.

Written against the sharding posture of the trainer: m/v mirror the param
pytree, and the launcher gives them ZeRO-1 specs (additionally sharded over
the `data` axis) so the fp32 optimizer state never replicates across data
parallel ranks — GSPMD then materializes the classic reduce-scatter(grads)
→ sharded update → all-gather(params) schedule automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # Cross-replica gradient compression: cast grads to bf16 before the
    # optimizer sees them (halves reduce-scatter bytes; update math stays f32).
    grad_compression_bf16: bool = True


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * jnp.clip(prog, 0.0, 1.0))
    )
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def adamw_update(cfg: AdamWConfig, grads: Any, state: dict, params: Any):
    """Returns (new_params, new_state, metrics). Param dtype is preserved
    (bf16 params get f32 update math, then cast back)."""
    if cfg.grad_compression_bf16:
        grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1**t
    bc2 = 1 - cfg.b2**t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
