"""Version-portable mesh / shard_map API.

The repo targets the modern jax surface (`jax.shard_map`, `jax.set_mesh`,
`check_vma`), but CI and local images may carry older releases where the
same machinery lives under `jax.experimental.shard_map` (with the
`check_rep` spelling) and the mesh context is entered by using the Mesh
object itself as a context manager. Everything that touches a mesh goes
through these two helpers so a jax upgrade/downgrade is a no-op for the
rest of the codebase.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh=None, in_specs=None, out_specs=None,
              check_vma: bool = False):
    """`jax.shard_map` where available, else the experimental spelling
    (whose `check_rep` flag is the old name for `check_vma`).

    `mesh=None` means "the ambient mesh" — supported natively by modern
    jax; on older releases it is resolved eagerly from the mesh context
    entered via `set_mesh` (so the context must be active when the mapped
    function is built, which every caller here satisfies)."""
    if hasattr(jax, "shard_map"):
        kwargs = {} if mesh is None else {"mesh": mesh}
        return jax.shard_map(
            f,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=check_vma,
            **kwargs,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    if mesh is None:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            raise ValueError(
                "shard_map without an explicit mesh needs an active mesh "
                "context (use repro.core.compat.set_mesh)"
            )
    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=check_vma,
    )


def axis_size(axis_name) -> int:
    """Static size of a mapped mesh axis, from inside shard_map.

    `jax.lax.axis_size` where available; older jax gets the same constant
    from `psum(1, axis)` (a sum of the unmapped literal 1 folds to the
    axis size at trace time)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def ambient_mesh():
    """The mesh made ambient by `set_mesh` (abstract on modern jax, the
    physical mesh entered as a context on older releases)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources

    return thread_resources.env.physical_mesh


def set_mesh(mesh):
    """Context manager making `mesh` the ambient mesh (so bare
    PartitionSpecs in `with_sharding_constraint` resolve against it).
    Older jax enters the context via the Mesh object itself."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
