"""BGP join-order planner — the paper's "CPU assigns subqueries" half.

The coprocessing strategy of MapSQ puts query planning on the CPU and join
execution on the accelerator. Here the host picks a left-deep join order by
greedy estimated cardinality (smallest pattern first, then the connected
pattern minimising the estimated intermediate size), and the device executes
the resulting chain of MapReduce joins.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence


@dataclasses.dataclass(frozen=True)
class TriplePattern:
    s: str  # variable "?x" or constant term
    p: str
    o: str

    def variables(self) -> tuple[str, ...]:
        return tuple(t for t in (self.s, self.p, self.o) if t.startswith("?"))

    def constants(self) -> tuple[tuple[str, str], ...]:
        out = []
        for pos, t in zip("spo", (self.s, self.p, self.o)):
            if not t.startswith("?"):
                out.append((pos, t))
        return tuple(out)


@dataclasses.dataclass
class JoinStep:
    pattern_index: int  # index into the BGP's pattern list
    key_vars: tuple[str, ...]  # join variables with the accumulated result
    is_cross: bool


def plan_bgp(
    patterns: Sequence[TriplePattern],
    cardinality: Callable[[TriplePattern], float],
) -> list[JoinStep]:
    """Greedy left-deep plan. `cardinality` estimates pattern match counts.

    Heuristic: start from the most selective pattern; repeatedly add the
    connected pattern with the smallest estimated cardinality (ties broken
    by more shared variables = more selective join). Disconnected components
    fall back to cross joins, taken last.
    """
    remaining = list(range(len(patterns)))
    remaining.sort(key=lambda i: cardinality(patterns[i]))
    first = remaining.pop(0)
    steps = [JoinStep(first, (), False)]
    bound: set[str] = set(patterns[first].variables())
    while remaining:
        connected = [
            i for i in remaining if set(patterns[i].variables()) & bound
        ]
        if connected:
            nxt = min(
                connected,
                key=lambda i: (
                    cardinality(patterns[i]),
                    -len(set(patterns[i].variables()) & bound),
                ),
            )
            key_vars = tuple(
                v for v in patterns[nxt].variables() if v in bound
            )
            steps.append(JoinStep(nxt, key_vars, False))
        else:
            # disconnected component: cross join. Pick the smallest pattern
            # by estimated cardinality (not input order) so the product
            # capacity of the cross-join intermediate stays minimal.
            nxt = min(remaining, key=lambda i: cardinality(patterns[i]))
            steps.append(JoinStep(nxt, (), True))
        bound |= set(patterns[nxt].variables())
        remaining.remove(nxt)
    return steps
