"""Algorithm 1 of MapSQ: the MapReduce-based join, TPU-native.

Three phases, exactly as the paper structures them:

  Map             — split every tuple into (key, value); tag side. Invalid
                    (padding) rows are mapped to per-side sentinel keys so
                    they can never join (the LEFT/RIGHT flag's purpose —
                    "reduce unnecessary computation" — achieved structurally).
  Sort            — sort both sides by key (the shuffle). On TPU this is a
                    bitonic network (see kernels/bitonic_sort); here we use
                    XLA's sort, which lowers to the same thing.
  ReduceDuplicate — per key group, emit the cartesian product of LEFT values
                    with RIGHT values. Realised as: per-left-row match counts
                    via binary search, prefix sum, then a dense inverse-
                    prefix-sum gather (kernels/pair_expand) — one output
                    element per lane, perfectly load balanced.

Dynamic result size is handled Mars-style: a count pass returns the exact
total; the expand pass fills a static-capacity buffer with a validity mask.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.relation import (
    INVALID_LEFT,
    INVALID_RIGHT,
    UNBOUND,
    Relation,
    shared_vars,
)
from repro.core.segments import dense_rank_two_sided


class JoinPlanArrays(NamedTuple):
    """Sorted intermediates shared by the count and expand passes."""

    order_l: jax.Array  # (n_l,) permutation sorting left by key
    order_r: jax.Array  # (n_r,) permutation sorting right by key
    lo: jax.Array  # (n_l,) first matching right slot per sorted-left row
    counts: jax.Array  # (n_l,) number of right matches per sorted-left row
    prefix: jax.Array  # (n_l,) inclusive prefix sum of counts
    total: jax.Array  # () int32 exact number of join results


def _map_phase(left: Relation, right: Relation, key_vars: list[str]):
    """Map: extract key columns, tag sides via sentinels on invalid rows."""
    lk = jnp.stack([left.column(v) for v in key_vars], axis=1)
    rk = jnp.stack([right.column(v) for v in key_vars], axis=1)
    lk = jnp.where(left.valid[:, None], lk, INVALID_LEFT)
    rk = jnp.where(right.valid[:, None], rk, INVALID_RIGHT)
    if len(key_vars) == 1:
        return lk[:, 0], rk[:, 0]
    # Multi-variable join: dense-rank tuples jointly so binary search works
    # on a single int32 key. Sentinel rows keep never-equal ranks.
    return dense_rank_two_sided(lk, rk)


def _sort_count_phase(l_key: jax.Array, r_key: jax.Array) -> JoinPlanArrays:
    """Sort + the counting half of ReduceDuplicate (Mars pass 1)."""
    order_l = jnp.argsort(l_key)
    order_r = jnp.argsort(r_key)
    lk_sorted = l_key[order_l]
    rk_sorted = r_key[order_r]
    lo = jnp.searchsorted(rk_sorted, lk_sorted, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(rk_sorted, lk_sorted, side="right").astype(jnp.int32)
    counts = hi - lo
    prefix = jnp.cumsum(counts, dtype=jnp.int32)
    total = prefix[-1] if counts.shape[0] else jnp.int32(0)
    return JoinPlanArrays(order_l, order_r, lo, counts, prefix, total)


def expand_pairs_jnp(plan: JoinPlanArrays, capacity: int):
    """Inverse-prefix-sum expansion (pure-jnp reference path).

    For output slot t: left sorted-row i = first index with prefix[i] > t,
    offset within the group = t - (prefix[i] - counts[i]), right sorted-row
    j = lo[i] + offset. This is the dense, branch-free form of the paper's
    per-key cartesian product.
    """
    t = jnp.arange(capacity, dtype=jnp.int32)
    i = jnp.searchsorted(plan.prefix, t, side="right").astype(jnp.int32)
    i_c = jnp.minimum(i, plan.counts.shape[0] - 1)
    start = plan.prefix[i_c] - plan.counts[i_c]
    j = plan.lo[i_c] + (t - start)
    valid = t < plan.total
    li = plan.order_l[i_c]
    rj = plan.order_r[jnp.clip(j, 0, plan.order_r.shape[0] - 1)]
    return li, rj, valid


def expand_pairs(plan: JoinPlanArrays, capacity: int, use_kernel: bool = False):
    if use_kernel:
        from repro.kernels.pair_expand import ops as pe_ops

        i, off, valid = pe_ops.pair_expand(plan.prefix, plan.counts, capacity)
        j = plan.lo[i] + off
        li = plan.order_l[i]
        rj = plan.order_r[jnp.clip(j, 0, plan.order_r.shape[0] - 1)]
        return li, rj, valid
    return expand_pairs_jnp(plan, capacity)


def mr_join_plan(left: Relation, right: Relation) -> tuple[JoinPlanArrays, list[str]]:
    key_vars = shared_vars(left, right)
    if not key_vars:
        raise ValueError(
            f"cross join between {left.schema} and {right.schema}; use cross_join()"
        )
    l_key, r_key = _map_phase(left, right, key_vars)
    return _sort_count_phase(l_key, r_key), key_vars


def mr_join_count(left: Relation, right: Relation) -> jax.Array:
    """Mars pass 1: the exact result cardinality (jit-able, O(n log n))."""
    plan, _ = mr_join_plan(left, right)
    return plan.total


def mr_join(
    left: Relation,
    right: Relation,
    capacity: int,
    use_kernel: bool = False,
) -> tuple[Relation, jax.Array, jax.Array]:
    """Full Algorithm 1. Returns (result, exact_total, overflowed).

    Output schema: all left vars, then right vars not already bound.
    `capacity` is static; rows past `exact_total` are masked invalid. If
    exact_total > capacity the result is truncated and overflowed=True —
    the eager engine re-runs with a larger capacity (Mars two-pass).
    """
    plan, key_vars = mr_join_plan(left, right)
    li, rj, valid = expand_pairs(plan, capacity, use_kernel=use_kernel)
    right_extra = [v for v in right.schema if v not in left.schema]
    out_schema = tuple(left.schema) + tuple(right_extra)
    l_cols = left.cols[li]
    r_cols = (
        right.project(right_extra).cols[rj]
        if right_extra
        else jnp.zeros((capacity, 0), jnp.int32)
    )
    cols = jnp.concatenate([l_cols, r_cols], axis=1)
    cols = jnp.where(valid[:, None], cols, 0)
    overflowed = plan.total > capacity
    return Relation(out_schema, cols, valid), plan.total, overflowed


def left_join(
    left: Relation,
    right: Relation,
    capacity: int,
    use_kernel: bool = False,
) -> tuple[Relation, jax.Array, jax.Array]:
    """OPTIONAL as Algorithm 1 plus unmatched-left padding.

    The first `capacity` output slots hold the inner-join result; the
    trailing `left.capacity` slots hold the left rows with no right match,
    their right-only columns set to the UNBOUND sentinel (so the padding
    part can never overflow). Returns (result, join_total, join_overflowed)
    where the total/overflow describe only the inner-join part — that is
    the bucket the engine calibrates and grows.
    """
    plan, _ = mr_join_plan(left, right)
    li, rj, valid = expand_pairs(plan, capacity, use_kernel=use_kernel)
    right_extra = [v for v in right.schema if v not in left.schema]
    out_schema = tuple(left.schema) + tuple(right_extra)
    l_cols = left.cols[li]
    r_cols = (
        right.project(right_extra).cols[rj]
        if right_extra
        else jnp.zeros((capacity, 0), jnp.int32)
    )
    join_cols = jnp.where(
        valid[:, None], jnp.concatenate([l_cols, r_cols], axis=1), 0
    )
    # unmatched-left padding (the semijoin mask, inverted)
    unmatched = left.valid & ~_matched_left_mask(plan, left)
    pad = jnp.full((left.capacity, len(right_extra)), UNBOUND, jnp.int32)
    pad_cols = jnp.concatenate([left.cols, pad], axis=1)
    cols = jnp.concatenate([join_cols, pad_cols], axis=0)
    valid_all = jnp.concatenate([valid, unmatched])
    overflowed = plan.total > capacity
    return Relation(out_schema, cols, valid_all), plan.total, overflowed


def cross_join(
    left: Relation, right: Relation, capacity: int
) -> tuple[Relation, jax.Array, jax.Array]:
    """Cartesian product for disconnected BGP components (no shared vars)."""
    n_r = right.capacity
    t = jnp.arange(capacity, dtype=jnp.int32)
    li, rj = t // n_r, t % n_r
    valid = left.valid[li] & right.valid[rj] & (t < left.capacity * n_r)
    cols = jnp.concatenate([left.cols[li], right.cols[rj]], axis=1)
    total = left.count() * right.count()
    # totals are exact but positions are not compacted: mask handles padding
    # interleaved with real rows; compact() can be applied afterwards.
    out = Relation(tuple(left.schema) + tuple(right.schema), cols, valid)
    return out, total, total > capacity


def compact(rel: Relation) -> Relation:
    """Stable-move valid rows to the front (static-shape compaction)."""
    order = jnp.argsort(~rel.valid, stable=True)
    return Relation(rel.schema, rel.cols[order], rel.valid[order])


def distinct(rel: Relation) -> Relation:
    """Mask duplicate rows (used for SELECT DISTINCT / projections)."""
    # Sort rows lexicographically with validity as the final tiebreak so all
    # valid copies of a row are adjacent and precede invalid (padding) copies.
    keys = ((~rel.valid).astype(jnp.int32),) + tuple(
        rel.cols[:, c] for c in reversed(range(rel.n_cols))
    )
    perm = jnp.lexsort(keys)
    cols_s = rel.cols[perm]
    valid_s = rel.valid[perm]
    same_as_prev = jnp.all(cols_s == jnp.roll(cols_s, 1, axis=0), axis=1)
    same_as_prev = same_as_prev.at[0].set(False)
    prev_valid = jnp.roll(valid_s, 1).at[0].set(False)
    keep = valid_s & ~(same_as_prev & prev_valid)
    inv = jnp.zeros_like(perm).at[perm].set(jnp.arange(perm.shape[0]))
    return Relation(rel.schema, rel.cols, keep[inv])


def _matched_left_mask(plan: JoinPlanArrays, left: Relation) -> jax.Array:
    """valid mask of left rows having >=1 right match, in buffer order
    (shared by semijoin_mask and left_join's unmatched padding)."""
    has = plan.counts > 0
    in_sorted_order = jnp.zeros(left.capacity, bool).at[plan.order_l].set(has)
    return left.valid & in_sorted_order


def semijoin_mask(left: Relation, right: Relation) -> jax.Array:
    """valid mask of left rows having >=1 match in right (for FILTER EXISTS)."""
    plan, _ = mr_join_plan(left, right)
    return _matched_left_mask(plan, left)


# -- FILTER masks and LIMIT/OFFSET (device-side, jit-able) -------------------

_NUMERIC_CMP = {
    "=": jnp.equal,
    "!=": jnp.not_equal,
    "<": jnp.less,
    "<=": jnp.less_equal,
    ">": jnp.greater,
    ">=": jnp.greater_equal,
}


def _numeric_of(col: jax.Array, num_vals: jax.Array) -> jax.Array:
    """Gather per-row numeric values; UNBOUND/non-numeric terms become NaN."""
    safe = jnp.clip(col, 0, num_vals.shape[0] - 1)
    return jnp.where(col >= 0, num_vals[safe], jnp.nan)


def _compare_mask(
    rel: Relation,
    lhs: str,
    op: str,
    kind: str,
    ref,
    consts_i: jax.Array,
    consts_f: jax.Array,
    num_vals: jax.Array,
) -> jax.Array:
    """One comparison as a boolean mask (validity handled by the caller).

      kind "var" — rhs is the variable named `ref`;
      kind "id"  — rhs is the term id `consts_i[ref]` (= / != by identity);
      kind "num" — rhs is the float `consts_f[ref]` (compared by value via
                   the dictionary's numeric table).
    SPARQL error semantics: an unbound operand, or a non-numeric term under
    a numeric comparison, fails the comparison — even for `!=`. With only
    `&&`/`||` above (no negation), error-as-false composes exactly like
    three-valued logic would.
    """
    a = rel.column(lhs)
    if kind == "num" or (kind == "var" and op in ("<", "<=", ">", ">=")):
        va = _numeric_of(a, num_vals)
        vb = (
            _numeric_of(rel.column(ref), num_vals)
            if kind == "var"
            else consts_f[ref]
        )
        ok = ~jnp.isnan(va) & ~jnp.isnan(vb)
        return ok & _NUMERIC_CMP[op](va, vb)
    # term-identity comparison (= / != on ids)
    b = rel.column(ref) if kind == "var" else consts_i[ref]
    bound = a != UNBOUND
    if kind == "var":
        bound = bound & (b != UNBOUND)
    eq = a == b
    return bound & (eq if op == "=" else ~eq)


def expr_mask(
    rel: Relation,
    expr: tuple,
    consts_i: jax.Array,
    consts_f: jax.Array,
    num_vals: jax.Array,
) -> jax.Array:
    """A plan_ir.FilterExpr as a composed device mask: comparisons at the
    leaves, `&`/`|` over ("and", ...) / ("or", ...) nodes."""
    tag = expr[0]
    if tag == "cmp":
        _, lhs, op, kind, ref = expr
        return _compare_mask(
            rel, lhs, op, kind, ref, consts_i, consts_f, num_vals
        )
    masks = [
        expr_mask(rel, c, consts_i, consts_f, num_vals) for c in expr[1]
    ]
    out = masks[0]
    for m in masks[1:]:
        out = (out & m) if tag == "and" else (out | m)
    return out


def filter_mask(
    rel: Relation,
    conds: tuple,
    consts_i: jax.Array,
    consts_f: jax.Array,
    num_vals: jax.Array,
) -> jax.Array:
    """Conjunction of filter expressions as a validity mask."""
    keep = rel.valid
    for expr in conds:
        keep = keep & expr_mask(rel, expr, consts_i, consts_f, num_vals)
    return keep


def union_all(rels: list[Relation], schema: tuple[str, ...]) -> Relation:
    """SPARQL UNION: multiset concatenation over an aligned schema.

    Columns a branch does not bind are filled with the UNBOUND sentinel
    (the decoder omits them; FILTER masks treat them as errors). Output
    capacity is the exact sum of branch capacities — never overflows.
    Duplicate solutions are preserved (multiset semantics); SELECT
    DISTINCT on top reuses the device `distinct` machinery to dedup.
    """
    cols_parts = []
    valid_parts = []
    for rel in rels:
        cols = [
            rel.column(v)
            if v in rel.schema
            else jnp.full((rel.capacity,), UNBOUND, jnp.int32)
            for v in schema
        ]
        cols_parts.append(jnp.stack(cols, axis=1))
        valid_parts.append(rel.valid)
    return Relation(
        tuple(schema),
        jnp.concatenate(cols_parts, axis=0),
        jnp.concatenate(valid_parts, axis=0),
    )


def slice_valid(rel: Relation, offset, limit) -> Relation:
    """LIMIT/OFFSET over the valid rows, in buffer order.

    `offset`/`limit` may be traced int scalars, so one compiled program
    serves every (offset, limit) combination of the same plan shape.
    """
    rank = jnp.cumsum(rel.valid.astype(jnp.int32))
    keep = rel.valid & (rank > offset) & (rank <= offset + limit)
    return Relation(rel.schema, rel.cols, keep)
