"""Physical-plan IR for the MapSQ join chain.

The planner (core/planner.py) decides the join ORDER; this module turns that
order into a *physical* plan — a tree of frozen, hashable nodes (Scan /
MRJoin / CrossJoin / LeftJoin / Filter / Project / Distinct / Slice) whose
static capacities are the shapes a compiled executor is specialised on
(core/executor.py lowers the tree to one jitted device program).

Three properties make plans reusable across queries, which is the whole
point of the plan/compile cache in sparql/engine.py:

  * capacity bucketing — every capacity is quantised to a pow-2 bucket with
    a floor (`bucket_capacity`), so near-miss result sizes land on the same
    static shape instead of forcing a recompile per query;
  * variable canonicalisation — variable names are renamed ?c0, ?c1, ... in
    plan order (`canonical_renaming`), so two queries that differ only in
    variable spelling (or in the constants inside their patterns — those
    live in the scan *data*, not the plan) share one compiled program;
  * runtime constants — FILTER comparison constants and LIMIT/OFFSET values
    are NOT part of the plan: they are passed to the compiled program as
    int/float input arrays (FilterCond stores an *index* into them), so
    queries differing only in a filter constant or a limit share one
    executable too.

`PlanShape` is the hashable cache key: scan schemas + scan buckets + join
structure (required chain plus OPTIONAL group specs) + filter structure +
projection + distinct + slice presence. `build_plan(shape, join_caps)`
fills in the per-join bucket capacities (learned from the calibration run
or grown by the overflow-retry fallback) and yields the node tree.
"""
from __future__ import annotations

import dataclasses
from typing import Union

# Pow-2 bucket floor: tiny relations all share the same smallest shape.
MIN_BUCKET = 8

# FILTER comparisons: (lhs_var, op, kind, ref) where kind is
#   "var" — ref is the rhs variable name;
#   "id"  — ref indexes the int runtime-constants array (term identity);
#   "num" — ref indexes the float runtime-constants array (numeric value).
FilterCond = tuple[str, str, str, Union[str, int]]


def next_pow2(n: int) -> int:
    return 1 << max(0, (max(1, n) - 1).bit_length())


def bucket_capacity(n: int, floor: int = MIN_BUCKET) -> int:
    """Quantise a row count to its static capacity bucket (pow-2, floored)."""
    return max(floor, next_pow2(int(n)))


# -- plan nodes --------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scan:
    """A partial-match relation, fed in as executor input `scans[index]`."""

    index: int
    schema: tuple[str, ...]
    capacity: int


@dataclasses.dataclass(frozen=True)
class MRJoin:
    """Algorithm-1 MapReduce join at a static output capacity."""

    left: "PlanNode"
    right: "PlanNode"
    key_vars: tuple[str, ...]
    schema: tuple[str, ...]
    capacity: int


@dataclasses.dataclass(frozen=True)
class CrossJoin:
    """Cartesian product for disconnected BGP components.

    Capacity is always the full left×right product: cross_join enumerates
    pair POSITIONS, so a smaller capacity could silently drop valid pairs
    (unlike MRJoin, whose overflow flag is exact).
    """

    left: "PlanNode"
    right: "PlanNode"
    schema: tuple[str, ...]
    capacity: int


@dataclasses.dataclass(frozen=True)
class LeftJoin:
    """OPTIONAL: MRJoin plus unmatched-left rows padded with UNBOUND.

    `join_cap` is the calibrated/grown bucket for the inner-join part; the
    node's output capacity is join_cap + left.capacity (the padding slots
    are exact, they can never overflow).
    """

    left: "PlanNode"
    right: "PlanNode"
    key_vars: tuple[str, ...]
    schema: tuple[str, ...]
    join_cap: int

    @property
    def capacity(self) -> int:
        return self.join_cap + self.left.capacity


@dataclasses.dataclass(frozen=True)
class Filter:
    """Device-side validity mask from comparison conditions."""

    child: "PlanNode"
    conds: tuple[FilterCond, ...]

    @property
    def schema(self) -> tuple[str, ...]:
        return self.child.schema

    @property
    def capacity(self) -> int:
        return self.child.capacity


@dataclasses.dataclass(frozen=True)
class Project:
    child: "PlanNode"
    schema: tuple[str, ...]

    @property
    def capacity(self) -> int:
        return self.child.capacity


@dataclasses.dataclass(frozen=True)
class Distinct:
    child: "PlanNode"

    @property
    def schema(self) -> tuple[str, ...]:
        return self.child.schema

    @property
    def capacity(self) -> int:
        return self.child.capacity


@dataclasses.dataclass(frozen=True)
class Slice:
    """LIMIT/OFFSET: the actual values are runtime inputs (indexes into the
    int constants array), so one program serves every limit."""

    child: "PlanNode"
    offset_index: int
    limit_index: int

    @property
    def schema(self) -> tuple[str, ...]:
        return self.child.schema

    @property
    def capacity(self) -> int:
        return self.child.capacity


PlanNode = Union[
    Scan, MRJoin, CrossJoin, LeftJoin, Filter, Project, Distinct, Slice
]


@dataclasses.dataclass(frozen=True)
class PhysicalPlan:
    root: PlanNode
    n_scans: int
    join_caps: tuple[int, ...]  # per join step, evaluation order

    def max_capacity(self) -> int:
        def walk(node: PlanNode) -> int:
            kids = [
                getattr(node, a)
                for a in ("left", "right", "child")
                if hasattr(node, a)
            ]
            return max([node.capacity] + [walk(k) for k in kids])

        return walk(self.root)


# -- shape (the cache key) ---------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """An OPTIONAL group: how many scans it consumes (in shape order, after
    the required chain and earlier groups) and its inner join structure."""

    n_scans: int
    cross_flags: tuple[bool, ...]  # len == n_scans - 1


@dataclasses.dataclass(frozen=True)
class PlanShape:
    """Everything a compiled program is specialised on, minus join caps.

    Pattern constants, filter constants and LIMIT/OFFSET values are
    deliberately absent: they only affect scan data / runtime inputs. Two
    queries with the same shape dispatch the same compiled executable.
    """

    scan_schemas: tuple[tuple[str, ...], ...]  # canonical names, plan order
    scan_caps: tuple[int, ...]
    cross_flags: tuple[bool, ...]  # required chain (len == n_required - 1)
    opt_groups: tuple[GroupSpec, ...] = ()
    filters: tuple[FilterCond, ...] = ()
    projection: tuple[str, ...] = ()  # canonical names
    distinct: bool = False
    has_slice: bool = False

    @property
    def n_required(self) -> int:
        return len(self.cross_flags) + 1

    def n_joins(self) -> int:
        """Join steps that carry a calibrated bucket, evaluation order:
        required chain, then per group its inner joins + the left join."""
        return len(self.cross_flags) + sum(
            len(g.cross_flags) + 1 for g in self.opt_groups
        )

    def n_id_consts(self) -> int:
        return sum(1 for c in self.filters if c[2] == "id")

    def slice_const_indices(self) -> tuple[int, int]:
        """(offset, limit) positions in the int runtime-constants array:
        appended right after the filter id constants."""
        base = self.n_id_consts()
        return base, base + 1


def canonical_renaming(
    schemas: tuple[tuple[str, ...], ...],
) -> dict[str, str]:
    """Original var -> ?cN by order of first appearance across the plan."""
    mapping: dict[str, str] = {}
    for schema in schemas:
        for v in schema:
            if v not in mapping:
                mapping[v] = f"?c{len(mapping)}"
    return mapping


def make_shape(
    scan_schemas: tuple[tuple[str, ...], ...],
    scan_caps: tuple[int, ...],
    cross_flags: tuple[bool, ...],
    projection: tuple[str, ...],
    distinct: bool,
    opt_groups: tuple[GroupSpec, ...] = (),
    filters: tuple[FilterCond, ...] = (),
    has_slice: bool = False,
) -> PlanShape:
    n_group_scans = sum(g.n_scans for g in opt_groups)
    assert len(scan_schemas) == len(scan_caps)
    assert len(scan_schemas) == len(cross_flags) + 1 + n_group_scans
    return PlanShape(
        scan_schemas,
        scan_caps,
        cross_flags,
        opt_groups,
        filters,
        projection,
        distinct,
        has_slice,
    )


def build_plan(shape: PlanShape, join_caps: tuple[int, ...]) -> PhysicalPlan:
    """Materialise the node tree for a shape at given join bucket capacities.

    `join_caps` are consumed in evaluation order: required-chain joins,
    then, per OPTIONAL group, its inner joins followed by the left join.
    """
    assert len(join_caps) == shape.n_joins(), (join_caps, shape)
    caps = iter(join_caps)
    effective: list[int] = []
    scan_idx = 0

    def next_scan() -> Scan:
        nonlocal scan_idx
        s = Scan(scan_idx, shape.scan_schemas[scan_idx],
                 shape.scan_caps[scan_idx])
        scan_idx += 1
        return s

    def chain(n_scans: int, cross_flags: tuple[bool, ...]) -> PlanNode:
        node: PlanNode = next_scan()
        for is_cross in cross_flags:
            right = next_scan()
            if is_cross:
                cap = node.capacity * right.capacity  # exact: see CrossJoin
                next(caps)  # consumes its slot, value is structural
                node = CrossJoin(node, right, node.schema + right.schema, cap)
            else:
                cap = bucket_capacity(next(caps))
                key = tuple(v for v in node.schema if v in right.schema)
                extra = tuple(
                    v for v in right.schema if v not in node.schema
                )
                node = MRJoin(node, right, key, node.schema + extra, cap)
            effective.append(cap)
        return node

    node = chain(shape.n_required, shape.cross_flags)
    for g in shape.opt_groups:
        grp = chain(g.n_scans, g.cross_flags)
        key = tuple(v for v in node.schema if v in grp.schema)
        if not key:
            raise ValueError(
                "OPTIONAL group shares no variable with the required "
                f"patterns: {grp.schema} vs {node.schema}"
            )
        join_cap = bucket_capacity(next(caps))
        extra = tuple(v for v in grp.schema if v not in node.schema)
        node = LeftJoin(node, grp, key, node.schema + extra, join_cap)
        effective.append(join_cap)
    if shape.filters:
        node = Filter(node, shape.filters)
    node = Project(node, shape.projection)
    if shape.distinct:
        node = Distinct(node)
    if shape.has_slice:
        off_idx, lim_idx = shape.slice_const_indices()
        node = Slice(node, off_idx, lim_idx)
    return PhysicalPlan(node, len(shape.scan_schemas), tuple(effective))


def grow_join_caps(
    join_caps: tuple[int, ...],
    totals: list[int],
    overflowed: list[bool],
) -> tuple[int, ...]:
    """Bucket-overflow fallback: resize flagged joins from their exact totals.

    `totals` are exact even when the join output was truncated (the count is
    computed before expansion), so one growth step is enough per flagged
    join; downstream joins that consumed a truncated input are re-checked on
    the retry dispatch.
    """
    new = list(join_caps)
    for i, flag in enumerate(overflowed):
        if flag:
            new[i] = bucket_capacity(max(int(totals[i]), 2 * join_caps[i]))
    return tuple(new)
