"""Physical-plan IR for the MapSQ join chain.

The planner (core/planner.py) decides the join ORDER; this module turns that
order into a *physical* plan — a tree of frozen, hashable nodes (Scan /
MRJoin / CrossJoin / Project / Distinct) whose static capacities are the
shapes a compiled executor is specialised on (core/executor.py lowers the
tree to one jitted device program).

Two properties make plans reusable across queries, which is the whole point
of the plan/compile cache in sparql/engine.py:

  * capacity bucketing — every capacity is quantised to a pow-2 bucket with
    a floor (`bucket_capacity`), so near-miss result sizes land on the same
    static shape instead of forcing a recompile per query;
  * variable canonicalisation — variable names are renamed ?c0, ?c1, ... in
    plan order (`canonical_renaming`), so two queries that differ only in
    variable spelling (or in the constants inside their patterns — those
    live in the scan *data*, not the plan) share one compiled program.

`PlanShape` is the hashable cache key: scan schemas + scan buckets + join
structure + projection + distinct. `build_plan(shape, join_caps)` fills in
the per-join bucket capacities (learned from the calibration run or grown
by the overflow-retry fallback) and yields the node tree.
"""
from __future__ import annotations

import dataclasses
from typing import Union

# Pow-2 bucket floor: tiny relations all share the same smallest shape.
MIN_BUCKET = 8


def next_pow2(n: int) -> int:
    return 1 << max(0, (max(1, n) - 1).bit_length())


def bucket_capacity(n: int, floor: int = MIN_BUCKET) -> int:
    """Quantise a row count to its static capacity bucket (pow-2, floored)."""
    return max(floor, next_pow2(int(n)))


# -- plan nodes --------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scan:
    """A partial-match relation, fed in as executor input `scans[index]`."""

    index: int
    schema: tuple[str, ...]
    capacity: int


@dataclasses.dataclass(frozen=True)
class MRJoin:
    """Algorithm-1 MapReduce join at a static output capacity."""

    left: "PlanNode"
    right: "PlanNode"
    key_vars: tuple[str, ...]
    schema: tuple[str, ...]
    capacity: int


@dataclasses.dataclass(frozen=True)
class CrossJoin:
    """Cartesian product for disconnected BGP components.

    Capacity is always the full left×right product: cross_join enumerates
    pair POSITIONS, so a smaller capacity could silently drop valid pairs
    (unlike MRJoin, whose overflow flag is exact).
    """

    left: "PlanNode"
    right: "PlanNode"
    schema: tuple[str, ...]
    capacity: int


@dataclasses.dataclass(frozen=True)
class Project:
    child: "PlanNode"
    schema: tuple[str, ...]

    @property
    def capacity(self) -> int:
        return self.child.capacity


@dataclasses.dataclass(frozen=True)
class Distinct:
    child: "PlanNode"

    @property
    def schema(self) -> tuple[str, ...]:
        return self.child.schema

    @property
    def capacity(self) -> int:
        return self.child.capacity


PlanNode = Union[Scan, MRJoin, CrossJoin, Project, Distinct]


@dataclasses.dataclass(frozen=True)
class PhysicalPlan:
    root: PlanNode
    n_scans: int
    join_caps: tuple[int, ...]  # per join step, chain order

    def max_capacity(self) -> int:
        def walk(node: PlanNode) -> int:
            kids = [
                getattr(node, a)
                for a in ("left", "right", "child")
                if hasattr(node, a)
            ]
            return max([node.capacity] + [walk(k) for k in kids])

        return walk(self.root)


# -- shape (the cache key) ---------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanShape:
    """Everything a compiled program is specialised on, minus join caps.

    Pattern constants are deliberately absent: they only affect scan *data*.
    Two queries with the same shape dispatch the same compiled executable.
    """

    scan_schemas: tuple[tuple[str, ...], ...]  # canonical names, plan order
    scan_caps: tuple[int, ...]
    cross_flags: tuple[bool, ...]  # per join step (len == n_scans - 1)
    projection: tuple[str, ...]  # canonical names
    distinct: bool


def canonical_renaming(
    schemas: tuple[tuple[str, ...], ...],
) -> dict[str, str]:
    """Original var -> ?cN by order of first appearance across the plan."""
    mapping: dict[str, str] = {}
    for schema in schemas:
        for v in schema:
            if v not in mapping:
                mapping[v] = f"?c{len(mapping)}"
    return mapping


def make_shape(
    scan_schemas: tuple[tuple[str, ...], ...],
    scan_caps: tuple[int, ...],
    cross_flags: tuple[bool, ...],
    projection: tuple[str, ...],
    distinct: bool,
) -> PlanShape:
    assert len(scan_schemas) == len(scan_caps) == len(cross_flags) + 1
    return PlanShape(scan_schemas, scan_caps, cross_flags, projection, distinct)


def build_plan(shape: PlanShape, join_caps: tuple[int, ...]) -> PhysicalPlan:
    """Materialise the node tree for a shape at given join bucket capacities."""
    assert len(join_caps) == len(shape.cross_flags)
    node: PlanNode = Scan(0, shape.scan_schemas[0], shape.scan_caps[0])
    effective: list[int] = []
    for i, is_cross in enumerate(shape.cross_flags):
        right = Scan(i + 1, shape.scan_schemas[i + 1], shape.scan_caps[i + 1])
        if is_cross:
            cap = node.capacity * right.capacity  # exact: see CrossJoin doc
            schema = node.schema + right.schema
            node = CrossJoin(node, right, schema, cap)
        else:
            cap = bucket_capacity(join_caps[i])
            key = tuple(v for v in node.schema if v in right.schema)
            extra = tuple(v for v in right.schema if v not in node.schema)
            node = MRJoin(node, right, key, node.schema + extra, cap)
        effective.append(cap)
    node = Project(node, shape.projection)
    if shape.distinct:
        node = Distinct(node)
    return PhysicalPlan(node, len(shape.scan_schemas), tuple(effective))


def grow_join_caps(
    join_caps: tuple[int, ...],
    totals: list[int],
    overflowed: list[bool],
) -> tuple[int, ...]:
    """Bucket-overflow fallback: resize flagged joins from their exact totals.

    `totals` are exact even when the join output was truncated (the count is
    computed before expansion), so one growth step is enough per flagged
    join; downstream joins that consumed a truncated input are re-checked on
    the retry dispatch.
    """
    new = list(join_caps)
    for i, flag in enumerate(overflowed):
        if flag:
            new[i] = bucket_capacity(max(int(totals[i]), 2 * join_caps[i]))
    return tuple(new)
