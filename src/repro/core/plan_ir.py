"""Physical-plan IR for the MapSQ join chain.

The optimizer (sparql/optimizer.py) decides the join ORDER and the filter
attachment stages; this module turns them into a *physical* plan — a tree
(a DAG when UNION branches share the required chain) of frozen, hashable
nodes (Scan / MRJoin / CrossJoin / LeftJoin / Filter / UnionAll / Project /
Distinct / Slice) whose static capacities are the shapes a compiled
executor is specialised on (core/executor.py lowers the tree to one jitted
device program).

Three properties make plans reusable across queries, which is the whole
point of the plan/compile cache in sparql/engine.py:

  * capacity bucketing — every capacity is quantised to a pow-2 bucket with
    a floor (`bucket_capacity`), so near-miss result sizes land on the same
    static shape instead of forcing a recompile per query;
  * variable canonicalisation — variable names are renamed ?c0, ?c1, ... in
    plan order (`canonical_renaming`), so two queries that differ only in
    variable spelling (or in the constants inside their patterns — those
    live in the scan *data*, not the plan) share one compiled program;
  * runtime constants — FILTER comparison constants and LIMIT/OFFSET values
    are NOT part of the plan: they are passed to the compiled program as
    int/float input arrays (FilterExpr comparison leaves store an *index*
    into them), so queries differing only in a filter constant or a limit
    share one executable too.

`PlanShape` is the hashable cache key: scan schemas + scan buckets + join
structure (required chain plus OPTIONAL group specs) + filter structure +
projection + distinct + slice presence. `build_plan(shape, join_caps)`
fills in the per-join bucket capacities (learned from the calibration run
or grown by the overflow-retry fallback) and yields the node tree.
"""
from __future__ import annotations

import dataclasses
from typing import Union

# Pow-2 bucket floor: tiny relations all share the same smallest shape.
MIN_BUCKET = 8

# FILTER expressions are nested hashable tuples:
#   ("cmp", lhs_var, op, kind, ref) — a comparison, where kind is
#       "var" — ref is the rhs variable name;
#       "id"  — ref indexes the int runtime-constants array (term identity);
#       "num" — ref indexes the float runtime-constants array (numeric);
#   ("and", (expr, ...)) / ("or", (expr, ...)) — boolean combination.
FilterExpr = tuple

# Where the optimizer attached a filter conjunct in the operator tree:
#   ("scan", i)  — masks scan i before it joins anything;
#   ("req", j)   — after required-chain join j (0-based);
#   ("opt", g)   — after OPTIONAL group g's left join;
#   ("bjoin", b) — after UNION branch b was joined with the required chain
#                  (or after the branch's own chain when none exists);
#   ("top",)     — after the whole tree, before projection (the unoptimized
#                  position — always sound).
FilterStage = tuple
FilterSpec = tuple[FilterStage, FilterExpr]


def expr_vars(expr: FilterExpr) -> tuple[str, ...]:
    """Variables a plan-level filter expression reads, in first appearance
    order."""
    if expr[0] == "cmp":
        _, lhs, _op, kind, ref = expr
        return (lhs, ref) if kind == "var" else (lhs,)
    out: list[str] = []
    for child in expr[1]:
        for v in expr_vars(child):
            if v not in out:
                out.append(v)
    return tuple(out)


def rename_expr(expr: FilterExpr, rn: dict[str, str]) -> FilterExpr:
    """Apply a variable renaming to a filter expression."""
    if expr[0] == "cmp":
        _, lhs, op, kind, ref = expr
        return (
            "cmp",
            rn.get(lhs, lhs),
            op,
            kind,
            rn.get(ref, ref) if kind == "var" else ref,
        )
    return (expr[0], tuple(rename_expr(c, rn) for c in expr[1]))


def format_expr(expr: FilterExpr) -> str:
    if expr[0] == "cmp":
        _, lhs, op, kind, ref = expr
        rhs = ref if kind == "var" else f"{kind}[{ref}]"
        return f"{lhs} {op} {rhs}"
    sep = " && " if expr[0] == "and" else " || "
    return "(" + sep.join(format_expr(c) for c in expr[1]) + ")"


def next_pow2(n: int) -> int:
    return 1 << max(0, (max(1, n) - 1).bit_length())


def bucket_capacity(n: int, floor: int = MIN_BUCKET) -> int:
    """Quantise a row count to its static capacity bucket (pow-2, floored)."""
    return max(floor, next_pow2(int(n)))


def floor_pow2(n: int) -> int:
    return 1 << (max(1, int(n)).bit_length() - 1)


def bucket_width(n: int, max_width: int) -> int:
    """Pow-2 batch-width bucket for a stacked same-shape dispatch.

    Groups of nearby sizes land on the same width, so a warm (shape, caps,
    width) executable is reused across micro-batches instead of
    recompiling per exact group size; the lanes past the real group are
    padding, masked out by the executor's per-lane validity mask
    (executor.lower_batched) so they never contribute rows or overflow
    flags. `max_width` is a lane CAP (it bounds device memory per
    dispatch), so a non-pow-2 value clamps DOWN to its floor bucket —
    callers must chunk groups at `floor_pow2(max_width)` lanes.
    """
    return min(next_pow2(int(n)), floor_pow2(max_width))


# -- plan nodes --------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scan:
    """A partial-match relation, fed in as executor input `scans[index]`.

    `part_col` is the schema position the rows are hash-partitioned on
    across a sharded store's mesh (-1 = none). A subject-variable scan of
    the subject-hash sharded store is partitioned on its subject column:
    shard k holds exactly the rows whose subject FNV-hashes to k — the
    same hash and routing core/distributed.shuffle_by_key uses — which is
    what lets the distributed lowering elide the shuffle of an already-
    aligned join input (core/dist_executor.analyze_plan). Single-device
    plans leave it at -1; it does not exist at runtime, only as lowering
    metadata."""

    index: int
    schema: tuple[str, ...]
    capacity: int
    part_col: int = -1


@dataclasses.dataclass(frozen=True)
class MRJoin:
    """Algorithm-1 MapReduce join at a static output capacity."""

    left: "PlanNode"
    right: "PlanNode"
    key_vars: tuple[str, ...]
    schema: tuple[str, ...]
    capacity: int


@dataclasses.dataclass(frozen=True)
class MatrixJoin:
    """The same equi-join lowered through the masked-SpMM backend
    (core/matrix_join.py): no sort, dense tiled key compares + a scatter
    expansion. Identical contract to MRJoin — same output schema, exact
    total, exact truncation — so the two are freely interchangeable per
    node; the optimizer picks from selectivity x skew."""

    left: "PlanNode"
    right: "PlanNode"
    key_vars: tuple[str, ...]
    schema: tuple[str, ...]
    capacity: int


@dataclasses.dataclass(frozen=True)
class CrossJoin:
    """Cartesian product for disconnected BGP components.

    Capacity is always the full left×right product: cross_join enumerates
    pair POSITIONS, so a smaller capacity could silently drop valid pairs
    (unlike MRJoin, whose overflow flag is exact).
    """

    left: "PlanNode"
    right: "PlanNode"
    schema: tuple[str, ...]
    capacity: int


@dataclasses.dataclass(frozen=True)
class LeftJoin:
    """OPTIONAL: MRJoin plus unmatched-left rows padded with UNBOUND.

    `join_cap` is the calibrated/grown bucket for the inner-join part; the
    node's output capacity is join_cap + left.capacity (the padding slots
    are exact, they can never overflow). `backend` selects the physical
    algebra for the inner join ("mr" or "matrix").
    """

    left: "PlanNode"
    right: "PlanNode"
    key_vars: tuple[str, ...]
    schema: tuple[str, ...]
    join_cap: int
    backend: str = "mr"

    @property
    def capacity(self) -> int:
        return self.join_cap + self.left.capacity


@dataclasses.dataclass(frozen=True)
class Filter:
    """Device-side validity mask from filter expressions (conjunction)."""

    child: "PlanNode"
    conds: tuple[FilterExpr, ...]

    @property
    def schema(self) -> tuple[str, ...]:
        return self.child.schema

    @property
    def capacity(self) -> int:
        return self.child.capacity


@dataclasses.dataclass(frozen=True)
class UnionAll:
    """SPARQL UNION: device-side multiset concatenation of the branches.

    The output schema is the first-appearance union of the child schemas;
    columns a branch does not bind are padded with the UNBOUND sentinel.
    Capacity is the exact sum of the children's capacities — concatenation
    can never overflow, so UNION adds no calibrated bucket of its own.
    """

    children: tuple["PlanNode", ...]
    schema: tuple[str, ...]

    @property
    def capacity(self) -> int:
        return sum(c.capacity for c in self.children)


@dataclasses.dataclass(frozen=True)
class Project:
    child: "PlanNode"
    schema: tuple[str, ...]

    @property
    def capacity(self) -> int:
        return self.child.capacity


@dataclasses.dataclass(frozen=True)
class Distinct:
    child: "PlanNode"

    @property
    def schema(self) -> tuple[str, ...]:
        return self.child.schema

    @property
    def capacity(self) -> int:
        return self.child.capacity


@dataclasses.dataclass(frozen=True)
class Slice:
    """LIMIT/OFFSET: the actual values are runtime inputs (indexes into the
    int constants array), so one program serves every limit."""

    child: "PlanNode"
    offset_index: int
    limit_index: int

    @property
    def schema(self) -> tuple[str, ...]:
        return self.child.schema

    @property
    def capacity(self) -> int:
        return self.child.capacity


PlanNode = Union[
    Scan, MRJoin, MatrixJoin, CrossJoin, LeftJoin, Filter, UnionAll,
    Project, Distinct, Slice,
]


def child_nodes(node: PlanNode) -> list[PlanNode]:
    if isinstance(node, UnionAll):
        return list(node.children)
    return [
        getattr(node, a)
        for a in ("left", "right", "child")
        if hasattr(node, a)
    ]


@dataclasses.dataclass(frozen=True)
class PhysicalPlan:
    root: PlanNode
    n_scans: int
    join_caps: tuple[int, ...]  # per join step, evaluation order

    def max_capacity(self) -> int:
        # the plan may be a DAG (union branches share the required chain);
        # id-dedup keeps the walk linear
        seen: set[int] = set()

        def walk(node: PlanNode) -> int:
            if id(node) in seen:
                return 0
            seen.add(id(node))
            return max(
                [node.capacity] + [walk(k) for k in child_nodes(node)]
            )

        return walk(self.root)


# -- shape (the cache key) ---------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """An OPTIONAL group: how many scans it consumes (in shape order, after
    the required chain and earlier groups) and its inner join structure."""

    n_scans: int
    cross_flags: tuple[bool, ...]  # len == n_scans - 1


@dataclasses.dataclass(frozen=True)
class PlanShape:
    """Everything a compiled program is specialised on, minus join caps.

    Pattern constants, filter constants and LIMIT/OFFSET values are
    deliberately absent: they only affect scan data / runtime inputs. Two
    queries with the same shape dispatch the same compiled executable.

    Scan order: required chain, then each OPTIONAL group's scans, then
    each UNION branch's scans. `filters` carry the optimizer's chosen
    attachment stage; `prune` enables projection narrowing (dropping
    variables nothing downstream reads) inside the compiled program.
    """

    scan_schemas: tuple[tuple[str, ...], ...]  # canonical names, plan order
    scan_caps: tuple[int, ...]
    cross_flags: tuple[bool, ...]  # required chain (len == n_required - 1)
    opt_groups: tuple[GroupSpec, ...] = ()
    union_groups: tuple[GroupSpec, ...] = ()
    has_required: bool = True  # False: UNION-only query, no required BGP
    filters: tuple[FilterSpec, ...] = ()
    n_consts: tuple[int, int] = (0, 0)  # (int, float) filter consts
    projection: tuple[str, ...] = ()  # canonical names
    distinct: bool = False
    has_slice: bool = False
    prune: bool = False  # optimizer projection pruning enabled
    # Physical algebra per join-cap slot ("mr" | "matrix"), evaluation
    # order, len == n_joins(). Part of the shape: a backend flip is a
    # different compiled program. Cross-join slots carry "mr" (unused).
    join_backends: tuple[str, ...] = ()
    # Per scan, the schema position the sharded store's rows are hash-
    # partitioned on (-1 = none; single-device shapes are all -1). Part of
    # the shape: the distributed lowering elides shuffles from it, so a
    # different partitioning is a different compiled program.
    scan_parts: tuple[int, ...] = ()

    @property
    def n_required(self) -> int:
        return len(self.cross_flags) + 1 if self.has_required else 0

    def n_joins(self) -> int:
        """Join steps that carry a calibrated bucket, evaluation order:
        required chain, per OPTIONAL group its inner joins + the left
        join, then per UNION branch its inner joins + (when a required
        chain exists) the branch-required join."""
        req = len(self.cross_flags) if self.has_required else 0
        opt = sum(len(g.cross_flags) + 1 for g in self.opt_groups)
        uni = sum(
            len(g.cross_flags) + (1 if self.has_required else 0)
            for g in self.union_groups
        )
        return req + opt + uni

    def slice_const_indices(self) -> tuple[int, int]:
        """(offset, limit) positions in the int runtime-constants array:
        appended right after the filter id constants."""
        base = self.n_consts[0]
        return base, base + 1


def canonical_renaming(
    schemas: tuple[tuple[str, ...], ...],
) -> dict[str, str]:
    """Original var -> ?cN by order of first appearance across the plan."""
    mapping: dict[str, str] = {}
    for schema in schemas:
        for v in schema:
            if v not in mapping:
                mapping[v] = f"?c{len(mapping)}"
    return mapping


def make_shape(
    scan_schemas: tuple[tuple[str, ...], ...],
    scan_caps: tuple[int, ...],
    cross_flags: tuple[bool, ...],
    projection: tuple[str, ...],
    distinct: bool,
    opt_groups: tuple[GroupSpec, ...] = (),
    union_groups: tuple[GroupSpec, ...] = (),
    has_required: bool = True,
    filters: tuple[FilterSpec, ...] = (),
    n_consts: tuple[int, int] = (0, 0),
    has_slice: bool = False,
    prune: bool = False,
    join_backends: tuple[str, ...] = (),
    scan_parts: tuple[int, ...] = (),
) -> PlanShape:
    n_group_scans = sum(g.n_scans for g in opt_groups)
    n_union_scans = sum(g.n_scans for g in union_groups)
    n_req = len(cross_flags) + 1 if has_required else 0
    assert has_required or not cross_flags
    assert has_required or not opt_groups
    assert len(scan_schemas) == len(scan_caps)
    assert len(scan_schemas) == n_req + n_group_scans + n_union_scans
    shape = PlanShape(
        scan_schemas,
        scan_caps,
        cross_flags,
        opt_groups,
        union_groups,
        has_required,
        filters,
        n_consts,
        projection,
        distinct,
        has_slice,
        prune,
    )
    # Normalise the backend and partitioning vectors so shapes differing
    # only in "explicit default" vs "omitted" compare (and hash) equal —
    # that equality is the plan-cache key.
    if not join_backends:
        join_backends = ("mr",) * shape.n_joins()
    assert len(join_backends) == shape.n_joins(), (join_backends, shape)
    assert all(b in ("mr", "matrix") for b in join_backends)
    if not scan_parts:
        scan_parts = (-1,) * len(scan_schemas)
    assert len(scan_parts) == len(scan_schemas), (scan_parts, scan_schemas)
    return dataclasses.replace(
        shape,
        join_backends=tuple(join_backends),
        scan_parts=tuple(scan_parts),
    )


def narrowed_schema(
    schema: tuple[str, ...], needed: set[str]
) -> tuple[str, ...]:
    return tuple(v for v in schema if v in needed)


def build_plan(shape: PlanShape, join_caps: tuple[int, ...]) -> PhysicalPlan:
    """Materialise the node tree for a shape at given join bucket capacities.

    `join_caps` are consumed in evaluation order: required-chain joins;
    per OPTIONAL group its inner joins then the left join; per UNION
    branch its inner joins then (when a required chain exists) the
    branch-required join. Filter conjuncts are interleaved at their
    optimizer-chosen stages, and (with shape.prune) intermediate schemas
    are narrowed to the variables something downstream still reads —
    projection pruning, applied inside the one compiled program.
    """
    assert len(join_caps) == shape.n_joins(), (join_caps, shape)
    caps = iter(join_caps)
    backends = iter(shape.join_backends or ("mr",) * shape.n_joins())
    effective: list[int] = []
    scan_idx = 0
    by_stage: dict[tuple, list[FilterExpr]] = {}
    for stage, expr in shape.filters:
        by_stage.setdefault(stage, []).append(expr)
    applied_stages: set[tuple] = set()

    def apply_filters(node: PlanNode, stage: tuple) -> PlanNode:
        applied_stages.add(stage)
        exprs = by_stage.get(stage)
        if exprs:
            node = Filter(node, tuple(exprs))
        return node

    def narrow(node: PlanNode, keep_joinable=()) -> PlanNode:
        """Project away variables nothing downstream reads: not in the
        final projection, not in a still-pending filter, not in a
        not-yet-consumed scan, and not in a schema we must stay joinable
        with (`keep_joinable`). Row counts are unaffected, so the
        calibration totals stay identical — only intermediate widths (and
        therefore join buffer bytes) shrink."""
        if not shape.prune:
            return node
        needed = set(shape.projection)
        for stage, expr in shape.filters:
            if stage not in applied_stages:
                needed.update(expr_vars(expr))
        for s in shape.scan_schemas[scan_idx:]:
            needed.update(s)
        for s in keep_joinable:
            needed.update(s)
        keep = narrowed_schema(node.schema, needed)
        if keep != tuple(node.schema):
            node = Project(node, keep)
        return node

    def next_scan() -> PlanNode:
        nonlocal scan_idx
        i = scan_idx
        part = shape.scan_parts[i] if shape.scan_parts else -1
        s = Scan(i, shape.scan_schemas[i], shape.scan_caps[i], part)
        scan_idx += 1
        return apply_filters(s, ("scan", i))

    def join_pair(
        node: PlanNode, right: PlanNode, is_cross: bool
    ) -> PlanNode:
        if is_cross:
            cap = node.capacity * right.capacity  # exact: see CrossJoin
            next(caps)  # consumes its slot, value is structural
            next(backends)  # cross joins have one algebra; slot is padding
            node = CrossJoin(
                node, right, tuple(node.schema) + tuple(right.schema), cap
            )
        else:
            cap = bucket_capacity(next(caps))
            key = tuple(v for v in node.schema if v in right.schema)
            extra = tuple(v for v in right.schema if v not in node.schema)
            cls = MatrixJoin if next(backends) == "matrix" else MRJoin
            node = cls(
                node, right, key, tuple(node.schema) + extra, cap
            )
        effective.append(cap)
        return node

    def chain(
        n_scans: int,
        cross_flags: tuple[bool, ...],
        req_stages: bool = False,
        keep_joinable=(),
    ) -> PlanNode:
        node = narrow(next_scan(), keep_joinable)
        for j, is_cross in enumerate(cross_flags):
            right = narrow(
                next_scan(), tuple(keep_joinable) + (node.schema,)
            )
            node = join_pair(node, right, is_cross)
            if req_stages:
                node = apply_filters(node, ("req", j))
            node = narrow(node, keep_joinable)
        return node

    node: PlanNode | None = None
    if shape.has_required:
        node = chain(shape.n_required, shape.cross_flags, req_stages=True)
    for gi, g in enumerate(shape.opt_groups):
        grp = chain(g.n_scans, g.cross_flags, keep_joinable=(node.schema,))
        key = tuple(v for v in node.schema if v in grp.schema)
        if not key:
            raise ValueError(
                "OPTIONAL group shares no variable with the required "
                f"patterns: {grp.schema} vs {node.schema}"
            )
        join_cap = bucket_capacity(next(caps))
        extra = tuple(v for v in grp.schema if v not in node.schema)
        node = LeftJoin(
            node, grp, key, tuple(node.schema) + extra, join_cap,
            backend=next(backends),
        )
        effective.append(join_cap)
        node = apply_filters(node, ("opt", gi))
        node = narrow(node)
    if shape.union_groups:
        req_node = node
        children: list[PlanNode] = []
        for bi, g in enumerate(shape.union_groups):
            keep = (req_node.schema,) if req_node is not None else ()
            bnode = chain(g.n_scans, g.cross_flags, keep_joinable=keep)
            if req_node is not None:
                shared = [v for v in req_node.schema if v in bnode.schema]
                bnode = join_pair(req_node, bnode, is_cross=not shared)
            bnode = apply_filters(bnode, ("bjoin", bi))
            bnode = narrow(bnode)
            children.append(bnode)
        schema: list[str] = []
        for c in children:
            for v in c.schema:
                if v not in schema:
                    schema.append(v)
        node = UnionAll(tuple(children), tuple(schema))
    node = apply_filters(node, ("top",))
    node = Project(node, shape.projection)
    if shape.distinct:
        node = Distinct(node)
    if shape.has_slice:
        off_idx, lim_idx = shape.slice_const_indices()
        node = Slice(node, off_idx, lim_idx)
    return PhysicalPlan(node, len(shape.scan_schemas), tuple(effective))


def grow_join_caps(
    join_caps: tuple[int, ...],
    totals: list[int],
    overflowed: list[bool],
) -> tuple[int, ...]:
    """Bucket-overflow fallback: resize flagged joins from their exact totals.

    `totals` are exact even when the join output was truncated (the count is
    computed before expansion), so one growth step is enough per flagged
    join; downstream joins that consumed a truncated input are re-checked on
    the retry dispatch.
    """
    new = list(join_caps)
    for i, flag in enumerate(overflowed):
        if flag:
            new[i] = bucket_capacity(max(int(totals[i]), 2 * join_caps[i]))
    return tuple(new)


# -- warmup persistence (plan-cache signatures as JSON) -----------------------


def _expr_from_json(e) -> FilterExpr:
    if e[0] == "cmp":
        return ("cmp", e[1], e[2], e[3], e[4])
    return (e[0], tuple(_expr_from_json(c) for c in e[1]))


def shape_to_jsonable(shape: PlanShape) -> dict:
    """A JSON-serialisable form of the cache key (tuples become lists; the
    inverse is `shape_from_jsonable`, which must round-trip to an equal
    PlanShape — that equality is what makes warmup hits possible)."""
    return {
        "scan_schemas": [list(s) for s in shape.scan_schemas],
        "scan_caps": list(shape.scan_caps),
        "cross_flags": list(shape.cross_flags),
        "opt_groups": [
            {"n_scans": g.n_scans, "cross_flags": list(g.cross_flags)}
            for g in shape.opt_groups
        ],
        "union_groups": [
            {"n_scans": g.n_scans, "cross_flags": list(g.cross_flags)}
            for g in shape.union_groups
        ],
        "has_required": shape.has_required,
        "filters": [[list(stage), expr] for stage, expr in shape.filters],
        "n_consts": list(shape.n_consts),
        "projection": list(shape.projection),
        "distinct": shape.distinct,
        "has_slice": shape.has_slice,
        "prune": shape.prune,
        "join_backends": list(shape.join_backends),
        "scan_parts": list(shape.scan_parts),
    }


def shape_from_jsonable(obj: dict) -> PlanShape:
    def group(d) -> GroupSpec:
        return GroupSpec(int(d["n_scans"]), tuple(d["cross_flags"]))

    shape = PlanShape(
        scan_schemas=tuple(tuple(s) for s in obj["scan_schemas"]),
        scan_caps=tuple(int(c) for c in obj["scan_caps"]),
        cross_flags=tuple(bool(f) for f in obj["cross_flags"]),
        opt_groups=tuple(group(g) for g in obj["opt_groups"]),
        union_groups=tuple(group(g) for g in obj["union_groups"]),
        has_required=bool(obj["has_required"]),
        filters=tuple(
            (tuple(stage), _expr_from_json(expr))
            for stage, expr in obj["filters"]
        ),
        n_consts=tuple(int(c) for c in obj["n_consts"]),
        projection=tuple(obj["projection"]),
        distinct=bool(obj["distinct"]),
        has_slice=bool(obj["has_slice"]),
        prune=bool(obj["prune"]),
    )
    # files predating the matrix backend carry no vector: all-MR
    backends = obj.get("join_backends")
    if backends is None:
        backends = ["mr"] * shape.n_joins()
    # files predating partitioning-aware lowering carry none: unpartitioned
    # (a sharded engine computes real parts, so such entries simply miss)
    parts = obj.get("scan_parts")
    if parts is None:
        parts = [-1] * len(shape.scan_schemas)
    return dataclasses.replace(
        shape,
        join_backends=tuple(backends),
        scan_parts=tuple(int(p) for p in parts),
    )
