"""The matrix join backend: MapSQ's equi-join as masked SpMM reductions.

Where Algorithm 1 (core/mr_join.py) realises the join as Map -> Sort ->
ReduceDuplicate, this backend — the gSMat/gSmart reformulation — never
sorts. The Map phase is shared (sentinel-tagged key extraction); then
dense masked reductions (kernels/spmm_join) drive the whole join:

  counts[i], first[i], b[i], cl[j]  <- match_layout: ONE eq/lt tile pass
  pos[j]    = stable sorted rank of rk[j]  (less-than + earlier-equal sum,
              right side only — the small input)

Left row i's outputs start at slot  start[i] = Pex[first[i]] + b[i],
where Pex is the exclusive prefix of cl in sorted-right order: slots for
all smaller keys, plus slots claimed by earlier same-key left rows. The
left side is never sorted OR ranked — zero-count rows occupy zero slots,
and every matching key exists on the right, so the right side's order
carries all the information. The expansion scatters the slot-monotone
code first[i]*n_l + i at start[i] and running-maxes it across slots to
recover each slot's left row; the right row is then a gather into the
sorted-right inverse permutation at first + occurrence rank.

The dense compares cost O(n_l * n_r) tiles, which is why the optimizer
only picks this backend when selectivity x skew says the output is within
a constant factor of the dense product — exactly where the MR backend's
two argsorts are pure overhead. Match ordering is IDENTICAL to mr_join's
(left rows in stable key order, then right buffer order within a key),
so the two backends are bit-compatible, not just set-equal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mr_join import _map_phase
from repro.core.relation import UNBOUND, Relation, shared_vars
from repro.kernels.spmm_join import ops as spmm_ops


def _match_arrays(left: Relation, right: Relation, use_kernel: bool):
    key_vars = shared_vars(left, right)
    if not key_vars:
        raise ValueError(
            f"cross join between {left.schema} and {right.schema}; "
            "use cross_join()"
        )
    l_key, r_key = _map_phase(left, right, key_vars)
    counts, first, b, cl = spmm_ops.match_layout(
        l_key, r_key, use_kernel=use_kernel
    )
    pos_r = spmm_ops.sort_ranks(r_key, use_kernel=use_kernel)
    return counts, first, b, cl, pos_r


def _expand_gather(counts, first, b, cl, pos_r, capacity: int):
    """Gather each output slot's (left row, right row) pair.

    Emission order is bit-identical to mr_join's (left rows in stable key
    order, right buffer order within a key) without ever ordering the
    left side: start[i] = Pex[first[i]] + b[i] places each matching row's
    slot range directly, and the slot-monotone code first[i]*n_l + i —
    strictly increasing along the emission order, decodable with one mod
    — is scattered at range starts and cummax-filled to invert the
    mapping. Everything per-slot is a gather or a scan; the only scatters
    are n_r- and n_l-sized (tiny next to capacity).
    """
    n_l, n_r = counts.shape[0], pos_r.shape[0]
    rows = jnp.arange(n_l, dtype=jnp.int32)
    # right side in stable key order: j_at[pos_r[j]] = j (no argsort)
    j_at = jnp.zeros((n_r,), jnp.int32).at[pos_r].set(
        jnp.arange(n_r, dtype=jnp.int32)
    )
    if n_r:
        cl_sorted = cl[j_at]
        pex = jnp.cumsum(cl_sorted, dtype=jnp.int32) - cl_sorted
        before_key = pex[jnp.clip(first, 0, n_r - 1)]
    else:
        before_key = jnp.zeros_like(first)
    start = before_key + b
    total = jnp.sum(counts, dtype=jnp.int32)
    # scatter each matching row's code at its range start; cummax fills
    # the whole range (codes increase along slots, so later starts win)
    idx = jnp.where(counts > 0, start, capacity)  # zero-count rows: drop
    marks = jnp.zeros((capacity,), jnp.int32).at[idx].set(
        first * n_l + rows, mode="drop"
    )
    li = jax.lax.cummax(marks) % max(n_l, 1)
    k = jnp.arange(capacity, dtype=jnp.int32)
    r_k = k - start[li]  # occurrence rank of slot k within its left row
    rj = j_at[jnp.clip(first[li] + r_k, 0, max(n_r - 1, 0))]
    valid = k < total
    return li, rj, valid, total


def _joined_cols(left, right, li, rj, valid, capacity):
    right_extra = [v for v in right.schema if v not in left.schema]
    out_schema = tuple(left.schema) + tuple(right_extra)
    l_cols = left.cols[li]
    r_cols = (
        right.project(right_extra).cols[rj]
        if right_extra
        else jnp.zeros((capacity, 0), jnp.int32)
    )
    cols = jnp.concatenate([l_cols, r_cols], axis=1)
    return out_schema, right_extra, jnp.where(valid[:, None], cols, 0)


def matrix_join(
    left: Relation,
    right: Relation,
    capacity: int,
    use_kernel: bool = False,
) -> tuple[Relation, jax.Array, jax.Array]:
    """Matrix-backend equi-join; same contract and output schema as
    mr_join: (result, exact_total, overflowed), schema = left vars then
    right vars not already bound, rows past capacity truncated exactly."""
    counts, first, b, cl, pos_r = _match_arrays(left, right, use_kernel)
    li, rj, valid, total = _expand_gather(
        counts, first, b, cl, pos_r, capacity
    )
    out_schema, _, cols = _joined_cols(left, right, li, rj, valid, capacity)
    return Relation(out_schema, cols, valid), total, total > capacity


def matrix_left_join(
    left: Relation,
    right: Relation,
    capacity: int,
    use_kernel: bool = False,
) -> tuple[Relation, jax.Array, jax.Array]:
    """OPTIONAL on the matrix backend; same layout as mr_join.left_join:
    `capacity` inner-join slots, then left.capacity unmatched-left padding
    slots with right-only columns UNBOUND. The unmatched mask falls out of
    the counts vector directly (counts are already in left buffer order —
    no sort to invert, unlike the MR backend's semijoin scatter-back)."""
    counts, first, b, cl, pos_r = _match_arrays(left, right, use_kernel)
    li, rj, valid, total = _expand_gather(
        counts, first, b, cl, pos_r, capacity
    )
    out_schema, right_extra, join_cols = _joined_cols(
        left, right, li, rj, valid, capacity
    )
    unmatched = left.valid & (counts == 0)
    pad = jnp.full((left.capacity, len(right_extra)), UNBOUND, jnp.int32)
    pad_cols = jnp.concatenate([left.cols, pad], axis=1)
    cols = jnp.concatenate([join_cols, pad_cols], axis=0)
    valid_all = jnp.concatenate([valid, unmatched])
    return Relation(out_schema, cols, valid_all), total, total > capacity
