"""Distributed MapSQ: the MapReduce shuffle as mesh collectives.

The paper's Map phase redistributes (key, value) pairs so equal keys meet;
on a TPU mesh that is a hash-partition + `all_to_all`, then each shard runs
the local sort-merge ReduceDuplicate. Multi-pod meshes use a hierarchical
two-stage shuffle (route to the destination pod over the slow inter-pod
links first, then to the destination chip over intra-pod ICI), which keeps
inter-pod bytes at 1/pod_count of the naive flat shuffle.

All functions here are written to run INSIDE `jax.shard_map`.
"""
from __future__ import annotations

from functools import partial, reduce

import jax
import jax.numpy as jnp

from repro.core import compat
from repro.core import mr_join as mj
from repro.core.relation import Relation
from repro.core.segments import segment_offsets_from_sorted

_FNV_OFFSET = jnp.uint32(2166136261)
_FNV_PRIME = jnp.uint32(16777619)


def hash_keys(key_cols: jax.Array) -> jax.Array:
    """FNV-1a over the key tuple -> uint32 (tuple-equal => hash-equal)."""
    h = jnp.full(key_cols.shape[0], _FNV_OFFSET, jnp.uint32)
    for c in range(key_cols.shape[1]):
        h = (h ^ key_cols[:, c].astype(jnp.uint32)) * _FNV_PRIME
    return h


def bucketize(cols: jax.Array, valid: jax.Array, part: jax.Array, num_parts: int,
              bucket_capacity: int):
    """Pack rows into per-destination buckets (static shapes).

    Returns (buf (P, cap, C), bvalid (P, cap), overflowed (), max_load ()).
    Rows beyond a destination's capacity are dropped and flagged;
    `max_load` is the EXACT largest per-destination row count (valid rows
    only, before the capacity clamp), so an overflowed shuffle bucket can
    be regrown to the needed size in one step — the same
    exact-totals-on-overflow discipline the join buckets use.
    """
    n, c = cols.shape
    part = jnp.where(valid, part, num_parts).astype(jnp.int32)
    order = jnp.argsort(part, stable=True)
    part_s = part[order]
    cols_s = cols[order]
    valid_s = valid[order]
    offsets = segment_offsets_from_sorted(part_s, num_parts)
    pos = jnp.arange(n, dtype=jnp.int32) - offsets[jnp.clip(part_s, 0, num_parts - 1)]
    ok = (part_s < num_parts) & (pos < bucket_capacity) & valid_s
    slot = jnp.where(ok, part_s * bucket_capacity + pos, num_parts * bucket_capacity)
    buf = jnp.zeros((num_parts * bucket_capacity, c), cols.dtype)
    buf = buf.at[slot].set(jnp.where(ok[:, None], cols_s, 0), mode="drop")
    bvalid = jnp.zeros((num_parts * bucket_capacity,), bool).at[slot].set(ok, mode="drop")
    overflowed = jnp.any((part_s < num_parts) & valid_s & (pos >= bucket_capacity))
    max_load = jnp.max(offsets[1:] - offsets[:-1])
    return (
        buf.reshape(num_parts, bucket_capacity, c),
        bvalid.reshape(num_parts, bucket_capacity),
        overflowed,
        max_load,
    )


def _shuffle_one_axis(cols, valid, dest_along_axis, axis_name, bucket_capacity):
    """Route rows to `dest_along_axis` coordinates over one mesh axis."""
    size = compat.axis_size(axis_name)
    buf, bvalid, overflowed, max_load = bucketize(
        cols, valid, dest_along_axis, size, bucket_capacity
    )
    buf = jax.lax.all_to_all(buf, axis_name, split_axis=0, concat_axis=0, tiled=False)
    bvalid = jax.lax.all_to_all(bvalid, axis_name, split_axis=0, concat_axis=0,
                                tiled=False)
    n_cols = cols.shape[1]
    return (
        buf.reshape(size * bucket_capacity, n_cols),
        bvalid.reshape(size * bucket_capacity),
        overflowed,
        max_load,
    )


def shuffle_by_key(cols: jax.Array, valid: jax.Array, key_idx: list[int],
                   axis_names: tuple[str, ...],
                   bucket_capacity: "int | tuple[int, ...]"):
    """Hierarchical MapReduce shuffle: equal keys land on the same shard.

    axis_names are ordered outermost (inter-pod) first. The destination shard
    id is hash(key) % total; stage k routes along axis k by the destination's
    coordinate on that axis, so inter-pod traffic happens exactly once.

    §Perf iteration (mapsq): `key_idx` names the key COLUMNS of `cols`
    instead of shipping a separate key copy + precomputed destination —
    the destination is recomputed from the payload at each stage, cutting
    shuffle bytes by (k+1)/(c+k+1) (50% for the 2-col relations here).

    `bucket_capacity` is PER STAGE (an int applies to every stage): stage
    k's per-destination load is ~rows/size_k, so the outer (pod) stage of
    a hierarchical mesh genuinely needs a larger bucket than the inner
    (chip) stage — sizing them together would inflate every stage's
    buffer to the worst stage's load.

    Returns (cols, valid, overflowed, need): `overflowed` and `need` are
    (n_stages,) vectors — stage k's drop flag and this shard's exact
    worst per-destination load at stage k — so an overflow regrows ONLY
    the overflowing stage's bucket (pmax the need over the mesh to get
    the capacity a retry dispatch must compile at).
    """
    sizes = [compat.axis_size(a) for a in axis_names]
    total = reduce(lambda a, b: a * b, sizes, 1)
    caps = (
        (int(bucket_capacity),) * len(axis_names)
        if isinstance(bucket_capacity, int)
        else tuple(bucket_capacity)
    )
    assert len(caps) == len(axis_names), (caps, axis_names)
    overflow: list[jax.Array] = []
    need: list[jax.Array] = []
    # decompose dest into per-axis coordinates (row-major over axis_names)
    for k, axis in enumerate(axis_names):
        dest = (hash_keys(cols[:, key_idx]) % jnp.uint32(total)).astype(
            jnp.int32)
        inner = reduce(lambda a, b: a * b, sizes[k + 1:], 1)
        coord = (dest // inner) % sizes[k]
        cols, valid, ov, max_load = _shuffle_one_axis(cols, valid, coord, axis,
                                                      caps[k])
        overflow.append(ov)
        need.append(max_load.astype(jnp.int32))
    return cols, valid, jnp.stack(overflow), jnp.stack(need)


class ShuffleSlots:
    """Double-buffered shuffle staging: issue a shuffle collective AHEAD of
    the join that consumes it.

    The distributed lowering walks the plan twice: a prestage pass calls
    `issue()` for every join input that (a) needs a shuffle and (b) is
    produced by a collective-free subtree (scans/filters/projections), then
    the join chain calls `take()` at each consuming site. Because the
    issued all_to_alls have no data dependency on earlier joins, they sit
    ahead of the whole join chain in program order — XLA's async
    collectives + latency-hiding scheduler can then run the shuffle for
    join step k+1 while step k's local Algorithm-1 join is still computing,
    instead of serialising collective -> join -> collective -> join.
    """

    def __init__(self):
        self._slots: dict = {}

    def issue(self, slot, cols, valid, key_idx, axis_names, caps) -> None:
        assert slot not in self._slots, slot
        self._slots[slot] = shuffle_by_key(
            cols, valid, key_idx, axis_names, caps
        )

    def ready(self, slot) -> bool:
        return slot in self._slots

    def take(self, slot):
        """(cols, valid, overflowed, need) of a previously issued shuffle."""
        return self._slots.pop(slot)


def distributed_mr_join(
    left: Relation,
    right: Relation,
    axis_names: tuple[str, ...],
    bucket_capacity: int,
    join_capacity: int,
):
    """Shuffle both sides by join key, then local Algorithm 1 per shard.

    Runs inside shard_map; each shard enters holding an arbitrary horizontal
    slice of both relations and exits holding the join results for its hash
    range. Returns (Relation, local_total, overflowed-any-stage).
    """
    key_vars = mj.shared_vars(left, right)
    if not key_vars:
        raise ValueError("distributed cross join not supported")
    l_idx = [left.schema.index(v) for v in key_vars]
    r_idx = [right.schema.index(v) for v in key_vars]
    l_cols, l_valid, ov_l, _ = shuffle_by_key(left.cols, left.valid, l_idx,
                                              axis_names, bucket_capacity)
    r_cols, r_valid, ov_r, _ = shuffle_by_key(right.cols, right.valid, r_idx,
                                              axis_names, bucket_capacity)
    l_rel = Relation(left.schema, l_cols, l_valid)
    r_rel = Relation(right.schema, r_cols, r_valid)
    out, total, ov_j = mj.mr_join(l_rel, r_rel, join_capacity)
    return out, total, jnp.any(ov_l) | jnp.any(ov_r) | ov_j


def make_distributed_join_fn(mesh: jax.sharding.Mesh,
                             axis_names: tuple[str, ...],
                             bucket_capacity: int, join_capacity: int,
                             left_schema: tuple[str, ...],
                             right_schema: tuple[str, ...]):
    """shard_mapped join over `mesh` (rows sharded on axes), not yet jitted."""
    from jax.sharding import PartitionSpec as P

    row_spec = P(axis_names)
    specs_in = (
        Relation(left_schema, row_spec, row_spec),
        Relation(right_schema, row_spec, row_spec),
    )
    out_schema = tuple(left_schema) + tuple(
        v for v in right_schema if v not in left_schema
    )
    specs_out = (Relation(out_schema, row_spec, row_spec), P(axis_names), P(axis_names))

    def local_fn(left: Relation, right: Relation):
        out, total, ov = distributed_mr_join(left, right, axis_names,
                                             bucket_capacity, join_capacity)
        return out, total[None], ov[None]

    return compat.shard_map(local_fn, mesh=mesh, in_specs=specs_in,
                            out_specs=specs_out, check_vma=False)


def make_distributed_join(mesh: jax.sharding.Mesh, axis_names: tuple[str, ...],
                          bucket_capacity: int, join_capacity: int,
                          left_schema: tuple[str, ...], right_schema: tuple[str, ...]):
    """Build a jit'd shard_mapped join over `mesh` (rows sharded on axes)."""
    return jax.jit(
        make_distributed_join_fn(mesh, axis_names, bucket_capacity,
                                 join_capacity, left_schema, right_schema)
    )
