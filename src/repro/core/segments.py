"""Segment machinery shared by the MR join, MoE dispatch, GNN aggregation
and embedding-bag: everything downstream of "sort by key" reasons in
contiguous segments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_rank_two_sided(left_keys: jax.Array, right_keys: jax.Array):
    """Dense-rank multi-column keys jointly across two relations.

    Returns int32 ranks (l_rank, r_rank) such that rows from either side have
    equal rank iff their key tuples are equal, and ranks are ordered
    lexicographically. This reduces multi-variable SPARQL joins to a
    single-int32-key join without 64-bit packing.

    left_keys: (n_l, k) int32, right_keys: (n_r, k) int32.
    """
    n_l = left_keys.shape[0]
    all_keys = jnp.concatenate([left_keys, right_keys], axis=0)
    # lexsort: primary key is column 0 -> pass columns reversed.
    order = jnp.lexsort(tuple(all_keys[:, c] for c in reversed(range(all_keys.shape[1]))))
    sorted_keys = all_keys[order]
    new_group = jnp.any(sorted_keys != jnp.roll(sorted_keys, 1, axis=0), axis=1)
    new_group = new_group.at[0].set(True)
    rank_sorted = jnp.cumsum(new_group.astype(jnp.int32)) - 1
    ranks = jnp.zeros(all_keys.shape[0], jnp.int32).at[order].set(rank_sorted)
    return ranks[:n_l], ranks[n_l:]


def segment_offsets_from_sorted(sorted_ids: jax.Array, num_segments: int):
    """Start offsets of each segment id in a sorted id array.

    offsets has length num_segments + 1; segment s occupies
    [offsets[s], offsets[s+1]).
    """
    return jnp.searchsorted(
        sorted_ids, jnp.arange(num_segments + 1, dtype=sorted_ids.dtype)
    ).astype(jnp.int32)


def counts_to_segment_ids(counts: jax.Array, total: int):
    """Inverse of bincount for sorted data: e.g. [2,0,3] -> [0,0,2,2,2].

    `total` is the static output length; positions beyond sum(counts) get id
    = len(counts) (one past the last segment) so callers can mask them.
    """
    starts = jnp.cumsum(counts) - counts
    out = jnp.zeros((total,), jnp.int32)
    # scatter-add 1 at each segment start (dropping empty segments whose
    # start == start of the next non-empty one handled by add semantics).
    out = out.at[starts].add(jnp.where(counts > 0, 1, 0).astype(jnp.int32), mode="drop")
    ids = jnp.cumsum(out) - 1
    valid_len = jnp.sum(counts)
    return jnp.where(jnp.arange(total) < valid_len, ids, len(counts)).astype(jnp.int32)


def sorted_segment_sum(data: jax.Array, sorted_ids: jax.Array, num_segments: int):
    """segment_sum specialised to sorted ids (the post-shuffle MapSQ reduce)."""
    return jax.ops.segment_sum(
        data, sorted_ids, num_segments=num_segments, indices_are_sorted=True
    )


def segment_softmax(scores: jax.Array, segment_ids: jax.Array, num_segments: int):
    """Numerically-stable softmax within segments (GAT edge softmax)."""
    seg_max = jax.ops.segment_max(scores, segment_ids, num_segments=num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = scores - seg_max[segment_ids]
    expd = jnp.exp(shifted)
    seg_sum = jax.ops.segment_sum(expd, segment_ids, num_segments=num_segments)
    return expd / jnp.maximum(seg_sum[segment_ids], 1e-30)
