"""Compiled executor: lower a PhysicalPlan to ONE jitted device program.

The eager engine dispatches per join (count pass, host sync, expand pass).
This module instead lowers the whole plan tree — every MapReduce join, the
cross joins, OPTIONAL left joins, FILTER masks, projection, DISTINCT and
LIMIT/OFFSET — into a single function of the scan relations plus the
runtime constants, then AOT-compiles it with `jax.jit(...).lower(...)
.compile()`.

A warm query is therefore exactly one device dispatch. The per-join exact
totals and overflow flags ride back in that same dispatch, so the host's
only synchronisation is reading the flags afterwards; when a bucket
overflowed, the engine grows it (plan_ir.grow_join_caps) and recompiles —
the Mars double-on-overflow discipline demoted to a rare fallback.

Runtime constants keep the cache hot across query variants: FILTER
comparison constants arrive as `consts_i` (term ids) / `consts_f` (numeric
values), LIMIT/OFFSET ride at the tail of `consts_i`, and `num_vals` is
the store's per-term numeric table — all plain inputs, none baked into the
executable.

AOT compilation (rather than relying on jit's implicit cache) keeps the
compile count observable: `compile_plan` / `compile_plan_batched` are the
only places XLA compilation happens, so ExecStats.n_compiles is exact and
tests can assert a warm cache compiles nothing.

`lower_batched` / `compile_plan_batched` stack W same-shape queries into
ONE device dispatch: the plan program is vmapped over the scan relations
and runtime constants (leading batch axis), with a lane-validity mask so
padded lanes contribute no rows and no overflow flags.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import matrix_join as mxj
from repro.core import mr_join as mj
from repro.core.plan_ir import (
    CrossJoin,
    Distinct,
    Filter,
    LeftJoin,
    MatrixJoin,
    MRJoin,
    PhysicalPlan,
    PlanNode,
    Project,
    Scan,
    Slice,
    UnionAll,
)
from repro.core.relation import Relation


class ChainResult(NamedTuple):
    """Everything one dispatch returns (all device-resident)."""

    relation: Relation
    totals: jax.Array  # (n_joins,) exact per-join cardinality
    overflows: jax.Array  # (n_joins,) bool: join i truncated its output


def lower(
    plan: PhysicalPlan, use_kernel: bool = False
) -> Callable[..., ChainResult]:
    """Plan tree -> a pure function of (scans, consts_i, consts_f, num_vals).

    Join totals/overflows are collected in evaluation (post-)order: the
    required chain first, then each OPTIONAL group's inner joins followed
    by its left join — the order the engine calibrates join_caps in.
    """

    def run(
        scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
    ) -> ChainResult:
        totals: list[jax.Array] = []
        flags: list[jax.Array] = []
        # The plan may be a DAG: UNION branches share the required-chain
        # subtree. Memoising by node identity evaluates the shared subtree
        # once, so its join totals/overflows are reported exactly once (in
        # first-visit order — the order the engine calibrates join_caps in).
        memo: dict[int, Relation] = {}

        def eval_node(node: PlanNode) -> Relation:
            hit = memo.get(id(node))
            if hit is not None:
                return hit
            rel = _eval(node)
            memo[id(node)] = rel
            return rel

        def _eval(node: PlanNode) -> Relation:
            if isinstance(node, Scan):
                return scans[node.index]
            if isinstance(node, (MRJoin, MatrixJoin)):
                left = eval_node(node.left)
                right = eval_node(node.right)
                join = (
                    mxj.matrix_join if isinstance(node, MatrixJoin)
                    else mj.mr_join
                )
                out, total, ovf = join(
                    left, right, capacity=node.capacity, use_kernel=use_kernel
                )
                totals.append(total)
                flags.append(ovf)
                return out
            if isinstance(node, CrossJoin):
                left = eval_node(node.left)
                right = eval_node(node.right)
                out, total, ovf = mj.cross_join(
                    left, right, capacity=node.capacity
                )
                totals.append(total)
                flags.append(ovf)
                return mj.compact(out)
            if isinstance(node, LeftJoin):
                left = eval_node(node.left)
                right = eval_node(node.right)
                ljoin = (
                    mxj.matrix_left_join if node.backend == "matrix"
                    else mj.left_join
                )
                out, total, ovf = ljoin(
                    left, right, capacity=node.join_cap, use_kernel=use_kernel
                )
                totals.append(total)
                flags.append(ovf)
                return out
            if isinstance(node, Filter):
                child = eval_node(node.child)
                keep = mj.filter_mask(
                    child, node.conds, consts_i, consts_f, num_vals
                )
                return Relation(child.schema, child.cols, keep)
            if isinstance(node, UnionAll):
                kids = [eval_node(c) for c in node.children]
                return mj.union_all(kids, node.schema)
            if isinstance(node, Project):
                return eval_node(node.child).project(list(node.schema))
            if isinstance(node, Distinct):
                return mj.distinct(eval_node(node.child))
            if isinstance(node, Slice):
                child = eval_node(node.child)
                return mj.slice_valid(
                    child,
                    consts_i[node.offset_index],
                    consts_i[node.limit_index],
                )
            raise TypeError(f"unknown plan node {node!r}")

        rel = eval_node(plan.root)
        totals_arr = (
            jnp.stack(totals) if totals else jnp.zeros((0,), jnp.int32)
        )
        flags_arr = jnp.stack(flags) if flags else jnp.zeros((0,), bool)
        return ChainResult(rel, totals_arr, flags_arr)

    return run


def join_slot_nodes(plan: PhysicalPlan) -> list[PlanNode]:
    """The join nodes of a plan in slot order — the order `lower` appends
    their totals/overflow flags (post-order, shared DAG subtrees visited
    once, in first-visit order). EXPLAIN ANALYZE uses this to label each
    actuals slot with its physical operator; it MUST mirror `lower`'s
    traversal exactly or actuals would land on the wrong node."""
    slots: list[PlanNode] = []
    seen: set[int] = set()

    def walk(node: PlanNode) -> None:
        if id(node) in seen:
            return
        seen.add(id(node))
        for attr in ("left", "right", "child"):
            kid = getattr(node, attr, None)
            if kid is not None:
                walk(kid)
        for kid in getattr(node, "children", ()):
            walk(kid)
        if isinstance(node, (MRJoin, MatrixJoin, CrossJoin, LeftJoin)):
            slots.append(node)

    walk(plan.root)
    return slots


@dataclasses.dataclass
class CompiledPlan:
    """An XLA executable specialised on one (shape, join-caps) point."""

    plan: PhysicalPlan
    executable: Any  # jax.stages.Compiled
    n_joins: int

    def __call__(
        self,
        scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
    ) -> ChainResult:
        return self.executable(scans, consts_i, consts_f, num_vals)


def compile_plan(
    plan: PhysicalPlan,
    scans: tuple[Relation, ...],
    consts_i: jax.Array,
    consts_f: jax.Array,
    num_vals: jax.Array,
    use_kernel: bool = False,
) -> CompiledPlan:
    """AOT-compile the plan against the inputs' (static) shapes.

    The executable accepts any input tuple with the same schemas/capacities
    — i.e. every future query that hashes to the same PlanShape.
    """
    fn = jax.jit(lower(plan, use_kernel=use_kernel))
    executable = fn.lower(scans, consts_i, consts_f, num_vals).compile()
    return CompiledPlan(plan, executable, len(plan.join_caps))


# -- batched (stacked same-shape) execution -----------------------------------


def lower_batched(
    plan: PhysicalPlan,
    use_kernel: bool = False,
    scan_axes: "tuple[int | None, ...] | None" = None,
) -> Callable[..., ChainResult]:
    """Stacked variant of `lower`: one dispatch executes a whole lane batch
    of same-shape queries.

    Every per-query runtime input — the scan relations, `consts_i`,
    `consts_f` — gains a leading batch axis; the store-wide `num_vals`
    table stays shared. A `(width,)` bool `lane_active` mask marks which
    lanes carry real queries: an inactive (padding) lane has its scan
    validity zeroed before anything else runs, so no operator downstream —
    join expansion, OPTIONAL unmatched-left padding, UNION concatenation —
    can emit a valid row for it, and its overflow flags are suppressed so
    padding can never trigger a bucket regrow.

    `scan_axes` is the per-scan vmap axis: 0 for a stacked (width, cap,
    n_cols) buffer, None for a BROADCAST scan every lane shares — the
    same-query-different-FILTER batch ships each such scan's device buffer
    once instead of W stacked copies, cutting staging memory by the batch
    width at those positions. Default: all stacked.

    Lanes need NOT stage at their natural scan capacities: cross-shape
    padded stacking (engine._coalesce_groups) runs near-miss PlanShapes —
    same plan DAG, smaller pow-2 scan caps — through one executable by
    padding each lane's scans up to the group's max caps. Padding rows
    arrive valid=False, and every operator here is masked on validity, so
    a padded lane emits exactly the rows its natural shape would have.
    """
    base = lower(plan, use_kernel=use_kernel)
    axes = scan_axes if scan_axes is not None else (0,) * plan.n_scans

    def run_lane(
        scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
        active: jax.Array,
    ) -> ChainResult:
        masked = tuple(
            Relation(s.schema, s.cols, s.valid & active) for s in scans
        )
        rel, totals, flags = base(masked, consts_i, consts_f, num_vals)
        return ChainResult(rel, totals, flags & active)

    return jax.vmap(run_lane, in_axes=(tuple(axes), 0, 0, None, 0))


@dataclasses.dataclass
class CompiledBatch:
    """A width-W stacked executable for one (shape, join-caps) point.

    Same specialisation as CompiledPlan plus the batch width and the
    per-scan stacked/broadcast layout: any group of <= W same-shape
    queries whose scans stack the same way dispatches through it
    (trailing lanes padded, masked inactive)."""

    plan: PhysicalPlan
    width: int
    executable: Any  # jax.stages.Compiled
    scan_axes: "tuple[int | None, ...]" = ()

    def __call__(
        self,
        scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
        lane_active: jax.Array,
    ) -> ChainResult:
        return self.executable(scans, consts_i, consts_f, num_vals, lane_active)


def compile_plan_batched(
    plan: PhysicalPlan,
    scans: tuple[Relation, ...],
    consts_i: jax.Array,
    consts_f: jax.Array,
    num_vals: jax.Array,
    lane_active: jax.Array,
    use_kernel: bool = False,
    scan_axes: "tuple[int | None, ...] | None" = None,
) -> CompiledBatch:
    """AOT-compile the stacked variant at the inputs' batch width (scans
    at a None axis in `scan_axes` must arrive UNstacked, (cap, n_cols))."""
    if scan_axes is None:
        scan_axes = (0,) * plan.n_scans
    fn = jax.jit(
        lower_batched(plan, use_kernel=use_kernel, scan_axes=scan_axes)
    )
    executable = fn.lower(
        scans, consts_i, consts_f, num_vals, lane_active
    ).compile()
    return CompiledBatch(
        plan, int(lane_active.shape[0]), executable, tuple(scan_axes)
    )


def execute_plan(
    plan: PhysicalPlan,
    scans: tuple[Relation, ...],
    consts_i: jax.Array,
    consts_f: jax.Array,
    num_vals: jax.Array,
    use_kernel: bool = False,
) -> ChainResult:
    """Uncompiled (op-by-op) interpretation — for tests and debugging."""
    return lower(plan, use_kernel=use_kernel)(
        scans, consts_i, consts_f, num_vals
    )
