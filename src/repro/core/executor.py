"""Compiled executor: lower a PhysicalPlan to ONE jitted device program.

The eager engine dispatches per join (count pass, host sync, expand pass).
This module instead lowers the whole plan tree — every MapReduce join, the
cross joins, projection and DISTINCT — into a single function of the scan
relations, then AOT-compiles it with `jax.jit(...).lower(...).compile()`.

A warm query is therefore exactly one device dispatch. The per-join exact
totals and overflow flags ride back in that same dispatch, so the host's
only synchronisation is reading the flags afterwards; when a bucket
overflowed, the engine grows it (plan_ir.grow_join_caps) and recompiles —
the Mars double-on-overflow discipline demoted to a rare fallback.

AOT compilation (rather than relying on jit's implicit cache) keeps the
compile count observable: `compile_plan` is the only place XLA compilation
happens, so ExecStats.n_compiles is exact and tests can assert a warm
cache compiles nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import mr_join as mj
from repro.core.plan_ir import (
    CrossJoin,
    Distinct,
    MRJoin,
    PhysicalPlan,
    PlanNode,
    Project,
    Scan,
)
from repro.core.relation import Relation


class ChainResult(NamedTuple):
    """Everything one dispatch returns (all device-resident)."""

    relation: Relation
    totals: jax.Array  # (n_joins,) exact per-join cardinality
    overflows: jax.Array  # (n_joins,) bool: join i truncated its output


def lower(
    plan: PhysicalPlan, use_kernel: bool = False
) -> Callable[[tuple[Relation, ...]], ChainResult]:
    """Plan tree -> a pure function of the scan tuple (jit-able).

    Join totals/overflows are collected in evaluation (post-)order, which
    for the planner's left-deep chains is simply chain order.
    """

    def run(scans: tuple[Relation, ...]) -> ChainResult:
        totals: list[jax.Array] = []
        flags: list[jax.Array] = []

        def eval_node(node: PlanNode) -> Relation:
            if isinstance(node, Scan):
                return scans[node.index]
            if isinstance(node, MRJoin):
                left = eval_node(node.left)
                right = eval_node(node.right)
                out, total, ovf = mj.mr_join(
                    left, right, capacity=node.capacity, use_kernel=use_kernel
                )
                totals.append(total)
                flags.append(ovf)
                return out
            if isinstance(node, CrossJoin):
                left = eval_node(node.left)
                right = eval_node(node.right)
                out, total, ovf = mj.cross_join(
                    left, right, capacity=node.capacity
                )
                totals.append(total)
                flags.append(ovf)
                return mj.compact(out)
            if isinstance(node, Project):
                return eval_node(node.child).project(list(node.schema))
            if isinstance(node, Distinct):
                return mj.distinct(eval_node(node.child))
            raise TypeError(f"unknown plan node {node!r}")

        rel = eval_node(plan.root)
        totals_arr = (
            jnp.stack(totals) if totals else jnp.zeros((0,), jnp.int32)
        )
        flags_arr = jnp.stack(flags) if flags else jnp.zeros((0,), bool)
        return ChainResult(rel, totals_arr, flags_arr)

    return run


@dataclasses.dataclass
class CompiledPlan:
    """An XLA executable specialised on one (shape, join-caps) point."""

    plan: PhysicalPlan
    executable: Any  # jax.stages.Compiled
    n_joins: int

    def __call__(self, scans: tuple[Relation, ...]) -> ChainResult:
        return self.executable(scans)


def compile_plan(
    plan: PhysicalPlan,
    scans: tuple[Relation, ...],
    use_kernel: bool = False,
) -> CompiledPlan:
    """AOT-compile the plan against the scans' (static) shapes.

    The executable accepts any scan tuple with the same schemas/capacities —
    i.e. every future query that hashes to the same PlanShape.
    """
    fn = jax.jit(lower(plan, use_kernel=use_kernel))
    executable = fn.lower(scans).compile()
    return CompiledPlan(plan, executable, len(plan.join_caps))


def execute_plan(
    plan: PhysicalPlan,
    scans: tuple[Relation, ...],
    use_kernel: bool = False,
) -> ChainResult:
    """Uncompiled (op-by-op) interpretation — for tests and debugging."""
    return lower(plan, use_kernel=use_kernel)(scans)
