"""Dictionary-encoded relations: the tables MapSQ's Algorithm 1 joins.

A Relation is the JAX-native form of the paper's partial-match tables
(Table 1a/1b): a fixed-capacity buffer of int32 rows, one column per SPARQL
variable, plus a validity mask (static shapes are required under jit; the
mask is the Mars-style answer to dynamic result sizes).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel keys: invalid rows are sent to distinct, never-equal key values so
# they sort to the end and can never pair up across sides.
INVALID_LEFT = np.int32(2**31 - 1)
INVALID_RIGHT = np.int32(2**31 - 2)

# Term-id sentinel for variables an OPTIONAL group left unbound. Real term
# ids are dense non-negative ints, so -1 can never collide; FILTER masks and
# the result decoder treat it as "no binding".
UNBOUND = np.int32(-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Relation:
    """A dictionary-encoded relation with static capacity.

    Attributes:
      schema: variable name per column (aux data, static under jit).
      cols:   (capacity, n_cols) int32 term ids. A leading batch axis —
              (width, capacity, n_cols) — is allowed so stacked same-shape
              queries travel as one pytree through the vmapped executor.
      valid:  (capacity,) bool — rows beyond the real result are padding.
    """

    schema: tuple[str, ...]
    cols: jax.Array
    valid: jax.Array

    def __post_init__(self):
        if isinstance(self.cols, (np.ndarray, jnp.ndarray)):
            assert self.cols.ndim >= 2, self.cols.shape
            assert len(self.schema) == self.cols.shape[-1], (
                self.schema,
                self.cols.shape,
            )

    # -- pytree protocol -----------------------------------------------------
    def tree_flatten(self):
        return (self.cols, self.valid), self.schema

    @classmethod
    def tree_unflatten(cls, schema, children):
        cols, valid = children
        return cls(schema=tuple(schema), cols=cols, valid=valid)

    # -- convenience ---------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.cols.shape[-2]  # row axis (batch axis, if any, leads)

    @property
    def n_cols(self) -> int:
        return self.cols.shape[-1]

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))

    def column(self, var: str) -> jax.Array:
        return self.cols[:, self.schema.index(var)]

    def project(self, vars: Sequence[str]) -> "Relation":
        idx = [self.schema.index(v) for v in vars]
        return Relation(tuple(vars), self.cols[:, idx], self.valid)

    def to_numpy(self) -> np.ndarray:
        """Compact valid rows to host (eager use only)."""
        cols = np.asarray(self.cols)
        valid = np.asarray(self.valid)
        return cols[valid]

    def to_set(self) -> set[tuple[int, ...]]:
        return {tuple(int(x) for x in row) for row in self.to_numpy()}

    @classmethod
    def from_numpy(
        cls,
        schema: Sequence[str],
        rows: np.ndarray,
        capacity: int | None = None,
    ) -> "Relation":
        rows = np.asarray(rows, dtype=np.int32).reshape(len(rows), len(schema))
        capacity = capacity or max(1, len(rows))
        assert capacity >= len(rows)
        cols = np.zeros((capacity, len(schema)), dtype=np.int32)
        cols[: len(rows)] = rows
        valid = np.zeros((capacity,), dtype=bool)
        valid[: len(rows)] = True
        return cls(tuple(schema), jnp.asarray(cols), jnp.asarray(valid))


def pad_to(rel: Relation, capacity: int) -> Relation:
    """The same relation at a larger static capacity: appended rows are
    zero ids with valid=False, so every masked operator treats them as
    absent. This is the soundness basis of cross-shape padded stacking —
    a scan padded up to a bigger pow-2 bucket computes exactly the same
    result, just in a wider buffer. A no-op at equal capacity."""
    cur = rel.capacity
    if capacity == cur:
        return rel
    assert capacity > cur, (capacity, cur)
    pad = [(0, 0)] * (rel.cols.ndim - 2) + [(0, capacity - cur), (0, 0)]
    return Relation(
        rel.schema,
        jnp.pad(rel.cols, pad),
        jnp.pad(rel.valid, [p for p in pad[:-1]]),
    )


def shared_vars(a: Relation | Sequence[str], b: Relation | Sequence[str]) -> list[str]:
    sa = a.schema if isinstance(a, Relation) else tuple(a)
    sb = b.schema if isinstance(b, Relation) else tuple(b)
    return [v for v in sa if v in sb]
