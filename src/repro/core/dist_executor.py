"""Distributed executor: one shard_map dispatch for the whole plan tree.

`core/executor.py` lowers a PhysicalPlan to a single-device program; this
module lowers the SAME plan IR to a mesh program, so the parser, algebra,
optimizer, plan-shape cache and bucket-calibration layers above stay
unchanged. Inside the one `shard_map`-wrapped dispatch:

  * Scan    — reads the shard-local partition of the sharded store's flat
              (n_shards * cap) scan buffer (the in_spec splits on exactly
              the per-shard row blocks the store laid out);
  * MRJoin  — the paper's Map phase becomes a hash shuffle over the mesh
              (core/distributed.shuffle_by_key: bucketize + all_to_all on
              the join key), then each shard runs the local Algorithm-1
              sort/ReduceDuplicate join — the cascading map-side join
              pattern, one shuffle per join step;
  * LeftJoin— both sides shuffle by the shared vars, then the local
              left join; unmatched-left padding is globally correct
              because every left row meets ALL right rows of its key;
  * CrossJoin — the right side is all_gathered (replicated) and each
              shard crosses its local left slice against it;
  * Filter / Project / UnionAll — purely row-local, unchanged;
  * Distinct — rows are shuffled by a hash of ALL columns (equal rows
              co-locate) before the local dedup, at its own calibrated
              per-shard bucket — a tracked shuffle site, regrown from
              the exact need on skew like the join shuffles, so
              per-device DISTINCT memory shrinks with the mesh too;
  * Slice   — LIMIT/OFFSET against the GLOBAL valid-row rank: per-shard
              counts are all_gathered, each shard offsets its local
              cumulative rank by the rows on earlier shards (the order
              results gather to host in).

Everything dynamic rides back in the same dispatch, per shard: exact join
totals, join-bucket overflow flags, exact shuffle bucket needs (worst
per-destination load) and shuffle overflow flags. The engine's only host
sync reads the flags; on overflow it regrows the flagged bucket from the
exact per-shard numbers and recompiles — the single-device overflow/
regrow fallback, now per shard.

Static shapes are all PER-SHARD: scan caps, join bucket caps and shuffle
bucket caps describe one shard's slice, which is what makes the memory
footprint scale down with the mesh (the D1 benchmark asserts the
per-shard max join bucket sits strictly below the single-device bucket).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core import distributed as dj
from repro.core import mr_join as mj
from repro.core.plan_ir import (
    CrossJoin,
    Distinct,
    Filter,
    LeftJoin,
    MRJoin,
    PhysicalPlan,
    PlanNode,
    Project,
    Scan,
    Slice,
    UnionAll,
)
from repro.core.relation import Relation


class ShardedChainResult(NamedTuple):
    """Everything one sharded dispatch returns (device-resident).

    `relation` rows gather over shards (shard k's slice is row block k);
    the per-join and per-shuffle accounting keeps the shard axis so the
    host can regrow buckets from the worst shard's exact numbers.
    """

    relation: Relation  # rows sharded: (n_shards * cap_out, n_cols)
    totals: jax.Array  # (n_shards, n_joins) exact local join totals
    overflows: jax.Array  # (n_shards, n_joins) join bucket truncated
    shuffle_needs: jax.Array  # (n_shards, n_sites) exact worst dest load
    shuffle_flags: jax.Array  # (n_shards, n_sites) shuffle bucket dropped


def n_shuffle_sites(plan: PhysicalPlan) -> int:
    """Shuffle sites in evaluation order: one per join step (MRJoin /
    LeftJoin / CrossJoin — the cross join's slot is structural) plus one
    per Distinct (the shuffle that co-locates equal rows)."""
    from repro.core.plan_ir import child_nodes

    count = 0
    seen: set[int] = set()

    def walk(node: PlanNode) -> None:
        nonlocal count
        if id(node) in seen:
            return
        seen.add(id(node))
        for child in child_nodes(node):
            walk(child)
        if isinstance(node, (MRJoin, LeftJoin, CrossJoin, Distinct)):
            count += 1

    walk(plan.root)
    return count


def initial_shuffle_caps(
    plan: PhysicalPlan, n_shards: int, floor: int = 8
) -> tuple[int, ...]:
    """Starting shuffle bucket per site: the uniform-distribution
    estimate (worst input capacity / n_shards, pow-2 bucketed). Skewed
    keys overflow the first dispatch, which reports the exact need —
    one regrow converges, exactly like the join buckets."""
    from repro.core.plan_ir import bucket_capacity, child_nodes

    caps: list[int] = []
    seen: set[int] = set()

    def walk(node: PlanNode) -> None:
        if id(node) in seen:
            return
        seen.add(id(node))
        for child in child_nodes(node):
            walk(child)
        if isinstance(node, (MRJoin, LeftJoin, CrossJoin)):
            worst = max(node.left.capacity, node.right.capacity)
            caps.append(
                bucket_capacity(max(floor, -(-worst // n_shards)))
            )
        elif isinstance(node, Distinct):
            caps.append(
                bucket_capacity(
                    max(floor, -(-node.capacity // n_shards))
                )
            )

    walk(plan.root)
    return tuple(caps)


def lower_sharded(
    plan: PhysicalPlan,
    mesh: jax.sharding.Mesh,
    axis_names: tuple[str, ...],
    shuffle_caps: tuple[int, ...],
    use_kernel: bool = False,
) -> Callable[..., ShardedChainResult]:
    """Plan tree -> shard_mapped function of (scans, consts_i, consts_f,
    num_vals) with the same call signature as the single-device program.

    Join/shuffle accounting is collected in evaluation order — the same
    order `build_plan` consumes join_caps in. `shuffle_caps` carries one
    slot per shuffle site (`n_shuffle_sites`): the join steps in
    join_caps order (cross joins keep a structural slot whose cap is
    unused) plus one per Distinct node."""
    n_shards = 1
    for a in axis_names:
        n_shards *= mesh.shape[a]

    def flat_rank() -> jax.Array:
        rank = jnp.int32(0)
        for a in axis_names:
            rank = rank * compat.axis_size(a) + jax.lax.axis_index(a)
        return rank

    def gather_rows(x: jax.Array) -> jax.Array:
        """all_gather rows over the mesh, ordered by flat shard rank."""
        for a in reversed(axis_names):
            x = jax.lax.all_gather(x, a, axis=0, tiled=True)
        return x

    def local_run(
        scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
    ) -> ShardedChainResult:
        totals: list[jax.Array] = []
        flags: list[jax.Array] = []
        sh_needs: list[jax.Array] = []
        sh_flags: list[jax.Array] = []
        site = iter(shuffle_caps)
        memo: dict[int, Relation] = {}

        def shuffle(rel: Relation, key_vars, cap: int):
            idx = [rel.schema.index(v) for v in key_vars]
            cols, valid, ov, need = dj.shuffle_by_key(
                rel.cols, rel.valid, idx, axis_names, cap
            )
            return Relation(rel.schema, cols, valid), ov, need

        def eval_node(node: PlanNode) -> Relation:
            hit = memo.get(id(node))
            if hit is not None:
                return hit
            rel = _eval(node)
            memo[id(node)] = rel
            return rel

        def _eval(node: PlanNode) -> Relation:
            if isinstance(node, Scan):
                return scans[node.index]
            if isinstance(node, MRJoin):
                left = eval_node(node.left)
                right = eval_node(node.right)
                cap_sh = next(site)
                left, ov_l, need_l = shuffle(left, node.key_vars, cap_sh)
                right, ov_r, need_r = shuffle(right, node.key_vars, cap_sh)
                out, total, ovf = mj.mr_join(
                    left, right, capacity=node.capacity,
                    use_kernel=use_kernel,
                )
                totals.append(total)
                flags.append(ovf)
                sh_needs.append(jnp.maximum(need_l, need_r))
                sh_flags.append(ov_l | ov_r)
                return out
            if isinstance(node, CrossJoin):
                left = eval_node(node.left)
                right = eval_node(node.right)
                next(site)  # structural slot; a gather has no bucket
                r_all = Relation(
                    right.schema,
                    gather_rows(right.cols),
                    gather_rows(right.valid),
                )
                # every (local-left, global-right) position is enumerated:
                # exact, like the single-device cross join
                out, total, ovf = mj.cross_join(
                    left, r_all, capacity=left.capacity * r_all.capacity
                )
                totals.append(total)
                flags.append(ovf)
                sh_needs.append(jnp.int32(0))
                sh_flags.append(jnp.bool_(False))
                return mj.compact(out)
            if isinstance(node, LeftJoin):
                left = eval_node(node.left)
                right = eval_node(node.right)
                cap_sh = next(site)
                left, ov_l, need_l = shuffle(left, node.key_vars, cap_sh)
                right, ov_r, need_r = shuffle(right, node.key_vars, cap_sh)
                out, total, ovf = mj.left_join(
                    left, right, capacity=node.join_cap,
                    use_kernel=use_kernel,
                )
                totals.append(total)
                flags.append(ovf)
                sh_needs.append(jnp.maximum(need_l, need_r))
                sh_flags.append(ov_l | ov_r)
                return out
            if isinstance(node, Filter):
                child = eval_node(node.child)
                keep = mj.filter_mask(
                    child, node.conds, consts_i, consts_f, num_vals
                )
                return Relation(child.schema, child.cols, keep)
            if isinstance(node, UnionAll):
                kids = [eval_node(c) for c in node.children]
                return mj.union_all(kids, node.schema)
            if isinstance(node, Project):
                return eval_node(node.child).project(list(node.schema))
            if isinstance(node, Distinct):
                child = eval_node(node.child)
                cap_sh = next(site)
                if n_shards > 1 and child.n_cols:
                    # co-locate equal rows at a calibrated per-shard
                    # bucket (skew regrows from the exact need, like the
                    # join shuffles) — per-device DISTINCT memory shrinks
                    # with the mesh instead of re-materialising the
                    # global relation on every shard
                    child, ov, need = shuffle(
                        child, child.schema, cap_sh
                    )
                    sh_needs.append(need)
                    sh_flags.append(ov)
                else:
                    sh_needs.append(jnp.int32(0))
                    sh_flags.append(jnp.bool_(False))
                return mj.distinct(child)
            if isinstance(node, Slice):
                child = eval_node(node.child)
                count = child.count().astype(jnp.int32)
                counts = gather_rows(count[None])  # (n_shards,)
                my = flat_rank()
                prev = jnp.sum(
                    jnp.where(
                        jnp.arange(n_shards) < my, counts, 0
                    )
                )
                offset = consts_i[node.offset_index]
                limit = consts_i[node.limit_index]
                rank = prev + jnp.cumsum(child.valid.astype(jnp.int32))
                keep = (
                    child.valid
                    & (rank > offset)
                    & (rank <= offset + limit)
                )
                return Relation(child.schema, child.cols, keep)
            raise TypeError(f"unknown plan node {node!r}")

        rel = eval_node(plan.root)
        n_joins = len(totals)
        totals_arr = (
            jnp.stack(totals)[None] if totals
            else jnp.zeros((1, 0), jnp.int32)
        )
        flags_arr = (
            jnp.stack(flags)[None] if flags
            else jnp.zeros((1, 0), bool)
        )
        needs_arr = (
            jnp.stack(sh_needs)[None] if sh_needs
            else jnp.zeros((1, 0), jnp.int32)
        )
        sh_flags_arr = (
            jnp.stack(sh_flags)[None] if sh_flags
            else jnp.zeros((1, 0), bool)
        )
        assert n_joins == len(plan.join_caps), (n_joins, plan.join_caps)
        assert len(sh_needs) == len(shuffle_caps), (
            len(sh_needs), shuffle_caps,
        )
        return ShardedChainResult(
            rel, totals_arr, flags_arr, needs_arr, sh_flags_arr
        )

    row = P(axis_names)
    scan_specs = tuple(
        Relation(node_schema, row, row)
        for node_schema in _scan_schemas(plan)
    )
    rep = P()
    out_specs = ShardedChainResult(
        Relation(plan.root.schema, row, row), row, row, row, row
    )
    return compat.shard_map(
        local_run,
        mesh=mesh,
        in_specs=(scan_specs, rep, rep, rep),
        out_specs=out_specs,
        check_vma=False,
    )


def _scan_schemas(plan: PhysicalPlan) -> list[tuple[str, ...]]:
    """Scan schemas by scan index (for the in_spec pytree)."""
    from repro.core.plan_ir import child_nodes

    out: dict[int, tuple[str, ...]] = {}
    seen: set[int] = set()

    def walk(node: PlanNode) -> None:
        if id(node) in seen:
            return
        seen.add(id(node))
        if isinstance(node, Scan):
            out[node.index] = node.schema
        for child in child_nodes(node):
            walk(child)

    walk(plan.root)
    return [out[i] for i in range(plan.n_scans)]


@dataclasses.dataclass
class CompiledShardedPlan:
    """An XLA mesh executable specialised on one (shape, per-shard join
    caps, per-shard shuffle caps) point. Call-compatible with
    executor.CompiledPlan so the engine's cache entries can hold either."""

    plan: PhysicalPlan
    shuffle_caps: tuple[int, ...]
    n_shards: int
    executable: Any  # jax.stages.Compiled

    def __call__(
        self,
        scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
    ) -> ShardedChainResult:
        return self.executable(scans, consts_i, consts_f, num_vals)


def compile_sharded_plan(
    plan: PhysicalPlan,
    mesh: jax.sharding.Mesh,
    axis_names: tuple[str, ...],
    shuffle_caps: tuple[int, ...],
    scans: tuple[Relation, ...],
    consts_i: jax.Array,
    consts_f: jax.Array,
    num_vals: jax.Array,
    use_kernel: bool = False,
) -> CompiledShardedPlan:
    """AOT-compile the sharded program against the inputs' static shapes
    (compilation is the only XLA entry point, so the engine's n_compiles
    accounting stays exact — warm queries must report zero)."""
    n_shards = 1
    for a in axis_names:
        n_shards *= mesh.shape[a]
    fn = jax.jit(
        lower_sharded(
            plan, mesh, axis_names, shuffle_caps, use_kernel=use_kernel
        )
    )
    executable = fn.lower(scans, consts_i, consts_f, num_vals).compile()
    return CompiledShardedPlan(plan, shuffle_caps, n_shards, executable)
