"""Distributed executor: one shard_map dispatch for the whole plan tree.

`core/executor.py` lowers a PhysicalPlan to a single-device program; this
module lowers the SAME plan IR to a mesh program, so the parser, algebra,
optimizer, plan-shape cache and bucket-calibration layers above stay
unchanged.

The lowering is PARTITIONING-AWARE (the cascading map-side-join idea):
`analyze_plan` propagates a `Partitioning` property bottom-up — a
subject-variable Scan of the subject-hash sharded store starts hash-
partitioned on its subject column (the store routes by the SAME FNV-1a
hash `shuffle_by_key` routes by, so "partitioned on ?s" and "shuffled by
(?s,)" are the same physical placement), each join computes its output
partitioning, and a shuffle collective is emitted ONLY when an input's
partitioning does not already match the join key. A subject-subject star
join chain therefore runs with ZERO collectives: every step is a pure
map-side join. Inside the one `shard_map`-wrapped dispatch:

  * Scan    — reads the shard-local partition of the sharded store's flat
              (n_shards * cap) scan buffer (the in_spec splits on exactly
              the per-shard row blocks the store laid out); partitioned on
              its subject column when the subject is a variable;
  * MRJoin / MatrixJoin — per side: already aligned -> local (no
              collective); small right side -> all_gather it and keep the
              big left side in place (one-sided broadcast join);
              otherwise the paper's Map phase: a hash shuffle over the
              mesh (core/distributed.shuffle_by_key) — then each shard
              runs the local Algorithm-1 join (or the masked-SpMM matrix
              backend, which composes with elision unchanged);
  * LeftJoin— same strategy menu (only the RIGHT side may broadcast:
              unmatched-left padding is emitted per shard, so the left
              side must stay uniquely placed); unmatched-left padding is
              globally correct because every left row meets ALL right
              rows of its key;
  * CrossJoin — the right side is all_gathered (replicated) and each
              shard crosses its local left slice against it;
  * Filter / Project / UnionAll — purely row-local; Project keeps the
              partitioning property when the partition columns survive;
  * Distinct — elides its co-locating shuffle when the child is already
              hash-partitioned on any subset of its columns (equal rows
              agree on every column, so they already share a shard);
              otherwise rows shuffle by a hash of ALL columns at a
              calibrated per-shard bucket;
  * Slice   — LIMIT/OFFSET against the GLOBAL valid-row rank.

OVERLAP: before the join chain runs, every emitted shuffle whose input is
a collective-free subtree (scan/filter/project) is issued into a
`distributed.ShuffleSlots` double buffer. Those all_to_alls carry no data
dependency on earlier joins, so in program order they all sit ahead of
the chain and XLA's async collectives can run the shuffle for join k+1
while join k's local compute is still going.

Everything dynamic rides back in the same dispatch, per shard: exact join
totals, join-bucket overflow flags, exact shuffle bucket needs and
overflow flags — PER SITE AND PER MESH-AXIS STAGE, so an overflow regrows
only the overflowing stage's bucket (a skewed pod-stage load no longer
inflates the chip-stage buffers). Static shapes are all PER-SHARD, which
is what makes the memory footprint scale down with the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.core import distributed as dj
from repro.core import matrix_join as mxj
from repro.core import mr_join as mj
from repro.core.plan_ir import (
    CrossJoin,
    Distinct,
    Filter,
    LeftJoin,
    MatrixJoin,
    MRJoin,
    PhysicalPlan,
    PlanNode,
    Project,
    Scan,
    Slice,
    UnionAll,
    child_nodes,
)
from repro.core.relation import Relation

# global-row threshold below which a misaligned join input is replicated
# (all_gather) instead of shuffling BOTH sides: one collective moving few
# rows, and the big side's partitioning survives the join
DEFAULT_BROADCAST_ROWS = 2048


class ShardedChainResult(NamedTuple):
    """Everything one sharded dispatch returns (device-resident).

    `relation` rows gather over shards (shard k's slice is row block k);
    the per-join and per-shuffle accounting keeps the shard axis so the
    host can regrow buckets from the worst shard's exact numbers. The
    shuffle arrays carry one slot per site PER MESH-AXIS STAGE
    (n_sites * n_stages, site-major), so a hierarchical shuffle's stages
    regrow independently.
    """

    relation: Relation  # rows sharded: (n_shards * cap_out, n_cols)
    totals: jax.Array  # (n_shards, n_joins) exact local join totals
    overflows: jax.Array  # (n_shards, n_joins) join bucket truncated
    shuffle_needs: jax.Array  # (n_shards, n_sites * n_stages) worst load
    shuffle_flags: jax.Array  # (n_shards, n_sites * n_stages) dropped


# -- partitioning property (the map-side-join lattice) ------------------------


@dataclasses.dataclass(frozen=True)
class Partitioning:
    """Where a relation's rows live across the mesh.

    hash(cols)  — the row with values v over `cols` lives on shard
                  FNV1a(v) % n_shards (column ORDER matters: the hash is
                  over the tuple in this order — exactly
                  distributed.hash_keys' routing);
    replicated  — every shard holds every row (an all_gather output);
    unknown     — arbitrary placement (the lattice bottom).
    """

    kind: str  # "hash" | "replicated" | "unknown"
    cols: tuple[str, ...] = ()

    def __str__(self) -> str:
        if self.kind == "hash":
            return "hash(" + ",".join(self.cols) + ")"
        return self.kind


UNKNOWN = Partitioning("unknown")
REPLICATED = Partitioning("replicated")


def hash_part(cols) -> Partitioning:
    cols = tuple(cols)
    assert cols
    return Partitioning("hash", cols)


@dataclasses.dataclass(frozen=True)
class SiteStrategy:
    """One shuffle site's chosen physical data movement.

    op: "mr_join" | "matrix_join" | "left_join" | "cross_join" | "distinct"
    left / right: "local" (elided — input already aligned), "shuffle"
    (emitted collective), "broadcast" (small side all_gathered),
    "gather" (cross join's structural replication), "-" (no such side:
    distinct uses `left` for its only input).
    """

    op: str
    key: tuple[str, ...]
    left: str = "-"
    right: str = "-"

    @property
    def emitted(self) -> int:
        return int(self.left == "shuffle") + int(self.right == "shuffle")

    @property
    def elided(self) -> int:
        return int(self.left == "local") + int(self.right == "local")

    @property
    def broadcast(self) -> bool:
        return self.right == "broadcast"


def strategy_counts(strategies) -> dict[str, int]:
    """Aggregate emitted/elided/broadcast counts for stats and explain()."""
    return {
        "emitted": sum(s.emitted for s in strategies),
        "elided": sum(s.elided for s in strategies),
        "broadcast": sum(1 for s in strategies if s.broadcast),
    }


def format_strategy(st: SiteStrategy) -> str:
    """One shuffle site's data-movement decision as the explain() line."""
    if st.op == "cross_join":
        return "right side replicated (all_gather)"
    if st.op == "distinct":
        return (
            "shuffle by all columns (emitted)"
            if st.left == "shuffle"
            else "co-located already (shuffle elided)"
        )
    sides = []
    for name, action in (("left", st.left), ("right", st.right)):
        if action == "local":
            sides.append(f"{name} map-side (shuffle elided)")
        elif action == "shuffle":
            sides.append(f"{name} shuffle emitted")
        elif action == "broadcast":
            sides.append(f"{name} broadcast (all_gather)")
    return ", ".join(sides) + f" on key ({', '.join(st.key)})"


def analyze_plan(
    plan: PhysicalPlan,
    n_shards: int,
    broadcast_rows: int = DEFAULT_BROADCAST_ROWS,
) -> tuple[SiteStrategy, ...]:
    """Propagate Partitioning bottom-up and fix each site's strategy.

    Pure host-side static analysis (capacities and schemas only), so the
    engine can show the chosen/elided shuffles in explain() and count
    them in ExecStats without touching the device. Strategies are in
    shuffle-site order (`shuffle_site_nodes`). Rules:

      Scan      -> hash(subject col) when the subject is a variable
      Filter    -> child's (masks move no rows)
      Project   -> child's if every partition column survives, else unknown
      UnionAll  -> the common child partitioning, if all agree
      Join      -> per side "local" iff its partitioning == hash(key)
                   (trivially true at n_shards == 1); a misaligned small
                   right side broadcasts instead of shuffling both sides;
                   output is hash(key), or the left partitioning under a
                   broadcast (left rows never move)
      Distinct  -> "local" iff the child is hash-partitioned on a subset
                   of its columns (equal rows agree on every column, so
                   they co-locate already); else shuffle by all columns
      Slice     -> child's (global-rank masking moves no rows)
    """
    strategies: list[SiteStrategy] = []
    parts: dict[int, Partitioning] = {}

    def aligned(p: Partitioning, key: tuple[str, ...]) -> bool:
        return n_shards == 1 or (p.kind == "hash" and p.cols == key)

    def restrict(p: Partitioning, schema) -> Partitioning:
        if p.kind == "hash" and not all(c in schema for c in p.cols):
            return UNKNOWN  # a partition column was projected away
        return p

    def part(node: PlanNode) -> Partitioning:
        hit = parts.get(id(node))
        if hit is not None:
            return hit
        p = _part(node)
        parts[id(node)] = p
        return p

    def _part(node: PlanNode) -> Partitioning:
        if isinstance(node, Scan):
            if node.part_col >= 0:
                return hash_part((node.schema[node.part_col],))
            return UNKNOWN
        if isinstance(node, (MRJoin, MatrixJoin, LeftJoin)):
            pl = part(node.left)
            pr = part(node.right)
            key = tuple(node.key_vars)
            op = (
                "left_join" if isinstance(node, LeftJoin)
                else "matrix_join" if isinstance(node, MatrixJoin)
                else "mr_join"
            )
            left = "local" if aligned(pl, key) else "shuffle"
            right = "local" if aligned(pr, key) else "shuffle"
            if (
                left == "shuffle"
                and right == "shuffle"
                and node.right.capacity * n_shards <= broadcast_rows
            ):
                # replicate the small right side and keep every left row
                # in place (sound for LeftJoin too: each left row meets
                # ALL right rows of its key, and exists on exactly one
                # shard, so inner matches and unmatched padding are both
                # globally exact)
                left, right = "local", "broadcast"
                out = restrict(pl, node.schema)
            else:
                out = hash_part(key) if key else UNKNOWN
            strategies.append(SiteStrategy(op, key, left, right))
            return out
        if isinstance(node, CrossJoin):
            pl = part(node.left)
            part(node.right)  # visit: nested sites keep evaluation order
            strategies.append(
                SiteStrategy("cross_join", (), "local", "gather")
            )
            return restrict(pl, node.schema)
        if isinstance(node, Filter):
            return part(node.child)
        if isinstance(node, Project):
            return restrict(part(node.child), node.schema)
        if isinstance(node, UnionAll):
            ps = [part(c) for c in node.children]
            if ps and all(p == ps[0] for p in ps) and ps[0].kind == "hash":
                return restrict(ps[0], node.schema)
            return UNKNOWN
        if isinstance(node, Distinct):
            p = part(node.child)
            schema = tuple(node.schema)
            local = (
                n_shards == 1
                or not schema
                or (p.kind == "hash" and set(p.cols) <= set(schema))
            )
            strategies.append(
                SiteStrategy(
                    "distinct", schema, "local" if local else "shuffle"
                )
            )
            return p if local else hash_part(schema)
        if isinstance(node, Slice):
            return part(node.child)
        raise TypeError(f"unknown plan node {node!r}")

    part(plan.root)
    assert len(strategies) == n_shuffle_sites(plan)
    return tuple(strategies)


# -- shuffle-site enumeration -------------------------------------------------


def shuffle_site_nodes(plan: PhysicalPlan) -> list[PlanNode]:
    """Shuffle sites in evaluation (post-)order: one per join step (MRJoin
    / MatrixJoin / LeftJoin / CrossJoin — the cross join's slot is
    structural) plus one per Distinct. The id-dedup matches the
    evaluator's memoised first-visit order on DAG plans."""
    sites: list[PlanNode] = []
    seen: set[int] = set()

    def walk(node: PlanNode) -> None:
        if id(node) in seen:
            return
        seen.add(id(node))
        for child in child_nodes(node):
            walk(child)
        if isinstance(
            node, (MRJoin, MatrixJoin, LeftJoin, CrossJoin, Distinct)
        ):
            sites.append(node)

    walk(plan.root)
    return sites


def n_shuffle_sites(plan: PhysicalPlan) -> int:
    return len(shuffle_site_nodes(plan))


def n_shuffle_slots(plan: PhysicalPlan, n_stages: int) -> int:
    """Shuffle cap slots: one per site per mesh-axis stage (site-major)."""
    return n_shuffle_sites(plan) * n_stages


def initial_shuffle_caps(
    plan: PhysicalPlan,
    axis_sizes: "tuple[int, ...] | int",
    floor: int = 8,
) -> tuple[int, ...]:
    """Starting shuffle bucket per (site, stage): the uniform-distribution
    estimate — stage k routes rows to axis_sizes[k] destinations, so its
    per-destination load is ~worst-input / axis_sizes[k]. Skewed keys
    overflow the first dispatch, which reports the exact per-stage need —
    one regrow converges, exactly like the join buckets."""
    from repro.core.plan_ir import bucket_capacity

    if isinstance(axis_sizes, int):
        axis_sizes = (axis_sizes,)
    caps: list[int] = []
    for node in shuffle_site_nodes(plan):
        if isinstance(node, Distinct):
            worst = node.capacity
        else:
            worst = max(node.left.capacity, node.right.capacity)
        for size in axis_sizes:
            caps.append(bucket_capacity(max(floor, -(-worst // size))))
    return tuple(caps)


def _collective_free(node: PlanNode, memo: dict[int, bool]) -> bool:
    """True when evaluating `node` runs no collective (so its shuffle can
    be issued ahead of the whole join chain)."""
    hit = memo.get(id(node))
    if hit is not None:
        return hit
    if isinstance(
        node, (MRJoin, MatrixJoin, LeftJoin, CrossJoin, Distinct, Slice)
    ):
        free = False
    else:
        free = all(_collective_free(c, memo) for c in child_nodes(node))
    memo[id(node)] = free
    return free


# -- the lowering -------------------------------------------------------------


def _local_program(
    plan: PhysicalPlan,
    axis_names: tuple[str, ...],
    n_shards: int,
    shuffle_caps: tuple[int, ...],
    strategies: tuple[SiteStrategy, ...],
    use_kernel: bool = False,
) -> Callable[..., ShardedChainResult]:
    """The per-shard program (runs INSIDE shard_map): plan tree -> pure
    function of (scans, consts_i, consts_f, num_vals), accounting with a
    leading singleton shard axis for the out_specs to gather over."""
    n_stages = len(axis_names)
    site_nodes = shuffle_site_nodes(plan)
    site_of = {id(n): i for i, n in enumerate(site_nodes)}
    assert len(shuffle_caps) == len(site_nodes) * n_stages, (
        shuffle_caps, len(site_nodes), n_stages,
    )

    def site_caps(i: int) -> tuple[int, ...]:
        return tuple(shuffle_caps[i * n_stages:(i + 1) * n_stages])

    def flat_rank() -> jax.Array:
        rank = jnp.int32(0)
        for a in axis_names:
            rank = rank * compat.axis_size(a) + jax.lax.axis_index(a)
        return rank

    def gather_rows(x: jax.Array) -> jax.Array:
        """all_gather rows over the mesh, ordered by flat shard rank."""
        for a in reversed(axis_names):
            x = jax.lax.all_gather(x, a, axis=0, tiled=True)
        return x

    def local_run(
        scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
    ) -> ShardedChainResult:
        totals: list[jax.Array] = []
        flags: list[jax.Array] = []
        sh_needs: list = [None] * len(site_nodes)
        sh_flags: list = [None] * len(site_nodes)
        memo: dict[int, Relation] = {}
        slots = dj.ShuffleSlots()

        def zero_acct():
            return (
                jnp.zeros((n_stages,), jnp.int32),
                jnp.zeros((n_stages,), bool),
            )

        def shuffled(node: PlanNode, side: str, rel: Relation):
            """Shuffle one join input by the node's key — consuming the
            prestaged double-buffer slot when the overlap pass issued it."""
            slot = (id(node), side)
            caps = site_caps(site_of[id(node)])
            if slots.ready(slot):
                cols, valid, ov, need = slots.take(slot)
            else:
                idx = [rel.schema.index(v) for v in node.key_vars]
                cols, valid, ov, need = dj.shuffle_by_key(
                    rel.cols, rel.valid, idx, axis_names, caps
                )
            return Relation(rel.schema, cols, valid), ov, need

        def replicate(rel: Relation) -> Relation:
            return Relation(
                rel.schema, gather_rows(rel.cols), gather_rows(rel.valid)
            )

        def eval_node(node: PlanNode) -> Relation:
            hit = memo.get(id(node))
            if hit is not None:
                return hit
            rel = _eval(node)
            memo[id(node)] = rel
            return rel

        def _eval(node: PlanNode) -> Relation:
            if isinstance(node, Scan):
                return scans[node.index]
            if isinstance(node, (MRJoin, MatrixJoin, LeftJoin)):
                si = site_of[id(node)]
                st = strategies[si]
                left = eval_node(node.left)
                right = eval_node(node.right)
                need, ov_sh = zero_acct()
                if st.left == "shuffle":
                    left, ov, nd = shuffled(node, "left", left)
                    need, ov_sh = jnp.maximum(need, nd), ov_sh | ov
                if st.right == "shuffle":
                    right, ov, nd = shuffled(node, "right", right)
                    need, ov_sh = jnp.maximum(need, nd), ov_sh | ov
                elif st.right == "broadcast":
                    right = replicate(right)
                if isinstance(node, LeftJoin):
                    ljoin = (
                        mxj.matrix_left_join if node.backend == "matrix"
                        else mj.left_join
                    )
                    out, total, ovf = ljoin(
                        left, right, capacity=node.join_cap,
                        use_kernel=use_kernel,
                    )
                else:
                    join = (
                        mxj.matrix_join if isinstance(node, MatrixJoin)
                        else mj.mr_join
                    )
                    out, total, ovf = join(
                        left, right, capacity=node.capacity,
                        use_kernel=use_kernel,
                    )
                totals.append(total)
                flags.append(ovf)
                sh_needs[si], sh_flags[si] = need, ov_sh
                return out
            if isinstance(node, CrossJoin):
                si = site_of[id(node)]
                left = eval_node(node.left)
                right = eval_node(node.right)
                r_all = replicate(right)
                # every (local-left, global-right) position is enumerated:
                # exact, like the single-device cross join
                out, total, ovf = mj.cross_join(
                    left, r_all, capacity=left.capacity * r_all.capacity
                )
                totals.append(total)
                flags.append(ovf)
                sh_needs[si], sh_flags[si] = zero_acct()
                return mj.compact(out)
            if isinstance(node, Filter):
                child = eval_node(node.child)
                keep = mj.filter_mask(
                    child, node.conds, consts_i, consts_f, num_vals
                )
                return Relation(child.schema, child.cols, keep)
            if isinstance(node, UnionAll):
                kids = [eval_node(c) for c in node.children]
                return mj.union_all(kids, node.schema)
            if isinstance(node, Project):
                return eval_node(node.child).project(list(node.schema))
            if isinstance(node, Distinct):
                si = site_of[id(node)]
                st = strategies[si]
                child = eval_node(node.child)
                if st.left == "shuffle":
                    # co-locate equal rows at a calibrated per-shard
                    # bucket; elided when the child is already hash-
                    # partitioned on a subset of its columns
                    idx = list(range(child.n_cols))
                    cols, valid, ov, need = dj.shuffle_by_key(
                        child.cols, child.valid, idx, axis_names,
                        site_caps(si),
                    )
                    child = Relation(child.schema, cols, valid)
                    sh_needs[si], sh_flags[si] = need, ov
                else:
                    sh_needs[si], sh_flags[si] = zero_acct()
                return mj.distinct(child)
            if isinstance(node, Slice):
                child = eval_node(node.child)
                count = child.count().astype(jnp.int32)
                counts = gather_rows(count[None])  # (n_shards,)
                my = flat_rank()
                prev = jnp.sum(
                    jnp.where(
                        jnp.arange(n_shards) < my, counts, 0
                    )
                )
                offset = consts_i[node.offset_index]
                limit = consts_i[node.limit_index]
                rank = prev + jnp.cumsum(child.valid.astype(jnp.int32))
                keep = (
                    child.valid
                    & (rank > offset)
                    & (rank <= offset + limit)
                )
                return Relation(child.schema, child.cols, keep)
            raise TypeError(f"unknown plan node {node!r}")

        # overlap prestage: issue every emitted shuffle whose input is a
        # collective-free subtree BEFORE the join chain runs, so the
        # collective for join step k+1 is already in flight while step
        # k's local join computes (ShuffleSlots double buffering)
        free_memo: dict[int, bool] = {}
        for node in site_nodes:
            if not isinstance(node, (MRJoin, MatrixJoin, LeftJoin)):
                continue
            st = strategies[site_of[id(node)]]
            for side, child, action in (
                ("left", node.left, st.left),
                ("right", node.right, st.right),
            ):
                if action == "shuffle" and _collective_free(
                    child, free_memo
                ):
                    rel = eval_node(child)
                    idx = [rel.schema.index(v) for v in node.key_vars]
                    slots.issue(
                        (id(node), side), rel.cols, rel.valid, idx,
                        axis_names, site_caps(site_of[id(node)]),
                    )

        rel = eval_node(plan.root)
        n_joins = len(totals)
        totals_arr = (
            jnp.stack(totals)[None] if totals
            else jnp.zeros((1, 0), jnp.int32)
        )
        flags_arr = (
            jnp.stack(flags)[None] if flags
            else jnp.zeros((1, 0), bool)
        )
        assert all(x is not None for x in sh_needs), sh_needs
        needs_arr = (
            jnp.concatenate(sh_needs)[None] if sh_needs
            else jnp.zeros((1, 0), jnp.int32)
        )
        sh_flags_arr = (
            jnp.concatenate(sh_flags)[None] if sh_flags
            else jnp.zeros((1, 0), bool)
        )
        assert n_joins == len(plan.join_caps), (n_joins, plan.join_caps)
        return ShardedChainResult(
            rel, totals_arr, flags_arr, needs_arr, sh_flags_arr
        )

    return local_run


def _mesh_shards(mesh: jax.sharding.Mesh, axis_names) -> int:
    n = 1
    for a in axis_names:
        n *= mesh.shape[a]
    return n


def lower_sharded(
    plan: PhysicalPlan,
    mesh: jax.sharding.Mesh,
    axis_names: tuple[str, ...],
    shuffle_caps: tuple[int, ...],
    use_kernel: bool = False,
    broadcast_rows: int = DEFAULT_BROADCAST_ROWS,
) -> Callable[..., ShardedChainResult]:
    """Plan tree -> shard_mapped function of (scans, consts_i, consts_f,
    num_vals) with the same call signature as the single-device program.

    Join/shuffle accounting is collected in evaluation order — the same
    order `build_plan` consumes join_caps in. `shuffle_caps` carries
    n_shuffle_slots(plan, len(axis_names)) entries: per shuffle site
    (join steps in join_caps order — cross joins keep a structural slot —
    plus one per Distinct), one bucket per mesh-axis stage."""
    n_shards = _mesh_shards(mesh, axis_names)
    strategies = analyze_plan(plan, n_shards, broadcast_rows)
    local_run = _local_program(
        plan, axis_names, n_shards, shuffle_caps, strategies,
        use_kernel=use_kernel,
    )
    row = P(axis_names)
    scan_specs = tuple(
        Relation(node_schema, row, row)
        for node_schema in _scan_schemas(plan)
    )
    rep = P()
    out_specs = ShardedChainResult(
        Relation(plan.root.schema, row, row), row, row, row, row
    )
    return compat.shard_map(
        local_run,
        mesh=mesh,
        in_specs=(scan_specs, rep, rep, rep),
        out_specs=out_specs,
        check_vma=False,
    )


def _scan_schemas(plan: PhysicalPlan) -> list[tuple[str, ...]]:
    """Scan schemas by scan index (for the in_spec pytree)."""
    out: dict[int, tuple[str, ...]] = {}
    seen: set[int] = set()

    def walk(node: PlanNode) -> None:
        if id(node) in seen:
            return
        seen.add(id(node))
        if isinstance(node, Scan):
            out[node.index] = node.schema
        for child in child_nodes(node):
            walk(child)

    walk(plan.root)
    return [out[i] for i in range(plan.n_scans)]


@dataclasses.dataclass
class CompiledShardedPlan:
    """An XLA mesh executable specialised on one (shape, per-shard join
    caps, per-shard per-stage shuffle caps) point. Call-compatible with
    executor.CompiledPlan so the engine's cache entries can hold either.
    `strategies` records each site's chosen data movement (emitted /
    elided / broadcast) for stats and explain()."""

    plan: PhysicalPlan
    shuffle_caps: tuple[int, ...]
    n_shards: int
    executable: Any  # jax.stages.Compiled
    strategies: tuple[SiteStrategy, ...] = ()

    def __call__(
        self,
        scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
    ) -> ShardedChainResult:
        return self.executable(scans, consts_i, consts_f, num_vals)


def compile_sharded_plan(
    plan: PhysicalPlan,
    mesh: jax.sharding.Mesh,
    axis_names: tuple[str, ...],
    shuffle_caps: tuple[int, ...],
    scans: tuple[Relation, ...],
    consts_i: jax.Array,
    consts_f: jax.Array,
    num_vals: jax.Array,
    use_kernel: bool = False,
    broadcast_rows: int = DEFAULT_BROADCAST_ROWS,
) -> CompiledShardedPlan:
    """AOT-compile the sharded program against the inputs' static shapes
    (compilation is the only XLA entry point, so the engine's n_compiles
    accounting stays exact — warm queries must report zero)."""
    n_shards = _mesh_shards(mesh, axis_names)
    fn = jax.jit(
        lower_sharded(
            plan, mesh, axis_names, shuffle_caps, use_kernel=use_kernel,
            broadcast_rows=broadcast_rows,
        )
    )
    executable = fn.lower(scans, consts_i, consts_f, num_vals).compile()
    return CompiledShardedPlan(
        plan, shuffle_caps, n_shards, executable,
        analyze_plan(plan, n_shards, broadcast_rows),
    )


# -- batched (lanes x shards) execution ---------------------------------------


def lower_sharded_batched(
    plan: PhysicalPlan,
    mesh: jax.sharding.Mesh,
    axis_names: tuple[str, ...],
    shuffle_caps: tuple[int, ...],
    scan_axes: tuple,
    use_kernel: bool = False,
    broadcast_rows: int = DEFAULT_BROADCAST_ROWS,
) -> Callable[..., ShardedChainResult]:
    """Stacked variant of `lower_sharded`: ONE mesh dispatch executes a
    whole lane batch of warm same-shape queries (lanes x shards), the
    distributed mirror of executor.lower_batched.

    Inside shard_map the per-shard program is vmapped over the lane axis;
    the shuffle/gather collectives batch under vmap (each lane's
    all_to_all rides the same launch). `scan_axes` is the per-scan vmap
    axis: 0 for a (width, n_shards * cap, n_cols) stacked buffer, None
    for a broadcast scan every lane shares. A `(width,)` bool
    `lane_active` mask zeroes padding lanes' scan validity and overflow
    flags, so padding can never emit rows or trigger a regrow."""
    n_shards = _mesh_shards(mesh, axis_names)
    strategies = analyze_plan(plan, n_shards, broadcast_rows)
    local_run = _local_program(
        plan, axis_names, n_shards, shuffle_caps, strategies,
        use_kernel=use_kernel,
    )

    def lane(
        scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
        active: jax.Array,
    ) -> ShardedChainResult:
        masked = tuple(
            Relation(s.schema, s.cols, s.valid & active) for s in scans
        )
        res = local_run(masked, consts_i, consts_f, num_vals)
        return ShardedChainResult(
            res.relation,
            res.totals,
            res.overflows & active,
            res.shuffle_needs,
            res.shuffle_flags & active,
        )

    local_batched = jax.vmap(
        lane, in_axes=(tuple(scan_axes), 0, 0, None, 0)
    )
    row = P(axis_names)
    lane_row = P(None, axis_names)
    scan_specs = tuple(
        Relation(
            schema,
            lane_row if ax == 0 else row,
            lane_row if ax == 0 else row,
        )
        for schema, ax in zip(_scan_schemas(plan), scan_axes)
    )
    rep = P()
    out_specs = ShardedChainResult(
        Relation(plan.root.schema, lane_row, lane_row),
        lane_row, lane_row, lane_row, lane_row,
    )
    return compat.shard_map(
        local_batched,
        mesh=mesh,
        in_specs=(scan_specs, rep, rep, rep, rep),
        out_specs=out_specs,
        check_vma=False,
    )


@dataclasses.dataclass
class CompiledShardedBatch:
    """A width-W lanes-x-shards mesh executable for one (shape, join caps,
    shuffle caps) point — any group of <= W warm same-shape queries whose
    scans stack the same way dispatches through it."""

    plan: PhysicalPlan
    width: int
    shuffle_caps: tuple[int, ...]
    n_shards: int
    executable: Any  # jax.stages.Compiled
    scan_axes: tuple = ()
    strategies: tuple[SiteStrategy, ...] = ()

    def __call__(
        self,
        scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
        lane_active: jax.Array,
    ) -> ShardedChainResult:
        return self.executable(
            scans, consts_i, consts_f, num_vals, lane_active
        )


def compile_sharded_plan_batched(
    plan: PhysicalPlan,
    mesh: jax.sharding.Mesh,
    axis_names: tuple[str, ...],
    shuffle_caps: tuple[int, ...],
    scans: tuple[Relation, ...],
    consts_i: jax.Array,
    consts_f: jax.Array,
    num_vals: jax.Array,
    lane_active: jax.Array,
    scan_axes: tuple,
    use_kernel: bool = False,
    broadcast_rows: int = DEFAULT_BROADCAST_ROWS,
) -> CompiledShardedBatch:
    """AOT-compile the stacked sharded program at the inputs' batch width
    (scans at a None axis in `scan_axes` arrive UNstacked)."""
    n_shards = _mesh_shards(mesh, axis_names)
    fn = jax.jit(
        lower_sharded_batched(
            plan, mesh, axis_names, shuffle_caps, tuple(scan_axes),
            use_kernel=use_kernel, broadcast_rows=broadcast_rows,
        )
    )
    executable = fn.lower(
        scans, consts_i, consts_f, num_vals, lane_active
    ).compile()
    return CompiledShardedBatch(
        plan,
        int(lane_active.shape[0]),
        shuffle_caps,
        n_shards,
        executable,
        tuple(scan_axes),
        analyze_plan(plan, n_shards, broadcast_rows),
    )
