"""Pure-jnp oracle for sorted segment sum."""
import jax


def sorted_segment_sum(data: jax.Array, ids: jax.Array, num_segments: int):
    return jax.ops.segment_sum(data, ids, num_segments=num_segments,
                               indices_are_sorted=True)
