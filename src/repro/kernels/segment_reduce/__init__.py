from repro.kernels.segment_reduce.ops import sorted_segment_sum  # noqa: F401
