"""Sorted segment sum as a one-hot MXU matmul — the shared Reduce phase.

Serves three consumers of the MapSQ reduce: GNN message aggregation
(edges sorted by destination), MoE combine (tokens sorted by expert), and
recsys embedding-bag (ids sorted by bag). On TPU the irregular scatter-add
becomes `onehot(ids).T @ data`, a 128x128 systolic matmul per tile — the
canonical TPU answer to reduce-by-key, and only viable BECAUSE the ids are
sorted/partitioned first (the paper's insight).

Tiling: rows are tiled (BLOCK_N x d) over a sequential grid; the (S x d)
output block stays resident in VMEM and accumulates across grid steps
(revisited output block, init on step 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 512


def _seg_sum_kernel(ids_ref, data_ref, out_ref, *, num_segments: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]
    data = data_ref[...]
    onehot = (
        ids[:, None] == jax.lax.iota(jnp.int32, num_segments)[None, :]
    ).astype(data.dtype)
    out_ref[...] += jnp.dot(
        onehot.T, data, preferred_element_type=out_ref.dtype
    )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "interpret")
)
def sorted_segment_sum_pallas(data: jax.Array, ids: jax.Array,
                              num_segments: int, *, interpret: bool = True):
    """data (n, d) float, ids (n,) int32 sorted; out (num_segments, d)."""
    n, d = data.shape
    assert n % BLOCK_N == 0, n
    kernel = functools.partial(_seg_sum_kernel, num_segments=num_segments)
    return pl.pallas_call(
        kernel,
        grid=(n // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), jnp.float32),
        interpret=interpret,
    )(ids, data)
