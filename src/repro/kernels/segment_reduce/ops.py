"""Public sorted-segment-sum API with padding + size-based fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.segment_reduce import kernel as _k
from repro.kernels.segment_reduce import ref as _ref

# Above this, the (S x d) one-hot accumulator would not fit VMEM; fall back.
_MAX_SEGMENTS = 4096


@functools.partial(jax.jit, static_argnames=("num_segments", "use_kernel",
                                              "interpret"))
def sorted_segment_sum(data: jax.Array, ids: jax.Array, num_segments: int, *,
                       use_kernel: bool = True, interpret: bool | None = None):
    """Sum rows of `data` by sorted segment id. ids >= num_segments drop."""
    n, d = data.shape
    if not use_kernel or num_segments > _MAX_SEGMENTS:
        return _ref.sorted_segment_sum(data, ids, num_segments)
    interpret = default_interpret() if interpret is None else interpret
    m = ((n + _k.BLOCK_N - 1) // _k.BLOCK_N) * _k.BLOCK_N
    pdata = jnp.zeros((m, d), data.dtype).at[:n].set(data)
    # out-of-range id => all-zero one-hot row => dropped (matches ref's drop)
    pids = jnp.full((m,), num_segments, jnp.int32).at[:n].set(ids.astype(jnp.int32))
    out = _k.sorted_segment_sum_pallas(pdata, pids, num_segments,
                                       interpret=interpret)
    return out.astype(data.dtype)
