"""Pallas TPU kernels for the MapSQ hot spots.

Each kernel package has:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, fallbacks, interpret auto-detect)
  ref.py    — pure-jnp oracle used by tests and by CPU-only paths

Kernels are validated in interpret mode on CPU (this container) and written
against TPU constraints: lane width 128, sublane 8, VMEM ~16 MB/core, MXU
128x128 matmul tiles, branch-free data-independent schedules.
"""

import jax


def default_interpret() -> bool:
    """Interpret Pallas on non-TPU backends so kernels run everywhere."""
    return jax.default_backend() != "tpu"
