"""Public sort API: padding, power-of-two handling, large-N fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import default_interpret
from repro.kernels.bitonic_sort import kernel as _k
from repro.kernels.bitonic_sort import ref as _ref

_MAX_KERNEL_N = 2**19  # ~4 MB keys+vals in VMEM, well under 16 MB
_PAD_KEY = np.int32(2**31 - 1)


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def sort_pairs(
    keys: jax.Array,
    vals: jax.Array,
    *,
    use_kernel: bool = True,
    interpret: bool | None = None,
):
    """Sort (keys, vals) by key ascending; any length, int32.

    Padding keys (INT32_MAX) sort to the end and are sliced off. NOTE: the
    bitonic network is not stable — equal keys may permute their payloads
    (callers in this codebase never rely on stability).
    """
    n = keys.shape[0]
    if not use_kernel or n > _MAX_KERNEL_N or n < 2:
        return _ref.sort_pairs(keys, vals)
    interpret = default_interpret() if interpret is None else interpret
    m = _next_pow2(n)
    pk = jnp.full((m,), _PAD_KEY, jnp.int32).at[:n].set(keys.astype(jnp.int32))
    pv = jnp.zeros((m,), jnp.int32).at[:n].set(vals.astype(jnp.int32))
    sk, sv = _k.bitonic_sort_pairs(pk, pv, interpret=interpret)
    return sk[:n], sv[:n]


def argsort_i32(keys: jax.Array, **kw) -> jax.Array:
    """Permutation sorting `keys` ascending (payload = row index)."""
    n = keys.shape[0]
    _, order = sort_pairs(keys, jnp.arange(n, dtype=jnp.int32), **kw)
    return order
