"""Pure-jnp oracle for the bitonic sort kernel."""
import jax
import jax.numpy as jnp


def sort_pairs(keys: jax.Array, vals: jax.Array):
    order = jnp.argsort(keys, stable=True)
    return keys[order], vals[order]


def argsort_i32(keys: jax.Array):
    return jnp.argsort(keys, stable=True).astype(jnp.int32)
