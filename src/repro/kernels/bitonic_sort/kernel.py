"""Bitonic sort of (key, payload) int32 pairs — the MapSQ Sort/shuffle phase.

TPU adaptation of the GPU sort in Mars/MapSQ: a bitonic network is branch-
free and data-independent, so every compare-exchange pass is a dense VPU op
on (8, 128) vector registers — no warp divergence analogue, no dynamic
memory. The whole array lives in VMEM (one block); each of the
log2(N)*(log2(N)+1)/2 passes is a reshape + select, unrolled at trace time.

For N beyond VMEM capacity ops.py falls back to XLA's sort (itself a bitonic
network on TPU); the kernel covers the per-shard working sets the join
actually sees after hash partitioning (<= 2^19 rows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(keys, vals, block: int, dist: int):
    """One bitonic pass: compare elements `dist` apart within `block` runs."""
    n = keys.shape[0]
    rows = n // (2 * dist)
    k2 = keys.reshape(rows, 2, dist)
    v2 = vals.reshape(rows, 2, dist)
    a_k, b_k = k2[:, 0, :], k2[:, 1, :]
    a_v, b_v = v2[:, 0, :], v2[:, 1, :]
    row_start = jnp.arange(rows, dtype=jnp.int32) * (2 * dist)
    asc = ((row_start // block) % 2 == 0)[:, None]
    swap = jnp.where(asc, a_k > b_k, a_k < b_k)
    lo_k = jnp.where(swap, b_k, a_k)
    hi_k = jnp.where(swap, a_k, b_k)
    lo_v = jnp.where(swap, b_v, a_v)
    hi_v = jnp.where(swap, a_v, b_v)
    keys = jnp.stack([lo_k, hi_k], axis=1).reshape(n)
    vals = jnp.stack([lo_v, hi_v], axis=1).reshape(n)
    return keys, vals


def _sort_kernel(keys_ref, vals_ref, out_k_ref, out_v_ref, *, n: int):
    keys = keys_ref[...]
    vals = vals_ref[...]
    stages = n.bit_length() - 1  # log2(n)
    for k in range(stages):
        block = 2 ** (k + 1)
        dist = block // 2
        while dist >= 1:
            keys, vals = _compare_exchange(keys, vals, block, dist)
            dist //= 2
    out_k_ref[...] = keys
    out_v_ref[...] = vals


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_pairs(keys: jax.Array, vals: jax.Array, *, interpret: bool = True):
    """Sort int32 (keys, vals) by key ascending. len must be a power of two."""
    n = keys.shape[0]
    assert n & (n - 1) == 0, f"bitonic length must be a power of two, got {n}"
    kernel = functools.partial(_sort_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(keys, vals)
