from repro.kernels.bitonic_sort.ops import argsort_i32, sort_pairs  # noqa: F401
