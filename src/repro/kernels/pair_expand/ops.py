"""Public pair-expand API with padding + fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.pair_expand import kernel as _k
from repro.kernels.pair_expand import ref as _ref


@functools.partial(jax.jit, static_argnames=("capacity", "use_kernel", "interpret"))
def pair_expand(prefix: jax.Array, counts: jax.Array, capacity: int, *,
                use_kernel: bool = True, interpret: bool | None = None):
    """For each output slot: (sorted-left row, offset within group, valid)."""
    if not use_kernel or prefix.shape[0] < 2:
        return _ref.pair_expand(prefix, counts, capacity)
    interpret = default_interpret() if interpret is None else interpret
    cap = ((capacity + _k.BLOCK - 1) // _k.BLOCK) * _k.BLOCK
    i, off, valid = _k.pair_expand_pallas(
        prefix.astype(jnp.int32), counts.astype(jnp.int32), cap,
        interpret=interpret)
    return i[:capacity], off[:capacity], valid[:capacity].astype(bool)
