"""ReduceDuplicate pair expansion — the MapSQ cartesian product, dense.

The paper's GPU ReduceDuplicate assigns one thread per output pair. The TPU
form: every output slot t inverts the inclusive prefix sum of per-left-row
match counts with a vectorized binary search (all lanes step the same
log2(n) schedule — branch-free), yielding its (left_row, offset) pair. The
result is a perfectly load-balanced gather regardless of join skew, which is
exactly the property the paper's flag/sort machinery buys on the GPU.

Tiling: the prefix/count arrays sit whole in VMEM (one int32 word per left
row — 4 MB covers a million-row shard); output slots are tiled (8, 128)
blocks over a 1-D grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024  # 8 sublanes x 128 lanes


def _pair_expand_kernel(prefix_ref, counts_ref, out_i_ref, out_off_ref,
                        out_valid_ref, *, n_left: int):
    t0 = pl.program_id(0) * BLOCK
    t = t0 + jax.lax.iota(jnp.int32, BLOCK)
    prefix = prefix_ref[...]
    counts = counts_ref[...]
    total = prefix[n_left - 1]
    # vectorized binary search: first i with prefix[i] > t
    lo = jnp.zeros((BLOCK,), jnp.int32)
    hi = jnp.full((BLOCK,), n_left, jnp.int32)
    for _ in range(max(1, n_left.bit_length())):
        mid = (lo + hi) // 2
        pm = jnp.take(prefix, jnp.clip(mid, 0, n_left - 1))
        pred = pm <= t
        lo = jnp.where(pred, mid + 1, lo)
        hi = jnp.where(pred, hi, mid)
    i = jnp.clip(lo, 0, n_left - 1)
    start = jnp.take(prefix, i) - jnp.take(counts, i)
    out_i_ref[...] = i
    out_off_ref[...] = t - start
    out_valid_ref[...] = (t < total).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def pair_expand_pallas(prefix: jax.Array, counts: jax.Array, capacity: int,
                       *, interpret: bool = True):
    """(prefix, counts) -> (left_sorted_row, offset_in_group, valid) per slot."""
    n_left = prefix.shape[0]
    assert capacity % BLOCK == 0
    kernel = functools.partial(_pair_expand_kernel, n_left=n_left)
    grid = (capacity // BLOCK,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_left,), lambda i: (0,)),
            pl.BlockSpec((n_left,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capacity,), jnp.int32),
            jax.ShapeDtypeStruct((capacity,), jnp.int32),
            jax.ShapeDtypeStruct((capacity,), jnp.int32),
        ],
        interpret=interpret,
    )(prefix, counts)
