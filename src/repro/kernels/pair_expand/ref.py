"""Pure-jnp oracle for pair expansion (the expand half of Algorithm 1)."""
import jax
import jax.numpy as jnp


def pair_expand(prefix: jax.Array, counts: jax.Array, capacity: int):
    n_left = prefix.shape[0]
    t = jnp.arange(capacity, dtype=jnp.int32)
    i = jnp.searchsorted(prefix, t, side="right").astype(jnp.int32)
    i = jnp.clip(i, 0, n_left - 1)
    start = prefix[i] - counts[i]
    total = prefix[-1]
    return i, t - start, (t < total)
