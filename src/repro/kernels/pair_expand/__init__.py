from repro.kernels.pair_expand.ops import pair_expand  # noqa: F401
