"""Pure-jnp oracle for the SpMM join reductions (blocked, O(n_l * n_r)).

`match_layout` is evaluated in fixed-height left-row blocks with a
per-column carry so peak memory is BLOCK_ROWS x n_r regardless of the
left side's size — the same sequential-grid accumulation the Pallas
kernel uses, minus the explicit VMEM placement. Small inputs (anything
the optimizer's dense cap admits) take a single fused compare tile.
"""
import jax
import jax.numpy as jnp

BLOCK_ROWS = 128
ONE_SHOT_ELEMS = 1 << 22  # full-tile path below this many compares


def _layout_tile(blk: jax.Array, right_keys: jax.Array, carry: jax.Array):
    """One left-row block of the layout reduction.

    Returns (counts, first, b) for the block and the updated per-column
    carry (running count of left matches per right row, i.e. the partial
    column sums of the eq tile over all left rows seen so far).
    """
    eq = (blk[:, None] == right_keys[None, :]).astype(jnp.int32)
    lt = (right_keys[None, :] < blk[:, None]).astype(jnp.int32)
    cume = jnp.cumsum(eq, axis=0) - eq + carry[None, :]
    counts = jnp.sum(eq, axis=1)
    first = jnp.sum(lt, axis=1)
    b = jnp.sum(eq * cume, axis=1)
    return counts, first, b, carry + jnp.sum(eq, axis=0)


def match_layout(
    left_keys: jax.Array, right_keys: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Everything the gather expansion needs, from ONE dense eq/lt pass:

      counts[i] = |{j : rk[j] == lk[i]}|       (SpMM row reduction)
      first[i]  = |{j : rk[j] <  lk[i]}|       (slot where row i's key
                                                begins in the key-ordered
                                                right side)
      b[i]      = counts[i] * |{i' < i : lk[i'] == lk[i]}|  (output slots
                  claimed by EARLIER left rows of the same key, via a
                  column-wise exclusive cumsum of the eq tile)
      cl[j]     = |{i : lk[i] == rk[j]}|       (column sums — per-right-row
                  match counts, the transpose reduction for free)

    Together: row i's outputs start at slot  prefix(cl, first[i]) + b[i]
    in mr_join's exact emission order (left rows in stable key order),
    with NO left-side sort or rank pass — zero-count rows occupy zero
    slots, so only matching rows need ordering and their keys all exist
    on the right side.
    """
    n_l, n_r = left_keys.shape[0], right_keys.shape[0]
    carry0 = jnp.zeros((n_r,), jnp.int32)
    if n_l * max(n_r, 1) <= ONE_SHOT_ELEMS:
        counts, first, b, cl = _layout_tile(left_keys, right_keys, carry0)
        return counts, first, b, cl

    n_pad = ((n_l + BLOCK_ROWS - 1) // BLOCK_ROWS) * BLOCK_ROWS
    kp = jnp.pad(left_keys, (0, n_pad - n_l))
    out0 = jnp.zeros((n_pad, 3), jnp.int32)

    def body(bi, state):
        acc, carry = state
        base = bi * BLOCK_ROWS
        blk = jax.lax.dynamic_slice(kp, (base,), (BLOCK_ROWS,))
        counts, first, b, carry = _layout_tile(blk, right_keys, carry)
        rows = jnp.stack([counts, first, b], axis=1)
        return jax.lax.dynamic_update_slice(acc, rows, (base, 0)), carry

    acc, cl = jax.lax.fori_loop(0, n_pad // BLOCK_ROWS, body, (out0, carry0))
    acc = acc[:n_l]
    # padded left rows (key 0) may have polluted cl; recompute their
    # contribution exactly: pad rows all share key 0, appended last.
    if n_pad != n_l:
        cl = cl - (n_pad - n_l) * (right_keys == 0).astype(jnp.int32)
    return acc[:, 0], acc[:, 1], acc[:, 2], cl


def sort_ranks(keys: jax.Array) -> jax.Array:
    """rank[j] = |{j' : keys[j'] < keys[j]}| + |{j' < j : keys[j'] == keys[j]}|
    — each row's STABLE sorted position (a permutation of 0..n-1), computed
    as a dense masked reduction instead of an argsort. Within one key group
    the ranks are contiguous and in buffer order, so rank[j] - group_start
    is the row's occurrence rank."""
    n = keys.shape[0]
    j_all = jnp.arange(n, dtype=jnp.int32)

    def count(blk, base):
        j = base + jnp.arange(blk.shape[0], dtype=jnp.int32)
        lt = keys[None, :] < blk[:, None]
        eq = blk[:, None] == keys[None, :]
        before = j_all[None, :] < j[:, None]
        return jnp.sum(lt | (eq & before), axis=1, dtype=jnp.int32)

    if n * max(n, 1) <= ONE_SHOT_ELEMS:
        return count(keys, 0)

    n_pad = ((n + BLOCK_ROWS - 1) // BLOCK_ROWS) * BLOCK_ROWS
    kp = jnp.pad(keys, (0, n_pad - n))
    out0 = jnp.zeros((n_pad,), jnp.int32)

    def body(b, acc):
        base = b * BLOCK_ROWS
        blk = jax.lax.dynamic_slice(kp, (base,), (BLOCK_ROWS,))
        return jax.lax.dynamic_update_slice(acc, count(blk, base), (base,))

    return jax.lax.fori_loop(0, n_pad // BLOCK_ROWS, body, out0)[:n]
