"""Masked SpMM primitives for the matrix join backend (core/matrix_join).

The gSMat/gSmart observation: a SPARQL equi-join over dictionary ids is a
sparse boolean matrix product. With L the (n_l x K) one-hot encoding of the
left key column and R the (K x n_r) one-hot encoding of the right keys,
`match_layout` reads the join's entire output layout off the implicit
product E = L @ R^T in one tiled pass:

  counts = E @ 1             — per-left-row match counts (SpMM row reduce)
  first  = LT @ 1            — slot where each left key's group begins in
           the key-ordered right side (LT[i,j] = [rk_j < lk_i])
  b      = (E * excl_cumsum_rows(E)) @ 1 — slots claimed by earlier
           same-key left rows
  cl     = 1 @ E             — per-right-row match counts (column reduce)

`sort_ranks` orders the (small) right side without an argsort: rank =
strict_lower(C) @ 1 where C[j, j'] = [k_j' < k_j] or ([k_j' == k_j] and
j' < j). The expansion is then pure gathers and scans over prefix sums
(see core/matrix_join.py) — no sort anywhere. The kernels never
materialise the one-hot forms — the products collapse to tiled key
compares, the shape the MXU/VPU wants.
"""
