"""Public SpMM-join reduction API with padding + fallback.

Padding values: the left side pads with INVALID_LEFT and the right side
with INVALID_RIGHT (the relation sentinels), which by construction never
equal a real dictionary id or dense rank — padded right rows therefore
contribute no spurious matches, and padded rows' own outputs are sliced
off before returning.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret
from repro.kernels.spmm_join import kernel as _k
from repro.kernels.spmm_join import ref as _ref

_PAD_LEFT = 2**31 - 1  # relation.INVALID_LEFT
_PAD_RIGHT = 2**31 - 2  # relation.INVALID_RIGHT


def _pad_to(x: jax.Array, multiple: int, value: int) -> jax.Array:
    n = x.shape[0]
    n_pad = ((n + multiple - 1) // multiple) * multiple
    return jnp.pad(x, (0, n_pad - n), constant_values=jnp.int32(value))


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def match_layout(left_keys: jax.Array, right_keys: jax.Array, *,
                 use_kernel: bool = True,
                 interpret: bool | None = None):
    """(counts[i], first[i], b[i], cl[j]): the full output layout of the
    join, from one dense eq/lt pass (see ref.match_layout).

    Right-side padding with INVALID_RIGHT is sound for every sum: no
    valid left key reaches the sentinels, so padded rows are neither
    equal to nor below any real left key. Left-side padding with
    INVALID_LEFT matches nothing on the right (so cl is clean) and sits
    after every real row (so no real row's b sees it)."""
    if not use_kernel or left_keys.shape[0] < 2 or right_keys.shape[0] < 2:
        return _ref.match_layout(left_keys, right_keys)
    interpret = default_interpret() if interpret is None else interpret
    lp = _pad_to(left_keys.astype(jnp.int32), _k.BLOCK, _PAD_LEFT)
    rp = _pad_to(right_keys.astype(jnp.int32), _k.CHUNK, _PAD_RIGHT)
    counts, first, b, cl = _k.match_layout_pallas(lp, rp, interpret=interpret)
    n_l, n_r = left_keys.shape[0], right_keys.shape[0]
    return counts[:n_l], first[:n_l], b[:n_l], cl[:n_r]


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def sort_ranks(keys: jax.Array, *, use_kernel: bool = True,
               interpret: bool | None = None) -> jax.Array:
    """rank[j] = the row's stable sorted position (a permutation of 0..n-1).

    Padding with INVALID_LEFT (int32 max) is sound for either side's keys:
    no real key exceeds it, and rows EQUAL to it (invalid-left sentinels)
    precede the pads in buffer order, so stability keeps every real row's
    rank inside 0..n-1 — padded rows rank strictly at the tail."""
    if not use_kernel or keys.shape[0] < 2:
        return _ref.sort_ranks(keys)
    interpret = default_interpret() if interpret is None else interpret
    kp = _pad_to(keys.astype(jnp.int32), _k.BLOCK, _PAD_LEFT)
    out = _k.sort_ranks_pallas(kp, interpret=interpret)
    return out[: keys.shape[0]]
