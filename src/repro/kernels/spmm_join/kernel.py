"""Pallas kernels for the SpMM join reductions.

Tiling: the comparison array (the right keys) sits whole in VMEM — one
int32 word per row, same budget argument as pair_expand's prefix array —
and the output rows are tiled in BLOCK-sized blocks over a 1-D grid. The
inner compare walks the VMEM-resident keys in CHUNK-wide slices, so the
live boolean tile is (BLOCK, CHUNK) — (8, 128)-aligned and far under the
VMEM ceiling — and every lane executes the same data-independent schedule
(no sort, no branches: this is the whole point of the matrix backend).

`match_layout` additionally carries a per-right-column running match
count across grid steps, accumulated in-place in its `cl` output block
(every grid step maps to block 0). TPU grids execute sequentially, so
the read-modify-write is well-defined — the same revisiting pattern as a
matmul's k-loop accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024  # output rows per grid step (8 sublanes x 128 lanes)
CHUNK = 256  # comparison-key slice width per inner step


def _match_layout_kernel(lk_ref, rk_ref, counts_ref, first_ref, b_ref,
                         cl_ref, *, n_right_pad: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        cl_ref[...] = jnp.zeros((n_right_pad,), jnp.int32)

    lk = lk_ref[...]  # (BLOCK,) this block's left keys
    rk = rk_ref[...]  # (n_right_pad,) all right keys
    counts = jnp.zeros((BLOCK,), jnp.int32)
    first = jnp.zeros((BLOCK,), jnp.int32)
    b = jnp.zeros((BLOCK,), jnp.int32)
    for c in range(n_right_pad // CHUNK):
        rc = rk[c * CHUNK:(c + 1) * CHUNK]
        carry = cl_ref[c * CHUNK:(c + 1) * CHUNK]
        eq = (lk[:, None] == rc[None, :]).astype(jnp.int32)
        lt = (rc[None, :] < lk[:, None]).astype(jnp.int32)
        cume = jnp.cumsum(eq, axis=0) - eq + carry[None, :]
        counts = counts + jnp.sum(eq, axis=1)
        first = first + jnp.sum(lt, axis=1)
        b = b + jnp.sum(eq * cume, axis=1)
        cl_ref[c * CHUNK:(c + 1) * CHUNK] = carry + jnp.sum(eq, axis=0)
    counts_ref[...] = counts
    first_ref[...] = first
    b_ref[...] = b


def _sort_ranks_kernel(keys_ref, blk_ref, out_ref, *, n_pad: int):
    base = pl.program_id(0) * BLOCK
    own = blk_ref[...]  # (BLOCK,) this block's keys
    keys = keys_ref[...]  # (n_pad,) all keys
    j = base + jax.lax.iota(jnp.int32, BLOCK)
    acc = jnp.zeros((BLOCK,), jnp.int32)
    for c in range(n_pad // CHUNK):
        kc = keys[c * CHUNK:(c + 1) * CHUNK]
        lt = kc[None, :] < own[:, None]
        eq = own[:, None] == kc[None, :]
        before = (c * CHUNK + jax.lax.iota(jnp.int32, CHUNK))[None, :] < j[:, None]
        acc = acc + jnp.sum((lt | (eq & before)).astype(jnp.int32), axis=1)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("interpret",))
def match_layout_pallas(left_keys: jax.Array, right_keys: jax.Array, *,
                        interpret: bool = True):
    """Per-left-row (counts, first, b) and per-right-row cl; inputs
    pre-padded to BLOCK / CHUNK. The right pad value must neither equal
    nor sit below any real left key, so padded right rows count into no
    sum; padded LEFT rows come after every real row, so their eq
    contributions to cl (none, by pad-value choice) and to later rows'
    cume (none — there are no later rows) are nil."""
    n_left, n_right = left_keys.shape[0], right_keys.shape[0]
    assert n_left % BLOCK == 0 and n_right % CHUNK == 0
    kernel = functools.partial(_match_layout_kernel, n_right_pad=n_right)
    return pl.pallas_call(
        kernel,
        grid=(n_left // BLOCK,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((n_right,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((n_right,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_left,), jnp.int32),
            jax.ShapeDtypeStruct((n_left,), jnp.int32),
            jax.ShapeDtypeStruct((n_left,), jnp.int32),
            jax.ShapeDtypeStruct((n_right,), jnp.int32),
        ],
        interpret=interpret,
    )(left_keys, right_keys)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_ranks_pallas(keys: jax.Array, *,
                      interpret: bool = True) -> jax.Array:
    """Per-row stable sorted position of its key; input pre-padded to
    BLOCK (the pad value must not be below any real key — padded rows sit
    at the tail of the ranking and real rows' ranks are unaffected)."""
    n = keys.shape[0]
    assert n % BLOCK == 0
    kernel = functools.partial(_sort_ranks_kernel, n_pad=n)
    return pl.pallas_call(
        kernel,
        grid=(n // BLOCK,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(keys, keys)
