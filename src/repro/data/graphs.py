"""Synthetic graph generators matching the assigned GNN shapes, plus the
CSR-backed minibatch pipeline (real neighbor sampling, fanout 15-10).

Edges are ALWAYS emitted sorted by dst — the MapSQ Sort phase executed once
at data-load time, so device-side aggregation is a sorted segment reduce.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.gnn.common import GraphBatch
from repro.models.gnn.sampler import CSRGraph, block_capacity, sample_block


def _pad_edges(src, dst, e_cap, n_sentinel):
    e = len(src)
    ps = np.full(e_cap, 0, np.int32)
    pd = np.full(e_cap, n_sentinel - 1, np.int32)
    ps[:e] = src
    pd[:e] = dst
    mask = np.zeros(e_cap, bool)
    mask[:e] = True
    return ps, pd, mask


def random_graph(rng: np.random.Generator, n: int, e: int,
                 sorted_dst: bool = True):
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    if sorted_dst:
        order = np.argsort(dst, kind="stable")
        src, dst = src[order], dst[order]
    return src, dst


def make_full_graph(arch: str, n: int, e: int, e_cap: int, d_feat: int,
                    n_classes: int, seed: int = 0,
                    extras_builder=None) -> GraphBatch:
    rng = np.random.default_rng(seed)
    src, dst = random_graph(rng, n, e)
    ps, pd, emask = _pad_edges(src, dst, e_cap, n)
    g = GraphBatch(
        node_feat=np.asarray(rng.normal(size=(n, d_feat)), np.float32),
        src=ps, dst=pd,
        node_mask=np.ones(n, bool), edge_mask=emask,
        graph_ids=np.zeros(n, np.int32),
        extras={},
    )
    return _with_extras(g, arch, rng, n, e_cap, n_classes)


def make_molecule_batch(arch: str, n_per: int, e_per: int, batch: int,
                        n_classes: int, seed: int = 0) -> GraphBatch:
    rng = np.random.default_rng(seed)
    n, e = n_per * batch, e_per * batch
    srcs, dsts, gids = [], [], []
    for b in range(batch):
        s, d = random_graph(rng, n_per, e_per)
        srcs.append(s + b * n_per)
        dsts.append(d + b * n_per)
        gids.append(np.full(n_per, b, np.int32))
    g = GraphBatch(
        node_feat=np.asarray(rng.normal(size=(n, 16)), np.float32),
        src=np.concatenate(srcs), dst=np.concatenate(dsts),
        node_mask=np.ones(n, bool), edge_mask=np.ones(e, bool),
        graph_ids=np.concatenate(gids),
        extras={},
    )
    return _with_extras(g, arch, rng, n, e, n_classes, n_graphs=batch)


def _with_extras(g: GraphBatch, arch: str, rng, n: int, e_cap: int,
                 n_classes: int, n_graphs: int = 1) -> GraphBatch:
    ex: dict = {}
    if arch == "gat-cora":
        ex["labels"] = rng.integers(0, n_classes, n).astype(np.int32)
        ex["train_mask"] = rng.random(n) < 0.3
    elif arch == "schnet":
        ex["positions"] = np.asarray(rng.normal(size=(n, 3)) * 3, np.float32)
        ex["species"] = rng.integers(1, 20, n).astype(np.int32)
        ex["energy"] = np.asarray(rng.normal(size=(n_graphs,)), np.float32)
        ex["graph_mask"] = np.ones(n_graphs, bool)
    elif arch == "meshgraphnet":
        ex["edge_feat"] = np.asarray(rng.normal(size=(e_cap, 4)), np.float32)
        ex["targets"] = np.asarray(rng.normal(size=(n, 3)), np.float32)
    elif arch == "graphcast":
        nm = max(8, n // 4)
        em = max(64, nm * 7)
        ms, md = random_graph(rng, nm, em)
        m2s = rng.integers(0, nm, e_cap).astype(np.int32)
        m2d = np.sort(rng.integers(0, n, e_cap).astype(np.int32))
        ex.update(
            mesh_feat_init=np.zeros((nm, 1), np.float32),
            g2m_feat=np.asarray(rng.normal(size=(e_cap, 4)), np.float32),
            mesh_edge_feat=np.asarray(rng.normal(size=(em, 4)), np.float32),
            mesh_src=ms, mesh_dst=md, mesh_mask=np.ones(em, bool),
            m2g_feat=np.asarray(rng.normal(size=(e_cap, 4)), np.float32),
            m2g_src=m2s, m2g_dst=m2d, m2g_mask=np.ones(e_cap, bool),
            # targets dim tracks the grid feature dim (= the model's n_vars)
            targets=np.asarray(
                rng.normal(size=(n, g.node_feat.shape[1])), np.float32),
        )
        # graphcast: GraphBatch.dst indexes MESH nodes (g2m edges)
        g = g._replace(dst=np.sort(rng.integers(0, nm, e_cap))
                       .astype(np.int32))
    return g._replace(extras=ex)


@dataclasses.dataclass
class MinibatchPipeline:
    """The minibatch_lg pipeline: CSR graph + layered neighbor sampling.

    RNG state advances deterministically with `step` (checkpointable).
    """

    arch: str
    n_nodes: int
    n_edges: int
    d_feat: int
    n_classes: int
    batch_nodes: int = 1024
    fanout: tuple[int, ...] = (15, 10)
    seed: int = 0
    step: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        src, dst = random_graph(rng, self.n_nodes, self.n_edges,
                                sorted_dst=False)
        self.csr = CSRGraph.from_edges(src, dst, self.n_nodes)
        self.feats = np.asarray(
            rng.normal(size=(self.n_nodes, self.d_feat)), np.float32
        )
        self.labels = rng.integers(0, self.n_classes, self.n_nodes).astype(
            np.int32
        )

    def __next__(self) -> GraphBatch:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 1, self.step])
        )
        seeds = rng.integers(0, self.n_nodes, self.batch_nodes)
        nodes, src, dst, emask = sample_block(self.csr, seeds,
                                              list(self.fanout), rng)
        n_cap, e_cap = block_capacity(self.batch_nodes, list(self.fanout))
        assert len(nodes) == n_cap and len(src) == e_cap
        train_mask = np.zeros(n_cap, bool)
        train_mask[: self.batch_nodes] = True
        g = GraphBatch(
            node_feat=self.feats[nodes],
            src=src.astype(np.int32), dst=dst.astype(np.int32),
            node_mask=np.ones(n_cap, bool), edge_mask=emask,
            graph_ids=np.zeros(n_cap, np.int32),
            extras={"labels": self.labels[nodes], "train_mask": train_mask},
        )
        self.step += 1
        return g

    def state_dict(self):
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, st):
        self.seed, self.step = int(st["seed"]), int(st["step"])
