"""Synthetic CTR stream for deepfm: zipf-distributed sparse ids (hot-key
skew like real logs), deterministic per (seed, step)."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CTRPipeline:
    n_sparse: int
    rows_per_field: int
    batch: int
    seed: int = 0
    step: int = 0

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )
        ids = (rng.zipf(1.2, size=(self.batch, self.n_sparse))
               % self.rows_per_field).astype(np.int32)
        # a planted linear signal so training has something to learn
        logit = (ids[:, 0] % 7 - 3) * 0.7 + rng.normal(size=self.batch) * 0.3
        labels = (logit > 0).astype(np.float32)
        return {"ids": ids, "labels": labels}

    def __next__(self):
        b = self.batch_at(self.step)
        self.step += 1
        return b

    def state_dict(self):
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, st):
        self.seed, self.step = int(st["seed"]), int(st["step"])
