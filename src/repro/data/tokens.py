"""Synthetic LM token pipeline: deterministic, step-addressed, checkpointable.

Batches are a pure function of (seed, step), so a restarted job regenerates
the exact stream — the pipeline 'state' in a checkpoint is just the step
counter. A background thread prefetches the next batch (host-side overlap
with device compute).
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    step: int = 0  # checkpointable cursor

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )
        # zipf-ish marginal so losses move like natural text, not uniform
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = (z % self.vocab).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __next__(self) -> dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, st: dict) -> None:
        self.seed, self.step = int(st["seed"]), int(st["step"])


class Prefetcher:
    """One-slot lookahead prefetch thread over any pipeline with __next__."""

    def __init__(self, pipeline, depth: int = 2):
        self.pipeline = pipeline
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._work, daemon=True)
        self.t.start()

    def _work(self):
        while not self._stop.is_set():
            try:
                self.q.put(next(self.pipeline), timeout=0.1)
            except queue.Full:
                continue

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.t.join(timeout=2)
