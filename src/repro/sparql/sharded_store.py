"""Subject-hash sharded triple store: the storage half of distributed MapSQ.

gStoreD (the paper's distributed baseline) partitions the RDF graph across
workers and plans partition-aware joins; this module is our equivalent for
a JAX device mesh. The triple set is hash-partitioned by SUBJECT id — the
same FNV-1a hash the device-side shuffle collectives use
(core/distributed.hash_keys), mirrored here on host numpy — into
`n_shards` disjoint partitions, each with its own sorted SPO/POS/OSP
indexes (a plain TripleStore over the partition, sharing one global
TermDict, so dictionary ids are mesh-wide).

Scans stay partitioned end to end: `match_pattern_device` range-scans
every shard, pads each shard's matches to ONE shared pow-2 capacity
bucket (the max across shards — shard_map needs equal static shapes per
shard) and uploads a flat (n_shards * cap, n_cols) device buffer whose
row blocks are the per-shard partitions, in shard order. The executor's
`shard_map` in_spec splits exactly on those blocks, so scan data is
uploaded once per pattern structure and never re-staged (the same
upload-once discipline as the single-device store, now per shard).

The `statistics` catalog the cost-based optimizer plans against is the
per-shard catalogs aggregated by `StoreStatistics.merge` — exact on all
additive counts for a subject-hash partitioning (see merge's docstring).

Writes reuse the single-device delta design per shard: inserts are routed
to their owner shard by the same subject hash, deletes tombstone inside
the owning shard, and `compact()` compacts every shard. The flat stacked
scan cache is versioned like the per-shard caches — a write bumps the
store version and stale flat blocks are evicted on their next lookup —
and per-pattern capacity floors keep the shared per-shard bucket from
shrinking, so compiled sharded programs survive updates too.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan_ir import bucket_capacity
from repro.core.planner import TriplePattern
from repro.core.relation import Relation
from repro.sparql.dictionary import TermDict
from repro.sparql.store import StoreStatistics, TripleStore

_FNV_OFFSET = np.uint32(2166136261)
_FNV_PRIME = np.uint32(16777619)


def subject_shard(subject_ids: np.ndarray, n_shards: int) -> np.ndarray:
    """Owner shard per subject id: FNV-1a (the device shuffle's hash,
    core/distributed.hash_keys) mod n_shards, on host numpy."""
    s = np.asarray(subject_ids).astype(np.uint32)
    with np.errstate(over="ignore"):
        h = (_FNV_OFFSET ^ s) * _FNV_PRIME
    return (h % np.uint32(n_shards)).astype(np.int64)


@dataclasses.dataclass
class ShardedTripleStore:
    """`n_shards` disjoint subject-hash partitions behind one store API.

    Exposes the same planning/scan surface the QueryEngine consumes
    (dictionary, statistics, estimate_cardinality, pattern_scan_info,
    match_pattern_device, numeric_values_device) — with the sharded
    semantics that `match_pattern_device` returns the flat stacked
    per-shard partitions and `pattern_scan_info` reports the PER-SHARD
    capacity bucket (the number a compiled sharded program is specialised
    on), so the plan-cache key probing in explain() stays correct.
    """

    triples: np.ndarray  # (n, 3) int32 dictionary-encoded (all shards)
    dictionary: TermDict
    n_shards: int
    scan_cache_entries: int = 512
    # NamedSharding placing row blocks on their shard's device; set by the
    # ShardedQueryEngine once it knows the mesh. None = default device
    # (fine for host-side use and for a 1-device mesh).
    row_sharding: object | None = None

    def __post_init__(self):
        assert self.n_shards >= 1
        self.triples = np.asarray(self.triples, np.int32).reshape(-1, 3)
        owner = subject_shard(self.triples[:, 0], self.n_shards)
        self.shards: list[TripleStore] = [
            TripleStore(
                self.triples[owner == k],
                self.dictionary,
                scan_cache_entries=self.scan_cache_entries,
            )
            for k in range(self.n_shards)
        ]
        # flat stacked (n_shards * cap) device scans, keyed like the
        # single-device cache: one upload per pattern structure, per shard.
        # Entries are (version, Relation) pairs; stale versions are evicted
        # (and counted) on lookup, mirroring the per-shard caches.
        self._device_cache: OrderedDict[tuple, tuple] = OrderedDict()
        self._scan_hits = 0
        self._scan_misses = 0
        self._evictions = 0
        # shared per-shard capacity floors (see TripleStore._device_capacity)
        self._cap_floor: dict[tuple, int] = {}
        self.version = 0
        self.compactions = 0
        self._lock = threading.RLock()
        self._statistics: StoreStatistics | None = None

    def __len__(self) -> int:
        return len(self.triples)

    @property
    def statistics(self) -> StoreStatistics:
        """Per-shard catalogs aggregated across the mesh. Re-merged lazily
        after each write batch (the per-shard catalogs themselves are
        maintained incrementally, so the merge is the only repeated work)."""
        if self._statistics is None:
            self._statistics = StoreStatistics.merge(
                [s.statistics for s in self.shards]
            )
        return self._statistics

    # -- write path (routed per-shard deltas) -----------------------------
    def snapshot_lock(self) -> threading.RLock:
        """Store-wide writer/staging lock (see TripleStore.snapshot_lock).
        Writers take this before the per-shard locks, staging takes only
        this — one consistent order, no deadlocks."""
        return self._lock

    def insert_triples(self, triples) -> int:
        rows = np.array(
            [
                [
                    self.dictionary.encode(s),
                    self.dictionary.encode(p),
                    self.dictionary.encode(o),
                ]
                for s, p, o in triples
            ],
            np.int32,
        ).reshape(-1, 3)
        return self.insert_rows(rows)

    def delete_triples(self, triples) -> int:
        rows = []
        for s, p, o in triples:
            ids = [self.dictionary.lookup(t) for t in (s, p, o)]
            if None not in ids:
                rows.append(ids)
        return self.delete_rows(np.asarray(rows, np.int32).reshape(-1, 3))

    def insert_rows(self, rows: np.ndarray) -> int:
        """Route encoded rows to their owner shard (same subject hash as
        the device shuffle) and insert into each shard's delta tail.
        Set-semantics dedup stays exact: a triple's duplicates always hash
        to the same shard. Returns the number added."""
        rows = np.asarray(rows, np.int32).reshape(-1, 3)
        n_added = 0
        with self._lock:
            owner = subject_shard(rows[:, 0], self.n_shards)
            for k, shard in enumerate(self.shards):
                part = rows[owner == k]
                if len(part):
                    n_added += shard.insert_rows(part)
            if n_added:
                self._commit_write()
        return n_added

    def delete_rows(self, rows: np.ndarray) -> int:
        rows = np.asarray(rows, np.int32).reshape(-1, 3)
        n_deleted = 0
        with self._lock:
            owner = subject_shard(rows[:, 0], self.n_shards)
            for k, shard in enumerate(self.shards):
                part = rows[owner == k]
                if len(part):
                    n_deleted += shard.delete_rows(part)
            if n_deleted:
                self._commit_write()
        return n_deleted

    def compact(self) -> None:
        """Compact every shard (fold tails, drop tombstones, rebuild the
        per-shard indexes) and invalidate the flat stacked scan cache.
        Capacity floors are kept, so warm sharded plan shapes survive."""
        with self._lock:
            for shard in self.shards:
                shard.compact()
            self._evictions += len(self._device_cache)
            self._device_cache.clear()
            self.version += 1
            self.compactions += 1
            self.triples = np.concatenate([s.triples for s in self.shards])
            self._statistics = None

    def write_stats(self) -> dict:
        parts = [s.write_stats() for s in self.shards]
        return {
            "version": self.version,
            "base_rows": sum(p["base_rows"] for p in parts),
            "tail_rows": sum(p["tail_rows"] for p in parts),
            "tombstones": sum(p["tombstones"] for p in parts),
            "compactions": self.compactions,
            "total_rows": int(len(self.triples)),
            "n_shards": self.n_shards,
        }

    def _commit_write(self) -> None:
        self.version += 1
        self.triples = np.concatenate([s.triples for s in self.shards])
        self._statistics = None  # re-merge the per-shard catalogs lazily

    # -- planning surface -------------------------------------------------
    def estimate_cardinality(self, tp: TriplePattern) -> int:
        """Store-wide match count: the per-shard counts sum exactly
        (partitions are disjoint)."""
        return sum(s.estimate_cardinality(tp) for s in self.shards)

    def pattern_scan_info(
        self, tp: TriplePattern
    ) -> tuple[tuple[str, ...], int]:
        """(schema, max per-shard effective match count) — display data for
        explain(); the plan-cache probe uses scan_capacity()."""
        schema: tuple[str, ...] = ()
        worst = 0
        for s in self.shards:
            schema, n = s.pattern_scan_info(tp)
            worst = max(worst, n)
        return schema, worst

    def scan_capacity(self, tp: TriplePattern) -> int:
        """The shared per-shard bucket `match_pattern_device` would stage
        this pattern at right now (staged rows incl. tombstone-masked base
        rows, floored by the pattern's high-water mark)."""
        key = self.shards[0]._scan_key(tp)
        worst = max(len(s._staged_columns(tp)[1]) for s in self.shards)
        return max(bucket_capacity(worst), self._cap_floor.get(key, 0))

    # -- device scans ------------------------------------------------------
    def per_shard_counts(self, tp: TriplePattern) -> list[int]:
        return [len(s.match_rows(tp)) for s in self.shards]

    def match_pattern_device(self, tp: TriplePattern) -> Relation:
        """Flat stacked per-shard partial match at one shared bucket.

        Row block k (`[k * cap, (k + 1) * cap)`) holds shard k's matches,
        padded to cap = bucket_capacity(max per-shard count). Device
        arrays are uploaded once per pattern structure and shared across
        queries (the Relation rebinds only the schema names) — the
        upload-once-per-shard contract.
        """
        key = self.shards[0]._scan_key(tp)
        entry = None
        slot = self._device_cache.get(key)
        if slot is not None:
            ver, cached = slot
            if ver == self.version:
                entry = cached
            else:
                del self._device_cache[key]  # stale version: rebuild below
                self._evictions += 1
        if entry is None:
            self._scan_misses += 1
            per_shard = []
            schema: tuple[str, ...] = ()
            for s in self.shards:
                schema, mat, valid = s._staged_columns(tp)
                per_shard.append((mat, valid))
            cap = max(
                bucket_capacity(max(len(m) for m, _ in per_shard)),
                self._cap_floor.get(key, 0),
            )
            self._cap_floor[key] = cap
            n_cols = len(schema)
            cols = np.zeros((self.n_shards * cap, n_cols), np.int32)
            valid = np.zeros((self.n_shards * cap,), bool)
            for k, (mat, v) in enumerate(per_shard):
                cols[k * cap : k * cap + len(mat)] = mat
                valid[k * cap : k * cap + len(mat)] = v
            placeholder = tuple(f"?{i}" for i in range(n_cols))
            entry = Relation(
                placeholder, self._place(cols), self._place(valid)
            )
            self._device_cache[key] = (self.version, entry)
            while len(self._device_cache) > self.scan_cache_entries:
                self._device_cache.popitem(last=False)
            actual = schema
        else:
            self._scan_hits += 1
            actual, _ = self.shards[0]._pattern_columns(
                tp, np.zeros((0, 3), np.int32)
            )
        return Relation(
            tuple(actual), self._place(entry.cols), self._place(entry.valid)
        )

    def _scan_key(self, tp: TriplePattern) -> tuple:
        """Canonical pattern structure (see TripleStore._scan_key) — the
        engine's batch grouping compares lanes' scan keys through us."""
        return self.shards[0]._scan_key(tp)

    def stacked_scan_device(
        self, tps: "tuple[TriplePattern, ...]"
    ) -> tuple:
        """One scan position of a stacked sharded batch: (width,
        n_shards * cap, n_cols) cols and (width, n_shards * cap) valid —
        each lane's flat per-shard blocks stacked on a leading lane axis.
        The mesh splits rows (dim 1) exactly as the solo flat buffer;
        vmap splits lanes (dim 0). Lanes share one capacity bucket by
        construction (capacity is part of the PlanShape they group on);
        a floor drift between patterns surfaces as a stack error and the
        engine falls back to sequential dispatch. Cached by the lane-key
        tuple at the current store version, like the flat scans."""
        key = ("stacked",) + tuple(self._scan_key(tp) for tp in tps)
        slot = self._device_cache.get(key)
        if slot is not None:
            ver, cached = slot
            if ver == self.version:
                self._scan_hits += 1
                return cached
            del self._device_cache[key]
            self._evictions += 1
        self._scan_misses += 1
        rels = [self.match_pattern_device(tp) for tp in tps]
        entry = (
            self._place_stacked(jnp.stack([r.cols for r in rels])),
            self._place_stacked(jnp.stack([r.valid for r in rels])),
        )
        self._device_cache[key] = (self.version, entry)
        while len(self._device_cache) > self.scan_cache_entries:
            self._device_cache.popitem(last=False)
        return entry

    def _place(self, arr):
        """Pin row blocks to their shard's device (no-op re-put on cache
        hits: equal shardings transfer nothing)."""
        if self.row_sharding is None:
            return jnp.asarray(arr)
        return jax.device_put(arr, self.row_sharding)

    def _place_stacked(self, arr):
        """Pin a lane-stacked buffer: lanes replicated over the lane axis'
        None spec, rows split over the mesh like the flat buffers."""
        if self.row_sharding is None:
            return jnp.asarray(arr)
        from jax.sharding import NamedSharding, PartitionSpec

        return jax.device_put(
            arr,
            NamedSharding(
                self.row_sharding.mesh,
                PartitionSpec(None, *self.row_sharding.spec),
            ),
        )

    def numeric_values_device(self):
        return self.shards[0].numeric_values_device()

    def scan_cache_stats(self) -> dict:
        return {
            "hits": self._scan_hits,
            "misses": self._scan_misses,
            "entries": len(self._device_cache),
            "evictions": self._evictions,
        }

    def shard_sizes(self) -> list[int]:
        return [len(s) for s in self.shards]


def shard_store(store: TripleStore, n_shards: int) -> ShardedTripleStore:
    """Partition an existing single-device store across `n_shards`."""
    return ShardedTripleStore(store.triples, store.dictionary, n_shards)


def sharded_store_from_string_triples(
    triples: list[tuple[str, str, str]],
    n_shards: int,
    dictionary: TermDict | None = None,
) -> ShardedTripleStore:
    d = dictionary or TermDict()
    enc = np.array(
        [[d.encode(s), d.encode(p), d.encode(o)] for s, p, o in triples],
        np.int32,
    ).reshape(-1, 3)
    return ShardedTripleStore(enc, d, n_shards)
