"""Subject-hash sharded triple store: the storage half of distributed MapSQ.

gStoreD (the paper's distributed baseline) partitions the RDF graph across
workers and plans partition-aware joins; this module is our equivalent for
a JAX device mesh. The triple set is hash-partitioned by SUBJECT id — the
same FNV-1a hash the device-side shuffle collectives use
(core/distributed.hash_keys), mirrored here on host numpy — into
`n_shards` disjoint partitions, each with its own sorted SPO/POS/OSP
indexes (a plain TripleStore over the partition, sharing one global
TermDict, so dictionary ids are mesh-wide).

Scans stay partitioned end to end: `match_pattern_device` range-scans
every shard, pads each shard's matches to ONE shared pow-2 capacity
bucket (the max across shards — shard_map needs equal static shapes per
shard) and uploads a flat (n_shards * cap, n_cols) device buffer whose
row blocks are the per-shard partitions, in shard order. The executor's
`shard_map` in_spec splits exactly on those blocks, so scan data is
uploaded once per pattern structure and never re-staged (the same
upload-once discipline as the single-device store, now per shard).

The `statistics` catalog the cost-based optimizer plans against is the
per-shard catalogs aggregated by `StoreStatistics.merge` — exact on all
additive counts for a subject-hash partitioning (see merge's docstring).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan_ir import bucket_capacity
from repro.core.planner import TriplePattern
from repro.core.relation import Relation
from repro.sparql.dictionary import TermDict
from repro.sparql.store import StoreStatistics, TripleStore

_FNV_OFFSET = np.uint32(2166136261)
_FNV_PRIME = np.uint32(16777619)


def subject_shard(subject_ids: np.ndarray, n_shards: int) -> np.ndarray:
    """Owner shard per subject id: FNV-1a (the device shuffle's hash,
    core/distributed.hash_keys) mod n_shards, on host numpy."""
    s = np.asarray(subject_ids).astype(np.uint32)
    with np.errstate(over="ignore"):
        h = (_FNV_OFFSET ^ s) * _FNV_PRIME
    return (h % np.uint32(n_shards)).astype(np.int64)


@dataclasses.dataclass
class ShardedTripleStore:
    """`n_shards` disjoint subject-hash partitions behind one store API.

    Exposes the same planning/scan surface the QueryEngine consumes
    (dictionary, statistics, estimate_cardinality, pattern_scan_info,
    match_pattern_device, numeric_values_device) — with the sharded
    semantics that `match_pattern_device` returns the flat stacked
    per-shard partitions and `pattern_scan_info` reports the PER-SHARD
    capacity bucket (the number a compiled sharded program is specialised
    on), so the plan-cache key probing in explain() stays correct.
    """

    triples: np.ndarray  # (n, 3) int32 dictionary-encoded (all shards)
    dictionary: TermDict
    n_shards: int
    scan_cache_entries: int = 512
    # NamedSharding placing row blocks on their shard's device; set by the
    # ShardedQueryEngine once it knows the mesh. None = default device
    # (fine for host-side use and for a 1-device mesh).
    row_sharding: object | None = None

    def __post_init__(self):
        assert self.n_shards >= 1
        self.triples = np.asarray(self.triples, np.int32).reshape(-1, 3)
        owner = subject_shard(self.triples[:, 0], self.n_shards)
        self.shards: list[TripleStore] = [
            TripleStore(
                self.triples[owner == k],
                self.dictionary,
                scan_cache_entries=self.scan_cache_entries,
            )
            for k in range(self.n_shards)
        ]
        # flat stacked (n_shards * cap) device scans, keyed like the
        # single-device cache: one upload per pattern structure, per shard
        self._device_cache: OrderedDict[tuple, Relation] = OrderedDict()
        self._scan_hits = 0
        self._scan_misses = 0
        self._statistics: StoreStatistics | None = None

    def __len__(self) -> int:
        return len(self.triples)

    @property
    def statistics(self) -> StoreStatistics:
        """Per-shard catalogs aggregated across the mesh (computed once;
        partitions are immutable after construction)."""
        if self._statistics is None:
            self._statistics = StoreStatistics.merge(
                [s.statistics for s in self.shards]
            )
        return self._statistics

    # -- planning surface -------------------------------------------------
    def estimate_cardinality(self, tp: TriplePattern) -> int:
        """Store-wide match count: the per-shard counts sum exactly
        (partitions are disjoint)."""
        return sum(s.estimate_cardinality(tp) for s in self.shards)

    def pattern_scan_info(
        self, tp: TriplePattern
    ) -> tuple[tuple[str, ...], int]:
        """(schema, max per-shard match count): bucketing that count gives
        the per-shard scan capacity a compiled sharded program uses, so
        explain()'s cache probing hashes to the right PlanShape."""
        schema: tuple[str, ...] = ()
        worst = 0
        for s in self.shards:
            schema, n = s.pattern_scan_info(tp)
            worst = max(worst, n)
        return schema, worst

    # -- device scans ------------------------------------------------------
    def per_shard_counts(self, tp: TriplePattern) -> list[int]:
        return [len(s.match_rows(tp)) for s in self.shards]

    def match_pattern_device(self, tp: TriplePattern) -> Relation:
        """Flat stacked per-shard partial match at one shared bucket.

        Row block k (`[k * cap, (k + 1) * cap)`) holds shard k's matches,
        padded to cap = bucket_capacity(max per-shard count). Device
        arrays are uploaded once per pattern structure and shared across
        queries (the Relation rebinds only the schema names) — the
        upload-once-per-shard contract.
        """
        key = self.shards[0]._scan_key(tp)
        entry = self._device_cache.get(key)
        if entry is None:
            self._scan_misses += 1
            per_shard = []
            schema: tuple[str, ...] = ()
            for s in self.shards:
                schema, mat = s._pattern_columns(tp, s.match_rows(tp))
                per_shard.append(mat)
            cap = bucket_capacity(max(len(m) for m in per_shard))
            n_cols = len(schema)
            cols = np.zeros((self.n_shards * cap, n_cols), np.int32)
            valid = np.zeros((self.n_shards * cap,), bool)
            for k, mat in enumerate(per_shard):
                cols[k * cap : k * cap + len(mat)] = mat
                valid[k * cap : k * cap + len(mat)] = True
            placeholder = tuple(f"?{i}" for i in range(n_cols))
            entry = Relation(
                placeholder, self._place(cols), self._place(valid)
            )
            self._device_cache[key] = entry
            while len(self._device_cache) > self.scan_cache_entries:
                self._device_cache.popitem(last=False)
            actual = schema
        else:
            self._scan_hits += 1
            actual, _ = self.shards[0]._pattern_columns(
                tp, np.zeros((0, 3), np.int32)
            )
        return Relation(
            tuple(actual), self._place(entry.cols), self._place(entry.valid)
        )

    def _place(self, arr):
        """Pin row blocks to their shard's device (no-op re-put on cache
        hits: equal shardings transfer nothing)."""
        if self.row_sharding is None:
            return jnp.asarray(arr)
        return jax.device_put(arr, self.row_sharding)

    def numeric_values_device(self):
        return self.shards[0].numeric_values_device()

    def scan_cache_stats(self) -> dict:
        return {
            "hits": self._scan_hits,
            "misses": self._scan_misses,
            "entries": len(self._device_cache),
        }

    def shard_sizes(self) -> list[int]:
        return [len(s) for s in self.shards]


def shard_store(store: TripleStore, n_shards: int) -> ShardedTripleStore:
    """Partition an existing single-device store across `n_shards`."""
    return ShardedTripleStore(store.triples, store.dictionary, n_shards)


def sharded_store_from_string_triples(
    triples: list[tuple[str, str, str]],
    n_shards: int,
    dictionary: TermDict | None = None,
) -> ShardedTripleStore:
    d = dictionary or TermDict()
    enc = np.array(
        [[d.encode(s), d.encode(p), d.encode(o)] for s, p, o in triples],
        np.int32,
    ).reshape(-1, 3)
    return ShardedTripleStore(enc, d, n_shards)
