"""Term dictionary: RDF terms <-> dense int32 ids.

Dictionary encoding happens on the host (the paper's CPU side); all device
arrays hold ids only. Ids are dense so they double as array indexes.
"""
from __future__ import annotations

from typing import Iterable


class TermDict:
    def __init__(self):
        self._term_to_id: dict[str, int] = {}
        self._id_to_term: list[str] = []

    def encode(self, term: str) -> int:
        tid = self._term_to_id.get(term)
        if tid is None:
            tid = len(self._id_to_term)
            self._term_to_id[term] = tid
            self._id_to_term.append(term)
        return tid

    def encode_many(self, terms: Iterable[str]) -> list[int]:
        return [self.encode(t) for t in terms]

    def lookup(self, term: str) -> int | None:
        return self._term_to_id.get(term)

    def decode(self, tid: int) -> str:
        return self._id_to_term[tid]

    def __len__(self) -> int:
        return len(self._id_to_term)

    def __contains__(self, term: str) -> bool:
        return term in self._term_to_id
