"""Term dictionary: RDF terms <-> dense int32 ids.

Dictionary encoding happens on the host (the paper's CPU side); all device
arrays hold ids only. Ids are dense so they double as array indexes — the
property `numeric_values` exploits for device-side FILTER evaluation: the
returned table is gathered by term id to compare numeric literals by value
(so `5` matches `5.0`) instead of by identity.
"""
from __future__ import annotations

import re
from typing import Iterable

import numpy as np

# bare integer/decimal lexical forms; quoted strings and IRIs never match
_NUMERIC = re.compile(r"-?\d+(?:\.\d+)?")


class TermDict:
    def __init__(self):
        self._term_to_id: dict[str, int] = {}
        self._id_to_term: list[str] = []

    def encode(self, term: str) -> int:
        tid = self._term_to_id.get(term)
        if tid is None:
            tid = len(self._id_to_term)
            self._term_to_id[term] = tid
            self._id_to_term.append(term)
        return tid

    def encode_many(self, terms: Iterable[str]) -> list[int]:
        return [self.encode(t) for t in terms]

    def lookup(self, term: str) -> int | None:
        return self._term_to_id.get(term)

    def decode(self, tid: int) -> str:
        return self._id_to_term[tid]

    def numeric_values(self) -> np.ndarray:
        """Per-id numeric value table (NaN for non-numeric terms).

        float32 is the engine's numeric-comparison precision contract:
        integers beyond 2^24 compare by their rounded value (the reference
        oracle in sparql/baseline.py applies the same rounding). Sized at
        least 1 so it stays gatherable for empty dictionaries.
        """
        out = np.full(max(1, len(self._id_to_term)), np.nan, np.float32)
        for i, term in enumerate(self._id_to_term):
            if _NUMERIC.fullmatch(term):
                out[i] = float(term)
        return out

    def __len__(self) -> int:
        return len(self._id_to_term)

    def __contains__(self, term: str) -> bool:
        return term in self._term_to_id
