"""Logical query algebra: the tree the parser emits and the planner consumes.

The prepared-query API layers the stack as

    text --parse--> logical algebra --plan--> physical plan --compile--> XLA

This module is the middle layer: a small, frozen, hashable tree of SPARQL
operators (BGP / Join / Union / LeftJoin / Filter / Project / Distinct /
Slice) covering the query class the paper's successors evaluate (gSMat,
gSmart: filtered, optional and union basic graph patterns). Every planner
feature — including the rewrite passes in sparql/optimizer.py — targets
this tree instead of ad-hoc pattern lists.

Supported FILTER expressions are boolean combinations (`&&`, `||`,
parentheses) of comparisons whose left side is a variable:

    ?x != ?y          term (id) comparison, both sides must be bound
    ?age >= 21        numeric comparison against an integer/decimal literal
    ?n = "alice"      term comparison against a string literal or IRI

SPARQL's error semantics apply: a comparison involving an unbound variable
or a non-numeric value under a numeric operator is an error, and an error
fails that comparison (even for `!=`). With only `&&`/`||` and no negation
operator, collapsing error to false at the leaves is observationally
equivalent to full three-valued logic (err && x = false = removed;
err || true = true either way), which is what the device masks do.
"""
from __future__ import annotations

import dataclasses
from typing import Union

from repro.core.planner import TriplePattern

COMPARE_OPS = ("=", "!=", "<", "<=", ">", ">=")
ORDERING_OPS = ("<", "<=", ">", ">=")


# -- filter expression operands ----------------------------------------------


@dataclasses.dataclass(frozen=True)
class Var:
    name: str  # "?x"


@dataclasses.dataclass(frozen=True)
class NumLit:
    """Integer or decimal literal; compared by numeric value."""

    value: float
    lexical: str  # as written, e.g. "42" or "3.5"


@dataclasses.dataclass(frozen=True)
class TermLit:
    """IRI or quoted string literal; compared by term identity."""

    lexical: str  # resolved form, e.g. '<http://...>' or '"alice"'


Operand = Union[Var, NumLit, TermLit]


@dataclasses.dataclass(frozen=True)
class Compare:
    lhs: str  # variable name
    op: str  # one of COMPARE_OPS
    rhs: Operand

    def variables(self) -> tuple[str, ...]:
        if isinstance(self.rhs, Var):
            return (self.lhs, self.rhs.name)
        return (self.lhs,)

    def __str__(self) -> str:
        if isinstance(self.rhs, Var):
            rhs = self.rhs.name
        elif isinstance(self.rhs, NumLit):
            rhs = self.rhs.lexical
        else:
            rhs = self.rhs.lexical
        return f"{self.lhs} {self.op} {rhs}"


@dataclasses.dataclass(frozen=True)
class And:
    """Conjunction of filter expressions (FILTER `&&`)."""

    children: tuple["FilterExpr", ...]

    def variables(self) -> tuple[str, ...]:
        return _expr_vars(self.children)

    def __str__(self) -> str:
        return " && ".join(_paren(c) for c in self.children)


@dataclasses.dataclass(frozen=True)
class Or:
    """Disjunction of filter expressions (FILTER `||`)."""

    children: tuple["FilterExpr", ...]

    def variables(self) -> tuple[str, ...]:
        return _expr_vars(self.children)

    def __str__(self) -> str:
        return " || ".join(_paren(c) for c in self.children)


FilterExpr = Union[Compare, And, Or]


def _expr_vars(children) -> tuple[str, ...]:
    out: list[str] = []
    for c in children:
        for v in c.variables():
            if v not in out:
                out.append(v)
    return tuple(out)


def _paren(expr: "FilterExpr") -> str:
    return f"({expr})" if isinstance(expr, (And, Or)) else str(expr)


def flatten_conjuncts(expr: "FilterExpr") -> tuple["FilterExpr", ...]:
    """Split top-level ANDs into the conjunct list the optimizer pushes
    around independently (an Or conjunct stays one opaque unit)."""
    if isinstance(expr, And):
        out: list[FilterExpr] = []
        for c in expr.children:
            out.extend(flatten_conjuncts(c))
        return tuple(out)
    return (expr,)


# -- algebra nodes ------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BGP:
    patterns: tuple[TriplePattern, ...]

    def variables(self) -> tuple[str, ...]:
        out: list[str] = []
        for tp in self.patterns:
            for v in tp.variables():
                if v not in out:
                    out.append(v)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class Join:
    """Inner join of two subtrees (required BGP joined with a UNION block)."""

    left: "AlgebraNode"
    right: "AlgebraNode"

    def variables(self) -> tuple[str, ...]:
        out = list(self.left.variables())
        for v in self.right.variables():
            if v not in out:
                out.append(v)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class UnionNode:
    """SPARQL UNION: multiset union of branch solutions. Branches may bind
    different variables; a row leaves the other branches' variables unbound."""

    branches: tuple["AlgebraNode", ...]

    def variables(self) -> tuple[str, ...]:
        out: list[str] = []
        for b in self.branches:
            for v in b.variables():
                if v not in out:
                    out.append(v)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class LeftJoin:
    """OPTIONAL: keep every left row; extend with right bindings when the
    optional group matches, leave its variables unbound otherwise."""

    left: "AlgebraNode"
    right: BGP

    def variables(self) -> tuple[str, ...]:
        out = list(self.left.variables())
        for v in self.right.variables():
            if v not in out:
                out.append(v)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class Filter:
    child: "AlgebraNode"
    conditions: tuple[FilterExpr, ...]  # conjunction of expressions

    def variables(self) -> tuple[str, ...]:
        return self.child.variables()


@dataclasses.dataclass(frozen=True)
class Project:
    child: "AlgebraNode"
    vars: tuple[str, ...]

    def variables(self) -> tuple[str, ...]:
        return self.vars


@dataclasses.dataclass(frozen=True)
class Distinct:
    child: "AlgebraNode"

    def variables(self) -> tuple[str, ...]:
        return self.child.variables()


@dataclasses.dataclass(frozen=True)
class Slice:
    child: "AlgebraNode"
    offset: int
    limit: int | None  # None: no LIMIT (OFFSET-only slice)

    def variables(self) -> tuple[str, ...]:
        return self.child.variables()


AlgebraNode = Union[
    BGP, Join, UnionNode, LeftJoin, Filter, Project, Distinct, Slice
]


# -- update surface (SPARQL Update ground-data operations) --------------------


@dataclasses.dataclass(frozen=True)
class InsertData:
    """INSERT DATA { ... }: ground triples appended to the store's mutable
    delta tail. Triples are TriplePatterns with no variables (the parser
    enforces groundness)."""

    triples: tuple[TriplePattern, ...]


@dataclasses.dataclass(frozen=True)
class DeleteData:
    """DELETE DATA { ... }: ground triples removed from the store — matching
    tail rows drop immediately, matching base rows are tombstoned until the
    next compaction."""

    triples: tuple[TriplePattern, ...]


UpdateOp = Union[InsertData, DeleteData]


def format_update(ops: tuple[UpdateOp, ...]) -> str:
    """One line per operation, mirroring format_algebra's report style."""
    lines = []
    for op in ops:
        kind = "InsertData" if isinstance(op, InsertData) else "DeleteData"
        lines.append(f"{kind}({len(op.triples)} triple(s))")
    return "\n".join(lines)


def format_algebra(node: AlgebraNode, indent: int = 0) -> str:
    """Indented one-node-per-line rendering (used by PreparedQuery.explain)."""
    pad = "  " * indent
    if isinstance(node, BGP):
        lines = [f"{pad}BGP"]
        lines += [
            f"{pad}  ({tp.s} {tp.p} {tp.o})" for tp in node.patterns
        ]
        return "\n".join(lines)
    if isinstance(node, Join):
        return (
            f"{pad}Join\n"
            + format_algebra(node.left, indent + 1)
            + "\n"
            + format_algebra(node.right, indent + 1)
        )
    if isinstance(node, UnionNode):
        return f"{pad}Union\n" + "\n".join(
            format_algebra(b, indent + 1) for b in node.branches
        )
    if isinstance(node, LeftJoin):
        return (
            f"{pad}LeftJoin (OPTIONAL)\n"
            + format_algebra(node.left, indent + 1)
            + "\n"
            + format_algebra(node.right, indent + 1)
        )
    if isinstance(node, Filter):
        conds = " && ".join(str(c) for c in node.conditions)
        return f"{pad}Filter({conds})\n" + format_algebra(node.child, indent + 1)
    if isinstance(node, Project):
        return (
            f"{pad}Project({', '.join(node.vars)})\n"
            + format_algebra(node.child, indent + 1)
        )
    if isinstance(node, Distinct):
        return f"{pad}Distinct\n" + format_algebra(node.child, indent + 1)
    if isinstance(node, Slice):
        limit = "-" if node.limit is None else node.limit
        return (
            f"{pad}Slice(offset={node.offset}, limit={limit})\n"
            + format_algebra(node.child, indent + 1)
        )
    raise TypeError(f"unknown algebra node {node!r}")
