"""Join baselines standing in for gStore / gStoreD's CPU joins (Table 2).

The paper compares MapSQ's GPU MapReduce join against the join operation of
two CPU engines. gStore itself isn't available (C++/CPU), so we implement
the comparison class faithfully:

  * nested_loop_join   — the "plain join algorithm" the paper names;
    classic tuple-at-a-time CPU nested loop (host numpy, O(n·m)).
  * hash_join          — build/probe hash join, the standard CPU engine
    join (host python dict, O(n+m)); stands in for gStore.
  * partitioned_hash_join — hash-partitioned two-phase variant standing in
    for the distributed gStoreD (partition overhead + per-partition probe).

All three consume/produce the same dictionary-encoded numpy rows as the
device join, so benchmarks/bench_join.py can reproduce the Table 2 shape:
same partial matches in, same result set out, join time compared.

`reference_rows` additionally evaluates a full parsed Query — BGP, UNION,
OPTIONAL, FILTER (boolean combinations), projection, DISTINCT — by
backtracking over decoded triples. It is the differential oracle the prepared-query tests compare
the device algebra against (LIMIT/OFFSET are left to the caller, since
any row subset of the right size is a correct slice).
"""
from __future__ import annotations

import re

import numpy as np


def _key_cols(schema_l, schema_r):
    shared = [v for v in schema_l if v in schema_r]
    li = [schema_l.index(v) for v in shared]
    ri = [schema_r.index(v) for v in shared]
    r_extra = [i for i, v in enumerate(schema_r) if v not in schema_l]
    out_schema = tuple(schema_l) + tuple(schema_r[i] for i in r_extra)
    return li, ri, r_extra, out_schema


def nested_loop_join(schema_l, rows_l: np.ndarray, schema_r,
                     rows_r: np.ndarray):
    """Tuple-at-a-time nested loop (the paper's 'plain join algorithm')."""
    li, ri, r_extra, out_schema = _key_cols(schema_l, schema_r)
    out = []
    for a in rows_l:
        ka = tuple(a[i] for i in li)
        for b in rows_r:
            if ka == tuple(b[i] for i in ri):
                out.append(list(a) + [b[i] for i in r_extra])
    return out_schema, np.asarray(out, np.int32).reshape(-1, len(out_schema))


def hash_join(schema_l, rows_l: np.ndarray, schema_r, rows_r: np.ndarray):
    """Build (left) + probe (right) hash join — the gStore stand-in."""
    li, ri, r_extra, out_schema = _key_cols(schema_l, schema_r)
    table: dict[tuple, list] = {}
    for a in rows_l:
        table.setdefault(tuple(a[i] for i in li), []).append(a)
    out = []
    for b in rows_r:
        for a in table.get(tuple(b[i] for i in ri), ()):
            out.append(list(a) + [b[i] for i in r_extra])
    return out_schema, np.asarray(out, np.int32).reshape(-1, len(out_schema))


_NUMERIC = re.compile(r"-?\d+(?:\.\d+)?")


def _term_numeric(term: str):
    """Numeric value of a term lexical, at the engine's documented float32
    precision (the device FILTER path gathers a float32 table, so integers
    beyond 2^24 compare by their rounded value — the oracle must agree)."""
    return np.float32(term) if _NUMERIC.fullmatch(term) else None


def _extend(bindings: list[dict], triples, tp) -> list[dict]:
    """All extensions of each binding by one triple pattern (backtracking)."""
    out = []
    for b in bindings:
        for s, p, o in triples:
            nb = dict(b)
            ok = True
            for term, val in ((tp.s, s), (tp.p, p), (tp.o, o)):
                if term.startswith("?"):
                    if nb.get(term, val) != val:
                        ok = False
                        break
                    nb[term] = val
                elif term != val:
                    ok = False
                    break
            if ok:
                out.append(nb)
    return out


def _filter_true(cond, b: dict) -> bool:
    """SPARQL error semantics: unbound operands or non-numeric values under
    numeric operators fail the condition (even for !=). `cond` may be a
    boolean combination (algebra.And / algebra.Or) of comparisons."""
    from repro.sparql import algebra

    if isinstance(cond, algebra.And):
        return all(_filter_true(c, b) for c in cond.children)
    if isinstance(cond, algebra.Or):
        return any(_filter_true(c, b) for c in cond.children)
    lhs = b.get(cond.lhs)
    if lhs is None:
        return False
    if isinstance(cond.rhs, algebra.Var):
        rhs = b.get(cond.rhs.name)
        if rhs is None:
            return False
        if cond.op in ("=", "!="):
            return (lhs == rhs) if cond.op == "=" else (lhs != rhs)
        lv, rv = _term_numeric(lhs), _term_numeric(rhs)
        if lv is None or rv is None:
            return False
    elif isinstance(cond.rhs, algebra.NumLit):
        lv, rv = _term_numeric(lhs), np.float32(cond.rhs.value)
        if lv is None:
            return False
    else:  # TermLit: identity comparison
        if cond.op == "=":
            return lhs == cond.rhs.lexical
        if cond.op == "!=":
            return lhs != cond.rhs.lexical
        return False
    return {
        "=": lv == rv, "!=": lv != rv, "<": lv < rv,
        "<=": lv <= rv, ">": lv > rv, ">=": lv >= rv,
    }[cond.op]


def reference_rows(store, q) -> list[dict[str, str]]:
    """Pure-python oracle for the logical algebra (everything but the
    slice): projected rows as {var: term} dicts, unbound vars omitted."""
    d = store.dictionary
    triples = [tuple(d.decode(int(t)) for t in row) for row in store.triples]
    bindings = [dict()]
    for tp in q.patterns:
        bindings = _extend(bindings, triples, tp)
    if getattr(q, "unions", ()):
        # multiset union: each branch extends the required bindings
        # independently; rows keep other branches' variables unbound
        unioned: list[dict] = []
        for branch in q.unions:
            ext = list(bindings)
            for tp in branch:
                ext = _extend(ext, triples, tp)
            unioned.extend(ext)
        bindings = unioned
    for group in q.optionals:
        joined = []
        for b in bindings:
            ext = [b]
            for tp in group:
                ext = _extend(ext, triples, tp)
            joined.extend(ext if ext else [b])  # no match: keep b unextended
        bindings = joined
    for cond in q.filters:
        bindings = [b for b in bindings if _filter_true(cond, b)]
    proj = q.projection()
    rows = [{v: b[v] for v in proj if v in b} for b in bindings]
    if q.distinct:
        seen, uniq = set(), []
        for r in rows:
            key = tuple(sorted(r.items()))
            if key not in seen:
                seen.add(key)
                uniq.append(r)
        rows = uniq
    return rows


def partitioned_hash_join(schema_l, rows_l, schema_r, rows_r,
                          n_parts: int = 4):
    """Grace-style partitioned hash join — the gStoreD stand-in (adds the
    partition pass a distributed engine pays before local joins)."""
    li, ri, r_extra, out_schema = _key_cols(schema_l, schema_r)

    def part(rows, idx):
        buckets = [[] for _ in range(n_parts)]
        for r in rows:
            buckets[hash(tuple(r[i] for i in idx)) % n_parts].append(r)
        return buckets

    bl = part(rows_l, li)
    br = part(rows_r, ri)
    out = []
    for p in range(n_parts):
        _, rows = hash_join(schema_l, np.asarray(bl[p], np.int32).reshape(
            -1, len(schema_l)), schema_r,
            np.asarray(br[p], np.int32).reshape(-1, len(schema_r)))
        out.append(rows)
    rows = np.concatenate(out) if out else np.zeros((0, len(out_schema)),
                                                    np.int32)
    return out_schema, rows
