"""Join baselines standing in for gStore / gStoreD's CPU joins (Table 2).

The paper compares MapSQ's GPU MapReduce join against the join operation of
two CPU engines. gStore itself isn't available (C++/CPU), so we implement
the comparison class faithfully:

  * nested_loop_join   — the "plain join algorithm" the paper names;
    classic tuple-at-a-time CPU nested loop (host numpy, O(n·m)).
  * hash_join          — build/probe hash join, the standard CPU engine
    join (host python dict, O(n+m)); stands in for gStore.
  * partitioned_hash_join — hash-partitioned two-phase variant standing in
    for the distributed gStoreD (partition overhead + per-partition probe).

All three consume/produce the same dictionary-encoded numpy rows as the
device join, so benchmarks/bench_join.py can reproduce the Table 2 shape:
same partial matches in, same result set out, join time compared.
"""
from __future__ import annotations

import numpy as np


def _key_cols(schema_l, schema_r):
    shared = [v for v in schema_l if v in schema_r]
    li = [schema_l.index(v) for v in shared]
    ri = [schema_r.index(v) for v in shared]
    r_extra = [i for i, v in enumerate(schema_r) if v not in schema_l]
    out_schema = tuple(schema_l) + tuple(schema_r[i] for i in r_extra)
    return li, ri, r_extra, out_schema


def nested_loop_join(schema_l, rows_l: np.ndarray, schema_r,
                     rows_r: np.ndarray):
    """Tuple-at-a-time nested loop (the paper's 'plain join algorithm')."""
    li, ri, r_extra, out_schema = _key_cols(schema_l, schema_r)
    out = []
    for a in rows_l:
        ka = tuple(a[i] for i in li)
        for b in rows_r:
            if ka == tuple(b[i] for i in ri):
                out.append(list(a) + [b[i] for i in r_extra])
    return out_schema, np.asarray(out, np.int32).reshape(-1, len(out_schema))


def hash_join(schema_l, rows_l: np.ndarray, schema_r, rows_r: np.ndarray):
    """Build (left) + probe (right) hash join — the gStore stand-in."""
    li, ri, r_extra, out_schema = _key_cols(schema_l, schema_r)
    table: dict[tuple, list] = {}
    for a in rows_l:
        table.setdefault(tuple(a[i] for i in li), []).append(a)
    out = []
    for b in rows_r:
        for a in table.get(tuple(b[i] for i in ri), ()):
            out.append(list(a) + [b[i] for i in r_extra])
    return out_schema, np.asarray(out, np.int32).reshape(-1, len(out_schema))


def partitioned_hash_join(schema_l, rows_l, schema_r, rows_r,
                          n_parts: int = 4):
    """Grace-style partitioned hash join — the gStoreD stand-in (adds the
    partition pass a distributed engine pays before local joins)."""
    li, ri, r_extra, out_schema = _key_cols(schema_l, schema_r)

    def part(rows, idx):
        buckets = [[] for _ in range(n_parts)]
        for r in rows:
            buckets[hash(tuple(r[i] for i in idx)) % n_parts].append(r)
        return buckets

    bl = part(rows_l, li)
    br = part(rows_r, ri)
    out = []
    for p in range(n_parts):
        _, rows = hash_join(schema_l, np.asarray(bl[p], np.int32).reshape(
            -1, len(schema_l)), schema_r,
            np.asarray(br[p], np.int32).reshape(-1, len(schema_r)))
        out.append(rows)
    rows = np.concatenate(out) if out else np.zeros((0, len(out_schema)),
                                                    np.int32)
    return out_schema, rows
