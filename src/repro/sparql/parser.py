"""A small SPARQL parser: PREFIX / SELECT [DISTINCT] / WHERE { BGP }.

Covers the query class the paper evaluates (basic graph patterns with
variables, IRIs, prefixed names, literals, and `;` predicate-object lists
as used in LUBM-style queries). Parsing is host-side — part of the CPU
half of the coprocessing strategy.
"""
from __future__ import annotations

import dataclasses
import re

from repro.core.planner import TriplePattern

_TOKEN = re.compile(
    r"""\s*(?:
        (?P<var>\?[A-Za-z_][\w]*)
      | (?P<iri><[^>]*>)
      | (?P<literal>"(?:[^"\\]|\\.)*")
      | (?P<pname>[A-Za-z_][\w\-]*:[A-Za-z_][\w\-]*)
      | (?P<pdecl>[A-Za-z_][\w\-]*:)
      | (?P<kw>PREFIX|SELECT|DISTINCT|WHERE|\{|\}|\.|;|\*|a\b)
    )""",
    re.VERBOSE | re.IGNORECASE,
)

_RDF_TYPE = "<http://www.w3.org/1999/02/22-rdf-syntax-ns#type>"


@dataclasses.dataclass
class Query:
    select_vars: list[str]  # empty => SELECT *
    distinct: bool
    patterns: list[TriplePattern]

    def all_vars(self) -> list[str]:
        out: list[str] = []
        for tp in self.patterns:
            for v in tp.variables():
                if v not in out:
                    out.append(v)
        return out

    def projection(self) -> list[str]:
        return self.select_vars or self.all_vars()


class ParseError(ValueError):
    pass


def _tokenize(text: str) -> list[str]:
    tokens, pos = [], 0
    while pos < len(text):
        if text[pos:].strip() == "":
            break
        m = _TOKEN.match(text, pos)
        if not m:
            raise ParseError(f"unexpected input at: {text[pos:pos + 30]!r}")
        tokens.append(m.group(0).strip())
        pos = m.end()
    return tokens


def parse(text: str) -> Query:
    tokens = _tokenize(text)
    i = 0
    prefixes: dict[str, str] = {}

    def peek() -> str:
        return tokens[i] if i < len(tokens) else ""

    def eat(expect: str | None = None) -> str:
        nonlocal i
        if i >= len(tokens):
            raise ParseError(f"unexpected end of query (wanted {expect})")
        tok = tokens[i]
        if expect and tok.upper() != expect.upper():
            raise ParseError(f"expected {expect}, got {tok!r}")
        i += 1
        return tok

    while peek().upper() == "PREFIX":
        eat()
        pname = eat()
        if not pname.endswith(":"):
            raise ParseError(f"malformed PREFIX declaration near {pname!r}")
        iri = eat()
        if not (iri.startswith("<") and iri.endswith(">")):
            raise ParseError(f"PREFIX needs an IRI, got {iri!r}")
        prefixes[pname[:-1]] = iri[1:-1]

    eat("SELECT")
    distinct = False
    if peek().upper() == "DISTINCT":
        eat()
        distinct = True
    select_vars: list[str] = []
    if peek() == "*":
        eat()
    else:
        while peek().startswith("?"):
            select_vars.append(eat())
        if not select_vars:
            raise ParseError("SELECT needs variables or *")
    eat("WHERE")
    eat("{")

    def resolve(tok: str) -> str:
        if tok.startswith("?"):
            return tok
        if tok == "a":
            return _RDF_TYPE
        if tok.startswith("<") or tok.startswith('"'):
            return tok
        ns, _, local = tok.partition(":")
        if ns not in prefixes:
            raise ParseError(f"unknown prefix {ns!r} in {tok!r}")
        return f"<{prefixes[ns]}{local}>"

    patterns: list[TriplePattern] = []
    while peek() != "}":
        s = resolve(eat())
        patterns.append(TriplePattern(s, resolve(eat()), resolve(eat())))
        # `;` predicate-object lists: `?x a ub:Student ; ub:memberOf ?d .`
        while peek() == ";":
            eat()
            if peek() in (".", "}"):  # dangling `;` before a terminator
                break
            patterns.append(TriplePattern(s, resolve(eat()), resolve(eat())))
        if peek() == ".":
            eat()
    eat("}")
    if not patterns:
        raise ParseError("empty basic graph pattern")
    unknown = [v for v in select_vars if all(v not in tp.variables() for tp in patterns)]
    if unknown:
        raise ParseError(f"SELECT vars not in WHERE clause: {unknown}")
    return Query(select_vars, distinct, patterns)
