"""SPARQL parser: PREFIX / SELECT [DISTINCT] / WHERE / LIMIT / OFFSET.

Covers the query class the paper (basic graph patterns with variables,
IRIs, prefixed names, literals, `;` predicate-object lists) and its
successors evaluate: FILTER expressions (comparisons over numeric and
string literals or variables, combined with `&&`, `||` and parentheses),
OPTIONAL groups, `{ .. } UNION { .. }` blocks, `#` line comments,
integer/decimal literals, and LIMIT/OFFSET solution modifiers. Parsing is
host-side — part of the CPU half of the coprocessing strategy.

The result is a `Query`: the WHERE group decomposed into a required BGP,
OPTIONAL groups, UNION branches and filter conjuncts, plus the solution
modifiers. `Query.algebra()` assembles the logical-algebra tree
(sparql/algebra.py) that the optimizer rewrites and the engine compiles.

`parse_update` covers the write side of the protocol: a SPARQL Update
request of one or more `INSERT DATA { ... }` / `DELETE DATA { ... }`
operations (ground triples only, `;`-separated, shared PREFIX prologue),
returned as an `UpdateRequest` of algebra.InsertData / algebra.DeleteData
ops in request order — the input `QueryEngine.update` applies against the
store's delta blocks.
"""
from __future__ import annotations

import dataclasses
import re

from repro.core.planner import TriplePattern
from repro.sparql import algebra

_TOKEN = re.compile(
    r"""\s*(?:
        (?P<comment>\#[^\n]*)
      | (?P<var>\?[A-Za-z_][\w]*)
      | (?P<iri><[^>\s]*>)
      | (?P<literal>"(?:[^"\\]|\\.)*")
      | (?P<num>-?\d+(?:\.\d+)?)
      | (?P<pname>[A-Za-z_][\w\-]*:[A-Za-z_][\w\-]*)
      | (?P<pdecl>[A-Za-z_][\w\-]*:)
      | (?P<op><=|>=|!=|&&|\|\||[=<>()])
      | (?P<kw>PREFIX|SELECT|DISTINCT|WHERE|FILTER|OPTIONAL|UNION|LIMIT
              |OFFSET|INSERT|DELETE|DATA|\{|\}|\.|;|\*|a\b)
    )""",
    re.VERBOSE | re.IGNORECASE,
)

_NUM = re.compile(r"-?\d+(?:\.\d+)?")

_RDF_TYPE = "<http://www.w3.org/1999/02/22-rdf-syntax-ns#type>"


@dataclasses.dataclass
class Query:
    select_vars: list[str]  # empty => SELECT *
    distinct: bool
    patterns: list[TriplePattern]  # the required BGP (may be empty if unions)
    optionals: tuple[tuple[TriplePattern, ...], ...] = ()
    filters: tuple[algebra.FilterExpr, ...] = ()  # conjunct list
    limit: int | None = None
    offset: int = 0
    unions: tuple[tuple[TriplePattern, ...], ...] = ()  # UNION branches

    def all_vars(self) -> list[str]:
        out: list[str] = []

        def add(group) -> None:
            for tp in group:
                for v in tp.variables():
                    if v not in out:
                        out.append(v)

        add(self.patterns)
        for branch in self.unions:
            add(branch)
        for group in self.optionals:
            add(group)
        return out

    def projection(self) -> list[str]:
        return self.select_vars or self.all_vars()

    def has_slice(self) -> bool:
        return self.limit is not None or self.offset > 0

    def algebra(self) -> algebra.AlgebraNode:
        """Assemble the logical tree: BGP [⋈ Union] → LeftJoin* → Filter
        → Project → Distinct → Slice."""
        node: algebra.AlgebraNode | None = (
            algebra.BGP(tuple(self.patterns)) if self.patterns else None
        )
        if self.unions:
            u = algebra.UnionNode(
                tuple(algebra.BGP(b) for b in self.unions)
            )
            node = algebra.Join(node, u) if node is not None else u
        assert node is not None  # parser guarantees patterns or unions
        for group in self.optionals:
            node = algebra.LeftJoin(node, algebra.BGP(group))
        if self.filters:
            node = algebra.Filter(node, self.filters)
        node = algebra.Project(node, tuple(self.projection()))
        if self.distinct:
            node = algebra.Distinct(node)
        if self.has_slice():
            node = algebra.Slice(node, self.offset, self.limit)
        return node


class ParseError(ValueError):
    pass


def _tokenize(text: str) -> list[str]:
    tokens, pos = [], 0
    while pos < len(text):
        if text[pos:].strip() == "":
            break
        m = _TOKEN.match(text, pos)
        if not m:
            raise ParseError(f"unexpected input at: {text[pos:pos + 30]!r}")
        if m.lastgroup != "comment":  # `#` line comments are skipped
            tokens.append(m.group(0).strip())
        pos = m.end()
    return tokens


def parse(text: str) -> Query:
    tokens = _tokenize(text)
    i = 0
    prefixes: dict[str, str] = {}

    def peek() -> str:
        return tokens[i] if i < len(tokens) else ""

    def eat(expect: str | None = None) -> str:
        nonlocal i
        if i >= len(tokens):
            raise ParseError(f"unexpected end of query (wanted {expect})")
        tok = tokens[i]
        if expect and tok.upper() != expect.upper():
            raise ParseError(f"expected {expect}, got {tok!r}")
        i += 1
        return tok

    while peek().upper() == "PREFIX":
        eat()
        pname = eat()
        if not pname.endswith(":"):
            raise ParseError(f"malformed PREFIX declaration near {pname!r}")
        iri = eat()
        if not (iri.startswith("<") and iri.endswith(">")):
            raise ParseError(f"PREFIX needs an IRI, got {iri!r}")
        prefixes[pname[:-1]] = iri[1:-1]

    eat("SELECT")
    distinct = False
    if peek().upper() == "DISTINCT":
        eat()
        distinct = True
    select_vars: list[str] = []
    if peek() == "*":
        eat()
    else:
        while peek().startswith("?"):
            select_vars.append(eat())
        if not select_vars:
            raise ParseError("SELECT needs variables or *")
    eat("WHERE")
    eat("{")

    def resolve(tok: str) -> str:
        if tok.startswith("?"):
            return tok
        if tok == "a":
            return _RDF_TYPE
        if tok.startswith("<") or tok.startswith('"') or _NUM.fullmatch(tok):
            return tok
        ns, colon, local = tok.partition(":")
        if not colon or ns not in prefixes:
            raise ParseError(f"unknown prefix {ns!r} in {tok!r}")
        return f"<{prefixes[ns]}{local}>"

    def parse_triples_into(dest: list[TriplePattern]) -> None:
        s = resolve(eat())
        dest.append(TriplePattern(s, resolve(eat()), resolve(eat())))
        # `;` predicate-object lists: `?x a ub:Student ; ub:memberOf ?d .`
        while peek() == ";":
            eat()
            if peek() in (".", "}"):  # dangling `;` before a terminator
                break
            dest.append(TriplePattern(s, resolve(eat()), resolve(eat())))

    def parse_operand() -> algebra.Operand:
        tok = eat()
        if tok.startswith("?"):
            return algebra.Var(tok)
        if _NUM.fullmatch(tok):
            return algebra.NumLit(float(tok), tok)
        return algebra.TermLit(resolve(tok))

    def parse_compare() -> algebra.Compare:
        lhs = parse_operand()
        if not isinstance(lhs, algebra.Var):
            raise ParseError(
                "FILTER comparisons must have a variable on the left"
            )
        op = eat()
        if op not in algebra.COMPARE_OPS:
            raise ParseError(f"expected a comparison operator, got {op!r}")
        rhs = parse_operand()
        if op in algebra.ORDERING_OPS and isinstance(rhs, algebra.TermLit):
            raise ParseError(
                f"ordering comparison {op!r} needs a numeric literal or "
                f"variable, got {rhs.lexical!r}"
            )
        return algebra.Compare(lhs.name, op, rhs)

    # FILTER expression grammar (|| binds loosest, && tighter, parens):
    #   expr    := and_exp ("||" and_exp)*
    #   and_exp := primary ("&&" primary)*
    #   primary := "(" expr ")" | comparison
    def parse_filter_expr() -> algebra.FilterExpr:
        terms = [parse_and_expr()]
        while peek() == "||":
            eat()
            terms.append(parse_and_expr())
        return algebra.Or(tuple(terms)) if len(terms) > 1 else terms[0]

    def parse_and_expr() -> algebra.FilterExpr:
        factors = [parse_primary()]
        while peek() == "&&":
            eat()
            factors.append(parse_primary())
        return algebra.And(tuple(factors)) if len(factors) > 1 else factors[0]

    def parse_primary() -> algebra.FilterExpr:
        if peek() == "(":
            eat()
            inner = parse_filter_expr()
            eat(")")
            return inner
        return parse_compare()

    def parse_group(dest: list[TriplePattern], what: str) -> None:
        """A braced block of plain triples (OPTIONAL / UNION bodies)."""
        eat("{")
        while peek() != "}":
            if peek().upper() in ("OPTIONAL", "FILTER", "UNION", "{"):
                raise ParseError(
                    f"nested OPTIONAL/FILTER/UNION inside {what} "
                    "is not supported"
                )
            parse_triples_into(dest)
            if peek() == ".":
                eat()
        eat("}")
        if not dest:
            raise ParseError(f"empty {what}")

    patterns: list[TriplePattern] = []
    optionals: list[tuple[TriplePattern, ...]] = []
    unions: list[tuple[TriplePattern, ...]] = []
    filters: list[algebra.FilterExpr] = []
    while peek() != "}":
        head = peek().upper()
        if head == "OPTIONAL":
            eat()
            block: list[TriplePattern] = []
            parse_group(block, "an OPTIONAL group")
            optionals.append(tuple(block))
        elif head == "FILTER":
            eat()
            eat("(")
            expr = parse_filter_expr()
            eat(")")
            # top-level conjunctions split into independently pushable
            # conjuncts (keeps the historical flat `filters` shape)
            filters.extend(algebra.flatten_conjuncts(expr))
        elif head == "{":
            # { branch } UNION { branch } [UNION { branch }]*
            if unions:
                raise ParseError(
                    "only one UNION block per query is supported"
                )
            branch: list[TriplePattern] = []
            parse_group(branch, "a UNION branch")
            unions.append(tuple(branch))
            if peek().upper() != "UNION":
                raise ParseError("a braced group must be part of a UNION")
            while peek().upper() == "UNION":
                eat()
                branch = []
                parse_group(branch, "a UNION branch")
                unions.append(tuple(branch))
        else:
            parse_triples_into(patterns)
        if peek() == ".":
            eat()
    eat("}")

    limit: int | None = None
    offset = 0
    seen_mods: set[str] = set()
    while peek().upper() in ("LIMIT", "OFFSET"):
        kw = eat().upper()
        if kw in seen_mods:
            raise ParseError(f"duplicate {kw}")
        seen_mods.add(kw)
        val = eat()
        if not re.fullmatch(r"\d+", val):
            raise ParseError(f"{kw} needs a non-negative integer, got {val!r}")
        if kw == "LIMIT":
            limit = int(val)
        else:
            offset = int(val)
    if peek():
        raise ParseError(f"trailing input after query: {peek()!r}")

    if not patterns and not unions:
        raise ParseError("empty basic graph pattern")
    if unions and optionals:
        raise ParseError(
            "OPTIONAL together with UNION in one query is not supported"
        )
    q = Query(
        select_vars,
        distinct,
        patterns,
        tuple(optionals),
        tuple(filters),
        limit,
        offset,
        tuple(unions),
    )
    bound = set(q.all_vars())
    unknown = [v for v in select_vars if v not in bound]
    if unknown:
        raise ParseError(f"SELECT vars not in WHERE clause: {unknown}")
    for cond in filters:
        loose = [v for v in cond.variables() if v not in bound]
        if loose:
            raise ParseError(f"FILTER vars not in WHERE clause: {loose}")
    return q


# -- SPARQL Update ------------------------------------------------------------


@dataclasses.dataclass
class UpdateRequest:
    """A parsed update: InsertData / DeleteData ops in request order."""

    ops: tuple[algebra.UpdateOp, ...]

    def n_triples(self) -> int:
        return sum(len(op.triples) for op in self.ops)


def parse_update(text: str) -> UpdateRequest:
    """Parse `INSERT DATA { ... }` / `DELETE DATA { ... }` operations.

    Grammar (the ground-data subset of SPARQL 1.1 Update):

        update  := PREFIX* op ( ';' op )* ';'?
        op      := ('INSERT' | 'DELETE') 'DATA' '{' triples '}'

    Data blocks hold ground triples only — variables (and the braces of
    GRAPH blocks) are rejected. `a` and `;` predicate-object lists resolve
    exactly as in queries; the shared PREFIX prologue applies to every op.
    """
    tokens = _tokenize(text)
    i = 0
    prefixes: dict[str, str] = {}

    def peek() -> str:
        return tokens[i] if i < len(tokens) else ""

    def eat(expect: str | None = None) -> str:
        nonlocal i
        if i >= len(tokens):
            raise ParseError(f"unexpected end of update (wanted {expect})")
        tok = tokens[i]
        if expect and tok.upper() != expect.upper():
            raise ParseError(f"expected {expect}, got {tok!r}")
        i += 1
        return tok

    while peek().upper() == "PREFIX":
        eat()
        pname = eat()
        if not pname.endswith(":"):
            raise ParseError(f"malformed PREFIX declaration near {pname!r}")
        iri = eat()
        if not (iri.startswith("<") and iri.endswith(">")):
            raise ParseError(f"PREFIX needs an IRI, got {iri!r}")
        prefixes[pname[:-1]] = iri[1:-1]

    def resolve(tok: str) -> str:
        if tok.startswith("?"):
            raise ParseError(
                f"variables are not allowed in DATA blocks: {tok!r}"
            )
        if tok == "a":
            return _RDF_TYPE
        if tok.startswith("<") or tok.startswith('"') or _NUM.fullmatch(tok):
            return tok
        ns, colon, local = tok.partition(":")
        if not colon or ns not in prefixes:
            raise ParseError(f"unknown prefix {ns!r} in {tok!r}")
        return f"<{prefixes[ns]}{local}>"

    def parse_data_block() -> tuple[TriplePattern, ...]:
        eat("{")
        triples: list[TriplePattern] = []
        while peek() != "}":
            s = resolve(eat())
            triples.append(TriplePattern(s, resolve(eat()), resolve(eat())))
            while peek() == ";":  # predicate-object lists share the subject
                eat()
                if peek() in (".", "}"):
                    break
                triples.append(
                    TriplePattern(s, resolve(eat()), resolve(eat()))
                )
            if peek() == ".":
                eat()
        eat("}")
        if not triples:
            raise ParseError("empty DATA block")
        return tuple(triples)

    ops: list[algebra.UpdateOp] = []
    while True:
        head = eat().upper()
        if head not in ("INSERT", "DELETE"):
            raise ParseError(
                f"expected INSERT DATA or DELETE DATA, got {head!r}"
            )
        eat("DATA")
        block = parse_data_block()
        ops.append(
            algebra.InsertData(block) if head == "INSERT"
            else algebra.DeleteData(block)
        )
        if peek() == ";":
            eat()
            if not peek():  # trailing `;` after the last op is legal
                break
            continue
        break
    if peek():
        raise ParseError(f"trailing input after update: {peek()!r}")
    return UpdateRequest(tuple(ops))
