"""SPARQL parser: PREFIX / SELECT [DISTINCT] / WHERE / LIMIT / OFFSET.

Covers the query class the paper (basic graph patterns with variables,
IRIs, prefixed names, literals, `;` predicate-object lists) and its
successors evaluate: FILTER comparisons (numeric and string literals,
variable-variable), OPTIONAL groups, `#` line comments, integer/decimal
literals, and LIMIT/OFFSET solution modifiers. Parsing is host-side — part
of the CPU half of the coprocessing strategy.

The result is a `Query`: the WHERE group decomposed into a required BGP,
OPTIONAL groups and filter conditions, plus the solution modifiers.
`Query.algebra()` assembles the logical-algebra tree (sparql/algebra.py)
that the engine plans and compiles.
"""
from __future__ import annotations

import dataclasses
import re

from repro.core.planner import TriplePattern
from repro.sparql import algebra

_TOKEN = re.compile(
    r"""\s*(?:
        (?P<comment>\#[^\n]*)
      | (?P<var>\?[A-Za-z_][\w]*)
      | (?P<iri><[^>\s]*>)
      | (?P<literal>"(?:[^"\\]|\\.)*")
      | (?P<num>-?\d+(?:\.\d+)?)
      | (?P<pname>[A-Za-z_][\w\-]*:[A-Za-z_][\w\-]*)
      | (?P<pdecl>[A-Za-z_][\w\-]*:)
      | (?P<op><=|>=|!=|&&|[=<>()])
      | (?P<kw>PREFIX|SELECT|DISTINCT|WHERE|FILTER|OPTIONAL|LIMIT|OFFSET
              |\{|\}|\.|;|\*|a\b)
    )""",
    re.VERBOSE | re.IGNORECASE,
)

_NUM = re.compile(r"-?\d+(?:\.\d+)?")

_RDF_TYPE = "<http://www.w3.org/1999/02/22-rdf-syntax-ns#type>"


@dataclasses.dataclass
class Query:
    select_vars: list[str]  # empty => SELECT *
    distinct: bool
    patterns: list[TriplePattern]  # the required BGP
    optionals: tuple[tuple[TriplePattern, ...], ...] = ()
    filters: tuple[algebra.Compare, ...] = ()
    limit: int | None = None
    offset: int = 0

    def all_vars(self) -> list[str]:
        out: list[str] = []
        for tp in self.patterns:
            for v in tp.variables():
                if v not in out:
                    out.append(v)
        for group in self.optionals:
            for tp in group:
                for v in tp.variables():
                    if v not in out:
                        out.append(v)
        return out

    def projection(self) -> list[str]:
        return self.select_vars or self.all_vars()

    def has_slice(self) -> bool:
        return self.limit is not None or self.offset > 0

    def algebra(self) -> algebra.AlgebraNode:
        """Assemble the logical tree: BGP → LeftJoin* → Filter → Project
        → Distinct → Slice (group filters apply after the group's joins)."""
        node: algebra.AlgebraNode = algebra.BGP(tuple(self.patterns))
        for group in self.optionals:
            node = algebra.LeftJoin(node, algebra.BGP(group))
        if self.filters:
            node = algebra.Filter(node, self.filters)
        node = algebra.Project(node, tuple(self.projection()))
        if self.distinct:
            node = algebra.Distinct(node)
        if self.has_slice():
            node = algebra.Slice(node, self.offset, self.limit)
        return node


class ParseError(ValueError):
    pass


def _tokenize(text: str) -> list[str]:
    tokens, pos = [], 0
    while pos < len(text):
        if text[pos:].strip() == "":
            break
        m = _TOKEN.match(text, pos)
        if not m:
            raise ParseError(f"unexpected input at: {text[pos:pos + 30]!r}")
        if m.lastgroup != "comment":  # `#` line comments are skipped
            tokens.append(m.group(0).strip())
        pos = m.end()
    return tokens


def parse(text: str) -> Query:
    tokens = _tokenize(text)
    i = 0
    prefixes: dict[str, str] = {}

    def peek() -> str:
        return tokens[i] if i < len(tokens) else ""

    def eat(expect: str | None = None) -> str:
        nonlocal i
        if i >= len(tokens):
            raise ParseError(f"unexpected end of query (wanted {expect})")
        tok = tokens[i]
        if expect and tok.upper() != expect.upper():
            raise ParseError(f"expected {expect}, got {tok!r}")
        i += 1
        return tok

    while peek().upper() == "PREFIX":
        eat()
        pname = eat()
        if not pname.endswith(":"):
            raise ParseError(f"malformed PREFIX declaration near {pname!r}")
        iri = eat()
        if not (iri.startswith("<") and iri.endswith(">")):
            raise ParseError(f"PREFIX needs an IRI, got {iri!r}")
        prefixes[pname[:-1]] = iri[1:-1]

    eat("SELECT")
    distinct = False
    if peek().upper() == "DISTINCT":
        eat()
        distinct = True
    select_vars: list[str] = []
    if peek() == "*":
        eat()
    else:
        while peek().startswith("?"):
            select_vars.append(eat())
        if not select_vars:
            raise ParseError("SELECT needs variables or *")
    eat("WHERE")
    eat("{")

    def resolve(tok: str) -> str:
        if tok.startswith("?"):
            return tok
        if tok == "a":
            return _RDF_TYPE
        if tok.startswith("<") or tok.startswith('"') or _NUM.fullmatch(tok):
            return tok
        ns, colon, local = tok.partition(":")
        if not colon or ns not in prefixes:
            raise ParseError(f"unknown prefix {ns!r} in {tok!r}")
        return f"<{prefixes[ns]}{local}>"

    def parse_triples_into(dest: list[TriplePattern]) -> None:
        s = resolve(eat())
        dest.append(TriplePattern(s, resolve(eat()), resolve(eat())))
        # `;` predicate-object lists: `?x a ub:Student ; ub:memberOf ?d .`
        while peek() == ";":
            eat()
            if peek() in (".", "}"):  # dangling `;` before a terminator
                break
            dest.append(TriplePattern(s, resolve(eat()), resolve(eat())))

    def parse_operand() -> algebra.Operand:
        tok = eat()
        if tok.startswith("?"):
            return algebra.Var(tok)
        if _NUM.fullmatch(tok):
            return algebra.NumLit(float(tok), tok)
        return algebra.TermLit(resolve(tok))

    def parse_compare() -> algebra.Compare:
        lhs = parse_operand()
        if not isinstance(lhs, algebra.Var):
            raise ParseError(
                "FILTER comparisons must have a variable on the left"
            )
        op = eat()
        if op not in algebra.COMPARE_OPS:
            raise ParseError(f"expected a comparison operator, got {op!r}")
        rhs = parse_operand()
        if op in algebra.ORDERING_OPS and isinstance(rhs, algebra.TermLit):
            raise ParseError(
                f"ordering comparison {op!r} needs a numeric literal or "
                f"variable, got {rhs.lexical!r}"
            )
        return algebra.Compare(lhs.name, op, rhs)

    patterns: list[TriplePattern] = []
    optionals: list[tuple[TriplePattern, ...]] = []
    filters: list[algebra.Compare] = []
    while peek() != "}":
        head = peek().upper()
        if head == "OPTIONAL":
            eat()
            eat("{")
            block: list[TriplePattern] = []
            while peek() != "}":
                if peek().upper() in ("OPTIONAL", "FILTER"):
                    raise ParseError(
                        "nested OPTIONAL/FILTER inside an OPTIONAL group "
                        "is not supported"
                    )
                parse_triples_into(block)
                if peek() == ".":
                    eat()
            eat("}")
            if not block:
                raise ParseError("empty OPTIONAL group")
            optionals.append(tuple(block))
        elif head == "FILTER":
            eat()
            eat("(")
            filters.append(parse_compare())
            while peek() == "&&":
                eat()
                filters.append(parse_compare())
            eat(")")
        else:
            parse_triples_into(patterns)
        if peek() == ".":
            eat()
    eat("}")

    limit: int | None = None
    offset = 0
    seen_mods: set[str] = set()
    while peek().upper() in ("LIMIT", "OFFSET"):
        kw = eat().upper()
        if kw in seen_mods:
            raise ParseError(f"duplicate {kw}")
        seen_mods.add(kw)
        val = eat()
        if not re.fullmatch(r"\d+", val):
            raise ParseError(f"{kw} needs a non-negative integer, got {val!r}")
        if kw == "LIMIT":
            limit = int(val)
        else:
            offset = int(val)
    if peek():
        raise ParseError(f"trailing input after query: {peek()!r}")

    if not patterns:
        raise ParseError("empty basic graph pattern")
    q = Query(
        select_vars,
        distinct,
        patterns,
        tuple(optionals),
        tuple(filters),
        limit,
        offset,
    )
    bound = set(q.all_vars())
    unknown = [v for v in select_vars if v not in bound]
    if unknown:
        raise ParseError(f"SELECT vars not in WHERE clause: {unknown}")
    for cond in filters:
        loose = [v for v in cond.variables() if v not in bound]
        if loose:
            raise ParseError(f"FILTER vars not in WHERE clause: {loose}")
    return q
