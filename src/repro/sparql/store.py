"""Indexed triple store — our stand-in for the paper's gStore black box.

Three sorted permutation indexes (SPO, POS, OSP) give a binary-search range
scan for any bound-prefix pattern; the scan result IS the paper's "partial
match" relation fed to the MapReduce join. Index build is host-side numpy
(load time); scans are O(log n) + slice.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.planner import TriplePattern
from repro.core.relation import Relation
from repro.sparql.dictionary import TermDict

# index order -> the permutation of (s, p, o) columns it sorts by
_INDEXES = {
    "spo": (0, 1, 2),
    "pos": (1, 2, 0),
    "osp": (2, 0, 1),
}
# bound-position tuple -> preferred index (longest sorted prefix bound)
_CHOICE = {
    (): "spo",
    ("s",): "spo",
    ("s", "p"): "spo",
    ("s", "p", "o"): "spo",
    ("p",): "pos",
    ("p", "o"): "pos",
    ("o",): "osp",
    ("s", "o"): "osp",
}


@dataclasses.dataclass
class TripleStore:
    triples: np.ndarray  # (n, 3) int32 dictionary-encoded
    dictionary: TermDict

    def __post_init__(self):
        self.triples = np.asarray(self.triples, np.int32).reshape(-1, 3)
        self._sorted: dict[str, np.ndarray] = {}
        for name, perm in _INDEXES.items():
            reordered = self.triples[:, perm]
            order = np.lexsort((reordered[:, 2], reordered[:, 1], reordered[:, 0]))
            self._sorted[name] = np.ascontiguousarray(reordered[order])

    def __len__(self) -> int:
        return len(self.triples)

    # -- pattern matching ------------------------------------------------
    def _bound(self, tp: TriplePattern) -> dict[str, int]:
        out = {}
        for pos, term in zip("spo", (tp.s, tp.p, tp.o)):
            if not term.startswith("?"):
                tid = self.dictionary.lookup(term)
                out[pos] = -1 if tid is None else tid
        return out

    def _range_scan(self, index: str, prefix_vals: list[int]) -> np.ndarray:
        data = self._sorted[index]
        lo, hi = 0, len(data)
        for level, v in enumerate(prefix_vals):
            col = data[lo:hi, level]
            lo, hi = lo + np.searchsorted(col, v, "left"), lo + np.searchsorted(
                col, v, "right"
            )
        return data[lo:hi]

    def estimate_cardinality(self, tp: TriplePattern) -> int:
        return len(self.match_rows(tp))

    def match_rows(self, tp: TriplePattern) -> np.ndarray:
        """Matching triples in (s, p, o) column order."""
        bound = self._bound(tp)
        if any(v < 0 for v in bound.values()):
            return np.zeros((0, 3), np.int32)  # unknown constant: no matches
        key = tuple(sorted(bound.keys(), key="spo".index))
        index = _CHOICE[key]  # every bound-position subset has an index
        perm = _INDEXES[index]
        pos_order = ["spo"[i] for i in perm]
        prefix = []
        for p in pos_order:
            if p in bound:
                prefix.append(bound[p])
            else:
                break
        rows = self._range_scan(index, prefix)
        # invert the permutation back to (s, p, o)
        inv = np.argsort(perm)
        rows = rows[:, inv]
        # residual filters for bound positions beyond the sorted prefix
        for i, p in enumerate("spo"):
            if p in bound and p not in pos_order[: len(prefix)]:
                rows = rows[rows[:, i] == bound[p]]
        return rows

    def match_pattern(self, tp: TriplePattern, min_capacity: int = 1) -> Relation:
        """Partial-match Relation over the pattern's variables."""
        rows = self.match_rows(tp)
        vars_, cols = [], []
        for i, term in enumerate((tp.s, tp.p, tp.o)):
            if term.startswith("?"):
                if term in vars_:  # repeated var, e.g. (?x p ?x): filter
                    rows = rows[rows[:, i] == rows[:, cols[vars_.index(term)]]]
                else:
                    vars_.append(term)
                    cols.append(i)
        mat = rows[:, cols] if len(rows) else np.zeros((0, len(cols)), np.int32)
        capacity = max(min_capacity, _next_pow2(len(mat)))
        return Relation.from_numpy(tuple(vars_), mat, capacity=capacity)


def _next_pow2(n: int) -> int:
    return 1 << max(0, (max(1, n) - 1).bit_length())


def store_from_string_triples(
    triples: list[tuple[str, str, str]], dictionary: TermDict | None = None
) -> TripleStore:
    d = dictionary or TermDict()
    enc = np.array(
        [[d.encode(s), d.encode(p), d.encode(o)] for s, p, o in triples], np.int32
    ).reshape(-1, 3)
    return TripleStore(enc, d)
