"""Indexed triple store — our stand-in for the paper's gStore black box.

Three sorted permutation indexes (SPO, POS, OSP) give a binary-search range
scan for any bound-prefix pattern; the scan result IS the paper's "partial
match" relation fed to the MapReduce join. Index build is host-side numpy
(load time); scans are O(log n) + slice.

For the compiled query pipeline the store additionally keeps scan results
*device-resident*: `match_pattern_device` uploads a pattern's partial-match
arrays once, at a bucketed (pow-2) capacity, and hands the same device
buffers to every later query with the same pattern structure — so warm
queries feed the compiled executor with zero host->device re-staging. A
host-side row cache backs `match_rows`, making repeated planning
(cardinality estimation) a dict lookup.

The store takes writes through a delta-block design (INSERT DATA / DELETE
DATA): the sorted indexes cover an immutable *base* block, inserted rows
live in a small mutable *tail*, and deleted base rows go into a *tombstone*
set until `compact()` folds everything back into a fresh base. A staged
scan block is the base matches (tombstoned rows retained but masked
invalid — the compiled program's validity masks apply the delete
device-side) followed by the tail matches, at a capacity floored by the
pattern's high-water mark; within a pow-2 bucket, writes change the
staged *contents* but never the *shape*, so plan caches and compiled
executables survive updates. Every committed write batch bumps the
monotonic `version`; scan-cache entries record the version they staged
and are evicted on first stale lookup.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.plan_ir import bucket_capacity, next_pow2
from repro.core.planner import TriplePattern
from repro.core.relation import Relation
from repro.sparql.dictionary import TermDict

# back-compat alias: engine/benchmarks historically import it from here
_next_pow2 = next_pow2

# index order -> the permutation of (s, p, o) columns it sorts by
_INDEXES = {
    "spo": (0, 1, 2),
    "pos": (1, 2, 0),
    "osp": (2, 0, 1),
}
# bound-position tuple -> preferred index (longest sorted prefix bound)
_CHOICE = {
    (): "spo",
    ("s",): "spo",
    ("s", "p"): "spo",
    ("s", "p", "o"): "spo",
    ("p",): "pos",
    ("p", "o"): "pos",
    ("o",): "osp",
    ("s", "o"): "osp",
}


@dataclasses.dataclass(frozen=True)
class PredicateStats:
    """Per-predicate catalog row: triple count, distinct-term counts and
    degree-skew metrics.

    `max_s_degree` / `max_o_degree` are the largest per-subject fan-out /
    per-object fan-in inside the predicate; the averages derive from the
    counts. Their ratio (`s_skew` / `o_skew`) is the skew signal the
    optimizer combines with join selectivity to pick the matrix join
    backend: a hot key makes the MR backend's sort + expansion scale with
    the dense product anyway, at which point the sort is pure overhead.
    Defaults keep catalogs from before the skew fields loading (skew 1 =
    uniform = never prefer the matrix backend on stale data)."""

    count: int
    n_subjects: int
    n_objects: int
    max_s_degree: int = 1
    max_o_degree: int = 1

    @property
    def avg_s_degree(self) -> float:
        return self.count / max(1, self.n_subjects)

    @property
    def avg_o_degree(self) -> float:
        return self.count / max(1, self.n_objects)

    @property
    def s_skew(self) -> float:
        return self.max_s_degree / max(1.0, self.avg_s_degree)

    @property
    def o_skew(self) -> float:
        return self.max_o_degree / max(1.0, self.avg_o_degree)


@dataclasses.dataclass(frozen=True)
class StoreStatistics:
    """The statistics catalog the cost-based optimizer plans against.

    Computed once at load time (host numpy over the encoded triples):
    global triple/subject/object counts plus, per predicate id, the triple
    count and the distinct subject/object counts. These drive two
    estimators: `pattern_cardinality` (formula-based match-count estimate
    for a triple pattern without scanning) and `distinct_values` (estimated
    number of distinct bindings a variable takes among a pattern's matches
    — the denominator of the System-R style join selectivity
    |L ⋈ R| ≈ |L|·|R| / max(d_L(v), d_R(v)) the optimizer uses).
    """

    n_triples: int
    n_subjects: int
    n_objects: int
    n_predicates: int
    predicates: dict[int, PredicateStats]

    @classmethod
    def from_triples(cls, triples: np.ndarray) -> "StoreStatistics":
        t = np.asarray(triples, np.int32).reshape(-1, 3)
        n = len(t)
        if n == 0:
            return cls(0, 0, 0, 0, {})
        preds: dict[int, PredicateStats] = {}
        order = np.argsort(t[:, 1], kind="stable")
        ts = t[order]
        pids, starts = np.unique(ts[:, 1], return_index=True)
        bounds = list(starts) + [n]
        for k, pid in enumerate(pids):
            seg = ts[bounds[k]:bounds[k + 1]]
            s_deg = np.unique(seg[:, 0], return_counts=True)[1]
            o_deg = np.unique(seg[:, 2], return_counts=True)[1]
            preds[int(pid)] = PredicateStats(
                count=len(seg),
                n_subjects=int(s_deg.size),
                n_objects=int(o_deg.size),
                max_s_degree=int(s_deg.max()),
                max_o_degree=int(o_deg.max()),
            )
        return cls(
            n_triples=n,
            n_subjects=int(np.unique(t[:, 0]).size),
            n_objects=int(np.unique(t[:, 2]).size),
            n_predicates=len(pids),
            predicates=preds,
        )

    @classmethod
    def merge(cls, parts: "list[StoreStatistics]") -> "StoreStatistics":
        """Aggregate per-shard catalogs into one store-wide catalog.

        Exact for subject-hash partitioned shards on every additive count
        (triple counts sum; subject sets are disjoint across shards, so
        distinct-subject counts sum too). Distinct OBJECT counts can
        overlap between shards, so the merge takes the per-shard maximum —
        a lower bound, which only makes the optimizer's System-R join
        selectivities more conservative (never unsound).
        """
        preds: dict[int, PredicateStats] = {}
        for part in parts:
            for pid, ps in part.predicates.items():
                old = preds.get(pid)
                if old is None:
                    preds[pid] = ps
                else:
                    preds[pid] = PredicateStats(
                        count=old.count + ps.count,
                        n_subjects=old.n_subjects + ps.n_subjects,
                        n_objects=max(old.n_objects, ps.n_objects),
                        # subject degrees are exact under subject-hash
                        # partitioning (a subject lives on one shard);
                        # object degrees merge as a lower bound, like the
                        # distinct-object counts above
                        max_s_degree=max(old.max_s_degree, ps.max_s_degree),
                        max_o_degree=max(old.max_o_degree, ps.max_o_degree),
                    )
        return cls(
            n_triples=sum(p.n_triples for p in parts),
            n_subjects=sum(p.n_subjects for p in parts),
            n_objects=max((p.n_objects for p in parts), default=0),
            n_predicates=len(preds),
            predicates=preds,
        )

    def _bound_ids(self, tp: TriplePattern, lookup) -> dict[str, int] | None:
        """Term ids of the pattern's constants; None if any is unknown
        (an unknown constant can never match — cardinality 0)."""
        out: dict[str, int] = {}
        for pos, term in zip("spo", (tp.s, tp.p, tp.o)):
            if not term.startswith("?"):
                tid = lookup(term)
                if tid is None:
                    return None
                out[pos] = tid
        return out

    def pattern_cardinality(self, tp: TriplePattern, lookup) -> float:
        """Estimated match count for a triple pattern, by uniformity
        assumptions over the catalog (no scan)."""
        bound = self._bound_ids(tp, lookup)
        if bound is None:
            return 0.0
        if "p" in bound:
            ps = self.predicates.get(bound["p"])
            if ps is None:
                return 0.0
            card = float(ps.count)
            if "s" in bound:
                card /= max(1, ps.n_subjects)
            if "o" in bound:
                card /= max(1, ps.n_objects)
            return card
        card = float(self.n_triples)
        if "s" in bound:
            card /= max(1, self.n_subjects)
        if "o" in bound:
            card /= max(1, self.n_objects)
        return card

    def distinct_values(self, tp: TriplePattern, var: str, lookup) -> float:
        """Estimated distinct bindings of `var` among `tp`'s matches."""
        ps = None
        if not tp.p.startswith("?"):
            pid = lookup(tp.p)
            if pid is None:
                return 0.0
            ps = self.predicates.get(pid)
            if ps is None:
                return 0.0
        if var == tp.s:
            return float(ps.n_subjects if ps else self.n_subjects)
        if var == tp.p:
            return float(self.n_predicates)
        if var == tp.o:
            return float(ps.n_objects if ps else self.n_objects)
        return 1.0

    # -- persistence (warmup files carry the catalog so backend decisions
    # -- survive restarts) ------------------------------------------------
    def to_jsonable(self) -> dict:
        return {
            "n_triples": self.n_triples,
            "n_subjects": self.n_subjects,
            "n_objects": self.n_objects,
            "n_predicates": self.n_predicates,
            "predicates": {
                str(pid): [
                    ps.count,
                    ps.n_subjects,
                    ps.n_objects,
                    ps.max_s_degree,
                    ps.max_o_degree,
                ]
                for pid, ps in self.predicates.items()
            },
        }

    @classmethod
    def from_jsonable(cls, obj: dict) -> "StoreStatistics":
        preds: dict[int, PredicateStats] = {}
        for pid, row in obj["predicates"].items():
            # rows from before the skew fields have 3 entries: default the
            # degrees to 1 (uniform — the conservative backend choice)
            count, n_s, n_o = (int(v) for v in row[:3])
            max_s = int(row[3]) if len(row) > 3 else 1
            max_o = int(row[4]) if len(row) > 4 else 1
            preds[int(pid)] = PredicateStats(count, n_s, n_o, max_s, max_o)
        return cls(
            n_triples=int(obj["n_triples"]),
            n_subjects=int(obj["n_subjects"]),
            n_objects=int(obj["n_objects"]),
            n_predicates=int(obj["n_predicates"]),
            predicates=preds,
        )


class PredicateSparse(NamedTuple):
    """A predicate's triples as a device-resident sparse matrix.

    `coo` is the upload-once (subject, object) partial-match block in scan
    order — the SAME device buffers `match_pattern_device` hands the
    executor for a `(?s <p> ?o)` pattern, so caching it here adds no
    staging. The CSR view rides alongside: `order` permutes the COO rows
    into subject-sorted order, `subj_ids` are the distinct subjects and
    `row_ptr` their segment bounds in that order — the adjacency structure
    the masked-SpMM backend's reductions are defined over.
    """

    coo: Relation  # schema ("?0", "?1"), bucketed capacity, valid mask
    subj_ids: jnp.ndarray  # (n_subj,) sorted distinct subject ids
    row_ptr: jnp.ndarray  # (n_subj + 1,) CSR indptr into sorted order
    order: jnp.ndarray  # (nnz,) COO row -> subject-sorted position


@dataclasses.dataclass
class TripleStore:
    triples: np.ndarray  # (n, 3) int32 dictionary-encoded
    dictionary: TermDict
    scan_cache_entries: int = 512  # per cache; FIFO eviction
    # stacked entries are up to batch-width times a solo entry's bytes, so
    # they get a much smaller budget: the steady state this cache serves
    # (the same warm micro-batch repeating) needs few distinct keys
    stacked_cache_entries: int = 32

    def __post_init__(self):
        self.triples = np.asarray(self.triples, np.int32).reshape(-1, 3)
        # delta-block state: the sorted indexes cover the immutable base;
        # inserted rows ride in the tail, deleted base rows in the
        # tombstone set, until compact() folds both into a new base.
        # `triples` stays the *effective* row set (base minus tombstones
        # plus tail), recomputed at each committed write batch — the
        # sharding partitioner, statistics rebuilds and the differential
        # oracle all read it.
        self._base: np.ndarray = self.triples
        self._tail: list[tuple[int, int, int]] = []
        self._tomb: set[int] = set()  # packed (s, p, o) keys, see _pack1
        self._tomb_arr: np.ndarray | None = None  # sorted-key view cache
        self.version = 0  # bumped by every committed write batch/compaction
        self.compactions = 0
        # writers and scan staging share this reentrant lock: a query's
        # scans are staged under it, so every run sees one store version
        self._lock = threading.RLock()
        self._build_indexes()
        # scan caches, keyed by the pattern's canonical structure; entries
        # are (version, value) pairs — a stale entry is evicted (and
        # counted) on its first lookup after a write
        self._rows_cache: OrderedDict[tuple, tuple] = OrderedDict()
        self._device_cache: OrderedDict[tuple, tuple] = OrderedDict()
        self._scan_hits = 0
        self._scan_misses = 0
        self._evictions = 0
        # per-scan-key capacity high-water marks: staged blocks never
        # shrink, so warm plan shapes survive deletes and compaction
        self._cap_floor: dict[tuple, int] = {}
        # stacked (batch-axis) scan gather cache, keyed by the per-lane
        # pattern structures — warm repeated micro-batches re-dispatch the
        # same (width, capacity, n_cols) device buffers with zero staging
        self._stacked_cache: OrderedDict[tuple, tuple] = OrderedDict()
        self._stacked_hits = 0
        self._stacked_misses = 0
        self._num_vals = None  # device numeric-value table (FILTER support)
        self._num_vals_len = -1  # dictionary size the table was built at
        # per-predicate device CSR/COO (matrix join backend), FIFO like the
        # scan caches; shares its COO buffers with _device_cache entries
        self._sparse_cache: OrderedDict[int, tuple] = OrderedDict()
        self._statistics: StoreStatistics | None = None

    def _build_indexes(self) -> None:
        self._sorted: dict[str, np.ndarray] = {}
        for name, perm in _INDEXES.items():
            reordered = self._base[:, perm]
            order = np.lexsort((reordered[:, 2], reordered[:, 1], reordered[:, 0]))
            self._sorted[name] = np.ascontiguousarray(reordered[order])

    @property
    def statistics(self) -> StoreStatistics:
        """The statistics catalog the cost-based optimizer plans against.

        Computed from the effective triples on first use, then maintained
        incrementally by inserts/deletes (see _stats_note_insert /
        _stats_note_delete) and fully recomputed after a compaction."""
        if self._statistics is None:
            self._statistics = StoreStatistics.from_triples(self.triples)
        return self._statistics

    def __len__(self) -> int:
        return len(self.triples)

    # -- write path (delta blocks, tombstones, compaction) ----------------

    _PACK_BITS = 21  # term ids per tombstone key: 3 x 21 bits in an int64

    def _pack1(self, s: int, p: int, o: int) -> int:
        if max(s, p, o) >= 1 << self._PACK_BITS:
            raise ValueError(
                "tombstone keys pack term ids into 21 bits each; stores "
                "beyond 2M terms need a wider packing"
            )
        b = self._PACK_BITS
        return (s << (2 * b)) | (p << b) | o

    def _pack_rows(self, rows: np.ndarray) -> np.ndarray:
        r = rows.astype(np.int64)
        b = self._PACK_BITS
        return (r[:, 0] << (2 * b)) | (r[:, 1] << b) | r[:, 2]

    def _tomb_mask(self, rows: np.ndarray) -> np.ndarray:
        """True where a base row is tombstoned."""
        if not self._tomb or not len(rows):
            return np.zeros(len(rows), bool)
        if self._tomb_arr is None:
            self._tomb_arr = np.fromiter(
                self._tomb, np.int64, len(self._tomb)
            )
        return np.isin(self._pack_rows(rows), self._tomb_arr)

    def snapshot_lock(self) -> threading.RLock:
        """Reentrant lock shared by writers and scan staging. The engine
        stages a query's scans under it, so every run sees one consistent
        store version even with concurrent updates."""
        return self._lock

    def insert_triples(self, triples) -> int:
        """Encode and insert (s, p, o) term-string triples; returns the
        number actually added (set semantics: duplicates are skipped)."""
        rows = np.array(
            [
                [
                    self.dictionary.encode(s),
                    self.dictionary.encode(p),
                    self.dictionary.encode(o),
                ]
                for s, p, o in triples
            ],
            np.int32,
        ).reshape(-1, 3)
        return self.insert_rows(rows)

    def delete_triples(self, triples) -> int:
        """Delete (s, p, o) term-string triples; returns the number
        removed. Unknown terms mean the triple is absent — skipped without
        growing the dictionary."""
        rows = []
        for s, p, o in triples:
            ids = [self.dictionary.lookup(t) for t in (s, p, o)]
            if None not in ids:
                rows.append(ids)
        return self.delete_rows(np.asarray(rows, np.int32).reshape(-1, 3))

    def insert_rows(self, rows: np.ndarray) -> int:
        """Insert dictionary-encoded rows into the delta tail (or revive a
        tombstoned base row). RDF set semantics: rows already present are
        skipped. Returns the number added."""
        rows = np.asarray(rows, np.int32).reshape(-1, 3)
        n_added = 0
        with self._lock:
            for r in rows:
                s, p, o = (int(x) for x in r)
                if self._count_ids(s, p, o):
                    continue  # already present
                self._stats_note_insert(s, p, o)
                key = self._pack1(s, p, o)
                if key in self._tomb:
                    # re-inserting a deleted base row: just un-tombstone it
                    self._tomb.discard(key)
                    self._tomb_arr = None
                else:
                    self._tail.append((s, p, o))
                n_added += 1
            if n_added:
                self._commit_write()
        return n_added

    def delete_rows(self, rows: np.ndarray) -> int:
        """Delete dictionary-encoded rows: tail rows drop immediately, base
        rows are tombstoned until the next compaction. Returns the number
        removed (absent rows are skipped)."""
        rows = np.asarray(rows, np.int32).reshape(-1, 3)
        n_deleted = 0
        with self._lock:
            for r in rows:
                s, p, o = (int(x) for x in r)
                if not self._count_ids(s, p, o):
                    continue  # absent (or already deleted)
                t = (s, p, o)
                if t in self._tail:
                    self._tail.remove(t)
                else:
                    self._tomb.add(self._pack1(s, p, o))
                    self._tomb_arr = None
                self._stats_note_delete(s, p, o)
                n_deleted += 1
            if n_deleted:
                self._commit_write()
        return n_deleted

    def compact(self) -> None:
        """Fold the tail into a fresh base block: drop tombstoned rows,
        rebuild the three sorted indexes, clear the delta state and the
        scan caches (side tables regrow lazily on next use). Statistics
        are fully recomputed on next access, replacing the incremental
        estimates with exact values. Capacity floors are KEPT, so warm
        plan shapes re-run with zero compiles after a compaction."""
        with self._lock:
            self._base = np.ascontiguousarray(self._effective_triples())
            self._tail = []
            self._tomb = set()
            self._tomb_arr = None
            self._build_indexes()
            self.triples = self._base
            self._statistics = None  # full recompute on next use
            self._drop_scan_caches()
            self._num_vals = None  # regrow the numeric side table
            self._num_vals_len = -1
            self.version += 1
            self.compactions += 1

    def write_stats(self) -> dict:
        """Write-path health counters (engine.stats() / server stats())."""
        return {
            "version": self.version,
            "base_rows": int(len(self._base)),
            "tail_rows": len(self._tail),
            "tombstones": len(self._tomb),
            "compactions": self.compactions,
            "total_rows": int(len(self.triples)),
        }

    def _effective_triples(self) -> np.ndarray:
        base = self._base
        if self._tomb:
            base = base[~self._tomb_mask(base)]
        if self._tail:
            return np.concatenate(
                [base, np.asarray(self._tail, np.int32).reshape(-1, 3)]
            )
        return base

    def _commit_write(self) -> None:
        self._tomb_arr = None
        self.version += 1
        self.triples = self._effective_triples()

    def _drop_scan_caches(self) -> None:
        self._evictions += (
            len(self._rows_cache)
            + len(self._device_cache)
            + len(self._stacked_cache)
            + len(self._sparse_cache)
        )
        self._rows_cache.clear()
        self._device_cache.clear()
        self._stacked_cache.clear()
        self._sparse_cache.clear()

    def _count_ids(self, s=None, p=None, o=None) -> int:
        """Effective match count for id-level bound positions (None =
        wildcard) — the membership/degree probe behind set semantics and
        the incremental statistics."""
        bound = {k: v for k, v in zip("spo", (s, p, o)) if v is not None}
        return len(self._effective_for_bound(bound))

    def _stats_note_insert(self, s: int, p: int, o: int) -> None:
        """Incremental catalog maintenance; call BEFORE adding the row.

        Counts and distinct counts stay exact (membership is checked with
        O(log n) range scans); max degrees stay exact on insert."""
        st = self._statistics
        if st is None:
            return  # catalog not materialized yet: built lazily, post-write
        s_deg = self._count_ids(s=s, p=p)
        o_deg = self._count_ids(p=p, o=o)
        new_subj = self._count_ids(s=s) == 0
        new_obj = self._count_ids(o=o) == 0
        ps = st.predicates.get(p)
        if ps is None:
            st.predicates[p] = PredicateStats(1, 1, 1, 1, 1)
        else:
            st.predicates[p] = PredicateStats(
                count=ps.count + 1,
                n_subjects=ps.n_subjects + int(s_deg == 0),
                n_objects=ps.n_objects + int(o_deg == 0),
                max_s_degree=max(ps.max_s_degree, s_deg + 1),
                max_o_degree=max(ps.max_o_degree, o_deg + 1),
            )
        self._statistics = dataclasses.replace(
            st,
            n_triples=st.n_triples + 1,
            n_subjects=st.n_subjects + int(new_subj),
            n_objects=st.n_objects + int(new_obj),
            n_predicates=len(st.predicates),
        )

    def _stats_note_delete(self, s: int, p: int, o: int) -> None:
        """Incremental catalog maintenance; call AFTER removing the row.

        Counts and distinct counts stay exact; max degrees become upper
        bounds (still safe: overestimating skew only biases the optimizer
        toward the matrix backend) until compaction recomputes them."""
        st = self._statistics
        if st is None:
            return
        s_deg = self._count_ids(s=s, p=p)  # remaining degree
        o_deg = self._count_ids(p=p, o=o)
        gone_subj = self._count_ids(s=s) == 0
        gone_obj = self._count_ids(o=o) == 0
        ps = st.predicates.get(p)
        if ps is not None:
            if ps.count <= 1:
                del st.predicates[p]
            else:
                st.predicates[p] = PredicateStats(
                    count=ps.count - 1,
                    n_subjects=max(0, ps.n_subjects - int(s_deg == 0)),
                    n_objects=max(0, ps.n_objects - int(o_deg == 0)),
                    max_s_degree=ps.max_s_degree,
                    max_o_degree=ps.max_o_degree,
                )
        self._statistics = dataclasses.replace(
            st,
            n_triples=max(0, st.n_triples - 1),
            n_subjects=max(0, st.n_subjects - int(gone_subj)),
            n_objects=max(0, st.n_objects - int(gone_obj)),
            n_predicates=len(st.predicates),
        )

    # -- pattern matching ------------------------------------------------
    def _bound(self, tp: TriplePattern) -> dict[str, int]:
        out = {}
        for pos, term in zip("spo", (tp.s, tp.p, tp.o)):
            if not term.startswith("?"):
                tid = self.dictionary.lookup(term)
                out[pos] = -1 if tid is None else tid
        return out

    def _range_scan(self, index: str, prefix_vals: list[int]) -> np.ndarray:
        data = self._sorted[index]
        lo, hi = 0, len(data)
        for level, v in enumerate(prefix_vals):
            col = data[lo:hi, level]
            lo, hi = lo + np.searchsorted(col, v, "left"), lo + np.searchsorted(
                col, v, "right"
            )
        return data[lo:hi]

    def _scan_key(self, tp: TriplePattern) -> tuple:
        """Canonical pattern structure: variables -> ?0/?1/... by first
        appearance (captures repeated-variable filters), constants verbatim.
        """
        seen: dict[str, str] = {}
        out = []
        for term in (tp.s, tp.p, tp.o):
            if term.startswith("?"):
                if term not in seen:
                    seen[term] = f"?{len(seen)}"
                out.append(seen[term])
            else:
                out.append(term)
        return tuple(out)

    @staticmethod
    def _put(cache: OrderedDict, key, value, limit: int) -> None:
        cache[key] = value
        while len(cache) > limit:
            cache.popitem(last=False)

    def _vget(self, cache: OrderedDict, key):
        """Version-checked cache lookup: a hit staged at an older store
        version is evicted (and counted) instead of being served stale —
        and instead of piling up beside its replacement, which is what
        kept these caches bounded across writes."""
        slot = cache.get(key)
        if slot is None:
            return None
        ver, value = slot
        if ver == self.version:
            return value
        del cache[key]
        self._evictions += 1
        return None

    def estimate_cardinality(self, tp: TriplePattern) -> int:
        return len(self.match_rows(tp))

    def match_rows(self, tp: TriplePattern) -> np.ndarray:
        """Matching *effective* triples (base minus tombstones plus tail)
        in (s, p, o) column order (cached; treat the returned array as
        read-only)."""
        key = self._scan_key(tp)
        cached = self._vget(self._rows_cache, key)
        if cached is not None:
            return cached
        rows = self._match_rows_uncached(tp)
        self._put(
            self._rows_cache, key, (self.version, rows), self.scan_cache_entries
        )
        return rows

    def _match_rows_uncached(self, tp: TriplePattern) -> np.ndarray:
        bound = self._bound(tp)
        if any(v < 0 for v in bound.values()):
            return np.zeros((0, 3), np.int32)  # unknown constant: no matches
        return self._effective_for_bound(bound)

    def _rows_for_bound(self, bound: dict[str, int]) -> np.ndarray:
        """Base-block rows matching the bound positions, in scan order.
        Tombstoned rows are NOT filtered here — staged scans retain them
        (masked invalid) so block shapes stay stable across deletes."""
        key = tuple(sorted(bound.keys(), key="spo".index))
        index = _CHOICE[key]  # every bound-position subset has an index
        perm = _INDEXES[index]
        pos_order = ["spo"[i] for i in perm]
        prefix = []
        for p in pos_order:
            if p in bound:
                prefix.append(bound[p])
            else:
                break
        rows = self._range_scan(index, prefix)
        # invert the permutation back to (s, p, o)
        inv = np.argsort(perm)
        rows = rows[:, inv]
        # residual filters for bound positions beyond the sorted prefix
        for i, p in enumerate("spo"):
            if p in bound and p not in pos_order[: len(prefix)]:
                rows = rows[rows[:, i] == bound[p]]
        return rows

    def _tail_rows_for_bound(self, bound: dict[str, int]) -> np.ndarray:
        """Tail (inserted) rows matching the bound positions. The tail is
        small by construction — compaction folds it away — so a linear
        pass is fine."""
        if not self._tail:
            return np.zeros((0, 3), np.int32)
        idx = {"s": 0, "p": 1, "o": 2}
        out = [
            t
            for t in self._tail
            if all(t[idx[k]] == v for k, v in bound.items())
        ]
        return np.asarray(out, np.int32).reshape(-1, 3)

    def _effective_for_bound(self, bound: dict[str, int]) -> np.ndarray:
        base = self._rows_for_bound(bound)
        if self._tomb:
            base = base[~self._tomb_mask(base)]
        tail = self._tail_rows_for_bound(bound)
        if len(tail):
            return np.concatenate([base, tail])
        return base

    def _pattern_columns(
        self, tp: TriplePattern, rows: np.ndarray
    ) -> tuple[tuple[str, ...], np.ndarray]:
        """Project matched triples to the pattern's variable columns,
        filtering repeated variables (e.g. (?x p ?x))."""
        vars_: list[str] = []
        cols: list[int] = []
        for i, term in enumerate((tp.s, tp.p, tp.o)):
            if term.startswith("?"):
                if term in vars_:  # repeated var: equality filter
                    rows = rows[rows[:, i] == rows[:, cols[vars_.index(term)]]]
                else:
                    vars_.append(term)
                    cols.append(i)
        mat = rows[:, cols] if len(rows) else np.zeros((0, len(cols)), np.int32)
        return tuple(vars_), mat

    def _staged_columns(
        self, tp: TriplePattern
    ) -> tuple[tuple[str, ...], np.ndarray, np.ndarray]:
        """The pattern's staged partial-match block: (vars, columns, valid).

        Base matches come first in scan order with tombstoned rows RETAINED
        but masked invalid — the compiled program's validity masks apply
        the delete device-side, so a delete never changes block shapes —
        then the tail (inserted) matches follow. Repeated-variable
        equality (e.g. `?x p ?x`) drops rows outright; that is a per-row
        property, stable across versions, so capacities stay deterministic.
        """
        bound = self._bound(tp)
        vars_: list[str] = []
        cols: list[int] = []
        seen: dict[str, int] = {}
        for i, term in enumerate((tp.s, tp.p, tp.o)):
            if term.startswith("?") and term not in seen:
                seen[term] = i
                vars_.append(term)
                cols.append(i)
        if any(v < 0 for v in bound.values()):
            return (
                tuple(vars_),
                np.zeros((0, len(cols)), np.int32),
                np.zeros((0,), bool),
            )
        base = self._rows_for_bound(bound)
        live = ~self._tomb_mask(base)
        tail = self._tail_rows_for_bound(bound)
        if len(tail):
            rows = np.concatenate([base, tail])
            valid = np.concatenate([live, np.ones(len(tail), bool)])
        else:
            rows, valid = base, live
        keep = np.ones(len(rows), bool)
        for i, term in enumerate((tp.s, tp.p, tp.o)):
            if term.startswith("?") and seen.get(term) != i:
                keep &= rows[:, i] == rows[:, seen[term]]
        if not keep.all():
            rows, valid = rows[keep], valid[keep]
        mat = rows[:, cols] if len(rows) else np.zeros((0, len(cols)), np.int32)
        return tuple(vars_), mat, valid

    def _device_capacity(self, key: tuple, staged: int) -> int:
        """Bucketed capacity for a staged block, floored by the pattern's
        high-water mark: capacities never shrink, so warm plan shapes (and
        their compiled executables) survive deletes and compaction."""
        cap = max(bucket_capacity(staged), self._cap_floor.get(key, 0))
        self._cap_floor[key] = cap
        return cap

    def scan_capacity(self, tp: TriplePattern) -> int:
        """The capacity `match_pattern_device` would stage this pattern at
        right now, without uploading anything (explain's cache probe)."""
        key = self._scan_key(tp)
        _, mat, _ = self._staged_columns(tp)
        return max(bucket_capacity(len(mat)), self._cap_floor.get(key, 0))

    @staticmethod
    def _staged_relation(
        schema: tuple, mat: np.ndarray, valid: np.ndarray, capacity: int
    ) -> Relation:
        """Upload a staged block at `capacity`, carrying a per-row validity
        mask (Relation.from_numpy marks every staged row valid, which can't
        express tombstones)."""
        cols = np.zeros((capacity, mat.shape[1]), np.int32)
        cols[: len(mat)] = mat
        v = np.zeros((capacity,), bool)
        v[: len(valid)] = valid
        return Relation(tuple(schema), jnp.asarray(cols), jnp.asarray(v))

    def match_pattern(self, tp: TriplePattern, min_capacity: int = 1) -> Relation:
        """Partial-match Relation over the pattern's variables (eager path:
        fresh host->device upload, exact next-pow2 capacity)."""
        vars_, mat = self._pattern_columns(tp, self.match_rows(tp))
        capacity = max(min_capacity, _next_pow2(len(mat)))
        return Relation.from_numpy(vars_, mat, capacity=capacity)

    def match_pattern_device(self, tp: TriplePattern) -> Relation:
        """Device-resident staged partial match at a bucketed capacity.

        The device arrays are uploaded once per pattern structure and store
        version, and shared by every subsequent call (and across queries
        differing only in variable spelling); the returned Relation just
        rebinds the schema to this pattern's variable names. A `(?s <p> ?o)`
        pattern shares its buffers with the predicate's sparse
        representation (`predicate_sparse`) instead of uploading a second
        copy.
        """
        key = self._scan_key(tp)
        entry = self._vget(self._device_cache, key)
        if entry is None:
            self._scan_misses += 1
            if key[0] == "?0" and key[2] == "?1" and not key[1].startswith("?"):
                # (?s <p> ?o) with distinct vars: reuse the predicate COO
                sp = self.predicate_sparse(tp.p)
                entry = sp.coo if sp is not None else self._staged_relation(
                    ("?0", "?1"),
                    np.zeros((0, 2), np.int32),
                    np.zeros((0,), bool),
                    self._device_capacity(key, 0),
                )
            else:
                vars_, mat, valid = self._staged_columns(tp)
                placeholder = tuple(f"?{i}" for i in range(len(vars_)))
                entry = self._staged_relation(
                    placeholder, mat, valid, self._device_capacity(key, len(mat))
                )
            self._put(
                self._device_cache,
                key,
                (self.version, entry),
                self.scan_cache_entries,
            )
        else:
            self._scan_hits += 1
        actual, _ = self._pattern_columns(tp, np.zeros((0, 3), np.int32))
        return Relation(tuple(actual), entry.cols, entry.valid)

    def predicate_sparse(self, pred: str) -> "PredicateSparse | None":
        """The predicate's device CSR/COO bundle (None for an unknown
        predicate term), built on first use and cached FIFO. The COO block
        is in scan order — identical rows, order and capacity to the
        `match_pattern_device` entry for `(?s <p> ?o)` — so both caches
        point at one device allocation."""
        pid = self.dictionary.lookup(pred)
        if pid is None:
            return None
        entry = self._vget(self._sparse_cache, pid)
        if entry is not None:
            return entry
        tp = TriplePattern("?s", pred, "?o")
        _, mat, valid = self._staged_columns(tp)
        coo = self._staged_relation(
            ("?0", "?1"),
            mat,
            valid,
            self._device_capacity(("?0", pred, "?1"), len(mat)),
        )
        # CSR over the staged rows (tombstoned rows included: the masked
        # reductions see their validity through the COO mask)
        order = np.argsort(mat[:, 0], kind="stable").astype(np.int32)
        subj_ids, seg_counts = np.unique(mat[:, 0], return_counts=True)
        row_ptr = np.zeros(len(subj_ids) + 1, np.int32)
        np.cumsum(seg_counts, out=row_ptr[1:])
        entry = PredicateSparse(
            coo=coo,
            subj_ids=jnp.asarray(subj_ids.astype(np.int32)),
            row_ptr=jnp.asarray(row_ptr),
            order=jnp.asarray(order),
        )
        self._put(
            self._sparse_cache, pid, (self.version, entry), self.scan_cache_entries
        )
        return entry

    def stacked_scan_device(
        self, tps: "tuple[TriplePattern, ...]", cap: "int | None" = None
    ) -> tuple:
        """One scan position of a stacked batch: the partial matches of
        `tps` (one pattern per lane, trailing padding lanes repeating
        lane 0) gathered into (width, capacity, n_cols) cols and
        (width, capacity) valid device arrays.

        Within a same-shape plan group every lane stages at one capacity
        bucket by construction (capacity is part of the PlanShape queries
        group on). A cross-shape PADDED group passes `cap` — the group's
        per-position max bucket — and each lane is padded up to it with
        valid=False rows before stacking. The gather is cached by the
        (capacity, lane keys) tuple, so a warm repeated batch (the
        serving steady state) re-dispatches the same stacked buffers
        without re-staging anything.
        """
        from repro.core.relation import pad_to

        key = ("stacked", cap) + tuple(self._scan_key(tp) for tp in tps)
        entry = self._vget(self._stacked_cache, key)
        if entry is None:
            self._stacked_misses += 1
            rels = [self.match_pattern_device(tp) for tp in tps]
            if cap is not None:
                rels = [pad_to(r, cap) for r in rels]
            entry = (
                jnp.stack([r.cols for r in rels]),
                jnp.stack([r.valid for r in rels]),
            )
            self._put(
                self._stacked_cache,
                key,
                (self.version, entry),
                self.stacked_cache_entries,
            )
        else:
            self._stacked_hits += 1
        return entry

    def pattern_scan_info(self, tp: TriplePattern) -> tuple[tuple[str, ...], int]:
        """Host-side (schema, effective matching-row count) for a pattern —
        what a device scan would bind, without uploading anything. Shown by
        PreparedQuery.explain(); the cache probe uses scan_capacity()."""
        vars_, mat = self._pattern_columns(tp, self.match_rows(tp))
        return vars_, len(mat)

    def numeric_values_device(self):
        """Per-term-id numeric value table, padded to the next pow-2 of the
        dictionary size and rebuilt when inserts grow the dictionary.

        Gathered by term id inside compiled FILTER masks so numeric
        literals compare by value. The pow-2 padding keeps the table's
        device shape stable while the dictionary grows within a bucket;
        crossing a bucket boundary recompiles affected plans (the engine
        checks the table shape against each plan-cache entry)."""
        n = len(self.dictionary)
        if self._num_vals is None or self._num_vals_len != n:
            vals = np.asarray(self.dictionary.numeric_values(), np.float32)
            cap = next_pow2(max(1, n))
            if cap > len(vals):
                pad = np.full(cap - len(vals), np.nan, np.float32)
                vals = np.concatenate([vals, pad])
            self._num_vals = jnp.asarray(vals)
            self._num_vals_len = n
        return self._num_vals

    def scan_cache_stats(self) -> dict:
        return {
            "hits": self._scan_hits,
            "misses": self._scan_misses,
            "entries": len(self._device_cache),
            "evictions": self._evictions,
            "stacked_hits": self._stacked_hits,
            "stacked_misses": self._stacked_misses,
            "stacked_entries": len(self._stacked_cache),
        }


def store_from_string_triples(
    triples: list[tuple[str, str, str]], dictionary: TermDict | None = None
) -> TripleStore:
    d = dictionary or TermDict()
    enc = np.array(
        [[d.encode(s), d.encode(p), d.encode(o)] for s, p, o in triples], np.int32
    ).reshape(-1, 3)
    return TripleStore(enc, d)
