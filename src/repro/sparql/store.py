"""Indexed triple store — our stand-in for the paper's gStore black box.

Three sorted permutation indexes (SPO, POS, OSP) give a binary-search range
scan for any bound-prefix pattern; the scan result IS the paper's "partial
match" relation fed to the MapReduce join. Index build is host-side numpy
(load time); scans are O(log n) + slice.

For the compiled query pipeline the store additionally keeps scan results
*device-resident*: `match_pattern_device` uploads a pattern's partial-match
arrays once, at a bucketed (pow-2) capacity, and hands the same device
buffers to every later query with the same pattern structure — so warm
queries feed the compiled executor with zero host->device re-staging. A
host-side row cache backs `match_rows`, making repeated planning
(cardinality estimation) a dict lookup. Both caches assume the triple set
is immutable after construction (it is: `triples` is fixed in __post_init__).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.plan_ir import bucket_capacity, next_pow2
from repro.core.planner import TriplePattern
from repro.core.relation import Relation
from repro.sparql.dictionary import TermDict

# back-compat alias: engine/benchmarks historically import it from here
_next_pow2 = next_pow2

# index order -> the permutation of (s, p, o) columns it sorts by
_INDEXES = {
    "spo": (0, 1, 2),
    "pos": (1, 2, 0),
    "osp": (2, 0, 1),
}
# bound-position tuple -> preferred index (longest sorted prefix bound)
_CHOICE = {
    (): "spo",
    ("s",): "spo",
    ("s", "p"): "spo",
    ("s", "p", "o"): "spo",
    ("p",): "pos",
    ("p", "o"): "pos",
    ("o",): "osp",
    ("s", "o"): "osp",
}


@dataclasses.dataclass(frozen=True)
class PredicateStats:
    """Per-predicate catalog row: triple count, distinct-term counts and
    degree-skew metrics.

    `max_s_degree` / `max_o_degree` are the largest per-subject fan-out /
    per-object fan-in inside the predicate; the averages derive from the
    counts. Their ratio (`s_skew` / `o_skew`) is the skew signal the
    optimizer combines with join selectivity to pick the matrix join
    backend: a hot key makes the MR backend's sort + expansion scale with
    the dense product anyway, at which point the sort is pure overhead.
    Defaults keep catalogs from before the skew fields loading (skew 1 =
    uniform = never prefer the matrix backend on stale data)."""

    count: int
    n_subjects: int
    n_objects: int
    max_s_degree: int = 1
    max_o_degree: int = 1

    @property
    def avg_s_degree(self) -> float:
        return self.count / max(1, self.n_subjects)

    @property
    def avg_o_degree(self) -> float:
        return self.count / max(1, self.n_objects)

    @property
    def s_skew(self) -> float:
        return self.max_s_degree / max(1.0, self.avg_s_degree)

    @property
    def o_skew(self) -> float:
        return self.max_o_degree / max(1.0, self.avg_o_degree)


@dataclasses.dataclass(frozen=True)
class StoreStatistics:
    """The statistics catalog the cost-based optimizer plans against.

    Computed once at load time (host numpy over the encoded triples):
    global triple/subject/object counts plus, per predicate id, the triple
    count and the distinct subject/object counts. These drive two
    estimators: `pattern_cardinality` (formula-based match-count estimate
    for a triple pattern without scanning) and `distinct_values` (estimated
    number of distinct bindings a variable takes among a pattern's matches
    — the denominator of the System-R style join selectivity
    |L ⋈ R| ≈ |L|·|R| / max(d_L(v), d_R(v)) the optimizer uses).
    """

    n_triples: int
    n_subjects: int
    n_objects: int
    n_predicates: int
    predicates: dict[int, PredicateStats]

    @classmethod
    def from_triples(cls, triples: np.ndarray) -> "StoreStatistics":
        t = np.asarray(triples, np.int32).reshape(-1, 3)
        n = len(t)
        if n == 0:
            return cls(0, 0, 0, 0, {})
        preds: dict[int, PredicateStats] = {}
        order = np.argsort(t[:, 1], kind="stable")
        ts = t[order]
        pids, starts = np.unique(ts[:, 1], return_index=True)
        bounds = list(starts) + [n]
        for k, pid in enumerate(pids):
            seg = ts[bounds[k]:bounds[k + 1]]
            s_deg = np.unique(seg[:, 0], return_counts=True)[1]
            o_deg = np.unique(seg[:, 2], return_counts=True)[1]
            preds[int(pid)] = PredicateStats(
                count=len(seg),
                n_subjects=int(s_deg.size),
                n_objects=int(o_deg.size),
                max_s_degree=int(s_deg.max()),
                max_o_degree=int(o_deg.max()),
            )
        return cls(
            n_triples=n,
            n_subjects=int(np.unique(t[:, 0]).size),
            n_objects=int(np.unique(t[:, 2]).size),
            n_predicates=len(pids),
            predicates=preds,
        )

    @classmethod
    def merge(cls, parts: "list[StoreStatistics]") -> "StoreStatistics":
        """Aggregate per-shard catalogs into one store-wide catalog.

        Exact for subject-hash partitioned shards on every additive count
        (triple counts sum; subject sets are disjoint across shards, so
        distinct-subject counts sum too). Distinct OBJECT counts can
        overlap between shards, so the merge takes the per-shard maximum —
        a lower bound, which only makes the optimizer's System-R join
        selectivities more conservative (never unsound).
        """
        preds: dict[int, PredicateStats] = {}
        for part in parts:
            for pid, ps in part.predicates.items():
                old = preds.get(pid)
                if old is None:
                    preds[pid] = ps
                else:
                    preds[pid] = PredicateStats(
                        count=old.count + ps.count,
                        n_subjects=old.n_subjects + ps.n_subjects,
                        n_objects=max(old.n_objects, ps.n_objects),
                        # subject degrees are exact under subject-hash
                        # partitioning (a subject lives on one shard);
                        # object degrees merge as a lower bound, like the
                        # distinct-object counts above
                        max_s_degree=max(old.max_s_degree, ps.max_s_degree),
                        max_o_degree=max(old.max_o_degree, ps.max_o_degree),
                    )
        return cls(
            n_triples=sum(p.n_triples for p in parts),
            n_subjects=sum(p.n_subjects for p in parts),
            n_objects=max((p.n_objects for p in parts), default=0),
            n_predicates=len(preds),
            predicates=preds,
        )

    def _bound_ids(self, tp: TriplePattern, lookup) -> dict[str, int] | None:
        """Term ids of the pattern's constants; None if any is unknown
        (an unknown constant can never match — cardinality 0)."""
        out: dict[str, int] = {}
        for pos, term in zip("spo", (tp.s, tp.p, tp.o)):
            if not term.startswith("?"):
                tid = lookup(term)
                if tid is None:
                    return None
                out[pos] = tid
        return out

    def pattern_cardinality(self, tp: TriplePattern, lookup) -> float:
        """Estimated match count for a triple pattern, by uniformity
        assumptions over the catalog (no scan)."""
        bound = self._bound_ids(tp, lookup)
        if bound is None:
            return 0.0
        if "p" in bound:
            ps = self.predicates.get(bound["p"])
            if ps is None:
                return 0.0
            card = float(ps.count)
            if "s" in bound:
                card /= max(1, ps.n_subjects)
            if "o" in bound:
                card /= max(1, ps.n_objects)
            return card
        card = float(self.n_triples)
        if "s" in bound:
            card /= max(1, self.n_subjects)
        if "o" in bound:
            card /= max(1, self.n_objects)
        return card

    def distinct_values(self, tp: TriplePattern, var: str, lookup) -> float:
        """Estimated distinct bindings of `var` among `tp`'s matches."""
        ps = None
        if not tp.p.startswith("?"):
            pid = lookup(tp.p)
            if pid is None:
                return 0.0
            ps = self.predicates.get(pid)
            if ps is None:
                return 0.0
        if var == tp.s:
            return float(ps.n_subjects if ps else self.n_subjects)
        if var == tp.p:
            return float(self.n_predicates)
        if var == tp.o:
            return float(ps.n_objects if ps else self.n_objects)
        return 1.0

    # -- persistence (warmup files carry the catalog so backend decisions
    # -- survive restarts) ------------------------------------------------
    def to_jsonable(self) -> dict:
        return {
            "n_triples": self.n_triples,
            "n_subjects": self.n_subjects,
            "n_objects": self.n_objects,
            "n_predicates": self.n_predicates,
            "predicates": {
                str(pid): [
                    ps.count,
                    ps.n_subjects,
                    ps.n_objects,
                    ps.max_s_degree,
                    ps.max_o_degree,
                ]
                for pid, ps in self.predicates.items()
            },
        }

    @classmethod
    def from_jsonable(cls, obj: dict) -> "StoreStatistics":
        preds: dict[int, PredicateStats] = {}
        for pid, row in obj["predicates"].items():
            # rows from before the skew fields have 3 entries: default the
            # degrees to 1 (uniform — the conservative backend choice)
            count, n_s, n_o = (int(v) for v in row[:3])
            max_s = int(row[3]) if len(row) > 3 else 1
            max_o = int(row[4]) if len(row) > 4 else 1
            preds[int(pid)] = PredicateStats(count, n_s, n_o, max_s, max_o)
        return cls(
            n_triples=int(obj["n_triples"]),
            n_subjects=int(obj["n_subjects"]),
            n_objects=int(obj["n_objects"]),
            n_predicates=int(obj["n_predicates"]),
            predicates=preds,
        )


class PredicateSparse(NamedTuple):
    """A predicate's triples as a device-resident sparse matrix.

    `coo` is the upload-once (subject, object) partial-match block in scan
    order — the SAME device buffers `match_pattern_device` hands the
    executor for a `(?s <p> ?o)` pattern, so caching it here adds no
    staging. The CSR view rides alongside: `order` permutes the COO rows
    into subject-sorted order, `subj_ids` are the distinct subjects and
    `row_ptr` their segment bounds in that order — the adjacency structure
    the masked-SpMM backend's reductions are defined over.
    """

    coo: Relation  # schema ("?0", "?1"), bucketed capacity, valid mask
    subj_ids: jnp.ndarray  # (n_subj,) sorted distinct subject ids
    row_ptr: jnp.ndarray  # (n_subj + 1,) CSR indptr into sorted order
    order: jnp.ndarray  # (nnz,) COO row -> subject-sorted position


@dataclasses.dataclass
class TripleStore:
    triples: np.ndarray  # (n, 3) int32 dictionary-encoded
    dictionary: TermDict
    scan_cache_entries: int = 512  # per cache; FIFO eviction
    # stacked entries are up to batch-width times a solo entry's bytes, so
    # they get a much smaller budget: the steady state this cache serves
    # (the same warm micro-batch repeating) needs few distinct keys
    stacked_cache_entries: int = 32

    def __post_init__(self):
        self.triples = np.asarray(self.triples, np.int32).reshape(-1, 3)
        self._sorted: dict[str, np.ndarray] = {}
        for name, perm in _INDEXES.items():
            reordered = self.triples[:, perm]
            order = np.lexsort((reordered[:, 2], reordered[:, 1], reordered[:, 0]))
            self._sorted[name] = np.ascontiguousarray(reordered[order])
        # scan caches, keyed by the pattern's canonical structure
        self._rows_cache: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._device_cache: OrderedDict[tuple, Relation] = OrderedDict()
        self._scan_hits = 0
        self._scan_misses = 0
        # stacked (batch-axis) scan gather cache, keyed by the per-lane
        # pattern structures — warm repeated micro-batches re-dispatch the
        # same (width, capacity, n_cols) device buffers with zero staging
        self._stacked_cache: OrderedDict[tuple, tuple] = OrderedDict()
        self._stacked_hits = 0
        self._stacked_misses = 0
        self._num_vals = None  # device numeric-value table (FILTER support)
        # per-predicate device CSR/COO (matrix join backend), FIFO like the
        # scan caches; shares its COO buffers with _device_cache entries
        self._sparse_cache: OrderedDict[int, PredicateSparse] = OrderedDict()
        self._statistics: StoreStatistics | None = None

    @property
    def statistics(self) -> StoreStatistics:
        """The statistics catalog the cost-based optimizer plans against,
        computed once on first use (the triple set is immutable)."""
        if self._statistics is None:
            self._statistics = StoreStatistics.from_triples(self.triples)
        return self._statistics

    def __len__(self) -> int:
        return len(self.triples)

    # -- pattern matching ------------------------------------------------
    def _bound(self, tp: TriplePattern) -> dict[str, int]:
        out = {}
        for pos, term in zip("spo", (tp.s, tp.p, tp.o)):
            if not term.startswith("?"):
                tid = self.dictionary.lookup(term)
                out[pos] = -1 if tid is None else tid
        return out

    def _range_scan(self, index: str, prefix_vals: list[int]) -> np.ndarray:
        data = self._sorted[index]
        lo, hi = 0, len(data)
        for level, v in enumerate(prefix_vals):
            col = data[lo:hi, level]
            lo, hi = lo + np.searchsorted(col, v, "left"), lo + np.searchsorted(
                col, v, "right"
            )
        return data[lo:hi]

    def _scan_key(self, tp: TriplePattern) -> tuple:
        """Canonical pattern structure: variables -> ?0/?1/... by first
        appearance (captures repeated-variable filters), constants verbatim.
        """
        seen: dict[str, str] = {}
        out = []
        for term in (tp.s, tp.p, tp.o):
            if term.startswith("?"):
                if term not in seen:
                    seen[term] = f"?{len(seen)}"
                out.append(seen[term])
            else:
                out.append(term)
        return tuple(out)

    @staticmethod
    def _put(cache: OrderedDict, key, value, limit: int) -> None:
        cache[key] = value
        while len(cache) > limit:
            cache.popitem(last=False)

    def estimate_cardinality(self, tp: TriplePattern) -> int:
        return len(self.match_rows(tp))

    def match_rows(self, tp: TriplePattern) -> np.ndarray:
        """Matching triples in (s, p, o) column order (cached; treat the
        returned array as read-only)."""
        key = self._scan_key(tp)
        cached = self._rows_cache.get(key)
        if cached is not None:
            return cached
        rows = self._match_rows_uncached(tp)
        self._put(self._rows_cache, key, rows, self.scan_cache_entries)
        return rows

    def _match_rows_uncached(self, tp: TriplePattern) -> np.ndarray:
        bound = self._bound(tp)
        if any(v < 0 for v in bound.values()):
            return np.zeros((0, 3), np.int32)  # unknown constant: no matches
        key = tuple(sorted(bound.keys(), key="spo".index))
        index = _CHOICE[key]  # every bound-position subset has an index
        perm = _INDEXES[index]
        pos_order = ["spo"[i] for i in perm]
        prefix = []
        for p in pos_order:
            if p in bound:
                prefix.append(bound[p])
            else:
                break
        rows = self._range_scan(index, prefix)
        # invert the permutation back to (s, p, o)
        inv = np.argsort(perm)
        rows = rows[:, inv]
        # residual filters for bound positions beyond the sorted prefix
        for i, p in enumerate("spo"):
            if p in bound and p not in pos_order[: len(prefix)]:
                rows = rows[rows[:, i] == bound[p]]
        return rows

    def _pattern_columns(
        self, tp: TriplePattern, rows: np.ndarray
    ) -> tuple[tuple[str, ...], np.ndarray]:
        """Project matched triples to the pattern's variable columns,
        filtering repeated variables (e.g. (?x p ?x))."""
        vars_: list[str] = []
        cols: list[int] = []
        for i, term in enumerate((tp.s, tp.p, tp.o)):
            if term.startswith("?"):
                if term in vars_:  # repeated var: equality filter
                    rows = rows[rows[:, i] == rows[:, cols[vars_.index(term)]]]
                else:
                    vars_.append(term)
                    cols.append(i)
        mat = rows[:, cols] if len(rows) else np.zeros((0, len(cols)), np.int32)
        return tuple(vars_), mat

    def match_pattern(self, tp: TriplePattern, min_capacity: int = 1) -> Relation:
        """Partial-match Relation over the pattern's variables (eager path:
        fresh host->device upload, exact next-pow2 capacity)."""
        vars_, mat = self._pattern_columns(tp, self.match_rows(tp))
        capacity = max(min_capacity, _next_pow2(len(mat)))
        return Relation.from_numpy(vars_, mat, capacity=capacity)

    def match_pattern_device(self, tp: TriplePattern) -> Relation:
        """Device-resident partial match at a bucketed capacity.

        The device arrays are uploaded once per pattern structure and shared
        by every subsequent call (and across queries differing only in
        variable spelling); the returned Relation just rebinds the schema to
        this pattern's variable names. A `(?s <p> ?o)` pattern shares its
        buffers with the predicate's sparse representation
        (`predicate_sparse`) instead of uploading a second copy.
        """
        key = self._scan_key(tp)
        entry = self._device_cache.get(key)
        if entry is None:
            self._scan_misses += 1
            if key[0] == "?0" and key[2] == "?1" and not key[1].startswith("?"):
                # (?s <p> ?o) with distinct vars: reuse the predicate COO
                sp = self.predicate_sparse(tp.p)
                entry = sp.coo if sp is not None else Relation.from_numpy(
                    ("?0", "?1"), np.zeros((0, 2), np.int32),
                    capacity=bucket_capacity(0),
                )
            else:
                vars_, mat = self._pattern_columns(tp, self.match_rows(tp))
                placeholder = tuple(f"?{i}" for i in range(len(vars_)))
                entry = Relation.from_numpy(
                    placeholder, mat, capacity=bucket_capacity(len(mat))
                )
            self._put(self._device_cache, key, entry, self.scan_cache_entries)
        else:
            self._scan_hits += 1
        actual, _ = self._pattern_columns(tp, np.zeros((0, 3), np.int32))
        return Relation(tuple(actual), entry.cols, entry.valid)

    def predicate_sparse(self, pred: str) -> "PredicateSparse | None":
        """The predicate's device CSR/COO bundle (None for an unknown
        predicate term), built on first use and cached FIFO. The COO block
        is in scan order — identical rows, order and capacity to the
        `match_pattern_device` entry for `(?s <p> ?o)` — so both caches
        point at one device allocation."""
        pid = self.dictionary.lookup(pred)
        if pid is None:
            return None
        entry = self._sparse_cache.get(pid)
        if entry is not None:
            return entry
        rows = self.match_rows(TriplePattern("?s", pred, "?o"))
        mat = rows[:, [0, 2]] if len(rows) else np.zeros((0, 2), np.int32)
        coo = Relation.from_numpy(
            ("?0", "?1"), mat, capacity=bucket_capacity(len(mat))
        )
        order = np.argsort(mat[:, 0], kind="stable").astype(np.int32)
        subj_ids, seg_counts = np.unique(mat[:, 0], return_counts=True)
        row_ptr = np.zeros(len(subj_ids) + 1, np.int32)
        np.cumsum(seg_counts, out=row_ptr[1:])
        entry = PredicateSparse(
            coo=coo,
            subj_ids=jnp.asarray(subj_ids.astype(np.int32)),
            row_ptr=jnp.asarray(row_ptr),
            order=jnp.asarray(order),
        )
        self._put(self._sparse_cache, pid, entry, self.scan_cache_entries)
        return entry

    def stacked_scan_device(
        self, tps: "tuple[TriplePattern, ...]"
    ) -> tuple:
        """One scan position of a stacked same-shape batch: the partial
        matches of `tps` (one pattern per lane, trailing padding lanes
        repeating lane 0) gathered into (width, capacity, n_cols) cols and
        (width, capacity) valid device arrays.

        All lanes share one capacity bucket — queries in a plan group have
        equal scan_caps by construction (capacity is part of the PlanShape
        they group on). The gather is cached by the lane-key tuple, so a
        warm repeated batch (the serving steady state) re-dispatches the
        same stacked buffers without re-staging anything.
        """
        key = ("stacked",) + tuple(self._scan_key(tp) for tp in tps)
        entry = self._stacked_cache.get(key)
        if entry is None:
            self._stacked_misses += 1
            rels = [self.match_pattern_device(tp) for tp in tps]
            entry = (
                jnp.stack([r.cols for r in rels]),
                jnp.stack([r.valid for r in rels]),
            )
            self._put(
                self._stacked_cache, key, entry, self.stacked_cache_entries
            )
        else:
            self._stacked_hits += 1
        return entry

    def pattern_scan_info(self, tp: TriplePattern) -> tuple[tuple[str, ...], int]:
        """Host-side (schema, matching-row count) for a pattern — exactly
        what a device scan would contain, without uploading anything.
        Used by PreparedQuery.explain() to probe the plan cache."""
        vars_, mat = self._pattern_columns(tp, self.match_rows(tp))
        return vars_, len(mat)

    def numeric_values_device(self):
        """Per-term-id numeric value table, uploaded once.

        Gathered by term id inside compiled FILTER masks so numeric
        literals compare by value. Assumes (like the scan caches) that the
        triple set and dictionary are immutable after construction.
        """
        if self._num_vals is None:
            self._num_vals = jnp.asarray(self.dictionary.numeric_values())
        return self._num_vals

    def scan_cache_stats(self) -> dict:
        return {
            "hits": self._scan_hits,
            "misses": self._scan_misses,
            "entries": len(self._device_cache),
            "stacked_hits": self._stacked_hits,
            "stacked_misses": self._stacked_misses,
            "stacked_entries": len(self._stacked_cache),
        }


def store_from_string_triples(
    triples: list[tuple[str, str, str]], dictionary: TermDict | None = None
) -> TripleStore:
    d = dictionary or TermDict()
    enc = np.array(
        [[d.encode(s), d.encode(p), d.encode(o)] for s, p, o in triples], np.int32
    ).reshape(-1, 3)
    return TripleStore(enc, d)
