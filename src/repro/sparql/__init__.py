"""RDF/SPARQL substrate: dictionary encoding, indexed triple store, a SPARQL
BGP parser, LUBM-style data generation, and query engines (MapSQ + the
CPU-join baselines the paper compares against)."""
