"""LUBM-style benchmark data + the 5 evaluation queries (paper §3).

The real LUBM generator emits a university-domain ontology; we reproduce
its structural skeleton (universities → departments → professors/students/
courses with typed relations) at an arbitrary scale factor, so join
selectivities behave like the benchmark: type scans are wide, relation
scans are narrow, multi-pattern BGPs have 1:N and N:M joins.

Five queries in the spirit of LUBM Q1/Q2/Q4/Q7/Q9 — star and chain BGPs of
2–5 triple patterns over the generated schema (the paper does not list its
exact 5; these cover the shape classes its Table 2 spans).
"""
from __future__ import annotations

import numpy as np

from repro.sparql.dictionary import TermDict
from repro.sparql.store import TripleStore

UB = "http://swat.cse.lehigh.edu/onto/univ-bench.owl#"
RDF_TYPE = "<http://www.w3.org/1999/02/22-rdf-syntax-ns#type>"


def _e(name: str) -> str:  # entity IRI
    return f"<http://example.org/{name}>"


def _u(name: str) -> str:  # ontology IRI
    return f"<{UB}{name}>"


def generate(scale: int = 1, seed: int = 0):
    """~scale × (15 departments × ~70 people) university graph."""
    rng = np.random.default_rng(seed)
    triples: list[tuple[str, str, str]] = []
    t = triples.append
    for ui in range(scale):
        uni = _e(f"University{ui}")
        t((uni, RDF_TYPE, _u("University")))
        for di in range(15):
            dept = _e(f"Dept{ui}_{di}")
            t((dept, RDF_TYPE, _u("Department")))
            t((dept, _u("subOrganizationOf"), uni))
            n_prof = 7 + int(rng.integers(0, 5))
            profs = []
            for pi in range(n_prof):
                prof = _e(f"Prof{ui}_{di}_{pi}")
                profs.append(prof)
                t((prof, RDF_TYPE, _u("FullProfessor")))
                t((prof, _u("worksFor"), dept))
                t((prof, _u("name"), f'"prof_{ui}_{di}_{pi}"'))
                deg = _e(f"University{int(rng.integers(0, max(1, scale)))}")
                t((prof, _u("undergraduateDegreeFrom"), deg))
            n_course = 12 + int(rng.integers(0, 6))
            courses = []
            for ci in range(n_course):
                c = _e(f"Course{ui}_{di}_{ci}")
                courses.append(c)
                t((c, RDF_TYPE, _u("Course")))
                teacher = profs[int(rng.integers(0, n_prof))]
                t((teacher, _u("teacherOf"), c))
            for si in range(40 + int(rng.integers(0, 20))):
                s = _e(f"Student{ui}_{di}_{si}")
                t((s, RDF_TYPE, _u("GraduateStudent")))
                t((s, _u("memberOf"), dept))
                t((s, _u("advisor"), profs[int(rng.integers(0, n_prof))]))
                for c in rng.choice(n_course, size=min(3, n_course),
                                    replace=False):
                    t((s, _u("takesCourse"), courses[int(c)]))
    d = TermDict()
    enc = np.array(
        [[d.encode(a), d.encode(b), d.encode(c)] for a, b, c in triples],
        np.int32,
    )
    return TripleStore(enc, d)


PREFIX = f"PREFIX ub: <{UB}>\nPREFIX rdf: <http://www.w3.org/1999/02/22-rdf-syntax-ns#>\n"

QUERIES: dict[str, str] = {
    # Q1 (LUBM-1-like): students taking a specific course — selective 2-join
    "Q1": PREFIX + """SELECT ?x WHERE {
        ?x rdf:type ub:GraduateStudent .
        ?x ub:takesCourse <http://example.org/Course0_0_0> .
    }""",
    # Q2 (chain): student -> advisor -> department (3 patterns, chain join)
    "Q2": PREFIX + """SELECT ?s ?p ?d WHERE {
        ?s ub:advisor ?p .
        ?p ub:worksFor ?d .
        ?d ub:subOrganizationOf <http://example.org/University0> .
    }""",
    # Q4 (star): professor attributes within a department
    "Q4": PREFIX + """SELECT ?p ?n WHERE {
        ?p rdf:type ub:FullProfessor .
        ?p ub:worksFor <http://example.org/Dept0_0> .
        ?p ub:name ?n .
    }""",
    # Q7 (N:M): students of courses taught by a given professor
    "Q7": PREFIX + """SELECT ?s ?c WHERE {
        ?s ub:takesCourse ?c .
        <http://example.org/Prof0_0_0> ub:teacherOf ?c .
        ?s rdf:type ub:GraduateStudent .
    }""",
    # Q9 (triangle-ish, 5 patterns): classmate pairs sharing advisor's course
    "Q9": PREFIX + """SELECT ?s ?t ?c WHERE {
        ?s ub:advisor ?t .
        ?t ub:teacherOf ?c .
        ?s ub:takesCourse ?c .
        ?s rdf:type ub:GraduateStudent .
        ?t rdf:type ub:FullProfessor .
    }""",
}
