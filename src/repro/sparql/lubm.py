"""LUBM-style benchmark data + the 5 evaluation queries (paper §3).

The real LUBM generator emits a university-domain ontology; we reproduce
its structural skeleton (universities → departments → professors/students/
courses with typed relations) at an arbitrary scale factor, so join
selectivities behave like the benchmark: type scans are wide, relation
scans are narrow, multi-pattern BGPs have 1:N and N:M joins.

Five queries in the spirit of LUBM Q1/Q2/Q4/Q7/Q9 — star and chain BGPs of
2–5 triple patterns over the generated schema (the paper does not list its
exact 5; these cover the shape classes its Table 2 spans).
"""
from __future__ import annotations

import numpy as np

from repro.sparql.dictionary import TermDict
from repro.sparql.store import TripleStore

UB = "http://swat.cse.lehigh.edu/onto/univ-bench.owl#"
RDF_TYPE = "<http://www.w3.org/1999/02/22-rdf-syntax-ns#type>"


def _e(name: str) -> str:  # entity IRI
    return f"<http://example.org/{name}>"


def _u(name: str) -> str:  # ontology IRI
    return f"<{UB}{name}>"


def join_shape_triples() -> list[tuple[str, str, str]]:
    """The J1/J2 bad-join-order subgraphs (deterministic).

    Both are chains whose *smallest* pattern is the wrong place to start:
    the greedy planner (leaf cardinality only) begins at the 10-row type
    scan, whose only connection is a 1:50/1:60 fan-out edge — a 500/600 row
    intermediate — while the statistics-driven order starts from the
    selective tail and keeps every intermediate at ~a dozen rows. The gap
    between the two orders' maximum join buckets is what
    benchmarks/bench_query.py and tests/test_optimizer.py measure.
    """
    out: list[tuple[str, str, str]] = []
    t = out.append
    # J1: jtype (10) -- j1 fan-out (500) -- j2 selective tail (12)
    for i in range(10):
        t((_e(f"J/x{i}"), _e("J/jtype"), _e("J/JT")))
        for k in range(50):
            t((_e(f"J/x{i}"), _e("J/j1"), _e(f"J/y{i * 50 + k}")))
    for n, yi in enumerate([i * 50 for i in range(10)] + [1, 2]):
        t((_e(f"J/y{yi}"), _e("J/j2"), _e(f"J/z{n}")))
    # J2: ktype (10) -- k1 fan-out (600) -- k2 (20) -- k3 tail (15)
    for i in range(10):
        t((_e(f"J/a{i}"), _e("J/ktype"), _e("J/KT")))
        for k in range(60):
            t((_e(f"J/a{i}"), _e("J/k1"), _e(f"J/b{i * 60 + k}")))
    for n, bi in enumerate([i * 60 for i in range(10)] + list(range(1, 11))):
        t((_e(f"J/b{bi}"), _e("J/k2"), _e(f"J/c{n}")))
    for n in range(15):
        t((_e(f"J/c{n}"), _e("J/k3"), _e(f"J/d{n}")))
    return out


def skewed_shape_triples() -> list[tuple[str, str, str]]:
    """The S1 skewed-predicate subgraph (deterministic).

    A 2-hop chain `?x p1 ?y . ?y p2 ?z` engineered so the join key is
    dominated by ONE hot value: p1 has 500 edges into a single hot object
    plus 100 degree-1 objects (o_skew ≈ 84), and p2 hangs 40 edges off
    that hot subject plus 20 degree-1 subjects. The join output (~20k
    rows) is within a constant factor of the dense |L|·|R| compare grid,
    which is exactly where the matrix (masked-SpMM) backend's
    argsort-free pipeline beats the MR join — the optimizer must pick it
    from σ·skew alone (see sparql/optimizer._choose_backend).
    """
    out: list[tuple[str, str, str]] = []
    t = out.append
    hot = _e("S/hub")
    for i in range(500):
        t((_e(f"S/x{i}"), _e("S/p1"), hot))
    for i in range(100):
        t((_e(f"S/u{i}"), _e("S/p1"), _e(f"S/v{i}")))
    for k in range(40):
        t((hot, _e("S/p2"), _e(f"S/z{k}")))
    for i in range(20):
        t((_e(f"S/w{i}"), _e("S/p2"), _e(f"S/q{i}")))
    return out


def generate(
    scale: int = 1,
    seed: int = 0,
    join_shapes: bool = False,
    skew_shapes: bool = False,
):
    """~scale × (15 departments × ~70 people) university graph.

    `join_shapes=True` additionally embeds the J1/J2 bad-join-order
    subgraphs (`join_shape_triples`) used to benchmark the optimizer;
    `skew_shapes=True` embeds the S1 skewed-predicate subgraph
    (`skewed_shape_triples`) used to benchmark backend selection."""
    rng = np.random.default_rng(seed)
    triples: list[tuple[str, str, str]] = []
    t = triples.append
    if join_shapes:
        triples.extend(join_shape_triples())
    if skew_shapes:
        triples.extend(skewed_shape_triples())
    for ui in range(scale):
        uni = _e(f"University{ui}")
        t((uni, RDF_TYPE, _u("University")))
        for di in range(15):
            dept = _e(f"Dept{ui}_{di}")
            t((dept, RDF_TYPE, _u("Department")))
            t((dept, _u("subOrganizationOf"), uni))
            n_prof = 7 + int(rng.integers(0, 5))
            profs = []
            for pi in range(n_prof):
                prof = _e(f"Prof{ui}_{di}_{pi}")
                profs.append(prof)
                t((prof, RDF_TYPE, _u("FullProfessor")))
                t((prof, _u("worksFor"), dept))
                t((prof, _u("name"), f'"prof_{ui}_{di}_{pi}"'))
                deg = _e(f"University{int(rng.integers(0, max(1, scale)))}")
                t((prof, _u("undergraduateDegreeFrom"), deg))
            n_course = 12 + int(rng.integers(0, 6))
            courses = []
            for ci in range(n_course):
                c = _e(f"Course{ui}_{di}_{ci}")
                courses.append(c)
                t((c, RDF_TYPE, _u("Course")))
                teacher = profs[int(rng.integers(0, n_prof))]
                t((teacher, _u("teacherOf"), c))
            for si in range(40 + int(rng.integers(0, 20))):
                s = _e(f"Student{ui}_{di}_{si}")
                t((s, RDF_TYPE, _u("GraduateStudent")))
                t((s, _u("memberOf"), dept))
                t((s, _u("advisor"), profs[int(rng.integers(0, n_prof))]))
                for c in rng.choice(n_course, size=min(3, n_course),
                                    replace=False):
                    t((s, _u("takesCourse"), courses[int(c)]))
    d = TermDict()
    enc = np.array(
        [[d.encode(a), d.encode(b), d.encode(c)] for a, b, c in triples],
        np.int32,
    )
    return TripleStore(enc, d)


PREFIX = f"PREFIX ub: <{UB}>\nPREFIX rdf: <http://www.w3.org/1999/02/22-rdf-syntax-ns#>\n"

QUERIES: dict[str, str] = {
    # Q1 (LUBM-1-like): students taking a specific course — selective 2-join
    "Q1": PREFIX + """SELECT ?x WHERE {
        ?x rdf:type ub:GraduateStudent .
        ?x ub:takesCourse <http://example.org/Course0_0_0> .
    }""",
    # Q2 (chain): student -> advisor -> department (3 patterns, chain join)
    "Q2": PREFIX + """SELECT ?s ?p ?d WHERE {
        ?s ub:advisor ?p .
        ?p ub:worksFor ?d .
        ?d ub:subOrganizationOf <http://example.org/University0> .
    }""",
    # Q4 (star): professor attributes within a department
    "Q4": PREFIX + """SELECT ?p ?n WHERE {
        ?p rdf:type ub:FullProfessor .
        ?p ub:worksFor <http://example.org/Dept0_0> .
        ?p ub:name ?n .
    }""",
    # Q7 (N:M): students of courses taught by a given professor
    "Q7": PREFIX + """SELECT ?s ?c WHERE {
        ?s ub:takesCourse ?c .
        <http://example.org/Prof0_0_0> ub:teacherOf ?c .
        ?s rdf:type ub:GraduateStudent .
    }""",
    # Q9 (triangle-ish, 5 patterns): classmate pairs sharing advisor's course
    "Q9": PREFIX + """SELECT ?s ?t ?c WHERE {
        ?s ub:advisor ?t .
        ?t ub:teacherOf ?c .
        ?s ub:takesCourse ?c .
        ?s rdf:type ub:GraduateStudent .
        ?t rdf:type ub:FullProfessor .
    }""",
}

# Bad-join-order shapes over the join_shape_triples() subgraphs: the greedy
# order explodes the first intermediate (500/600 rows), the statistics
# order stays ~12/15 rows. Only valid on generate(..., join_shapes=True).
J_QUERIES: dict[str, str] = {
    "J1": """SELECT ?x ?y ?z WHERE {
        ?x <http://example.org/J/jtype> <http://example.org/J/JT> .
        ?x <http://example.org/J/j1> ?y .
        ?y <http://example.org/J/j2> ?z .
    }""",
    "J2": """SELECT ?a ?b ?c ?d WHERE {
        ?a <http://example.org/J/ktype> <http://example.org/J/KT> .
        ?a <http://example.org/J/k1> ?b .
        ?b <http://example.org/J/k2> ?c .
        ?c <http://example.org/J/k3> ?d .
    }""",
}

# Skewed-predicate shape over skewed_shape_triples(): a hot join key puts
# the output within a constant factor of the dense |L|·|R| grid, so the
# cost model (selectivity × skew) routes the join to the matrix backend.
# Only valid on generate(..., skew_shapes=True).
S_QUERIES: dict[str, str] = {
    "S1": """SELECT ?x ?y ?z WHERE {
        ?x <http://example.org/S/p1> ?y .
        ?y <http://example.org/S/p2> ?z .
    }""",
}
