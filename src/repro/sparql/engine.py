"""The MapSQ query engine (Figure 1 of the paper) and its prepared-query API.

Coprocessing split, exactly as the paper describes it:
  CPU  — parse, dictionary-encode, plan join order, size capacities,
         dispatch subqueries (this file, host Python);
  GPU→TPU — pattern range-scans feed the MapReduce join (Algorithm 1,
         core/mr_join.py, jitted).

The public API is layered around prepared queries:

  engine.prepare(text) -> PreparedQuery   parse + validate + plan once
  pq.run()             -> ResultSet       typed rows + the run's ExecStats
  pq.explain()         -> str             algebra tree, physical plan,
                                          bucket capacities, cache state
  engine.query(text)   -> list[dict]      thin wrapper: prepare().run().rows

Two execution modes share one planner:

  compiled (default) — plan → plan-cache lookup → ONE device dispatch. The
      whole operator tree (joins, OPTIONAL left joins, FILTER masks,
      projection, DISTINCT, LIMIT/OFFSET) is lowered by core/executor.py
      into a single AOT-compiled program, cached by (plan shape, bucket
      signature) in a PlanCache. FILTER constants and LIMIT/OFFSET are
      runtime inputs, so query variants share the executable. A cache miss
      first runs the eager evaluator once: its Mars count passes double as
      the capacity *calibration* that picks the pow-2 join buckets the
      program is compiled at. Warm queries then run with zero compiles and
      no per-join host sync (the only sync reads the overflow flags that
      ride back with the results). If a bucket overflows (a same-shape
      query with a bigger result), the engine grows the bucket from the
      exact totals returned by the dispatch and recompiles — the
      double-on-overflow retry demoted to a host-level fallback.

  eager (compiled=False) — the per-operator loop, kept for differential
      testing: per join, a jitted COUNT pass, host sync of the cardinality,
      exactly-sized (next-pow2) buffer, jitted EXPAND pass; or
      double-on-overflow when exact_count_pass=False.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import executor as ex
from repro.core import mr_join as mj
from repro.core import plan_ir
from repro.core.planner import TriplePattern, plan_bgp
from repro.core.relation import UNBOUND, Relation
from repro.sparql import algebra
from repro.sparql.parser import Query, parse
from repro.sparql.store import TripleStore, _next_pow2

# LIMIT stand-in when only OFFSET was given (far above max_capacity, safe
# from int32 overflow in `offset + limit`).
_NO_LIMIT = 1 << 30


@dataclasses.dataclass
class ExecStats:
    n_joins: int = 0
    n_count_passes: int = 0
    n_retries: int = 0
    peak_capacity: int = 0
    # compiled-pipeline accounting
    cache_hits: int = 0
    cache_misses: int = 0
    n_compiles: int = 0  # XLA compilations triggered by this query
    n_dispatches: int = 0  # device program launches (warm target: 1)

    def add(self, other: "ExecStats") -> None:
        self.n_joins += other.n_joins
        self.n_count_passes += other.n_count_passes
        self.n_retries += other.n_retries
        self.peak_capacity = max(self.peak_capacity, other.peak_capacity)
        self.cache_hits += other.cache_hits
        self.cache_misses += other.cache_misses
        self.n_compiles += other.n_compiles
        self.n_dispatches += other.n_dispatches


@dataclasses.dataclass
class PlanCacheEntry:
    shape: plan_ir.PlanShape
    join_caps: tuple[int, ...]
    compiled: ex.CompiledPlan


class PlanCache:
    """(plan shape, bucket signature) -> compiled executable, FIFO-bounded."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: OrderedDict[plan_ir.PlanShape, PlanCacheEntry] = (
            OrderedDict()
        )
        self.hits = 0
        self.misses = 0
        self.compiles = 0

    def get(self, shape: plan_ir.PlanShape) -> PlanCacheEntry | None:
        return self._entries.get(shape)

    def put(self, shape: plan_ir.PlanShape, entry: PlanCacheEntry) -> None:
        self._entries[shape] = entry
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "compiles": self.compiles,
            "entries": len(self._entries),
            "hit_rate": self.hit_rate,
        }


@dataclasses.dataclass
class _Program:
    """A planned query: scan order, join structure, runtime constants.

    This is the engine-internal bridge from the logical algebra to a
    PlanShape; a PreparedQuery owns one and reuses it across runs.
    """

    query: Query
    patterns: list[TriplePattern]  # scan order: required chain, then groups
    cross_flags: tuple[bool, ...]  # required chain
    opt_groups: tuple[plan_ir.GroupSpec, ...]
    conds: tuple[plan_ir.FilterCond, ...]  # original var names
    consts_i: np.ndarray  # int32: filter term ids (+ offset, limit)
    consts_f: np.ndarray  # float32: numeric filter constants
    projection: tuple[str, ...]
    distinct: bool
    has_slice: bool


class ResultSet:
    """Typed, decoded query result: rows as {var: term} dicts (variables an
    OPTIONAL group left unbound are omitted), plus the producing run's
    ExecStats. Compares equal to a plain list of row dicts for convenience.
    """

    def __init__(self, vars: tuple[str, ...], rows: list[dict[str, str]],
                 stats: ExecStats):
        self.vars = tuple(vars)
        self.rows = rows
        self.stats = stats

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    def __getitem__(self, i):
        return self.rows[i]

    def __eq__(self, other) -> bool:
        if isinstance(other, ResultSet):
            return self.rows == other.rows
        if isinstance(other, list):
            return self.rows == other
        return NotImplemented

    def __repr__(self) -> str:
        return f"ResultSet(vars={self.vars}, n_rows={len(self.rows)})"


class PreparedQuery:
    """A parsed, validated and planned query, reusable across runs.

    Holds per-handle accounting: `stats` accumulates ExecStats over every
    run (peak_capacity as a running max), `last_stats` is the most recent
    run's. The compiled executable itself lives in the engine's PlanCache,
    shared by every handle (and every client) with the same plan shape.
    """

    def __init__(self, engine: "QueryEngine", text: str, query: Query):
        self.engine = engine
        self.text = text
        self.query = query
        self._program = engine._build_program(query)
        self.stats = ExecStats()  # accumulated across runs
        self.last_stats: ExecStats | None = None
        self.n_runs = 0

    def run(self) -> ResultSet:
        stats = ExecStats()
        rel = self.engine._execute_program(self._program, stats)
        rows = self.engine._decode_rows(rel)
        self.stats.add(stats)
        self.last_stats = stats
        self.n_runs += 1
        return ResultSet(self._program.projection, rows, stats)

    def explain(self) -> str:
        return self.engine._explain_program(self, self._program)


@dataclasses.dataclass
class QueryEngine:
    store: TripleStore
    use_kernel: bool = False  # Pallas pair-expand in the join
    exact_count_pass: bool = True  # Mars two-pass vs double-on-overflow
    max_capacity: int = 1 << 24
    compiled: bool = True  # one-dispatch compiled pipeline vs eager loop
    plan_cache_entries: int = 256

    def __post_init__(self):
        self._jit_join = jax.jit(
            mj.mr_join, static_argnames=("capacity", "use_kernel")
        )
        self._jit_left_join = jax.jit(
            mj.left_join, static_argnames=("capacity", "use_kernel")
        )
        self._jit_count = jax.jit(mj.mr_join_count)
        self._jit_cross = jax.jit(mj.cross_join, static_argnames=("capacity",))
        self.plan_cache = PlanCache(self.plan_cache_entries)

    # -- public API --------------------------------------------------------
    def prepare(self, text: str) -> PreparedQuery:
        """Parse, validate and plan once; run (and re-run) later."""
        return PreparedQuery(self, text, parse(text))

    def query(self, text: str) -> list[dict[str, str]]:
        """One-shot convenience: rows as {var: term} dicts."""
        return self.prepare(text).run().rows

    def execute(self, q: Query) -> tuple[Relation, ExecStats]:
        """Run a parsed query; the result Relation carries the projected
        (and DISTINCT-deduplicated, filtered, sliced) bindings."""
        stats = ExecStats()
        rel = self._execute_program(self._build_program(q), stats)
        return rel, stats

    def explain(self, text: str) -> str:
        return self.prepare(text).explain()

    def cache_stats(self) -> dict:
        return self.plan_cache.stats()

    # -- planning ----------------------------------------------------------
    def _build_program(self, q: Query) -> _Program:
        est = self.store.estimate_cardinality
        steps = plan_bgp(q.patterns, est)
        patterns = [q.patterns[st.pattern_index] for st in steps]
        cross_flags = tuple(st.is_cross for st in steps[1:])
        required_bound = {v for tp in patterns for v in tp.variables()}
        opt_bound: set[str] = set()  # vars that may end up UNBOUND
        opt_groups: list[plan_ir.GroupSpec] = []
        for group in q.optionals:
            gsteps = plan_bgp(list(group), est)
            gpats = [group[st.pattern_index] for st in gsteps]
            gvars = {v for tp in gpats for v in tp.variables()}
            # SPARQL's LeftJoin treats an unbound variable as compatible
            # with anything; the device join treats UNBOUND as an ordinary
            # (never-matching) key. Sound only when groups join exclusively
            # through always-bound (required) variables — reject the rest.
            overlap = gvars & opt_bound
            if overlap:
                raise ValueError(
                    "unsupported: OPTIONAL group reuses variable(s) bound "
                    f"by an earlier OPTIONAL group: {sorted(overlap)} "
                    "(unbound-compatible chained-OPTIONAL semantics are "
                    "not implemented)"
                )
            if not (gvars & required_bound):
                raise ValueError(
                    "OPTIONAL group shares no variable with the required "
                    f"patterns: {sorted(gvars)}"
                )
            patterns += gpats
            opt_groups.append(
                plan_ir.GroupSpec(
                    len(gpats), tuple(st.is_cross for st in gsteps[1:])
                )
            )
            opt_bound |= gvars - required_bound
        conds: list[plan_ir.FilterCond] = []
        id_consts: list[int] = []
        f_consts: list[float] = []
        for c in q.filters:
            if isinstance(c.rhs, algebra.Var):
                conds.append((c.lhs, c.op, "var", c.rhs.name))
            elif isinstance(c.rhs, algebra.NumLit):
                conds.append((c.lhs, c.op, "num", len(f_consts)))
                f_consts.append(c.rhs.value)
            else:  # TermLit: identity comparison; unknown terms can never
                # match a bound variable, -1 encodes that correctly
                tid = self.store.dictionary.lookup(c.rhs.lexical)
                conds.append((c.lhs, c.op, "id", len(id_consts)))
                id_consts.append(-1 if tid is None else tid)
        has_slice = q.has_slice()
        if has_slice:
            limit = q.limit if q.limit is not None else _NO_LIMIT
            id_consts += [min(q.offset, _NO_LIMIT), min(limit, _NO_LIMIT)]
        return _Program(
            q,
            patterns,
            cross_flags,
            tuple(opt_groups),
            tuple(conds),
            np.asarray(id_consts, np.int32),
            np.asarray(f_consts, np.float32),
            tuple(q.projection()),
            q.distinct,
            has_slice,
        )

    def _shape_for(
        self,
        prog: _Program,
        schemas: tuple[tuple[str, ...], ...],
        caps: tuple[int, ...],
        rename: dict[str, str] | None = None,
    ) -> plan_ir.PlanShape:
        r = rename or {}

        def rn(v: str) -> str:
            return r.get(v, v)

        conds = tuple(
            (rn(lhs), op, kind, rn(ref) if kind == "var" else ref)
            for lhs, op, kind, ref in prog.conds
        )
        return plan_ir.make_shape(
            tuple(tuple(rn(v) for v in s) for s in schemas),
            caps,
            prog.cross_flags,
            tuple(rn(v) for v in prog.projection),
            prog.distinct,
            opt_groups=prog.opt_groups,
            filters=conds,
            has_slice=prog.has_slice,
        )

    # -- execution ---------------------------------------------------------
    def _execute_program(self, prog: _Program, stats: ExecStats) -> Relation:
        if self.compiled:
            return self._execute_compiled(prog, stats)
        scans = tuple(self.store.match_pattern(tp) for tp in prog.patterns)
        shape = self._shape_for(
            prog,
            tuple(s.schema for s in scans),
            tuple(s.capacity for s in scans),
        )
        rel, _ = self._eval_shape_eager(shape, scans, prog, stats)
        return rel

    def _decode_rows(self, rel: Relation) -> list[dict[str, str]]:
        d = self.store.dictionary
        return [
            {
                v: d.decode(int(t))
                for v, t in zip(rel.schema, row)
                if int(t) != UNBOUND
            }
            for row in rel.to_numpy()
        ]

    # -- eager evaluator ---------------------------------------------------
    def _eval_shape_eager(
        self,
        shape: plan_ir.PlanShape,
        scans: tuple[Relation, ...],
        prog: _Program,
        stats: ExecStats,
    ) -> tuple[Relation, list[int]]:
        """Operator-at-a-time evaluation with exact (count-pass) bucket
        sizing. Returns the result and each join's exact total in the same
        order the compiled program reports them — the totals are what the
        compiled path calibrates its buckets on."""
        totals: list[int] = []
        scan_iter = iter(scans)

        def chain(n_scans: int, cross_flags: tuple[bool, ...]) -> Relation:
            acc = next(scan_iter)
            for is_cross in cross_flags:
                acc, total = self._join_once(
                    acc, next(scan_iter), is_cross, stats
                )
                totals.append(total)
            return acc

        acc = chain(shape.n_required, shape.cross_flags)
        for g in shape.opt_groups:
            grp = chain(g.n_scans, g.cross_flags)
            stats.n_joins += 1
            stats.n_dispatches += 1
            total = int(self._jit_count(acc, grp))
            stats.n_count_passes += 1
            cap = max(1, _next_pow2(total))
            stats.n_dispatches += 1
            out, _, overflow = self._jit_left_join(
                acc, grp, capacity=cap, use_kernel=self.use_kernel
            )
            assert not bool(overflow)
            stats.peak_capacity = max(
                stats.peak_capacity, cap + acc.capacity
            )
            totals.append(total)
            acc = out
        if shape.filters:
            keep = mj.filter_mask(
                acc,
                shape.filters,
                jnp.asarray(prog.consts_i),
                jnp.asarray(prog.consts_f),
                self.store.numeric_values_device(),
            )
            acc = Relation(acc.schema, acc.cols, keep)
        acc = acc.project(list(shape.projection))
        if shape.distinct:
            acc = mj.distinct(acc)  # device-side dedup before decode
        if shape.has_slice:
            oi, li = shape.slice_const_indices()
            acc = mj.slice_valid(
                acc, int(prog.consts_i[oi]), int(prog.consts_i[li])
            )
        return acc, totals

    def _join_once(
        self, left: Relation, right: Relation, is_cross: bool, stats: ExecStats
    ) -> tuple[Relation, int]:
        stats.n_joins += 1
        if is_cross:
            cap = max(1, _next_pow2(left.capacity * right.capacity))
            stats.n_dispatches += 1
            out, total, overflow = self._jit_cross(left, right, capacity=cap)
            assert not bool(overflow)
            stats.peak_capacity = max(stats.peak_capacity, cap)
            return mj.compact(out), int(total)
        if self.exact_count_pass:
            stats.n_dispatches += 1
            total = int(self._jit_count(left, right))
            stats.n_count_passes += 1
            cap = max(1, _next_pow2(total))
            stats.n_dispatches += 1
            out, _, overflow = self._jit_join(
                left, right, capacity=cap, use_kernel=self.use_kernel
            )
            assert not bool(overflow)
            stats.peak_capacity = max(stats.peak_capacity, cap)
            return out, total
        cap = max(left.capacity, right.capacity)
        while True:
            stats.n_dispatches += 1
            out, total, overflow = self._jit_join(
                left, right, capacity=cap, use_kernel=self.use_kernel
            )
            stats.peak_capacity = max(stats.peak_capacity, cap)
            if not bool(overflow):
                return out, int(total)
            stats.n_retries += 1
            cap *= 2
            if cap > self.max_capacity:
                raise MemoryError(f"join result exceeds {self.max_capacity}")

    # -- compiled path -----------------------------------------------------
    def _execute_compiled(self, prog: _Program, stats: ExecStats) -> Relation:
        # upload-once device scans (bucketed pow-2 capacities)
        scans = tuple(
            self.store.match_pattern_device(tp) for tp in prog.patterns
        )
        # canonicalise variable names so structurally-equal queries share
        # one compiled program (constants live in the scan data and the
        # runtime-constant inputs, not here)
        schemas = tuple(s.schema for s in scans)
        rename = plan_ir.canonical_renaming(schemas)
        inverse = {c: o for o, c in rename.items()}
        canon_scans = tuple(
            Relation(tuple(rename[v] for v in s.schema), s.cols, s.valid)
            for s in scans
        )
        shape = self._shape_for(
            prog, schemas, tuple(s.capacity for s in scans), rename
        )
        stats.n_joins = shape.n_joins()
        consts_i = jnp.asarray(prog.consts_i)
        consts_f = jnp.asarray(prog.consts_f)
        num_vals = self.store.numeric_values_device()

        entry = self.plan_cache.get(shape)
        if entry is None:
            rel = self._compiled_cold(shape, canon_scans, prog, stats)
        else:
            rel = self._compiled_warm(
                shape, entry, canon_scans, consts_i, consts_f, num_vals, stats
            )
        # back to the query's own variable names
        return Relation(
            tuple(inverse[v] for v in rel.schema), rel.cols, rel.valid
        )

    def _compiled_cold(
        self,
        shape: plan_ir.PlanShape,
        canon_scans: tuple[Relation, ...],
        prog: _Program,
        stats: ExecStats,
    ) -> Relation:
        """Cache miss: the eager evaluator's count passes calibrate the join
        buckets; compile at those shapes; serve this query from the eager
        result (the compiled program takes over from the next query on)."""
        stats.cache_misses += 1
        self.plan_cache.misses += 1
        eager_stats = ExecStats()
        rel, totals = self._eval_shape_eager(
            shape, canon_scans, prog, eager_stats
        )
        stats.n_count_passes += eager_stats.n_count_passes
        stats.n_dispatches += eager_stats.n_dispatches
        stats.n_retries += eager_stats.n_retries
        stats.peak_capacity = max(
            stats.peak_capacity, eager_stats.peak_capacity
        )
        join_caps = tuple(plan_ir.bucket_capacity(t) for t in totals)
        self._compile_entry(shape, join_caps, canon_scans, prog, stats)
        return rel

    def _compiled_warm(
        self,
        shape: plan_ir.PlanShape,
        entry: PlanCacheEntry,
        canon_scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
        stats: ExecStats,
    ) -> Relation:
        stats.cache_hits += 1
        self.plan_cache.hits += 1
        while True:
            stats.n_dispatches += 1
            rel, totals, flags = entry.compiled(
                canon_scans, consts_i, consts_f, num_vals
            )
            stats.peak_capacity = max(
                stats.peak_capacity, entry.compiled.plan.max_capacity()
            )
            flags_np = np.asarray(flags)  # the single host sync
            if not flags_np.any():
                return rel
            # bucket overflow: grow from the exact totals, recompile, retry
            stats.n_retries += 1
            new_caps = plan_ir.grow_join_caps(
                entry.join_caps,
                [int(t) for t in np.asarray(totals)],
                [bool(f) for f in flags_np],
            )
            if max(new_caps) > self.max_capacity:
                raise MemoryError(
                    f"join result exceeds {self.max_capacity}"
                )
            entry = self._compile_entry(
                shape, new_caps, canon_scans, None, stats
            )

    def _compile_entry(
        self,
        shape: plan_ir.PlanShape,
        join_caps: tuple[int, ...],
        canon_scans: tuple[Relation, ...],
        prog: _Program | None,
        stats: ExecStats,
    ) -> PlanCacheEntry:
        plan = plan_ir.build_plan(shape, join_caps)
        # the consts are signature templates here — only shapes/dtypes
        # matter to AOT lowering, and they are determined by the PlanShape
        n_i = shape.n_id_consts() + (2 if shape.has_slice else 0)
        n_f = sum(1 for c in shape.filters if c[2] == "num")
        consts_i = jnp.asarray(
            prog.consts_i if prog is not None else np.zeros(n_i, np.int32)
        )
        consts_f = jnp.asarray(
            prog.consts_f if prog is not None else np.zeros(n_f, np.float32)
        )
        compiled = ex.compile_plan(
            plan,
            canon_scans,
            consts_i,
            consts_f,
            self.store.numeric_values_device(),
            use_kernel=self.use_kernel,
        )
        stats.n_compiles += 1
        self.plan_cache.compiles += 1
        entry = PlanCacheEntry(shape, join_caps, compiled)
        self.plan_cache.put(shape, entry)
        return entry

    # -- explain -----------------------------------------------------------
    def _explain_program(self, pq: PreparedQuery, prog: _Program) -> str:
        """Human-readable plan report: the logical algebra, the physical
        scan/join structure with estimated rows and pow-2 buckets, and the
        plan-cache state for this shape — all host-side (no device work)."""
        est = self.store.estimate_cardinality
        lines = ["PreparedQuery", "logical algebra:"]
        lines.append(algebra.format_algebra(pq.query.algebra(), 1))
        lines.append("physical plan (scan order -> join chain):")
        schemas: list[tuple[str, ...]] = []
        caps: list[int] = []
        for i, tp in enumerate(prog.patterns):
            schema, n_rows = self.store.pattern_scan_info(tp)
            schemas.append(schema)
            caps.append(plan_ir.bucket_capacity(n_rows))
            kind = (
                "required" if i < len(prog.cross_flags) + 1 else "optional"
            )
            lines.append(
                f"  scan[{i}] ({tp.s} {tp.p} {tp.o}) "
                f"est_rows={est(tp)} bucket={caps[-1]} [{kind}]"
            )
        rename = plan_ir.canonical_renaming(tuple(schemas))
        shape = self._shape_for(prog, tuple(schemas), tuple(caps), rename)
        for i, is_cross in enumerate(shape.cross_flags):
            lines.append(
                f"  join[{i}] {'cross_join' if is_cross else 'mr_join'}"
            )
        for gi, g in enumerate(shape.opt_groups):
            lines.append(
                f"  left_join[{gi}] OPTIONAL group of {g.n_scans} "
                f"pattern(s), unmatched rows padded UNBOUND"
            )
        if shape.filters:
            conds = " && ".join(str(c) for c in pq.query.filters)
            lines.append(f"  filter: {conds} (device-side mask)")
        if shape.has_slice:
            q = pq.query
            limit = "-" if q.limit is None else q.limit
            lines.append(f"  slice: offset={q.offset} limit={limit}")
        entry = self.plan_cache.get(shape)
        if entry is None:
            lines.append(
                "cache: shape not compiled yet (first run calibrates "
                "buckets from exact counts, then compiles)"
            )
        else:
            lines.append(
                f"cache: compiled, join buckets={entry.join_caps}, "
                f"max_capacity={entry.compiled.plan.max_capacity()}"
            )
        lines.append(
            f"plan-cache: {len(self.plan_cache)} entries, "
            f"hit_rate={self.plan_cache.hit_rate:.0%}"
        )
        lines.append(
            f"handle: {pq.n_runs} run(s)"
            + (
                f", last run: {pq.last_stats.n_dispatches} dispatch(es), "
                f"{pq.last_stats.n_compiles} compile(s)"
                if pq.last_stats
                else ""
            )
        )
        return "\n".join(lines)
