"""The MapSQ query engine (Figure 1 of the paper) and its prepared-query API.

Coprocessing split, exactly as the paper describes it:
  CPU  — parse, dictionary-encode, optimize (sparql/optimizer.py:
         statistics-driven join order, filter pushdown, projection
         pruning), size capacities, dispatch subqueries (this file,
         host Python);
  GPU→TPU — pattern range-scans feed the MapReduce join (Algorithm 1,
         core/mr_join.py, jitted).

The public API is layered around prepared queries:

  engine.prepare(text) -> PreparedQuery   parse + validate + plan once
  pq.run()             -> ResultSet       typed rows + the run's ExecStats
  pq.explain()         -> str             algebra tree, physical plan,
                                          bucket capacities, cache state
  engine.query(text)   -> list[dict]      thin wrapper: prepare().run().rows
  engine.run_batch(ps) -> list[ResultSet] micro-batch execution: same-shape
                                          queries coalesce into stacked
                                          (vmapped) device dispatches —
                                          N warm same-shape queries cost
                                          ceil(N / width) launches
  engine.update(text)  -> UpdateResult    INSERT DATA / DELETE DATA against
                                          the store's delta blocks; warm
                                          plan shapes survive the write
  engine.stats()       -> dict            plan cache + scan cache + the
                                          store's write-path health

Two execution modes share one planner:

  compiled (default) — plan → plan-cache lookup → ONE device dispatch. The
      whole operator tree (joins, OPTIONAL left joins, FILTER masks,
      projection, DISTINCT, LIMIT/OFFSET) is lowered by core/executor.py
      into a single AOT-compiled program, cached by (plan shape, bucket
      signature) in a PlanCache. FILTER constants and LIMIT/OFFSET are
      runtime inputs, so query variants share the executable. A cache miss
      first runs the eager evaluator once: its Mars count passes double as
      the capacity *calibration* that picks the pow-2 join buckets the
      program is compiled at. Warm queries then run with zero compiles and
      no per-join host sync (the only sync reads the overflow flags that
      ride back with the results). If a bucket overflows (a same-shape
      query with a bigger result), the engine grows the bucket from the
      exact totals returned by the dispatch and recompiles — the
      double-on-overflow retry demoted to a host-level fallback.

  eager (compiled=False) — the per-operator loop, kept for differential
      testing: per join, a jitted COUNT pass, host sync of the cardinality,
      exactly-sized (next-pow2) buffer, jitted EXPAND pass; or
      double-on-overflow when exact_count_pass=False.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import threading
import time
from collections import OrderedDict

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import executor as ex
from repro.core import mr_join as mj
from repro.core import plan_ir
from repro.core.planner import TriplePattern
from repro.core.relation import UNBOUND, Relation
from repro.obs import MetricsRegistry, Tracer
from repro.sparql import algebra, optimizer
from repro.sparql.parser import Query, UpdateRequest, parse, parse_update
from repro.sparql.store import TripleStore, _next_pow2

# LIMIT stand-in when only OFFSET was given (far above max_capacity, safe
# from int32 overflow in `offset + limit`).
_NO_LIMIT = 1 << 30


@dataclasses.dataclass
class ExecStats:
    n_joins: int = 0
    n_count_passes: int = 0
    n_retries: int = 0
    peak_capacity: int = 0
    peak_join_bucket: int = 0  # largest intermediate join bucket this run
    # compiled-pipeline accounting
    cache_hits: int = 0
    cache_misses: int = 0
    n_compiles: int = 0  # XLA compilations triggered by this query
    n_dispatches: int = 0  # device program launches (warm target: 1)
    # stacked-batch accounting: width of the vmapped dispatch that served
    # this run (0 = solo). Batchmates share one dispatch, so their
    # n_dispatches/n_compiles report the chunk's shared counts.
    batch_width: int = 0
    # the store version this run's scans were staged at (-1 = not set):
    # the snapshot the results are consistent with
    store_version: int = -1
    # sharded-execution data movement (zero on the single-device engine):
    # shuffle collectives the lowering emitted vs elided because the input
    # was already hash-partitioned on the join key, and small-side
    # broadcast (all_gather) joins
    n_shuffles_emitted: int = 0
    n_shuffles_elided: int = 0
    n_broadcast_joins: int = 0
    # host wall seconds spent inside device dispatch + result sync for
    # THIS run (the engine-level `device_time_s` is the sum of these)
    device_time_s: float = 0.0
    # rows this run's decode emitted (-1 = not yet decoded)
    rows_emitted: int = -1
    # EXPLAIN ANALYZE actuals, in join-slot (evaluation) order — the same
    # order as plan.join_ests/join_caps. Captured from the exact totals
    # that ride back with every dispatch:
    #   join_totals    global matched rows per join slot
    #   join_worst     worst single shard/lane per slot (fill pressure)
    #   join_overflows overflow->regrow events per slot (summed)
    #   join_caps      bucket capacity the final (successful) run used
    #   shuffle_loads  worst per-shard shuffle rows per shuffle slot
    join_totals: tuple[int, ...] = ()
    join_worst: tuple[int, ...] = ()
    join_overflows: tuple[int, ...] = ()
    join_caps: tuple[int, ...] = ()
    shuffle_loads: tuple[int, ...] = ()

    def add(self, other: "ExecStats") -> None:
        self.n_joins += other.n_joins
        self.n_count_passes += other.n_count_passes
        self.n_retries += other.n_retries
        self.peak_capacity = max(self.peak_capacity, other.peak_capacity)
        self.peak_join_bucket = max(
            self.peak_join_bucket, other.peak_join_bucket
        )
        self.cache_hits += other.cache_hits
        self.cache_misses += other.cache_misses
        self.n_compiles += other.n_compiles
        self.n_dispatches += other.n_dispatches
        self.batch_width = max(self.batch_width, other.batch_width)
        self.store_version = max(self.store_version, other.store_version)
        self.n_shuffles_emitted += other.n_shuffles_emitted
        self.n_shuffles_elided += other.n_shuffles_elided
        self.n_broadcast_joins += other.n_broadcast_joins
        self.device_time_s += other.device_time_s
        if other.rows_emitted >= 0:
            self.rows_emitted = other.rows_emitted
        # actuals: last run wins (pq.stats accumulates across runs but
        # the analyze view reports the most recent execution); overflow
        # events accumulate
        if other.join_totals:
            self.join_totals = other.join_totals
            self.join_worst = other.join_worst
            self.join_caps = other.join_caps
            self.shuffle_loads = other.shuffle_loads
        if other.join_overflows:
            mine = self.join_overflows
            if len(mine) == len(other.join_overflows):
                self.join_overflows = tuple(
                    a + b for a, b in zip(mine, other.join_overflows)
                )
            else:
                self.join_overflows = other.join_overflows


@dataclasses.dataclass
class PlanCacheEntry:
    shape: plan_ir.PlanShape
    join_caps: tuple[int, ...]
    compiled: ex.CompiledPlan
    # (width, per-scan stacked/broadcast axes) -> stacked executable at
    # THESE join caps (compiled on demand by run_batch; reset when an
    # overflow regrow replaces the entry)
    batched: dict[tuple, ex.CompiledBatch] = dataclasses.field(
        default_factory=dict
    )
    # (width, axes) layouts persisted by a previous process (save_cache
    # round-trips them even before this process serves a stacked batch);
    # pre-layout files carried widths only — those load as all-stacked
    warm_layouts: tuple[tuple, ...] = ()
    # numeric-value table length the executable was lowered against
    # (0 = unchecked). Inserts that grow the dictionary past a pow-2
    # boundary change that shape; the engine recompiles the entry at the
    # same join caps when it notices the mismatch.
    num_cap: int = 0

    def widths(self) -> tuple[int, ...]:
        """Known stacked widths for this signature: compiled this process
        (at any scan layout) plus persisted from the warmup file."""
        return tuple(
            sorted(
                {k[0] for k in self.batched}
                | {w for w, _ in self.warm_layouts}
            )
        )

    def layouts(self) -> tuple[tuple, ...]:
        """Known (width, scan_axes) stacked layouts for this signature."""
        return tuple(
            sorted(
                set(self.batched) | set(self.warm_layouts),
                key=lambda k: (k[0], str(k[1])),
            )
        )


class PlanCache:
    """(plan shape, bucket signature) -> compiled executable, FIFO-bounded."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: OrderedDict[plan_ir.PlanShape, PlanCacheEntry] = (
            OrderedDict()
        )
        self.hits = 0
        self.misses = 0
        self.compiles = 0

    def get(self, shape: plan_ir.PlanShape) -> PlanCacheEntry | None:
        return self._entries.get(shape)

    def put(self, shape: plan_ir.PlanShape, entry: PlanCacheEntry) -> None:
        self._entries[shape] = entry
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)

    def __len__(self) -> int:
        return len(self._entries)

    def entries(self) -> list[PlanCacheEntry]:
        return list(self._entries.values())

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "compiles": self.compiles,
            "entries": len(self._entries),
            "hit_rate": self.hit_rate,
        }


@dataclasses.dataclass
class BatchGroupStats:
    """run_batch accounting for one plan group (shared PlanShape).

    `n_dispatches` counts every device launch the group made — stacked
    chunks, overflow retries, and the sequential calibration run of a cold
    group — so ceil(N/width) is directly assertable. `widths` lists the
    bucketed lane width of each stacked chunk, in dispatch order."""

    n_queries: int
    widths: tuple[int, ...] = ()
    n_dispatches: int = 0
    n_compiles: int = 0
    cold: bool = False  # group paid calibration/compilation this batch
    fallback: bool = False  # stacked dispatch failed; ran sequentially
    # scan positions shipped ONCE (vmap in_axes=None) because every lane's
    # pattern was identical — the same-query-different-FILTER win: those
    # buffers skip the W-copy stacking entirely
    n_broadcast_scans: int = 0
    # cross-shape padding: this group coalesced `n_shapes` near-miss
    # PlanShapes (same plan DAG, smaller pow-2 scan caps) into one stacked
    # signature by padding every lane's scans up to the group's max caps
    padded: bool = False
    n_shapes: int = 1


@dataclasses.dataclass
class _Program:
    """A planned query: scan order, join structure, runtime constants.

    This is the engine-internal bridge from the optimizer's output to a
    PlanShape; a PreparedQuery owns one and reuses it across runs.
    """

    query: Query
    plan: optimizer.OptimizedProgram  # optimizer output incl. trace/ests
    patterns: list[TriplePattern]  # scan order: required, groups, branches
    cross_flags: tuple[bool, ...]  # required chain
    opt_groups: tuple[plan_ir.GroupSpec, ...]
    union_groups: tuple[plan_ir.GroupSpec, ...]
    has_required: bool
    filters: tuple[plan_ir.FilterSpec, ...]  # staged, original var names
    n_consts: tuple[int, int]  # (int, float) filter consts (sans slice)
    consts_i: np.ndarray  # int32: filter term ids (+ offset, limit)
    consts_f: np.ndarray  # float32: numeric filter constants
    projection: tuple[str, ...]
    distinct: bool
    has_slice: bool


@dataclasses.dataclass
class _BatchCtx:
    """Per-query HOST staging for run_batch: the program, its plan-cache
    key and the canonical->original name mapping. Deliberately holds no
    device arrays — scans are re-fetched from the store's bounded caches
    per batch, so a cached PreparedQuery handle never pins device buffers
    past the scan cache's eviction policy. `store_version` records the
    version the shape was computed at: a write can move a pattern into a
    bigger capacity bucket, so a stale ctx is recomputed before grouping."""

    prog: _Program
    shape: plan_ir.PlanShape
    inverse: dict[str, str]
    store_version: int = -1


class ResultSet:
    """Typed, decoded query result: rows as {var: term} dicts (variables an
    OPTIONAL group left unbound are omitted), plus the producing run's
    ExecStats. Compares equal to a plain list of row dicts for convenience.
    """

    def __init__(self, vars: tuple[str, ...], rows: list[dict[str, str]],
                 stats: ExecStats):
        self.vars = tuple(vars)
        self.rows = rows
        self.stats = stats

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    def __getitem__(self, i):
        return self.rows[i]

    def __eq__(self, other) -> bool:
        if isinstance(other, ResultSet):
            return self.rows == other.rows
        if isinstance(other, list):
            return self.rows == other
        return NotImplemented

    def __repr__(self) -> str:
        return f"ResultSet(vars={self.vars}, n_rows={len(self.rows)})"


class _SharedFetch:
    """One device→host transfer shared by every lane of a stacked chunk.

    The transfer is LAZY: the batcher thread hands lanes to the decode
    pool holding only device references; whichever decode worker resolves
    its lane first pays the (single) `np.asarray` sync, and the device
    buffers are dropped immediately after so a slow decode queue never
    pins a chunk's device memory longer than one transfer."""

    __slots__ = ("_lock", "_rel", "cols", "valid", "transfer_s")

    def __init__(self, rel: Relation):
        self._lock = threading.Lock()
        self._rel: Relation | None = rel
        self.cols: np.ndarray | None = None
        self.valid: np.ndarray | None = None
        self.transfer_s = 0.0

    def fetch(self) -> tuple[np.ndarray, np.ndarray, bool]:
        """Returns (cols, valid, paid): `paid` is True for the one caller
        that performed the device->host sync, False for sharers."""
        with self._lock:
            if self._rel is not None:
                t0 = time.perf_counter()
                self.cols = np.asarray(self._rel.cols)
                self.valid = np.asarray(self._rel.valid)
                self.transfer_s = time.perf_counter() - t0
                self._rel = None
                return self.cols, self.valid, True
        return self.cols, self.valid, False


class PendingDecode:
    """A dispatched query's undecoded result: result buffers (device-side
    until the first consumer fetches) plus the lane metadata needed to
    materialise rows.

    This is the unit the serving pipeline passes from the dispatch stage
    to the decode stage — `run_batch_pipelined` returns one per slot, and
    `resolve()` (the transfer + row decode + per-handle accounting) runs
    on a decode worker, overlapping the batcher thread's next dispatch.
    `lane` selects this query's slice of a stacked chunk (None for a solo
    run whose buffers are already 2-D)."""

    __slots__ = ("engine", "pq", "vars", "names", "fetch", "lane", "stats",
                 "trace")

    def __init__(self, engine: "QueryEngine", pq: "PreparedQuery",
                 vars: tuple[str, ...], names: tuple[str, ...],
                 fetch: _SharedFetch, lane: "int | None", stats: ExecStats,
                 trace=None):
        self.engine = engine
        self.pq = pq
        self.vars = vars
        self.names = names
        self.fetch = fetch
        self.lane = lane
        self.stats = stats
        self.trace = trace

    def resolve(self) -> ResultSet:
        t0 = time.perf_counter()
        cols, valid, paid = self.fetch.fetch()
        t1 = time.perf_counter()
        if self.lane is not None:
            cols, valid = cols[self.lane], valid[self.lane]
        rows = self.engine._decode_numpy(self.names, cols[valid])
        t2 = time.perf_counter()
        if self.trace is not None:
            # the sharing lanes' "transfer" span is their wait on the
            # paying lane's sync (usually ~0): attrs distinguish them
            self.trace.add_span("transfer", t0, t1, paid=paid,
                                transfer_s=round(self.fetch.transfer_s, 6))
            self.trace.add_span("decode", t1, t2, rows=len(rows))
        self.stats.rows_emitted = len(rows)
        pq = self.pq
        pq.stats.add(self.stats)
        pq.last_stats = self.stats
        pq.n_runs += 1
        return ResultSet(self.vars, rows, self.stats)


class PreparedQuery:
    """A parsed, validated and planned query, reusable across runs.

    Holds per-handle accounting: `stats` accumulates ExecStats over every
    run (peak_capacity as a running max), `last_stats` is the most recent
    run's. The compiled executable itself lives in the engine's PlanCache,
    shared by every handle (and every client) with the same plan shape.
    """

    def __init__(self, engine: "QueryEngine", text: str, query: Query):
        self.engine = engine
        self.text = text
        self.query = query
        self._program = engine._build_program(query)
        self._batch_ctx: _BatchCtx | None = None  # run_batch staging cache
        self.stats = ExecStats()  # accumulated across runs
        self.last_stats: ExecStats | None = None
        self.n_runs = 0
        # the store version this handle was planned against. Runs stay
        # CORRECT regardless (scans re-stage at the current version each
        # run, under the store's snapshot lock); the pin records which
        # statistics the optimizer's choices reflect — see refresh().
        self.planned_version = engine.store.version

    def refresh(self) -> bool:
        """Re-plan against the store's current statistics if data changed
        since this handle was planned (or last refreshed).

        Optional: run() results are always computed on the live snapshot;
        refresh only updates the optimizer's join-order/backend choices
        (and this handle's pinned version). Returns True if re-planned."""
        if self.planned_version == self.engine.store.version:
            return False
        self._program = self.engine._build_program(self.query)
        self._batch_ctx = None
        self.planned_version = self.engine.store.version
        return True

    def run(self, trace=None) -> ResultSet:
        return self._run_pending(trace).resolve()

    def _run_pending(self, trace=None) -> PendingDecode:
        """Dispatch the query, returning its result as a PendingDecode:
        device work is enqueued, host decode is not yet paid. run() is
        `_run_pending().resolve()`; the pipelined server resolves on a
        decode worker instead."""
        stats = ExecStats()
        rel = self.engine._execute_program(self._program, stats, trace)
        return PendingDecode(
            self.engine, self, self._program.projection, rel.schema,
            _SharedFetch(rel), None, stats, trace,
        )

    def explain(self, analyze: bool = False) -> str:
        """The plan explanation; `analyze=True` appends per-join-node
        actuals (estimated vs actual rows, bucket fill, overflows, the
        chosen backend) from the most recent run — running the query once
        first if this handle has never executed."""
        if analyze and self.last_stats is None:
            self.run()
        return self.engine._explain_program(self, self._program,
                                            analyze=analyze)


@dataclasses.dataclass
class UpdateResult:
    """Outcome of engine.update(): rows actually applied (set semantics —
    duplicate inserts and absent deletes are skipped) and the store
    version the update committed at."""

    inserted: int
    deleted: int
    n_ops: int
    version: int


@dataclasses.dataclass
class QueryEngine:
    store: TripleStore
    use_kernel: bool = False  # Pallas pair-expand in the join
    exact_count_pass: bool = True  # Mars two-pass vs double-on-overflow
    max_capacity: int = 1 << 24
    compiled: bool = True  # one-dispatch compiled pipeline vs eager loop
    plan_cache_entries: int = 256
    optimize: bool = True  # cost-based optimizer (False: legacy greedy)
    # physical join algebra: None = per-node cost-based choice (the
    # optimizer's selectivity x skew rule), "mr" / "matrix" = force every
    # join slot onto that backend (differential tests, benchmarks)
    join_backend: str | None = None
    warmup_path: str | None = None  # saved bucket signatures (save_cache)
    max_batch_width: int = 64  # lane cap per stacked run_batch dispatch
    # cross-shape padded stacking: run_batch coalesces near-miss PlanShapes
    # (identical but for pow-2 scan caps) into one stacked dispatch by
    # padding scans up to the group's max caps — padding rows are
    # valid=False, hence invisible to every masked operator. Merges are
    # taken only when every member shape is already warm and the padding
    # waste stays under pad_waste_limit (padded/real cell ratio - 1).
    pad_stacking: bool = True
    pad_waste_limit: float = 2.0
    # per-query span tracing: None (default) = off, zero overhead beyond
    # `trace is not None` checks on the dispatch path. The server shares
    # this Tracer so its request spans and the engine's dispatch spans
    # land in one trace tree.
    tracer: Tracer | None = None

    def __post_init__(self):
        if self.join_backend not in (None, "mr", "matrix"):
            raise ValueError(
                f"join_backend must be None, 'mr' or 'matrix' "
                f"(got {self.join_backend!r})"
            )
        self._jit_join = jax.jit(
            mj.mr_join, static_argnames=("capacity", "use_kernel")
        )
        self._jit_left_join = jax.jit(
            mj.left_join, static_argnames=("capacity", "use_kernel")
        )
        self._jit_count = jax.jit(mj.mr_join_count)
        self._jit_cross = jax.jit(mj.cross_join, static_argnames=("capacity",))
        self.plan_cache = PlanCache(self.plan_cache_entries)
        # learned bucket signatures from a previous process: a shape found
        # here compiles directly at the saved capacities, skipping the
        # eager calibration run entirely
        self._warm_caps: dict[plan_ir.PlanShape, tuple[int, ...]] = {}
        # persisted stacked (width, scan_axes) layouts per shape; files
        # written before run_batch existed simply have none, and files
        # from before broadcast scans carry widths only (all-stacked)
        self._warm_layouts: dict[plan_ir.PlanShape, tuple[tuple, ...]] = {}
        if self.warmup_path is not None:
            p = pathlib.Path(self.warmup_path)
            if p.exists():
                data = json.loads(p.read_text())
                # v3 files carry the writer's statistics catalog: seed the
                # store's lazy cache with it so backend choices (hence plan
                # shapes) match the saved signatures exactly. Older files
                # (v1/v2) have no catalog — the store computes its own,
                # which is identical for the same triples.
                stats_blob = data.get("statistics")
                if stats_blob is not None and self.store._statistics is None:
                    from repro.sparql.store import StoreStatistics

                    self.store._statistics = StoreStatistics.from_jsonable(
                        stats_blob
                    )
                for e in data["entries"]:
                    shape = plan_ir.shape_from_jsonable(e["shape"])
                    self._warm_caps[shape] = tuple(
                        int(c) for c in e["join_caps"]
                    )
                    layouts = [
                        (int(w), tuple(axes))
                        for w, axes in e.get("layouts", ())
                    ]
                    stacked = (0,) * len(shape.scan_schemas)
                    for w in e.get("widths", ()):
                        if not any(lw == int(w) for lw, _ in layouts):
                            layouts.append((int(w), stacked))
                    if layouts:
                        self._warm_layouts[shape] = tuple(layouts)
        # stacked-batch counters (cumulative; server stats report them)
        self.batch_width_hist: dict[int, int] = {}
        self.stacked_dispatches = 0
        self.stacked_queries = 0
        self.last_batch: list[BatchGroupStats] = []
        # cross-shape padding counters: merges taken / rejected by the
        # cost guard, and the cell ledger behind the waste ratio
        # (padded_cells ≥ real_cells; their gap is what padding burned)
        self.padded_groups = 0
        self.pad_rejects = 0
        self.padded_cells = 0
        self.real_cells = 0
        # cumulative wall seconds the host spent inside device dispatch +
        # result sync — the open-loop bench derives the device-idle
        # fraction as 1 - Δdevice_time_s / wall
        self.device_time_s = 0.0
        # correlates the N lane "dispatch" spans a stacked chunk fans out
        self._dispatch_seq = 0
        # the unified metrics registry: engine-side counters are bridged
        # in by a scrape-time collector (the dispatch path pays nothing);
        # the server registers its request metrics on this same registry
        self.metrics = MetricsRegistry()
        self._register_metrics()

    def _register_metrics(self) -> None:
        """Declare the engine's metrics and the collector that mirrors
        the hot-path counters into them at scrape time (naming scheme:
        mapsq_<subsystem>_<name>[_total|_seconds|_ratio])."""
        m = self.metrics
        g = {
            "plan_hits": m.counter(
                "mapsq_plan_cache_hits_total", "plan cache hits"),
            "plan_misses": m.counter(
                "mapsq_plan_cache_misses_total", "plan cache misses"),
            "plan_compiles": m.counter(
                "mapsq_plan_cache_compiles_total", "XLA compilations"),
            "plan_entries": m.gauge(
                "mapsq_plan_cache_entries", "live plan cache entries"),
            "scan_hits": m.counter(
                "mapsq_scan_cache_hits_total", "scan cache hits"),
            "scan_misses": m.counter(
                "mapsq_scan_cache_misses_total", "scan cache misses"),
            "scan_evictions": m.counter(
                "mapsq_scan_cache_evictions_total",
                "scan cache entries dropped by writes"),
            "stacked_dispatches": m.counter(
                "mapsq_stacked_dispatches_total",
                "vmapped multi-query device launches"),
            "stacked_queries": m.counter(
                "mapsq_stacked_queries_total",
                "queries served by stacked launches"),
            "padded_groups": m.counter(
                "mapsq_padding_groups_total",
                "cross-shape padded merges taken"),
            "pad_rejects": m.counter(
                "mapsq_padding_rejects_total",
                "padded merges rejected by the waste guard"),
            "padded_cells": m.counter(
                "mapsq_padding_padded_cells_total",
                "scan cells dispatched incl. padding"),
            "real_cells": m.counter(
                "mapsq_padding_real_cells_total",
                "scan cells that were real data"),
            "device_time": m.counter(
                "mapsq_device_time_seconds_total",
                "host wall seconds inside device dispatch + sync"),
            "store_version": m.gauge(
                "mapsq_store_version", "store write version"),
            "store_tail": m.gauge(
                "mapsq_store_tail_rows", "uncompacted delta rows"),
            "store_tombstones": m.gauge(
                "mapsq_store_tombstones", "live tombstone rows"),
        }
        g["traces"] = m.counter(
            "mapsq_traces_total", "finished query traces")
        g["slow"] = m.counter(
            "mapsq_slow_queries_total",
            "traces over the slow-query threshold")

        def collect() -> None:
            pc = self.plan_cache.stats()
            g["plan_hits"].set_total(pc["hits"])
            g["plan_misses"].set_total(pc["misses"])
            g["plan_compiles"].set_total(pc["compiles"])
            g["plan_entries"].set(pc["entries"])
            sc = self.store.scan_cache_stats()
            g["scan_hits"].set_total(sc.get("hits", 0))
            g["scan_misses"].set_total(sc.get("misses", 0))
            g["scan_evictions"].set_total(sc.get("evictions", 0))
            g["stacked_dispatches"].set_total(self.stacked_dispatches)
            g["stacked_queries"].set_total(self.stacked_queries)
            g["padded_groups"].set_total(self.padded_groups)
            g["pad_rejects"].set_total(self.pad_rejects)
            g["padded_cells"].set_total(self.padded_cells)
            g["real_cells"].set_total(self.real_cells)
            g["device_time"].set_total(self.device_time_s)
            ws = self.store.write_stats()
            g["store_version"].set(ws["version"])
            g["store_tail"].set(ws["tail_rows"])
            g["store_tombstones"].set(ws["tombstones"])
            if self.tracer is not None:
                g["traces"].set_total(self.tracer.n_traces)
                g["slow"].set_total(self.tracer.n_slow)

        m.register_collector(collect)

    def _device_tick(self, stats: ExecStats, t0: float) -> float:
        """Account one dispatch-and-sync interval on BOTH ledgers (the
        engine-wide total and this run's ExecStats) so the engine total
        always equals the sum over runs. Returns the end stamp."""
        t1 = time.perf_counter()
        dt = t1 - t0
        self.device_time_s += dt
        stats.device_time_s += dt
        return t1

    def render_prometheus(self) -> str:
        return self.metrics.render_prometheus()

    def save_cache(self, path: str) -> int:
        """Serialize the plan cache's learned bucket signatures to JSON.

        A `QueryEngine(warmup_path=...)` in a restarted process compiles
        known shapes straight at these capacities — no calibration run.
        Each entry carries the stacked batch widths seen for the shape
        (compiled this process or inherited from a previous warmup file),
        so (shape, caps, width) signatures round-trip across restarts;
        files written before batching existed load unchanged (the widths
        key is optional). Returns the number of signatures written.
        """
        entries = [
            self._entry_jsonable(e) for e in self.plan_cache.entries()
        ]
        pathlib.Path(path).write_text(
            json.dumps(
                {
                    "version": 3,
                    # the statistics catalog (incl. per-predicate degree
                    # skew) rides along so a restarted process makes the
                    # SAME backend decisions — shapes keep hashing to the
                    # saved signatures even if it recomputes nothing
                    "statistics": self.store.statistics.to_jsonable(),
                    "entries": entries,
                }
            )
        )
        return len(entries)

    def _entry_jsonable(self, e: PlanCacheEntry) -> dict:
        """One warmup-file entry (the sharded engine appends its shuffle
        bucket caps here — keep the base format in one place)."""
        return {
            "shape": plan_ir.shape_to_jsonable(e.shape),
            "join_caps": list(e.join_caps),
            "widths": list(e.widths()),
            "layouts": [[w, list(axes)] for w, axes in e.layouts()],
        }

    # -- public API --------------------------------------------------------
    def prepare(self, text: str, trace=None) -> PreparedQuery:
        """Parse, validate and plan once; run (and re-run) later."""
        if trace is None:
            return PreparedQuery(self, text, parse(text))
        with trace.span("parse"):
            q = parse(text)
        with trace.span("optimize"):
            return PreparedQuery(self, text, q)

    def query(self, text: str) -> list[dict[str, str]]:
        """One-shot convenience: rows as {var: term} dicts."""
        return self.prepare(text).run().rows

    def execute(self, q: Query) -> tuple[Relation, ExecStats]:
        """Run a parsed query; the result Relation carries the projected
        (and DISTINCT-deduplicated, filtered, sliced) bindings."""
        stats = ExecStats()
        rel = self._execute_program(self._build_program(q), stats)
        return rel, stats

    def explain(self, text: str, analyze: bool = False) -> str:
        return self.prepare(text).explain(analyze=analyze)

    def update(self, text: str) -> UpdateResult:
        """Parse and apply `INSERT DATA { ... }` / `DELETE DATA { ... }`
        operations, in request order, atomically against queries (the
        whole request holds the store's write lock, so no run observes a
        half-applied request).

        Warm plan shapes survive the write: inserted rows and tombstone
        masks ride inside the existing pow-2 scan buckets, so previously
        compiled programs keep re-running at 0 compiles / 1 dispatch until
        a pattern outgrows its bucket."""
        req: UpdateRequest = parse_update(text)
        inserted = deleted = 0
        with self.store.snapshot_lock():
            for op in req.ops:
                rows = [(tp.s, tp.p, tp.o) for tp in op.triples]
                if isinstance(op, algebra.InsertData):
                    inserted += self.store.insert_triples(rows)
                else:
                    deleted += self.store.delete_triples(rows)
        return UpdateResult(
            inserted, deleted, len(req.ops), self.store.version
        )

    def cache_stats(self) -> dict:
        return self.plan_cache.stats()

    def stats(self) -> dict:
        """One observability snapshot: plan cache, scan cache, and the
        store's write-path health (version, tail size, tombstone count,
        compaction count)."""
        return {
            "plan_cache": self.plan_cache.stats(),
            "scan_cache": self.store.scan_cache_stats(),
            "store": self.store.write_stats(),
        }

    def run_batch(self, prepared: list[PreparedQuery]) -> list[ResultSet]:
        """Execute a micro-batch, coalescing same-shape queries.

        Queries are grouped by compiled plan signature (PlanShape); each
        warm group runs as ONE stacked device dispatch per pow-2 width
        chunk (vmap over scan tuples and runtime constants), so N warm
        same-shape queries cost ceil(N / width) dispatches instead of N.
        Mixed batches fall back per-group; a cold group calibrates on its
        first query and stacks the rest. Results are positionally aligned
        with `prepared`. Per-group accounting lands in `self.last_batch`;
        the first failing query's exception is re-raised (use
        `run_batch_outcomes` for per-query error isolation).
        """
        outcomes = self.run_batch_outcomes(prepared)
        for oc in outcomes:
            if isinstance(oc, Exception):
                raise oc
        return outcomes

    def run_batch_outcomes(
        self, prepared: list[PreparedQuery]
    ) -> list["ResultSet | Exception"]:
        """run_batch with per-query error isolation: each slot is either a
        ResultSet or the exception that query raised (the server's batch
        path relies on one bad query never failing its batchmates)."""
        return self._run_batch_impl(prepared, defer=False)

    def run_batch_pipelined(
        self, prepared: list[PreparedQuery], traces: "list | None" = None
    ) -> list["ResultSet | Exception | PendingDecode"]:
        """The serving pipeline's dispatch stage: like run_batch_outcomes,
        but slots whose device work dispatched cleanly come back as
        PendingDecode — the host decode (device→host transfer + row
        materialisation + per-handle accounting) has NOT been paid, and
        `.resolve()` may run on any thread. The batcher thread returns as
        soon as device work is enqueued, so dispatch of batch k+1 overlaps
        decode of batch k on the decode pool."""
        return self._run_batch_impl(prepared, defer=True, traces=traces)

    def _run_batch_impl(
        self, prepared: list[PreparedQuery], defer: bool,
        traces: "list | None" = None,
    ) -> list:
        self.last_batch = []
        out: list = [None] * len(prepared)
        if traces is None:
            traces = [None] * len(prepared)
        if not self.compiled:
            group = BatchGroupStats(n_queries=len(prepared), fallback=True)
            self.last_batch.append(group)
            for i, pq in enumerate(prepared):
                out[i] = self._run_single(pq, group, defer, traces[i])
            return out
        # group by compiled plan signature (the PlanShape cache key)
        ctxs: list[_BatchCtx | None] = [None] * len(prepared)
        groups: OrderedDict[plan_ir.PlanShape, list[int]] = OrderedDict()
        for i, pq in enumerate(prepared):
            try:
                # staging is stable per handle between writes (program,
                # cache key) — compute once, reuse across micro-batches,
                # recompute after a store version bump (a write can move a
                # pattern into a bigger capacity bucket = a new shape)
                if (
                    pq._batch_ctx is None
                    or pq._batch_ctx.store_version != self.store.version
                ):
                    pq._batch_ctx = self._batch_context(pq._program)
                ctxs[i] = pq._batch_ctx
            except Exception as e:
                out[i] = e
                continue
            groups.setdefault(ctxs[i].shape, []).append(i)
        merged: OrderedDict[plan_ir.PlanShape, tuple[list[int], int, int]]
        if self.pad_stacking and len(groups) > 1:
            merged = self._coalesce_groups(groups)
        else:
            merged = OrderedDict(
                (s, (idxs, 1, 0)) for s, idxs in groups.items()
            )
        for shape, (idxs, n_shapes, n_compiles) in merged.items():
            self._run_group(
                shape, idxs, ctxs, prepared, out, defer,
                n_shapes=n_shapes, extra_compiles=n_compiles,
                traces=traces,
            )
        return out

    def _template_scans(
        self, shape: plan_ir.PlanShape
    ) -> tuple[Relation, ...]:
        """Abstract (shape/dtype) scan templates for AOT-lowering a shape
        without staging device data — the only template source that is
        correct for PADDED shapes, whose scan caps exceed every member
        query's natural staging capacities."""
        sds = jax.ShapeDtypeStruct
        return tuple(
            Relation(
                schema,
                sds((cap, len(schema)), jnp.int32),
                sds((cap,), jnp.bool_),
            )
            for schema, cap in zip(shape.scan_schemas, shape.scan_caps)
        )

    def _coalesce_groups(
        self, groups: "OrderedDict[plan_ir.PlanShape, list[int]]"
    ) -> "OrderedDict[plan_ir.PlanShape, tuple[list[int], int, int]]":
        """Cross-shape padded stacking: merge near-miss plan groups —
        identical PlanShapes except for pow-2 scan caps — into one padded
        group at the per-position MAX caps, so a mixed-shape batch still
        coalesces into few stacked dispatches. Padding rows carry
        valid=False, which every masked operator already treats as
        absent, so merged lanes decode exactly the rows their natural
        shape would have produced.

        Guards (a rejected bucket simply keeps its per-shape groups):
          * every member shape must be WARM — a padded group has no
            calibration story of its own, so the padded entry's join caps
            are derived as the elementwise max of the members' calibrated
            caps, which only exist once each member has run;
          * the cost guard: padding waste (padded/real scan-cell ratio
            minus 1) must stay ≤ pad_waste_limit, so one huge outlier
            shape cannot inflate every lane's scan buffers;
          * the padded entry must compile (template lowering) — any
            failure falls back to per-shape groups rather than the
            sequential path.
        """
        buckets: OrderedDict[tuple, list[plan_ir.PlanShape]] = OrderedDict()
        for shape in groups:
            key = dataclasses.replace(
                shape, scan_caps=(0,) * len(shape.scan_caps)
            )
            buckets.setdefault(key, []).append(shape)
        merged: OrderedDict[
            plan_ir.PlanShape, tuple[list[int], int, int]
        ] = OrderedDict()
        for members in buckets.values():
            if len(members) < 2:
                s = members[0]
                merged[s] = (groups[s], 1, 0)
                continue
            entries = [self.plan_cache.get(s) for s in members]
            target = tuple(
                max(s.scan_caps[j] for s in members)
                for j in range(len(members[0].scan_caps))
            )
            n_q = sum(len(groups[s]) for s in members)
            real = sum(
                len(groups[s]) * sum(s.scan_caps) for s in members
            )
            padded = n_q * sum(target)
            ok = all(e is not None for e in entries)
            if ok and (padded - real) / real > self.pad_waste_limit:
                self.pad_rejects += 1
                ok = False
            n_compiles = 0
            padded_shape = None
            if ok:
                padded_shape = dataclasses.replace(
                    members[0], scan_caps=target
                )
                if self.plan_cache.get(padded_shape) is None:
                    join_caps = tuple(
                        max(e.join_caps[j] for e in entries)
                        for j in range(len(entries[0].join_caps))
                    )
                    sink = ExecStats()
                    try:
                        self._compile_entry(
                            padded_shape, join_caps,
                            self._template_scans(padded_shape), None, sink,
                        )
                    except Exception:
                        ok = False
                    n_compiles = sink.n_compiles
            if not ok:
                for s in members:
                    merged[s] = (groups[s], 1, 0)
                continue
            idxs = sorted(
                i for s in members for i in groups[s]
            )  # arrival order across member groups
            merged[padded_shape] = (idxs, len(members), n_compiles)
            self.padded_groups += 1
            self.padded_cells += padded
            self.real_cells += real
        return merged

    # -- batched execution internals ---------------------------------------
    def _batch_context(self, prog: _Program) -> "_BatchCtx":
        with self.store.snapshot_lock():
            _, shape, inverse = self._canonicalize(prog)
            version = self.store.version
        return _BatchCtx(
            prog=prog, shape=shape, inverse=inverse, store_version=version
        )

    def _run_single(
        self, pq: PreparedQuery, group: BatchGroupStats, defer: bool = False,
        trace=None,
    ) -> "ResultSet | Exception | PendingDecode":
        """Sequential fallback inside run_batch: the normal per-query path,
        with its dispatch/compile counts folded into the group's. With
        `defer`, host decode is left pending for the decode stage."""
        try:
            pending = pq._run_pending(trace)
        except Exception as e:
            return e
        group.n_dispatches += pending.stats.n_dispatches
        group.n_compiles += pending.stats.n_compiles
        return pending if defer else pending.resolve()

    def _run_group(
        self,
        shape: plan_ir.PlanShape,
        idxs: list[int],
        ctxs: list["_BatchCtx | None"],
        prepared: list[PreparedQuery],
        out: list,
        defer: bool = False,
        n_shapes: int = 1,
        extra_compiles: int = 0,
        traces: "list | None" = None,
    ) -> None:
        if traces is None:
            traces = [None] * len(out)
        group = BatchGroupStats(
            n_queries=len(idxs),
            padded=n_shapes > 1,
            n_shapes=n_shapes,
            n_compiles=extra_compiles,  # the padded entry's template compile
        )
        self.last_batch.append(group)
        pos = 0
        if self.plan_cache.get(shape) is None:
            # cold shape: the first query runs the normal path (calibration
            # or warmup compile), populating the cache the rest stack on
            group.cold = True
            out[idxs[0]] = self._run_single(
                prepared[idxs[0]], group, defer, traces[idxs[0]]
            )
            pos = 1
        # chunk at the pow-2 floor of the lane cap: max_batch_width bounds
        # device memory per dispatch, so it must never round UP
        width_cap = plan_ir.floor_pow2(self.max_batch_width)
        while pos < len(idxs):
            chunk = idxs[pos:pos + width_cap]
            pos += len(chunk)
            if len(chunk) < 2 or self.plan_cache.get(shape) is None:
                for i in chunk:
                    out[i] = self._run_single(prepared[i], group, defer)
                continue
            try:
                self._run_chunk_stacked(
                    shape, chunk, ctxs, prepared, out, group, defer, traces
                )
            except Exception:
                # stacked dispatch failed (e.g. bucket growth past
                # max_capacity): isolate errors by re-running the chunk's
                # queries sequentially so only the culprit raises
                group.fallback = True
                for i in chunk:
                    out[i] = self._run_single(
                        prepared[i], group, defer, traces[i]
                    )

    def _run_chunk_stacked(
        self,
        shape: plan_ir.PlanShape,
        chunk: list[int],
        ctxs: list["_BatchCtx | None"],
        prepared: list[PreparedQuery],
        out: list,
        group: BatchGroupStats,
        defer: bool = False,
        traces: "list | None" = None,
    ) -> None:
        """ONE stacked dispatch for a chunk of warm same-shape queries.

        For a PADDED group (`shape` is the coalesced max-caps signature)
        every lane's scans are padded up to `shape.scan_caps` — padding
        rows are valid=False, so the lane computes exactly what its
        natural shape would have."""
        entry = self.plan_cache.get(shape)
        n = len(chunk)
        width = plan_ir.bucket_width(n, self.max_batch_width)
        # pad trailing lanes with lane 0's inputs; lane_active masks them
        lanes = [ctxs[i] for i in chunk] + [ctxs[chunk[0]]] * (width - n)
        # per scan position: if every lane scans the SAME pattern (e.g. a
        # batch differing only in FILTER constants) AND its staged buffer
        # already sits at the group's capacity, ship the device buffer
        # once and let vmap broadcast it (in_axes=None) instead of
        # staging W stacked copies
        scans_b: list[Relation] = []
        axes: list[int | None] = []
        with self.store.snapshot_lock():  # one store version per chunk
            for j in range(len(shape.scan_schemas)):
                cap = shape.scan_caps[j]
                tps = tuple(c.prog.patterns[j] for c in lanes)
                rel = None
                if len({self.store._scan_key(tp) for tp in tps}) == 1:
                    rel = self.store.match_pattern_device(tps[0])
                if rel is not None and rel.capacity == cap:
                    scans_b.append(
                        Relation(shape.scan_schemas[j], rel.cols, rel.valid)
                    )
                    axes.append(None)
                else:
                    scans_b.append(
                        Relation(
                            shape.scan_schemas[j],
                            *self.store.stacked_scan_device(tps, cap=cap),
                        )
                    )
                    axes.append(0)
            staged_version = self.store.version
        scans_b = tuple(scans_b)
        scan_axes = tuple(axes)
        group.n_broadcast_scans += sum(1 for a in scan_axes if a is None)
        consts_i = jnp.asarray(np.stack([c.prog.consts_i for c in lanes]))
        consts_f = jnp.asarray(np.stack([c.prog.consts_f for c in lanes]))
        active = jnp.asarray(np.arange(width) < n)
        num_vals = self.store.numeric_values_device()
        stats = ExecStats(
            n_joins=shape.n_joins(),
            cache_hits=1,
            batch_width=width,
            store_version=staged_version,
        )
        self.plan_cache.hits += n
        if entry.num_cap not in (0, int(num_vals.shape[-1])):
            # dictionary growth crossed a pow-2 boundary since the entry
            # compiled: recompile at the same join caps (shape unchanged).
            # Templates come from the SHAPE, not lane 0's natural staging
            # — for a padded group those differ.
            entry = self._compile_entry(
                shape, entry.join_caps, self._template_scans(shape), None,
                stats,
            )
        # retroactive span intervals, fanned out to every lane trace after
        # the chunk succeeds (one device launch -> N lane "dispatch" spans
        # correlated by a shared dispatch_id)
        events: list[tuple[str, float, float]] = []
        ovf_counts = [0] * shape.n_joins()
        try:
            while True:
                bexec = entry.batched.get((width, scan_axes))
                if bexec is None:
                    tc0 = time.perf_counter()
                    bexec = ex.compile_plan_batched(
                        entry.compiled.plan,
                        scans_b,
                        consts_i,
                        consts_f,
                        num_vals,
                        active,
                        use_kernel=self.use_kernel,
                        scan_axes=scan_axes,
                    )
                    events.append(("compile", tc0, time.perf_counter()))
                    entry.batched[(width, scan_axes)] = bexec
                    stats.n_compiles += 1
                    self.plan_cache.compiles += 1
                stats.n_dispatches += 1
                t0 = time.perf_counter()
                rel_b, totals_b, flags_b = bexec(
                    scans_b, consts_i, consts_f, num_vals, active
                )
                flags_np = np.asarray(flags_b)  # the single host sync
                events.append(("dispatch", t0, self._device_tick(stats, t0)))
                if not flags_np.any():
                    break
                # some lane overflowed a bucket: grow each flagged join to
                # the worst lane's exact total, recompile, retry the chunk
                stats.n_retries += 1
                totals_np = np.asarray(totals_b)
                overflowed = [
                    bool(flags_np[:, j].any())
                    for j in range(flags_np.shape[1])
                ]
                for j, f in enumerate(overflowed):
                    ovf_counts[j] += int(f)
                new_caps = plan_ir.grow_join_caps(
                    entry.join_caps,
                    [int(totals_np[:, j].max())
                     for j in range(totals_np.shape[1])],
                    overflowed,
                )
                if max(new_caps) > self.max_capacity:
                    raise MemoryError(
                        f"join result exceeds {self.max_capacity}"
                    )
                entry = self._compile_entry(
                    shape, new_caps, self._template_scans(shape), None,
                    stats,
                )
        finally:
            # the group ledger counts every launch and compile, including
            # those of a chunk that then failed over to the sequential path
            group.n_dispatches += stats.n_dispatches
            group.n_compiles += stats.n_compiles
        # the serving counters only describe *successful* stacked service,
        # so queries_per_dispatch can never be skewed by a failed chunk
        group.widths = group.widths + (width,)
        self.stacked_dispatches += stats.n_dispatches
        self.batch_width_hist[width] = (
            self.batch_width_hist.get(width, 0) + stats.n_dispatches
        )
        self.stacked_queries += n
        caps = entry.compiled.plan.join_caps
        stats.peak_join_bucket = max(caps) if caps else 0
        stats.peak_capacity = entry.compiled.plan.max_capacity()
        stats.join_caps = tuple(caps)
        stats.join_overflows = tuple(ovf_counts)
        # per-lane exact totals (width, n_joins): each lane's analyze view
        # reports ITS actual rows, not the chunk's
        lane_totals = self._chunk_lane_totals(totals_b)
        self._emit_chunk_results(
            rel_b, chunk, ctxs, prepared, out, stats, defer,
            lane_totals=lane_totals, traces=traces, events=events,
        )

    def _chunk_lane_totals(self, totals_b) -> tuple[np.ndarray, np.ndarray]:
        """Stacked totals -> per-lane (global, worst-partition) actuals,
        each (width, n_joins). On the single-device engine they coincide;
        the sharded override sums/maxes away its shard axis."""
        t = np.asarray(totals_b)
        return t, t

    def _emit_chunk_results(
        self,
        rel_b: Relation,
        chunk: list[int],
        ctxs: list["_BatchCtx | None"],
        prepared: list[PreparedQuery],
        out: list,
        stats: ExecStats,
        defer: bool,
        lane_totals: "tuple | None" = None,
        traces: "list | None" = None,
        events: "list | None" = None,
    ) -> None:
        """Unstack a chunk's result: ONE device→host transfer shared by
        every lane (lazy — the first decode consumer pays it), then
        per-lane row decode under each query's own variable names, either
        inline or left pending for the serving decode pool."""
        fetch = _SharedFetch(rel_b)
        schema = rel_b.schema
        if events:
            self._dispatch_seq += 1
        for k, i in enumerate(chunk):
            names = tuple(ctxs[i].inverse[v] for v in schema)
            st = dataclasses.replace(stats)
            if lane_totals is not None:
                totals, worst = lane_totals
                st.join_totals = tuple(int(x) for x in totals[k])
                st.join_worst = tuple(int(x) for x in worst[k])
                # the chunk's dispatch wall is shared: attribute an equal
                # share to each lane so the engine-level device_time_s
                # stays equal to the sum over per-run ExecStats
                st.device_time_s = stats.device_time_s / len(chunk)
            trace = traces[i] if traces is not None else None
            if trace is not None and events:
                for name, t0, t1 in events:
                    trace.add_span(
                        name, t0, t1,
                        dispatch_id=self._dispatch_seq,
                        width=stats.batch_width, stacked=True, lane=k,
                    )
            pending = PendingDecode(
                self, prepared[i], names, names, fetch, k, st, trace,
            )
            out[i] = pending if defer else pending.resolve()

    # -- planning ----------------------------------------------------------
    def _lower_expr(
        self,
        expr: algebra.FilterExpr,
        id_consts: list[int],
        f_consts: list[float],
    ) -> plan_ir.FilterExpr:
        """Algebra filter expression -> plan expression, allocating the
        runtime-constant slots its literal leaves reference."""
        if isinstance(expr, algebra.Compare):
            if isinstance(expr.rhs, algebra.Var):
                return ("cmp", expr.lhs, expr.op, "var", expr.rhs.name)
            if isinstance(expr.rhs, algebra.NumLit):
                idx = len(f_consts)
                f_consts.append(expr.rhs.value)
                return ("cmp", expr.lhs, expr.op, "num", idx)
            # TermLit: identity comparison; unknown terms can never match
            # a bound variable, -1 encodes that correctly
            tid = self.store.dictionary.lookup(expr.rhs.lexical)
            idx = len(id_consts)
            id_consts.append(-1 if tid is None else tid)
            return ("cmp", expr.lhs, expr.op, "id", idx)
        tag = "and" if isinstance(expr, algebra.And) else "or"
        return (
            tag,
            tuple(
                self._lower_expr(c, id_consts, f_consts)
                for c in expr.children
            ),
        )

    def _build_program(self, q: Query) -> _Program:
        # the sharded engine reports its mesh size so the join ordering
        # can weigh shuffle cost; single-device engines pass 1 (no-op)
        plan = optimizer.optimize(
            q, self.store, enabled=self.optimize,
            n_shards=getattr(self, "n_shards", 1),
        )
        patterns = list(plan.all_patterns())
        opt_groups = tuple(
            plan_ir.GroupSpec(len(g), plan.opt_cross_flags[i])
            for i, g in enumerate(plan.opt_groups)
        )
        union_groups = tuple(
            plan_ir.GroupSpec(len(b), plan.branch_cross_flags[i])
            for i, b in enumerate(plan.branches)
        )
        id_consts: list[int] = []
        f_consts: list[float] = []
        # a conjunct the optimizer distributed into several UNION branches
        # is lowered once and shares its constant slots across the copies
        lowered: dict[int, plan_ir.FilterExpr] = {}
        specs: list[plan_ir.FilterSpec] = []
        for stage, expr in plan.filters:
            key = id(expr)
            if key not in lowered:
                lowered[key] = self._lower_expr(expr, id_consts, f_consts)
            specs.append((stage, lowered[key]))
        n_consts = (len(id_consts), len(f_consts))
        has_slice = q.has_slice()
        if has_slice:
            limit = q.limit if q.limit is not None else _NO_LIMIT
            id_consts += [min(q.offset, _NO_LIMIT), min(limit, _NO_LIMIT)]
        return _Program(
            q,
            plan,
            patterns,
            plan.cross_flags,
            opt_groups,
            union_groups,
            plan.has_required,
            tuple(specs),
            n_consts,
            np.asarray(id_consts, np.int32),
            np.asarray(f_consts, np.float32),
            tuple(q.projection()),
            q.distinct,
            has_slice,
        )

    def _shape_for(
        self,
        prog: _Program,
        schemas: tuple[tuple[str, ...], ...],
        caps: tuple[int, ...],
        rename: dict[str, str] | None = None,
    ) -> plan_ir.PlanShape:
        r = rename or {}

        def rn(v: str) -> str:
            return r.get(v, v)

        specs = tuple(
            (stage, plan_ir.rename_expr(expr, r))
            for stage, expr in prog.filters
        )
        # per-slot physical algebra rides in the shape (a backend flip is
        # a different compiled program); an engine-level override forces
        # every slot, otherwise the optimizer's per-node choice stands
        backends = prog.plan.join_backends
        if self.join_backend is not None:
            backends = (self.join_backend,) * len(backends)
        return plan_ir.make_shape(
            tuple(tuple(rn(v) for v in s) for s in schemas),
            caps,
            prog.cross_flags,
            tuple(rn(v) for v in prog.projection),
            prog.distinct,
            opt_groups=prog.opt_groups,
            union_groups=prog.union_groups,
            has_required=prog.has_required,
            filters=specs,
            n_consts=prog.n_consts,
            has_slice=prog.has_slice,
            prune=prog.plan.prune,
            join_backends=backends,
            scan_parts=self._scan_parts(prog, schemas),
        )

    def _scan_parts(
        self,
        prog: _Program,
        schemas: tuple[tuple[str, ...], ...],
    ) -> tuple[int, ...]:
        """Per-scan partition column (index into the scan's schema; -1 =
        unpartitioned). The single-device store is one shard, so nothing
        is partitioned; the sharded engine overrides with the store's
        subject-hash placement. Column positions are invariant under the
        canonical rename, so the shape stays structurally hashable."""
        return ()

    # -- execution ---------------------------------------------------------
    def _execute_program(
        self, prog: _Program, stats: ExecStats, trace=None
    ) -> Relation:
        if self.compiled:
            return self._execute_compiled(prog, stats, trace)
        with self.store.snapshot_lock():  # consistent version across scans
            scans = tuple(
                self.store.match_pattern(tp) for tp in prog.patterns
            )
            stats.store_version = self.store.version
        shape = self._shape_for(
            prog,
            tuple(s.schema for s in scans),
            tuple(s.capacity for s in scans),
        )
        t0 = time.perf_counter()
        rel, totals = self._eval_shape_eager(shape, scans, prog, stats)
        stats.join_totals = tuple(totals)
        stats.join_worst = stats.join_totals
        if trace is not None:
            trace.add_span("dispatch", t0, time.perf_counter(), eager=True)
        return rel

    def _decode_rows(self, rel: Relation) -> list[dict[str, str]]:
        return self._decode_numpy(rel.schema, rel.to_numpy())

    def _decode_numpy(
        self, schema: tuple[str, ...], rows: np.ndarray
    ) -> list[dict[str, str]]:
        d = self.store.dictionary
        return [
            {
                v: d.decode(int(t))
                for v, t in zip(schema, row)
                if int(t) != UNBOUND
            }
            for row in rows
        ]

    # -- eager evaluator ---------------------------------------------------
    def _eval_shape_eager(
        self,
        shape: plan_ir.PlanShape,
        scans: tuple[Relation, ...],
        prog: _Program,
        stats: ExecStats,
    ) -> tuple[Relation, list[int]]:
        """Operator-at-a-time evaluation with exact (count-pass) bucket
        sizing. Returns the result and each join's exact total in the same
        order the compiled program reports them — the totals are what the
        compiled path calibrates its buckets on, so filter stages must be
        applied at exactly the positions build_plan interleaves them."""
        totals: list[int] = []
        consts_i = jnp.asarray(prog.consts_i)
        consts_f = jnp.asarray(prog.consts_f)
        num_vals = self.store.numeric_values_device()
        by_stage: dict[tuple, list[plan_ir.FilterExpr]] = {}
        for stage, expr in shape.filters:
            by_stage.setdefault(stage, []).append(expr)

        def apply_stage(rel: Relation, stage: tuple) -> Relation:
            exprs = by_stage.get(stage)
            if not exprs:
                return rel
            keep = mj.filter_mask(
                rel, tuple(exprs), consts_i, consts_f, num_vals
            )
            return Relation(rel.schema, rel.cols, keep)

        scan_idx = 0

        def next_scan() -> Relation:
            nonlocal scan_idx
            rel = apply_stage(scans[scan_idx], ("scan", scan_idx))
            scan_idx += 1
            return rel

        def chain(
            n_scans: int,
            cross_flags: tuple[bool, ...],
            req_stages: bool = False,
        ) -> Relation:
            acc = next_scan()
            for j, is_cross in enumerate(cross_flags):
                acc, total = self._join_once(
                    acc, next_scan(), is_cross, stats
                )
                totals.append(total)
                if req_stages:
                    acc = apply_stage(acc, ("req", j))
            return acc

        acc: Relation | None = None
        if shape.has_required:
            acc = chain(
                shape.n_required, shape.cross_flags, req_stages=True
            )
        for gi, g in enumerate(shape.opt_groups):
            grp = chain(g.n_scans, g.cross_flags)
            stats.n_joins += 1
            stats.n_dispatches += 1
            t0 = time.perf_counter()
            total = int(self._jit_count(acc, grp))
            self._device_tick(stats, t0)
            stats.n_count_passes += 1
            cap = max(1, _next_pow2(total))
            stats.n_dispatches += 1
            t0 = time.perf_counter()
            out, _, overflow = self._jit_left_join(
                acc, grp, capacity=cap, use_kernel=self.use_kernel
            )
            ok = not bool(overflow)
            self._device_tick(stats, t0)
            assert ok
            stats.peak_capacity = max(
                stats.peak_capacity, cap + acc.capacity
            )
            stats.peak_join_bucket = max(stats.peak_join_bucket, cap)
            totals.append(total)
            acc = apply_stage(out, ("opt", gi))
        if shape.union_groups:
            children: list[Relation] = []
            for bi, g in enumerate(shape.union_groups):
                branch = chain(g.n_scans, g.cross_flags)
                if acc is not None:
                    shared = [v for v in acc.schema if v in branch.schema]
                    branch, total = self._join_once(
                        acc, branch, not shared, stats
                    )
                    totals.append(total)
                children.append(apply_stage(branch, ("bjoin", bi)))
            schema: list[str] = []
            for c in children:
                for v in c.schema:
                    if v not in schema:
                        schema.append(v)
            acc = mj.union_all(children, tuple(schema))
        acc = apply_stage(acc, ("top",))
        acc = acc.project(list(shape.projection))
        if shape.distinct:
            acc = mj.distinct(acc)  # device-side dedup before decode
        if shape.has_slice:
            oi, li = shape.slice_const_indices()
            acc = mj.slice_valid(
                acc, int(prog.consts_i[oi]), int(prog.consts_i[li])
            )
        return acc, totals

    def _join_once(
        self, left: Relation, right: Relation, is_cross: bool, stats: ExecStats
    ) -> tuple[Relation, int]:
        # every branch ends in a host sync (int()/bool() of a device
        # scalar), so the _device_tick interval covers dispatch + sync —
        # the same accounting the compiled paths use
        stats.n_joins += 1
        if is_cross:
            cap = max(1, _next_pow2(left.capacity * right.capacity))
            stats.n_dispatches += 1
            t0 = time.perf_counter()
            out, total, overflow = self._jit_cross(left, right, capacity=cap)
            ok, total = not bool(overflow), int(total)
            self._device_tick(stats, t0)
            assert ok
            stats.peak_capacity = max(stats.peak_capacity, cap)
            stats.peak_join_bucket = max(stats.peak_join_bucket, cap)
            return mj.compact(out), total
        if self.exact_count_pass:
            stats.n_dispatches += 1
            t0 = time.perf_counter()
            total = int(self._jit_count(left, right))
            self._device_tick(stats, t0)
            stats.n_count_passes += 1
            cap = max(1, _next_pow2(total))
            stats.n_dispatches += 1
            t0 = time.perf_counter()
            out, _, overflow = self._jit_join(
                left, right, capacity=cap, use_kernel=self.use_kernel
            )
            ok = not bool(overflow)
            self._device_tick(stats, t0)
            assert ok
            stats.peak_capacity = max(stats.peak_capacity, cap)
            stats.peak_join_bucket = max(stats.peak_join_bucket, cap)
            return out, total
        cap = max(left.capacity, right.capacity)
        while True:
            stats.n_dispatches += 1
            t0 = time.perf_counter()
            out, total, overflow = self._jit_join(
                left, right, capacity=cap, use_kernel=self.use_kernel
            )
            overflowed = bool(overflow)
            self._device_tick(stats, t0)
            stats.peak_capacity = max(stats.peak_capacity, cap)
            stats.peak_join_bucket = max(stats.peak_join_bucket, cap)
            if not overflowed:
                return out, int(total)
            stats.n_retries += 1
            cap *= 2
            if cap > self.max_capacity:
                raise MemoryError(f"join result exceeds {self.max_capacity}")

    # -- compiled path -----------------------------------------------------
    def _canonicalize(
        self, prog: _Program
    ) -> tuple[tuple[Relation, ...], plan_ir.PlanShape, dict[str, str]]:
        """Device scans + cache key for a program: upload-once scans
        (bucketed pow-2 capacities), variable names canonicalised so
        structurally-equal queries share one compiled program (constants
        live in the scan data and the runtime-constant inputs, not here).
        Returns (canonical scans, shape, canonical -> original names).

        Staging runs under the store's snapshot lock so every scan reflects
        ONE store version even while concurrent updates land."""
        with self.store.snapshot_lock():
            scans = tuple(
                self.store.match_pattern_device(tp) for tp in prog.patterns
            )
        schemas = tuple(s.schema for s in scans)
        rename = plan_ir.canonical_renaming(schemas)
        inverse = {c: o for o, c in rename.items()}
        canon_scans = tuple(
            Relation(tuple(rename[v] for v in s.schema), s.cols, s.valid)
            for s in scans
        )
        shape = self._shape_for(
            prog, schemas, self._scan_caps(scans), rename
        )
        return canon_scans, shape, inverse

    def _scan_caps(
        self, scans: tuple[Relation, ...]
    ) -> tuple[int, ...]:
        """Scan capacities as the PlanShape records them (the sharded
        engine overrides this to report PER-SHARD buckets)."""
        return tuple(s.capacity for s in scans)

    def _device_consts(
        self, prog: _Program
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Device placement of the runtime-constant inputs (the sharded
        engine overrides this to replicate them over its mesh)."""
        return (
            jnp.asarray(prog.consts_i),
            jnp.asarray(prog.consts_f),
            self.store.numeric_values_device(),
        )

    def _caps_from_totals(self, totals: list[int]) -> tuple[int, ...]:
        """Join bucket capacities from the calibration run's exact totals
        (the sharded engine overrides this to size PER-SHARD buckets)."""
        return tuple(plan_ir.bucket_capacity(t) for t in totals)

    def _execute_compiled(
        self, prog: _Program, stats: ExecStats, trace=None
    ) -> Relation:
        with self.store.snapshot_lock():
            canon_scans, shape, inverse = self._canonicalize(prog)
            stats.store_version = self.store.version
        stats.n_joins = shape.n_joins()
        consts_i, consts_f, num_vals = self._device_consts(prog)

        entry = self.plan_cache.get(shape)
        if entry is not None and entry.num_cap not in (
            0,
            int(num_vals.shape[-1]),
        ):
            # dictionary growth crossed a pow-2 boundary since the entry
            # compiled (the numeric table is an input shape the executable
            # is specialised on): recompile at the same join caps
            entry = self._compile_entry(
                shape, entry.join_caps, canon_scans, prog, stats,
                trace=trace,
            )
        if entry is None:
            rel = self._compiled_cold(
                shape, canon_scans, prog, stats, trace
            )
        else:
            rel = self._compiled_warm(
                shape, entry, canon_scans, consts_i, consts_f, num_vals,
                stats, trace,
            )
        # back to the query's own variable names
        return Relation(
            tuple(inverse[v] for v in rel.schema), rel.cols, rel.valid
        )

    def _compiled_cold(
        self,
        shape: plan_ir.PlanShape,
        canon_scans: tuple[Relation, ...],
        prog: _Program,
        stats: ExecStats,
        trace=None,
    ) -> Relation:
        """Cache miss: the eager evaluator's count passes calibrate the join
        buckets; compile at those shapes; serve this query from the eager
        result (the compiled program takes over from the next query on).
        A shape with a saved warmup signature skips the calibration run and
        compiles straight at the persisted capacities."""
        stats.cache_misses += 1
        self.plan_cache.misses += 1
        warm_caps = self._warm_caps.get(shape)
        if warm_caps is not None and len(warm_caps) == shape.n_joins():
            entry = self._compile_entry(
                shape, warm_caps, canon_scans, prog, stats, trace=trace
            )
            return self._dispatch_entry(
                shape, entry, canon_scans, *self._device_consts(prog),
                stats, trace,
            )
        eager_stats = ExecStats()
        t0 = time.perf_counter()
        rel, totals = self._eval_shape_eager(
            shape, canon_scans, prog, eager_stats
        )
        if trace is not None:
            trace.add_span(
                "dispatch", t0, time.perf_counter(), calibration=True
            )
        stats.n_count_passes += eager_stats.n_count_passes
        stats.n_dispatches += eager_stats.n_dispatches
        stats.n_retries += eager_stats.n_retries
        stats.device_time_s += eager_stats.device_time_s
        stats.peak_capacity = max(
            stats.peak_capacity, eager_stats.peak_capacity
        )
        stats.peak_join_bucket = max(
            stats.peak_join_bucket, eager_stats.peak_join_bucket
        )
        join_caps = self._caps_from_totals(totals)
        stats.join_totals = tuple(totals)
        stats.join_worst = stats.join_totals
        stats.join_caps = join_caps
        self._compile_entry(
            shape, join_caps, canon_scans, prog, stats, trace=trace
        )
        return rel

    def _compiled_warm(
        self,
        shape: plan_ir.PlanShape,
        entry: PlanCacheEntry,
        canon_scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
        stats: ExecStats,
        trace=None,
    ) -> Relation:
        stats.cache_hits += 1
        self.plan_cache.hits += 1
        return self._dispatch_entry(
            shape, entry, canon_scans, consts_i, consts_f, num_vals,
            stats, trace,
        )

    def _dispatch_entry(
        self,
        shape: plan_ir.PlanShape,
        entry: PlanCacheEntry,
        canon_scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
        stats: ExecStats,
        trace=None,
    ) -> Relation:
        ovf_counts = [0] * shape.n_joins()
        while True:
            stats.n_dispatches += 1
            t0 = time.perf_counter()
            rel, totals, flags = entry.compiled(
                canon_scans, consts_i, consts_f, num_vals
            )
            stats.peak_capacity = max(
                stats.peak_capacity, entry.compiled.plan.max_capacity()
            )
            caps = entry.compiled.plan.join_caps
            stats.peak_join_bucket = max(
                stats.peak_join_bucket, max(caps) if caps else 0
            )
            flags_np = np.asarray(flags)  # the single host sync
            t1 = self._device_tick(stats, t0)
            if trace is not None:
                trace.add_span("dispatch", t0, t1)
            if not flags_np.any():
                stats.join_totals = tuple(
                    int(t) for t in np.asarray(totals)
                )
                stats.join_worst = stats.join_totals
                stats.join_caps = tuple(caps)
                stats.join_overflows = tuple(ovf_counts)
                return rel
            # bucket overflow: grow from the exact totals, recompile, retry
            stats.n_retries += 1
            for j, f in enumerate(flags_np):
                ovf_counts[j] += int(bool(f))
            new_caps = plan_ir.grow_join_caps(
                entry.join_caps,
                [int(t) for t in np.asarray(totals)],
                [bool(f) for f in flags_np],
            )
            if max(new_caps) > self.max_capacity:
                raise MemoryError(
                    f"join result exceeds {self.max_capacity}"
                )
            entry = self._compile_entry(
                shape, new_caps, canon_scans, None, stats, trace=trace
            )

    def _compile_entry(
        self,
        shape: plan_ir.PlanShape,
        join_caps: tuple[int, ...],
        canon_scans: tuple[Relation, ...],
        prog: _Program | None,
        stats: ExecStats,
        trace=None,
    ) -> PlanCacheEntry:
        t_compile = time.perf_counter()
        plan = plan_ir.build_plan(shape, join_caps)
        # the consts are signature templates here — only shapes/dtypes
        # matter to AOT lowering, and they are determined by the PlanShape
        n_i = shape.n_consts[0] + (2 if shape.has_slice else 0)
        n_f = shape.n_consts[1]
        consts_i = jnp.asarray(
            prog.consts_i if prog is not None else np.zeros(n_i, np.int32)
        )
        consts_f = jnp.asarray(
            prog.consts_f if prog is not None else np.zeros(n_f, np.float32)
        )
        compiled = ex.compile_plan(
            plan,
            canon_scans,
            consts_i,
            consts_f,
            self.store.numeric_values_device(),
            use_kernel=self.use_kernel,
        )
        stats.n_compiles += 1
        self.plan_cache.compiles += 1
        entry = PlanCacheEntry(
            shape,
            join_caps,
            compiled,
            warm_layouts=self._warm_layouts.get(shape, ()),
            num_cap=int(self.store.numeric_values_device().shape[-1]),
        )
        if prog is not None:
            # cold-compile path only: a regrow retry (prog=None) must not
            # pay vmap compiles for widths the next regrow would discard
            self._precompile_batched(entry, canon_scans, stats)
        self.plan_cache.put(shape, entry)
        if trace is not None:
            trace.add_span(
                "compile", t_compile, time.perf_counter(),
                n_joins=len(join_caps),
            )
        return entry

    def _precompile_batched(
        self,
        entry: PlanCacheEntry,
        canon_scans: tuple[Relation, ...],
        stats: ExecStats,
    ) -> None:
        """Compile stacked executables for the (width, scan-layout)
        signatures a previous process persisted (save_cache /
        warmup_path), so a restarted server's first micro-batch dispatches
        warm instead of paying the vmap compile. Abstract (shape/dtype)
        templates stand in for the batched inputs — no device data is
        staged here; broadcast scan positions keep their UNstacked
        template shapes."""
        width_cap = plan_ir.floor_pow2(self.max_batch_width)
        sds = jax.ShapeDtypeStruct
        for w, axes in entry.warm_layouts:
            key = (w, axes)
            if (
                key in entry.batched
                or w < 2
                or w > width_cap
                or len(axes) != len(canon_scans)
            ):
                continue
            scans_b = tuple(
                Relation(
                    s.schema,
                    sds(
                        ((w,) if ax == 0 else ()) + s.cols.shape,
                        s.cols.dtype,
                    ),
                    sds(
                        ((w,) if ax == 0 else ()) + s.valid.shape,
                        s.valid.dtype,
                    ),
                )
                for s, ax in zip(canon_scans, axes)
            )
            n_i = entry.shape.n_consts[0] + (
                2 if entry.shape.has_slice else 0
            )
            n_f = entry.shape.n_consts[1]
            try:
                entry.batched[key] = ex.compile_plan_batched(
                    entry.compiled.plan,
                    scans_b,
                    sds((w, n_i), jnp.int32),
                    sds((w, n_f), jnp.float32),
                    self.store.numeric_values_device(),
                    sds((w,), jnp.bool_),
                    use_kernel=self.use_kernel,
                    scan_axes=axes,
                )
            except Exception:
                continue  # a stale width must never fail a live query
            stats.n_compiles += 1
            self.plan_cache.compiles += 1

    # -- explain -----------------------------------------------------------
    def _explain_program(
        self, pq: PreparedQuery, prog: _Program, analyze: bool = False
    ) -> str:
        """Human-readable plan report: the logical algebra, the optimizer's
        pass-by-pass rewrite trace, the physical scan/join structure with
        estimated rows and pow-2 buckets, and the plan-cache state for
        this shape — all host-side (no device work). With `analyze`, the
        last run's per-join actuals (captured from the exact totals every
        dispatch returns) are appended beside the estimates."""
        est = self.store.estimate_cardinality
        lines = ["PreparedQuery", "logical algebra:"]
        lines.append(algebra.format_algebra(pq.query.algebra(), 1))
        lines.append(
            "optimizer trace (parse -> algebra -> optimize -> plan):"
        )
        for t in prog.plan.trace:
            lines.append(f"  {t}")
        lines.append("physical plan (scan order -> operator tree):")
        schemas: list[tuple[str, ...]] = []
        caps: list[int] = []
        n_req = len(prog.cross_flags) + 1 if prog.has_required else 0
        n_opt = sum(g.n_scans for g in prog.opt_groups)
        for i, tp in enumerate(prog.patterns):
            schema, _ = self.store.pattern_scan_info(tp)
            schemas.append(schema)
            caps.append(self.store.scan_capacity(tp))
            if i < n_req:
                kind = "required"
            elif i < n_req + n_opt:
                kind = "optional"
            else:
                kind = "union"
            lines.append(
                f"  scan[{i}] ({tp.s} {tp.p} {tp.o}) "
                f"est_rows={est(tp)} bucket={caps[-1]} [{kind}]"
            )
        rename = plan_ir.canonical_renaming(tuple(schemas))
        shape = self._shape_for(prog, tuple(schemas), tuple(caps), rename)
        ests = prog.plan.join_ests
        backends = shape.join_backends
        ji = 0

        def est_str() -> str:
            nonlocal ji
            out = (
                f" est_rows={int(ests[ji])}" if ji < len(ests) else ""
            )
            ji += 1
            return out

        def bk() -> str:
            """Physical algebra of the CURRENT join slot (pre-est_str)."""
            if ji < len(backends) and backends[ji] == "matrix":
                return "matrix_join"
            return "mr_join"

        for i, is_cross in enumerate(shape.cross_flags):
            kind = "cross_join" if is_cross else bk()
            lines.append(f"  join[{i}] {kind}{est_str()}")
        for gi, g in enumerate(shape.opt_groups):
            for _ in g.cross_flags:
                est_str()  # group-internal joins ride in the group line
            kind = bk()
            lines.append(
                f"  left_join[{gi}] ({kind}) OPTIONAL group of {g.n_scans} "
                f"pattern(s), unmatched rows padded UNBOUND,"
                f" inner{est_str()}"
            )
        for bi, g in enumerate(shape.union_groups):
            for _ in g.cross_flags:
                est_str()
            kind = bk()
            tail = est_str() if prog.has_required else ""
            lines.append(
                f"  union_branch[{bi}] {g.n_scans} pattern(s)"
                + (
                    f", joined with required chain ({kind}),{tail}"
                    if tail
                    else ""
                )
            )
        if shape.union_groups:
            lines.append(
                f"  union: concat {len(shape.union_groups)} branch(es), "
                "unbound columns padded UNBOUND"
            )
        for stage, expr in prog.plan.filters:
            lines.append(
                f"  filter: {expr} @ {optimizer._fmt_stage(stage)} "
                "(device-side mask)"
            )
        if shape.has_slice:
            q = pq.query
            limit = "-" if q.limit is None else q.limit
            lines.append(f"  slice: offset={q.offset} limit={limit}")
        entry = self.plan_cache.get(shape)
        if entry is None:
            lines.append(
                "cache: shape not compiled yet (first run calibrates "
                "buckets from exact counts, then compiles)"
            )
        else:
            lines.append(
                f"cache: compiled, join buckets={entry.join_caps}, "
                f"max_capacity={entry.compiled.plan.max_capacity()}"
            )
        lines.append(
            f"plan-cache: {len(self.plan_cache)} entries, "
            f"hit_rate={self.plan_cache.hit_rate:.0%}"
        )
        stale = pq.planned_version != self.store.version
        lines.append(
            f"store: version={self.store.version}, planned against "
            f"v{pq.planned_version}"
            + (
                " (stale: refresh() re-plans on current statistics; "
                "runs are snapshot-consistent either way)"
                if stale
                else ""
            )
        )
        lines.append(
            f"handle: {pq.n_runs} run(s)"
            + (
                f", last run: {pq.last_stats.n_dispatches} dispatch(es), "
                f"{pq.last_stats.n_compiles} compile(s)"
                if pq.last_stats
                else ""
            )
        )
        if analyze:
            lines.extend(self._analyze_lines(pq, prog, shape))
        return "\n".join(lines)

    # -- EXPLAIN ANALYZE ---------------------------------------------------
    def _join_slot_labels(
        self, shape: plan_ir.PlanShape, st: ExecStats
    ) -> list[str]:
        """Physical operator label per join slot, in the evaluation
        (totals) order — recovered from the plan tree by the same
        traversal the lowering uses, so labels line up with actuals."""
        n = len(st.join_totals)
        caps = st.join_caps if len(st.join_caps) == n else (0,) * n
        try:
            plan = plan_ir.build_plan(shape, tuple(caps))
            nodes = ex.join_slot_nodes(plan)
        except Exception:
            nodes = []
        labels = []
        for i in range(n):
            if i < len(nodes):
                node = nodes[i]
                kind = {
                    plan_ir.MRJoin: "mr_join",
                    plan_ir.MatrixJoin: "matrix_join",
                    plan_ir.CrossJoin: "cross_join",
                }.get(type(node))
                if kind is None and isinstance(node, plan_ir.LeftJoin):
                    kind = f"left_join[{node.backend}]"
                labels.append(kind or type(node).__name__.lower())
            else:
                labels.append("join")
        return labels

    def _analyze_slot_extra(self, st: ExecStats, i: int) -> str:
        """Per-slot suffix hook (the sharded engine adds worst-shard and
        shuffle pressure here)."""
        return ""

    def _analyze_tail(self, st: ExecStats) -> list[str]:
        """Run-summary hook after the per-slot lines."""
        return []

    def _analyze_lines(
        self, pq: PreparedQuery, prog: _Program, shape: plan_ir.PlanShape
    ) -> list[str]:
        st = pq.last_stats
        lines = ["EXPLAIN ANALYZE (last run):"]
        if st is None:
            lines.append("  no recorded run — execute the query first")
            return lines
        ests = prog.plan.join_ests
        if st.join_totals:
            labels = self._join_slot_labels(shape, st)
            for i, actual in enumerate(st.join_totals):
                est_v = int(ests[i]) if i < len(ests) else 0
                parts = [
                    f"  join[{i}] {labels[i]}",
                    f"est_rows={est_v}",
                    f"actual_rows={actual}",
                    f"q_error={optimizer.q_error(est_v, actual):.2f}",
                ]
                if i < len(st.join_caps):
                    cap = st.join_caps[i]
                    worst = (
                        st.join_worst[i]
                        if i < len(st.join_worst) else actual
                    )
                    parts.append(f"cap={cap}")
                    parts.append(
                        f"fill={worst / cap:.0%}" if cap else "fill=-"
                    )
                if i < len(st.join_overflows) and st.join_overflows[i]:
                    parts.append(f"overflows={st.join_overflows[i]}")
                lines.append(" ".join(parts) + self._analyze_slot_extra(st, i))
        elif st.n_joins:
            lines.append(
                "  actuals not captured for the last run "
                "(pre-observability execution path)"
            )
        else:
            lines.append("  no join nodes in this plan")
        lines.extend(self._analyze_tail(st))
        rows = st.rows_emitted if st.rows_emitted >= 0 else "-"
        lines.append(
            f"  run: {st.n_dispatches} dispatch(es), "
            f"{st.n_compiles} compile(s), {st.n_retries} retried, "
            f"batch_width={st.batch_width}, "
            f"device_time={st.device_time_s * 1e3:.2f}ms, "
            f"rows_emitted={rows}, store_version={st.store_version}"
        )
        return lines


@dataclasses.dataclass
class ShardedQueryEngine(QueryEngine):
    """Distributed MapSQ: the same engine over a subject-hash sharded store.

    `store` must be a sparql.sharded_store.ShardedTripleStore whose shard
    count equals the mesh size. Parsing, the algebra, the cost-based
    optimizer, the plan IR and the plan/compile cache are the single-device
    layers UNCHANGED; only three things differ:

      * scans come up as flat per-shard partitions (upload-once per shard)
        and the PlanShape's scan/join capacities are PER-SHARD buckets;
      * the compiled executable is core/dist_executor.py's one
        shard_map-wrapped dispatch — PARTITIONING-AWARE: a join input
        already hash-partitioned on the join key (subject-variable scans
        start that way) joins map-side with NO collective, a small
        misaligned side is broadcast (all_gather) instead of shuffling
        both, and only genuinely misaligned sides pay the hash shuffle;
        shuffles whose inputs are collective-free are issued ahead of the
        join chain so the interconnect overlaps the local joins;
      * overflow handling grows the worst SHARD's flagged bucket (join or
        shuffle — per mesh-axis stage) from the exact numbers that ride
        back with the dispatch, recompiles, and retries — the
        single-device discipline per shard.

    `mesh=None` builds a 1-axis mesh over every local device. Warm queries
    are exactly one dispatch and zero compiles, same as the base engine.
    """

    mesh: "jax.sharding.Mesh | None" = None
    axis_name: str = "shards"

    def __post_init__(self):
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.sparql.sharded_store import ShardedTripleStore

        # the distributed executor lowers both local-join algebras (MR and
        # masked-SpMM matrix), so the optimizer's per-slot backend picks —
        # and an engine-level override — pass straight through: shard-local
        # joins after a shuffle/elision are ordinary joins
        if self.mesh is None:
            self.mesh = jax.make_mesh(
                (jax.device_count(),), (self.axis_name,)
            )
        self.axis_names = tuple(self.mesh.axis_names)
        self.n_shards = 1
        for a in self.axis_names:
            self.n_shards *= self.mesh.shape[a]
        if not isinstance(self.store, ShardedTripleStore):
            raise TypeError(
                "ShardedQueryEngine needs a ShardedTripleStore "
                f"(got {type(self.store).__name__}); wrap a TripleStore "
                "with sparql.sharded_store.shard_store(store, n_shards)"
            )
        if self.store.n_shards != self.n_shards:
            raise ValueError(
                f"store has {self.store.n_shards} shards but the mesh has "
                f"{self.n_shards} devices"
            )
        if not self.compiled:
            raise ValueError(
                "sharded execution is compiled-only (compiled=True)"
            )
        super().__post_init__()
        # cross-shape padded stacking is single-device only: the sharded
        # stacked path lowers through shard_map with concrete row-sharded
        # scan buffers, which the padded entry's abstract-template compile
        # cannot reproduce — near-miss shapes stay per-shape groups here
        self.pad_stacking = False
        self._row_sharding = NamedSharding(self.mesh, P(self.axis_names))
        self._rep_sharding = NamedSharding(self.mesh, P())
        self.store.row_sharding = self._row_sharding
        self._num_vals_rep = None
        self._num_vals_src = None  # store table the replica was built from
        # shuffle bucket signatures persisted by a previous process (the
        # sharded extension of the warmup file; absent in older files)
        self._warm_shuffle: dict[plan_ir.PlanShape, tuple[int, ...]] = {}
        if self.warmup_path is not None:
            p = pathlib.Path(self.warmup_path)
            if p.exists():
                for e in json.loads(p.read_text())["entries"]:
                    sh = tuple(int(c) for c in e.get("shuffle_caps", ()))
                    if sh:
                        shape = plan_ir.shape_from_jsonable(e["shape"])
                        self._warm_shuffle[shape] = sh

    # -- device placement --------------------------------------------------
    def _replicated(self, arr) -> jax.Array:
        return jax.device_put(arr, self._rep_sharding)

    def _num_vals(self) -> jax.Array:
        # the store rebuilds its table when inserts grow the dictionary;
        # rebuild the mesh replica whenever the source array changes (an
        # identity check — the store caches one array object per build)
        base = self.store.numeric_values_device()
        if self._num_vals_rep is None or self._num_vals_src is not base:
            self._num_vals_src = base
            self._num_vals_rep = self._replicated(np.asarray(base))
        return self._num_vals_rep

    def _device_consts(self, prog: _Program):
        return (
            self._replicated(prog.consts_i),
            self._replicated(prog.consts_f),
            self._num_vals(),
        )

    # -- planning ----------------------------------------------------------
    def _scan_caps(
        self, scans: tuple[Relation, ...]
    ) -> tuple[int, ...]:
        """Capacities entering the PlanShape are the PER-SHARD row
        buckets (the flat scan buffer holds n_shards equal blocks, so
        its per-shard slice is capacity // n_shards)."""
        return tuple(s.capacity // self.n_shards for s in scans)

    def _scan_parts(
        self,
        prog: _Program,
        schemas: tuple[tuple[str, ...], ...],
    ) -> tuple[int, ...]:
        """The store shards rows by subject hash — the SAME FNV-1a route
        the shuffle uses — so a subject-VARIABLE scan arrives already
        hash-partitioned on that column; the lowering elides every
        shuffle this placement satisfies. A constant subject pins all
        matches to one shard (not a hash placement of any variable)."""
        return tuple(
            schema.index(tp.s) if tp.s.startswith("?") else -1
            for tp, schema in zip(prog.patterns, schemas)
        )

    def _axis_sizes(self) -> tuple[int, ...]:
        return tuple(self.mesh.shape[a] for a in self.axis_names)

    def _caps_from_totals(self, totals: list[int]) -> tuple[int, ...]:
        """Per-shard join buckets from the calibration run's exact GLOBAL
        totals: the uniform-hash share, pow-2 bucketed. Key skew shows up
        as an overflow on the first dispatch and regrows from the worst
        shard's exact total."""
        return tuple(
            plan_ir.bucket_capacity(max(1, -(-int(t) // self.n_shards)))
            for t in totals
        )

    # -- compiled path -----------------------------------------------------
    def _compiled_cold(
        self,
        shape: plan_ir.PlanShape,
        canon_scans: tuple[Relation, ...],
        prog: _Program,
        stats: ExecStats,
        trace=None,
    ) -> Relation:
        """Cache miss: calibrate GLOBAL join totals with the eager
        evaluator (the flat scan buffer is a valid single-device relation,
        so the count passes are exact), size per-shard buckets at the
        uniform-hash share, then DISPATCH once — unlike the base engine,
        the cold query is served from the mesh so any hash-skew overflow
        regrows now and warm queries stay at one dispatch, zero compiles."""
        stats.cache_misses += 1
        self.plan_cache.misses += 1
        warm_caps = self._warm_caps.get(shape)
        if warm_caps is not None and len(warm_caps) == shape.n_joins():
            entry = self._compile_entry(
                shape, warm_caps, canon_scans, prog, stats, trace=trace
            )
        else:
            eager_stats = ExecStats()
            t0 = time.perf_counter()
            _, totals = self._eval_shape_eager(
                shape, canon_scans, prog, eager_stats
            )
            if trace is not None:
                trace.add_span(
                    "dispatch", t0, time.perf_counter(), calibration=True
                )
            stats.n_count_passes += eager_stats.n_count_passes
            stats.n_dispatches += eager_stats.n_dispatches
            stats.n_retries += eager_stats.n_retries
            stats.device_time_s += eager_stats.device_time_s
            entry = self._compile_entry(
                shape, self._caps_from_totals(totals), canon_scans, prog,
                stats, trace=trace,
            )
        return self._dispatch_entry(
            shape, entry, canon_scans, *self._device_consts(prog), stats,
            trace,
        )

    def _compile_entry(
        self,
        shape: plan_ir.PlanShape,
        join_caps: tuple[int, ...],
        canon_scans: tuple[Relation, ...],
        prog: "_Program | None",
        stats: ExecStats,
        trace=None,
        shuffle_caps: "tuple[int, ...] | None" = None,
    ) -> PlanCacheEntry:
        from repro.core import dist_executor as dx

        t_compile = time.perf_counter()
        plan = plan_ir.build_plan(shape, join_caps)
        # one shuffle slot per site per mesh-axis stage (stages of a
        # hierarchical shuffle size and regrow independently); warmup
        # files from before the per-stage split carry the wrong length
        # and fall through to fresh estimates
        n_slots = dx.n_shuffle_slots(plan, len(self.axis_names))
        if shuffle_caps is None:
            prev = self.plan_cache.get(shape)
            if prev is not None and len(
                prev.compiled.shuffle_caps
            ) == n_slots:
                shuffle_caps = prev.compiled.shuffle_caps
            else:
                shuffle_caps = self._warm_shuffle.get(shape)
        if shuffle_caps is None or len(shuffle_caps) != n_slots:
            shuffle_caps = dx.initial_shuffle_caps(plan, self._axis_sizes())
        n_i = shape.n_consts[0] + (2 if shape.has_slice else 0)
        n_f = shape.n_consts[1]
        consts_i = self._replicated(
            prog.consts_i if prog is not None else np.zeros(n_i, np.int32)
        )
        consts_f = self._replicated(
            prog.consts_f if prog is not None else np.zeros(n_f, np.float32)
        )
        compiled = dx.compile_sharded_plan(
            plan,
            self.mesh,
            self.axis_names,
            shuffle_caps,
            canon_scans,
            consts_i,
            consts_f,
            self._num_vals(),
            use_kernel=self.use_kernel,
        )
        stats.n_compiles += 1
        self.plan_cache.compiles += 1
        entry = PlanCacheEntry(
            shape,
            join_caps,
            compiled,
            num_cap=int(self._num_vals().shape[-1]),
        )
        self.plan_cache.put(shape, entry)
        if trace is not None:
            trace.add_span(
                "compile", t_compile, time.perf_counter(),
                n_joins=len(join_caps), sharded=True,
            )
        return entry

    def _dispatch_entry(
        self,
        shape: plan_ir.PlanShape,
        entry: PlanCacheEntry,
        canon_scans: tuple[Relation, ...],
        consts_i: jax.Array,
        consts_f: jax.Array,
        num_vals: jax.Array,
        stats: ExecStats,
        trace=None,
    ) -> Relation:
        ovf_counts = [0] * shape.n_joins()
        while True:
            stats.n_dispatches += 1
            self._count_shuffles(entry, stats)
            t0 = time.perf_counter()
            res = entry.compiled(canon_scans, consts_i, consts_f, num_vals)
            caps = entry.compiled.plan.join_caps
            stats.peak_capacity = max(
                stats.peak_capacity, entry.compiled.plan.max_capacity()
            )
            stats.peak_join_bucket = max(
                stats.peak_join_bucket, max(caps) if caps else 0
            )
            # the single host sync: join AND shuffle flags, all shards
            flags_np = np.asarray(res.overflows)
            sh_flags_np = np.asarray(res.shuffle_flags)
            t1 = self._device_tick(stats, t0)
            if trace is not None:
                trace.add_span(
                    "dispatch", t0, t1, n_shards=self.n_shards
                )
            if not flags_np.any() and not sh_flags_np.any():
                # totals are (n_shards, n_joins): the analyze view wants
                # the global rows AND the worst shard (fill pressure is a
                # per-shard property under hash skew)
                totals_np = np.asarray(res.totals)
                needs_np = np.asarray(res.shuffle_needs)
                stats.join_totals = tuple(
                    int(x) for x in totals_np.sum(axis=0)
                )
                stats.join_worst = tuple(
                    int(x) for x in totals_np.max(axis=0)
                )
                stats.join_caps = tuple(caps)
                stats.join_overflows = tuple(ovf_counts)
                if needs_np.size:
                    stats.shuffle_loads = tuple(
                        int(x) for x in needs_np.max(axis=0)
                    )
                return res.relation
            # a bucket overflowed on some shard: grow the flagged ones
            # from the worst shard's exact numbers, recompile, retry
            stats.n_retries += 1
            totals_np = np.asarray(res.totals)
            needs_np = np.asarray(res.shuffle_needs)
            n_j = flags_np.shape[1]
            n_s = sh_flags_np.shape[1]  # (site x mesh-axis stage) slots
            for j in range(n_j):
                ovf_counts[j] += int(bool(flags_np[:, j].any()))
            new_caps = plan_ir.grow_join_caps(
                entry.join_caps,
                [int(totals_np[:, j].max()) for j in range(n_j)],
                [bool(flags_np[:, j].any()) for j in range(n_j)],
            )
            new_shuffle = plan_ir.grow_join_caps(
                entry.compiled.shuffle_caps,
                [int(needs_np[:, j].max()) for j in range(n_s)],
                [bool(sh_flags_np[:, j].any()) for j in range(n_s)],
            )
            if max(new_caps + new_shuffle) > self.max_capacity:
                raise MemoryError(
                    f"join result exceeds {self.max_capacity}"
                )
            entry = self._compile_entry(
                shape, new_caps, canon_scans, None, stats, trace=trace,
                shuffle_caps=new_shuffle,
            )

    def _count_shuffles(self, entry: PlanCacheEntry, stats: ExecStats):
        """Fold the compiled program's static data-movement choices into
        the run's stats, once per mesh dispatch."""
        from repro.core import dist_executor as dx

        cnt = dx.strategy_counts(entry.compiled.strategies)
        stats.n_shuffles_emitted += cnt["emitted"]
        stats.n_shuffles_elided += cnt["elided"]
        stats.n_broadcast_joins += cnt["broadcast"]

    # -- batching ----------------------------------------------------------
    def _run_chunk_stacked(
        self,
        shape: plan_ir.PlanShape,
        chunk: list[int],
        ctxs: list["_BatchCtx | None"],
        prepared: list[PreparedQuery],
        out: list,
        group: BatchGroupStats,
        defer: bool = False,
        traces: "list | None" = None,
    ) -> None:
        """ONE stacked mesh dispatch (lanes x shards) for a chunk of warm
        same-shape queries — the distributed mirror of the base engine's
        stacked path: the per-shard program is vmapped over lanes inside
        shard_map, so a micro-batch's shuffles/joins for every lane ride
        one launch. Grouping, chunking, deferred decode and the
        sequential-fallback safety net are the inherited run_batch
        machinery (cross-shape padding stays disabled here, so `shape` is
        always every lane's natural signature)."""
        from repro.core import dist_executor as dx

        entry = self.plan_cache.get(shape)
        n = len(chunk)
        width = plan_ir.bucket_width(n, self.max_batch_width)
        lanes = [ctxs[i] for i in chunk] + [ctxs[chunk[0]]] * (width - n)
        # per scan position: identical pattern across lanes -> ship the
        # row-sharded buffer once (vmap broadcasts it); else a stacked
        # (width, n_shards * cap) buffer — the mesh splits rows (dim 1),
        # vmap splits lanes (dim 0)
        scans_b: list[Relation] = []
        axes: list[int | None] = []
        with self.store.snapshot_lock():  # one store version per chunk
            for j in range(len(shape.scan_schemas)):
                tps = tuple(c.prog.patterns[j] for c in lanes)
                if len({self.store._scan_key(tp) for tp in tps}) == 1:
                    rel = self.store.match_pattern_device(tps[0])
                    scans_b.append(
                        Relation(shape.scan_schemas[j], rel.cols, rel.valid)
                    )
                    axes.append(None)
                else:
                    scans_b.append(
                        Relation(
                            shape.scan_schemas[j],
                            *self.store.stacked_scan_device(tps),
                        )
                    )
                    axes.append(0)
            staged_version = self.store.version
        scans_b = tuple(scans_b)
        scan_axes = tuple(axes)
        group.n_broadcast_scans += sum(1 for a in scan_axes if a is None)
        consts_i = self._replicated(
            np.stack([c.prog.consts_i for c in lanes])
        )
        consts_f = self._replicated(
            np.stack([c.prog.consts_f for c in lanes])
        )
        active = self._replicated(np.arange(width) < n)
        num_vals = self._num_vals()
        stats = ExecStats(
            n_joins=shape.n_joins(),
            cache_hits=1,
            batch_width=width,
            store_version=staged_version,
        )
        self.plan_cache.hits += n
        if entry.num_cap not in (0, int(num_vals.shape[-1])):
            template_scans, _, _ = self._canonicalize(lanes[0].prog)
            entry = self._compile_entry(
                shape, entry.join_caps, template_scans, None, stats
            )
        events: list[tuple[str, float, float]] = []
        ovf_counts = [0] * shape.n_joins()
        try:
            while True:
                bexec = entry.batched.get((width, scan_axes))
                if bexec is None:
                    tc0 = time.perf_counter()
                    bexec = dx.compile_sharded_plan_batched(
                        entry.compiled.plan,
                        self.mesh,
                        self.axis_names,
                        entry.compiled.shuffle_caps,
                        scans_b,
                        consts_i,
                        consts_f,
                        num_vals,
                        active,
                        scan_axes,
                        use_kernel=self.use_kernel,
                    )
                    events.append(("compile", tc0, time.perf_counter()))
                    entry.batched[(width, scan_axes)] = bexec
                    stats.n_compiles += 1
                    self.plan_cache.compiles += 1
                stats.n_dispatches += 1
                self._count_shuffles(entry, stats)
                t0 = time.perf_counter()
                res = bexec(scans_b, consts_i, consts_f, num_vals, active)
                # the single host sync: join AND shuffle flags, every
                # (lane, shard) pair
                flags_np = np.asarray(res.overflows)
                sh_flags_np = np.asarray(res.shuffle_flags)
                events.append(
                    ("dispatch", t0, self._device_tick(stats, t0))
                )
                if not flags_np.any() and not sh_flags_np.any():
                    break
                # a bucket overflowed in some lane on some shard: grow the
                # flagged ones to the worst (lane, shard)'s exact numbers,
                # recompile (solo entry + this width), retry the chunk
                stats.n_retries += 1
                totals_np = np.asarray(res.totals)
                needs_np = np.asarray(res.shuffle_needs)
                n_j = flags_np.shape[-1]
                n_s = sh_flags_np.shape[-1]
                for j in range(n_j):
                    ovf_counts[j] += int(bool(flags_np[..., j].any()))
                new_caps = plan_ir.grow_join_caps(
                    entry.join_caps,
                    [int(totals_np[..., j].max()) for j in range(n_j)],
                    [bool(flags_np[..., j].any()) for j in range(n_j)],
                )
                new_shuffle = plan_ir.grow_join_caps(
                    entry.compiled.shuffle_caps,
                    [int(needs_np[..., j].max()) for j in range(n_s)],
                    [bool(sh_flags_np[..., j].any()) for j in range(n_s)],
                )
                if max(new_caps + new_shuffle) > self.max_capacity:
                    raise MemoryError(
                        f"join result exceeds {self.max_capacity}"
                    )
                template_scans, _, _ = self._canonicalize(lanes[0].prog)
                entry = self._compile_entry(
                    shape, new_caps, template_scans, None, stats,
                    shuffle_caps=new_shuffle,
                )
        finally:
            group.n_dispatches += stats.n_dispatches
            group.n_compiles += stats.n_compiles
        group.widths = group.widths + (width,)
        self.stacked_dispatches += stats.n_dispatches
        self.batch_width_hist[width] = (
            self.batch_width_hist.get(width, 0) + stats.n_dispatches
        )
        self.stacked_queries += n
        caps = entry.compiled.plan.join_caps
        stats.peak_join_bucket = max(caps) if caps else 0
        stats.peak_capacity = entry.compiled.plan.max_capacity()
        stats.join_caps = tuple(caps)
        stats.join_overflows = tuple(ovf_counts)
        needs_np = np.asarray(res.shuffle_needs)
        if needs_np.size:
            # (width, n_shards, n_slots) -> worst shard over every lane
            stats.shuffle_loads = tuple(
                int(x) for x in needs_np.max(axis=(0, 1))
            )
        self._emit_chunk_results(
            res.relation, chunk, ctxs, prepared, out, stats, defer,
            lane_totals=self._chunk_lane_totals(res.totals),
            traces=traces, events=events,
        )

    def _chunk_lane_totals(self, totals_b) -> tuple[np.ndarray, np.ndarray]:
        # batched sharded totals are (width, n_shards, n_joins): per-lane
        # global rows sum over shards, fill pressure is the worst shard
        t = np.asarray(totals_b)
        return t.sum(axis=1), t.max(axis=1)

    # -- persistence -------------------------------------------------------
    def _entry_jsonable(self, e: PlanCacheEntry) -> dict:
        """Base signature plus the entry's shuffle bucket caps, so a
        restarted sharded server compiles warm shapes with zero
        shuffle-overflow retries too."""
        d = super()._entry_jsonable(e)
        d["shuffle_caps"] = list(e.compiled.shuffle_caps)
        return d

    # -- explain -----------------------------------------------------------
    def _analyze_slot_extra(self, st: ExecStats, i: int) -> str:
        if i < len(st.join_worst):
            return f" worst_shard_rows={st.join_worst[i]}"
        return ""

    def _analyze_tail(self, st: ExecStats) -> list[str]:
        lines = []
        if st.shuffle_loads:
            lines.append(
                "  shuffle slots worst-shard rows="
                f"{list(st.shuffle_loads)}"
            )
        lines.append(
            f"  data movement: {st.n_shuffles_emitted} shuffle(s) "
            f"emitted, {st.n_shuffles_elided} elided, "
            f"{st.n_broadcast_joins} broadcast join(s)"
        )
        return lines

    def _explain_program(
        self, pq: PreparedQuery, prog: _Program, analyze: bool = False
    ) -> str:
        lines = [super()._explain_program(pq, prog, analyze=analyze)]
        lines.append(
            f"sharded: {self.n_shards} shard(s), mesh axes "
            f"{list(self.axis_names)}, subject-hash partitioned scans"
        )
        schemas: list[tuple[str, ...]] = []
        caps: list[int] = []
        for i, tp in enumerate(prog.patterns):
            counts = self.store.per_shard_counts(tp)
            schema, _ = self.store.pattern_scan_info(tp)
            schemas.append(schema)
            caps.append(self.store.scan_capacity(tp))
            lines.append(
                f"  scan[{i}] per-shard rows={counts} "
                f"per-shard bucket={caps[-1]}"
            )
        rename = plan_ir.canonical_renaming(tuple(schemas))
        shape = self._shape_for(prog, tuple(schemas), tuple(caps), rename)
        entry = self.plan_cache.get(shape)
        if entry is not None:
            lines.append(
                f"  per-shard join buckets={entry.join_caps}, "
                f"shuffle buckets={entry.compiled.shuffle_caps}"
            )
            strategies = entry.compiled.strategies
        else:
            # not compiled yet: derive the strategies the lowering WILL
            # choose (pure static analysis over the would-be plan)
            from repro.core import dist_executor as dx

            plan = plan_ir.build_plan(
                shape, (plan_ir.MIN_BUCKET,) * shape.n_joins()
            )
            strategies = dx.analyze_plan(plan, self.n_shards)
        from repro.core import dist_executor as dx

        for i, st in enumerate(strategies):
            lines.append(f"  shuffle[{i}] {st.op}: {dx.format_strategy(st)}")
        cnt = dx.strategy_counts(strategies)
        lines.append(
            f"  shuffles: {cnt['emitted']} emitted, {cnt['elided']} "
            f"elided, {cnt['broadcast']} broadcast join(s)"
        )
        return "\n".join(lines)
