"""The MapSQ query engine (Figure 1 of the paper).

Coprocessing split, exactly as the paper describes it:
  CPU  — parse, dictionary-encode, plan join order, size capacities,
         dispatch subqueries (this file, host Python);
  GPU→TPU — pattern range-scans feed the MapReduce join (Algorithm 1,
         core/mr_join.py, jitted).

Dynamic result sizes use the Mars two-pass discipline: a jitted COUNT pass
returns the exact cardinality of the next join; the host allocates the
exactly-sized (next-pow2) buffer and runs the jitted EXPAND pass. On
overflow (capacity hints disabled) the engine doubles and retries.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax

from repro.core import mr_join as mj
from repro.core.planner import TriplePattern, plan_bgp
from repro.core.relation import Relation
from repro.sparql.parser import Query, parse
from repro.sparql.store import TripleStore, _next_pow2


@dataclasses.dataclass
class ExecStats:
    n_joins: int = 0
    n_count_passes: int = 0
    n_retries: int = 0
    peak_capacity: int = 0


@dataclasses.dataclass
class QueryEngine:
    store: TripleStore
    use_kernel: bool = False  # Pallas pair-expand in the join
    exact_count_pass: bool = True  # Mars two-pass vs double-on-overflow
    max_capacity: int = 1 << 24

    def __post_init__(self):
        self._jit_join = jax.jit(
            mj.mr_join, static_argnames=("capacity", "use_kernel")
        )
        self._jit_count = jax.jit(mj.mr_join_count)
        self._jit_cross = jax.jit(mj.cross_join, static_argnames=("capacity",))

    # -- public API --------------------------------------------------------
    def query(self, text: str) -> list[dict[str, str]]:
        """Parse, execute, decode: rows as {var: term} dicts."""
        q = parse(text)
        rel, stats = self.execute(q)
        rel = rel.project(q.projection())
        rows = rel.to_numpy()
        if q.distinct:
            rows = np.unique(rows, axis=0)
        d = self.store.dictionary
        return [
            {v: d.decode(int(t)) for v, t in zip(rel.schema, row)}
            for row in rows
        ]

    def execute(self, q: Query) -> tuple[Relation, ExecStats]:
        """Run the BGP: partial matching then the MapReduce-join chain."""
        stats = ExecStats()
        steps = plan_bgp(q.patterns, self.store.estimate_cardinality)
        # partial matching (the paper's step 1; gStore-equivalent scans)
        partials = [
            self.store.match_pattern(q.patterns[st.pattern_index])
            for st in steps
        ]
        acc = partials[0]
        for st, nxt in zip(steps[1:], partials[1:]):
            acc = self._join_once(acc, nxt, st.is_cross, stats)
        return acc, stats

    # -- internals ---------------------------------------------------------
    def _join_once(self, left: Relation, right: Relation, is_cross: bool,
                   stats: ExecStats) -> Relation:
        stats.n_joins += 1
        if is_cross:
            cap = max(1, _next_pow2(left.capacity * right.capacity))
            out, total, overflow = self._jit_cross(left, right, capacity=cap)
            assert not bool(overflow)
            stats.peak_capacity = max(stats.peak_capacity, cap)
            return mj.compact(out)
        if self.exact_count_pass:
            total = int(self._jit_count(left, right))
            stats.n_count_passes += 1
            cap = max(1, _next_pow2(total))
            out, _, overflow = self._jit_join(
                left, right, capacity=cap, use_kernel=self.use_kernel
            )
            assert not bool(overflow)
            stats.peak_capacity = max(stats.peak_capacity, cap)
            return out
        cap = max(left.capacity, right.capacity)
        while True:
            out, total, overflow = self._jit_join(
                left, right, capacity=cap, use_kernel=self.use_kernel
            )
            stats.peak_capacity = max(stats.peak_capacity, cap)
            if not bool(overflow):
                return out
            stats.n_retries += 1
            cap *= 2
            if cap > self.max_capacity:
                raise MemoryError(f"join result exceeds {self.max_capacity}")

    def explain(self, text: str) -> list[dict[str, Any]]:
        q = parse(text)
        steps = plan_bgp(q.patterns, self.store.estimate_cardinality)
        return [
            {
                "pattern": dataclasses.astuple(q.patterns[st.pattern_index]),
                "est_rows": self.store.estimate_cardinality(
                    q.patterns[st.pattern_index]
                ),
                "join_vars": st.key_vars,
                "cross": st.is_cross,
            }
            for st in steps
        ]
