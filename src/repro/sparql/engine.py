"""The MapSQ query engine (Figure 1 of the paper).

Coprocessing split, exactly as the paper describes it:
  CPU  — parse, dictionary-encode, plan join order, size capacities,
         dispatch subqueries (this file, host Python);
  GPU→TPU — pattern range-scans feed the MapReduce join (Algorithm 1,
         core/mr_join.py, jitted).

Two execution modes share one planner:

  compiled (default) — parse → plan → plan-cache lookup → ONE device
      dispatch. The whole join chain (plus projection and DISTINCT) is
      lowered by core/executor.py into a single AOT-compiled program,
      cached by (plan shape, bucket signature) in a PlanCache. A cache
      miss first runs the eager chain once: its Mars count passes double
      as the capacity *calibration* that picks the pow-2 join buckets the
      program is compiled at. Warm queries then run with zero compiles,
      no per-join host sync (the only sync reads the overflow flags that
      ride back with the results), and upload-once device scans from
      TripleStore.match_pattern_device. If a bucket overflows (a
      same-shape query with a bigger result), the engine grows the bucket
      from the exact totals returned by the dispatch and recompiles —
      the double-on-overflow retry demoted to a host-level fallback.

  eager (compiled=False) — the original loop, kept for differential
      testing: per join, a jitted COUNT pass, host sync of the
      cardinality, exactly-sized (next-pow2) buffer, jitted EXPAND pass;
      or double-on-overflow when exact_count_pass=False.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any

import numpy as np

import jax

from repro.core import executor as ex
from repro.core import mr_join as mj
from repro.core import plan_ir
from repro.core.planner import JoinStep, plan_bgp
from repro.core.relation import Relation
from repro.sparql.parser import Query, parse
from repro.sparql.store import TripleStore, _next_pow2


@dataclasses.dataclass
class ExecStats:
    n_joins: int = 0
    n_count_passes: int = 0
    n_retries: int = 0
    peak_capacity: int = 0
    # compiled-pipeline accounting
    cache_hits: int = 0
    cache_misses: int = 0
    n_compiles: int = 0  # XLA compilations triggered by this query
    n_dispatches: int = 0  # device program launches (warm target: 1)


@dataclasses.dataclass
class PlanCacheEntry:
    shape: plan_ir.PlanShape
    join_caps: tuple[int, ...]
    compiled: ex.CompiledPlan


class PlanCache:
    """(plan shape, bucket signature) -> compiled executable, FIFO-bounded."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: OrderedDict[plan_ir.PlanShape, PlanCacheEntry] = (
            OrderedDict()
        )
        self.hits = 0
        self.misses = 0
        self.compiles = 0

    def get(self, shape: plan_ir.PlanShape) -> PlanCacheEntry | None:
        return self._entries.get(shape)

    def put(self, shape: plan_ir.PlanShape, entry: PlanCacheEntry) -> None:
        self._entries[shape] = entry
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "compiles": self.compiles,
            "entries": len(self._entries),
            "hit_rate": self.hit_rate,
        }


@dataclasses.dataclass
class QueryEngine:
    store: TripleStore
    use_kernel: bool = False  # Pallas pair-expand in the join
    exact_count_pass: bool = True  # Mars two-pass vs double-on-overflow
    max_capacity: int = 1 << 24
    compiled: bool = True  # one-dispatch compiled pipeline vs eager loop
    plan_cache_entries: int = 256

    def __post_init__(self):
        self._jit_join = jax.jit(
            mj.mr_join, static_argnames=("capacity", "use_kernel")
        )
        self._jit_count = jax.jit(mj.mr_join_count)
        self._jit_cross = jax.jit(mj.cross_join, static_argnames=("capacity",))
        self.plan_cache = PlanCache(self.plan_cache_entries)

    # -- public API --------------------------------------------------------
    def query(self, text: str) -> list[dict[str, str]]:
        """Parse, execute, decode: rows as {var: term} dicts."""
        q = parse(text)
        rel, _ = self.execute(q)
        rows = rel.to_numpy()
        d = self.store.dictionary
        return [
            {v: d.decode(int(t)) for v, t in zip(rel.schema, row)}
            for row in rows
        ]

    def execute(self, q: Query) -> tuple[Relation, ExecStats]:
        """Run the BGP; the result is projected (and DISTINCT-deduplicated,
        device-side) per the query."""
        stats = ExecStats()
        steps = plan_bgp(q.patterns, self.store.estimate_cardinality)
        if self.compiled:
            rel = self._execute_compiled(q, steps, stats)
        else:
            rel = self._execute_eager(q, steps, stats)
        return rel, stats

    def cache_stats(self) -> dict:
        return self.plan_cache.stats()

    # -- eager path --------------------------------------------------------
    def _execute_eager(
        self, q: Query, steps: list[JoinStep], stats: ExecStats
    ) -> Relation:
        partials = [
            self.store.match_pattern(q.patterns[st.pattern_index])
            for st in steps
        ]
        acc, _ = self._run_chain_eager(
            partials, [st.is_cross for st in steps[1:]], stats
        )
        acc = acc.project(q.projection())
        if q.distinct:
            acc = mj.distinct(acc)  # device-side dedup before decode
        return acc

    def _run_chain_eager(
        self,
        partials: list[Relation],
        cross_flags: list[bool],
        stats: ExecStats,
    ) -> tuple[Relation, list[int]]:
        """The per-join loop. Returns the result and each join's exact total
        (the totals are what the compiled path calibrates its buckets on)."""
        acc = partials[0]
        totals: list[int] = []
        for nxt, is_cross in zip(partials[1:], cross_flags):
            acc, total = self._join_once(acc, nxt, is_cross, stats)
            totals.append(total)
        return acc, totals

    def _join_once(
        self, left: Relation, right: Relation, is_cross: bool, stats: ExecStats
    ) -> tuple[Relation, int]:
        stats.n_joins += 1
        if is_cross:
            cap = max(1, _next_pow2(left.capacity * right.capacity))
            stats.n_dispatches += 1
            out, total, overflow = self._jit_cross(left, right, capacity=cap)
            assert not bool(overflow)
            stats.peak_capacity = max(stats.peak_capacity, cap)
            return mj.compact(out), int(total)
        if self.exact_count_pass:
            stats.n_dispatches += 1
            total = int(self._jit_count(left, right))
            stats.n_count_passes += 1
            cap = max(1, _next_pow2(total))
            stats.n_dispatches += 1
            out, _, overflow = self._jit_join(
                left, right, capacity=cap, use_kernel=self.use_kernel
            )
            assert not bool(overflow)
            stats.peak_capacity = max(stats.peak_capacity, cap)
            return out, total
        cap = max(left.capacity, right.capacity)
        while True:
            stats.n_dispatches += 1
            out, total, overflow = self._jit_join(
                left, right, capacity=cap, use_kernel=self.use_kernel
            )
            stats.peak_capacity = max(stats.peak_capacity, cap)
            if not bool(overflow):
                return out, int(total)
            stats.n_retries += 1
            cap *= 2
            if cap > self.max_capacity:
                raise MemoryError(f"join result exceeds {self.max_capacity}")

    # -- compiled path -----------------------------------------------------
    def _execute_compiled(
        self, q: Query, steps: list[JoinStep], stats: ExecStats
    ) -> Relation:
        patterns = [q.patterns[st.pattern_index] for st in steps]
        cross_flags = tuple(st.is_cross for st in steps[1:])
        # upload-once device scans (bucketed pow-2 capacities)
        scans = tuple(self.store.match_pattern_device(tp) for tp in patterns)
        # canonicalise variable names so structurally-equal queries share
        # one compiled program (constants live in the scan data, not here)
        schemas = tuple(s.schema for s in scans)
        rename = plan_ir.canonical_renaming(schemas)
        inverse = {c: o for o, c in rename.items()}
        canon_scans = tuple(
            Relation(tuple(rename[v] for v in s.schema), s.cols, s.valid)
            for s in scans
        )
        shape = plan_ir.make_shape(
            tuple(s.schema for s in canon_scans),
            tuple(s.capacity for s in canon_scans),
            cross_flags,
            tuple(rename[v] for v in q.projection()),
            q.distinct,
        )
        stats.n_joins = len(cross_flags)

        entry = self.plan_cache.get(shape)
        if entry is None:
            rel = self._compiled_cold(shape, canon_scans, cross_flags, stats)
        else:
            rel = self._compiled_warm(shape, entry, canon_scans, stats)
        # back to the query's own variable names
        return Relation(
            tuple(inverse[v] for v in rel.schema), rel.cols, rel.valid
        )

    def _compiled_cold(
        self,
        shape: plan_ir.PlanShape,
        canon_scans: tuple[Relation, ...],
        cross_flags: tuple[bool, ...],
        stats: ExecStats,
    ) -> Relation:
        """Cache miss: the eager chain's count passes calibrate the join
        buckets; compile at those shapes; serve this query from the eager
        result (the compiled program takes over from the next query on)."""
        stats.cache_misses += 1
        self.plan_cache.misses += 1
        eager_stats = ExecStats()
        acc, totals = self._run_chain_eager(
            list(canon_scans), list(cross_flags), eager_stats
        )
        stats.n_count_passes += eager_stats.n_count_passes
        stats.n_dispatches += eager_stats.n_dispatches
        stats.n_retries += eager_stats.n_retries
        join_caps = tuple(plan_ir.bucket_capacity(t) for t in totals)
        self._compile_entry(shape, join_caps, canon_scans, stats)
        acc = acc.project(list(shape.projection))
        if shape.distinct:
            acc = mj.distinct(acc)
        return acc

    def _compiled_warm(
        self,
        shape: plan_ir.PlanShape,
        entry: PlanCacheEntry,
        canon_scans: tuple[Relation, ...],
        stats: ExecStats,
    ) -> Relation:
        stats.cache_hits += 1
        self.plan_cache.hits += 1
        while True:
            stats.n_dispatches += 1
            rel, totals, flags = entry.compiled(canon_scans)
            stats.peak_capacity = max(
                stats.peak_capacity, entry.compiled.plan.max_capacity()
            )
            flags_np = np.asarray(flags)  # the single host sync
            if not flags_np.any():
                return rel
            # bucket overflow: grow from the exact totals, recompile, retry
            stats.n_retries += 1
            new_caps = plan_ir.grow_join_caps(
                entry.join_caps,
                [int(t) for t in np.asarray(totals)],
                [bool(f) for f in flags_np],
            )
            if max(new_caps) > self.max_capacity:
                raise MemoryError(
                    f"join result exceeds {self.max_capacity}"
                )
            entry = self._compile_entry(shape, new_caps, canon_scans, stats)

    def _compile_entry(
        self,
        shape: plan_ir.PlanShape,
        join_caps: tuple[int, ...],
        canon_scans: tuple[Relation, ...],
        stats: ExecStats,
    ) -> PlanCacheEntry:
        plan = plan_ir.build_plan(shape, join_caps)
        compiled = ex.compile_plan(
            plan, canon_scans, use_kernel=self.use_kernel
        )
        stats.n_compiles += 1
        self.plan_cache.compiles += 1
        entry = PlanCacheEntry(shape, join_caps, compiled)
        self.plan_cache.put(shape, entry)
        return entry

    def explain(self, text: str) -> list[dict[str, Any]]:
        q = parse(text)
        steps = plan_bgp(q.patterns, self.store.estimate_cardinality)
        return [
            {
                "pattern": dataclasses.astuple(q.patterns[st.pattern_index]),
                "est_rows": self.store.estimate_cardinality(
                    q.patterns[st.pattern_index]
                ),
                "bucket": plan_ir.bucket_capacity(
                    self.store.estimate_cardinality(
                        q.patterns[st.pattern_index]
                    )
                ),
                "join_vars": st.key_vars,
                "cross": st.is_cross,
            }
            for st in steps
        ]
