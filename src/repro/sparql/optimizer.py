"""Cost-based query optimizer: rewrite passes over the logical algebra.

MapSQ's coprocessing strategy makes the CPU responsible for assigning
subqueries — i.e. planning. This module is that planner, grown from the
constant-free greedy heuristic in core/planner.py into a statistics-driven
pipeline (the step gSMat/gSmart show separates a reproduction from a
competitive engine). `optimize()` runs an ordered sequence of passes over
a parsed query's algebra and emits an `OptimizedProgram` — the scan
orders, filter attachment stages and cardinality estimates the engine
lowers to a physical plan:

  1. join_order        — statistics-backed greedy join ordering. Leaf
       cardinalities are the store's exact per-pattern match counts; join
       selectivities come from the StoreStatistics catalog (per-predicate
       triple counts and distinct-subject/object counts) via the System-R
       estimate |L ⋈ R| ≈ |L|·|R| / Π_v max(d_L(v), d_R(v)). Every pattern
       is tried as the chain head (left-deep greedy from each start) and
       the order minimising (max, sum) of estimated intermediate sizes
       wins — that is what keeps MR-join buckets small.
  2. filter_pushdown   — each FILTER conjunct sinks to the deepest sound
       stage: onto a single scan, after the earliest required-chain join
       binding its variables, after an OPTIONAL left join (never *into*
       the optional side — that would turn filtered-out rows into
       unmatched-but-kept rows), or distributed into every UNION branch.
  3. projection_prune  — variables nothing downstream needs (not
       projected, not filtered, bound by exactly one pattern) are marked
       prunable; the physical plan drops them before they widen
       intermediate relations (plan_ir.build_plan narrowing).

The passes record a human-readable trace that PreparedQuery.explain()
prints, pass by pass, together with the per-node cardinality estimates.

`optimize(q, store, enabled=False)` keeps the legacy behaviour (greedy
order from core/planner.plan_bgp, every filter at the top) so the
optimized and unoptimized plans can be compared differentially.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

from repro.core.planner import TriplePattern, plan_bgp
from repro.sparql import algebra
from repro.sparql.store import StoreStatistics, TripleStore

# filter attachment stages — see core/plan_ir.py FilterStage
Stage = tuple


def q_error(est: float, actual: float) -> float:
    """The cardinality model's q-error for one join node: the symmetric
    over/under-estimation factor max(est/actual, actual/est), the metric
    EXPLAIN ANALYZE reports beside estimated-vs-actual rows. Defined as
    1.0 when both sides are zero (a perfect empty estimate) and inf when
    exactly one side is zero."""
    e, a = max(0.0, float(est)), max(0.0, float(actual))
    if e == 0.0 and a == 0.0:
        return 1.0
    if e == 0.0 or a == 0.0:
        return math.inf
    return max(e / a, a / e)


@dataclasses.dataclass(frozen=True)
class OptimizedProgram:
    """The optimizer's output: everything the engine lowers to a PlanShape.

    Scan order is required chain, then each OPTIONAL group, then each
    UNION branch; `filters` pair every conjunct with its attachment stage
    (a conjunct distributed into UNION branches appears once per branch);
    `join_ests` align with the physical plan's join-capacity slots in
    evaluation order.
    """

    required: tuple[TriplePattern, ...]
    cross_flags: tuple[bool, ...]
    opt_groups: tuple[tuple[TriplePattern, ...], ...]
    opt_cross_flags: tuple[tuple[bool, ...], ...]
    branches: tuple[tuple[TriplePattern, ...], ...]
    branch_cross_flags: tuple[tuple[bool, ...], ...]
    filters: tuple[tuple[Stage, algebra.FilterExpr], ...]
    join_ests: tuple[float, ...]
    # physical algebra per join slot ("mr" | "matrix"), aligned with
    # join_ests; cross slots always carry "mr"
    join_backends: tuple[str, ...]
    prune: bool
    trace: tuple[str, ...]

    @property
    def has_required(self) -> bool:
        return bool(self.required)

    def all_patterns(self) -> tuple[TriplePattern, ...]:
        """Every scan in plan order (required, optionals, branches)."""
        out = list(self.required)
        for g in self.opt_groups:
            out.extend(g)
        for b in self.branches:
            out.extend(b)
        return tuple(out)


# -- cardinality / selectivity model ------------------------------------------


@dataclasses.dataclass
class _State:
    """Estimated intermediate: row count, per-variable distinct counts and
    per-variable degree skew (max/avg join fan-out of the predicate
    position that bound the variable — the matrix backend's signal).

    `schema` is the relation's column order (the physical plan derives
    each join key from it, so the optimizer can mirror the lowering's key
    exactly); `part` is the hash-partitioning columns under a sharded
    store (None = unknown placement) — the host-side mirror of
    core/dist_executor's Partitioning property, driving the shuffle-cost
    term of the join ordering."""

    card: float
    dv: dict[str, float]
    skew: dict[str, float] = dataclasses.field(default_factory=dict)
    schema: tuple[str, ...] = ()
    part: "tuple[str, ...] | None" = None


def _filter_selectivity(expr: algebra.FilterExpr, dv: dict[str, float]) -> float:
    """Textbook selectivity of a pushed filter over a single pattern:
    `=` 1/distinct, range comparisons 1/3, `!=` 1 (conservative), `&&`
    multiplies, `||` adds (clamped)."""
    if isinstance(expr, algebra.Compare):
        if expr.op == "=":
            return 1.0 / max(1.0, dv.get(expr.lhs, 1.0))
        if expr.op == "!=":
            return 1.0
        return 1.0 / 3.0
    sels = [_filter_selectivity(c, dv) for c in expr.children]
    if isinstance(expr, algebra.And):
        return math.prod(sels)
    return min(1.0, sum(sels))


def _pattern_state(
    tp: TriplePattern,
    leaf_card: Callable[[TriplePattern], float],
    stats: StoreStatistics,
    lookup,
    filters: Sequence[algebra.FilterExpr] = (),
) -> _State:
    card = float(leaf_card(tp))
    dv = {
        v: max(1.0, min(stats.distinct_values(tp, v, lookup), card))
        for v in tp.variables()
    }
    skew: dict[str, float] = {}
    ps = None
    if not tp.p.startswith("?"):
        pid = lookup(tp.p)
        ps = stats.predicates.get(pid) if pid is not None else None
    for v in tp.variables():
        if ps is not None and v == tp.s:
            skew[v] = ps.s_skew
        elif ps is not None and v == tp.o:
            skew[v] = ps.o_skew
        else:
            skew[v] = 1.0
    # fold pushed-filter selectivity into the leaf estimate: a filter whose
    # variables the pattern binds will mask the scan before it joins, so
    # the join ordering should see the filtered cardinality
    tp_vars = set(tp.variables())
    for expr in filters:
        if tp_vars and set(expr.variables()) <= tp_vars:
            card *= _filter_selectivity(expr, dv)
    dv = {v: max(1.0, min(d, card)) for v, d in dv.items()}
    # scan-order column schema (s,p,o first appearance — the store's scan
    # column order); a variable subject means the sharded store hands this
    # scan out already subject-hash partitioned
    schema = tuple(dict.fromkeys(tp.variables()))
    part = (tp.s,) if tp.s.startswith("?") else None
    return _State(card, dv, skew, schema, part)


def _join_states(a: _State, b: _State) -> tuple[_State, bool]:
    """System-R style join estimate; returns (joined state, shared?)."""
    shared = set(a.dv) & set(b.dv)
    denom = 1.0
    for v in shared:
        denom *= max(a.dv[v], b.dv[v], 1.0)
    est = a.card * b.card / denom
    dv = {}
    for v in set(a.dv) | set(b.dv):
        d = min(a.dv.get(v, math.inf), b.dv.get(v, math.inf))
        dv[v] = max(1.0, min(d, est)) if est > 0 else 1.0
    skew = {
        v: max(a.skew.get(v, 1.0), b.skew.get(v, 1.0))
        for v in set(a.skew) | set(b.skew)
    }
    schema = a.schema + tuple(v for v in b.schema if v not in a.schema)
    return _State(est, dv, skew, schema), bool(shared)


def _dist_step(
    a: _State, b: _State, n_shards: int
) -> tuple[float, "tuple[str, ...] | None"]:
    """Shuffle cost of the sharded join a ⋈ b: (estimated rows moved over
    the interconnect, output partitioning). Mirrors the strategy rules of
    core/dist_executor.analyze_plan on the estimates: an aligned side
    moves nothing; a misaligned side shuffles card × (n-1)/n rows; a
    small doubly-misaligned right side broadcasts (card × (n-1)) and the
    left partitioning survives. Zero at n_shards == 1, so single-device
    join ordering is unchanged."""
    key = tuple(v for v in a.schema if v in set(b.schema))
    if n_shards <= 1:
        return 0.0, (key or a.part)
    if not key:  # cross join: the right side is replicated
        return b.card * (n_shards - 1), a.part
    left_ok = a.part == key
    right_ok = b.part == key
    if left_ok and right_ok:
        return 0.0, key
    if (
        not left_ok
        and not right_ok
        and b.card * n_shards <= _BROADCAST_ROWS
    ):
        return b.card * (n_shards - 1), a.part
    frac = (n_shards - 1) / n_shards
    moved = (0.0 if left_ok else a.card) + (0.0 if right_ok else b.card)
    return moved * frac, key


# mirrors core/dist_executor.DEFAULT_BROADCAST_ROWS (kept as a literal so
# the optimizer stays importable without the executor stack; the actual
# broadcast decision is re-made from real capacities at lowering time —
# this copy only shapes the cost model)
_BROADCAST_ROWS = 2048


# -- backend selection: MR join vs matrix (masked SpMM) join ------------------

# choose "matrix" when selectivity x skew says the join output is within a
# constant factor of the dense |L| x |R| compare grid the matrix backend
# walks: there the MR backend's two argsorts are pure overhead, while a hot
# (skewed) key makes its expansion scale with the dense product anyway
MATRIX_THRESHOLD = 0.5
# never go dense past this |L| x |R| work bound, whatever the skew
MATRIX_DENSE_CAP = 1 << 22


def _choose_backend(a: _State, b: _State, est: float) -> str:
    shared = set(a.dv) & set(b.dv)
    if not shared:
        return "mr"  # cross join: one algebra, slot value is padding
    work = a.card * b.card
    if work <= 0 or work > MATRIX_DENSE_CAP:
        return "mr"
    sigma = est / work
    skew = max(max(a.skew.get(v, 1.0), b.skew.get(v, 1.0)) for v in shared)
    return "matrix" if sigma * skew >= MATRIX_THRESHOLD else "mr"


def _greedy_from(
    states: list[_State], start: int, n_shards: int = 1
) -> tuple[
    list[int], list[bool], list[float], list[str], _State, list[float]
]:
    """Left-deep greedy order from a fixed head, minimising each next
    join's estimated output PLUS its shuffle cost (rows moved over the
    interconnect — zero at n_shards == 1, so single-device ordering is
    bit-identical). Cross joins go last, smallest first. Also returns the
    per-step costs (est + moved) the start-selection compares."""
    order = [start]
    flags: list[bool] = []
    ests: list[float] = []
    costs: list[float] = []
    backends: list[str] = []
    cur = states[start]
    remaining = [i for i in range(len(states)) if i != start]

    def step_cost(i: int) -> float:
        new, _ = _join_states(cur, states[i])
        moved, _ = _dist_step(cur, states[i], n_shards)
        return new.card + moved

    while remaining:
        connected = [
            i for i in remaining if set(states[i].dv) & set(cur.dv)
        ]
        if connected:
            nxt = min(connected, key=lambda i: (step_cost(i), i))
        else:  # disconnected component: cheapest pattern first
            nxt = min(remaining, key=lambda i: (states[i].card, i))
        new, shared = _join_states(cur, states[nxt])
        moved, out_part = _dist_step(cur, states[nxt], n_shards)
        new.part = out_part
        order.append(nxt)
        flags.append(not shared)
        ests.append(new.card)
        costs.append(new.card + moved)
        backends.append(_choose_backend(cur, states[nxt], new.card))
        cur = new
        remaining.remove(nxt)
    return order, flags, ests, backends, cur, costs


# starts tried exhaustively up to this many patterns (n × O(n²) greedy
# runs); beyond it, fall back to the single min-cardinality start
_MAX_EXHAUSTIVE_STARTS = 10


def order_patterns(
    patterns: Sequence[TriplePattern],
    leaf_card: Callable[[TriplePattern], float],
    stats: StoreStatistics,
    lookup,
    filters: Sequence[algebra.FilterExpr] = (),
    n_shards: int = 1,
) -> tuple[
    list[int], tuple[bool, ...], list[float], list[str], _State,
    list[float],
]:
    """Statistics-backed join ordering for one BGP.

    Tries every pattern as the chain head and keeps the greedy order with
    the smallest (max, sum) of per-step COSTS — estimated intermediate
    cardinality plus, when `n_shards` > 1, the shuffle term (rows moved ×
    (n_shards-1)/n_shards), which steers toward alignment-preserving
    orders (a subject-star chain keeps every join map-side). At
    n_shards == 1 cost == cardinality, so single-device plans are
    unchanged. Deterministic for a given store, so structurally-equal
    queries keep hashing to one PlanShape. `filters` (the query's FILTER
    conjuncts) sharpen the leaf estimates: a conjunct a single pattern
    binds is treated as a scan-stage mask, scaling that leaf by its
    selectivity. Also returns the per-step shuffle cost (cost − est) for
    the trace.
    """
    states = [
        _pattern_state(tp, leaf_card, stats, lookup, filters)
        for tp in patterns
    ]
    if len(patterns) == 1:
        return [0], (), [], [], states[0], []
    if len(patterns) <= _MAX_EXHAUSTIVE_STARTS:
        starts = range(len(patterns))
    else:
        starts = [min(range(len(patterns)), key=lambda i: states[i].card)]
    best = None
    for s in starts:
        order, flags, ests, backends, final, costs = _greedy_from(
            states, s, n_shards
        )
        key = (max(costs), sum(costs), tuple(order))
        if best is None or key < best[0]:
            best = (key, order, flags, ests, backends, final, costs)
    _, order, flags, ests, backends, final, costs = best
    moved = [c - e for c, e in zip(costs, ests)]
    return order, tuple(flags), ests, backends, final, moved


# -- the pass pipeline --------------------------------------------------------


def _fmt_tp(tp: TriplePattern) -> str:
    return f"({tp.s} {tp.p} {tp.o})"


def _fmt_est(x: float) -> str:
    return str(int(x)) if x < 1e15 else f"{x:.2e}"


def _order_bgp(
    patterns: Sequence[TriplePattern],
    store: TripleStore,
    enabled: bool,
    label: str,
    trace: list[str],
    filters: Sequence[algebra.FilterExpr] = (),
    n_shards: int = 1,
) -> tuple[
    list[TriplePattern], tuple[bool, ...], list[float], list[str], _State
]:
    """One BGP through the join_order pass (or the legacy greedy)."""
    leaf = store.estimate_cardinality
    lookup = store.dictionary.lookup
    if not enabled:
        steps = plan_bgp(patterns, leaf)
        ordered = [patterns[st.pattern_index] for st in steps]
        flags = tuple(st.is_cross for st in steps[1:])
        # estimates still reported for explain(), just not acted on; the
        # legacy path always lowers to the MR backend
        states = [
            _pattern_state(tp, leaf, store.statistics, lookup)
            for tp in ordered
        ]
        cur, ests = states[0], []
        for st in states[1:]:
            cur, _ = _join_states(cur, st)
            ests.append(cur.card)
        return ordered, flags, ests, ["mr"] * len(ests), cur
    order, flags, ests, backends, final, moved = order_patterns(
        patterns, leaf, store.statistics, lookup, filters, n_shards
    )
    ordered = [patterns[i] for i in order]
    trace.append(
        f"join_order[{label}]: "
        + " -> ".join(_fmt_tp(tp) for tp in ordered)
        + (
            "  est rows per join: ["
            + ", ".join(_fmt_est(e) for e in ests)
            + "]"
            if ests
            else ""
        )
    )
    if n_shards > 1 and moved:
        trace.append(
            f"shuffle_cost[{label}]: est rows moved per join "
            f"({n_shards} shards): ["
            + ", ".join(_fmt_est(m) for m in moved)
            + "]"
            + (
                ""
                if any(m > 0 for m in moved)
                else "  (all joins map-side)"
            )
        )
    if "matrix" in backends:
        picked = [i for i, b in enumerate(backends) if b == "matrix"]
        trace.append(
            f"join_backend[{label}]: matrix join at step(s) "
            + ", ".join(str(i) for i in picked)
            + " (selectivity x skew >= threshold)"
        )
    return ordered, flags, ests, backends, final


def _validate_optionals(
    q, required_vars: set[str]
) -> None:
    """The engine's OPTIONAL soundness rules, enforced at plan time."""
    opt_bound: set[str] = set()
    for group in q.optionals:
        gvars = {v for tp in group for v in tp.variables()}
        overlap = gvars & opt_bound
        if overlap:
            raise ValueError(
                "unsupported: OPTIONAL group reuses variable(s) bound "
                f"by an earlier OPTIONAL group: {sorted(overlap)} "
                "(unbound-compatible chained-OPTIONAL semantics are "
                "not implemented)"
            )
        if not (gvars & required_vars):
            raise ValueError(
                "OPTIONAL group shares no variable with the required "
                f"patterns: {sorted(gvars)}"
            )
        opt_bound |= gvars - required_vars


def _attach_filters(
    q,
    required: Sequence[TriplePattern],
    opt_groups: Sequence[Sequence[TriplePattern]],
    branches: Sequence[Sequence[TriplePattern]],
    enabled: bool,
    trace: list[str],
) -> tuple[tuple[Stage, algebra.FilterExpr], ...]:
    """filter_pushdown: sink each conjunct to its deepest sound stage."""
    if not q.filters:
        return ()
    if not enabled:
        return tuple((("top",), expr) for expr in q.filters)
    req_scan_vars = [set(tp.variables()) for tp in required]
    req_all: set[str] = set().union(*req_scan_vars) if required else set()
    acc: set[str] = set(req_scan_vars[0]) if required else set()
    acc_after_join: list[set[str]] = []
    for s in req_scan_vars[1:]:
        acc = acc | s
        acc_after_join.append(set(acc))
    group_vars = [
        {v for tp in g for v in tp.variables()} for g in opt_groups
    ]
    branch_scan_vars = [
        [set(tp.variables()) for tp in b] for b in branches
    ]
    branch_vars = [set().union(*bs) for bs in branch_scan_vars]
    n_req = len(required)
    n_opt = sum(len(g) for g in opt_groups)
    branch_scan_base = []
    base = n_req + n_opt
    for b in branches:
        branch_scan_base.append(base)
        base += len(b)

    def required_stage(v: set[str]) -> Stage | None:
        """Deepest required-chain stage binding all of `v`, or None."""
        if not required or not v <= req_all:
            return None
        for i, sv in enumerate(req_scan_vars):
            if v <= sv:
                return ("scan", i)
        for j, av in enumerate(acc_after_join):
            if v <= av:
                return ("req", j)
        return None  # unreachable: acc_after_join[-1] == req_all

    specs: list[tuple[Stage, algebra.FilterExpr]] = []
    for expr in q.filters:
        v = set(expr.variables())
        stage = required_stage(v)
        if stage is not None:
            # bound by the required chain (which, with UNION, every
            # branch joins through) — attach inside the chain
            specs.append((stage, expr))
        elif branches and all(
            v <= req_all | bv for bv in branch_vars
        ):
            # distribute a copy into every branch (dropping it from the
            # top is only sound if each branch enforces it)
            stages = []
            for b, bs in enumerate(branch_scan_vars):
                st: Stage = ("bjoin", b)
                for i, sv in enumerate(bs):
                    if v <= sv:
                        st = ("scan", branch_scan_base[b] + i)
                        break
                stages.append(st)
                specs.append((st, expr))
            trace.append(
                f"filter_pushdown: ({expr}) distributed into "
                f"{len(stages)} UNION branch(es)"
            )
            continue
        elif opt_groups and v - req_all:
            needed = [
                g for g, gv in enumerate(group_vars) if (v - req_all) & gv
            ]
            if needed and (v - req_all) <= set().union(
                *(group_vars[g] for g in needed)
            ):
                stage = ("opt", max(needed))
                specs.append((stage, expr))
            else:
                stage = ("top",)
                specs.append((stage, expr))
        else:
            stage = ("top",)
            specs.append((stage, expr))
        if stage is not None:
            trace.append(
                f"filter_pushdown: ({expr}) -> {_fmt_stage(stage)}"
            )
    return tuple(specs)


def _fmt_stage(stage: Stage) -> str:
    kind = stage[0]
    if kind == "scan":
        return f"scan[{stage[1]}]"
    if kind == "req":
        return f"after join[{stage[1]}]"
    if kind == "opt":
        return f"after left_join[{stage[1]}]"
    if kind == "bjoin":
        return f"after union branch[{stage[1]}] join"
    return "top (unpushed)"


def _prune_trace(
    q,
    all_patterns: Sequence[TriplePattern],
    specs,
    trace: list[str],
) -> None:
    """projection_prune: report the variables the physical plan will drop
    early (bound by exactly one pattern, not projected, not filtered —
    plan_ir.build_plan performs the actual narrowing)."""
    from collections import Counter

    uses = Counter(
        v for tp in all_patterns for v in set(tp.variables())
    )
    keep = set(q.projection())
    for _, expr in specs:
        keep.update(expr.variables())
    dead = sorted(
        v for v, n in uses.items() if n == 1 and v not in keep
    )
    if dead:
        trace.append(
            "projection_prune: dropping "
            + ", ".join(dead)
            + " before they widen intermediates"
        )


def optimize(
    q, store: TripleStore, enabled: bool = True, n_shards: int = 1
) -> OptimizedProgram:
    """Run the pass pipeline over a parsed query.

    `enabled=False` reproduces the pre-optimizer behaviour (legacy greedy
    join order, all filters evaluated at the top, no pruning) — the
    baseline the differential tests and the J1/J2 benchmarks compare
    against. `n_shards` > 1 (the sharded engine) adds the per-step
    shuffle-cost term to the join ordering — communication the plan can
    avoid by keeping joins on already-aligned keys.
    """
    trace: list[str] = []
    required_vars = {v for tp in q.patterns for v in tp.variables()}
    _validate_optionals(q, required_vars)

    join_ests: list[float] = []
    join_backends: list[str] = []
    est_filters = tuple(q.filters) if enabled else ()
    req_state: _State | None = None
    if q.patterns:
        required, cross_flags, ests, bks, req_state = _order_bgp(
            q.patterns, store, enabled, "required", trace, est_filters,
            n_shards,
        )
        join_ests.extend(ests)
        join_backends.extend(bks)
    else:
        required, cross_flags = [], ()

    opt_groups: list[tuple[TriplePattern, ...]] = []
    opt_cross_flags: list[tuple[bool, ...]] = []
    for gi, group in enumerate(q.optionals):
        ordered, flags, ests, bks, g_state = _order_bgp(
            list(group), store, enabled, f"optional[{gi}]", trace,
            est_filters, n_shards,
        )
        opt_groups.append(tuple(ordered))
        opt_cross_flags.append(flags)
        join_ests.extend(ests)
        join_backends.extend(bks)
        joined, _ = _join_states(req_state, g_state)
        join_ests.append(joined.card)  # the left join's inner-join bucket
        join_backends.append(
            _choose_backend(req_state, g_state, joined.card)
            if enabled
            else "mr"
        )

    branches: list[tuple[TriplePattern, ...]] = []
    branch_cross_flags: list[tuple[bool, ...]] = []
    for bi, branch in enumerate(q.unions):
        ordered, flags, ests, bks, b_state = _order_bgp(
            list(branch), store, enabled, f"union[{bi}]", trace,
            est_filters, n_shards,
        )
        branches.append(tuple(ordered))
        branch_cross_flags.append(flags)
        join_ests.extend(ests)
        join_backends.extend(bks)
        if req_state is not None:
            joined, _ = _join_states(req_state, b_state)
            join_ests.append(joined.card)
            join_backends.append(
                _choose_backend(req_state, b_state, joined.card)
                if enabled
                else "mr"
            )

    specs = _attach_filters(
        q, required, opt_groups, branches, enabled, trace
    )
    if enabled:
        _prune_trace(
            q,
            list(required)
            + [tp for g in opt_groups for tp in g]
            + [tp for b in branches for tp in b],
            specs,
            trace,
        )
    else:
        trace.append("optimizer disabled: legacy greedy order, filters at top")
    return OptimizedProgram(
        required=tuple(required),
        cross_flags=tuple(cross_flags),
        opt_groups=tuple(opt_groups),
        opt_cross_flags=tuple(opt_cross_flags),
        branches=tuple(branches),
        branch_cross_flags=tuple(branch_cross_flags),
        filters=specs,
        join_ests=tuple(join_ests),
        join_backends=tuple(join_backends),
        prune=enabled,
        trace=tuple(trace),
    )
