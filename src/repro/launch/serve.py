"""Serving launcher: `python -m repro.launch.serve --mode sparql|lm`.

sparql — stand up the MapSQ engine + micro-batching server over LUBM data
         and run the 5 benchmark queries through it.
lm     — reduced-config LM generation (prefill + greedy decode loop).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np
from repro.core import compat


def serve_sparql(scale: int, n_queries: int, shards: int = 0) -> None:
    """`shards > 0` opens the store SHARDED: subject-hash partitioned over
    a `shards`-device mesh, queries served by the distributed executor
    (one shard_map dispatch per warm query). Force host devices first,
    e.g. XLA_FLAGS=--xla_force_host_platform_device_count=4 for CPU."""
    from repro.serve.sparql_server import SPARQLServer
    from repro.sparql.engine import QueryEngine, ShardedQueryEngine
    from repro.sparql.lubm import QUERIES, generate

    store = generate(scale=scale)
    print(f"LUBM-ish store: {len(store)} triples")
    if shards > 0:
        from repro.sparql.sharded_store import shard_store

        sharded = shard_store(store, shards)
        print(f"sharded over {shards} device(s): "
              f"per-shard triples {sharded.shard_sizes()}")
        engine: QueryEngine = ShardedQueryEngine(sharded)
    else:
        engine = QueryEngine(store)
    srv = SPARQLServer(engine)
    import threading

    results = {}

    def ask(name, text):
        results[name] = srv.query(text)

    threads = [
        threading.Thread(target=ask, args=(f"{name}#{i}", text))
        for i in range(n_queries)
        for name, text in QUERIES.items()
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for name in sorted(results):
        print(f"{name}: {len(results[name])} rows")
    print("server stats:", srv.stats())
    srv.close()


def serve_lm(arch: str) -> None:
    import importlib

    from repro.configs.registry import ARCHS
    from repro.launch.mesh import make_local_mesh
    from repro.launch.train import reduced_lm
    from repro.models import transformer as T
    from repro.serve.decode import Generator

    cfg = reduced_lm(importlib.import_module(ARCHS[arch]).CONFIG)
    mesh = make_local_mesh(model=jax.device_count())
    params = T.init_params(jax.random.PRNGKey(0), cfg,
                           ep=mesh.shape["model"])
    gen = Generator(cfg, params, mesh, max_len=64)
    with compat.set_mesh(mesh):
        prompts = np.arange(8, dtype=np.int32).reshape(2, 4) % cfg.vocab
        out = gen.generate(prompts, n_new=16)
    print("generated:", out.shape)
    print(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["sparql", "lm"], default="sparql")
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--scale", type=int, default=2)
    ap.add_argument("--n-queries", type=int, default=4)
    ap.add_argument("--shards", type=int, default=0,
                    help="open the store sharded over this many devices "
                         "(0 = single-device store)")
    args = ap.parse_args()
    if args.mode == "sparql":
        serve_sparql(args.scale, args.n_queries, args.shards)
    else:
        serve_lm(args.arch)


if __name__ == "__main__":
    main()
