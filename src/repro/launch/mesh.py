"""Production mesh builders. A FUNCTION, not a module constant, so importing
this module never touches jax device state (device count locks at first use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: "data" = batch/shuffle parallel, "model" = tensor/expert/sequence
    parallel, "pod" = the slow inter-pod axis (data-parallel across pods;
    the hierarchical shuffle routes over it exactly once).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1,
                    pod: int = 0) -> jax.sharding.Mesh:
    """Small mesh over however many devices this host actually has
    (smoke tests, examples, CI)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
