"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

Runs a REDUCED config of the selected architecture on this host's devices
(full configs are exercised via dryrun.py). This is the same code path a
real pod launch takes: registry config → mesh → jitted step → Trainer with
checkpoints/restart.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.launch.mesh import make_local_mesh
from repro.core import compat


def reduced_lm(cfg, vocab=512):
    return dataclasses.replace(
        cfg, n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4), d_head=16,
        d_ff=min(cfg.d_ff, 128), vocab=vocab,
        n_experts=min(cfg.n_experts, 8) if cfg.is_moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
        d_expert_ff=min(cfg.d_expert_ff, 64) if cfg.is_moe else 0,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window
        else 0, kv_chunk=16, fsdp=False,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    import importlib

    from repro.configs.registry import ARCHS
    from repro.data.tokens import TokenPipeline
    from repro.models import transformer as T
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.trainer import Trainer, TrainSettings

    mod = importlib.import_module(ARCHS[args.arch])
    assert mod.FAMILY == "lm", "train.py drives LM archs; see examples/"
    cfg = reduced_lm(mod.CONFIG)
    mesh = make_local_mesh(data=1, model=jax.device_count())
    params = T.init_params(jax.random.PRNGKey(0), cfg,
                           ep=mesh.shape["model"])
    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=10,
                          total_steps=args.steps)
    step_fn = jax.jit(T.make_train_step(cfg, mesh, opt_cfg, False),
                      donate_argnums=(0, 1))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    tr = Trainer(
        step_fn, params, pipe, args.ckpt_dir,
        TrainSettings(total_steps=args.steps, ckpt_every=args.ckpt_every),
    )
    tr.resume_if_possible()
    with compat.set_mesh(mesh):
        hist = tr.run()
    print(f"final loss: {hist[-1]['loss']:.4f} (step {hist[-1]['step']})")


if __name__ == "__main__":
    main()
