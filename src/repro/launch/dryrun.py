import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
cell on 512 placeholder devices, and extract the roofline inputs
(per-device FLOPs / bytes from cost_analysis, per-device collective bytes
parsed from the post-SPMD HLO, memory_analysis to prove it fits).

The two lines above MUST stay first — jax locks the device count at first
init, and only the dry-run wants 512 fake devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b \
      --shape train_4k --mesh both --out results/
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs.registry import ARCHS, SHAPES_FOR, build_cell
from repro.launch.mesh import make_production_mesh
from repro.core import compat

# TPU v5e-like hardware constants (per chip) — see EXPERIMENTS.md §Roofline.
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
LINK_BW = 50e9

# result type is either a scalar type or a tuple `(...)` which may contain
# `=` inside /*index=N*/ comments — match to the closing paren, not to `=`.
_INSTR_RE = re.compile(r"^\s*%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_COLL_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute"}
_TYPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                      r"\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
          "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
          "u64": 8}


def _type_bytes(type_str: str) -> int:
    total = 0
    for ty, dims in _TYPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[ty]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device collective bytes by op kind, from the post-SPMD HLO.

    Scheduled HLO omits operand types, so pass 1 maps instruction name ->
    result bytes, pass 2 sums the named operands of every collective
    (the assignment's 'sum operand sizes' definition). Shapes in the
    partitioned module are already per-device. `link_bytes` additionally
    applies per-op wire multipliers (all-reduce moves ~2x its operand).
    """
    sizes: dict[str, int] = {}
    colls: list[tuple[str, str]] = []  # (op, args_segment)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        sizes[name] = _type_bytes(type_str)
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLL_OPS:
            args = line[m.end():].split(")", 1)[0]
            colls.append((base, args))
    out: dict[str, int] = {}
    count: dict[str, int] = {}
    link = 0.0
    for op, args in colls:
        bytes_ = sum(sizes.get(nm, 0) for nm in _OPERAND_RE.findall(args))
        out[op] = out.get(op, 0) + bytes_
        count[op] = count.get(op, 0) + 1
        link += bytes_ * (2.0 if op == "all-reduce" else 1.0)
    out["total"] = sum(v for k, v in out.items())
    out["link_bytes"] = link
    out["counts"] = count
    return out


def _compile_cell(arch, shape, multi_pod, mesh, n_layers=None):
    cell = build_cell(arch, shape, mesh, multi_pod, n_layers=n_layers)
    jf = jax.jit(cell.fn, donate_argnums=cell.donate)
    with compat.set_mesh(mesh):  # PartitionSpec constraints resolve here
        t0 = time.time()
        lowered = jf.lower(*cell.inputs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return cell, compiled, t_lower, t_compile


def _cost_terms(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    from repro.configs.registry import family_of, lm_layer_count

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    cell, compiled, t_lower, t_compile = _compile_cell(
        arch, shape, multi_pod, mesh)
    mem = compiled.memory_analysis()
    terms = _cost_terms(compiled)
    probe = None
    if family_of(arch) == "lm":
        # Differential cost extraction: XLA counts the scanned layer body
        # once, so compile L=2 / L=4 and extrapolate the affine terms.
        L = lm_layer_count(arch)
        _, c2, _, _ = _compile_cell(arch, shape, multi_pod, mesh, n_layers=2)
        _, c4, _, _ = _compile_cell(arch, shape, multi_pod, mesh, n_layers=4)
        t2, t4 = _cost_terms(c2), _cost_terms(c4)

        def extrap(a2, a4):
            # clamp: scheduling noise can make the L=4 module report fewer
            # collective bytes than L=2; a negative slope would extrapolate
            # below zero, so never go under the larger measured module.
            return max(a4 + (a4 - a2) / 2.0 * (L - 4), a2, a4)

        probe = {"L2": t2, "L4": t4}
        terms = {
            "flops": extrap(t2["flops"], t4["flops"]),
            "bytes": extrap(t2["bytes"], t4["bytes"]),
            "coll": {
                "total": extrap(t2["coll"]["total"], t4["coll"]["total"]),
                "link_bytes": extrap(t2["coll"]["link_bytes"],
                                     t4["coll"]["link_bytes"]),
                "counts": t4["coll"]["counts"],
            },
        }
    coll = terms["coll"]
    flops_dev = terms["flops"]
    bytes_dev = terms["bytes"]
    rec = {
        "arch": arch,
        "shape": shape,
        "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll,
        "memory": {
            "temp_bytes": mem.temp_size_in_bytes,
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "model_flops_global": cell.model_flops,
        "layer_probe": probe,
        # roofline terms (seconds)
        "t_compute": flops_dev / PEAK_FLOPS,
        "t_memory": bytes_dev / HBM_BW,
        # 'bytes accessed' sums every HLO op's operands — an upper bound
        # that ignores fusion/VMEM residency. t_memory_io is the matching
        # lower bound: only the per-device resident state (args + outputs)
        # crossing HBM once. True HBM time lies between the two.
        "t_memory_io": (mem.argument_size_in_bytes
                        + mem.output_size_in_bytes
                        - mem.alias_size_in_bytes) / HBM_BW,
        "t_collective": coll["link_bytes"] / LINK_BW,
    }
    terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
             "collective": rec["t_collective"]}
    rec["bottleneck"] = max(terms, key=terms.get)
    hlo_global = flops_dev * n_chips
    rec["useful_flops_ratio"] = (
        cell.model_flops / hlo_global if hlo_global else 0.0
    )
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES_FOR(a):
                cells.append((a, s))
    else:
        assert args.arch, "--arch or --all required"
        shapes = [args.shape] if args.shape else list(SHAPES_FOR(args.arch))
        cells = [(args.arch, s) for s in shapes]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (cached)", flush=True)
                continue
            print(f"[run ] {tag}", flush=True)
            try:
                rec = run_cell(arch, shape, mp)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(
                    f"[ ok ] {tag}: compile={rec['t_compile_s']}s "
                    f"flops/dev={rec['flops_per_device']:.3e} "
                    f"coll/dev={rec['collective_bytes_per_device']['total']:.3e}B "
                    f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
                    f"bottleneck={rec['bottleneck']}",
                    flush=True,
                )
            except Exception:
                failures += 1
                with open(path + ".err", "w") as f:
                    f.write(traceback.format_exc())
                print(f"[FAIL] {tag}:\n{traceback.format_exc()}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
