"""Request micro-batcher: collects requests into fixed-size device batches
(pad-to-capacity, the serving analogue of the Mars static-shape discipline),
dispatches when full or when max_wait elapses.

The batcher thread is the serving tier's CPU stage of the MapSQ
coprocessing split: it must only GROUP and DISPATCH. Host-side result
decode — the expensive Python loop that turns device buffers into row
dicts — is handed off through `Deferred` slots: `batch_fn` may return, per
request, a zero-argument callable wrapped in `Deferred`, and the batcher
routes it to the configured decode pool (serve/decode.py) instead of
running it inline. With a pool attached, dispatch of batch k+1 overlaps
decode of batch k and per-request futures resolve from the decode side;
without one, deferred slots are resolved inline (the synchronous
pre-pipeline behaviour).
"""
from __future__ import annotations

import copy
import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Optional


class BatchTimeout(TimeoutError):
    """A submitter's wall-clock deadline expired before its request
    resolved. The request itself is NOT cancelled — the batch it rides in
    keeps running — but it is marked abandoned so the decode stage can
    skip producing a result nobody will read."""


def _exc_copy(e: BaseException) -> BaseException:
    """An independent per-request copy of a batch failure, carrying the
    original raise site's traceback.

    Each request in a failed batch re-raises on its own submitter thread;
    sharing one exception instance makes those re-raises race on
    `__traceback__` (and lets one caller's handling mutate what another
    sees). copy.copy reconstructs via cls(*args), which TypeErrors for
    classes whose __init__ signature diverges from their stored args — for
    those, clone the instance structurally (__new__ + __dict__ + args).
    Only if even that fails is the original shared, as a last resort.
    """
    try:
        c = copy.copy(e)
    except Exception:
        try:
            c = e.__class__.__new__(e.__class__)
            c.__dict__.update(e.__dict__)
            c.args = e.args
        except Exception:
            return e
    if c is e:
        return e
    c.__cause__ = e.__cause__
    c.__suppress_context__ = True  # the copy has no raise context of its own
    return c.with_traceback(e.__traceback__)


class Deferred:
    """A batch_fn result slot whose finalisation (host decode) runs off the
    batcher thread: `fn()` produces the request's final result (or returns/
    raises an exception, which the submitter re-raises)."""

    __slots__ = ("fn",)

    def __init__(self, fn: Callable[[], Any]):
        self.fn = fn


@dataclasses.dataclass
class Request:
    payload: Any
    event: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    result: Any = None
    # submitter gave up (deadline expired): decode stages skip the work
    abandoned: bool = False
    # per-request trace (obs.Trace) or None: the batcher and decode pool
    # annotate failure paths on it (duck-typed — this module stays
    # import-free of the obs package)
    trace: Any = None


class MicroBatcher:
    def __init__(self, batch_fn: Callable[[list[Any]], list[Any]],
                 max_batch: int, max_wait_s: float = 0.005,
                 decode_pool: Optional[Any] = None):
        self.batch_fn = batch_fn
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.decode_pool = decode_pool  # serve.decode.DecodePool (or None)
        self.q: queue.Queue[Request] = queue.Queue()
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._loop, daemon=True)
        self.t.start()
        self.n_batches = 0
        self.n_requests = 0
        self.n_deferred = 0  # result slots handed to the decode stage
        # cumulative wall time the batcher thread spent inside batch_fn
        # (group + dispatch; with a decode pool, decode is NOT in here) —
        # the open-loop bench reads this to report dispatch-stage busyness
        self.dispatch_s = 0.0
        # arrival-size histogram: batch size -> number of batches formed
        # (how much same-dispatch coalescing the traffic actually offers)
        self.batch_size_hist: dict[int, int] = {}

    def submit(self, payload: Any, timeout: float = 30.0,
               trace: Any = None) -> Any:
        r = Request(payload, trace=trace)
        self.q.put(r)
        if not r.event.wait(timeout):
            r.abandoned = True
            raise BatchTimeout(
                f"request did not resolve within {timeout:.3f}s"
            )
        if isinstance(r.result, BaseException):
            raise r.result
        return r.result

    def _resolve(self, r: Request, res: Any) -> None:
        """Finalize one request: deferred slots go to the decode pool (or
        run inline when none is attached), plain slots resolve now."""
        if isinstance(res, Deferred):
            self.n_deferred += 1
            if self.decode_pool is not None:
                self.decode_pool.submit(r, res.fn)
                return
            try:
                res = res.fn()
            except BaseException as e:
                res = e
        r.result = res
        r.event.set()

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                first = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.time() + self.max_wait_s
            while len(batch) < self.max_batch:
                left = deadline - time.time()
                if left <= 0:
                    break
                try:
                    batch.append(self.q.get(timeout=left))
                except queue.Empty:
                    break
            t0 = time.perf_counter()
            try:
                results = self.batch_fn([r.payload for r in batch])
            except BaseException as e:  # keep the worker alive: fail the
                # batch, not the server; independent per-request copies
                # (original traceback attached) so concurrent re-raises in
                # client threads never share one instance
                t1 = time.perf_counter()
                for r in batch:
                    if r.trace is not None:
                        # retroactive (born-closed) span: a failed batch
                        # leaks nothing even though batch_fn blew up
                        r.trace.add_span(
                            "batch_error", t0, t1,
                            error=type(e).__name__,
                        )
                results = [_exc_copy(e) for _ in batch]
            self.dispatch_s += time.perf_counter() - t0
            self.n_batches += 1
            self.n_requests += len(batch)
            self.batch_size_hist[len(batch)] = (
                self.batch_size_hist.get(len(batch), 0) + 1
            )
            for r, res in zip(batch, results):
                self._resolve(r, res)

    def close(self) -> None:
        self._stop.set()
        self.t.join(timeout=2)
