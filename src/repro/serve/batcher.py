"""Request micro-batcher: collects requests into fixed-size device batches
(pad-to-capacity, the serving analogue of the Mars static-shape discipline),
dispatches when full or when max_wait elapses."""
from __future__ import annotations

import copy
import dataclasses
import queue
import threading
import time
from typing import Any, Callable


def _safe_copy(e: BaseException) -> BaseException:
    """copy.copy reconstructs exceptions via cls(*args), which TypeErrors
    for classes whose __init__ signature diverges from their stored args;
    fall back to sharing the original rather than killing the worker."""
    try:
        return copy.copy(e)
    except Exception:
        return e


@dataclasses.dataclass
class Request:
    payload: Any
    event: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    result: Any = None


class MicroBatcher:
    def __init__(self, batch_fn: Callable[[list[Any]], list[Any]],
                 max_batch: int, max_wait_s: float = 0.005):
        self.batch_fn = batch_fn
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.q: queue.Queue[Request] = queue.Queue()
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._loop, daemon=True)
        self.t.start()
        self.n_batches = 0
        self.n_requests = 0
        # arrival-size histogram: batch size -> number of batches formed
        # (how much same-dispatch coalescing the traffic actually offers)
        self.batch_size_hist: dict[int, int] = {}

    def submit(self, payload: Any, timeout: float = 30.0) -> Any:
        r = Request(payload)
        self.q.put(r)
        if not r.event.wait(timeout):
            raise TimeoutError("batcher timed out")
        if isinstance(r.result, BaseException):
            raise r.result
        return r.result

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                first = self.q.get(timeout=0.05)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.time() + self.max_wait_s
            while len(batch) < self.max_batch:
                left = deadline - time.time()
                if left <= 0:
                    break
                try:
                    batch.append(self.q.get(timeout=left))
                except queue.Empty:
                    break
            try:
                results = self.batch_fn([r.payload for r in batch])
            except BaseException as e:  # keep the worker alive: fail the
                # batch, not the server; per-request copies so concurrent
                # re-raises in client threads don't race on __traceback__
                results = [_safe_copy(e) for _ in batch]
            self.n_batches += 1
            self.n_requests += len(batch)
            self.batch_size_hist[len(batch)] = (
                self.batch_size_hist.get(len(batch), 0) + 1
            )
            for r, res in zip(batch, results):
                r.result = res
                r.event.set()

    def close(self) -> None:
        self._stop.set()
        self.t.join(timeout=2)
