"""Serving-side decode stage.

Two residents:

- `DecodePool` — the host half of the SPARQL serving pipeline. The
  MicroBatcher thread dispatches device work and hands each request's
  finalisation (device→host transfer + row materialisation) to this
  bounded worker pool, so dispatch of batch k+1 overlaps decode of
  batch k (MapSQ's CPU/GPU split applied to the serving tier).
- `Generator` — the autoregressive LM driver: prefill once, then greedy
  decode with a static-capacity KV cache (prefill_step / serve_step from
  models/transformer).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


class DecodePool:
    """Bounded pool of daemon workers that finalise batch result slots off
    the batcher thread.

    Items are (request, fn) pairs where `request` duck-types the
    batcher's Request (``.result``, ``.event``, ``.abandoned``) and
    ``fn()`` produces the request's final value. Crash isolation is per
    item: any exception a worker hits becomes that one request's result
    (re-raised on the submitter's thread) and the worker keeps serving.
    Should a worker thread die anyway (e.g. a BaseException escaping the
    handler during interpreter teardown), `submit` respawns it, so a
    decode-worker crash never wedges the server. Abandoned requests
    (submitter deadline already expired) are skipped without decoding.
    """

    def __init__(self, n_workers: int = 2, max_queue: int = 64):
        self.n_workers = max(1, n_workers)
        self.q: queue.Queue = queue.Queue(maxsize=max(1, max_queue))
        self._lock = threading.Lock()
        self._closed = False
        self.n_decoded = 0
        self.n_errors = 0   # fn() raised; exception delivered to submitter
        self.n_skipped = 0  # abandoned requests dropped undecoded
        self.max_depth = 0  # high-water queue depth observed at submit
        self._threads = [
            threading.Thread(target=self._worker, daemon=True)
            for _ in range(self.n_workers)
        ]
        for t in self._threads:
            t.start()

    def submit(self, request: Any, fn: Callable[[], Any]) -> None:
        """Enqueue one finalisation. Blocks (backpressure on the batcher
        thread) when the queue is full rather than growing unboundedly."""
        with self._lock:
            if self._closed:
                raise RuntimeError("DecodePool is closed")
            # respawn any worker that died outside the per-item handler
            for i, t in enumerate(self._threads):
                if not t.is_alive():
                    nt = threading.Thread(target=self._worker, daemon=True)
                    self._threads[i] = nt
                    nt.start()
        depth = self.q.qsize() + 1
        if depth > self.max_depth:
            self.max_depth = depth
        self.q.put((request, fn))

    def _worker(self) -> None:
        while True:
            item = self.q.get()
            if item is None:  # close() sentinel
                return
            r, fn = item
            trace = getattr(r, "trace", None)
            if getattr(r, "abandoned", False):
                self.n_skipped += 1
                if trace is not None:
                    # retroactive zero-length marker: the skip closes the
                    # request's trace path without decoding anything
                    t = time.perf_counter()
                    trace.add_span("decode_skipped", t, t, abandoned=True)
                r.event.set()
                continue
            try:
                r.result = fn()
                self.n_decoded += 1
            except BaseException as e:
                r.result = e
                self.n_errors += 1
                if trace is not None:
                    t = time.perf_counter()
                    trace.add_span(
                        "decode_error", t, t, error=type(e).__name__
                    )
            r.event.set()

    def stats(self) -> dict:
        return {
            "workers": self.n_workers,
            "decoded": self.n_decoded,
            "errors": self.n_errors,
            "skipped": self.n_skipped,
            "max_depth": self.max_depth,
            "depth": self.q.qsize(),
        }

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        for _ in self._threads:
            self.q.put(None)
        for t in self._threads:
            t.join(timeout=2)


@dataclasses.dataclass
class Generator:
    cfg: T.TransformerConfig
    params: dict
    mesh: jax.sharding.Mesh
    multi_pod: bool = False
    max_len: int = 256

    def __post_init__(self):
        self._prefill = jax.jit(
            T.make_prefill_step(self.cfg, self.mesh, self.multi_pod)
        )
        self._step = jax.jit(
            T.make_serve_step(self.cfg, self.mesh, self.multi_pod),
            donate_argnums=(1, 2),
        )

    def generate(self, prompts: np.ndarray, n_new: int) -> np.ndarray:
        """prompts: (B, S0) int32. Returns (B, n_new) greedy tokens."""
        b, s0 = prompts.shape
        assert s0 + n_new <= self.max_len
        kc, vc = T.init_decode_cache(self.cfg, b, self.max_len)
        nxt, kc_p, vc_p = self._prefill(self.params, jnp.asarray(prompts))
        kc = jax.lax.dynamic_update_slice(
            kc, kc_p.astype(kc.dtype), (0, 0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, vc_p.astype(vc.dtype), (0, 0, 0, 0, 0))
        out = [np.asarray(nxt)]
        pos = s0
        for _ in range(n_new - 1):
            nxt, kc, vc = self._step(self.params, kc, vc, jnp.int32(pos), nxt)
            out.append(np.asarray(nxt))
            pos += 1
        return np.stack(out, axis=1)
