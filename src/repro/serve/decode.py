"""Autoregressive serving driver: prefill once, then greedy decode with a
static-capacity KV cache (prefill_step / serve_step from models/transformer).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


@dataclasses.dataclass
class Generator:
    cfg: T.TransformerConfig
    params: dict
    mesh: jax.sharding.Mesh
    multi_pod: bool = False
    max_len: int = 256

    def __post_init__(self):
        self._prefill = jax.jit(
            T.make_prefill_step(self.cfg, self.mesh, self.multi_pod)
        )
        self._step = jax.jit(
            T.make_serve_step(self.cfg, self.mesh, self.multi_pod),
            donate_argnums=(1, 2),
        )

    def generate(self, prompts: np.ndarray, n_new: int) -> np.ndarray:
        """prompts: (B, S0) int32. Returns (B, n_new) greedy tokens."""
        b, s0 = prompts.shape
        assert s0 + n_new <= self.max_len
        kc, vc = T.init_decode_cache(self.cfg, b, self.max_len)
        nxt, kc_p, vc_p = self._prefill(self.params, jnp.asarray(prompts))
        kc = jax.lax.dynamic_update_slice(
            kc, kc_p.astype(kc.dtype), (0, 0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, vc_p.astype(vc.dtype), (0, 0, 0, 0, 0))
        out = [np.asarray(nxt)]
        pos = s0
        for _ in range(n_new - 1):
            nxt, kc, vc = self._step(self.params, kc, vc, jnp.int32(pos), nxt)
            out.append(np.asarray(nxt))
            pos += 1
        return np.stack(out, axis=1)
