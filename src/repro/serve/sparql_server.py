"""SPARQL serving front-end: the MapSQ framework (Fig 1) as a service.

Requests (query strings) flow through the MicroBatcher; the engine executes
each batch — partial matching per pattern, then the operator tree on
device. Batching amortizes dispatch overhead exactly like the paper's
CPU-assigns / GPU-computes split — and with `batch_execution` (default on)
the batch is routed through `engine.run_batch_pipelined`, which coalesces
same-shape batchmates into single stacked (vmapped) device dispatches: N
warm identical-shape requests cost ceil(N / width) launches, not N — and
cross-shape padded stacking merges near-miss plan shapes into those
dispatches too. Mixed batches fall back per plan group; `stats()
["batched"]` reports the batch-width histogram, queries-per-dispatch and
the padding ledger so operators can watch the coalescing win.

The hot path is a TWO-STAGE pipeline. The batcher thread only groups and
dispatches: each request's host decode (device→host transfer + row
materialisation) comes back as a PendingDecode and is handed to a bounded
`DecodePool` (serve/decode.py), so dispatch of batch k+1 overlaps decode
of batch k and per-request futures resolve from the decode side.
`decode_workers=0` restores the synchronous batcher (decode inline on the
batcher thread) — the bench's baseline. Per-request wall-clock deadlines
(`query(text, timeout_ms=...)`) raise QueryTimeoutError and mark the
request abandoned so the decode stage skips work nobody will read.

Responses are typed: a successful request yields a `QueryResult` (which
still compares/iterates like the plain row list for back-compat), a failed
one raises a `QueryError` on the caller's thread — parse failures raise
`ParseQueryError`, which is also a `ParseError`. Raw `Exception` objects
never travel inside result lists.

All requests in all batches share one QueryEngine and therefore ONE plan/
compile cache and one device scan cache — plus a server-side cache of
`PreparedQuery` handles keyed by query text, so repeated queries skip
parsing and planning entirely. The first request of a given query shape
pays calibration + compilation, every later request (from any client) is a
cache hit dispatching a single precompiled device program. `stats()`
reports the cache hit rates so operators can watch the warm fraction.

The store is live: `update(text)` applies `INSERT DATA` / `DELETE DATA`
requests through the delta-block write path. Cached prepared handles stay
valid across updates — each run re-stages its scans at the store's current
version, so warm plan shapes keep dispatching precompiled programs as long
as writes stay within their capacity buckets. `stats()["store"]` and
`stats()["updates"]` report store version, tail/tombstone sizes, and the
server's cumulative write counters.

Observability: when the engine carries a `Tracer`, every request gets a
per-query trace — parse, optimize, compile, dispatch (fanned across
stacked lanes), transfer and decode spans — finished (and ring-buffered)
in `query()`'s finally, the ONLY closer, so no path leaks an open span.
Request counters live on the engine's `MetricsRegistry`
(`render_prometheus()` is a single scrape covering server + engine), and
every request is counted under exactly ONE terminal outcome
(ok/timeout/error) at this submitter site — a timed-out request whose
decode later completes is a timeout, full stop, never also an "ok".
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict

from repro.serve.batcher import BatchTimeout, Deferred, MicroBatcher
from repro.serve.decode import DecodePool
from repro.sparql.engine import (
    PendingDecode,
    PreparedQuery,
    QueryEngine,
    UpdateResult,
)
from repro.sparql.parser import ParseError


@dataclasses.dataclass
class QueryResult:
    """Successful response envelope: decoded rows + result metadata.

    Sequence-compatible with the historical `list[dict]` return shape:
    len/iter/index/== all defer to `rows`.
    """

    rows: list[dict[str, str]]
    vars: tuple[str, ...]
    from_cache: bool  # served via a cached PreparedQuery handle

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    def __getitem__(self, i):
        return self.rows[i]

    def __eq__(self, other) -> bool:
        if isinstance(other, QueryResult):
            return self.rows == other.rows
        if isinstance(other, list):
            return self.rows == other
        return NotImplemented


class QueryError(Exception):
    """Typed failure envelope: what failed (parse/plan/execution) and for
    which query. Raised on the submitting caller's thread, never returned
    inside a result list."""

    def __init__(self, kind: str, message: str, query: str):
        super().__init__(message)
        self.kind = kind
        self.message = message
        self.query = query

    def __str__(self) -> str:
        return f"[{self.kind}] {self.message}"


class ParseQueryError(QueryError, ParseError):
    """Parse-stage QueryError; also a sparql.parser.ParseError so callers
    catching ParseError keep working."""

    def __init__(self, message: str, query: str):
        QueryError.__init__(self, "parse", message, query)


class QueryTimeoutError(QueryError, TimeoutError):
    """The per-request wall-clock deadline expired before the result
    resolved (kind="timeout"); also a TimeoutError. The batch the request
    rode in keeps running and stays cached — only this caller gives up,
    and the decode stage skips the abandoned slot."""

    def __init__(self, message: str, query: str):
        QueryError.__init__(self, "timeout", message, query)


@dataclasses.dataclass
class SPARQLServer:
    engine: QueryEngine
    max_batch: int = 8
    max_wait_s: float = 0.002
    prepared_cache_entries: int = 256
    batch_execution: bool = True  # stack same-shape batchmates per dispatch
    # decode pipeline: worker threads resolving PendingDecode slots off the
    # batcher thread (0 = synchronous decode on the batcher thread)
    decode_workers: int = 2
    decode_queue: int = 64  # backpressure bound on undecoded results
    default_timeout_s: float = 30.0  # per-request deadline when none given

    def __post_init__(self):
        self._decode_pool = (
            DecodePool(self.decode_workers, self.decode_queue)
            if self.decode_workers > 0 else None
        )
        self._batcher = MicroBatcher(self._run_batch, self.max_batch,
                                     self.max_wait_s,
                                     decode_pool=self._decode_pool)
        self._prepared: OrderedDict[str, PreparedQuery] = OrderedDict()
        # request-path instruments live on the engine's registry so one
        # render_prometheus() scrape covers both layers; stats() reads the
        # instruments back (the registry is the source of truth)
        m = self.engine.metrics
        self._m_requests = m.counter(
            "mapsq_requests_total",
            "query requests by terminal outcome (counted exactly once, "
            "at the submitting call site)",
            labelnames=("outcome",),
        )
        for outcome in ("ok", "timeout", "error"):
            self._m_requests.labels(outcome=outcome)  # render zeros
        self._m_latency = m.histogram(
            "mapsq_request_latency_seconds",
            "end-to-end request latency: submit to resolve/timeout",
        )
        self._m_prepared_hits = m.counter(
            "mapsq_prepared_cache_hits_total",
            "server-side PreparedQuery handle cache hits",
        )
        self._m_prepared_misses = m.counter(
            "mapsq_prepared_cache_misses_total",
            "server-side PreparedQuery handle cache misses",
        )
        self._m_update_requests = m.counter(
            "mapsq_update_requests_total", "SPARQL UPDATE requests applied"
        )
        self._m_rows_inserted = m.counter(
            "mapsq_update_rows_inserted_total", "rows inserted via UPDATE"
        )
        self._m_rows_deleted = m.counter(
            "mapsq_update_rows_deleted_total", "rows deleted via UPDATE"
        )
        # pipeline-stage counters kept as plain attributes on the batcher/
        # decode-pool hot paths, mirrored into the registry at scrape time
        m_batches = m.counter(
            "mapsq_batches_total", "micro-batches dispatched"
        )
        m_deferred = m.counter(
            "mapsq_deferred_total",
            "result slots handed to the decode stage",
        )
        m_dispatch_s = m.counter(
            "mapsq_dispatch_seconds_total",
            "batcher-thread seconds inside batch_fn (group + dispatch)",
        )
        m_decoded = m.counter(
            "mapsq_decode_decoded_total", "decode-pool slots finalised"
        )
        m_dec_errors = m.counter(
            "mapsq_decode_errors_total",
            "decode-pool slots whose fn raised",
        )
        m_dec_skipped = m.counter(
            "mapsq_decode_skipped_total",
            "abandoned slots dropped undecoded",
        )
        m_depth = m.gauge(
            "mapsq_decode_queue_depth", "undecoded slots waiting"
        )

        def _collect() -> None:
            m_batches.set_total(self._batcher.n_batches)
            m_deferred.set_total(self._batcher.n_deferred)
            m_dispatch_s.set_total(self._batcher.dispatch_s)
            if self._decode_pool is not None:
                ds = self._decode_pool.stats()
                m_decoded.set_total(ds["decoded"])
                m_dec_errors.set_total(ds["errors"])
                m_dec_skipped.set_total(ds["skipped"])
                m_depth.set(ds["depth"])

        m.register_collector(_collect)

    def _prepared_handle(
        self, text: str, trace=None
    ) -> tuple[PreparedQuery, bool]:
        pq = self._prepared.get(text)
        if pq is not None:
            self._m_prepared_hits.inc()
            self._prepared.move_to_end(text)
            return pq, True
        self._m_prepared_misses.inc()
        pq = self.engine.prepare(text, trace=trace)
        self._prepared[text] = pq
        while len(self._prepared) > self.prepared_cache_entries:
            self._prepared.popitem(last=False)
        return pq, False

    def _deferred(self, pending: PendingDecode, text: str,
                  cached: bool) -> Deferred:
        """Wrap a dispatched-but-undecoded slot for the decode stage: the
        callable resolves the decode and types the envelope; any decode
        failure becomes a QueryError raised on the submitter's thread."""
        def fn() -> QueryResult:
            try:
                rs = pending.resolve()
            except Exception as e:
                raise QueryError("decode", str(e), query=text) from e
            return QueryResult(rows=rs.rows, vars=rs.vars, from_cache=cached)
        return Deferred(fn)

    def _run_batch(
        self, payloads: list
    ) -> "list[QueryResult | QueryError | Deferred]":
        """The pipeline's DISPATCH stage, on the batcher thread: same-shape
        (and padded near-miss-shape) queries coalesce into stacked device
        dispatches via engine.run_batch_pipelined, and each successfully
        dispatched slot returns as a Deferred whose decode runs on the
        decode pool. Every failure (parse, plan, execution) stays isolated
        to its own slot — one bad query never fails its batchmates or the
        worker thread.

        Payloads are query strings, or (text, trace) pairs when the
        request carries a per-query trace — the trace rides through
        prepare (parse/optimize spans), the stacked dispatch fan-out and
        the PendingDecode (transfer/decode spans)."""
        queries: list[str] = []
        traces: list = []
        for p in payloads:
            if isinstance(p, tuple):
                queries.append(p[0])
                traces.append(p[1])
            else:
                queries.append(p)
                traces.append(None)
        outs: list[QueryResult | QueryError | Deferred | None] = (
            [None] * len(queries)
        )
        pending: list[tuple[int, "PreparedQuery", bool]] = []
        for i, text in enumerate(queries):
            try:
                pq, cached = self._prepared_handle(text, trace=traces[i])
            except ParseError as e:
                outs[i] = ParseQueryError(str(e), query=text)
            except Exception as e:
                outs[i] = QueryError("plan", str(e), query=text)
            else:
                pending.append((i, pq, cached))
        if not pending:
            return outs
        if self.batch_execution:
            outcomes = self.engine.run_batch_pipelined(
                [pq for _, pq, _ in pending],
                traces=[traces[i] for i, _, _ in pending],
            )
        else:
            outcomes = []
            for i, pq, _ in pending:
                try:
                    outcomes.append(pq._run_pending(traces[i]))
                except Exception as e:
                    outcomes.append(e)
        for (i, pq, cached), oc in zip(pending, outcomes):
            if isinstance(oc, PendingDecode):
                outs[i] = self._deferred(oc, queries[i], cached)
            elif isinstance(oc, Exception):
                outs[i] = QueryError("execution", str(oc), query=queries[i])
            else:
                # an inline-resolved ResultSet (e.g. a cold calibration run
                # that decoded eagerly on a non-pipelined engine path)
                outs[i] = QueryResult(
                    rows=oc.rows, vars=oc.vars, from_cache=cached
                )
        return outs

    def query(self, text: str,
              timeout_ms: "float | None" = None) -> QueryResult:
        """Submit one query; raises QueryError (a ParseQueryError for parse
        failures) on this thread if the request failed. `timeout_ms` caps
        the request's wall-clock wait — dispatch queueing AND decode — and
        raises QueryTimeoutError on expiry (the server keeps running the
        batch; only this caller gives up).

        This is the request's ONE terminal-outcome accounting site: it
        resolves to exactly one of ok/timeout/error here, regardless of
        what the decode stage later does with an abandoned slot. The
        per-request trace (when the engine has a Tracer) is also finished
        here, in the finally — every span the pipeline recorded on it is
        born closed, so the finished trace has zero open spans even on
        the timeout and failure paths."""
        timeout = (
            timeout_ms / 1000.0 if timeout_ms is not None
            else self.default_timeout_s
        )
        tracer = self.engine.tracer
        trace = (
            tracer.new_trace("query", query=text[:120])
            if tracer is not None else None
        )
        payload = (text, trace) if trace is not None else text
        t0 = time.perf_counter()
        outcome = "error"
        try:
            res = self._batcher.submit(payload, timeout=timeout,
                                       trace=trace)
            outcome = "ok"
            return res
        except BatchTimeout as e:
            outcome = "timeout"
            raise QueryTimeoutError(
                f"query did not resolve within {timeout * 1000:.0f} ms",
                query=text,
            ) from e
        finally:
            self._m_requests.labels(outcome=outcome).inc()
            self._m_latency.observe(time.perf_counter() - t0)
            if trace is not None:
                tracer.finish(trace, outcome=outcome)

    def update(self, text: str) -> UpdateResult:
        """Apply a SPARQL UPDATE request (`INSERT DATA` / `DELETE DATA`,
        `;`-separated) against the live store.

        Updates run synchronously on the caller's thread under the store's
        snapshot lock — in-flight query batches that already staged their
        scans keep their pinned snapshot, later requests see the new store
        version. Prepared handles cached by the server stay valid: they
        re-stage scans at the current version on their next run (a query
        whose scan outgrows its capacity bucket simply compiles one new
        plan-cache entry). Parse failures raise ParseQueryError."""
        try:
            res = self.engine.update(text)
        except ParseQueryError:
            raise
        except ParseError as e:
            raise ParseQueryError(str(e), query=text) from e
        self._m_update_requests.inc()
        self._m_rows_inserted.inc(res.inserted)
        self._m_rows_deleted.inc(res.deleted)
        return res

    def explain(self, text: str, analyze: bool = False) -> str:
        """Host-side plan report (algebra, optimizer trace, physical plan,
        cache state) for a query, through the prepared-handle cache. With
        `analyze=True`, appends the EXPLAIN ANALYZE section — estimated vs
        actual rows per join node from the handle's last run (running the
        query once if it never ran)."""
        pq, _ = self._prepared_handle(text)
        return pq.explain(analyze=analyze)

    def save_cache(self, path: str) -> int:
        """Persist the engine's learned bucket signatures (see
        QueryEngine.save_cache); a restarted server constructed with
        QueryEngine(warmup_path=...) skips calibration for these shapes."""
        return self.engine.save_cache(path)

    def render_prometheus(self) -> str:
        """One text-exposition scrape of the shared registry: request
        outcomes/latency, prepared-cache and update counters (direct
        instruments) plus the engine's pipeline/padding/cache/store
        bridge collectors."""
        return self.engine.metrics.render_prometheus()

    def recent_traces(self) -> list:
        """The tracer's bounded ring of finished per-query traces
        (oldest first); empty when the engine has no Tracer."""
        t = self.engine.tracer
        return t.recent() if t is not None else []

    def slow_queries(self) -> list:
        """Finished traces that crossed the tracer's slow_ms threshold."""
        t = self.engine.tracer
        return t.slow_queries() if t is not None else []

    def stats(self) -> dict:
        hits = int(self._m_prepared_hits.value)
        misses = int(self._m_prepared_misses.value)
        total = hits + misses
        eng = self.engine
        sd, sq = eng.stacked_dispatches, eng.stacked_queries
        # snapshot before sorting: the worker thread inserts new histogram
        # keys concurrently with a client thread reading stats
        width_hist = dict(eng.batch_width_hist)
        arrival_hist = dict(self._batcher.batch_size_hist)
        pc, rc = eng.padded_cells, eng.real_cells
        return {
            "batches": self._batcher.n_batches,
            "requests": self._batcher.n_requests,
            "timeouts": int(
                self._m_requests.labels(outcome="timeout").value
            ),
            "plan_cache": self.engine.cache_stats(),
            "scan_cache": self.engine.store.scan_cache_stats(),
            "store": self.engine.store.write_stats(),
            "updates": {
                "requests": int(self._m_update_requests.value),
                "rows_inserted": int(self._m_rows_inserted.value),
                "rows_deleted": int(self._m_rows_deleted.value),
            },
            "prepared_cache": {
                "entries": len(self._prepared),
                "hits": hits,
                "misses": misses,
                "hit_rate": hits / total if total else 0.0,
            },
            # the coalescing win: how many device dispatches were stacked,
            # how many queries each one carried, at which lane widths, and
            # what cross-shape padding bought (merges taken/rejected and
            # the padded-vs-real scan-cell waste ratio)
            "batched": {
                "stacked_dispatches": sd,
                "stacked_queries": sq,
                "queries_per_dispatch": sq / sd if sd else 0.0,
                "batch_width_hist": dict(sorted(width_hist.items())),
                "arrival_batch_hist": dict(sorted(arrival_hist.items())),
                "padding": {
                    "padded_groups": eng.padded_groups,
                    "pad_rejects": eng.pad_rejects,
                    "padded_cells": pc,
                    "real_cells": rc,
                    "waste_ratio": (pc - rc) / rc if rc else 0.0,
                },
            },
            # the two pipeline stages' health: slots handed to the decode
            # side, batcher time spent in dispatch, device busy seconds
            # (1 - Δdevice_time_s / wall is the bench's idle fraction)
            "pipeline": {
                "deferred": self._batcher.n_deferred,
                "dispatch_s": self._batcher.dispatch_s,
                "device_time_s": eng.device_time_s,
                "decode": (
                    self._decode_pool.stats()
                    if self._decode_pool is not None else None
                ),
            },
        }

    def close(self) -> None:
        self._batcher.close()
        if self._decode_pool is not None:
            self._decode_pool.close()
