"""SPARQL serving front-end: the MapSQ framework (Fig 1) as a service.

Requests (query strings) flow through the MicroBatcher; the engine executes
each batch — partial matching per pattern, then the operator tree on
device. Batching amortizes dispatch overhead exactly like the paper's
CPU-assigns / GPU-computes split — and with `batch_execution` (default on)
the batch is routed through `engine.run_batch_pipelined`, which coalesces
same-shape batchmates into single stacked (vmapped) device dispatches: N
warm identical-shape requests cost ceil(N / width) launches, not N — and
cross-shape padded stacking merges near-miss plan shapes into those
dispatches too. Mixed batches fall back per plan group; `stats()
["batched"]` reports the batch-width histogram, queries-per-dispatch and
the padding ledger so operators can watch the coalescing win.

The hot path is a TWO-STAGE pipeline. The batcher thread only groups and
dispatches: each request's host decode (device→host transfer + row
materialisation) comes back as a PendingDecode and is handed to a bounded
`DecodePool` (serve/decode.py), so dispatch of batch k+1 overlaps decode
of batch k and per-request futures resolve from the decode side.
`decode_workers=0` restores the synchronous batcher (decode inline on the
batcher thread) — the bench's baseline. Per-request wall-clock deadlines
(`query(text, timeout_ms=...)`) raise QueryTimeoutError and mark the
request abandoned so the decode stage skips work nobody will read.

Responses are typed: a successful request yields a `QueryResult` (which
still compares/iterates like the plain row list for back-compat), a failed
one raises a `QueryError` on the caller's thread — parse failures raise
`ParseQueryError`, which is also a `ParseError`. Raw `Exception` objects
never travel inside result lists.

All requests in all batches share one QueryEngine and therefore ONE plan/
compile cache and one device scan cache — plus a server-side cache of
`PreparedQuery` handles keyed by query text, so repeated queries skip
parsing and planning entirely. The first request of a given query shape
pays calibration + compilation, every later request (from any client) is a
cache hit dispatching a single precompiled device program. `stats()`
reports the cache hit rates so operators can watch the warm fraction.

The store is live: `update(text)` applies `INSERT DATA` / `DELETE DATA`
requests through the delta-block write path. Cached prepared handles stay
valid across updates — each run re-stages its scans at the store's current
version, so warm plan shapes keep dispatching precompiled programs as long
as writes stay within their capacity buckets. `stats()["store"]` and
`stats()["updates"]` report store version, tail/tombstone sizes, and the
server's cumulative write counters.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

from repro.serve.batcher import BatchTimeout, Deferred, MicroBatcher
from repro.serve.decode import DecodePool
from repro.sparql.engine import (
    PendingDecode,
    PreparedQuery,
    QueryEngine,
    UpdateResult,
)
from repro.sparql.parser import ParseError


@dataclasses.dataclass
class QueryResult:
    """Successful response envelope: decoded rows + result metadata.

    Sequence-compatible with the historical `list[dict]` return shape:
    len/iter/index/== all defer to `rows`.
    """

    rows: list[dict[str, str]]
    vars: tuple[str, ...]
    from_cache: bool  # served via a cached PreparedQuery handle

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)

    def __getitem__(self, i):
        return self.rows[i]

    def __eq__(self, other) -> bool:
        if isinstance(other, QueryResult):
            return self.rows == other.rows
        if isinstance(other, list):
            return self.rows == other
        return NotImplemented


class QueryError(Exception):
    """Typed failure envelope: what failed (parse/plan/execution) and for
    which query. Raised on the submitting caller's thread, never returned
    inside a result list."""

    def __init__(self, kind: str, message: str, query: str):
        super().__init__(message)
        self.kind = kind
        self.message = message
        self.query = query

    def __str__(self) -> str:
        return f"[{self.kind}] {self.message}"


class ParseQueryError(QueryError, ParseError):
    """Parse-stage QueryError; also a sparql.parser.ParseError so callers
    catching ParseError keep working."""

    def __init__(self, message: str, query: str):
        QueryError.__init__(self, "parse", message, query)


class QueryTimeoutError(QueryError, TimeoutError):
    """The per-request wall-clock deadline expired before the result
    resolved (kind="timeout"); also a TimeoutError. The batch the request
    rode in keeps running and stays cached — only this caller gives up,
    and the decode stage skips the abandoned slot."""

    def __init__(self, message: str, query: str):
        QueryError.__init__(self, "timeout", message, query)


@dataclasses.dataclass
class SPARQLServer:
    engine: QueryEngine
    max_batch: int = 8
    max_wait_s: float = 0.002
    prepared_cache_entries: int = 256
    batch_execution: bool = True  # stack same-shape batchmates per dispatch
    # decode pipeline: worker threads resolving PendingDecode slots off the
    # batcher thread (0 = synchronous decode on the batcher thread)
    decode_workers: int = 2
    decode_queue: int = 64  # backpressure bound on undecoded results
    default_timeout_s: float = 30.0  # per-request deadline when none given

    def __post_init__(self):
        self._decode_pool = (
            DecodePool(self.decode_workers, self.decode_queue)
            if self.decode_workers > 0 else None
        )
        self._batcher = MicroBatcher(self._run_batch, self.max_batch,
                                     self.max_wait_s,
                                     decode_pool=self._decode_pool)
        self._prepared: OrderedDict[str, PreparedQuery] = OrderedDict()
        self._prepared_hits = 0
        self._prepared_misses = 0
        self._timeouts = 0  # per-request deadline expirations
        # update-endpoint counters (stats()["updates"])
        self._update_requests = 0
        self._rows_inserted = 0
        self._rows_deleted = 0

    def _prepared_handle(self, text: str) -> tuple[PreparedQuery, bool]:
        pq = self._prepared.get(text)
        if pq is not None:
            self._prepared_hits += 1
            self._prepared.move_to_end(text)
            return pq, True
        self._prepared_misses += 1
        pq = self.engine.prepare(text)
        self._prepared[text] = pq
        while len(self._prepared) > self.prepared_cache_entries:
            self._prepared.popitem(last=False)
        return pq, False

    def _deferred(self, pending: PendingDecode, text: str,
                  cached: bool) -> Deferred:
        """Wrap a dispatched-but-undecoded slot for the decode stage: the
        callable resolves the decode and types the envelope; any decode
        failure becomes a QueryError raised on the submitter's thread."""
        def fn() -> QueryResult:
            try:
                rs = pending.resolve()
            except Exception as e:
                raise QueryError("decode", str(e), query=text) from e
            return QueryResult(rows=rs.rows, vars=rs.vars, from_cache=cached)
        return Deferred(fn)

    def _run_batch(
        self, queries: list[str]
    ) -> "list[QueryResult | QueryError | Deferred]":
        """The pipeline's DISPATCH stage, on the batcher thread: same-shape
        (and padded near-miss-shape) queries coalesce into stacked device
        dispatches via engine.run_batch_pipelined, and each successfully
        dispatched slot returns as a Deferred whose decode runs on the
        decode pool. Every failure (parse, plan, execution) stays isolated
        to its own slot — one bad query never fails its batchmates or the
        worker thread."""
        outs: list[QueryResult | QueryError | Deferred | None] = (
            [None] * len(queries)
        )
        pending: list[tuple[int, "PreparedQuery", bool]] = []
        for i, text in enumerate(queries):
            try:
                pq, cached = self._prepared_handle(text)
            except ParseError as e:
                outs[i] = ParseQueryError(str(e), query=text)
            except Exception as e:
                outs[i] = QueryError("plan", str(e), query=text)
            else:
                pending.append((i, pq, cached))
        if not pending:
            return outs
        if self.batch_execution:
            outcomes = self.engine.run_batch_pipelined(
                [pq for _, pq, _ in pending]
            )
        else:
            outcomes = []
            for _, pq, _ in pending:
                try:
                    outcomes.append(pq._run_pending())
                except Exception as e:
                    outcomes.append(e)
        for (i, pq, cached), oc in zip(pending, outcomes):
            if isinstance(oc, PendingDecode):
                outs[i] = self._deferred(oc, queries[i], cached)
            elif isinstance(oc, Exception):
                outs[i] = QueryError("execution", str(oc), query=queries[i])
            else:
                # an inline-resolved ResultSet (e.g. a cold calibration run
                # that decoded eagerly on a non-pipelined engine path)
                outs[i] = QueryResult(
                    rows=oc.rows, vars=oc.vars, from_cache=cached
                )
        return outs

    def query(self, text: str,
              timeout_ms: "float | None" = None) -> QueryResult:
        """Submit one query; raises QueryError (a ParseQueryError for parse
        failures) on this thread if the request failed. `timeout_ms` caps
        the request's wall-clock wait — dispatch queueing AND decode — and
        raises QueryTimeoutError on expiry (the server keeps running the
        batch; only this caller gives up)."""
        timeout = (
            timeout_ms / 1000.0 if timeout_ms is not None
            else self.default_timeout_s
        )
        try:
            return self._batcher.submit(text, timeout=timeout)
        except BatchTimeout as e:
            self._timeouts += 1
            raise QueryTimeoutError(
                f"query did not resolve within {timeout * 1000:.0f} ms",
                query=text,
            ) from e

    def update(self, text: str) -> UpdateResult:
        """Apply a SPARQL UPDATE request (`INSERT DATA` / `DELETE DATA`,
        `;`-separated) against the live store.

        Updates run synchronously on the caller's thread under the store's
        snapshot lock — in-flight query batches that already staged their
        scans keep their pinned snapshot, later requests see the new store
        version. Prepared handles cached by the server stay valid: they
        re-stage scans at the current version on their next run (a query
        whose scan outgrows its capacity bucket simply compiles one new
        plan-cache entry). Parse failures raise ParseQueryError."""
        try:
            res = self.engine.update(text)
        except ParseQueryError:
            raise
        except ParseError as e:
            raise ParseQueryError(str(e), query=text) from e
        self._update_requests += 1
        self._rows_inserted += res.inserted
        self._rows_deleted += res.deleted
        return res

    def explain(self, text: str) -> str:
        """Host-side plan report (algebra, optimizer trace, physical plan,
        cache state) for a query, through the prepared-handle cache."""
        pq, _ = self._prepared_handle(text)
        return pq.explain()

    def save_cache(self, path: str) -> int:
        """Persist the engine's learned bucket signatures (see
        QueryEngine.save_cache); a restarted server constructed with
        QueryEngine(warmup_path=...) skips calibration for these shapes."""
        return self.engine.save_cache(path)

    def stats(self) -> dict:
        total = self._prepared_hits + self._prepared_misses
        eng = self.engine
        sd, sq = eng.stacked_dispatches, eng.stacked_queries
        # snapshot before sorting: the worker thread inserts new histogram
        # keys concurrently with a client thread reading stats
        width_hist = dict(eng.batch_width_hist)
        arrival_hist = dict(self._batcher.batch_size_hist)
        pc, rc = eng.padded_cells, eng.real_cells
        return {
            "batches": self._batcher.n_batches,
            "requests": self._batcher.n_requests,
            "timeouts": self._timeouts,
            "plan_cache": self.engine.cache_stats(),
            "scan_cache": self.engine.store.scan_cache_stats(),
            "store": self.engine.store.write_stats(),
            "updates": {
                "requests": self._update_requests,
                "rows_inserted": self._rows_inserted,
                "rows_deleted": self._rows_deleted,
            },
            "prepared_cache": {
                "entries": len(self._prepared),
                "hits": self._prepared_hits,
                "misses": self._prepared_misses,
                "hit_rate": self._prepared_hits / total if total else 0.0,
            },
            # the coalescing win: how many device dispatches were stacked,
            # how many queries each one carried, at which lane widths, and
            # what cross-shape padding bought (merges taken/rejected and
            # the padded-vs-real scan-cell waste ratio)
            "batched": {
                "stacked_dispatches": sd,
                "stacked_queries": sq,
                "queries_per_dispatch": sq / sd if sd else 0.0,
                "batch_width_hist": dict(sorted(width_hist.items())),
                "arrival_batch_hist": dict(sorted(arrival_hist.items())),
                "padding": {
                    "padded_groups": eng.padded_groups,
                    "pad_rejects": eng.pad_rejects,
                    "padded_cells": pc,
                    "real_cells": rc,
                    "waste_ratio": (pc - rc) / rc if rc else 0.0,
                },
            },
            # the two pipeline stages' health: slots handed to the decode
            # side, batcher time spent in dispatch, device busy seconds
            # (1 - Δdevice_time_s / wall is the bench's idle fraction)
            "pipeline": {
                "deferred": self._batcher.n_deferred,
                "dispatch_s": self._batcher.dispatch_s,
                "device_time_s": eng.device_time_s,
                "decode": (
                    self._decode_pool.stats()
                    if self._decode_pool is not None else None
                ),
            },
        }

    def close(self) -> None:
        self._batcher.close()
        if self._decode_pool is not None:
            self._decode_pool.close()
