"""SPARQL serving front-end: the MapSQ framework (Fig 1) as a service.

Requests (query strings) flow through the MicroBatcher; the engine executes
each batch — partial matching per pattern, then the join chain on device.
Batching amortizes dispatch overhead exactly like the paper's
CPU-assigns / GPU-computes split.

All requests in all batches share one QueryEngine and therefore ONE plan/
compile cache and one device scan cache: the first request of a given query
shape pays calibration + compilation, every later request (from any client)
is a cache hit dispatching a single precompiled device program. `stats()`
reports the plan-cache hit rate so operators can watch the warm fraction.
"""
from __future__ import annotations

import dataclasses

from repro.serve.batcher import MicroBatcher
from repro.sparql.engine import QueryEngine


@dataclasses.dataclass
class SPARQLServer:
    engine: QueryEngine
    max_batch: int = 8
    max_wait_s: float = 0.002

    def __post_init__(self):
        self._batcher = MicroBatcher(self._run_batch, self.max_batch,
                                     self.max_wait_s)

    def _run_batch(self, queries: list[str]) -> list:
        # per-request isolation: one bad query (parse error, overflow) fails
        # that request only, never its batchmates or the worker thread
        out = []
        for q in queries:
            try:
                out.append(self.engine.query(q))
            except Exception as e:
                out.append(e)
        return out

    def query(self, text: str) -> list[dict]:
        return self._batcher.submit(text)

    def stats(self) -> dict:
        return {
            "batches": self._batcher.n_batches,
            "requests": self._batcher.n_requests,
            "plan_cache": self.engine.cache_stats(),
            "scan_cache": self.engine.store.scan_cache_stats(),
        }

    def close(self) -> None:
        self._batcher.close()
