"""SPARQL serving front-end: the MapSQ framework (Fig 1) as a service.

Requests (query strings) flow through the MicroBatcher; the engine executes
each batch — partial matching per pattern, then the MapReduce join chain on
device. Batching amortizes dispatch overhead exactly like the paper's
CPU-assigns / GPU-computes split.
"""
from __future__ import annotations

import dataclasses

from repro.serve.batcher import MicroBatcher
from repro.sparql.engine import QueryEngine


@dataclasses.dataclass
class SPARQLServer:
    engine: QueryEngine
    max_batch: int = 8
    max_wait_s: float = 0.002

    def __post_init__(self):
        self._batcher = MicroBatcher(self._run_batch, self.max_batch,
                                     self.max_wait_s)

    def _run_batch(self, queries: list[str]) -> list[list[dict]]:
        return [self.engine.query(q) for q in queries]

    def query(self, text: str) -> list[dict]:
        return self._batcher.submit(text)

    def stats(self) -> dict:
        return {
            "batches": self._batcher.n_batches,
            "requests": self._batcher.n_requests,
        }

    def close(self) -> None:
        self._batcher.close()
