"""Model zoo: LM transformers (dense + MoE), GNNs, RecSys.

Every irregular-compute model (MoE dispatch, GNN aggregation, embedding
bags) is built on repro.core.segments — the paper's sort→segment pipeline.
"""
