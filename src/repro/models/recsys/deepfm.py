"""DeepFM (arXiv:1703.04247): sparse embedding tables → FM interaction →
deep MLP. The embedding LOOKUP is the hot path and JAX has no EmbeddingBag —
we build it from `jnp.take` + `segment_sum` (local form) and, distributed,
as the MapSQ shuffle: ids routed to the table shard that owns them over the
"model" axis (sort → bucketize → all_to_all), rows shipped back, combined.
This reuses moe.route_plan / scatter / gather — one join, three consumers.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import moe as M
from repro.models.gnn.common import init_mlp, mlp
from repro.core import compat


@dataclasses.dataclass(frozen=True)
class DeepFMConfig:
    n_sparse: int = 39
    embed_dim: int = 10
    mlp_dims: tuple[int, ...] = (400, 400, 400)
    rows_per_field: int = 860_000  # ~33.5M rows total (Criteo-scale)
    n_item_fields: int = 3  # retrieval: fields forming the item tower
    shuffle_capacity_factor: float = 1.5

    @property
    def total_rows(self) -> int:
        return self.n_sparse * self.rows_per_field


def init_params(key: jax.Array, cfg: DeepFMConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in = cfg.n_sparse * cfg.embed_dim
    return {
        "table": jax.random.normal(
            k1, (cfg.total_rows, cfg.embed_dim), jnp.float32
        ) * 0.01,
        "fm_w": jax.random.normal(k2, (cfg.total_rows, 1), jnp.float32) * 0.01,
        "mlp": init_mlp(k3, [d_in, *cfg.mlp_dims, 1]),
        "bias": jnp.zeros((), jnp.float32),
    }


def param_specs(cfg: DeepFMConfig) -> dict:
    return {
        "table": P("model", None),  # row-sharded: the huge array
        "fm_w": P("model", None),
        "mlp": [{"w": P(None, None), "b": P(None)} for _ in
                range(len(cfg.mlp_dims) + 1)],
        "bias": P(),
    }


# ---------------------------------------------------------------------------
# EmbeddingBag
# ---------------------------------------------------------------------------

def embedding_bag_local(table: jax.Array, flat_ids: jax.Array,
                        bag_ids: jax.Array, n_bags: int) -> jax.Array:
    """Single-device EmbeddingBag: take + sorted segment_sum (the oracle)."""
    rows = jnp.take(table, jnp.clip(flat_ids, 0, table.shape[0] - 1), axis=0)
    return jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags,
                               indices_are_sorted=True)


def _sharded_lookup_local(table_shard, ids, *, expert_axis: str, cap: int):
    """shard_map body: route each id to its owner shard, gather, route back.

    table_shard: (R_local, D) — this chip's row range;
    ids: (n_local,) — this chip's slice of the flattened id stream.
    Returns (n_local, D) embedding rows.
    """
    ep = compat.axis_size(expert_axis)
    er = jax.lax.axis_index(expert_axis)
    r_local = table_shard.shape[0]
    n = ids.shape[0]
    owner = (ids // r_local).astype(jnp.int32)
    order, slot, ok = M.route_plan(owner, jnp.ones((n,), bool), ep, cap)
    send_ids = M.scatter_to_buckets(ids.astype(jnp.int32), order, slot, ok,
                                    ep, cap)
    recv_ids = jax.lax.all_to_all(send_ids, expert_axis, 0, 0, tiled=False)
    local_idx = jnp.clip(recv_ids - er * r_local, 0, r_local - 1)
    rows = jnp.take(table_shard, local_idx.reshape(-1), axis=0)
    back = jax.lax.all_to_all(rows.reshape(ep, cap, -1), expert_axis, 0, 0,
                              tiled=False)
    return M.gather_from_buckets(back, order, slot, ok, n)


def make_sharded_lookup(mesh, dp: tuple[str, ...], cap: int):
    """jit-compatible distributed lookup: ids (n_flat,) sharded over
    (dp..., model) jointly; table (R, D) row-sharded on model."""
    spec_ids = P(dp + ("model",))
    return compat.shard_map(
        partial(_sharded_lookup_local, expert_axis="model", cap=cap),
        mesh=mesh,
        in_specs=(P("model", None), spec_ids),
        out_specs=P(dp + ("model",), None),
        check_vma=False,
    )


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

def _lookup(params, ids, cfg, lookup_fn):
    """ids: (B, F) field-offset-free ids in [0, rows_per_field). Returns
    (emb (B, F, D), fm1 (B, F))."""
    b, f = ids.shape
    offsets = (jnp.arange(f, dtype=jnp.int32) * cfg.rows_per_field)[None]
    flat = (ids + offsets).reshape(-1)
    if lookup_fn is None:
        emb = embedding_bag_local(params["table"], flat,
                                  jnp.arange(flat.shape[0]), flat.shape[0])
        fm1 = embedding_bag_local(params["fm_w"], flat,
                                  jnp.arange(flat.shape[0]), flat.shape[0])
    else:
        emb = lookup_fn(params["table"], flat)
        fm1 = lookup_fn(params["fm_w"], flat)
    return emb.reshape(b, f, cfg.embed_dim), fm1.reshape(b, f)


def forward(params: dict, ids: jax.Array, cfg: DeepFMConfig,
            lookup_fn=None) -> jax.Array:
    """CTR logits (B,). ids: (B, n_sparse) int32."""
    emb, fm1 = _lookup(params, ids, cfg, lookup_fn)
    # FM second order: 0.5 * ((Σv)² − Σv²), summed over embed dim
    s = jnp.sum(emb, axis=1)
    fm2 = 0.5 * jnp.sum(s * s - jnp.sum(emb * emb, axis=1), axis=-1)
    deep = mlp(params["mlp"], emb.reshape(emb.shape[0], -1))[:, 0]
    return params["bias"] + fm1.sum(axis=1) + fm2 + deep


def bce_loss(params, ids, labels, cfg, lookup_fn=None):
    logits = forward(params, ids, cfg, lookup_fn)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def retrieval_scores(params: dict, user_ids: jax.Array, cand_ids: jax.Array,
                     cfg: DeepFMConfig, lookup_fn=None) -> jax.Array:
    """Score 1 query against n_candidates items: batched dot, not a loop.

    user_ids: (1, n_sparse); cand_ids: (n_cand, n_item_fields).
    Item tower = sum of item-field embeddings; score = item · user.
    The user tower is a handful of rows — always the local gather path
    (a 39-id shuffle can't shard over 256+ chips, and shouldn't).
    """
    emb_u, _ = _lookup(params, user_ids, cfg, None)
    u = jnp.sum(emb_u[0], axis=0)  # (D,)
    b, f = cand_ids.shape
    offsets = (jnp.arange(f, dtype=jnp.int32) * cfg.rows_per_field)[None]
    flat = (cand_ids + offsets).reshape(-1)
    if lookup_fn is None:
        rows = jnp.take(params["table"],
                        jnp.clip(flat, 0, cfg.total_rows - 1), axis=0)
    else:
        rows = lookup_fn(params["table"], flat)
    items = rows.reshape(b, f, cfg.embed_dim).sum(axis=1)  # (n_cand, D)
    return items @ u
