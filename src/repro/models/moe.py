"""Mixture-of-Experts FFN with MapSQ-style sort-based expert-parallel dispatch.

The MoE token→expert exchange IS the paper's MapReduce join (DESIGN.md §3):

  Map    — every (token, expert-choice) assignment is tagged with its
           destination chip (expert owner), exactly the paper's key tagging;
  Sort   — assignments are sorted by destination (``route_plan``);
  Shuffle— one ``all_to_all`` over the expert (model) mesh axis moves token
           vectors to expert owners — the MapReduce shuffle as a collective;
  Reduce — on the expert side a second sort groups rows into contiguous
           per-expert segments for the grouped GEMM; the weighted combine
           back on the token side is the segment-sum reduce.

Two realizations, one logical join:
  * ``moe_ffn_ep_local`` — the shard_map expert-parallel path for training
    and prefill (tokens sharded over the model axis, sort-based dispatch).
  * ``moe_ffn_onehot`` — a GShard-style one-hot-dispatch einsum used at
    decode time, where per-shard token counts are too small (< #chips) to
    shard; the dispatch/combine tensors stay tiny because T is tiny.

Expert counts that don't divide the mesh axis (granite's 40 experts on a
16-way axis) are padded to the next multiple; padded experts get -inf router
logits and are never selected (20% dead weight memory for granite, noted in
DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import compat

from repro.core.segments import segment_offsets_from_sorted


class MoEParams(NamedTuple):
    router: jax.Array  # (D, E_pad)
    we_gate: jax.Array  # (E_pad, D, Fe)
    we_up: jax.Array  # (E_pad, D, Fe)
    we_down: jax.Array  # (E_pad, Fe, D)


@dataclasses.dataclass(frozen=True)
class MoESettings:
    n_experts: int
    top_k: int
    d_expert_ff: int
    capacity_factor: float = 2.0

    def e_pad(self, ep: int) -> int:
        return ((self.n_experts + ep - 1) // ep) * ep


# ---------------------------------------------------------------------------
# Routing machinery (the Map + Sort phases, shared with core/distributed)
# ---------------------------------------------------------------------------

def route_plan(part: jax.Array, valid: jax.Array, num_parts: int, cap: int):
    """Sort rows by destination partition and assign buffer slots.

    Returns (order, slot, ok):
      order — permutation sorting rows by destination (stable);
      slot  — flat index into a (num_parts, cap) buffer, for sorted row j;
      ok    — sorted-row validity (dest in range, within capacity).
    """
    n = part.shape[0]
    part = jnp.where(valid, part, num_parts).astype(jnp.int32)
    order = jnp.argsort(part, stable=True)
    part_s = part[order]
    offsets = segment_offsets_from_sorted(part_s, num_parts)
    pos = jnp.arange(n, dtype=jnp.int32) - offsets[jnp.clip(part_s, 0, num_parts - 1)]
    ok = (part_s < num_parts) & (pos < cap)
    slot = jnp.where(ok, part_s * cap + pos, num_parts * cap)
    return order, slot, ok


def scatter_to_buckets(data, order, slot, ok, num_parts: int, cap: int):
    """Pack rows (in original order) into a (num_parts, cap, ...) buffer."""
    trail = data.shape[1:]
    src = data[order]
    mask = ok.reshape((-1,) + (1,) * len(trail))
    buf = jnp.zeros((num_parts * cap,) + trail, data.dtype)
    buf = buf.at[slot].set(jnp.where(mask, src, 0), mode="drop")
    return buf.reshape((num_parts, cap) + trail)


def gather_from_buckets(buf, order, slot, ok, n_rows: int):
    """Inverse of scatter_to_buckets: recover per-row values (original order).
    Rows that were dropped (not ok) come back as zeros."""
    flat = buf.reshape((-1,) + buf.shape[2:])
    res_sorted = flat[jnp.clip(slot, 0, flat.shape[0] - 1)]
    mask = ok.reshape((-1,) + (1,) * (flat.ndim - 1))
    res_sorted = jnp.where(mask, res_sorted, 0)
    out = jnp.zeros((n_rows,) + flat.shape[1:], flat.dtype)
    return out.at[order].set(res_sorted)


# ---------------------------------------------------------------------------
# Expert-parallel path (training / prefill) — runs INSIDE shard_map
# ---------------------------------------------------------------------------

def moe_ffn_ep_local(
    p: MoEParams,
    x: jax.Array,
    st: MoESettings,
    *,
    expert_axis: str,
):
    """Per-device body of the EP MoE layer.

    x: (B_loc, S_loc, D) — this device's token shard (S split over the
    expert/model axis by shard_map's in_spec, so every token exists exactly
    once per data-parallel group; gradients are exact).
    p: this device's expert shard — we_*: (e_local, ...), router replicated.
    """
    ep = compat.axis_size(expert_axis)
    er = jax.lax.axis_index(expert_axis)
    b, s_loc, d = x.shape
    t_my = b * s_loc
    e_pad = st.e_pad(ep)
    e_local = e_pad // ep
    k = st.top_k

    x_my = x.reshape(t_my, d)
    # Router (Map phase: key = expert id).
    logits = x_my.astype(jnp.float32) @ p.router.astype(jnp.float32)
    logits = jnp.where(jnp.arange(e_pad) < st.n_experts, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, k)  # (t_my, k)

    a_e = eidx.reshape(-1).astype(jnp.int32)  # (A,) assignment expert ids
    a_tok = jnp.repeat(jnp.arange(t_my, dtype=jnp.int32), k)
    a_gate = gate_vals.reshape(-1)
    n_assign = a_e.shape[0]

    # Sort + bucketize by destination chip, shuffle (all_to_all).
    chip_cap = _round8(int(n_assign / ep * st.capacity_factor) + 8)
    dest = a_e // e_local
    order, slot, ok = route_plan(dest, jnp.ones((n_assign,), bool), ep, chip_cap)
    send_x = scatter_to_buckets(x_my[a_tok], order, slot, ok, ep, chip_cap)
    send_e = scatter_to_buckets(a_e, order, slot, ok, ep, chip_cap)
    send_v = scatter_to_buckets(
        jnp.ones((n_assign,), jnp.int32), order, slot, ok, ep, chip_cap
    )
    recv_x = jax.lax.all_to_all(send_x, expert_axis, 0, 0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, expert_axis, 0, 0, tiled=False)
    recv_v = jax.lax.all_to_all(send_v, expert_axis, 0, 0, tiled=False)

    # Expert-side Reduce: second sort groups rows into per-expert segments.
    n_recv = ep * chip_cap
    rx = recv_x.reshape(n_recv, d)
    re_loc = recv_e.reshape(-1) - er * e_local
    rv = recv_v.reshape(-1) > 0
    expert_cap = _round8(int(n_assign / e_local * st.capacity_factor) + 8)
    order2, slot2, ok2 = route_plan(re_loc, rv, e_local, expert_cap)
    ebuf = scatter_to_buckets(rx, order2, slot2, ok2, e_local, expert_cap)

    # Grouped GEMM over contiguous expert segments (SwiGLU experts).
    g = jnp.einsum("ecd,edf->ecf", ebuf, p.we_gate,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", ebuf, p.we_up,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    eout = jnp.einsum("ecf,efd->ecd", h, p.we_down,
                      preferred_element_type=jnp.float32).astype(x.dtype)

    # Return trip: un-bucket on the expert side, shuffle back, un-bucket at
    # the sender, weighted segment-sum combine over each token's k slots.
    res_recv = gather_from_buckets(eout, order2, slot2, ok2, n_recv)
    back = jax.lax.all_to_all(
        res_recv.reshape(ep, chip_cap, d), expert_axis, 0, 0, tiled=False
    )
    res_asn = gather_from_buckets(back, order, slot, ok, n_assign)
    combined = jnp.zeros((t_my, d), jnp.float32)
    combined = combined.at[a_tok].add(
        res_asn.astype(jnp.float32) * a_gate[:, None]
    )
    return combined.astype(x.dtype).reshape(b, s_loc, d)


def _round8(n: int) -> int:
    return ((n + 7) // 8) * 8


# ---------------------------------------------------------------------------
# One-hot dispatch path (decode: tiny per-shard token counts) — plain pjit
# ---------------------------------------------------------------------------

def moe_ffn_onehot(p: MoEParams, x: jax.Array, st: MoESettings, e_pad: int,
                   capacity: int | None = None):
    """GShard-style dispatch/combine einsum MoE for small T (decode).

    x: (B, S, D) with B*S small. The (T, E, C) dispatch tensor is the dense
    materialization of the same token↔expert join; it is only affordable
    because T is tiny at decode time.
    """
    b, s, d = x.shape
    t = b * s
    k = st.top_k
    cap = capacity or _round8(max(k, int(t * k / st.n_experts * 4) + 1))
    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ p.router.astype(jnp.float32)
    logits = jnp.where(jnp.arange(e_pad) < st.n_experts, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, k)  # (T, k)
    onehot = jax.nn.one_hot(eidx, e_pad, dtype=jnp.int32)  # (T, k, E)
    # position of each assignment within its expert (running count over T*k)
    flat = onehot.reshape(t * k, e_pad)
    pos = jnp.cumsum(flat, axis=0) - flat  # (T*k, E)
    pos = pos.reshape(t, k, e_pad)
    within = pos < cap
    disp = (onehot * within).astype(x.dtype)  # (T, k, E)
    # dispatch tensor (T, E, C): 1 where token t goes to expert e slot c
    posc = jnp.sum(pos * onehot, axis=-1)  # (T, k) slot per assignment
    dmask = jnp.einsum("tke,tkc->tec", disp,
                       jax.nn.one_hot(posc, cap, dtype=x.dtype))
    xe = jnp.einsum("tec,td->ecd", dmask, xf)  # (E, C, D)
    g = jnp.einsum("ecd,edf->ecf", xe, p.we_gate,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xe, p.we_up,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    eo = jnp.einsum("ecf,efd->ecd", h, p.we_down,
                    preferred_element_type=jnp.float32).astype(jnp.float32)
    comb = jnp.einsum("tke,tkc->tec", disp * gate_vals[..., None].astype(x.dtype),
                      jax.nn.one_hot(posc, cap, dtype=x.dtype)).astype(jnp.float32)
    y = jnp.einsum("tec,ecd->td", comb, eo)
    return y.astype(x.dtype).reshape(b, s, d)


def moe_aux_loss(p: MoEParams, x: jax.Array, st: MoESettings, e_pad: int):
    """Switch-style load-balance loss, computed in the pjit world (cheap:
    one (T, E) router matmul; the EP path doesn't have to export stats)."""
    xf = x.reshape(-1, x.shape[-1])
    logits = xf.astype(jnp.float32) @ p.router.astype(jnp.float32)
    logits = jnp.where(jnp.arange(e_pad) < st.n_experts, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    _, eidx = jax.lax.top_k(probs, st.top_k)
    f = jnp.mean(
        jax.nn.one_hot(eidx, e_pad, dtype=jnp.float32).sum(axis=1), axis=0
    )
    pmean = jnp.mean(probs, axis=0)
    return st.n_experts * jnp.sum(f * pmean) / st.top_k


def init_moe_params(key, d_model: int, st: MoESettings, ep: int, dtype):
    e_pad = st.e_pad(ep)
    ks = jax.random.split(key, 4)
    fe = st.d_expert_ff
    live = (jnp.arange(e_pad) < st.n_experts).astype(jnp.float32)

    def w(k, shape, fan_in):
        arr = jax.random.normal(k, shape, jnp.float32) * fan_in**-0.5
        return (arr * live[:, None, None]).astype(dtype)

    router = (
        jax.random.normal(ks[0], (d_model, e_pad), jnp.float32) * d_model**-0.5
    ).astype(jnp.float32)
    return MoEParams(
        router=router,
        we_gate=w(ks[1], (e_pad, d_model, fe), d_model),
        we_up=w(ks[2], (e_pad, d_model, fe), d_model),
        we_down=w(ks[3], (e_pad, fe, d_model), fe),
    )
