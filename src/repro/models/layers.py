"""Transformer building blocks: RMSNorm, RoPE, GQA attention (chunked
online-softmax for long prefill), SwiGLU FFN.

Everything is shape-static and scan-friendly: per-layer weights arrive as
pytrees of arrays WITHOUT the layer axis (the caller scans over stacked
weights), and attention takes an `is_global` scalar so local/global layer
patterns (gemma3's 5:1) stay branch-free inside `lax.scan`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, Dh), positions: (..., S)."""
    d_half = x.shape[-1] // 2
    freq = theta ** (-jnp.arange(0, d_half, dtype=jnp.float32) / d_half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, d_half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :d_half], x[..., d_half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


class AttnParams(NamedTuple):
    wq: jax.Array  # (D, H*Dh)
    wk: jax.Array  # (D, K*Dh)
    wv: jax.Array  # (D, K*Dh)
    wo: jax.Array  # (H*Dh, D)
    bq: jax.Array | None = None  # (H*Dh,) — qwen-style QKV bias
    bk: jax.Array | None = None
    bv: jax.Array | None = None


def _project_qkv(p: AttnParams, x: jax.Array, n_heads: int, n_kv: int, d_head: int):
    b, s, _ = x.shape
    q = x @ p.wq
    k = x @ p.wk
    v = x @ p.wv
    if p.bq is not None:
        q, k, v = q + p.bq, k + p.bk, v + p.bv
    return (
        q.reshape(b, s, n_heads, d_head),
        k.reshape(b, s, n_kv, d_head),
        v.reshape(b, s, n_kv, d_head),
    )


def _grouped_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """GQA scores without materializing repeated KV.

    q: (B, Sq, H, Dh) grouped as (B, Sq, K, G, Dh); k: (B, Sk, K, Dh).
    Returns (B, K, G, Sq, Sk) float32.
    """
    b, sq, h, dh = q.shape
    kheads = k.shape[2]
    g = h // kheads
    qg = q.reshape(b, sq, kheads, g, dh)
    return jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32
    )


def _grouped_values(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (B, K, G, Sq, Sk), v: (B, Sk, K, Dh) -> (B, Sq, H, Dh)."""
    b, kheads, g, sq, _ = probs.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, kheads * g, v.shape[-1])


def attention_prefill(
    p: AttnParams,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    rope_theta: float,
    is_global,
    window: int,
    kv_chunk: int = 1024,
    scale: float | None = None,
):
    """Causal self-attention, chunked over KV (online softmax).

    Never materializes the full (Sq, Sk) score matrix: a `lax.scan` walks KV
    chunks carrying running (max, sum, out) — the standard flash-attention
    recurrence in pure JAX. `is_global` is a traced bool scalar: local layers
    add a sliding-window mask of width `window` (branch-free, one code path
    for gemma3's 5:1 local:global pattern).
    """
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(p, x, n_heads, n_kv, d_head)
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)
    sc = scale if scale is not None else d_head**-0.5
    q = q * sc

    n_chunks = max(1, s // kv_chunk)
    ck = k.reshape(b, n_chunks, kv_chunk, n_kv, d_head).transpose(1, 0, 2, 3, 4)
    cv = v.reshape(b, n_chunks, kv_chunk, n_kv, d_head).transpose(1, 0, 2, 3, 4)
    g = n_heads // n_kv
    q_idx = jnp.arange(s, dtype=jnp.int32)

    def step(carry, chunk):
        m, l, o = carry
        kc, vc, c0 = chunk  # kc/vc: (B, C, K, Dh); c0: chunk start offset
        sc_ = _grouped_scores(q, kc)  # (B, K, G, Sq, C)
        k_idx = c0 + jnp.arange(kv_chunk, dtype=jnp.int32)
        causal = q_idx[:, None] >= k_idx[None, :]
        in_window = (q_idx[:, None] - k_idx[None, :]) < window
        mask = causal & (is_global | in_window)
        sc_ = jnp.where(mask[None, None, None], sc_, NEG_INF)
        m_new = jnp.maximum(m, sc_.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pr = jnp.exp(sc_ - m_new[..., None])
        l_new = l * alpha + pr.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", pr, vc.astype(jnp.float32)
        )
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, n_kv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, s), jnp.float32)
    o0 = jnp.zeros((b, n_kv, g, s, d_head), jnp.float32)
    starts = jnp.arange(n_chunks, dtype=jnp.int32) * kv_chunk
    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0), (ck, cv, starts))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, s, n_heads * d_head)  # (B,S,H*Dh)
    return o.astype(x.dtype) @ p.wo


def attention_decode(
    p: AttnParams,
    x: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    rope_theta: float,
    is_global,
    window: int,
    scale: float | None = None,
):
    """One-token decode against a KV cache.

    x: (B, 1, D); caches: (B, S_max, K, Dh); cache_len: () current length.
    Returns (attn_out (B, 1, D), k_cache', v_cache'). Linear in S_max —
    decode is sub-quadratic by construction, which is why `long_500k` runs
    for every LM arch (see DESIGN.md §6).
    """
    b, _, _ = x.shape
    s_max = k_cache.shape[1]
    pos = cache_len  # scalar: write position of the new token
    q, k_new, v_new = _project_qkv(p, x, n_heads, n_kv, d_head)
    q = rope(q, pos[None, None].astype(jnp.int32), rope_theta)
    k_new = rope(k_new, pos[None, None].astype(jnp.int32), rope_theta)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, pos, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, pos, 0, 0)
    )
    sc = scale if scale is not None else d_head**-0.5
    scores = _grouped_scores(q * sc, k_cache)  # (B, K, G, 1, S_max)
    k_idx = jnp.arange(s_max, dtype=jnp.int32)
    visible = k_idx <= pos
    in_window = (pos - k_idx) < window
    mask = visible & (is_global | in_window)
    scores = jnp.where(mask[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = _grouped_values(probs, v_cache)  # (B, 1, H, Dh)
    o = o.reshape(b, 1, n_heads * d_head).astype(x.dtype)
    return o @ p.wo, k_cache, v_cache


class FFNParams(NamedTuple):
    w_gate: jax.Array  # (D, F)
    w_up: jax.Array  # (D, F)
    w_down: jax.Array  # (F, D)


def swiglu_ffn(p: FFNParams, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p.w_gate) * (x @ p.w_up)) @ p.w_down


@dataclasses.dataclass(frozen=True)
class InitSpec:
    fan_in_scaled: bool = True


def dense_init(key, shape, fan_in: int, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * fan_in**-0.5).astype(dtype)
