"""Shared GNN machinery: padded static-shape graph batches, MLPs, and
message passing built on repro.core.segments / the segment_reduce kernel.

JAX sparse is BCOO-only, so SpMM/SDDMM-style aggregation is implemented as
edge-index gathers + `segment_sum` scatters over dst-sorted edges — this IS
part of the system (see the assignment brief), and it is exactly the MapSQ
reduce with node ids as join keys.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.segments import segment_softmax


class GraphBatch(NamedTuple):
    """Static-shape (padded) graph. Edges are SORTED BY dst at build time.

    node_feat: (N, F) float; src/dst: (E,) int32; edge_mask: (E,) bool;
    node_mask: (N,) bool; graph_ids: (N,) int32 (molecule batching; 0 for
    single graphs); n_graphs: static int; extras: arch-specific arrays
    (positions for schnet, mesh graphs for graphcast, ...).
    """

    node_feat: jax.Array
    src: jax.Array
    dst: jax.Array
    node_mask: jax.Array
    edge_mask: jax.Array
    graph_ids: jax.Array
    extras: dict[str, Any]

    @property
    def n_nodes(self) -> int:
        return self.node_feat.shape[0]

    @property
    def n_edges(self) -> int:
        return self.src.shape[0]


def aggregate(messages: jax.Array, dst: jax.Array, n_nodes: int,
              edge_mask: jax.Array | None = None,
              sorted_edges: bool = True,
              node_spec: tuple[str, ...] = ()) -> jax.Array:
    """Sum messages into destination nodes (the MapSQ reduce).

    dst must be sorted ascending when sorted_edges=True (our pipelines sort
    at load time); padding edges carry dst == n_nodes and drop out.
    `node_spec`: mesh axes the node dim is sharded over (large graphs —
    §Perf iteration 1); constrains the scatter output so XLA doesn't keep
    replicated node activations resident.
    """
    if edge_mask is not None:
        messages = jnp.where(edge_mask[:, None], messages, 0)
    out = jax.ops.segment_sum(
        messages, dst, num_segments=n_nodes, indices_are_sorted=sorted_edges
    )
    return constrain_nodes(out, node_spec)


def constrain_nodes(x: jax.Array, node_spec: tuple[str, ...]) -> jax.Array:
    """Shard dim 0 (nodes) over `node_spec` axes (no-op when unset)."""
    if not node_spec:
        return x
    from jax.sharding import PartitionSpec as P

    spec = P(node_spec, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def take_nodes(x: jax.Array, ids: jax.Array, edge_mask: jax.Array,
               node_spec: tuple[str, ...] = (),
               shuffle: bool = False) -> jax.Array:
    """x[ids] — either local indexing (GSPMD chooses collectives) or the
    MapSQ shuffle gather (§Perf iteration 4: O(E·d) traffic, never O(N·d))."""
    if shuffle and node_spec:
        from repro.models.gnn.distributed import gather_nodes

        return gather_nodes(x, ids, edge_mask, node_spec)
    return x[ids]


def aggregate_nodes(messages: jax.Array, dst: jax.Array, n_nodes: int,
                    edge_mask: jax.Array,
                    node_spec: tuple[str, ...] = (),
                    shuffle: bool = False) -> jax.Array:
    """aggregate() that can route through the shuffle scatter instead of a
    GSPMD segment_sum (same contract)."""
    if shuffle and node_spec:
        from repro.models.gnn.distributed import scatter_add_nodes

        return scatter_add_nodes(
            jnp.where(edge_mask[:, None], messages, 0), dst, edge_mask,
            n_nodes, node_spec)
    return aggregate(messages, dst, n_nodes, edge_mask, node_spec=node_spec)


def aggregate_softmax(scores: jax.Array, values: jax.Array, dst: jax.Array,
                      n_nodes: int, edge_mask: jax.Array) -> jax.Array:
    """Attention aggregation (GAT): segment softmax over incoming edges,
    then weighted sum. scores: (E, H); values: (E, H, D)."""
    scores = jnp.where(edge_mask[:, None], scores, -1e30)
    h = scores.shape[1]
    outs = []
    for i in range(h):  # heads are few (8); loop keeps segment ops 1-D
        a = segment_softmax(scores[:, i], dst, n_nodes)
        a = jnp.where(edge_mask, a, 0.0)
        outs.append(
            jax.ops.segment_sum(values[:, i] * a[:, None], dst,
                                num_segments=n_nodes,
                                indices_are_sorted=True)
        )
    return jnp.stack(outs, axis=1)  # (N, H, D)


# ---------------------------------------------------------------------------
# Tiny NN toolbox (no flax available)
# ---------------------------------------------------------------------------

def init_mlp(key, sizes: list[int], dtype=jnp.float32) -> list[dict]:
    ps = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        ps.append({
            "w": (jax.random.normal(k1, (a, b), jnp.float32) * a**-0.5).astype(dtype),
            "b": jnp.zeros((b,), dtype),
        })
    return ps


def mlp(ps: list[dict], x: jax.Array, act=jax.nn.relu,
        final_act: bool = False) -> jax.Array:
    for i, p in enumerate(ps):
        x = x @ p["w"] + p["b"]
        if i < len(ps) - 1 or final_act:
            x = act(x)
    return x


def layer_norm(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    m = jnp.mean(x32, axis=-1, keepdims=True)
    v = jnp.var(x32, axis=-1, keepdims=True)
    return (x32 - m) * jax.lax.rsqrt(v + eps)


def mse_loss(pred: jax.Array, target: jax.Array, mask: jax.Array) -> jax.Array:
    err = jnp.where(mask[:, None], (pred - target) ** 2, 0.0)
    return jnp.sum(err) / jnp.maximum(jnp.sum(mask) * pred.shape[-1], 1)


def masked_ce(logits: jax.Array, labels: jax.Array, mask: jax.Array):
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    nll = jnp.where(mask, lse - ll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)
