"""GNN family. Message passing = the paper's pipeline (DESIGN.md §3):
edges are sorted by destination once at load time (the Sort phase), and
aggregation is a sorted segment reduce (the ReduceDuplicate phase) — the
same machinery as the SPARQL join, with node ids as keys.
"""
