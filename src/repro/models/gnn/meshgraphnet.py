"""MeshGraphNet (arXiv:2010.03409): encode-process-decode with residual
edge/node update blocks. n_layers=15, d=128, 2-layer MLPs + LayerNorm.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class MGNConfig:
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 8
    d_edge_in: int = 4
    d_out: int = 3
    # §Perf iterations 1-4: axes the node dim shards over on large graphs
    node_spec: tuple[str, ...] = ()
    remat: bool = False
    compute_dtype: object = None  # set to jnp.bfloat16 on large graphs
    shuffle_gather: bool = False  # MapSQ shuffle gather/scatter (iter 4)


def _mlp_sizes(cfg: MGNConfig, d_in: int) -> list[int]:
    return [d_in] + [cfg.d_hidden] * cfg.mlp_layers


def init_params(key: jax.Array, cfg: MGNConfig) -> dict:
    ks = iter(jax.random.split(key, 3 + 2 * cfg.n_layers))
    d = cfg.d_hidden
    blocks = []
    for _ in range(cfg.n_layers):
        blocks.append({
            "edge": C.init_mlp(next(ks), _mlp_sizes(cfg, 3 * d)),
            "node": C.init_mlp(next(ks), _mlp_sizes(cfg, 2 * d)),
        })
    return {
        "enc_node": C.init_mlp(next(ks), _mlp_sizes(cfg, cfg.d_node_in)),
        "enc_edge": C.init_mlp(next(ks), _mlp_sizes(cfg, cfg.d_edge_in)),
        "blocks": blocks,
        "dec": C.init_mlp(next(ks), [d, d, cfg.d_out]),
    }


def apply(params: dict, g: C.GraphBatch, cfg: MGNConfig) -> jax.Array:
    n = g.n_nodes
    ns = cfg.node_spec
    dt = cfg.compute_dtype or g.node_feat.dtype
    x = C.constrain_nodes(
        C.layer_norm(C.mlp(params["enc_node"],
                           g.node_feat.astype(dt))).astype(dt), ns)
    e = C.layer_norm(C.mlp(params["enc_edge"],
                           g.extras["edge_feat"].astype(dt))).astype(dt)

    sg = cfg.shuffle_gather

    def block(p, x, e):
        xs = C.take_nodes(x, g.src, g.edge_mask, ns, sg)
        xd = C.take_nodes(x, g.dst, g.edge_mask, ns, sg)
        e_in = jnp.concatenate([e, xs, xd], axis=-1)
        e = e + C.layer_norm(C.mlp(p["edge"], e_in)).astype(dt)
        agg = C.aggregate_nodes(e, g.dst, n, g.edge_mask, ns, sg)
        x = x + C.layer_norm(
            C.mlp(p["node"], jnp.concatenate([x, agg], -1))).astype(dt)
        return C.constrain_nodes(x, ns), e

    blk = jax.checkpoint(block) if cfg.remat else block
    for p in params["blocks"]:
        x, e = blk(p, x, e)
    out = C.mlp(params["dec"], x)
    return jnp.where(g.node_mask[:, None], out, 0.0)


def loss_fn(params, g: C.GraphBatch, cfg: MGNConfig):
    pred = apply(params, g, cfg)
    return C.mse_loss(pred, g.extras["targets"], g.node_mask)
