"""Real neighbor sampler for minibatch_lg (fanout 15-10), host-side.

CSR adjacency built once; per-batch GraphSAGE-style layered sampling with a
deterministic np.random.Generator (its state is part of the data-pipeline
checkpoint). Output is a static-shape padded GraphBatch: capacity =
batch * (1 + f1 + f1*f2) nodes, batch * (f1 + f1*f2) edges, dst-sorted.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray  # (N+1,)
    indices: np.ndarray  # (E,)

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray, n_nodes: int):
        """CSR over incoming edges: row v lists the neighbors that message v."""
        order = np.argsort(dst, kind="stable")
        dst_s = dst[order]
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(indptr, dst_s + 1, 1)
        indptr = np.cumsum(indptr)
        return cls(indptr=indptr, indices=src[order].astype(np.int32))


def sample_block(
    g: CSRGraph,
    seeds: np.ndarray,
    fanouts: list[int],
    rng: np.random.Generator,
):
    """Layered fanout sampling (with replacement, GraphSAGE-style).

    Returns (nodes, src, dst, edge_mask) where src/dst index into `nodes`
    (position-based ids), edges are sorted by dst, and padded entries point
    at the sentinel slot len(nodes)-1 with edge_mask False.
    """
    frontier = seeds.astype(np.int32)
    all_nodes = [frontier]
    e_src, e_dst = [], []
    offset = 0  # position of the current frontier inside all_nodes
    for f in fanouts:
        deg = g.indptr[frontier + 1] - g.indptr[frontier]
        picks = rng.integers(
            0, np.maximum(deg, 1)[:, None], size=(len(frontier), f)
        )
        nbr = g.indices[
            np.minimum(g.indptr[frontier, None] + picks,
                       len(g.indices) - 1)
        ].astype(np.int32)
        has_deg = deg > 0
        nbr = np.where(has_deg[:, None], nbr, frontier[:, None])  # self-loop
        new_pos = offset + len(frontier) + np.arange(nbr.size, dtype=np.int32)
        # edge: sampled neighbor (child layer) -> frontier node
        e_src.append(new_pos)
        e_dst.append(np.repeat(offset + np.arange(len(frontier),
                                                  dtype=np.int32), f))
        all_nodes.append(nbr.reshape(-1))
        offset += len(frontier)
        frontier = nbr.reshape(-1)
    nodes = np.concatenate(all_nodes)
    src = np.concatenate(e_src)
    dst = np.concatenate(e_dst)
    order = np.argsort(dst, kind="stable")  # the Sort phase, host-side
    return nodes, src[order], dst[order], np.ones(len(src), bool)


def block_capacity(batch: int, fanouts: list[int]) -> tuple[int, int]:
    n, e, layer = batch, 0, batch
    for f in fanouts:
        e += layer * f
        layer *= f
        n += layer
    return n, e
