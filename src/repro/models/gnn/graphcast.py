"""GraphCast-style encoder-processor-decoder mesh GNN (arXiv:2212.12794).

Grid nodes carry n_vars=227 features; a coarser mesh (n_mesh = N/4 here,
standing in for the refined icosahedron) runs 16 interaction-network
processor layers; grid→mesh and mesh→grid bipartite GNN blocks encode and
decode. Every aggregation is a dst-sorted segment sum — the MapSQ reduce.

The assigned shape grid (full_graph_sm / minibatch_lg / ogb_products /
molecule) supplies (n_nodes, n_edges); mesh sizes derive from them (see
configs/gnn_shapes.py) so every (arch × shape) cell is well-defined.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    n_layers: int = 16  # processor depth
    d_hidden: int = 512
    n_vars: int = 227
    mesh_refinement: int = 6  # recorded; mesh size derives from the shape
    # §Perf iterations 1-5: axes the node dim shards over on large graphs
    node_spec: tuple[str, ...] = ()
    remat: bool = False  # checkpoint each processor block
    compute_dtype: object = jnp.float32  # bf16 halves node/edge traffic
    shuffle_gather: bool = False  # MapSQ shuffle gather/scatter (iter 4)
    # iter 5: stream the g2m/m2g edge sets through a scan in ~this many
    # chunks (their edge features are consumed once, so nothing O(E·d)
    # ever lives). 0 = off.
    edge_stream_chunks: int = 0


def _block_init(key, d):
    k1, k2 = jax.random.split(key)
    return {
        "edge": C.init_mlp(k1, [3 * d, d, d]),
        "node": C.init_mlp(k2, [2 * d, d, d]),
    }


def init_params(key: jax.Array, cfg: GraphCastConfig) -> dict:
    ks = iter(jax.random.split(key, 8 + cfg.n_layers))
    d = cfg.d_hidden
    return {
        "enc_grid": C.init_mlp(next(ks), [cfg.n_vars, d, d]),
        "mesh_init": jax.random.normal(next(ks), (1, d), jnp.float32) * 0.02,
        "enc_g2m_edge": C.init_mlp(next(ks), [4, d, d]),
        "g2m": _block_init(next(ks), d),
        "enc_mesh_edge": C.init_mlp(next(ks), [4, d, d]),
        "processor": [_block_init(next(ks), d) for _ in range(cfg.n_layers)],
        "enc_m2g_edge": C.init_mlp(next(ks), [4, d, d]),
        "m2g": _block_init(next(ks), d),
        "dec_grid": C.init_mlp(next(ks), [d, d, cfg.n_vars]),
    }


def _bipartite_block(p, e_feat, x_src_tab, x_dst_tab, src, dst, mask, n_dst,
                     node_spec=(), shuffle=False):
    """Interaction-network block over a (possibly bipartite) edge set.
    (n_dst / node_spec / shuffle are static — last, for jax.checkpoint.)"""
    xs = C.take_nodes(x_src_tab, src, mask, node_spec, shuffle)
    xd = C.take_nodes(x_dst_tab, dst, mask, node_spec, shuffle)
    e_in = jnp.concatenate([e_feat, xs, xd], -1)
    e = e_feat + C.layer_norm(C.mlp(p["edge"], e_in)).astype(e_feat.dtype)
    agg = C.aggregate_nodes(e, dst, n_dst, mask, node_spec, shuffle)
    x = x_dst_tab + C.layer_norm(
        C.mlp(p["node"], jnp.concatenate([x_dst_tab, agg], -1))
    ).astype(x_dst_tab.dtype)
    return e, C.constrain_nodes(x, node_spec)


def _pick_chunks(e: int, want: int) -> int:
    """Largest divisor of e//512 that is <= want (chunks must keep the
    512-way edge sharding divisible)."""
    base = max(1, e // 512)
    best = 1
    for k in range(1, min(want, base) + 1):
        if base % k == 0:
            best = k
    return best


def _bipartite_block_streamed(p, enc_p, raw_ef, x_src_tab, x_dst_tab, src,
                              dst, mask, n_dst, node_spec, n_chunks):
    """iter 5 (§Perf): one-shot edge sets (g2m / m2g) processed in chunks —
    encode chunk → shuffle-gather endpoints → edge MLP → shuffle-scatter
    partial aggregate. No O(E·d) tensor is ever resident."""
    e = src.shape[0]
    n_chunks = _pick_chunks(e, n_chunks)
    c = e // n_chunks
    dt = x_dst_tab.dtype
    d = x_dst_tab.shape[-1]

    def chunked(a):
        return a.reshape((n_chunks, c) + a.shape[1:])

    def body(agg, inp):
        ef_c, src_c, dst_c, m_c = inp
        e_enc = C.layer_norm(C.mlp(enc_p, ef_c.astype(dt))).astype(dt)
        xs = C.take_nodes(x_src_tab, src_c, m_c, node_spec, True)
        xd = C.take_nodes(x_dst_tab, dst_c, m_c, node_spec, True)
        e_in = jnp.concatenate([e_enc, xs, xd], -1)
        e_out = e_enc + C.layer_norm(C.mlp(p["edge"], e_in)).astype(dt)
        agg = agg + C.aggregate_nodes(e_out, dst_c, n_dst, m_c, node_spec,
                                      True)
        return C.constrain_nodes(agg, node_spec), None

    agg0 = C.constrain_nodes(jnp.zeros((n_dst, d), dt), node_spec)
    agg, _ = jax.lax.scan(
        body, agg0, (chunked(raw_ef), chunked(src), chunked(dst),
                     chunked(mask)))
    x = x_dst_tab + C.layer_norm(
        C.mlp(p["node"], jnp.concatenate([x_dst_tab, agg], -1))
    ).astype(dt)
    return C.constrain_nodes(x, node_spec)


def apply(params: dict, g: C.GraphBatch, cfg: GraphCastConfig) -> jax.Array:
    ex = g.extras
    n_grid = g.n_nodes
    n_mesh = ex["mesh_feat_init"].shape[0]
    ns = cfg.node_spec
    dt = cfg.compute_dtype
    xg = C.constrain_nodes(
        C.layer_norm(C.mlp(params["enc_grid"],
                           g.node_feat.astype(dt))).astype(dt), ns)
    xm = C.constrain_nodes(
        jnp.broadcast_to(params["mesh_init"].astype(dt),
                         (n_mesh, cfg.d_hidden)), ns)
    blk = (jax.checkpoint(_bipartite_block, static_argnums=(7, 8, 9))
           if cfg.remat else _bipartite_block)
    sg = cfg.shuffle_gather
    stream = cfg.edge_stream_chunks
    if stream:  # iter 5: one-shot edge sets never materialize at O(E·d)
        sblk = (jax.checkpoint(_bipartite_block_streamed,
                               static_argnums=(8, 9, 10))
                if cfg.remat else _bipartite_block_streamed)
        xm = sblk(params["g2m"], params["enc_g2m_edge"], ex["g2m_feat"],
                  xg, xm, g.src, g.dst, g.edge_mask, n_mesh, ns, stream)
    else:
        # encoder: grid -> mesh (edges of the GraphBatch ARE the g2m set)
        e_g2m = C.layer_norm(C.mlp(params["enc_g2m_edge"],
                                   ex["g2m_feat"].astype(dt))).astype(dt)
        _, xm = blk(params["g2m"], e_g2m, xg, xm, g.src,
                    g.dst, g.edge_mask, n_mesh, ns, sg)
    # processor: 16 interaction layers on the mesh graph (edge features are
    # carried across layers, so these stay resident — mesh edges are small)
    e_m = C.layer_norm(C.mlp(params["enc_mesh_edge"],
                             ex["mesh_edge_feat"].astype(dt))).astype(dt)
    for p in params["processor"]:
        e_m, xm = blk(p, e_m, xm, xm, ex["mesh_src"],
                      ex["mesh_dst"], ex["mesh_mask"], n_mesh, ns, sg)
    # decoder: mesh -> grid
    if stream:
        xg = sblk(params["m2g"], params["enc_m2g_edge"], ex["m2g_feat"],
                  xm, xg, ex["m2g_src"], ex["m2g_dst"], ex["m2g_mask"],
                  n_grid, ns, stream)
    else:
        e_m2g = C.layer_norm(C.mlp(params["enc_m2g_edge"],
                                   ex["m2g_feat"].astype(dt))).astype(dt)
        _, xg = blk(params["m2g"], e_m2g, xm, xg, ex["m2g_src"],
                    ex["m2g_dst"], ex["m2g_mask"], n_grid, ns, sg)
    out = C.mlp(params["dec_grid"], xg).astype(jnp.float32)
    return jnp.where(g.node_mask[:, None], out, 0.0)


def loss_fn(params, g: C.GraphBatch, cfg: GraphCastConfig):
    pred = apply(params, g, cfg)
    return C.mse_loss(pred, g.extras["targets"], g.node_mask)
