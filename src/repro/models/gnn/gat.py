"""GAT (Velickovic et al., arXiv:1710.10903) — SDDMM/SpMM regime.

Edge attention = per-edge score (SDDMM analogue via gathers), segment
softmax over dst (sorted; the MapSQ reduce), weighted segment sum (SpMM).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class GATConfig:
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    n_classes: int = 7
    d_in: int = 1433
    negative_slope: float = 0.2


def init_params(key: jax.Array, cfg: GATConfig) -> dict:
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        h = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        k1, k2, k3, key = jax.random.split(key, 4)
        layers.append({
            "w": jax.random.normal(k1, (d_in, h, d_out), jnp.float32) * d_in**-0.5,
            "a_src": jax.random.normal(k2, (h, d_out), jnp.float32) * d_out**-0.5,
            "a_dst": jax.random.normal(k3, (h, d_out), jnp.float32) * d_out**-0.5,
            "b": jnp.zeros((h, d_out), jnp.float32),
        })
        d_in = d_out * h if not last else d_out
    return {"layers": layers}


def apply(params: dict, g: C.GraphBatch, cfg: GATConfig) -> jax.Array:
    x = g.node_feat
    n = g.n_nodes
    for i, p in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1
        h = jnp.einsum("nf,fhd->nhd", x, p["w"])  # (N, H, D)
        s_src = jnp.einsum("nhd,hd->nh", h, p["a_src"])
        s_dst = jnp.einsum("nhd,hd->nh", h, p["a_dst"])
        scores = jax.nn.leaky_relu(
            s_src[g.src] + s_dst[g.dst], cfg.negative_slope
        )  # (E, H)
        agg = C.aggregate_softmax(scores, h[g.src], g.dst, n, g.edge_mask)
        agg = agg + p["b"][None]
        if last:
            x = jnp.mean(agg, axis=1)  # average heads -> (N, C)
        else:
            x = jax.nn.elu(agg).reshape(n, -1)  # concat heads
        x = jnp.where(g.node_mask[:, None], x, 0)
    return x


def loss_fn(params, g: C.GraphBatch, cfg: GATConfig):
    logits = apply(params, g, cfg)
    labels = g.extras["labels"]
    mask = g.extras["train_mask"] & g.node_mask
    return C.masked_ce(logits, labels, mask)
