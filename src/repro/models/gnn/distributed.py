"""Distributed node gather/scatter via the MapSQ shuffle (§Perf iteration 4).

On 2.45M-node graphs, GSPMD implements `x[src]` (node table sharded, edge
indices sharded) by all-gathering the FULL node table per use — 2.5 GB × 18
blocks resident, 118 GiB/chip. This module replaces those gathers/scatters
with the paper's own mechanism: requests are sorted by owner shard, shipped
over one `all_to_all`, served locally, and shipped back (Map → Sort →
Shuffle → Reduce). Per-device traffic is then O(E_local·d), never O(N·d).

Both ops run inside `shard_map` over the node-sharding axes and reuse
models/moe.py's route_plan / bucket machinery — the same join, fourth
consumer. Gradients are exact (all_to_all and scatter-add have exact
transposes); capacity overflow drops are sized at 2× the uniform
expectation and flagged in the docstring contract.
"""
from __future__ import annotations

from functools import partial, reduce

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.models.moe import gather_from_buckets, route_plan, \
    scatter_to_buckets


def _flat_rank(axes: tuple[str, ...]) -> jax.Array:
    rank = jnp.int32(0)
    for a in axes:
        rank = rank * compat.axis_size(a) + jax.lax.axis_index(a)
    return rank


def _ndev(axes: tuple[str, ...]) -> int:
    return reduce(lambda x, a: x * compat.axis_size(a), axes, 1)


def _gather_local(x_local, ids, valid, *, axes, cap):
    """Per-device body: fetch rows of the node table for global ids."""
    ndev = _ndev(axes)
    rank = _flat_rank(axes)
    n_loc = x_local.shape[0]
    owner = (ids // n_loc).astype(jnp.int32)
    order, slot, ok = route_plan(owner, valid, ndev, cap)
    send = scatter_to_buckets(ids.astype(jnp.int32), order, slot, ok, ndev,
                              cap)
    recv = jax.lax.all_to_all(send, axes, 0, 0, tiled=False)  # (ndev, cap)
    local_idx = jnp.clip(recv.reshape(-1) - rank * n_loc, 0, n_loc - 1)
    rows = x_local[local_idx].reshape(ndev, cap, -1)
    back = jax.lax.all_to_all(rows, axes, 0, 0, tiled=False)
    return gather_from_buckets(back, order, slot, ok, ids.shape[0])


def _scatter_local(msgs, dst, valid, *, axes, cap, n_nodes):
    """Per-device body: sum edge messages into owner shards of the nodes."""
    ndev = _ndev(axes)
    rank = _flat_rank(axes)
    n_loc = n_nodes // ndev
    owner = (dst // n_loc).astype(jnp.int32)
    order, slot, ok = route_plan(owner, valid, ndev, cap)
    send = scatter_to_buckets(msgs, order, slot, ok, ndev, cap)
    send_ids = scatter_to_buckets(dst.astype(jnp.int32), order, slot, ok,
                                  ndev, cap)
    recv = jax.lax.all_to_all(send, axes, 0, 0, tiled=False)
    recv_ids = jax.lax.all_to_all(send_ids, axes, 0, 0, tiled=False)
    flat = recv.reshape(-1, msgs.shape[-1])
    idx = jnp.clip(recv_ids.reshape(-1) - rank * n_loc, 0, n_loc - 1)
    # dropped slots arrive as zero rows -> adding them anywhere is a no-op
    out = jnp.zeros((n_loc, msgs.shape[-1]), flat.dtype)
    return out.at[idx].add(flat)


def _cap_for(n_requests: int, axes: tuple[str, ...], cf: float = 2.0) -> int:
    mesh = compat.ambient_mesh()
    ndev = 1
    for a in axes:
        ndev *= mesh.shape[a]
    per_dev = max(1, n_requests // ndev)
    return ((int(per_dev / ndev * cf) + 15) // 8) * 8


def gather_nodes(x: jax.Array, ids: jax.Array, valid: jax.Array,
                 axes: tuple[str, ...]) -> jax.Array:
    """x: (N, d) sharded P(axes, None); ids/valid: (E,) sharded P(axes).
    Returns (E, d) rows, edge-sharded. O(E·d/ndev) traffic per device."""
    cap = _cap_for(ids.shape[0], axes)
    fn = compat.shard_map(
        partial(_gather_local, axes=axes, cap=cap),
        in_specs=(P(axes, None), P(axes), P(axes)),
        out_specs=P(axes, None),
        check_vma=False,
    )
    return fn(x, ids, valid)


def scatter_add_nodes(msgs: jax.Array, dst: jax.Array, valid: jax.Array,
                      n_nodes: int, axes: tuple[str, ...]) -> jax.Array:
    """msgs: (E, d) edge-sharded; returns (N, d) node table P(axes, None)."""
    cap = _cap_for(dst.shape[0], axes)
    fn = compat.shard_map(
        partial(_scatter_local, axes=axes, cap=cap, n_nodes=n_nodes),
        in_specs=(P(axes, None), P(axes), P(axes)),
        out_specs=P(axes, None),
        check_vma=False,
    )
    return fn(msgs, dst, valid)
