"""SchNet (arXiv:1706.08566) — triplet-gather regime (distance-expanded
continuous-filter convolutions); aggregation is the sorted segment sum.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.gnn import common as C


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    max_z: int = 100


def ssp(x):  # shifted softplus, SchNet's activation
    return jax.nn.softplus(x) - jnp.log(2.0)


def init_params(key: jax.Array, cfg: SchNetConfig) -> dict:
    ks = iter(jax.random.split(key, 4 + 4 * cfg.n_interactions))
    d = cfg.d_hidden
    inter = []
    for _ in range(cfg.n_interactions):
        inter.append({
            "w_in": C.init_mlp(next(ks), [d, d]),
            "filter": C.init_mlp(next(ks), [cfg.n_rbf, d, d]),
            "w_out": C.init_mlp(next(ks), [d, d, d]),
        })
    return {
        "embed": jax.random.normal(next(ks), (cfg.max_z, d), jnp.float32) * 0.1,
        "inter": inter,
        "readout": C.init_mlp(next(ks), [d, d // 2, 1]),
    }


def rbf_expand(dist: jax.Array, cfg: SchNetConfig) -> jax.Array:
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = 10.0 / cfg.cutoff
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def apply(params: dict, g: C.GraphBatch, cfg: SchNetConfig) -> jax.Array:
    """Per-graph energies: (n_graphs,)."""
    pos = g.extras["positions"]  # (N, 3)
    species = g.extras["species"]  # (N,) int32
    n = g.n_nodes
    x = params["embed"][jnp.clip(species, 0, cfg.max_z - 1)]
    d_ij = jnp.linalg.norm(pos[g.src] - pos[g.dst] + 1e-12, axis=-1)
    rbf = rbf_expand(d_ij, cfg)  # (E, n_rbf)
    for p in params["inter"]:
        filt = C.mlp(p["filter"], rbf, act=ssp, final_act=True)  # (E, D)
        msg = C.mlp(p["w_in"], x, act=ssp)[g.src] * filt  # cfconv
        agg = C.aggregate(msg, g.dst, n, g.edge_mask)
        x = x + C.mlp(p["w_out"], agg, act=ssp)
    atom_e = C.mlp(params["readout"], x, act=ssp)[:, 0]  # (N,)
    atom_e = jnp.where(g.node_mask, atom_e, 0.0)
    n_graphs = g.extras["energy"].shape[0]  # static from the batch shape
    return jax.ops.segment_sum(atom_e, g.graph_ids, num_segments=n_graphs,
                               indices_are_sorted=True)


def loss_fn(params, g: C.GraphBatch, cfg: SchNetConfig):
    energy = apply(params, g, cfg)
    target = g.extras["energy"]  # (n_graphs,)
    gmask = g.extras["graph_mask"]
    err = jnp.where(gmask, (energy - target) ** 2, 0.0)
    return jnp.sum(err) / jnp.maximum(jnp.sum(gmask), 1)
