"""LM transformer family: one config covers the five assigned archs
(olmoe-1b-7b, granite-moe-3b-a800m, qwen2.5-32b, gemma3-1b, deepseek-67b).

Structure: weights are stacked per-layer pytrees scanned with `lax.scan`
(small HLO, fast multi-pod compiles); attention is chunked online-softmax
(flash recurrence in pure JAX — never materializes S×S); MoE layers use the
MapSQ sort-based EP dispatch (models/moe.py) under shard_map for train /
prefill and the one-hot einsum at decode.

Sharding posture (see DESIGN.md §5):
  * batch on ("pod","data"), Megatron TP on "model" (heads / FFN / vocab);
  * residual stream sequence-sharded on "model" between layers (SP) so
    saved activations divide by the model axis;
  * `fsdp=True` archs (qwen-32b, deepseek-67b) additionally shard weight
    matrices over "data" — GSPMD materializes the per-layer all-gather /
    reduce-scatter schedule (ZeRO-3);
  * optimizer state always gets a ZeRO-1 extra "data" sharding (launch/).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import moe as M
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.core import compat


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # MoE (n_experts == 0 -> dense)
    n_experts: int = 0
    top_k: int = 0
    d_expert_ff: int = 0
    capacity_factor: float = 2.0
    # attention pattern
    sliding_window: int = 0  # 0 -> full attention in every layer
    global_every: int = 0  # gemma3: every Nth layer global (5:1 -> 6)
    qkv_bias: bool = False  # qwen
    qk_norm: bool = False  # gemma3
    rope_theta: float = 1e4
    rope_theta_local: float = 0.0  # gemma3 local layers (0 -> same)
    embed_scale: bool = False  # gemma: x *= sqrt(d_model)
    tied_embeddings: bool = False
    # distribution
    fsdp: bool = False
    seq_shard: bool = True
    remat: bool = True
    dtype: Any = jnp.bfloat16
    kv_chunk: int = 1024
    # Fully unroll the layer scan. Used by the dry-run's cost probes:
    # XLA's cost_analysis counts a while-loop body ONCE, so only an
    # unrolled module yields true FLOP/byte/collective counts.
    scan_unroll: bool = False
    # §Perf iteration (deepseek/qwen train): fuse head-projection + CE in
    # sequence chunks so the (B, S, V) logits tensor never materializes.
    # 0 = off (loss over full logits).
    ce_chunk: int = 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def padded_vocab(self) -> int:
        """Physical vocab rows: padded to 256 so the embedding/head shard
        over any mesh axis combination (padding logits are masked out)."""
        return ((self.vocab + 255) // 256) * 256

    def moe_settings(self) -> M.MoESettings:
        return M.MoESettings(
            self.n_experts, self.top_k, self.d_expert_ff, self.capacity_factor
        )

    def is_global_layers(self) -> jnp.ndarray:
        idx = jnp.arange(self.n_layers)
        if self.global_every <= 0:
            return jnp.ones((self.n_layers,), bool)
        return (idx + 1) % self.global_every == 0

    def rope_thetas(self) -> jnp.ndarray:
        base = jnp.full((self.n_layers,), self.rope_theta, jnp.float32)
        if self.rope_theta_local <= 0 or self.global_every <= 0:
            return base
        return jnp.where(
            self.is_global_layers(), base, self.rope_theta_local
        )


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: TransformerConfig, ep: int = 1) -> dict:
    """`ep` = size of the expert/model axis (for expert padding)."""
    ks = iter(jax.random.split(key, 32))
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    lyr = cfg.n_layers
    dt = cfg.dtype

    def w(shape, fan_in):
        return L.dense_init(next(ks), shape, fan_in, dt)

    attn = {
        "wq": w((lyr, d, h * dh), d),
        "wk": w((lyr, d, kv * dh), d),
        "wv": w((lyr, d, kv * dh), d),
        "wo": w((lyr, h * dh, d), h * dh),
    }
    if cfg.qkv_bias:
        attn["bq"] = jnp.zeros((lyr, h * dh), dt)
        attn["bk"] = jnp.zeros((lyr, kv * dh), dt)
        attn["bv"] = jnp.zeros((lyr, kv * dh), dt)
    if cfg.qk_norm:
        attn["qnorm"] = jnp.zeros((lyr, dh), dt)
        attn["knorm"] = jnp.zeros((lyr, dh), dt)
    blocks: dict[str, Any] = {
        "ln1": jnp.zeros((lyr, d), dt),
        "ln2": jnp.zeros((lyr, d), dt),
        "attn": attn,
    }
    if cfg.is_moe:
        st = cfg.moe_settings()
        moe0 = M.init_moe_params(next(ks), d, st, ep, dt)
        blocks["moe"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (lyr,) + a.shape), moe0
        )._asdict()
    else:
        blocks["ffn"] = {
            "w_gate": w((lyr, d, cfg.d_ff), d),
            "w_up": w((lyr, d, cfg.d_ff), d),
            "w_down": w((lyr, cfg.d_ff, d), cfg.d_ff),
        }
    params = {
        "embed": w((cfg.padded_vocab, d), d),
        "blocks": blocks,
        "ln_f": jnp.zeros((d,), dt),
    }
    if not cfg.tied_embeddings:
        params["head"] = w((d, cfg.padded_vocab), d)
    return params


def count_params(cfg: TransformerConfig, ep: int = 1) -> tuple[int, int]:
    """(total, active) parameter counts — active discounts unused experts."""
    shapes = jax.eval_shape(lambda k: init_params(k, cfg, ep),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = sum(int(math.prod(a.shape)) for a in jax.tree.leaves(shapes))
    # discount dead vocab-padding rows from the 'useful param' count
    pad_rows = cfg.padded_vocab - cfg.vocab
    total -= pad_rows * cfg.d_model * (1 if cfg.tied_embeddings else 2)
    active = total
    if cfg.is_moe:
        st = cfg.moe_settings()
        e_pad = st.e_pad(ep)
        per_expert = 3 * cfg.d_model * cfg.d_expert_ff
        expert_total = cfg.n_layers * e_pad * per_expert
        expert_active = cfg.n_layers * cfg.top_k * per_expert
        active = total - expert_total + expert_active
    return total, active


# ---------------------------------------------------------------------------
# Partition specs
# ---------------------------------------------------------------------------

def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def param_specs(cfg: TransformerConfig, multi_pod: bool, model_size: int) -> dict:
    fs = "data" if cfg.fsdp else None
    kv_shardable = (cfg.n_kv_heads % model_size == 0)
    kvs = "model" if kv_shardable else None
    attn = {
        "wq": P(None, fs, "model"),
        "wk": P(None, fs, kvs),
        "wv": P(None, fs, kvs),
        "wo": P(None, "model", fs),
    }
    if cfg.qkv_bias:
        attn.update(bq=P(None, "model"), bk=P(None, kvs), bv=P(None, kvs))
    if cfg.qk_norm:
        attn.update(qnorm=P(None, None), knorm=P(None, None))
    blocks: dict[str, Any] = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "attn": attn,
    }
    if cfg.is_moe:
        blocks["moe"] = {
            "router": P(None, None, None),
            "we_gate": P(None, "model", fs, None),
            "we_up": P(None, "model", fs, None),
            "we_down": P(None, "model", None, fs),
        }
    else:
        blocks["ffn"] = {
            "w_gate": P(None, fs, "model"),
            "w_up": P(None, fs, "model"),
            "w_down": P(None, "model", fs),
        }
    specs = {
        "embed": P("model", fs),
        "blocks": blocks,
        "ln_f": P(None),
    }
    if not cfg.tied_embeddings:
        specs["head"] = P(fs, "model")
    return specs


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _moe_specs_one_layer(cfg: TransformerConfig) -> M.MoEParams:
    return M.MoEParams(
        router=P(None, None),
        we_gate=P("model", None, None),
        we_up=P("model", None, None),
        we_down=P("model", None, None),
    )


def _forward_trunk(
    params: dict,
    tokens: jax.Array,
    cfg: TransformerConfig,
    mesh: jax.sharding.Mesh,
    multi_pod: bool,
    *,
    collect_cache: bool = False,
):
    """Embed + layer stack + final norm. Returns (x, aux, caches|None)."""
    dp = dp_axes(multi_pod)
    sp = "model" if cfg.seq_shard else None

    def _c(arr, spec):  # sharding constraint bound to our mesh
        return jax.lax.with_sharding_constraint(
            arr, jax.sharding.NamedSharding(mesh, spec)
        )

    x_spec = P(dp, sp, None)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    x = _c(x, x_spec)

    st = cfg.moe_settings() if cfg.is_moe else None
    if cfg.is_moe:
        moe_local = partial(M.moe_ffn_ep_local, st=st, expert_axis="model")
        token_spec = P(dp, "model", None)
        moe_ep = compat.shard_map(
            moe_local,
            mesh=mesh,
            in_specs=(_moe_specs_one_layer(cfg), token_spec),
            out_specs=token_spec,
            check_vma=False,
        )

    window = cfg.sliding_window if cfg.sliding_window > 0 else s + 1

    def block(x, xs):
        p, is_global, theta = xs
        h = L.rms_norm(x, p["ln1"])
        ap = L.AttnParams(
            wq=p["attn"]["wq"], wk=p["attn"]["wk"], wv=p["attn"]["wv"],
            wo=p["attn"]["wo"],
            bq=p["attn"].get("bq"), bk=p["attn"].get("bk"),
            bv=p["attn"].get("bv"),
        )
        attn_out, kc, vc = _attention_prefill_cached(
            ap, h, cfg, is_global=is_global, window=window, theta=theta,
            qk=(p["attn"].get("qnorm"), p["attn"].get("knorm")),
        )
        x = x + attn_out
        x = _c(x, x_spec)
        h2 = L.rms_norm(x, p["ln2"])
        if cfg.is_moe:
            h2 = _c(h2, P(dp, "model", None))
            moe_p = M.MoEParams(**{k: p["moe"][k] for k in M.MoEParams._fields})
            y = moe_ep(moe_p, h2)
        else:
            fp = L.FFNParams(**p["ffn"])
            y = L.swiglu_ffn(fp, h2)
        x = x + y
        x = _c(x, x_spec)
        ys = (kc, vc) if collect_cache else None
        return x, ys

    body = jax.checkpoint(block) if cfg.remat else block
    xs = (params["blocks"], cfg.is_global_layers(), cfg.rope_thetas())
    x, caches = jax.lax.scan(body, x, xs,
                             unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = L.rms_norm(x, params["ln_f"])

    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        # Load-balance loss from the last layer's router on the final
        # hidden state (cheap proxy; per-layer stats cost one extra scan).
        moe_p0 = jax.tree.map(lambda a: a[-1], params["blocks"]["moe"])
        aux = M.moe_aux_loss(
            M.MoEParams(**moe_p0), x, st, st.e_pad(_axis_size(mesh, "model"))
        )
    return x, aux, caches


def forward(
    params: dict,
    tokens: jax.Array,
    cfg: TransformerConfig,
    mesh: jax.sharding.Mesh,
    multi_pod: bool,
    *,
    collect_cache: bool = False,
):
    """Full-sequence forward. Returns (logits, aux_loss, caches|None)."""
    x, aux, caches = _forward_trunk(
        params, tokens, cfg, mesh, multi_pod, collect_cache=collect_cache)
    dp = dp_axes(multi_pod)
    head = params["embed"].T if cfg.tied_embeddings else params["head"]
    logits = x @ head.astype(cfg.dtype)
    if cfg.padded_vocab != cfg.vocab:  # mask dead padding columns
        logits = jnp.where(
            jnp.arange(cfg.padded_vocab) < cfg.vocab, logits, L.NEG_INF
        )
    # S and V can't both sit on "model": keep vocab sharded for the loss.
    logits = jax.lax.with_sharding_constraint(
        logits, jax.sharding.NamedSharding(mesh, P(dp, None, "model")))
    return logits, aux, caches


def forward_hidden(params, tokens, cfg, mesh, multi_pod):
    """forward() up to the final RMSNorm — no head projection (the chunked
    CE path fuses projection into the loss). Returns (x, aux_loss)."""
    x, aux, _ = _forward_trunk(params, tokens, cfg, mesh, multi_pod)
    return x, aux


def _axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _attention_prefill_cached(ap, h, cfg, *, is_global, window, theta, qk):
    """attention_prefill + expose post-RoPE K/V for prefill cache export."""
    b, s, _ = h.shape
    positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    q, k, v = L._project_qkv(ap, h, cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    if qk[0] is not None:
        q = L.rms_norm(q, qk[0])
        k = L.rms_norm(k, qk[1])
    q = L.rope(q, positions, theta)
    k = L.rope(k, positions, theta)
    out = _flash_core(
        q, k, v, cfg, is_global=is_global, window=window
    )
    return out.astype(h.dtype) @ ap.wo, k, v


def _flash_core(q, k, v, cfg, *, is_global, window):
    """Online-softmax over KV chunks (shared by prefill paths)."""
    b, s, h, dh = q.shape
    n_kv = cfg.n_kv_heads
    g = h // n_kv
    q = q * (dh**-0.5)
    kv_chunk = min(cfg.kv_chunk, s)
    n_chunks = s // kv_chunk
    ck = k.reshape(b, n_chunks, kv_chunk, n_kv, dh).transpose(1, 0, 2, 3, 4)
    cv = v.reshape(b, n_chunks, kv_chunk, n_kv, dh).transpose(1, 0, 2, 3, 4)
    q_idx = jnp.arange(s, dtype=jnp.int32)

    def step(carry, chunk):
        m, l, o = carry
        kc, vc, c0 = chunk
        sc_ = L._grouped_scores(q, kc)
        k_idx = c0 + jnp.arange(kv_chunk, dtype=jnp.int32)
        causal = q_idx[:, None] >= k_idx[None, :]
        in_window = (q_idx[:, None] - k_idx[None, :]) < window
        mask = causal & (is_global | in_window)
        sc_ = jnp.where(mask[None, None, None], sc_, L.NEG_INF)
        m_new = jnp.maximum(m, sc_.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        pr = jnp.exp(sc_ - m_new[..., None])
        l_new = l * alpha + pr.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", pr, vc.astype(jnp.float32)
        )
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, n_kv, g, s), L.NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, s), jnp.float32)
    o0 = jnp.zeros((b, n_kv, g, s, dh), jnp.float32)
    starts = jnp.arange(n_chunks, dtype=jnp.int32) * kv_chunk
    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0), (ck, cv, starts))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(b, s, h * dh)


# ---------------------------------------------------------------------------
# Loss + train step
# ---------------------------------------------------------------------------

def ce_loss(logits: jax.Array, labels: jax.Array, z_loss: float = 1e-4):
    """Cross-entropy over a vocab-sharded last dim (one-hot form keeps GSPMD
    from all-gathering logits: per-shard partial sums + psum)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.bfloat16)
    ll = jnp.einsum("bsv,bsv->bs", lf.astype(jnp.bfloat16), onehot).astype(
        jnp.float32
    )
    nll = jnp.mean(lse - ll)
    return nll + z_loss * jnp.mean(lse**2), nll


def chunked_ce_loss(x: jax.Array, head: jax.Array, labels: jax.Array,
                    cfg: TransformerConfig, z_loss: float = 1e-4):
    """Fused projection + CE over sequence chunks: the (B, S, V) logits
    tensor never lives in memory — only (B, ce_chunk, V/model) slices.
    §Perf iteration on the memory term of the big dense archs."""
    b, s, d = x.shape
    # clamp for short sequences (smoke tests): one chunk when S % chunk != 0
    c = cfg.ce_chunk if (cfg.ce_chunk and s % cfg.ce_chunk == 0) else s
    n_chunks = s // c
    xs = x.reshape(b, n_chunks, c, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n_chunks, c).transpose(1, 0, 2)

    def chunk(carry, inp):
        xc, lc = inp
        logits = xc @ head.astype(xc.dtype)  # (B, c, V)
        if cfg.padded_vocab != cfg.vocab:
            logits = jnp.where(
                jnp.arange(cfg.padded_vocab) < cfg.vocab, logits, L.NEG_INF)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        onehot = jax.nn.one_hot(lc, logits.shape[-1], dtype=jnp.bfloat16)
        ll = jnp.einsum("bsv,bsv->bs", lf.astype(jnp.bfloat16),
                        onehot).astype(jnp.float32)
        nll_sum, z_sum = carry
        return (nll_sum + jnp.sum(lse - ll), z_sum + jnp.sum(lse**2)), None

    (nll_sum, z_sum), _ = jax.lax.scan(
        chunk, (jnp.float32(0), jnp.float32(0)), (xs, ls))
    n = b * s
    return nll_sum / n + z_loss * z_sum / n, nll_sum / n


def make_loss_fn(cfg, mesh, multi_pod, aux_weight: float = 0.01):
    use_chunked = cfg.ce_chunk > 0

    def loss_fn(params, tokens, labels):
        if use_chunked:
            x, aux = forward_hidden(params, tokens, cfg, mesh, multi_pod)
            head = (params["embed"].T if cfg.tied_embeddings
                    else params["head"])
            total, nll = chunked_ce_loss(x, head, labels, cfg)
        else:
            logits, aux, _ = forward(params, tokens, cfg, mesh, multi_pod)
            total, nll = ce_loss(logits, labels)
        total = total + aux_weight * aux
        return total, {"loss": nll, "aux": aux}

    return loss_fn


def make_train_step(
    cfg: TransformerConfig,
    mesh: jax.sharding.Mesh,
    opt_cfg: AdamWConfig,
    multi_pod: bool,
    n_micro: int = 1,
):
    """Returns train_step(params, opt_state, batch)->(params, opt_state,
    metrics). Grad accumulation scans `n_micro` microbatches (straggler
    blast-radius control + activation memory bound)."""
    loss_fn = make_loss_fn(cfg, mesh, multi_pod)
    grad_fn = jax.grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        if n_micro == 1:
            grads, metrics = grad_fn(params, tokens, labels)
        else:
            b = tokens.shape[0]
            mb = b // n_micro
            tk = tokens.reshape(n_micro, mb, -1)
            lb = labels.reshape(n_micro, mb, -1)

            def micro(acc, xs):
                t, l = xs
                g, m = grad_fn(params, t, l)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g
                )
                return acc, m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, ms = jax.lax.scan(micro, zeros, (tk, lb))
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            metrics = jax.tree.map(lambda a: a[-1], ms)
        new_params, new_state, om = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, **om)
        return new_params, new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def make_prefill_step(cfg, mesh, multi_pod):
    def prefill_step(params, tokens):
        logits, _, caches = forward(
            params, tokens, cfg, mesh, multi_pod, collect_cache=True
        )
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        kc, vc = caches
        return next_tok, kc.astype(cfg.dtype), vc.astype(cfg.dtype)

    return prefill_step


def make_serve_step(cfg: TransformerConfig, mesh, multi_pod: bool):
    """decode: (params, kc, vc, pos, tokens(B,)) -> (next (B,), kc', vc').
    kc/vc: (L, B, S_max, K, Dh); pos: () int32 current cache length."""
    st = cfg.moe_settings() if cfg.is_moe else None
    e_pad = st.e_pad(_axis_size(mesh, "model")) if cfg.is_moe else 0

    def serve_step(params, kc, vc, pos, tokens):
        x = params["embed"][tokens][:, None, :].astype(cfg.dtype)  # (B,1,D)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
        window = cfg.sliding_window if cfg.sliding_window > 0 else kc.shape[2] + 1

        def block(x, xs):
            p, kc_l, vc_l, is_global, theta = xs
            h = L.rms_norm(x, p["ln1"])
            ap = L.AttnParams(
                wq=p["attn"]["wq"], wk=p["attn"]["wk"], wv=p["attn"]["wv"],
                wo=p["attn"]["wo"],
                bq=p["attn"].get("bq"), bk=p["attn"].get("bk"),
                bv=p["attn"].get("bv"),
            )
            attn_out, kc_n, vc_n = _decode_attn(
                ap, h, kc_l, vc_l, pos, cfg, is_global, window, theta,
                qk=(p["attn"].get("qnorm"), p["attn"].get("knorm")),
            )
            x = x + attn_out
            h2 = L.rms_norm(x, p["ln2"])
            if cfg.is_moe:
                moe_p = M.MoEParams(
                    **{k: p["moe"][k] for k in M.MoEParams._fields}
                )
                y = M.moe_ffn_onehot(moe_p, h2, st, e_pad)
            else:
                y = L.swiglu_ffn(L.FFNParams(**p["ffn"]), h2)
            return x + y, (kc_n, vc_n)

        xs = (params["blocks"], kc, vc, cfg.is_global_layers(),
              cfg.rope_thetas())
        x, (kc2, vc2) = jax.lax.scan(
            block, x, xs, unroll=cfg.n_layers if cfg.scan_unroll else 1)
        x = L.rms_norm(x, params["ln_f"])
        head = params["embed"].T if cfg.tied_embeddings else params["head"]
        logits = (x @ head.astype(cfg.dtype))[:, 0, :]
        if cfg.padded_vocab != cfg.vocab:
            logits = jnp.where(
                jnp.arange(cfg.padded_vocab) < cfg.vocab, logits, L.NEG_INF
            )
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, kc2, vc2

    return serve_step


def _decode_attn(ap, x, kc, vc, pos, cfg, is_global, window, theta, qk):
    b = x.shape[0]
    s_max = kc.shape[1]
    q, k_new, v_new = L._project_qkv(ap, x, cfg.n_heads, cfg.n_kv_heads,
                                     cfg.d_head)
    if qk[0] is not None:
        q = L.rms_norm(q, qk[0])
        k_new = L.rms_norm(k_new, qk[1])
    posb = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    q = L.rope(q, posb, theta)
    k_new = L.rope(k_new, posb, theta)
    kc = jax.lax.dynamic_update_slice(kc, k_new.astype(kc.dtype),
                                      (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v_new.astype(vc.dtype),
                                      (0, pos, 0, 0))
    scores = L._grouped_scores(q * (cfg.d_head**-0.5), kc)
    k_idx = jnp.arange(s_max, dtype=jnp.int32)
    visible = k_idx <= pos
    in_window = (pos - k_idx) < window
    mask = visible & (is_global | in_window)
    scores = jnp.where(mask[None, None, None, None, :], scores, L.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = L._grouped_values(probs, vc)
    o = o.reshape(b, 1, cfg.n_heads * cfg.d_head).astype(x.dtype)
    return o @ ap.wo, kc, vc


def init_decode_cache(cfg: TransformerConfig, batch: int, s_max: int):
    shape = (cfg.n_layers, batch, s_max, cfg.n_kv_heads, cfg.d_head)
    return jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype)


# ---------------------------------------------------------------------------
# Roofline bookkeeping
# ---------------------------------------------------------------------------

def model_flops(cfg: TransformerConfig, kind: str, batch: int, seq: int,
                ep: int = 1) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (+attention) for inference."""
    total, active = count_params(cfg, ep)
    n_tok = batch * seq
    attn = 4.0 * n_tok * seq * cfg.n_heads * cfg.d_head  # QK^T + PV (causal/2 applied below)
    if kind == "train":
        return 6.0 * active * n_tok + 3.0 * attn / 2
    if kind == "prefill":
        return 2.0 * active * n_tok + attn / 2
    # decode: one token per sequence over a seq-long cache
    return 2.0 * active * batch + 4.0 * batch * seq * cfg.n_heads * cfg.d_head
