"""Train a small MoE LM with the full production stack: registry config
(reduced), MapSQ-dispatch MoE, AdamW, checkpointing, restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--big]

--big uses a ~100M-parameter config (the deliverable-scale run for real
hardware; on this CPU container the default is a few-M-param model so the
example finishes in minutes).
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.core import compat
from repro.data.tokens import Prefetcher, TokenPipeline
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainSettings

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--big", action="store_true")
args = ap.parse_args()

if args.big:  # ~100M params (run this variant on real accelerators)
    cfg = T.TransformerConfig(
        name="olmoe-100m", n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
        d_head=64, d_ff=512, vocab=32768, n_experts=16, top_k=4,
        d_expert_ff=512, kv_chunk=64)
else:  # CPU-friendly miniature of the same architecture
    cfg = T.TransformerConfig(
        name="olmoe-mini", n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
        d_head=32, d_ff=256, vocab=2048, n_experts=8, top_k=2,
        d_expert_ff=128, kv_chunk=32)

mesh = make_local_mesh(data=1, model=jax.device_count())
params = T.init_params(jax.random.PRNGKey(0), cfg, ep=mesh.shape["model"])
total, active = T.count_params(cfg, mesh.shape["model"])
print(f"{cfg.name}: {total / 1e6:.1f}M params ({active / 1e6:.1f}M active)")

opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
step_fn = jax.jit(T.make_train_step(cfg, mesh, opt_cfg, False),
                  donate_argnums=(0, 1))
pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
pf = Prefetcher(pipe)
to_dev = lambda b: {k: jnp.asarray(v) for k, v in b.items()}

with tempfile.TemporaryDirectory() as ckpt_dir:
    trainer = Trainer(
        step_fn, params, pipe, ckpt_dir,
        TrainSettings(total_steps=args.steps, ckpt_every=50, log_every=20),
        to_device=lambda _: to_dev(next(pf)),
    )
    with compat.set_mesh(mesh):
        hist = trainer.run()
pf.close()
first = [h["loss"] for h in hist[:10]]
last = [h["loss"] for h in hist[-10:]]
print(f"loss: first10={sum(first) / len(first):.3f} "
      f"last10={sum(last) / len(last):.3f}")
assert sum(last) < sum(first), "training should reduce loss"
print("TRAINING OK")
