"""The paper's join at multi-chip scale: hash-shuffle (all_to_all) + local
MapReduce join on an 8-device mesh — the same code path the 512-chip
dry-run lowers, executed for real on host devices.

    PYTHONPATH=src python examples/distributed_join.py
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import compat  # noqa: E402
from repro.core.distributed import make_distributed_join  # noqa: E402
from repro.core.relation import Relation  # noqa: E402

mesh = jax.make_mesh((2, 4), ("data", "model"))
n = 1 << 12
rng = np.random.default_rng(0)
left = Relation.from_numpy(("?x", "?y"), np.stack(
    [rng.integers(0, 256, n), np.arange(n)], 1))
right = Relation.from_numpy(("?y", "?z"), np.stack(
    [np.arange(n) % 256, rng.integers(0, 99, n)], 1))
# note: left keys ?y are in column 1... schemas share ?y (left col0 is ?x)

join = make_distributed_join(mesh, ("data", "model"), bucket_capacity=2048,
                             join_capacity=1 << 16,
                             left_schema=("?x", "?y"),
                             right_schema=("?y", "?z"))
with compat.set_mesh(mesh):
    out, totals, overflows = join(left, right)
per_shard = np.asarray(totals)
print(f"8 shards hold {per_shard.sum()} join rows "
      f"(per-shard: {per_shard.tolist()})")
assert not bool(np.asarray(overflows).any())

# verify against the single-device join
from repro.core import mr_join as mj

total_ref = int(mj.mr_join_count(left, right))
assert per_shard.sum() == total_ref, (per_shard.sum(), total_ref)
print(f"matches single-device Algorithm 1 count: {total_ref}")
print("DISTRIBUTED JOIN OK")
