"""Open a LUBM store SHARDED over the local devices and query it.

The triple set is subject-hash partitioned across a device mesh; every
warm query executes as ONE shard_map dispatch — scans read shard-local
partitions, each MapReduce join hash-shuffles by its key over the mesh
(all_to_all) then joins locally, and results gather back to host.

    PYTHONPATH=src python examples/sharded_lubm.py

(The XLA flag below fakes 4 host devices so the example runs on CPU;
on a real TPU/GPU mesh, drop it and the mesh spans the actual chips.)
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=4 "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402

from repro.sparql import lubm  # noqa: E402
from repro.sparql.engine import ShardedQueryEngine  # noqa: E402
from repro.sparql.sharded_store import shard_store  # noqa: E402

store = lubm.generate(scale=1, seed=0)
sharded = shard_store(store, n_shards=jax.device_count())
print(f"{len(store)} triples over {sharded.n_shards} shards: "
      f"{sharded.shard_sizes()} triples per shard")

engine = ShardedQueryEngine(sharded)
pq = engine.prepare(lubm.QUERIES["Q2"])

rows = pq.run()  # cold: calibrates buckets, compiles the mesh program
warm = pq.run()  # warm: ONE shard_map dispatch, zero compiles
print(f"Q2: {len(rows)} rows; warm run = {warm.stats.n_dispatches} "
      f"dispatch, {warm.stats.n_compiles} compiles, per-shard max join "
      f"bucket {warm.stats.peak_join_bucket}")

# the plan report now shows per-shard rows and join/shuffle buckets
print(pq.explain())
