"""Quickstart: the paper's own running example, end to end.

Table 1 of MapSQ: two triple patterns over a tiny hospital graph, joined on
the shared variable ?job by the MapReduce join (Map -> Sort ->
ReduceDuplicate). Run:

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import mr_join as mj
from repro.core.relation import Relation
from repro.sparql.engine import QueryEngine
from repro.sparql.store import store_from_string_triples

# --- 1. the raw Algorithm 1, on the paper's Table 1 data -------------------
# Tp1 = matches of (?person hasJob ?job), keyed by ?job
tp1 = Relation.from_numpy(("?job", "?person"), np.array([
    [0, 10],  # Professor, Anny
    [1, 11],  # Doctor,    Jim
    [2, 12],  # Nurse,     Susan
]), capacity=4)
# Tp2 = matches of (?job workAt "Hospital")
tp2 = Relation.from_numpy(("?job",), np.array([[1], [2]]), capacity=4)

result, total, overflowed = mj.mr_join(tp1, tp2, capacity=8)
print("Algorithm 1 join result (job_id, person_id):")
print(result.to_numpy(), f" exact_total={int(total)}")
assert int(total) == 2 and not bool(overflowed)

# --- 2. the same query through the full engine (parser->planner->join) ----
store = store_from_string_triples([
    ("<anny>", "<hasJob>", "<professor>"),
    ("<jim>", "<hasJob>", "<doctor>"),
    ("<susan>", "<hasJob>", "<nurse>"),
    ("<doctor>", "<workAt>", '"Hospital"'),
    ("<nurse>", "<workAt>", '"Hospital"'),
])
engine = QueryEngine(store)
q = 'SELECT ?person WHERE { ?person <hasJob> ?job . ?job <workAt> "Hospital" . }'
print("\nSPARQL:", q)
prepared = engine.prepare(q)
print(prepared.explain())
print("answers:", prepared.run().rows)
